"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state).  Production target: TPU v5e, 256 chips/pod, 16x16
(data, model); multi-pod adds a leading "pod" axis for cross-pod DP.

``make_mesh_for(n)`` supports *elastic* restarts: given however many
devices survive, it picks the largest (data, model) grid with model <= 16,
and checkpoint restore reshards into it (see repro.checkpointing).

``make_mesh`` / ``abstract_mesh`` are version-compat shims: jax moved the
mesh-construction API between releases (``axis_types=`` kwarg +
``jax.sharding.AxisType`` appeared after 0.4.x; ``AbstractMesh`` changed
from a ``((name, size), ...)`` shape-tuple to ``(sizes, names)``).  All
repo code and tests construct meshes through these two helpers so the same
tree runs on either side of the drift.
"""
from __future__ import annotations

import math

import jax


def _axis_type_kwargs(n: int) -> dict:
    """``axis_types=(Auto,) * n`` on jax versions that have it, else {}."""
    axis_type = getattr(jax.sharding, "AxisType", None)
    if axis_type is None:
        return {}
    return {"axis_types": (axis_type.Auto,) * n}


def make_mesh(shape, axes, **kwargs):
    """``jax.make_mesh`` across the axis_types API drift."""
    try:
        return jax.make_mesh(shape, axes, **_axis_type_kwargs(len(axes)),
                             **kwargs)
    except TypeError:
        return jax.make_mesh(shape, axes, **kwargs)


def abstract_mesh(shape, axes):
    """``jax.sharding.AbstractMesh`` across its signature drift."""
    try:
        return jax.sharding.AbstractMesh(tuple(shape), tuple(axes))
    except TypeError:
        return jax.sharding.AbstractMesh(tuple(zip(axes, shape)))


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return make_mesh(shape, axes)


def make_mesh_for(n_devices: int | None = None, *, max_model: int = 16):
    """Largest (data, model) mesh for an arbitrary device count (elastic)."""
    n = n_devices or len(jax.devices())
    model = math.gcd(n, max_model)
    while model > 1 and n % model:
        model //= 2
    return make_mesh((n // model, model), ("data", "model"))


def describe(mesh) -> str:
    return " x ".join(f"{a}={mesh.shape[a]}" for a in mesh.axis_names)
