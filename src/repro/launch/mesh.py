"""Production mesh construction.

``make_production_mesh`` is a FUNCTION (importing this module never touches
jax device state).  Production target: TPU v5e, 256 chips/pod, 16x16
(data, model); multi-pod adds a leading "pod" axis for cross-pod DP.

``make_mesh_for(n)`` supports *elastic* restarts: given however many
devices survive, it picks the largest (data, model) grid with model <= 16,
and checkpoint restore reshards into it (see repro.checkpointing).
"""
from __future__ import annotations

import math

import jax


def _auto(n: int):
    return (jax.sharding.AxisType.Auto,) * n


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return jax.make_mesh(shape, axes, axis_types=_auto(len(axes)))


def make_mesh_for(n_devices: int | None = None, *, max_model: int = 16):
    """Largest (data, model) mesh for an arbitrary device count (elastic)."""
    n = n_devices or len(jax.devices())
    model = math.gcd(n, max_model)
    while model > 1 and n % model:
        model //= 2
    return jax.make_mesh((n // model, model), ("data", "model"),
                         axis_types=_auto(2))


def describe(mesh) -> str:
    return " x ".join(f"{a}={mesh.shape[a]}" for a in mesh.axis_names)
