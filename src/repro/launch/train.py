"""Production training driver.

Fault-tolerance features wired here:
  * resume from the latest atomic checkpoint (params + optimizer + loss
    scale + data-iterator state) — restart-safe;
  * SIGTERM/SIGINT -> save-and-exit (preemption handling);
  * periodic + final checkpointing (keep-last GC), with the config
    fingerprint verified on restore (a checkpoint from a different arch
    fails loudly, never silently);
  * step watchdog: a daemon thread logs (and would page, in production) if
    a step exceeds ``watchdog_factor`` x the trailing-median step time —
    straggler/hang mitigation;
  * ``--guard``: NaN/Inf-grad steps apply no update (in-jit skip via
    ``TrainConfig.skip_nonfinite``) and a rolling-median loss-spike
    detector (``train/guards.py``) escalates consecutive bad steps to a
    rollback that restores the last good checkpoint and replays;
  * elastic restarts: the mesh is built from however many devices exist
    (launch.mesh.make_mesh_for) and restore reshards into it.

Usage:
  PYTHONPATH=src python -m repro.launch.train --arch llama3-8b --smoke \
      --steps 50 --batch 8 --seq 128 --ckpt-dir /tmp/run1
"""
from __future__ import annotations

import argparse
import dataclasses
import signal
import statistics
import threading
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.checkpointing.ckpt import CheckpointManager
from repro.core.checkpoint import CheckpointConfig
from repro.core.mixed_precision import LossScale
from repro.data.synthetic import token_stream
from repro.events import EventSink
from repro.launch.mesh import describe, make_mesh_for
from repro.models import transformer
from repro.obs import MemStat, MetricsRegistry, Tracer, maybe_span
from repro.optim import adamw
from repro.train.guards import GuardConfig, TrainGuard
from repro.train.train_step import TrainConfig, make_train_step


class Watchdog:
    """Logs when the current step runs long (straggler/hang detection).

    Thread-safe by a lock + step generation counter: the old
    implementation's monitor thread cleared ``self._started`` (its "one
    alert per step" latch) while ``step_end`` was reading it on the main
    thread — an alert racing a step boundary could drop that step's
    duration sample or re-arm against the wrong step.  Now every field
    is read/written under ``_lock``, the alert latch is "alerted at
    generation N" (so an alerted step still records its duration at
    ``step_end``), and with a ``sink`` each alert is also emitted as a
    ``watchdog_alert`` event to the JSONL stream (``--events``) instead
    of being print-only."""

    def __init__(self, factor: float = 5.0, min_history: int = 5,
                 *, sink: EventSink | None = None):
        self.factor, self.min_history = factor, min_history
        self.times: list[float] = []
        self._started: float | None = None
        self._gen = 0                 # step generation (monotonic)
        self._alerted_gen = -1        # last generation already alerted
        self._lock = threading.Lock()
        self._stop = threading.Event()
        self.alerts = 0
        self.sink = sink
        self._thread = threading.Thread(target=self._run, daemon=True)
        self._thread.start()

    def step_start(self):
        with self._lock:
            self._gen += 1
            self._started = time.time()

    def step_end(self):
        with self._lock:
            if self._started is not None:
                self.times.append(time.time() - self._started)
                self.times = self.times[-100:]
            self._started = None

    def _run(self):
        while not self._stop.wait(0.5):
            with self._lock:
                if (self._started is None
                        or self._gen == self._alerted_gen
                        or len(self.times) < self.min_history):
                    continue
                med = statistics.median(self.times)
                running = time.time() - self._started
                if running <= self.factor * med:
                    continue
                self.alerts += 1
                self._alerted_gen = self._gen    # one alert per step
            print(f"[watchdog] step running {running:.1f}s"
                  f" > {self.factor:.0f}x median {med:.2f}s — straggler?")
            if self.sink is not None:
                self.sink.emit("watchdog_alert", running_s=running,
                               median_s=med, factor=self.factor)

    def close(self):
        self._stop.set()


def synthetic_lm_batches(cfg, batch: int, seq: int, *, seed=0, state=None):
    """Deterministic, resumable synthetic LM stream (batch index = state)."""
    start = state or 0
    corpus = token_stream(max(200_000, batch * (seq + 1) * 4), cfg.vocab,
                          seed=seed)
    i = start
    while True:
        rng = np.random.default_rng((seed, i))
        offs = rng.integers(0, len(corpus) - seq - 1, size=batch)
        toks = np.stack([corpus[o:o + seq] for o in offs])
        labs = np.stack([corpus[o + 1:o + seq + 1] for o in offs])
        yield i, {"tokens": jnp.asarray(toks), "labels": jnp.asarray(labs)}
        i += 1


def _auto_remat(cfg, args, mesh, batch_sds) -> CheckpointConfig:
    """Planner-driven remat: budget-constrained when ``--mem-budget-mb``
    is given (delegating to ``train_step.resolve_remat`` — the same path
    ``TrainConfig.mem_budget_mb`` takes programmatically), else Chen-style
    sqrt(L) checkpoints at the byte-optimal sites.  Either way the profile
    is the per-device microbatch in the policy's compute dtype
    (``train_step.plan_profile``)."""
    import math

    from repro import plan as plan_mod
    from repro.train.train_step import plan_profile, resolve_remat

    base = CheckpointConfig(enabled=True, policy=args.remat_policy)
    tc0 = TrainConfig(policy=args.policy, remat=base, accum=args.accum,
                      mem_budget_mb=args.mem_budget_mb)
    prof = plan_profile(cfg, tc0, batch_sds, mesh=mesh)
    if args.mem_budget_mb > 0:
        remat = resolve_remat(cfg, tc0, batch_sds, mesh=mesh).remat
    else:
        rp = plan_mod.plan_min_peak(prof, math.isqrt(cfg.n_layers) or 1,
                                    policy=args.remat_policy)
        remat = dataclasses.replace(base, plan=rp)
    rep = plan_mod.plan_report(prof, remat.plan)
    print(f"remat plan [{remat.plan.source}]: "
          f"segments {remat.plan.segment_sizes()} "
          f"peak {rep['peak_bytes']/2**20:.1f} MiB/device "
          f"(no-remat {rep['no_remat_bytes']/2**20:.1f} MiB, "
          f"recompute >= {rep['recompute_frac']*100:.0f}% of fwd FLOPs)")
    return remat, int(rep["peak_bytes"])


def run(args):
    mesh = make_mesh_for(max_model=args.max_model)
    print(f"mesh: {describe(mesh)} ({mesh.size} devices)")
    if args.mem_budget_mb > 0:
        from repro.distributed import sharding as shd
        print(f"mem budget: {args.mem_budget_mb} MiB PER DEVICE "
              f"(microbatch = batch / {shd.dp_size(mesh)} dp shards; "
              f"attention residuals / {mesh.shape['model']} model shards)")
    cfg = configs.smoke_config(args.arch) if args.smoke \
        else configs.get_config(args.arch)
    if args.attn_backend is not None:
        cfg = dataclasses.replace(cfg, attn_backend=args.attn_backend)
    if cfg.attn_backend == "jnp":
        print("attn backend: jnp")
    else:
        from repro.plan import flash_attn_flop_report, \
            flash_training_eligible
        eligible = flash_training_eligible(cfg, args.seq)
        print(f"attn backend: {cfg.attn_backend}"
              + (" (flash custom_vjp: O(S*D) attention residuals)"
                 if eligible else
                 " — flash INELIGIBLE for this arch/shape, jnp path "
                 "(O(S^2) residuals) will run"))
        if eligible:
            rep = flash_attn_flop_report(cfg, args.batch, args.seq)
            print(f"  sparse flash grids: {rep['skip_frac']*100:.0f}% of KV "
                  f"tile-steps skipped "
                  f"({rep['visited_flops']/1e9:.1f} GFLOPs visited vs "
                  f"{rep['dense_flops']/1e9:.1f} dense per step)")

    batch_sds = {
        "tokens": jax.ShapeDtypeStruct((args.batch, args.seq), jnp.int32),
        "labels": jax.ShapeDtypeStruct((args.batch, args.seq), jnp.int32),
    }
    remat_mode = "off" if args.no_remat else args.remat
    if remat_mode == "off" and args.mem_budget_mb > 0:
        print("[warn] --mem-budget-mb ignored with remat off")
    plan_bytes = None                     # activation budget (MemStat score)
    if remat_mode == "auto" or (remat_mode == "on" and args.mem_budget_mb > 0):
        # a budget implies the planner even without an explicit --remat auto
        remat, plan_bytes = _auto_remat(cfg, args, mesh, batch_sds)
    else:
        remat = CheckpointConfig(enabled=remat_mode != "off",
                                 policy=args.remat_policy)
    tc = TrainConfig(
        policy=args.policy,
        remat=remat,
        accum=args.accum,
        use_loss_scale=(args.policy == "fp16"),
        skip_nonfinite=args.guard,
        opt=adamw.AdamWConfig(lr=args.lr, total_steps=args.steps,
                              warmup_steps=min(100, args.steps // 10 + 1)),
    )
    step_fn, shards = make_train_step(cfg, mesh, tc, batch_sds)

    mgr = CheckpointManager(args.ckpt_dir, keep_last=args.keep_last)
    if tc.remat.plan is not None:
        import os
        os.makedirs(args.ckpt_dir, exist_ok=True)
        tc.remat.plan.save(os.path.join(args.ckpt_dir, "remat_plan.json"))
    params = transformer.init_params(cfg, jax.random.PRNGKey(args.seed))
    opt = adamw.init(params)
    ls = LossScale.init() if tc.use_loss_scale else LossScale.noop()
    start_step, data_state = 0, 0

    latest = mgr.latest_intact_step()
    if latest is not None and not args.fresh:
        state_like = {"params": params, "opt": opt}
        (restored, extra) = mgr.restore(
            latest, state_like,
            shardings={"params": shards["params"], "opt": shards["opt"]},
            config=cfg.arch_id)
        params, opt = restored["params"], restored["opt"]
        start_step = extra.get("step", latest)
        data_state = extra.get("data_state", 0)
        if tc.use_loss_scale and "loss_scale" in extra:
            ls = dataclasses.replace(ls, scale=jnp.float32(extra["loss_scale"]))
        print(f"resumed from step {start_step} (data batch {data_state})")
    else:
        params = jax.device_put(params, shards["params"])
        opt = jax.device_put(opt, shards["opt"])

    stop = {"now": False}

    def _sig(_s, _f):
        print("[signal] preemption notice — checkpoint and exit")
        stop["now"] = True

    old_handlers = [signal.signal(s, _sig) for s in (signal.SIGTERM,
                                                     signal.SIGINT)]

    def save(step):
        # `step` here = number of completed steps; resume continues there
        with maybe_span(tracer, "checkpoint", step=step, op="save"):
            mgr.save(step, {"params": params, "opt": opt},
                     extra={"step": step, "data_state": data_state,
                            "loss_scale": float(ls.scale),
                            "arch": cfg.arch_id},
                     config=cfg.arch_id)

    sink = EventSink(args.events) if args.events else None
    if args.trace and sink is None:
        print("[warn] --trace requires --events; tracing disabled")
    registry = MetricsRegistry()
    tracer = Tracer(sink, pid="train") if args.trace and sink is not None \
        else None
    memstat = MemStat(sink=sink, registry=registry, plan_bytes=plan_bytes)
    guard = None
    if args.guard:
        guard = TrainGuard(GuardConfig(
            window=args.guard_window,
            spike_factor=args.guard_spike_factor,
            rollback_after=args.guard_rollback_after), sink=sink,
            registry=registry)
        print(f"guard: skip non-finite steps in-jit; loss spike > "
              f"{args.guard_spike_factor}x rolling median; "
              f"{args.guard_rollback_after} consecutive bad steps -> "
              f"rollback (costs one loss sync per step)")
    wd = Watchdog(sink=sink)
    data = synthetic_lm_batches(cfg, args.batch, args.seq, seed=args.seed,
                                state=data_state)
    t0 = time.time()
    step = start_step
    try:
        while step < args.steps:
            with maybe_span(tracer, "data", step=step):
                data_state, batch = next(data)
            wd.step_start()
            with maybe_span(tracer, "train_step", step=step):
                params, opt, ls, metrics = step_fn(params, opt, ls, batch)
                verdict = TrainGuard.OK
                if guard is not None:
                    # the loss sync closes the step: the span measures
                    # dispatch + device time, not just dispatch
                    with maybe_span(tracer, "guard", step=step):
                        verdict = guard.observe(
                            float(metrics["loss"]),  # sync
                            bool(metrics["grads_finite"]),
                            grad_norm=float(metrics["grad_norm"]))
            if verdict == TrainGuard.ROLLBACK:
                wd.step_end()
                if guard.rollbacks > args.guard_max_rollbacks:
                    print(f"[guard] {guard.rollbacks} rollbacks exceed "
                          f"--guard-max-rollbacks="
                          f"{args.guard_max_rollbacks} — persistent "
                          f"fault, aborting ({guard.counters()})")
                    return 1
                # never roll back onto a torn/corrupt checkpoint — fall
                # back to the newest one whose shard checksums verify
                latest = mgr.latest_intact_step()
                if latest is None:
                    print("[guard] rollback with no checkpoint on disk — "
                          "restarting from init")
                    params = jax.device_put(
                        transformer.init_params(
                            cfg, jax.random.PRNGKey(args.seed)),
                        shards["params"])
                    opt = jax.device_put(adamw.init(params), shards["opt"])
                    step, data_state = 0, 0
                else:
                    with maybe_span(tracer, "checkpoint", step=latest,
                                    op="restore"):
                        restored, extra = mgr.restore(
                            latest, {"params": params, "opt": opt},
                            shardings={"params": shards["params"],
                                       "opt": shards["opt"]},
                            config=cfg.arch_id)
                    params, opt = restored["params"], restored["opt"]
                    step = extra.get("step", latest)
                    data_state = extra.get("data_state", 0)
                    if tc.use_loss_scale and "loss_scale" in extra:
                        ls = dataclasses.replace(
                            ls, scale=jnp.float32(extra["loss_scale"]))
                guard.reset_history()
                data = synthetic_lm_batches(cfg, args.batch, args.seq,
                                            seed=args.seed,
                                            state=data_state)
                print(f"[guard] rolled back to step {step} "
                      f"(data batch {data_state}; {guard.counters()})")
                continue
            if verdict == TrainGuard.SKIP:
                print(f"[guard] step {step}: bad step "
                      f"({guard.counters()}) — update "
                      f"{'suppressed in-jit' if not bool(metrics['grads_finite']) else 'applied; loss quarantined'}")
            if step % args.log_every == 0 or step == args.steps - 1:
                loss = float(metrics["loss"])  # sync point
                print(f"step {step:5d} loss {loss:.4f} "
                      f"lr {float(metrics['lr']):.2e} "
                      f"gnorm {float(metrics['grad_norm']):.2f} "
                      f"({(time.time()-t0):.1f}s)")
            wd.step_end()
            data_state += 1
            step += 1
            if args.metrics_every and step % args.metrics_every == 0:
                # host-side only: live-array walk + registry snapshot,
                # never a device sync
                memstat.sample(step)
                if sink is not None:
                    registry.emit(sink, step=step)
            healthy = guard is None or guard.bad_streak == 0
            if step % args.ckpt_every == 0 and healthy:
                # never checkpoint mid-bad-streak: the rollback target
                # must be a GOOD state
                save(step)
            if stop["now"]:
                if healthy:
                    save(step)
                return 0
        save(args.steps)
    finally:
        wd.close()
        if sink is not None:
            sink.close()
        for s, h in zip((signal.SIGTERM, signal.SIGINT), old_handlers):
            signal.signal(s, h)
    if guard is not None:
        print(f"guard: {guard.counters()}")
    if memstat.samples:
        print(memstat.banner())
    print("done")
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--smoke", action="store_true",
                    help="reduced config (CPU-sized)")
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--accum", type=int, default=1)
    ap.add_argument("--policy", default="bf16",
                    choices=["full", "bf16", "fp16", "bf16_params",
                             "resid_bf16"],
                    help="mixed-precision policy; resid_bf16 = f32 compute "
                         "with the flash custom_vjp's saved (q,k,v,o) "
                         "residuals stored in bf16 (stats stay f32)")
    ap.add_argument("--attn-backend", default=None,
                    choices=["jnp", "interpret", "pallas"],
                    help="attention kernel override (default: the arch "
                         "config's backend): jnp (O(S^2) residuals), or "
                         "the flash kernel via the Pallas interpreter / "
                         "compiled Mosaic (trainable custom_vjp, O(S*D) "
                         "residuals)")
    ap.add_argument("--remat", default="on", choices=["on", "off", "auto"],
                    help="auto: profile-driven RematPlan (see repro.plan)")
    ap.add_argument("--mem-budget-mb", type=int, default=0,
                    help="per-device activation-byte budget; > 0 engages "
                         "the remat planner (with --remat auto, 0 means "
                         "sqrt(L) checkpoints instead)")
    ap.add_argument("--remat-policy", default="full")
    ap.add_argument("--no-remat", action="store_true",
                    help="deprecated alias for --remat off")
    ap.add_argument("--lr", type=float, default=3e-4)
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--ckpt-dir", default="/tmp/repro_ckpt")
    ap.add_argument("--ckpt-every", type=int, default=50)
    ap.add_argument("--keep-last", type=int, default=3)
    ap.add_argument("--log-every", type=int, default=10)
    ap.add_argument("--max-model", type=int, default=16)
    ap.add_argument("--fresh", action="store_true")
    ap.add_argument("--guard", action="store_true",
                    help="enable train/guards.py: skip NaN/Inf-grad "
                         "updates in-jit, detect loss spikes against a "
                         "rolling median, roll back to the last good "
                         "checkpoint after consecutive bad steps")
    ap.add_argument("--guard-window", type=int, default=32,
                    help="guard: healthy-loss history for the median")
    ap.add_argument("--guard-spike-factor", type=float, default=4.0,
                    help="guard: loss > factor x median => spike")
    ap.add_argument("--guard-rollback-after", type=int, default=3,
                    help="guard: consecutive bad steps before rollback")
    ap.add_argument("--guard-max-rollbacks", type=int, default=5,
                    help="guard: abort (exit 1) past this many rollbacks "
                         "— a persistent fault, not a transient")
    ap.add_argument("--events", default=None,
                    help="append-only JSONL event log (repro.events): "
                         "guard verdicts stream here for post-mortems")
    ap.add_argument("--metrics-every", type=int, default=0,
                    help="every N steps: sample live-array bytes "
                         "(mem_sample) and emit a metrics_snapshot of "
                         "the obs registry to --events (0 = off)")
    ap.add_argument("--trace", action="store_true",
                    help="emit span_begin/span_end records (data / "
                         "train_step / guard / checkpoint) to --events; "
                         "tools/tracelens.py renders the timeline")
    return run(ap.parse_args())


if __name__ == "__main__":
    raise SystemExit(main())
