import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

"""Multi-pod dry-run: lower + compile every (arch x shape) cell on the
production mesh and extract the roofline terms from the compiled artifact.

No arrays are ever allocated: inputs are ShapeDtypeStructs, params are
eval_shape trees.  This proves the distribution config is coherent (sharding
propagates, collectives legal, memory fits) for 16x16 and 2x16x16 meshes.

Usage:
  PYTHONPATH=src python -m repro.launch.dryrun --arch llama3-8b --shape train_4k
  PYTHONPATH=src python -m repro.launch.dryrun --all [--multi-pod] [--json out.json]
"""
import argparse
import json
import re
import sys
import time

import jax
import jax.numpy as jnp

from repro import configs
from repro.launch.mesh import make_production_mesh, describe

# TPU v5e hardware constants (per chip)
PEAK_FLOPS = 197e12          # bf16
HBM_BW = 819e9               # bytes/s
ICI_BW = 50e9                # bytes/s/link

_COLL_OPS = ("all-gather", "all-reduce", "reduce-scatter", "all-to-all",
             "collective-permute")
_OP_RE = re.compile(
    r"=\s*(.*?)\s+(all-gather|all-reduce|reduce-scatter|all-to-all|"
    r"collective-permute)(-start|-done)?\(")
_SHAPE_RE = re.compile(r"(f64|f32|bf16|f16|s64|u64|s32|u32|s16|u16|s8|u8|pred)"
                       r"\[([0-9,]*)\]")
_DTYPE_BYTES = {
    "f64": 8, "f32": 4, "bf16": 2, "f16": 2, "s64": 8, "u64": 8,
    "s32": 4, "u32": 4, "s16": 2, "u16": 2, "s8": 1, "u8": 1, "pred": 1,
}


def collective_bytes(hlo_text: str) -> dict:
    """Sum per-device *output* bytes of every collective op in the SPMD HLO.

    Handles scalar and tuple-shaped collectives; `-start` ops are counted,
    their `-done` halves skipped (same transfer).

    CPU-widening correction: the CPU backend has no bf16 arithmetic, so it
    wraps bf16 collectives in convert(bf16->f32) — the HLO shows f32 at 2x
    the bytes that would cross TPU links.  Collectives whose operands are
    convert fusions are therefore counted at half width (recorded
    separately under ``<op>_widened``).
    """
    totals: dict[str, float] = {}
    for line in hlo_text.splitlines():
        m = _OP_RE.search(line)
        if not m or m.group(3) == "-done":
            continue
        op = m.group(2)
        size = 0
        for dt, dims in _SHAPE_RE.findall(m.group(1)):
            n = 1
            for d in dims.split(","):
                if d:
                    n *= int(d)
            size += n * _DTYPE_BYTES[dt]
        args = line.split("(", 1)[1] if "(" in line else ""
        if "convert" in args and " f32[" in f" {line.split('=',1)[1]}":
            size //= 2
            totals[op + "_widened"] = totals.get(op + "_widened", 0) + size
        totals[op] = totals.get(op, 0) + size
    totals["total"] = sum(v for k, v in totals.items()
                          if not k.endswith("_widened"))
    return totals


def build_lowerable(cfg, shape_name: str, mesh, *, accum: int = 4,
                    scan_unroll: int = 1):
    """Returns (jitted_fn, kwargs-of-ShapeDtypeStructs) for the cell."""
    from repro.models import transformer
    from repro.train.train_step import TrainConfig, make_train_step
    from repro.train.serve_step import make_serve_steps
    from repro.optim import adamw
    from repro.core.mixed_precision import LossScale

    kind = configs.SHAPES[shape_name]["kind"]
    specs = configs.input_specs(cfg, shape_name)
    params_sds = jax.eval_shape(
        lambda: transformer.init_params(cfg, jax.random.PRNGKey(0)))

    if kind == "train":
        tc = TrainConfig(policy="bf16", accum=accum, scan_unroll=scan_unroll)
        step, _ = make_train_step(cfg, mesh, tc, specs, donate=True)
        opt_sds = jax.eval_shape(adamw.init, params_sds)
        ls = LossScale.noop()
        ls_sds = jax.tree_util.tree_map(
            lambda x: jax.ShapeDtypeStruct(jnp.shape(x), x.dtype), ls)
        return step, (params_sds, opt_sds, ls_sds, specs)

    if kind == "prefill":
        step, _ = make_serve_steps(cfg, mesh, specs, kind="prefill",
                                   scan_unroll=scan_unroll)
        return step, (params_sds, specs)

    step, _ = make_serve_steps(cfg, mesh, specs, kind="decode", donate=False,
                               scan_unroll=scan_unroll)
    args = [params_sds, specs["cache"], specs["tokens_t"]]
    if cfg.encoder is not None:
        args.append(specs["enc_out"])
    return step, tuple(args)


def _compile_cell(cfg, shape_name, mesh, *, accum=4, scan_unroll=1):
    fn, args = build_lowerable(cfg, shape_name, mesh, accum=accum,
                               scan_unroll=scan_unroll)
    with mesh:
        lowered = fn.lower(*args)
        compiled = lowered.compile()
    return compiled


def _costs(compiled) -> dict:
    cost = compiled.cost_analysis()
    coll = collective_bytes(compiled.as_text())
    return {
        "flops": float(cost.get("flops", 0.0)),
        "bytes": float(cost.get("bytes accessed", 0.0)),
        "coll": coll["total"],
        "coll_by_op": coll,
    }


def _memory_floor_bytes(cfg, shape_name: str, mesh, accum: int) -> float:
    """Analytic per-device HBM-traffic floor for one step (bytes).

    Counts only unavoidable streams: parameter reads (fwd/bwd/remat x
    microbatches), optimizer state read+write, checkpointed activations
    write+read, logit stream, and (serving) the KV-cache read.  Fusable
    element-wise traffic is deliberately excluded -> a true lower bound.
    """
    sh = configs.SHAPES[shape_name]
    kind = sh["kind"]
    n_model = mesh.shape["model"]
    n_dp = mesh.size // n_model
    p_dev = cfg.param_count() / n_model          # params per device (approx)

    if kind == "train":
        ub_local = max(1, sh["batch"] // (n_dp * accum))
        param_stream = 3 * 2 * p_dev * accum         # fwd+bwd+remat, bf16
        opt_stream = 10 * 4 * p_dev                  # p,m,v read+write f32 + grads
        ckpt = 2 * cfg.n_layers * ub_local * sh["seq"] * cfg.d_model * 2 * accum
        logits = 10 * ub_local * sh["seq"] * (cfg.vocab / n_model) * accum
        return param_stream + opt_stream + ckpt + logits
    if kind == "prefill":
        b_local = max(1, sh["batch"] // n_dp)
        acts = 2 * cfg.n_layers * b_local * sh["seq"] * cfg.d_model * 2
        cache = _cache_bytes_per_device(cfg, sh["batch"], sh["seq"], mesh)
        return 2 * p_dev + acts + cache
    # decode: params once + cache read once
    cache = _cache_bytes_per_device(cfg, sh["batch"], sh["seq"], mesh)
    return 2 * p_dev + cache


def _cache_bytes_per_device(cfg, batch: int, seq: int, mesh) -> float:
    """int8 KV (or MLA-latent / SSM-state) cache bytes per device."""
    n_chips = mesh.size
    L = cfg.n_layers
    total = 0.0
    if cfg.mixer in ("attn", "hybrid"):
        if cfg.mla is not None:
            m = cfg.mla
            total += L * batch * seq * (m.kv_lora_rank + m.qk_rope_dim) * 2
        else:
            total += L * batch * cfg.n_kv * seq * cfg.head_dim * 2 * 1  # int8 k+v
            total += L * batch * cfg.n_kv * seq * 2 * 4                 # scales
    if cfg.mixer in ("ssm", "hybrid"):
        s = cfg.ssm
        total += L * batch * s.heads * s.d_state * s.head_p * 4
    # cache is sharded over every mesh axis we can use (B and Hkv/S rules);
    # assume full spread except the pod axis for B=1 long-context
    spread = n_chips if batch > 1 else mesh.shape["model"] * (
        mesh.shape.get("data", 1))
    return total / spread


def dryrun_cell(arch: str, shape_name: str, mesh, *, verbose=True,
                accum: int = 4) -> dict:
    """Compile the cell at full depth (memory proof) and at L=1, L=2 to
    loop-correct the cost terms (XLA cost_analysis counts a while-loop body
    once; layers are homogeneous, so total = c1 + (L-1) * (c2 - c1))."""
    import dataclasses as dc
    cfg = configs.get_config(arch)
    L = cfg.n_layers
    t0 = time.time()
    compiled = _compile_cell(cfg, shape_name, mesh, accum=accum)
    t_compile = time.time() - t0
    mem = compiled.memory_analysis()

    # cost probes: accum=1 and fully-unrolled layer stack so every op is
    # visible to cost_analysis (whose while-loop bodies count once)
    c1 = _costs(_compile_cell(dc.replace(cfg, n_layers=1), shape_name, mesh,
                              accum=1, scan_unroll=1))
    c2 = _costs(_compile_cell(dc.replace(cfg, n_layers=2), shape_name, mesh,
                              accum=1, scan_unroll=2))
    flops = c1["flops"] + (L - 1) * (c2["flops"] - c1["flops"])
    bytes_acc = c1["bytes"] + (L - 1) * (c2["bytes"] - c1["bytes"])
    coll_total = c1["coll"] + (L - 1) * (c2["coll"] - c1["coll"])
    coll = {
        op: c1["coll_by_op"].get(op, 0)
        + (L - 1) * (c2["coll_by_op"].get(op, 0) - c1["coll_by_op"].get(op, 0))
        for op in set(c1["coll_by_op"]) | set(c2["coll_by_op"])
        if op != "total"
    }
    raw = _costs(compiled)
    n_chips = mesh.size

    # The compiled SPMD module is the PER-DEVICE program: cost_analysis
    # flops/bytes and parsed collective bytes are per-chip already.
    # XLA 'bytes accessed' sums operand+result bytes of every op with no
    # fusion credit (gross upper bound on CPU HLO); we pair it with an
    # analytic lower bound (params + checkpointed activations + logits).
    mem_lb = _memory_floor_bytes(cfg, shape_name, mesh, accum)
    terms = {
        "compute_s": flops / PEAK_FLOPS,
        "memory_s": mem_lb / HBM_BW,
        "collective_s": coll_total / ICI_BW,
    }
    terms_ub = {"memory_ub_s": bytes_acc / HBM_BW}
    bottleneck = max(terms, key=terms.get)
    t_lower = 0.0

    kind = configs.SHAPES[shape_name]["kind"]
    n_active = cfg.active_param_count()
    sh = configs.SHAPES[shape_name]

    # Planner cross-check (train cells): predicted activation peak of the
    # default per-block remat vs what XLA actually compiled.  The planner
    # models ONLY checkpointed activations + recompute live set, so it must
    # lower-bound the compiled temp bytes; a violation means the cost model
    # drifted from the executed remat structure.
    plan_info = {}
    if kind == "train":
        try:
            from repro import plan as plan_mod
            from repro.train.train_step import microbatch_specs
            batch_sds = {"tokens": jax.ShapeDtypeStruct(
                (sh["batch"], sh["seq"]), jnp.int32)}
            mb_sds = microbatch_specs(batch_sds, accum=accum, mesh=mesh)
            prof = plan_mod.profile_transformer(cfg, mb_sds)
            per_block = plan_mod.RematPlan.uniform(cfg.n_layers, cfg.n_layers)
            rep = plan_mod.plan_report(prof, per_block)
            # resolved attention backend + what it costs the backward: the
            # jnp path budgets O(S^2) probability residuals, the flash
            # custom_vjp budgets O(S*D) stats + known recompute FLOPs
            mb_b, mb_s = mb_sds["tokens"].shape
            cfg_flash = dc.replace(cfg, attn_backend="pallas")
            flash_prof = plan_mod.profile_transformer(cfg_flash, mb_sds)
            # sparse-grid honesty: what the dense nQ x nK grids would
            # spend vs the tiles the wedge grids actually visit
            flop_rep = plan_mod.flash_attn_flop_report(cfg_flash, mb_b,
                                                       mb_s)
            plan_info = {
                "plan_peak_bytes": rep["peak_bytes"],
                "plan_no_remat_bytes": rep["no_remat_bytes"],
                "plan_n_segments": rep["n_segments"],
                "attn_backend": cfg.attn_backend,
                "attn_resid_bytes": prof.total_resid_bytes(),
                "flash_resid_bytes": flash_prof.total_resid_bytes(),
                "flash_bwd_recompute_flops": sum(
                    plan_mod.flash_bwd_recompute_flops(cfg_flash, mb_b,
                                                       mb_s)),
                "flash_attn_dense_flops": flop_rep["dense_flops"],
                "flash_attn_visited_flops": flop_rep["visited_flops"],
                "flash_tile_skip_frac": flop_rep["skip_frac"],
            }
        except Exception as e:  # noqa: BLE001 - advisory, never fail a cell
            plan_info = {"plan_error": f"{type(e).__name__}: {e}"[:200]}
    # Serve cells (prefill + decode): what the int8 cache encoding and the
    # length-aware split-K decode grid buy, from the planner's serve-side
    # reports (visited-tile counts match the kernel's debug counters
    # tile-for-tile by construction).
    if kind in ("prefill", "decode"):
        try:
            from repro import plan as plan_mod
            cache_rep = plan_mod.kv_cache_report(cfg, sh["batch"], sh["seq"])
            plan_info = {
                "kv_cache_int8_bytes": cache_rep["int8_bytes"],
                "kv_cache_f32_bytes": cache_rep["f32_bytes"],
                "kv_cache_quant_ratio": round(cache_rep["ratio"], 3),
            }
            if kind == "decode" and cache_rep["eligible"]:
                dec = plan_mod.decode_tile_report(cfg, sh["batch"],
                                                  sh["seq"])
                plan_info.update(
                    decode_visited_tile_steps=dec["visited_tile_steps"],
                    decode_dense_tile_steps=dec["dense_tile_steps"],
                    decode_tile_skip_frac=round(dec["skip_frac"], 4),
                    decode_visited_kv_gbytes=dec["visited_kv_bytes"] / 1e9,
                    decode_dense_kv_gbytes=dec["dense_kv_bytes"] / 1e9,
                )
        except Exception as e:  # noqa: BLE001 - advisory, never fail a cell
            plan_info = {"serve_plan_error": f"{type(e).__name__}: {e}"[:200]}
    tokens = sh["batch"] * sh["seq"] if kind == "train" else (
        sh["batch"] * sh["seq"] if kind == "prefill" else sh["batch"])
    mult = 6 if kind == "train" else 2
    model_flops = mult * n_active * tokens

    result = {
        "arch": arch, "shape": shape_name, "mesh": describe(mesh),
        "kind": kind,
        "params_b": round(cfg.param_count() / 1e9, 3),
        "active_params_b": round(n_active / 1e9, 3),
        "hlo_gflops": flops / 1e9,
        "hlo_gbytes": bytes_acc / 1e9,
        "collective_gbytes": coll_total / 1e9,
        "collectives": {k: v / 1e9 for k, v in coll.items()},
        "raw_uncorrected": {k: v for k, v in raw.items() if k != "coll_by_op"},
        "bytes_per_device": int(getattr(mem, "temp_size_in_bytes", 0)
                                + getattr(mem, "argument_size_in_bytes", 0)
                                + getattr(mem, "output_size_in_bytes", 0)),
        "temp_bytes_per_device": int(getattr(mem, "temp_size_in_bytes", 0)),
        "arg_bytes_per_device": int(getattr(mem, "argument_size_in_bytes", 0)),
        **{k: float(v) for k, v in terms.items()},
        "bottleneck": bottleneck,
        "model_flops": model_flops,
        "useful_flops_frac": (model_flops / n_chips) / flops if flops else 0.0,
        "memory_ub_s": terms_ub["memory_ub_s"],
        "memory_lb_bytes": mem_lb,
        "lower_s": round(t_lower, 1), "compile_s": round(t_compile, 1),
        **plan_info,
    }
    if verbose:
        print(f"[{arch} x {shape_name} @ {describe(mesh)}]")
        print(f"  compile {t_compile:.0f}s | HLO {flops/1e12:.2f} TF, "
              f"{bytes_acc/1e9:.1f} GB, coll {coll_total/1e9:.2f} GB")
        print(f"  terms: compute {terms['compute_s']*1e3:.3f} ms | "
              f"memory(lb) {terms['memory_s']*1e3:.3f} ms "
              f"(ub {terms_ub['memory_ub_s']*1e3:.1f}) | "
              f"collective {terms['collective_s']*1e3:.3f} ms "
              f"-> {bottleneck}")
        print(f"  per-device bytes: temp {result['temp_bytes_per_device']/2**30:.2f} GiB, "
              f"args {result['arg_bytes_per_device']/2**30:.2f} GiB")
        if "plan_peak_bytes" in result:
            print(f"  planner: activation peak {result['plan_peak_bytes']/2**30:.2f} GiB "
                  f"planned (per-block remat) vs {result['temp_bytes_per_device']/2**30:.2f} GiB "
                  f"compiled temp (no-remat would be "
                  f"{result['plan_no_remat_bytes']/2**30:.2f} GiB)")
            print(f"  attn: backend={result['attn_backend']} "
                  f"resid {result['attn_resid_bytes']/2**20:.1f} MiB "
                  f"(flash would be {result['flash_resid_bytes']/2**20:.1f} "
                  f"MiB + {result['flash_bwd_recompute_flops']/1e9:.1f} "
                  f"recompute GFLOPs)")
            if result.get("flash_tile_skip_frac"):
                print(f"  flash sparse grids: "
                      f"{result['flash_attn_visited_flops']/1e9:.1f} GFLOPs "
                      f"visited vs {result['flash_attn_dense_flops']/1e9:.1f}"
                      f" dense ({result['flash_tile_skip_frac']*100:.0f}% of "
                      f"KV tile-steps skipped)")
        if "kv_cache_int8_bytes" in result and result["kv_cache_int8_bytes"]:
            print(f"  kv cache: int8 "
                  f"{result['kv_cache_int8_bytes']/2**30:.2f} GiB vs f32 "
                  f"{result['kv_cache_f32_bytes']/2**30:.2f} GiB "
                  f"({result['kv_cache_quant_ratio']:.2f}x)")
        if "decode_visited_tile_steps" in result:
            print(f"  decode tiles: {result['decode_visited_tile_steps']} "
                  f"visited vs {result['decode_dense_tile_steps']} dense "
                  f"({result['decode_tile_skip_frac']*100:.0f}% skipped; "
                  f"kv stream {result['decode_visited_kv_gbytes']:.2f} vs "
                  f"{result['decode_dense_kv_gbytes']:.2f} GB)")
        print(f"  useful-FLOP fraction {result['useful_flops_frac']:.2f}")
        sys.stdout.flush()
    return result


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", type=str, default=None)
    ap.add_argument("--shape", type=str, default=None)
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--multi-pod", action="store_true")
    ap.add_argument("--both-meshes", action="store_true")
    ap.add_argument("--json", type=str, default=None)
    args = ap.parse_args()

    meshes = []
    if args.both_meshes:
        meshes = [make_production_mesh(), make_production_mesh(multi_pod=True)]
    else:
        meshes = [make_production_mesh(multi_pod=args.multi_pod)]

    cells = []
    if args.all:
        for arch in configs.list_archs():
            cfg = configs.get_config(arch)
            for shape in configs.applicable_shapes(cfg):
                cells.append((arch, shape))
    else:
        cells = [(args.arch, args.shape)]

    results, failures = [], []
    for mesh in meshes:
        for arch, shape in cells:
            try:
                results.append(dryrun_cell(arch, shape, mesh))
            except Exception as e:  # noqa: BLE001 - report and continue
                print(f"[FAIL] {arch} x {shape} @ {describe(mesh)}: "
                      f"{type(e).__name__}: {e}")
                sys.stdout.flush()
                failures.append({"arch": arch, "shape": shape,
                                 "mesh": describe(mesh), "error": str(e)[:2000]})
    if args.json:
        with open(args.json, "w") as f:
            json.dump({"results": results, "failures": failures}, f, indent=1)
    print(f"\n{len(results)} cells OK, {len(failures)} failed")
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
