"""Production serving driver: continuous-batching engine or lockstep demo.

Engine mode (``--engine``) drives ``repro.serve.ServeEngine`` over a
seeded synthetic request trace — slot-pooled int8 KV cache, FCFS
admission, mid-flight joins/retirements with zero re-jits after warmup:

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
        --engine --requests 16 --max-slots 4 --max-len 128

The default (lockstep) mode keeps the original demo: one fixed batch
prefills once and decodes ``--gen`` steps in unison — every slot pays for
the slowest request.  Both modes share the seeded sampler
(``--temperature`` / ``--top-k``; greedy stays the default).

Fleet mode (``--engine --replicas N``) fronts N engine replicas with the
health-routing ``repro.serve.Router`` — least-loaded admission, an
error-budget circuit breaker per replica, and cross-replica request
migration.  ``--chaos-seed`` runs the seeded chaos harness (replica
crash/sick/slow events) against the fleet:

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
        --engine --replicas 2 --requests 16 --chaos-seed 11

Serving-side fault tolerance: the decode loop is stateless beyond the
cache, so a restart re-prefills in one step; the watchdog flags stuck
steps (straggler chips in production); ``--events out.jsonl`` streams
fault/health/failover events to an append-only JSONL sink.

Durability (``--journal wal.jsonl``): every fleet request transition is
written ahead to an fsync'd journal; after a whole-process crash,
re-running with ``--recover`` rebuilds the fleet from the journal and
finishes every in-flight request from its durable prompt + token
prefix.  ``--workers`` runs each replica as a REAL subprocess behind
the pipe RPC (``repro.serve.worker``) — crashes become SIGKILLs and the
breaker is exercised across a process boundary:

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
        --engine --replicas 2 --workers --journal wal.jsonl --recover
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch.mesh import describe, make_mesh_for
from repro.launch.train import Watchdog
from repro.models import transformer
from repro.obs import MemStat, Tracer
from repro.serve import sampling
from repro.train.serve_step import build_decode_step, build_prefill_step


def _kv_banner(cfg, args, s_total: int):
    """Honest banner: name what decode will ACTUALLY run — the int8 kvq
    kernel only dispatches on a quantized GQA cache (MLA latents and SSM
    states take their own decode paths), and the split count is clamped
    to the KV tile count of the preallocated cache."""
    quant = not args.no_quantize
    kvq_eligible = cfg.mixer in ("attn", "hybrid") and cfg.mla is None
    if not kvq_eligible:
        kv_backend, kv_splits = "n/a (no kvq-layout attention cache)", 1
    elif quant:
        from repro.kernels.kvq import ops as kvq_ops
        kv_backend = args.kv_backend
        kv_splits = kvq_ops.resolve_splits(s_total, args.kv_splits)
    else:
        kv_backend, kv_splits = "jnp (cache not quantized)", 1
    print(f"kv decode: backend={kv_backend} splits={kv_splits} "
          f"(requested {args.kv_splits}, cache {s_total} slots)")


def _fleet_buckets(max_len: int) -> tuple:
    """Fleet prefill buckets: the defaults plus a max_len bucket, so a
    migration or crash-recovery replay (prompt + emitted tokens, up to
    max_len) always fits some bucket instead of going FAILED."""
    from repro.serve import default_buckets
    base = default_buckets(max_len)
    return base if base[-1] >= max_len else base + (max_len,)


def _build_engine(args, cfg, params, mesh=None, *, sink=None,
                  sampler_keys: str = "step", replay_buckets: bool = False):
    from repro.serve import ServeEngine
    quant = not args.no_quantize
    budget = (int(args.mem_budget_mb * 2**20)
              if args.mem_budget_mb else None)
    return ServeEngine(
        params, cfg, max_slots=args.max_slots, max_len=args.max_len,
        prompt_buckets=(_fleet_buckets(args.max_len)
                        if replay_buckets else None),
        policy_name=args.policy, quantized=quant,
        kv_backend=args.kv_backend, kv_splits=args.kv_splits,
        temperature=args.temperature, top_k=args.top_k, seed=args.seed,
        max_prefill_per_step=args.max_prefill_per_step,
        mem_budget_bytes=budget, mesh=mesh,
        max_queue=args.max_queue or None,
        deadline_steps=(args.deadline_steps
                        if args.deadline_steps >= 0 else None),
        max_retries=args.max_retries, sampler_keys=sampler_keys,
        sink=sink)


def _worker_kwargs(args) -> dict:
    """The ``engine_factory`` spec for subprocess replicas — mirrors
    ``_build_engine`` for the knobs a worker child builds itself (each
    worker initializes its own params from ``--seed``; meshes stay
    in-process)."""
    budget = (int(args.mem_budget_mb * 2**20)
              if args.mem_budget_mb else None)
    return dict(
        arch=args.arch, smoke=args.smoke, init_seed=args.seed,
        max_slots=args.max_slots, max_len=args.max_len,
        prompt_buckets=_fleet_buckets(args.max_len),
        policy_name=args.policy, quantized=not args.no_quantize,
        kv_backend=args.kv_backend, kv_splits=args.kv_splits,
        temperature=args.temperature, top_k=args.top_k, seed=args.seed,
        max_prefill_per_step=args.max_prefill_per_step,
        mem_budget_bytes=budget, max_queue=args.max_queue or None,
        deadline_steps=(args.deadline_steps
                        if args.deadline_steps >= 0 else None),
        max_retries=args.max_retries, sampler_keys="request")


def _make_trace(args, cfg, engine):
    from repro.serve import synthetic_trace
    # size the trace to what the engine can admit: prompts within the
    # largest bucket, prompt+gen within max_len
    max_prompt = min(engine.buckets[-1], max(4, args.max_len // 2))
    return synthetic_trace(
        args.requests, seed=args.seed, vocab=cfg.vocab,
        mean_prompt=args.mean_prompt, max_prompt=max_prompt,
        mean_gen=args.mean_gen, max_gen=max(1, args.max_len - max_prompt),
        arrival_rate=args.arrival_rate, min_prompt=min(4, max_prompt))


def _open_sink(args):
    if not args.events:
        return None
    from repro.events import EventSink
    print(f"events: streaming to {args.events}")
    return EventSink(args.events)


def _want_trace(args, sink) -> bool:
    if args.trace and sink is None:
        print("[warn] --trace requires --events; tracing disabled")
        return False
    return bool(args.trace)


def _install_obs_hook(obj, sink, memstat, every: int, snapshot_fn) -> None:
    """Chain a periodic metrics/memory emitter onto ``pre_step`` —
    AFTER any fault injector, so neither hook clobbers the other."""
    prev = obj.hooks.get("pre_step")

    def _hook(o, _prev=prev):
        if _prev is not None:
            _prev(o)
        if o.step_no and o.step_no % every == 0:
            memstat.sample(o.step_no)
            if sink is not None:
                sink.emit("metrics_snapshot", snapshot=snapshot_fn(),
                          step=o.step_no)

    obj.hooks["pre_step"] = _hook


def run_fleet(args, cfg, params, mesh=None) -> int:
    """N engine replicas behind the health-routing Router, optionally
    under the seeded chaos harness."""
    from repro.serve import (BreakerConfig, FleetFaultInjector, Router,
                             chaos_plan, supports)
    if not supports(cfg):
        print(f"fleet: {cfg.arch_id} is not engine-eligible")
        return 2
    _kv_banner(cfg, args, args.max_len)
    sink = _open_sink(args)
    journal = None
    if args.journal:
        from repro.serve import RequestJournal
        journal = RequestJournal(args.journal, snapshot_every=64)
        print(f"journal: write-ahead log at {args.journal} "
              f"({journal.state.n_live} live requests on open)")
    t0 = time.time()
    if args.workers:
        from repro.serve import spawn_workers
        engines = spawn_workers(args.replicas, kwargs=_worker_kwargs(args))
        for i, w in enumerate(engines):
            w.metrics.replica = i
        print(f"fleet: {args.replicas} subprocess workers "
              f"(pids {[w.pid for w in engines]}) warmed in "
              f"{time.time()-t0:.1f}s "
              f"({engines[0].pool.max_slots} slots each)")
    else:
        engines = []
        for i in range(args.replicas):
            e = _build_engine(args, cfg, params, mesh, sink=sink,
                              sampler_keys="request",
                              replay_buckets=True)
            e.metrics.replica = i
            e.warmup()
            engines.append(e)
        print(f"fleet: {args.replicas} replicas warmed in "
              f"{time.time()-t0:.1f}s "
              f"({engines[0].pool.max_slots} slots each)")
    breaker = BreakerConfig(
        window_steps=args.breaker_window,
        degrade_faults=args.breaker_degrade,
        quarantine_faults=args.breaker_quarantine,
        cooldown_steps=args.breaker_cooldown,
        stall_steps=args.breaker_stall)
    router = Router(engines, policy=args.route, breaker=breaker,
                    max_migrations=args.max_migrations, sink=sink,
                    journal=journal,
                    journal_tokens_every=args.journal_tokens_every)
    if _want_trace(args, sink):
        # tracers attach POST-warmup (the warmup probe must not trace)
        # and BEFORE recover() so recovery replay gets root spans
        for i, e in enumerate(engines):
            e.tracer = Tracer(sink, pid=f"r{i}")
        router.tracer = Tracer(sink, pid="router")
        if journal is not None:
            journal.tracer = Tracer(sink, pid="journal")
        print("trace: span records -> events "
              "(render with tools/tracelens.py)")
    if args.recover:
        if journal is None:
            print("--recover needs --journal")
            return 2
        info = router.recover()
        print(f"recover: {info['n_recovered']} requests rebuilt from the "
              f"journal ({info['n_done']} already complete on disk, "
              f"{info['n_placed']} re-placed, {info['n_pending']} pending, "
              f"{info['n_failed']} failed)")
    if args.chaos_seed >= 0:
        plan = chaos_plan(args.chaos_seed, steps=max(8, args.requests),
                          replicas=args.replicas,
                          n_events=args.chaos_events)
        FleetFaultInjector(router, plan)
        print(f"chaos: seed {args.chaos_seed} -> "
              f"{dict(plan.counts())}")
    memstat = None
    if args.metrics_every:
        memstat = MemStat(sink=sink,
                          plan_bytes=(int(args.mem_budget_mb * 2**20)
                                      or None))
        _install_obs_hook(router, sink, memstat, args.metrics_every,
                          router.registry_snapshot)
    trace = _make_trace(args, cfg, engines[0])
    t0 = time.time()
    summary = router.run(trace)
    wall = time.time() - t0
    fleet = summary["fleet"]
    print(f"fleet trace: {args.requests} requests in {wall:.2f}s; "
          f"health={summary['health']}")
    print(f"throughput: {summary['tokens_per_s']:.1f} tok/s, goodput "
          f"{summary['goodput_tokens_per_s']:.1f} tok/s "
          f"({summary['total_tokens']} tokens)")
    print(f"failover: {fleet['failovers']} failovers, "
          f"{fleet['n_migrations']} migrations, replay success "
          f"{fleet['replay_success_rate']:.2f}, quarantine steps "
          f"{summary['time_in_quarantine']}")
    print(f"outcomes: done {fleet['n_done']} dropped {fleet['n_dropped']} "
          f"cancelled {fleet['n_cancelled']} failed {fleet['n_failed']} "
          f"rejected {fleet['n_rejected']}")
    if fleet["n_recovered"]:
        print(f"recovery: {fleet['n_recovered']} recovered, replay "
              f"success {fleet['recovery_replay_success']:.2f}")
    if memstat is not None and memstat.samples:
        print(memstat.banner())
    if journal is not None:
        st = journal.state
        print(f"journal: {journal.appends} appends, "
              f"{journal.snapshots} snapshots, {st.n_submits} submits -> "
              f"{st.n_terminals} terminals (+{st.n_live} live)")
        journal.close()
    if args.workers:
        for w in engines:
            w.shutdown()
    if sink is not None:
        sink.close()
    if summary["stalled"]:
        print("STALLED fleet run")
        return 1
    rec = summary["reconcile"]
    assert rec["ok"], f"fleet ledger does not reconcile: {rec}"
    for e in engines:
        assert e.pool.occupancy == 0 and e.pool.allocs == e.pool.frees, \
            "slot leak"
    return 0


def run_engine(args, cfg, params, mesh=None) -> int:
    from repro.serve import supports

    if not supports(cfg):
        print(f"engine: {cfg.arch_id} is not engine-eligible (needs a "
              f"uniform-window GQA attention cache — MLA/SSM/encoder/"
              f"global-layer archs serve through the lockstep driver)")
        return 2
    _kv_banner(cfg, args, args.max_len)
    sink = _open_sink(args)
    budget = (int(args.mem_budget_mb * 2**20)
              if args.mem_budget_mb else None)
    engine = _build_engine(args, cfg, params, mesh, sink=sink)
    # one source of truth for capacity: the engine's own clamp/accounting
    if mesh is not None:
        from repro.distributed import sharding as shd
        print(f"mesh: {describe(mesh)}, kv cache sharded over "
              f"'{shd.serve_kv_shard(mesh, cfg.n_kv, args.max_len)}', "
              f"{engine.pool.bytes_per_slot_per_device()/2**20:.2f} "
              f"MB/slot PER DEVICE")
    print(f"capacity: {engine.pool.bytes_per_slot_per_device()/2**20:.2f} "
          f"MB/slot{'/device' if mesh is not None else ''} at "
          f"max_len={args.max_len}"
          + (f" -> budget {args.mem_budget_mb} MB"
             f"{' per device' if mesh is not None else ''} admits "
             f"{engine.pool.max_slots} of "
             f"{args.max_slots} requested slots" if budget else ""))
    t0 = time.time()
    compiles = engine.warmup()
    print(f"warmup: {time.time()-t0:.1f}s, programs={compiles}")
    if _want_trace(args, sink):
        engine.tracer = Tracer(sink, pid="r0")   # post-warmup attach
        print("trace: span records -> events "
              "(render with tools/tracelens.py)")
    memstat = None
    if args.metrics_every:
        memstat = MemStat(sink=sink,
                          plan_bytes=budget,
                          registry=engine.metrics.registry)
        _install_obs_hook(engine, sink, memstat, args.metrics_every,
                          engine.metrics.registry_snapshot)

    trace = _make_trace(args, cfg, engine)
    t0 = time.time()
    summary = engine.run(trace)
    wall = time.time() - t0
    assert engine.compile_counts() == compiles, \
        "recompile during serving (static-shape contract broken)"
    print(f"trace: {args.requests} requests in {wall:.2f}s "
          f"({summary['n_steps']} engine steps)")
    print(f"throughput: {summary['tokens_per_s']:.1f} tok/s "
          f"({summary['total_tokens']} tokens)")
    print(f"ttft: mean {summary['ttft_mean_s']*1e3:.1f} ms "
          f"(p95 {summary['ttft_p95_s']*1e3:.1f} ms, "
          f"{summary['ttft_mean_steps']:.1f} steps); "
          f"itl: {summary['itl_mean_s']*1e3:.1f} ms")
    print(f"occupancy: {summary['occupancy_mean']:.2f}/"
          f"{engine.pool.max_slots} slots "
          f"(queue depth mean {summary['queue_depth_mean']:.2f}, "
          f"max {summary['queue_depth_max']})")
    failures = (summary["n_cancelled"] + summary["n_dropped"]
                + summary["n_failed"])
    if failures or summary["n_rejected"] or summary["n_faults"]:
        print(f"failure paths: dropped {summary['n_dropped']} "
              f"cancelled {summary['n_cancelled']} "
              f"failed {summary['n_failed']} "
              f"rejected {summary['n_rejected']} "
              f"(faults {summary['n_faults']}, "
              f"retries {summary['n_retried']}); "
              f"goodput {summary['goodput_tokens_per_s']:.1f} tok/s "
              f"of {summary['tokens_per_s']:.1f}")
    if memstat is not None and memstat.samples:
        print(memstat.banner())
    if sink is not None:
        sink.close()
    if summary["stalled"]:
        print(f"STALLED: {summary['diagnostics']}")
        return 1
    # every trace request must be accounted for: finished, shed, or
    # rejected at the door — nothing silently lost
    assert summary["n_done"] + failures + summary["n_rejected"] \
        == args.requests
    assert engine.pool.occupancy == 0 and \
        engine.pool.allocs == engine.pool.frees, "slot leak"
    return 0


def run_lockstep(args, cfg, params) -> int:
    quant = not args.no_quantize
    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)

    s_total = args.prompt_len + args.gen
    _kv_banner(cfg, args, s_total)

    # the decode cache is preallocated at prompt_len + gen INSIDE the
    # compiled prefill (grow_cache) — no post-hoc host-side pad
    prefill = jax.jit(build_prefill_step(cfg, policy_name=args.policy,
                                         quantized=quant, s_max=s_total))
    decode = jax.jit(build_decode_step(cfg, policy_name=args.policy,
                                       quantized=quant,
                                       kvq_backend=args.kv_backend,
                                       kvq_splits=args.kv_splits))
    sampler = sampling.make_sampler(temperature=args.temperature,
                                    top_k=args.top_k)
    key = jax.random.PRNGKey(args.seed)

    t0 = time.time()
    batch = {"tokens": prompts}
    if cfg.encoder is not None:
        batch["frames"] = jnp.zeros(
            (args.batch, cfg.encoder.n_frames, cfg.d_model), jnp.float32)
    last_logits, cache = prefill(params, batch)
    tok = sampler(last_logits, jax.random.fold_in(key, 0))
    t_prefill = time.time() - t0

    wd = Watchdog()
    out_tokens = [np.asarray(tok)]
    dec_kw = {}
    if cfg.encoder is not None:
        dec_kw["enc_out"] = batch["frames"]
    t0 = time.time()
    try:
        for i in range(args.gen - 1):
            wd.step_start()
            logits, cache = decode(params, cache, tok, **dec_kw)
            tok = sampler(logits, jax.random.fold_in(key, i + 1))
            out_tokens.append(np.asarray(tok))
            wd.step_end()
    finally:
        wd.close()
    t_decode = time.time() - t0

    gen = np.stack(out_tokens, 1)
    print(f"prefill {args.batch}x{args.prompt_len}: {t_prefill*1e3:.0f} ms")
    print(f"decode {args.gen} tok: {t_decode*1e3:.0f} ms "
          f"({t_decode/max(1, args.gen-1)*1e3:.1f} ms/tok, "
          f"{args.batch*(args.gen-1)/max(t_decode,1e-9):.1f} tok/s)")
    print(f"sample: {gen[0][:12].tolist()}")
    assert np.isfinite(gen).all()
    return 0


def run(args):
    mesh = make_mesh_for(max_model=args.max_model)
    print(f"mesh: {describe(mesh)} ({mesh.size} devices)")
    cfg = configs.smoke_config(args.arch) if args.smoke \
        else configs.get_config(args.arch)
    params = transformer.init_params(cfg, jax.random.PRNGKey(args.seed))
    if args.engine:
        # single-device mesh adds nothing but sharding plumbing — keep the
        # engine on the exact unsharded path there
        eng_mesh = mesh if mesh.size > 1 else None
        if args.replicas > 1 or args.workers or args.journal:
            # journal/worker modes always go through the router — a
            # single replica is just a fleet of one
            return run_fleet(args, cfg, params, mesh=eng_mesh)
        return run_engine(args, cfg, params, mesh=eng_mesh)
    return run_lockstep(args, cfg, params)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--policy", default="bf16")
    ap.add_argument("--kv-backend", default="ref",
                    choices=["ref", "interpret", "pallas"],
                    help="int8 KV decode-attention backend (kernels/kvq)")
    ap.add_argument("--kv-splits", type=int, default=1,
                    help="split-K fan-out of the decode grid (clamped to "
                         "the cache's KV tile count)")
    ap.add_argument("--no-quantize", action="store_true")
    ap.add_argument("--temperature", type=float, default=0.0,
                    help="sampling temperature (0 = greedy, the default)")
    ap.add_argument("--top-k", type=int, default=0,
                    help="restrict sampling to the top-k logits (0 = all)")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--max-model", type=int, default=16)
    # -- continuous-batching engine mode ----------------------------------
    ap.add_argument("--engine", action="store_true",
                    help="serve a synthetic request trace through the "
                         "continuous-batching engine (repro.serve)")
    ap.add_argument("--requests", type=int, default=16,
                    help="engine: number of trace requests")
    ap.add_argument("--max-slots", type=int, default=4,
                    help="engine: resident request slots in the KV pool")
    ap.add_argument("--max-len", type=int, default=128,
                    help="engine: per-slot cache length (prompt + gen cap)")
    ap.add_argument("--mean-prompt", type=int, default=24,
                    help="engine: mean trace prompt length")
    ap.add_argument("--mean-gen", type=int, default=12,
                    help="engine: mean trace generation length")
    ap.add_argument("--arrival-rate", type=float, default=0.5,
                    help="engine: trace arrivals per engine step")
    ap.add_argument("--max-prefill-per-step", type=int, default=1,
                    help="engine: prefill-vs-decode interleave quota")
    ap.add_argument("--mem-budget-mb", type=float, default=0.0,
                    help="engine: clamp resident slots to this KV-pool "
                         "budget (plan.serve_capacity_report)")
    ap.add_argument("--max-queue", type=int, default=0,
                    help="engine: bounded queue depth — submits beyond it "
                         "are rejected (0 = unbounded)")
    ap.add_argument("--deadline-steps", type=int, default=-1,
                    help="engine: queue TTL in engine steps — requests "
                         "still queued past it are DROPPED (-1 = none)")
    ap.add_argument("--max-retries", type=int, default=2,
                    help="engine: replay budget per request after a "
                         "detected decode fault")
    ap.add_argument("--events", default="",
                    help="append fault/health/failover events to this "
                         "JSONL file (repro.events.EventSink)")
    ap.add_argument("--metrics-every", type=int, default=0,
                    help="every N steps: sample live-array bytes "
                         "(mem_sample) and emit a metrics_snapshot of "
                         "the obs registry to --events (0 = off)")
    ap.add_argument("--trace", action="store_true",
                    help="emit span_begin/span_end records (queue / "
                         "prefill / decode / migrate / journal / rpc) "
                         "to --events; tools/tracelens.py renders "
                         "per-request timelines and Perfetto JSON")
    # -- replica fleet (router) --------------------------------------------
    ap.add_argument("--replicas", type=int, default=1,
                    help="fleet: engine replicas behind the router "
                         "(1 = plain single-engine mode)")
    ap.add_argument("--route", default="least_loaded",
                    choices=["least_loaded", "round_robin"],
                    help="fleet: admission routing policy")
    ap.add_argument("--max-migrations", type=int, default=2,
                    help="fleet: cross-replica moves per request before "
                         "it FAILs at fleet level")
    ap.add_argument("--breaker-window", type=int, default=32,
                    help="fleet: circuit-breaker fault window (steps)")
    ap.add_argument("--breaker-degrade", type=int, default=1,
                    help="fleet: faults in window -> DEGRADED")
    ap.add_argument("--breaker-quarantine", type=int, default=3,
                    help="fleet: faults in window -> QUARANTINED")
    ap.add_argument("--breaker-cooldown", type=int, default=16,
                    help="fleet: quarantine steps before probation rejoin")
    ap.add_argument("--breaker-stall", type=int, default=8,
                    help="fleet: no-progress steps -> QUARANTINED")
    ap.add_argument("--chaos-seed", type=int, default=-1,
                    help="fleet: run the seeded chaos harness (replica "
                         "crash/sick/slow; -1 = off)")
    ap.add_argument("--chaos-events", type=int, default=3,
                    help="fleet: chaos events to schedule")
    # -- durability (write-ahead journal + subprocess workers) -------------
    ap.add_argument("--journal", default="",
                    help="fleet: write-ahead request journal (JSONL; "
                         "fsync'd).  Reopening an existing journal "
                         "replays it")
    ap.add_argument("--workers", action="store_true",
                    help="fleet: run each replica as a real subprocess "
                         "behind the pipe RPC (repro.serve.worker)")
    ap.add_argument("--recover", action="store_true",
                    help="fleet: rebuild in-flight requests from the "
                         "--journal before serving the trace "
                         "(whole-router crash recovery)")
    ap.add_argument("--journal-tokens-every", type=int, default=1,
                    help="fleet: journal token deltas every N router "
                         "steps (group-commit cadence; lost tail tokens "
                         "are regenerated deterministically on recovery)")
    return run(ap.parse_args())


if __name__ == "__main__":
    raise SystemExit(main())
