"""Production serving driver: batched prefill + decode with int8 KV cache.

    PYTHONPATH=src python -m repro.launch.serve --arch llama3-8b --smoke \
        --batch 4 --prompt-len 64 --gen 32

Serving-side fault tolerance: the decode loop is stateless beyond the
cache, so a restart re-prefills in one step; the watchdog flags stuck
steps (straggler chips in production).
"""
from __future__ import annotations

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.launch.mesh import describe, make_mesh_for
from repro.launch.train import Watchdog
from repro.models import transformer
from repro.train.serve_step import build_decode_step, build_prefill_step


def run(args):
    mesh = make_mesh_for(max_model=args.max_model)
    print(f"mesh: {describe(mesh)}")
    cfg = configs.smoke_config(args.arch) if args.smoke \
        else configs.get_config(args.arch)
    quant = not args.no_quantize
    params = transformer.init_params(cfg, jax.random.PRNGKey(args.seed))
    rng = np.random.default_rng(args.seed)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)

    # honest banner: name what decode will ACTUALLY run — the int8 kvq
    # kernel only dispatches on a quantized GQA cache (MLA latents and SSM
    # states take their own decode paths), and the split count is clamped
    # to the KV tile count of the grown cache
    s_total = args.prompt_len + args.gen
    kvq_eligible = cfg.mixer in ("attn", "hybrid") and cfg.mla is None
    if not kvq_eligible:
        kv_backend, kv_splits = "n/a (no kvq-layout attention cache)", 1
    elif quant:
        from repro.kernels.kvq import ops as kvq_ops
        kv_backend = args.kv_backend
        kv_splits = kvq_ops.resolve_splits(s_total, args.kv_splits)
    else:
        kv_backend, kv_splits = "jnp (cache not quantized)", 1
    print(f"kv decode: backend={kv_backend} splits={kv_splits} "
          f"(requested {args.kv_splits}, cache {s_total} slots)")

    prefill = jax.jit(build_prefill_step(cfg, policy_name=args.policy,
                                         quantized=quant))
    decode = jax.jit(build_decode_step(cfg, policy_name=args.policy,
                                       quantized=quant,
                                       kvq_backend=args.kv_backend,
                                       kvq_splits=args.kv_splits))

    t0 = time.time()
    batch = {"tokens": prompts}
    if cfg.encoder is not None:
        batch["frames"] = jnp.zeros(
            (args.batch, cfg.encoder.n_frames, cfg.d_model), jnp.float32)
    last_logits, cache = prefill(params, batch)

    def grow(path, x):
        name = str(path[-1].key)
        if name in ("k", "v"):
            return jnp.pad(x, [(0, 0)] * 3 + [(0, args.gen), (0, 0)])
        if name in ("k_scale", "v_scale"):
            return jnp.pad(x, [(0, 0)] * (x.ndim - 1) + [(0, args.gen)])
        if name in ("mla_lat", "mla_rope"):
            return jnp.pad(x, [(0, 0), (0, 0), (0, args.gen), (0, 0)])
        return x

    cache = jax.tree_util.tree_map_with_path(grow, cache)
    tok = jnp.asarray(last_logits.argmax(-1), jnp.int32)
    t_prefill = time.time() - t0

    wd = Watchdog()
    out_tokens = [np.asarray(tok)]
    dec_kw = {}
    if cfg.encoder is not None:
        dec_kw["enc_out"] = batch["frames"]
    t0 = time.time()
    try:
        for _ in range(args.gen - 1):
            wd.step_start()
            logits, cache = decode(params, cache, tok, **dec_kw)
            tok = jnp.asarray(logits.argmax(-1), jnp.int32)
            out_tokens.append(np.asarray(tok))
            wd.step_end()
    finally:
        wd.close()
    t_decode = time.time() - t0

    gen = np.stack(out_tokens, 1)
    print(f"prefill {args.batch}x{args.prompt_len}: {t_prefill*1e3:.0f} ms")
    print(f"decode {args.gen} tok: {t_decode*1e3:.0f} ms "
          f"({t_decode/max(1, args.gen-1)*1e3:.1f} ms/tok, "
          f"{args.batch*(args.gen-1)/max(t_decode,1e-9):.1f} tok/s)")
    print(f"sample: {gen[0][:12].tolist()}")
    assert np.isfinite(gen).all()
    return 0


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--smoke", action="store_true")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=64)
    ap.add_argument("--gen", type=int, default=32)
    ap.add_argument("--policy", default="bf16")
    ap.add_argument("--kv-backend", default="ref",
                    choices=["ref", "interpret", "pallas"],
                    help="int8 KV decode-attention backend (kernels/kvq)")
    ap.add_argument("--kv-splits", type=int, default=1,
                    help="split-K fan-out of the decode grid (clamped to "
                         "the cache's KV tile count)")
    ap.add_argument("--no-quantize", action="store_true")
    ap.add_argument("--seed", type=int, default=0)
    ap.add_argument("--max-model", type=int, default=16)
    return run(ap.parse_args())


if __name__ == "__main__":
    raise SystemExit(main())
