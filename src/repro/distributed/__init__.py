from repro.distributed import sharding, collectives
