"""Sharding rules: parameter, activation, and cache PartitionSpecs.

Mesh axes: ``("pod", "data", "model")`` multi-pod or ``("data", "model")``
single-pod.  DP runs over (pod, data); TP over model.  Rules are name-based
over the param tree:

  * last-dim "model"      : wq wk wv w_gate w_up q_b kv_b w1 b1 shared_* lm_head
  * penultimate "model"   : wo w_down w2 shared_down embed
  * MoE EP mode           : experts sharded on the expert axis instead
  * SSM params            : replicated (small; heads rarely divide 16 —
                            DESIGN.md §5 records this choice)

Caches shard batch over DP when divisible, KV-heads over model when
divisible, otherwise the *sequence* dim over model (long-context serving).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig

_LAST = {"wq", "wk", "wv", "w_gate", "w_up", "q_b", "kv_b", "w1", "b1",
         "shared_gate", "shared_up", "lm_head"}
_PENULT = {"wo", "w_down", "w2", "shared_down", "embed"}


def dp_axes(mesh: Mesh):
    # a bare axis name (not a 1-tuple) so PartitionSpec entries compare
    # equal across jax versions that do / don't normalize singleton tuples
    return ("pod", "data") if "pod" in mesh.axis_names else "data"


def dp_size(mesh: Mesh) -> int:
    axes = dp_axes(mesh)
    s = 1
    for a in ((axes,) if isinstance(axes, str) else axes):
        s *= mesh.shape[a]
    return s


def _path_names(path) -> list[str]:
    names = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            names.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            names.append(str(p.idx))
    return names


def param_specs(cfg: ModelConfig | None, params_shape) -> Any:
    """PartitionSpec tree matching ``params_shape`` (shapes or arrays)."""
    ep = cfg is not None and cfg.moe is not None and cfg.moe.expert_mode == "ep"

    def spec_for(path, leaf):
        names = _path_names(path)
        name = names[-1]
        nd = len(leaf.shape)
        in_ssm = "ssm" in names
        if in_ssm:
            return P()
        if ep and name in ("w_gate", "w_up", "w_down") and nd == 4:
            return P(None, "model", None, None)      # experts over model
        if name in _LAST and nd >= 1:
            return P(*([None] * (nd - 1) + ["model"]))
        if name in _PENULT and nd >= 2:
            return P(*([None] * (nd - 2) + ["model", None]))
        return P()

    return jax.tree_util.tree_map_with_path(spec_for, params_shape)


def batch_specs(cfg: ModelConfig, batch_shape, mesh: Mesh) -> Any:
    """Input-batch specs: leading batch dim over DP (positions: dim 1)."""
    dp = dp_axes(mesh)

    def spec_for(path, leaf):
        name = _path_names(path)[-1]
        nd = len(leaf.shape)
        if name == "positions" and nd == 3:          # (3, B, S) M-RoPE
            return P(None, dp, None)
        return P(*([dp] + [None] * (nd - 1)))

    return jax.tree_util.tree_map_with_path(spec_for, batch_shape)


def cache_specs(cfg: ModelConfig, cache_shape, mesh: Mesh) -> Any:
    """Decode-cache specs (see module docstring for the policy)."""
    dp = dp_axes(mesh)
    n_dp = dp_size(mesh)
    n_model = mesh.shape["model"]

    def spec_for(path, leaf):
        name = _path_names(path)[-1]
        shape = leaf.shape
        if name == "pos":
            return P()
        b = shape[1] if len(shape) > 1 else 0
        b_ax = dp if (b and b % n_dp == 0) else None
        if name in ("k", "v", "gk", "gv", "wk", "wv"):   # (L, B, Hkv, S, hd)
            hkv, s = shape[2], shape[3]
            if hkv % n_model == 0:
                return P(None, b_ax, "model", None, None)
            seq_ax = ("data", "model") if b_ax is None else "model"
            n_seq = n_model if b_ax is not None else (
                n_dp * n_model // mesh.shape.get("pod", 1))
            if s % n_seq:                # rolling window buffers stay local
                seq_ax = None
            return P(None, b_ax, None, seq_ax, None)
        if name in ("k_scale", "v_scale", "gk_scale", "gv_scale",
                    "wk_scale", "wv_scale"):             # (L, B, Hkv, S)
            hkv, s = shape[2], shape[3]
            if hkv % n_model == 0:
                return P(None, b_ax, "model", None)
            seq_ax = ("data", "model") if b_ax is None else "model"
            n_seq = n_model if b_ax is not None else (
                n_dp * n_model // mesh.shape.get("pod", 1))
            if s % n_seq:
                seq_ax = None
            return P(None, b_ax, None, seq_ax)
        if name in ("mla_lat", "mla_rope"):          # (L, B, S, r)
            seq_ax = ("data", "model") if b_ax is None else "model"
            return P(None, b_ax, seq_ax, None)
        if name in ("ssm", "conv"):                  # small states: DP only
            return P(None, b_ax)
        return P()

    return jax.tree_util.tree_map_with_path(spec_for, cache_shape)


def to_shardings(mesh: Mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
