"""Sharding rules: parameter, activation, and cache PartitionSpecs.

Mesh axes: ``("pod", "data", "model")`` multi-pod or ``("data", "model")``
single-pod.  DP runs over (pod, data); TP over model.  Rules are name-based
over the param tree:

  * last-dim "model"      : wq wk wv w_gate w_up q_b kv_b w1 b1 shared_* lm_head
  * penultimate "model"   : wo w_down w2 shared_down embed
  * MoE EP mode           : experts sharded on the expert axis instead
  * SSM params            : replicated (small; heads rarely divide 16 —
                            DESIGN.md §5 records this choice)

Caches shard batch over DP when divisible, KV-heads over model when
divisible, otherwise the *sequence* dim over model (long-context serving).
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P

from repro.models.config import ModelConfig

_LAST = {"wq", "wk", "wv", "w_gate", "w_up", "q_b", "kv_b", "w1", "b1",
         "shared_gate", "shared_up", "lm_head"}
_PENULT = {"wo", "w_down", "w2", "shared_down", "embed"}


def dp_axes(mesh: Mesh):
    # a bare axis name (not a 1-tuple) so PartitionSpec entries compare
    # equal across jax versions that do / don't normalize singleton tuples
    return ("pod", "data") if "pod" in mesh.axis_names else "data"


def dp_size(mesh: Mesh) -> int:
    axes = dp_axes(mesh)
    s = 1
    for a in ((axes,) if isinstance(axes, str) else axes):
        s *= mesh.shape[a]
    return s


def _path_names(path) -> list[str]:
    names = []
    for p in path:
        if isinstance(p, jax.tree_util.DictKey):
            names.append(str(p.key))
        elif isinstance(p, jax.tree_util.SequenceKey):
            names.append(str(p.idx))
    return names


def param_specs(cfg: ModelConfig | None, params_shape,
                mesh: Mesh | None = None) -> Any:
    """PartitionSpec tree matching ``params_shape`` (shapes or arrays).

    With ``mesh``, specs are validated against the actual model-axis width:
    any dim the rule would put on "model" but whose size doesn't divide
    ``mesh.shape["model"]`` falls back to replicated for that leaf — so the
    same rule table serves production 16-wide TP and a 2-wide CPU-CI mesh
    without per-arch special cases.  Without ``mesh`` the raw (production)
    rules are returned unchanged.
    """
    ep = cfg is not None and cfg.moe is not None and cfg.moe.expert_mode == "ep"
    n_model = None
    if mesh is not None:
        n_model = mesh.shape["model"] if "model" in mesh.axis_names else 1

    def fit(spec: P, shape) -> P:
        if n_model is None:
            return spec
        out = []
        for ax, name in enumerate(spec):
            if name == "model" and (n_model == 1 or shape[ax] % n_model):
                out.append(None)
            else:
                out.append(name)
        return P(*out)

    def spec_for(path, leaf):
        names = _path_names(path)
        name = names[-1]
        nd = len(leaf.shape)
        in_ssm = "ssm" in names
        if in_ssm:
            return P()
        if ep and name in ("w_gate", "w_up", "w_down") and nd == 4:
            return fit(P(None, "model", None, None), leaf.shape)
        if name in _LAST and nd >= 1:
            return fit(P(*([None] * (nd - 1) + ["model"])), leaf.shape)
        if name in _PENULT and nd >= 2:
            return fit(P(*([None] * (nd - 2) + ["model", None])), leaf.shape)
        return P()

    return jax.tree_util.tree_map_with_path(spec_for, params_shape)


def flash_shard_specs(mesh: Mesh | None, batch: int, heads: int,
                      kv_heads: int) -> "P | None":
    """The PartitionSpec to shard_map flash attention with, or None.

    Flash q/k/v/o all travel in (B, H|Hkv, S, D) layout and shard the same
    way: batch over DP, heads over "model".  Head sharding needs BOTH head
    counts to divide the model axis — contiguous equal blocks keep every
    GQA group (q-head j with kv-head j // g) on one shard, so the kernel
    never crosses shards.  None means the mesh can't split the call
    cleanly (or is trivial) and the caller should dispatch unsharded.
    """
    if mesh is None or "model" not in mesh.axis_names:
        return None
    n_model = mesh.shape["model"]
    dp = dp_axes(mesh)
    n_dp = dp_size(mesh)
    b_ax = dp if (n_dp > 1 and batch % n_dp == 0) else None
    h_ax = "model" if (n_model > 1 and heads % n_model == 0
                       and kv_heads % n_model == 0) else None
    if b_ax is None and h_ax is None:
        return None
    return P(b_ax, h_ax, None, None)


def serve_kv_shard(mesh: Mesh | None, kv_heads: int, s: int) -> str:
    """How the serve pool's (B, Hkv, S, hd) cache shards under ``mesh``.

    "heads": kv-heads over "model" (the natural GQA split); "seq": the
    sequence axis over "model" with the flash-combine collective merging
    per-shard softmax partials; "none": replicated.  The slot (batch) axis
    is NEVER sharded — data parallelism in serving is separate engine
    replicas, and a sharded slot axis would turn ``scatter_request``'s
    join into a cross-device scatter.  The ONE rule ``serve_cache_specs``,
    ``attn_decode``, and the capacity planner all consult, so placement
    and compute can't drift.
    """
    if mesh is None or "model" not in mesh.axis_names:
        return "none"
    n_model = mesh.shape["model"]
    if n_model == 1:
        return "none"
    if kv_heads % n_model == 0:
        return "heads"
    if s % n_model == 0:
        return "seq"
    return "none"


def serve_cache_specs(cfg: ModelConfig, cache_shape, mesh: Mesh) -> Any:
    """Slot-pool cache specs for the continuous-batching engine.

    Per :func:`serve_kv_shard`; leaves the engine doesn't shard (per-slot
    ``pos`` lengths, SSM/conv state) are replicated."""

    def spec_for(path, leaf):
        name = _path_names(path)[-1]
        shape = leaf.shape
        if name in ("k", "v") and len(shape) == 5:       # (L, B, Hkv, S, hd)
            mode = serve_kv_shard(mesh, shape[2], shape[3])
            if mode == "heads":
                return P(None, None, "model", None, None)
            if mode == "seq":
                return P(None, None, None, "model", None)
        if name in ("k_scale", "v_scale") and len(shape) == 4:  # (L,B,Hkv,S)
            mode = serve_kv_shard(mesh, shape[2], shape[3])
            if mode == "heads":
                return P(None, None, "model", None)
            if mode == "seq":
                return P(None, None, None, "model")
        return P()

    return jax.tree_util.tree_map_with_path(spec_for, cache_shape)


def spec_shards(mesh: Mesh, spec: P) -> int:
    """Number of devices a PartitionSpec splits one array across."""
    n = 1
    for entry in spec:
        if entry is None:
            continue
        for ax in ((entry,) if isinstance(entry, str) else entry):
            n *= mesh.shape[ax]
    return n


def batch_specs(cfg: ModelConfig, batch_shape, mesh: Mesh) -> Any:
    """Input-batch specs: leading batch dim over DP (positions: dim 1)."""
    dp = dp_axes(mesh)

    def spec_for(path, leaf):
        name = _path_names(path)[-1]
        nd = len(leaf.shape)
        if name == "positions" and nd == 3:          # (3, B, S) M-RoPE
            return P(None, dp, None)
        return P(*([dp] + [None] * (nd - 1)))

    return jax.tree_util.tree_map_with_path(spec_for, batch_shape)


def cache_specs(cfg: ModelConfig, cache_shape, mesh: Mesh) -> Any:
    """Decode-cache specs (see module docstring for the policy)."""
    dp = dp_axes(mesh)
    n_dp = dp_size(mesh)
    n_model = mesh.shape["model"]

    def spec_for(path, leaf):
        name = _path_names(path)[-1]
        shape = leaf.shape
        if name == "pos":
            return P()
        b = shape[1] if len(shape) > 1 else 0
        b_ax = dp if (b and b % n_dp == 0) else None
        if name in ("k", "v", "gk", "gv", "wk", "wv"):   # (L, B, Hkv, S, hd)
            hkv, s = shape[2], shape[3]
            if hkv % n_model == 0:
                return P(None, b_ax, "model", None, None)
            seq_ax = ("data", "model") if b_ax is None else "model"
            n_seq = n_model if b_ax is not None else (
                n_dp * n_model // mesh.shape.get("pod", 1))
            if s % n_seq:                # rolling window buffers stay local
                seq_ax = None
            return P(None, b_ax, None, seq_ax, None)
        if name in ("k_scale", "v_scale", "gk_scale", "gv_scale",
                    "wk_scale", "wv_scale"):             # (L, B, Hkv, S)
            hkv, s = shape[2], shape[3]
            if hkv % n_model == 0:
                return P(None, b_ax, "model", None)
            seq_ax = ("data", "model") if b_ax is None else "model"
            n_seq = n_model if b_ax is not None else (
                n_dp * n_model // mesh.shape.get("pod", 1))
            if s % n_seq:
                seq_ax = None
            return P(None, b_ax, None, seq_ax)
        if name in ("mla_lat", "mla_rope"):          # (L, B, S, r)
            seq_ax = ("data", "model") if b_ax is None else "model"
            return P(None, b_ax, seq_ax, None)
        if name in ("ssm", "conv"):                  # small states: DP only
            return P(None, b_ax)
        return P()

    return jax.tree_util.tree_map_with_path(spec_for, cache_shape)


def to_shardings(mesh: Mesh, spec_tree):
    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), spec_tree,
        is_leaf=lambda x: isinstance(x, P))
