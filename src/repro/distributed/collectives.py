"""Explicit collectives built on shard_map: compressed DP all-reduce and
sequence-parallel decode attention (flash-combine across the model axis).

The pjit training path leaves gradient reduction to XLA; these are the
hand-rolled equivalents for (a) gradient compression over slow cross-pod
links, (b) serving long contexts with the KV sequence dim sharded.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def compressed_psum_grads(grads, mesh: Mesh, axis: str | tuple, key,
                          *, codec: str = "int8"):
    """All-reduce ``grads`` over the DP axis with int8 payloads.

    Each device quantizes its local shard-grads to int8, the psum runs on
    the *dequantized* values (XLA reduces fp32; on real interconnect the
    int8 payload is what crosses links — we account bytes, not wire format,
    see benchmarks/bench_compression.py), and the result is rescaled.
    Stochastic rounding keeps the estimate unbiased.
    """
    from repro.optim import compression

    axes = (axis,) if isinstance(axis, str) else tuple(axis)

    def local_reduce(g):
        def per_leaf(x, k):
            q, s = compression.quantize_int8(x, k)
            deq = compression.dequantize_int8(q, s)
            return jax.lax.psum(deq, axes)

        leaves, treedef = jax.tree_util.tree_flatten(g)
        keys = jax.random.split(key, len(leaves))
        return treedef.unflatten(
            [per_leaf(x, k) for x, k in zip(leaves, keys)])

    spec = jax.tree_util.tree_map(lambda _: P(), grads)
    return shard_map(local_reduce, mesh=mesh, in_specs=(spec,),
                     out_specs=spec, check_rep=False)(grads)


def sp_decode_attention(q, k_cache, v_cache, bias, mesh: Mesh, *,
                        sm_scale: float, seq_axis: str = "model"):
    """Decode attention with the KV sequence dim sharded over ``seq_axis``.

    Each shard computes local flash statistics (m_i, l_i, o_i); a psum-style
    renormalization combines them — one small collective instead of
    all-gathering the cache:
      m = max_i m_i;  l = sum_i l_i e^{m_i - m};  o = sum_i o_i l_i e^{m_i-m} / l
    q: (B, H, D); k/v_cache: (B, H, S, D); bias: (B, S).
    """
    def local(q_l, k_l, v_l, b_l):
        logits = jnp.einsum("bhd,bhsd->bhs", q_l.astype(jnp.float32),
                            k_l.astype(jnp.float32)) * sm_scale
        logits = logits + b_l[:, None, :]
        m_i = logits.max(-1)                                   # (B, H)
        p = jnp.exp(logits - m_i[..., None])
        l_i = p.sum(-1)
        o_i = jnp.einsum("bhs,bhsd->bhd", p, v_l.astype(jnp.float32))
        m = jax.lax.pmax(m_i, seq_axis)
        corr = jnp.exp(m_i - m)
        l = jax.lax.psum(l_i * corr, seq_axis)
        o = jax.lax.psum(o_i * corr[..., None], seq_axis)
        return (o / jnp.maximum(l, 1e-30)[..., None]).astype(q_l.dtype)

    return shard_map(
        local, mesh=mesh,
        in_specs=(P(), P(None, None, seq_axis, None),
                  P(None, None, seq_axis, None), P(None, seq_axis)),
        out_specs=P(), check_rep=False)(q, k_cache, v_cache, bias)
