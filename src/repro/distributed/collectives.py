"""Explicit collectives built on shard_map: compressed DP all-reduce and
sequence-parallel decode attention (flash-combine across the model axis).

The pjit training path leaves gradient reduction to XLA; these are the
hand-rolled equivalents for (a) gradient compression over slow cross-pod
links, (b) serving long contexts with the KV sequence dim sharded.
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import Mesh, NamedSharding, PartitionSpec as P
from jax.experimental.shard_map import shard_map


def compressed_psum_grads(grads, mesh: Mesh, axis: str | tuple, key,
                          *, codec: str = "int8"):
    """Mean all-reduce of PER-DEVICE grads over a DP axis, int8 payloads.

    ``grads`` leaves carry a leading device axis of size ``prod(axis)`` —
    one microbatch-grad per DP rank, the tensor each device holds after
    its local backward.  Each rank quantizes its OWN slice to int8 with a
    rank-folded stochastic-rounding key (decorrelated noise is what makes
    the mean unbiased — a shared key would correlate the rounding errors
    and they'd no longer average out), the psum runs on the dequantized
    values (XLA reduces fp32; on real interconnect the int8 payload is
    what crosses links — we account bytes, not wire format, see
    benchmarks/bench_compression.py), and every rank gets the replicated
    mean with the device axis dropped.
    """
    from repro.optim import compression

    axes = (axis,) if isinstance(axis, str) else tuple(axis)
    n = 1
    for a in axes:
        n *= mesh.shape[a]

    def local_reduce(g):
        rank = jnp.int32(0)
        for a in axes:
            rank = rank * mesh.shape[a] + jax.lax.axis_index(a)
        rkey = jax.random.fold_in(key, rank)

        def per_leaf(x, k):
            q, s = compression.quantize_int8(x[0], k)
            deq = compression.dequantize_int8(q, s)
            return jax.lax.psum(deq, axes) / n

        leaves, treedef = jax.tree_util.tree_flatten(g)
        keys = jax.random.split(rkey, len(leaves))
        return treedef.unflatten(
            [per_leaf(x, k) for x, k in zip(leaves, keys)])

    in_spec = jax.tree_util.tree_map(
        lambda x: P(axes if len(axes) > 1 else axes[0],
                    *([None] * (x.ndim - 1))), grads)
    out_spec = jax.tree_util.tree_map(lambda _: P(), grads)
    return shard_map(local_reduce, mesh=mesh, in_specs=(in_spec,),
                     out_specs=out_spec, check_rep=False)(grads)


def sp_decode_attention(q, k_cache, v_cache, bias, mesh: Mesh, *,
                        sm_scale: float, seq_axis: str = "model"):
    """Decode attention with the KV sequence dim sharded over ``seq_axis``.

    Each shard computes local flash statistics (m_i, l_i, o_i); a psum-style
    renormalization combines them — one small collective instead of
    all-gathering the cache:
      m = max_i m_i;  l = sum_i l_i e^{m_i - m};  o = sum_i o_i l_i e^{m_i-m} / l
    q: (B, H, D); k/v_cache: (B, H, S, D); bias: (B, S).
    """
    def local(q_l, k_l, v_l, b_l):
        logits = jnp.einsum("bhd,bhsd->bhs", q_l.astype(jnp.float32),
                            k_l.astype(jnp.float32)) * sm_scale
        logits = logits + b_l[:, None, :]
        m_i = logits.max(-1)                                   # (B, H)
        p = jnp.exp(logits - m_i[..., None])
        l_i = p.sum(-1)
        o_i = jnp.einsum("bhs,bhsd->bhd", p, v_l.astype(jnp.float32))
        m = jax.lax.pmax(m_i, seq_axis)
        corr = jnp.exp(m_i - m)
        l = jax.lax.psum(l_i * corr, seq_axis)
        o = jax.lax.psum(o_i * corr[..., None], seq_axis)
        return (o / jnp.maximum(l, 1e-30)[..., None]).astype(q_l.dtype)

    return shard_map(
        local, mesh=mesh,
        in_specs=(P(), P(None, None, seq_axis, None),
                  P(None, None, seq_axis, None), P(None, seq_axis)),
        out_specs=P(), check_rep=False)(q, k_cache, v_cache, bias)


NEG_INF = -1e30


def sp_decode_attention_int8(q, k_q, k_s, v_q, v_s, write, write_at,
                             mesh: Mesh, *, sm_scale: float, lengths=None,
                             bias=None, seq_axis: str = "model"):
    """One-token GQA decode over an int8 cache whose SEQUENCE dim is
    sharded over ``seq_axis`` — the serve fallback when kv-heads don't
    divide the model axis (:func:`repro.distributed.sharding.serve_kv_shard`).

    The token WRITE happens inside the same shard_map: each shard tests
    whether ``write_at`` lands in its slice and applies a local
    dynamic_update_slice (a DUS on a sharded dim outside shard_map would
    make XLA re-shard the cache — exactly the all-gather this path
    exists to avoid).  Attention is the cross-device twin of the split-K
    kernel: per-shard masked softmax partials (m_i, l_i, o_i) merged with
    one flash-combine (pmax/psum) — small collectives over (B, H)-sized
    stats, never the cache.

    A fully-masked shard (every owned position beyond the row's length)
    contributes m_i = -inf, l_i = o_i = 0 and underflows out of the
    combine; ``lengths >= 1`` (the engine's free-slot clamp) guarantees at
    least one live shard per row.

    q: (B, H, D); k_q/v_q: (B, Hkv, S, D) int8; k_s/v_s: (B, Hkv, S) f32;
    write: (kq_new (B,Hkv,D) int8, ks_new (B,Hkv) f32, vq_new, vs_new);
    write_at: (B,) int32 global positions; lengths: (B,) int32 XOR
    bias: (B, S) additive mask.  Returns (out (B, H, D) f32, then the
    four updated cache shards).
    """
    assert (lengths is None) != (bias is None), \
        "exactly one of lengths/bias"
    b, h, d = q.shape
    hkv = k_q.shape[1]
    g = h // hkv
    have_lengths = lengths is not None

    def local_write(c_l, new, local_at, own):
        if c_l.ndim == 4:
            upd = jax.vmap(lambda c, n_, a: jax.lax.dynamic_update_slice(
                c, n_[:, None], (0, a, 0)))(c_l, new, local_at)
        else:
            upd = jax.vmap(lambda c, n_, a: jax.lax.dynamic_update_slice(
                c, n_[:, None], (0, a)))(c_l, new, local_at)
        return jnp.where(own.reshape((-1,) + (1,) * (c_l.ndim - 1)),
                         upd, c_l)

    def local(q_l, kq_l, ks_l, vq_l, vs_l, kqn, ksn, vqn, vsn, at, mask):
        s_l = kq_l.shape[2]
        offset = jax.lax.axis_index(seq_axis) * s_l
        local_at = jnp.clip(at - offset, 0, s_l - 1)
        own = (at >= offset) & (at < offset + s_l)
        kq_l = local_write(kq_l, kqn, local_at, own)
        ks_l = local_write(ks_l, ksn, local_at, own)
        vq_l = local_write(vq_l, vqn, local_at, own)
        vs_l = local_write(vs_l, vsn, local_at, own)

        k = kq_l.astype(jnp.float32) * ks_l[..., None]
        v = vq_l.astype(jnp.float32) * vs_l[..., None]
        qg = q_l.astype(jnp.float32).reshape(b, hkv, g, d)
        logits = jnp.einsum("bhgd,bhsd->bhgs", qg, k) * sm_scale
        if have_lengths:
            kv_pos = offset + jnp.arange(s_l)
            valid = kv_pos[None, :] < mask[:, None]            # (B, S_l)
            logits = jnp.where(valid[:, None, None], logits, NEG_INF)
        else:
            logits = logits + mask[:, None, None, :]
        ok = logits > NEG_INF / 2
        m_i = jnp.where(ok.any(-1), logits.max(-1), NEG_INF)   # (B,Hkv,G)
        p = jnp.where(ok, jnp.exp(logits - m_i[..., None]), 0.0)
        l_i = p.sum(-1)
        o_i = jnp.einsum("bhgs,bhsd->bhgd", p, v)
        m = jax.lax.pmax(m_i, seq_axis)
        corr = jnp.exp(m_i - m)
        l = jax.lax.psum(l_i * corr, seq_axis)
        o = jax.lax.psum(o_i * corr[..., None], seq_axis)
        out = o / jnp.maximum(l, 1e-30)[..., None]
        return out.reshape(b, h, d), kq_l, ks_l, vq_l, vs_l

    kv_spec = P(None, None, seq_axis, None)
    sc_spec = P(None, None, seq_axis)
    mask = lengths if have_lengths else bias
    mask_spec = P() if have_lengths else P(None, seq_axis)
    return shard_map(
        local, mesh=mesh,
        in_specs=(P(), kv_spec, sc_spec, kv_spec, sc_spec,
                  P(), P(), P(), P(), P(), mask_spec),
        out_specs=(P(), kv_spec, sc_spec, kv_spec, sc_spec),
        check_rep=False)(q, k_q, k_s, v_q, v_s, *write, write_at, mask)
