"""Gradient compression with error feedback (distributed-optimization trick).

Two codecs for the DP gradient reduction:
  * int8 per-leaf-scaled quantization (stochastic rounding) — 4x fewer
    reduction bytes than fp32, unbiased.
  * top-k sparsification — k largest-magnitude entries per leaf.

Both maintain an *error-feedback* buffer (residual added back next step)
so compression error does not accumulate as bias.  Used by the explicit
shard_map DP trainer (``repro.distributed.collectives.compressed_psum``)
and benchmarked in benchmarks/; the pjit path leaves reduction to XLA.
"""
from __future__ import annotations

import dataclasses
from typing import Any

import jax
import jax.numpy as jnp


def quantize_int8(x: jax.Array, key) -> tuple[jax.Array, jax.Array]:
    amax = jnp.max(jnp.abs(x))
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    scaled = x / scale
    noise = jax.random.uniform(key, x.shape, minval=-0.5, maxval=0.5)
    q = jnp.clip(jnp.round(scaled + noise), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale


def compress_with_feedback(grads, residual, key, *, codec: str = "int8",
                           topk_frac: float = 0.01):
    """Returns (payload, new_residual).  payload leaves are (q, scale) or
    (values, indices) — what would cross the DP links."""
    leaves, treedef = jax.tree_util.tree_flatten(grads)
    res_leaves = treedef.flatten_up_to(residual) if residual is not None \
        else [jnp.zeros_like(l) for l in leaves]
    keys = jax.random.split(key, len(leaves))
    payload, new_res = [], []
    for g, r, k in zip(leaves, res_leaves, keys):
        g = g.astype(jnp.float32) + r
        if codec == "int8":
            q, s = quantize_int8(g, k)
            recon = dequantize_int8(q, s)
            payload.append((q, s))
        elif codec == "topk":
            kk = max(1, int(g.size * topk_frac))
            flat = g.reshape(-1)
            vals, idx = jax.lax.top_k(jnp.abs(flat), kk)
            kept = flat[idx]
            recon = jnp.zeros_like(flat).at[idx].set(kept).reshape(g.shape)
            payload.append((kept, idx))
        else:
            raise ValueError(codec)
        new_res.append(g - recon)
    return treedef.unflatten(payload), treedef.unflatten(new_res)


def decompress(payload, like, *, codec: str = "int8"):
    leaves, treedef = jax.tree_util.tree_flatten(
        like)
    pay = treedef.flatten_up_to(payload)
    out = []
    for (a, b), l in zip(pay, leaves):
        if codec == "int8":
            out.append(dequantize_int8(a, b).reshape(l.shape))
        else:
            out.append(jnp.zeros((l.size,), jnp.float32).at[b].set(a)
                       .reshape(l.shape))
    return treedef.unflatten(out)


def payload_bytes(payload) -> int:
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(payload))
