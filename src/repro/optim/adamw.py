"""AdamW with decoupled weight decay, cosine schedule, and grad clipping.

Optimizer state shards exactly like the params (mu/nu inherit the param
PartitionSpecs), so DP+TP training needs no extra rules.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp


@jax.tree_util.register_dataclass
@dataclasses.dataclass
class AdamWState:
    mu: Any
    nu: Any
    count: jax.Array


@dataclasses.dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    grad_clip: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10000
    min_lr_frac: float = 0.1


def schedule(cfg: AdamWConfig, step):
    step = step.astype(jnp.float32)
    warm = jnp.minimum(1.0, (step + 1) / max(1, cfg.warmup_steps))
    prog = jnp.clip((step - cfg.warmup_steps)
                    / max(1, cfg.total_steps - cfg.warmup_steps), 0.0, 1.0)
    cos = cfg.min_lr_frac + (1 - cfg.min_lr_frac) * 0.5 * (1 + jnp.cos(jnp.pi * prog))
    return cfg.lr * warm * cos


def init(params) -> AdamWState:
    zeros = lambda p: jnp.zeros(p.shape, jnp.float32)
    return AdamWState(mu=jax.tree_util.tree_map(zeros, params),
                      nu=jax.tree_util.tree_map(zeros, params),
                      count=jnp.zeros((), jnp.int32))


def global_norm(tree) -> jax.Array:
    leaves = [jnp.sum(jnp.square(x.astype(jnp.float32)))
              for x in jax.tree_util.tree_leaves(tree)]
    return jnp.sqrt(jnp.sum(jnp.stack(leaves)))


def update(cfg: AdamWConfig, grads, state: AdamWState, params, *,
           skip: jax.Array | None = None):
    """Returns (new_params, new_state, metrics).  ``skip`` (from the fp16
    loss-scale finite check) freezes params/moments for this step."""
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.grad_clip / jnp.maximum(gnorm, 1e-9)) \
        if cfg.grad_clip > 0 else jnp.float32(1.0)
    grads = jax.tree_util.tree_map(
        lambda g: g.astype(jnp.float32) * scale, grads)

    count = state.count + 1
    lr = schedule(cfg, state.count)
    b1c = 1 - cfg.b1 ** count.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** count.astype(jnp.float32)

    def upd(p, g, m, v):
        m2 = cfg.b1 * m + (1 - cfg.b1) * g
        v2 = cfg.b2 * v + (1 - cfg.b2) * jnp.square(g)
        step_ = lr * (m2 / b1c) / (jnp.sqrt(v2 / b2c) + cfg.eps)
        decay = jnp.where(jnp.ndim(p) >= 2, cfg.weight_decay, 0.0)
        p2 = (p.astype(jnp.float32) * (1 - lr * decay) - step_).astype(p.dtype)
        if skip is not None:
            keep = skip  # True => skip the update
            p2 = jnp.where(keep, p, p2)
            m2 = jnp.where(keep, m, m2)
            v2 = jnp.where(keep, v, v2)
        return p2, m2, v2

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(state.mu)
    flat_v = treedef.flatten_up_to(state.nu)
    out = [upd(p, g, m, v) for p, g, m, v in zip(flat_p, flat_g, flat_m, flat_v)]
    new_p = treedef.unflatten([o[0] for o in out])
    new_m = treedef.unflatten([o[1] for o in out])
    new_v = treedef.unflatten([o[2] for o in out])
    new_count = count if skip is None else jnp.where(skip, state.count, count)
    return new_p, AdamWState(new_m, new_v, new_count), {
        "grad_norm": gnorm, "lr": lr}
