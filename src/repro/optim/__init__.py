from repro.optim import adamw, compression
