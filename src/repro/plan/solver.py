"""Checkpoint-placement solvers: the paper's Fig. 11 advice as optimization.

Peak training memory under sequential checkpoints (S-C) is modelled as

    peak = sum(stored checkpoint activations) + max over segments of the
           segment's internal live set (all intra-segment activations are
           live at once while that segment's backward recomputes),

following Chen et al. (sublinear memory cost) and Beaumont et al.
(optimal checkpointing for heterogeneous chains).  Two solvers:

  * ``min_peak_boundaries``  — the dual problem: given a checkpoint *count*
    k, place the k boundaries minimizing peak bytes (picks the narrow
    activations on a UNet-shaped profile — paper Fig. 11).
  * ``budget_boundaries``    — the primal: given a byte *budget*, minimize
    recompute FLOPs subject to ``peak <= budget``.  Key structural fact:
    under full remat every segment before the last checkpoint is re-run,
    so recompute FLOPs = prefix_flops(last boundary) — independent of the
    interior placement.  Minimizing recompute therefore means finding the
    EARLIEST feasible last boundary, then any interior placement that fits.

Both emit a :class:`RematPlan` — a serializable, model-agnostic description
(boundaries + per-segment policy) that ``repro.core.checkpoint`` executes.
This module is dependency-free (no jax) so every layer can import it.
"""
from __future__ import annotations

import dataclasses
import json
from typing import Sequence


# ---------------------------------------------------------------------------
# The plan artifact.
# ---------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class RematPlan:
    """Where to cut a layer chain into remat segments.

    n_layers:    length of the chain the plan was solved for (validated at
                 application time — a plan never silently applies to a
                 different depth).
    boundaries:  sorted interior checkpoint sites b (0 < b < n_layers);
                 segment j spans layers [b_{j-1}, b_j).
    policy:      a single policy name for every segment, or one name per
                 segment (len == n_segments) for heterogeneous plans.
    source:      provenance string ("uniform", "min_peak:k=3",
                 "budget:128MiB", ...) for logs and reproducibility.
    """

    n_layers: int
    boundaries: tuple[int, ...] = ()
    policy: "str | tuple[str, ...]" = "full"
    source: str = ""

    def __post_init__(self):
        b = tuple(sorted(int(x) for x in self.boundaries))
        if len(set(b)) != len(b):
            raise ValueError(f"duplicate plan boundaries {b}")
        if b and not (0 < b[0] and b[-1] < self.n_layers):
            raise ValueError(
                f"plan boundaries {b} out of range for {self.n_layers} layers")
        object.__setattr__(self, "boundaries", b)
        if not isinstance(self.policy, str):
            pol = tuple(self.policy)
            if len(pol) != self.n_segments:
                raise ValueError(
                    f"per-segment policy count {len(pol)} != "
                    f"{self.n_segments} segments")
            object.__setattr__(self, "policy", pol)

    @property
    def n_segments(self) -> int:
        return len(self.boundaries) + 1

    def segments(self) -> list[tuple[int, int]]:
        bounds = (0, *self.boundaries, self.n_layers)
        return list(zip(bounds[:-1], bounds[1:]))

    def segment_policy(self, j: int) -> str:
        return self.policy if isinstance(self.policy, str) else self.policy[j]

    def segment_sizes(self) -> list[int]:
        return [hi - lo for lo, hi in self.segments()]

    @classmethod
    def uniform(cls, n_layers: int, num_segments: int,
                policy: str = "full") -> "RematPlan":
        """Even split — the legacy knob expressed as a plan."""
        k = max(1, min(int(num_segments), n_layers))
        bounds = sorted({round(i * n_layers / k) for i in range(1, k)}
                        - {0, n_layers})
        return cls(n_layers, tuple(bounds), policy, source="uniform")

    # -- serialization (reproducible runs) ---------------------------------
    def to_json(self) -> str:
        return json.dumps({
            "n_layers": self.n_layers,
            "boundaries": list(self.boundaries),
            "policy": (self.policy if isinstance(self.policy, str)
                       else list(self.policy)),
            "source": self.source,
        })

    @classmethod
    def from_json(cls, text: str) -> "RematPlan":
        d = json.loads(text)
        pol = d.get("policy", "full")
        return cls(int(d["n_layers"]), tuple(d.get("boundaries", ())),
                   pol if isinstance(pol, str) else tuple(pol),
                   d.get("source", ""))

    def save(self, path: str) -> None:
        with open(path, "w") as f:
            f.write(self.to_json())

    @classmethod
    def load(cls, path: str) -> "RematPlan":
        with open(path) as f:
            return cls.from_json(f.read())


# ---------------------------------------------------------------------------
# Shared pieces.
# ---------------------------------------------------------------------------
def _prefix(values: Sequence[float]) -> list[float]:
    out = [0.0]
    for v in values:
        out.append(out[-1] + v)
    return out


def _live_prefix(act_bytes: Sequence[int],
                 resid_bytes: "Sequence[int] | None") -> list[float]:
    """Prefix sums of the per-layer LIVE bytes during a segment's backward:
    the recomputed carry plus the layer's own backward residuals (for
    attention layers, the jnp path's O(S^2) probability matrix or the flash
    path's O(S*D) stats — see ``profile.profile_transformer``)."""
    if resid_bytes is None:
        return _prefix(act_bytes)
    if len(resid_bytes) != len(act_bytes):
        raise ValueError(
            f"resid_bytes length {len(resid_bytes)} != {len(act_bytes)}")
    return _prefix([a + r for a, r in zip(act_bytes, resid_bytes)])


def plan_metrics(act_bytes: Sequence[int], flops: Sequence[float],
                 boundaries: Sequence[int],
                 resid_bytes: "Sequence[int] | None" = None) -> dict:
    """Cost model of a placement: stored/live/peak bytes + recompute FLOPs.

    ``resid_bytes`` (optional, per layer) are backward residuals live
    during the segment's backward but NOT stored at checkpoint boundaries
    — they widen ``max_live_bytes`` only.  ``recompute_flops`` is exact
    for the sequential execution form (``checkpoint_sequential`` leaves
    the last segment un-rematted) and a LOWER bound for the scan form,
    where ``remat_scan`` remats every segment — there the true recompute
    is ~all forward FLOPs regardless of placement, and boundary choice
    trades stored vs live bytes only.
    """
    n = len(act_bytes)
    b = sorted(boundaries)
    pl_ = _live_prefix(act_bytes, resid_bytes)
    fp = _prefix(flops)
    bounds = [0, *b, n]
    stored = sum(act_bytes[x - 1] for x in b)
    max_live = max(pl_[hi] - pl_[lo] for lo, hi in zip(bounds[:-1],
                                                      bounds[1:]))
    return {
        "stored_bytes": int(stored),
        "max_live_bytes": int(max_live),
        "peak_bytes": int(stored + max_live),
        # every segment before the last boundary is re-run in the backward
        "recompute_flops": float(fp[b[-1]]) if b else 0.0,
        "n_segments": len(b) + 1,
    }


def _pareto(states):
    """Prune (stored, max_live, bounds) states: keep the (stored ↑, live ↓)
    frontier."""
    states.sort(key=lambda s: (s[0], s[1]))
    out, best_live = [], float("inf")
    for s in states:
        if s[1] < best_live:
            out.append(s)
            best_live = s[1]
    return out


# ---------------------------------------------------------------------------
# Dual: fixed checkpoint count -> min peak (the original repo DP, kept
# semantically identical; repro.core.checkpoint.optimal_segments delegates
# here).
# ---------------------------------------------------------------------------
def min_peak_boundaries(act_bytes: Sequence[int], num_checkpoints: int,
                        resid_bytes: "Sequence[int] | None" = None
                        ) -> list[int]:
    """Place ``num_checkpoints`` boundaries minimizing stored + max live.

    ``resid_bytes`` widen each layer's live contribution (backward
    residuals recomputed/held inside the segment) without being storable
    at boundaries — segments rich in jnp-attention S^2 residuals get cut
    shorter, flash segments longer.
    """
    n = len(act_bytes)
    k = min(num_checkpoints, n - 1)
    if k <= 0 or n <= 1:
        return []
    sizes = list(act_bytes)
    p = _live_prefix(sizes, resid_bytes)

    def seg_cost(lo, hi):
        return p[hi] - p[lo]

    memo: dict[tuple[int, int], list] = {}

    def solve(j: int, i: int):
        key = (j, i)
        if key in memo:
            return memo[key]
        if j == 0:
            states = [(0, seg_cost(0, i), ())]
        else:
            states = []
            for b in range(j, i):
                for stored, mx, bounds in solve(j - 1, b):
                    states.append((stored + sizes[b - 1],
                                   max(mx, seg_cost(b, i)), bounds + (b,)))
            states = _pareto(states)
        memo[key] = states
        return states

    final = solve(k, n)
    best = min(final, key=lambda s: s[0] + s[1])
    return list(best[2])


# ---------------------------------------------------------------------------
# Primal: byte budget -> min recompute FLOPs.
# ---------------------------------------------------------------------------
def budget_boundaries(act_bytes: Sequence[int], flops: Sequence[float],
                      budget_bytes: float,
                      resid_bytes: "Sequence[int] | None" = None
                      ) -> tuple[list[int], bool]:
    """Minimize recompute FLOPs subject to ``peak_bytes <= budget``.

    Returns ``(boundaries, feasible)``.  When no placement fits the budget,
    the globally peak-minimal placement is returned with ``feasible=False``
    (best effort — the caller decides whether to warn or abort).
    ``resid_bytes`` enter the live-set (peak) term only, as in
    :func:`plan_metrics`.
    """
    n = len(act_bytes)
    sizes = list(act_bytes)
    p = _live_prefix(sizes, resid_bytes)

    def live(lo, hi):
        return p[hi] - p[lo]

    if n <= 1 or live(0, n) <= budget_bytes:
        return [], True  # everything fits without any remat

    # h[L]: Pareto (stored, max_live, bounds) over chains of checkpoints in
    # (0, L] whose LAST checkpoint is exactly at L.
    h: dict[int, list] = {}
    for L in range(1, n):
        states = [(sizes[L - 1], live(0, L), (L,))]
        for prev in range(1, L):
            for stored, mx, bounds in h[prev]:
                states.append((stored + sizes[L - 1],
                               max(mx, live(prev, L)), bounds + (L,)))
        h[L] = _pareto(states)

    # recompute FLOPs = prefix_flops(L): scan L ascending, first feasible
    # last-boundary wins; among its placements take the peak-minimal one.
    for L in range(1, n):
        feasible = [(stored + max(mx, live(L, n)), bounds)
                    for stored, mx, bounds in h[L]
                    if stored + max(mx, live(L, n)) <= budget_bytes]
        if feasible:
            _, bounds = min(feasible)
            return list(bounds), True

    candidates = [(live(0, n), ())]
    for L in range(1, n):
        for stored, mx, bounds in h[L]:
            candidates.append((stored + max(mx, live(L, n)), bounds))
    _, bounds = min(candidates, key=lambda c: c[0])
    return list(bounds), False
