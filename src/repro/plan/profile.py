"""Chain profiling: measured per-layer activation bytes + recompute FLOPs.

The planner (``repro.plan.solver``) needs, for every candidate checkpoint
site, (a) how many bytes the activation at that site occupies and (b) how
expensive the layers before it are to re-run.  This module measures both
WITHOUT allocating anything:

  * activation bytes via ``jax.eval_shape`` walked layer-by-layer
    (``_tree_bytes`` — same accounting as
    ``repro.core.checkpoint.activation_bytes_of``, one fn at a time);
  * FLOPs via XLA's lowered cost analysis per layer (cheap — no compile),
    falling back to an analytic estimate when the backend refuses.

Two concrete chain walkers cover every model stack in the repo:

  * ``profile_resnet``      — the explicit ``cnn.layer_fns`` list (the
    paper's own experiment models; UNet-shaped byte profiles).
  * ``profile_transformer`` — the homogeneous block scan: bytes are the
    scan carry, FLOPs are analytic per block (window-aware, so hybrid
    archs with mixed global/sliding layers profile heterogeneously).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from repro.plan.solver import (RematPlan, budget_boundaries,
                               min_peak_boundaries, plan_metrics)


@dataclasses.dataclass(frozen=True)
class ChainProfile:
    """Per-layer costs of a sequential chain (index i = layer i's output).

    ``resid_bytes`` (optional, same length) are per-layer BACKWARD
    residuals: bytes live while that layer's segment backward runs, beyond
    the checkpointable carry — e.g. the jnp attention path's f32 (S x ctx)
    probability matrix, or the flash custom_vjp path's O(S*D) softmax
    stats.  They widen the planner's live-set term but are never stored at
    checkpoint boundaries.
    """

    act_bytes: tuple[int, ...]
    flops: tuple[float, ...]
    labels: tuple[str, ...] = ()
    resid_bytes: tuple[int, ...] = ()

    def __post_init__(self):
        if len(self.act_bytes) != len(self.flops):
            raise ValueError("act_bytes and flops length mismatch")
        if self.resid_bytes and len(self.resid_bytes) != len(self.act_bytes):
            raise ValueError("resid_bytes and act_bytes length mismatch")

    @property
    def n_layers(self) -> int:
        return len(self.act_bytes)

    @property
    def resid_or_none(self) -> "tuple[int, ...] | None":
        """What the solvers take: None when no residuals were profiled."""
        return self.resid_bytes or None

    def total_bytes(self) -> int:
        return int(sum(self.act_bytes))

    def total_resid_bytes(self) -> int:
        return int(sum(self.resid_bytes))

    def total_flops(self) -> float:
        return float(sum(self.flops))

    def to_json(self) -> str:
        return json.dumps({"act_bytes": list(self.act_bytes),
                           "flops": list(self.flops),
                           "labels": list(self.labels),
                           "resid_bytes": list(self.resid_bytes)})

    @classmethod
    def from_json(cls, text: str) -> "ChainProfile":
        d = json.loads(text)
        return cls(tuple(d["act_bytes"]), tuple(d["flops"]),
                   tuple(d.get("labels", ())),
                   tuple(d.get("resid_bytes", ())))


def _tree_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(tree))


def _layer_flops(fn: Callable, x_sds) -> float:
    """XLA lowered cost analysis; analytic fallback (2 flops/output elem)."""
    try:
        cost = jax.jit(fn).lower(x_sds).cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        f = float(cost.get("flops", 0.0))
        if f > 0:
            return f
    except Exception:  # noqa: BLE001 - backend-dependent API; fall back
        pass
    out = jax.eval_shape(fn, x_sds)
    return float(2 * sum(x.size for x in jax.tree_util.tree_leaves(out)))


# ---------------------------------------------------------------------------
# Chain walkers.
# ---------------------------------------------------------------------------
def profile_sequential(layer_fns: Sequence[Callable], x0,
                       labels: Sequence[str] = ()) -> ChainProfile:
    """Walk an explicit layer-fn chain with eval_shape; never allocates."""
    x = jax.eval_shape(lambda a: a, x0)
    act, flops = [], []
    for fn in layer_fns:
        flops.append(_layer_flops(fn, x))
        x = jax.eval_shape(fn, x)
        act.append(_tree_bytes(x))
    return ChainProfile(tuple(act), tuple(flops),
                        tuple(labels) if labels else ())


def profile_resnet(params, cfg, image_sds) -> ChainProfile:
    """Profile the ResNet layer list ``checkpoint_sequential`` consumes."""
    from repro.models import cnn
    fns = cnn.layer_fns(params, cfg)
    labels = ["stem"] + [f"block{i}" for i in range(len(fns) - 2)] + ["head"]
    return profile_sequential(fns, image_sds, labels)


def flash_training_eligible(cfg, s: int) -> bool:
    """Would the training forward ACTUALLY dispatch to the flash kernel?

    Mirrors the dispatch gates end to end — ``transformer.forward`` (a
    uniform window schedule is required to pass a static window into the
    scan), ``attention.attn_block`` (non-MLA attention, 1-D rope
    positions), and ``kernels.flash.ops`` (Mosaic-legal head_dim and
    sequence length for the compiled backend).  The planner must budget
    what the model will really do: a config that *asks* for flash but
    falls back to the jnp/ref path still pays O(S^2) residuals.
    """
    from repro.kernels.flash import kernel as flash_kernel, ops as flash_ops
    if cfg.mixer not in ("attn", "hybrid") or cfg.mla is not None:
        return False
    if cfg.attn_backend == "jnp":
        return False
    if cfg.global_layers or cfg.mrope_sections is not None:
        return False
    if cfg.attn_backend == "pallas":
        if cfg.head_dim not in flash_ops.SUPPORTED_HEAD_DIMS:
            return False
        if s < flash_kernel.DEFAULT_BQ and s % flash_kernel.DEFAULT_BQ:
            return False
    return True


def attn_resid_bytes(cfg, b: int, s: int, ctx: int,
                     dtype_bytes: int = 2) -> int:
    """Backward-residual bytes of one attention layer, backend-aware.

    Both paths keep q/o per query head and k/v per KV head alive between
    forward and backward.  On top of that the jnp path's autodiff saves
    the f32 (S x ctx) probability matrix per head — the O(S^2) term —
    while the flash custom_vjp saves only the two f32 softmax stat rows
    (m, l) per head and recomputes scores tile-by-tile in the backward
    kernels.  This is the modelling change that stops RematPlans budgeting
    phantom S^2 score tensors once the flash kernel really dispatches
    (:func:`flash_training_eligible` — NOT merely when the config asks
    for a flash backend).
    """
    if cfg.mixer not in ("attn", "hybrid"):
        return 0
    qo_kv = (2 * cfg.n_heads + 2 * cfg.n_kv) * b * s * cfg.head_dim \
        * dtype_bytes
    if not flash_training_eligible(cfg, s):
        return qo_kv + 4 * b * cfg.n_heads * s * ctx       # f32 probs
    return qo_kv + 2 * 4 * b * cfg.n_heads * s             # f32 m, l rows


def flash_bwd_recompute_flops(cfg, b: int, s: int) -> tuple[float, ...]:
    """Per-layer extra FLOPs the flash backward spends recomputing scores.

    Both the dQ and dKV kernels re-run the (S x ctx) QK^T contraction from
    the saved stats instead of loading a stored probability matrix —
    2 x (2 * b * s * ctx * H * D) per layer, the flash memory/FLOP trade.
    Zero when the flash kernel would not actually dispatch
    (:func:`flash_training_eligible`) — e.g. ``attn_backend="jnp"``
    (scores are stored, not recomputed) or non-attention layers.
    """
    from repro.models import transformer
    if not flash_training_eligible(cfg, s):
        return tuple(0.0 for _ in range(cfg.n_layers))
    out = []
    for w in (int(x) for x in transformer.layer_windows(cfg)):
        ctx = s if w == 0 else min(w, s)
        out.append(4.0 * b * s * ctx * cfg.n_heads * cfg.head_dim)
    return tuple(out)


def profile_transformer(cfg, batch_sds, *, dtype_bytes: int = 2
                        ) -> ChainProfile:
    """Profile the block scan: carry bytes + window-aware analytic FLOPs.

    ``batch_sds`` is the train input-spec dict ({tokens: (B, S), ...}).
    The checkpointable site between scanned blocks is the (B, S, D) carry;
    per-block FLOPs are 2 * tokens * block_params (matmuls) plus the
    attention-score term, which varies per layer for windowed/hybrid archs
    (``cfg.window`` + ``cfg.global_layers``) — the source of heterogeneity
    the budget solver exploits.  ``resid_bytes`` carries the backend-aware
    attention backward residuals (:func:`attn_resid_bytes`): O(S^2) on the
    jnp path, O(S*D) on the flash (interpret/pallas) path.
    """
    from repro.models import transformer
    b, s = batch_sds["tokens"].shape
    carry_bytes = b * s * cfg.d_model * dtype_bytes

    params_sds = jax.eval_shape(
        lambda: transformer.init_params(cfg, jax.random.PRNGKey(0)))
    block_elems = sum(x.size for x in
                      jax.tree_util.tree_leaves(params_sds["blocks"]))
    per_block_params = block_elems / cfg.n_layers

    windows = [int(w) for w in transformer.layer_windows(cfg)]
    act, flops, labels, resid = [], [], [], []
    for i, w in enumerate(windows):
        ctx = s if w == 0 else min(w, s)
        attn_flops = 0.0
        if cfg.mixer in ("attn", "hybrid"):
            attn_flops = 4.0 * b * s * ctx * cfg.n_heads * cfg.head_dim
        flops.append(2.0 * b * s * per_block_params + attn_flops)
        act.append(carry_bytes)
        resid.append(attn_resid_bytes(cfg, b, s, ctx, dtype_bytes))
        labels.append(f"block{i}" + ("" if w == 0 else f"@w{w}"))
    return ChainProfile(tuple(act), tuple(flops), tuple(labels),
                        tuple(resid))


# ---------------------------------------------------------------------------
# Profile -> plan.
# ---------------------------------------------------------------------------
def plan_min_peak(profile: ChainProfile, num_checkpoints: int,
                  policy: str = "full") -> RematPlan:
    """Dual solver: best placement of a fixed number of checkpoints."""
    bounds = min_peak_boundaries(profile.act_bytes, num_checkpoints,
                                 resid_bytes=profile.resid_or_none)
    return RematPlan(profile.n_layers, tuple(bounds), policy,
                     source=f"min_peak:k={num_checkpoints}")


def plan_for_budget(profile: ChainProfile, budget_bytes: float,
                    policy: str = "full") -> RematPlan:
    """Primal solver: min recompute FLOPs with peak bytes <= budget.

    An unsatisfiable budget yields the peak-minimal best-effort plan,
    tagged ``:infeasible`` in ``source`` AND warned about — every consumer
    (trainer --remat auto, TrainConfig.mem_budget_mb, hillclimb budget<N>)
    funnels through here, so the violated constraint is never silent.
    """
    import warnings

    bounds, feasible = budget_boundaries(profile.act_bytes, profile.flops,
                                         budget_bytes,
                                         resid_bytes=profile.resid_or_none)
    tag = f"budget:{int(budget_bytes)}" + ("" if feasible else ":infeasible")
    if not feasible:
        peak = plan_metrics(profile.act_bytes, profile.flops, bounds,
                            resid_bytes=profile.resid_or_none)["peak_bytes"]
        warnings.warn(
            f"remat budget {budget_bytes/2**20:.1f} MiB is infeasible for "
            f"this chain; best-effort plan peaks at {peak/2**20:.1f} MiB "
            f"(min achievable)", stacklevel=2)
    return RematPlan(profile.n_layers, tuple(bounds), policy, source=tag)


def plan_report(profile: ChainProfile, plan: RematPlan) -> dict:
    """Human/JSON-facing summary of a plan against its profile."""
    m = plan_metrics(profile.act_bytes, profile.flops, plan.boundaries,
                     resid_bytes=profile.resid_or_none)
    return {
        "source": plan.source,
        "n_layers": plan.n_layers,
        "boundaries": list(plan.boundaries),
        "segment_sizes": plan.segment_sizes(),
        **m,
        "recompute_frac": (m["recompute_flops"] / profile.total_flops()
                           if profile.total_flops() else 0.0),
        "no_remat_bytes": profile.total_bytes(),
        "resid_bytes_total": profile.total_resid_bytes(),
    }
