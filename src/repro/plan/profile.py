"""Chain profiling: measured per-layer activation bytes + recompute FLOPs.

The planner (``repro.plan.solver``) needs, for every candidate checkpoint
site, (a) how many bytes the activation at that site occupies and (b) how
expensive the layers before it are to re-run.  This module measures both
WITHOUT allocating anything:

  * activation bytes via ``jax.eval_shape`` walked layer-by-layer
    (``_tree_bytes`` — same accounting as
    ``repro.core.checkpoint.activation_bytes_of``, one fn at a time);
  * FLOPs via XLA's lowered cost analysis per layer (cheap — no compile),
    falling back to an analytic estimate when the backend refuses.

Two concrete chain walkers cover every model stack in the repo:

  * ``profile_resnet``      — the explicit ``cnn.layer_fns`` list (the
    paper's own experiment models; UNet-shaped byte profiles).
  * ``profile_transformer`` — the homogeneous block scan: bytes are the
    scan carry, FLOPs are analytic per block (window-aware, so hybrid
    archs with mixed global/sliding layers profile heterogeneously).
"""
from __future__ import annotations

import dataclasses
import json
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from repro.plan.solver import (RematPlan, budget_boundaries,
                               min_peak_boundaries, plan_metrics)


@dataclasses.dataclass(frozen=True)
class ChainProfile:
    """Per-layer costs of a sequential chain (index i = layer i's output).

    ``resid_bytes`` (optional, same length) are per-layer BACKWARD
    residuals: bytes live while that layer's segment backward runs, beyond
    the checkpointable carry — e.g. the jnp attention path's f32 (S x ctx)
    probability matrix, or the flash custom_vjp path's O(S*D) softmax
    stats.  They widen the planner's live-set term but are never stored at
    checkpoint boundaries.
    """

    act_bytes: tuple[int, ...]
    flops: tuple[float, ...]
    labels: tuple[str, ...] = ()
    resid_bytes: tuple[int, ...] = ()

    def __post_init__(self):
        if len(self.act_bytes) != len(self.flops):
            raise ValueError("act_bytes and flops length mismatch")
        if self.resid_bytes and len(self.resid_bytes) != len(self.act_bytes):
            raise ValueError("resid_bytes and act_bytes length mismatch")

    @property
    def n_layers(self) -> int:
        return len(self.act_bytes)

    @property
    def resid_or_none(self) -> "tuple[int, ...] | None":
        """What the solvers take: None when no residuals were profiled."""
        return self.resid_bytes or None

    def total_bytes(self) -> int:
        return int(sum(self.act_bytes))

    def total_resid_bytes(self) -> int:
        return int(sum(self.resid_bytes))

    def total_flops(self) -> float:
        return float(sum(self.flops))

    def to_json(self) -> str:
        return json.dumps({"act_bytes": list(self.act_bytes),
                           "flops": list(self.flops),
                           "labels": list(self.labels),
                           "resid_bytes": list(self.resid_bytes)})

    @classmethod
    def from_json(cls, text: str) -> "ChainProfile":
        d = json.loads(text)
        return cls(tuple(d["act_bytes"]), tuple(d["flops"]),
                   tuple(d.get("labels", ())),
                   tuple(d.get("resid_bytes", ())))


def _tree_bytes(tree) -> int:
    return sum(x.size * x.dtype.itemsize
               for x in jax.tree_util.tree_leaves(tree))


def _layer_flops(fn: Callable, x_sds) -> float:
    """XLA lowered cost analysis; analytic fallback (2 flops/output elem)."""
    try:
        cost = jax.jit(fn).lower(x_sds).cost_analysis()
        if isinstance(cost, (list, tuple)):
            cost = cost[0]
        f = float(cost.get("flops", 0.0))
        if f > 0:
            return f
    except Exception:  # noqa: BLE001 - backend-dependent API; fall back
        pass
    out = jax.eval_shape(fn, x_sds)
    return float(2 * sum(x.size for x in jax.tree_util.tree_leaves(out)))


# ---------------------------------------------------------------------------
# Chain walkers.
# ---------------------------------------------------------------------------
def profile_sequential(layer_fns: Sequence[Callable], x0,
                       labels: Sequence[str] = ()) -> ChainProfile:
    """Walk an explicit layer-fn chain with eval_shape; never allocates."""
    x = jax.eval_shape(lambda a: a, x0)
    act, flops = [], []
    for fn in layer_fns:
        flops.append(_layer_flops(fn, x))
        x = jax.eval_shape(fn, x)
        act.append(_tree_bytes(x))
    return ChainProfile(tuple(act), tuple(flops),
                        tuple(labels) if labels else ())


def profile_resnet(params, cfg, image_sds) -> ChainProfile:
    """Profile the ResNet layer list ``checkpoint_sequential`` consumes."""
    from repro.models import cnn
    fns = cnn.layer_fns(params, cfg)
    labels = ["stem"] + [f"block{i}" for i in range(len(fns) - 2)] + ["head"]
    return profile_sequential(fns, image_sds, labels)


def flash_training_eligible(cfg, s: int) -> bool:
    """Would the training forward ACTUALLY dispatch to the flash kernel?

    Mirrors the dispatch gates end to end — ``transformer.forward`` (a
    uniform window schedule is required to pass a static window into the
    scan), ``attention.attn_block`` (non-MLA attention, 1-D rope
    positions), and ``kernels.flash.ops`` (Mosaic-legal head_dim and
    sequence length for the compiled backend).  The planner must budget
    what the model will really do: a config that *asks* for flash but
    falls back to the jnp/ref path still pays O(S^2) residuals.
    """
    from repro.kernels.flash import kernel as flash_kernel, ops as flash_ops
    if cfg.mixer not in ("attn", "hybrid") or cfg.mla is not None:
        return False
    if cfg.attn_backend == "jnp":
        return False
    if cfg.global_layers or cfg.mrope_sections is not None:
        return False
    if cfg.attn_backend == "pallas":
        if cfg.head_dim not in flash_ops.SUPPORTED_HEAD_DIMS:
            return False
        if s < flash_kernel.DEFAULT_BQ and s % flash_kernel.DEFAULT_BQ:
            return False
    return True


def attn_resid_bytes(cfg, b: int, s: int, ctx: int,
                     dtype_bytes: int = 2,
                     flash_resid_bytes: "int | None" = None,
                     model_shards: int = 1) -> int:
    """Backward-residual bytes of one attention layer, backend-aware.

    Both paths keep q/o per query head and k/v per KV head alive between
    forward and backward.  On top of that the jnp path's autodiff saves
    the f32 (S x ctx) probability matrix per head — the O(S^2) term —
    while the flash custom_vjp saves only the two f32 softmax stat rows
    (m, l) per head and recomputes scores tile-by-tile in the backward
    kernels.  This is the modelling change that stops RematPlans budgeting
    phantom S^2 score tensors once the flash kernel really dispatches
    (:func:`flash_training_eligible` — NOT merely when the config asks
    for a flash backend).

    ``flash_resid_bytes`` is the per-element width of the SAVED flash
    (q, k, v, o) tuple when a ``Policy.flash_resid_dtype`` residual policy
    is active (e.g. 2 for bf16-stored residuals under f32 compute);
    default: residuals follow the compute dtype.  The (m, l) stats are
    budgeted at f32 regardless — exactly the kernel contract.

    ``model_shards`` divides the per-HEAD terms (q/o, k/v, probs, stats)
    when heads shard over the mesh's model axis (both head counts must
    divide — the same gate ``sharding.flash_shard_specs`` applies to the
    kernel dispatch, so the planner budgets exactly what each chip holds);
    an indivisible head count leaves residuals whole, matching the
    replicated fallback.
    """
    if cfg.mixer not in ("attn", "hybrid"):
        return 0
    ms = model_shards if (model_shards > 1
                          and cfg.n_heads % model_shards == 0
                          and cfg.n_kv % model_shards == 0) else 1
    if not flash_training_eligible(cfg, s):
        qo_kv = (2 * cfg.n_heads + 2 * cfg.n_kv) * b * s * cfg.head_dim \
            * dtype_bytes
        return (qo_kv + 4 * b * cfg.n_heads * s * ctx) // ms   # f32 probs
    rb = dtype_bytes if flash_resid_bytes is None else flash_resid_bytes
    qo_kv = (2 * cfg.n_heads + 2 * cfg.n_kv) * b * s * cfg.head_dim * rb
    return (qo_kv + 2 * 4 * b * cfg.n_heads * s) // ms     # f32 m, l rows


def _flash_tile_counts(cfg, s: int) -> "list[dict]":
    """Per-layer visited/dense tile-step counts of the sparse flash grids.

    Computed on the PADDED grid the kernels actually run (ops.py rounds S
    up to the 128-lane block and masks the tail via ``kv_len``), from the
    same :func:`repro.kernels.flash.kernel.tile_step_counts` bounds the
    kernels build their wedge grids from — planner budgets and measured
    ``debug_counts`` counters agree tile-for-tile by construction.
    """
    from repro.kernels.flash import kernel as flash_kernel, ops as flash_ops
    from repro.models import transformer
    s_pad = flash_ops.padded_seq_len(s)
    return [flash_kernel.tile_step_counts(s_pad, causal=True, window=w,
                                          kv_len=s)
            for w in (int(x) for x in transformer.layer_windows(cfg))]


def flash_bwd_recompute_flops(cfg, b: int, s: int) -> tuple[float, ...]:
    """Per-layer extra FLOPs the flash backward spends recomputing scores.

    Both the dQ and dKV kernels re-run the QK^T contraction from the
    saved stats instead of loading a stored probability matrix — but only
    on the tiles their sparse grids actually visit: ``2 * BQ * BK * D``
    FLOPs per visited tile-step per (batch x head), summed over the dQ
    and dKV grids (causal visits ~1/2 of the dense rectangle, window
    ~W/S).  Zero when the flash kernel would not actually dispatch
    (:func:`flash_training_eligible`) — e.g. ``attn_backend="jnp"``
    (scores are stored, not recomputed) or non-attention layers.
    """
    if not flash_training_eligible(cfg, s):
        return tuple(0.0 for _ in range(cfg.n_layers))
    bh = b * cfg.n_heads * cfg.head_dim
    return tuple(2.0 * bh * c["bq"] * c["bk"] * (c["dq"] + c["dkv"])
                 for c in _flash_tile_counts(cfg, s))


def flash_attn_flop_report(cfg, b: int, s: int) -> dict:
    """Dense-vs-visited attention FLOPs across the three sparse grids.

    Counts every matmul each grid runs per visited tile-step — forward
    (QK^T, PV: 4·BQ·BK·D flops), dQ (score recompute, dP, dS·K: 6), dKV
    (score recompute, P^T·dO, dP, dS^T·Q: 8) — against the same matmuls
    on the dense nQ x nK rectangle a mask-blind grid executes.  This is
    what dryrun train cells, the trainer banner and BENCH_flash.json
    report as the sparse-grid FLOP claw-back.
    """
    if not flash_training_eligible(cfg, s):
        return {"eligible": False, "dense_flops": 0.0, "visited_flops": 0.0,
                "skip_frac": 0.0, "visited_tile_steps": 0,
                "dense_tile_steps": 0}
    bh = b * cfg.n_heads * cfg.head_dim
    dense = visited = 0.0
    vis_steps = dense_steps = 0
    for c in _flash_tile_counts(cfg, s):
        tile = bh * c["bq"] * c["bk"]
        visited += tile * (4.0 * c["fwd"] + 6.0 * c["dq"] + 8.0 * c["dkv"])
        dense += tile * (4.0 + 6.0 + 8.0) * c["dense"]
        vis_steps += c["fwd"] + c["dq"] + c["dkv"]
        dense_steps += 3 * c["dense"]
    return {"eligible": True, "dense_flops": dense, "visited_flops": visited,
            "skip_frac": 1.0 - (vis_steps / dense_steps if dense_steps
                                else 0.0),
            "visited_tile_steps": vis_steps, "dense_tile_steps": dense_steps}


def decode_tile_report(cfg, b: int, s: int, *, lengths=None, splits: int = 1,
                       block_s: int | None = None) -> dict:
    """Visited-vs-dense tile accounting for split-K int8 KV decode.

    The serve-side mirror of :func:`flash_attn_flop_report`: per layer,
    how many KV tile-steps the length-aware split-K decode kernel
    (``kernels/kvq``) actually executes versus the dense per-(batch,
    kv-head) sweep a length- and window-blind kernel over the full
    S-slot single-tier cache would pay, with the FLOPs and int8 cache
    bytes those tiles carry.  Visited counts come from the SAME
    ``tiling.decode_tile_step_counts`` bounds the kernel builds its grid
    and early-outs from, so the report and the measured ``debug_counts``
    counters agree tile-for-tile by construction.

    Two-tier geometry is honored: windowed layers (``cfg.window`` > 0,
    not in ``cfg.global_layers``) serve from a rolling W-slot buffer, so
    their per-layer cache length — and with it the split-K axis — shrinks
    statically to ~W/BS tiles (``min(window, s)``), and per-batch
    ``lengths`` clamp to it.  ``lengths=None`` budgets a full cache
    (steady-state worst case); pass the ragged batch for serving-time
    accounting.
    """
    from repro.kernels import tiling
    from repro.models import transformer
    zeros = {"eligible": False, "visited_tile_steps": 0,
             "dense_tile_steps": 0, "visited_flops": 0.0, "dense_flops": 0.0,
             "visited_kv_bytes": 0, "dense_kv_bytes": 0, "skip_frac": 0.0,
             "per_layer": []}
    if cfg.mixer not in ("attn", "hybrid") or cfg.mla is not None:
        return zeros                 # MLA/SSM caches aren't the kvq layout
    if lengths is not None and len(lengths) != b:
        raise ValueError(f"decode_tile_report: {len(lengths)} lengths for "
                         f"batch {b} — the visited/dense ratio would mix "
                         f"batch sizes")
    lens = [s] * b if lengths is None else [int(x) for x in lengths]
    hkv, g, d = cfg.n_kv, cfg.n_heads // cfg.n_kv, cfg.head_dim
    bs_kw = {} if block_s is None else {"block_s": block_s}
    # dense baseline: the old sequential sweep over a full S-slot
    # single-tier cache, every tile visited (no lengths, no two-tier)
    c_full = tiling.decode_tile_step_counts(s, None, **bs_kw)
    per_layer = []
    visited = dense = vis_fl = den_fl = vis_by = den_by = 0
    for w in (int(x) for x in transformer.layer_windows(cfg)):
        s_l = s if w <= 0 else min(w, s)
        c = tiling.decode_tile_step_counts(
            s_l, [min(ln, s_l) for ln in lens], splits=splits, **bs_kw)
        vis, den = c["visited"], b * c_full["ns"]
        # per (batch, kv-head) tile-step: QK^T (G,D)x(D,BS) + PV
        # (G,BS)x(BS,D) = 4*G*D*BS flops; int8 K+V tiles + f32 scales
        tile_fl = lambda bs_: 4.0 * g * d * bs_ * hkv
        tile_by = lambda bs_: hkv * (2 * bs_ * d + 2 * bs_ * 4)
        per_layer.append({"window": w, "cache_len": s_l, "bs": c["bs"],
                          "splits": c["splits"], "visited": vis,
                          "dense": den})
        visited += vis
        dense += den
        vis_fl += vis * tile_fl(c["bs"])
        den_fl += den * tile_fl(c_full["bs"])
        vis_by += vis * tile_by(c["bs"])
        den_by += den * tile_by(c_full["bs"])
    return {"eligible": True, "visited_tile_steps": visited,
            "dense_tile_steps": dense, "visited_flops": vis_fl,
            "dense_flops": den_fl, "visited_kv_bytes": vis_by,
            "dense_kv_bytes": den_by,
            "skip_frac": 1.0 - (visited / dense if dense else 0.0),
            "per_layer": per_layer}


def kv_cache_report(cfg, b: int, s: int) -> dict:
    """int8-vs-f32 KV-cache bytes at serve time, two-tier aware.

    int8 counts the deployed encoding (1 B/elem K+V plus the two f32
    per-token scale rows); f32 is the un-encoded strawman.  Windowed
    layers are sized at their rolling ``min(window, s)`` buffer — the
    same geometry :func:`decode_tile_report` budgets tiles on.
    """
    from repro.models import transformer
    if cfg.mixer not in ("attn", "hybrid") or cfg.mla is not None:
        return {"eligible": False, "int8_bytes": 0, "f32_bytes": 0,
                "ratio": 0.0}
    hkv, d = cfg.n_kv, cfg.head_dim
    int8 = f32 = 0
    for w in (int(x) for x in transformer.layer_windows(cfg)):
        s_l = s if w <= 0 else min(w, s)
        tokens = b * hkv * s_l
        int8 += 2 * tokens * d + 2 * tokens * 4
        f32 += 2 * tokens * d * 4
    return {"eligible": True, "int8_bytes": int8, "f32_bytes": f32,
            "ratio": f32 / int8 if int8 else 0.0}


def serve_capacity_report(cfg, s_max: int, budget_bytes: int, *,
                          quantized: bool = True,
                          params_bytes: int = 0, mesh=None) -> dict:
    """Max resident request slots a serve-memory budget admits.

    The serving mirror of the training budget solver: the slot pool
    (``repro.serve``) preallocates its decode cache at ``(max_slots,
    s_max)``, so capacity is ``(budget - params) // bytes_per_slot``.
    ``bytes_per_slot`` is EXACT — eval_shape over ``init_cache`` at batch
    1, counting every leaf the pool actually allocates (int8 K/V + f32
    scale rows, or the bf16 leaves when not quantized, plus SSM/conv
    state on hybrid archs).  ``kv_int8_bytes_per_slot`` cross-references
    :func:`kv_cache_report`'s two-tier accounting for the attention share.

    With ``mesh``, ``budget_bytes`` means bytes PER CHIP (the same
    contract the training planner applies): each K/V leaf divides by the
    shard factor ``sharding.serve_kv_shard`` actually applies on that
    mesh, giving ``bytes_per_slot_per_device``, and ``max_slots`` becomes
    what one chip's budget admits — slots are replicated across the mesh
    (every device holds its slice of EVERY slot), so one chip bounds
    residency.  ``bytes_per_slot_per_device x model_shards >=
    bytes_per_slot`` never rounds capacity up.
    """
    from repro.models import transformer
    cache_sds = jax.eval_shape(
        lambda: transformer.init_cache(cfg, 1, s_max, quantized=quantized))
    bytes_per_slot = sum(x.size * x.dtype.itemsize
                         for k, x in cache_sds.items() if k != "pos")
    shard = 1
    kv_mode = "none"
    devices = 1
    if mesh is not None:
        from repro.distributed import sharding as shd
        devices = mesh.size
        kv_mode = shd.serve_kv_shard(mesh, cfg.n_kv, s_max)
        if kv_mode != "none":
            shard = mesh.shape["model"]
    per_dev = sum(
        (x.size * x.dtype.itemsize)
        // (shard if k in ("k", "v", "k_scale", "v_scale") else 1)
        for k, x in cache_sds.items() if k != "pos")
    kv_rep = kv_cache_report(cfg, 1, s_max)
    usable = max(0, int(budget_bytes) - int(params_bytes))
    return {
        "eligible": bytes_per_slot > 0,
        "bytes_per_slot": int(bytes_per_slot),
        "bytes_per_slot_per_device": int(per_dev),
        "kv_int8_bytes_per_slot": int(kv_rep["int8_bytes"]),
        "budget_bytes": int(budget_bytes),
        "params_bytes": int(params_bytes),
        "max_slots": (usable // per_dev) if per_dev else 0,
        "devices": int(devices),
        "model_shards": int(shard),
        "kv_shard": kv_mode,
        "s_max": int(s_max),
        "quantized": bool(quantized),
    }


def profile_transformer(cfg, batch_sds, *, dtype_bytes: int = 2,
                        flash_resid_bytes: "int | None" = None,
                        model_shards: int = 1) -> ChainProfile:
    """Profile the block scan: carry bytes + window-aware analytic FLOPs.

    ``batch_sds`` is the train input-spec dict ({tokens: (B, S), ...}).
    The checkpointable site between scanned blocks is the (B, S, D) carry;
    per-block FLOPs are 2 * tokens * block_params (matmuls) plus the
    attention-score term, which varies per layer for windowed/hybrid archs
    (``cfg.window`` + ``cfg.global_layers``) — the source of heterogeneity
    the budget solver exploits.  ``resid_bytes`` carries the backend-aware
    attention backward residuals (:func:`attn_resid_bytes`): O(S^2) on the
    jnp path, O(S*D) on the flash (interpret/pallas) path;
    ``flash_resid_bytes`` forwards a residual-policy dtype width
    (``Policy.flash_resid_dtype``).

    Attention-score FLOPs are dispatch-honest: the jnp paths execute the
    dense (masked) score matmul, but the flash kernels run SPARSE grids
    that skip whole-masked KV tiles — so flash-eligible layers are
    budgeted at the visited-tile count (causal ~1/2 of dense, window
    ~W/S), exactly what the remat DP pays to recompute that layer.

    ``model_shards`` (the mesh's TP width) makes the profile PER-DEVICE:
    ``batch_sds`` is already the per-device microbatch (DP divides batch
    upstream, ``train_step.microbatch_specs``), the (B, S, D) carry is
    replicated over the model axis so it stays whole, and the attention
    residuals divide by the head shards each chip actually holds
    (:func:`attn_resid_bytes`) — together ``--mem-budget-mb`` means bytes
    per CHIP, on every mesh.
    """
    from repro.models import transformer
    b, s = batch_sds["tokens"].shape
    carry_bytes = b * s * cfg.d_model * dtype_bytes

    params_sds = jax.eval_shape(
        lambda: transformer.init_params(cfg, jax.random.PRNGKey(0)))
    block_elems = sum(x.size for x in
                      jax.tree_util.tree_leaves(params_sds["blocks"]))
    per_block_params = block_elems / cfg.n_layers

    windows = [int(w) for w in transformer.layer_windows(cfg)]
    flash = flash_training_eligible(cfg, s)
    tile_counts = _flash_tile_counts(cfg, s) if flash else None
    act, flops, labels, resid = [], [], [], []
    for i, w in enumerate(windows):
        ctx = s if w == 0 else min(w, s)
        attn_flops = 0.0
        if cfg.mixer in ("attn", "hybrid"):
            if flash:
                c = tile_counts[i]
                attn_flops = 4.0 * b * cfg.n_heads * cfg.head_dim \
                    * c["bq"] * c["bk"] * c["fwd"]
            else:
                attn_flops = 4.0 * b * s * ctx * cfg.n_heads * cfg.head_dim
        flops.append(2.0 * b * s * per_block_params + attn_flops)
        act.append(carry_bytes)
        resid.append(attn_resid_bytes(cfg, b, s, ctx, dtype_bytes,
                                      flash_resid_bytes=flash_resid_bytes,
                                      model_shards=model_shards))
        labels.append(f"block{i}" + ("" if w == 0 else f"@w{w}"))
    return ChainProfile(tuple(act), tuple(flops), tuple(labels),
                        tuple(resid))


# ---------------------------------------------------------------------------
# Profile -> plan.
# ---------------------------------------------------------------------------
def plan_min_peak(profile: ChainProfile, num_checkpoints: int,
                  policy: str = "full") -> RematPlan:
    """Dual solver: best placement of a fixed number of checkpoints."""
    bounds = min_peak_boundaries(profile.act_bytes, num_checkpoints,
                                 resid_bytes=profile.resid_or_none)
    return RematPlan(profile.n_layers, tuple(bounds), policy,
                     source=f"min_peak:k={num_checkpoints}")


def plan_for_budget(profile: ChainProfile, budget_bytes: float,
                    policy: str = "full") -> RematPlan:
    """Primal solver: min recompute FLOPs with peak bytes <= budget.

    An unsatisfiable budget yields the peak-minimal best-effort plan,
    tagged ``:infeasible`` in ``source`` AND warned about — every consumer
    (trainer --remat auto, TrainConfig.mem_budget_mb, hillclimb budget<N>)
    funnels through here, so the violated constraint is never silent.
    """
    import warnings

    bounds, feasible = budget_boundaries(profile.act_bytes, profile.flops,
                                         budget_bytes,
                                         resid_bytes=profile.resid_or_none)
    tag = f"budget:{int(budget_bytes)}" + ("" if feasible else ":infeasible")
    if not feasible:
        peak = plan_metrics(profile.act_bytes, profile.flops, bounds,
                            resid_bytes=profile.resid_or_none)["peak_bytes"]
        warnings.warn(
            f"remat budget {budget_bytes/2**20:.1f} MiB is infeasible for "
            f"this chain; best-effort plan peaks at {peak/2**20:.1f} MiB "
            f"(min achievable)", stacklevel=2)
    return RematPlan(profile.n_layers, tuple(bounds), policy, source=tag)


def plan_report(profile: ChainProfile, plan: RematPlan) -> dict:
    """Human/JSON-facing summary of a plan against its profile."""
    m = plan_metrics(profile.act_bytes, profile.flops, plan.boundaries,
                     resid_bytes=profile.resid_or_none)
    return {
        "source": plan.source,
        "n_layers": plan.n_layers,
        "boundaries": list(plan.boundaries),
        "segment_sizes": plan.segment_sizes(),
        **m,
        "recompute_frac": (m["recompute_flops"] / profile.total_flops()
                           if profile.total_flops() else 0.0),
        "no_remat_bytes": profile.total_bytes(),
        "resid_bytes_total": profile.total_resid_bytes(),
    }
