"""Unified memory planner: profile-driven remat plans (paper Fig. 11 as a
subsystem).  ``profile_*`` measures a model's layer chain, ``plan_*`` solves
for checkpoint placement, and the resulting :class:`RematPlan` is executed
by ``repro.core.checkpoint.CheckpointConfig(plan=...)`` — the single remat
entry point for every model stack."""
from repro.plan.profile import (ChainProfile, attn_resid_bytes,
                                decode_tile_report, flash_attn_flop_report,
                                flash_bwd_recompute_flops,
                                flash_training_eligible, kv_cache_report,
                                plan_for_budget, plan_min_peak, plan_report,
                                profile_resnet, profile_sequential,
                                profile_transformer, serve_capacity_report)
from repro.plan.solver import (RematPlan, budget_boundaries,
                               min_peak_boundaries, plan_metrics)

__all__ = [
    "ChainProfile", "RematPlan",
    "profile_sequential", "profile_resnet", "profile_transformer",
    "attn_resid_bytes", "flash_attn_flop_report",
    "flash_bwd_recompute_flops", "flash_training_eligible",
    "decode_tile_report", "kv_cache_report", "serve_capacity_report",
    "plan_min_peak", "plan_for_budget", "plan_report",
    "min_peak_boundaries", "budget_boundaries", "plan_metrics",
]
