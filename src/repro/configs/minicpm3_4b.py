"""MiniCPM3-4B [hf:openbmb]: MLA (multi-head latent attention) decoder."""
from repro.models.config import MLAConfig, ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        arch_id="minicpm3-4b", family="dense",
        n_layers=62, d_model=2560, n_heads=40, n_kv=40, d_ff=6400,
        vocab=73448, head_dim=96,
        mla=MLAConfig(q_lora_rank=768, kv_lora_rank=256, qk_nope_dim=64,
                      qk_rope_dim=32, v_head_dim=64),
    )
