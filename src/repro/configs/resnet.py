"""The paper's OWN experiment models (Figs. 8-10): ResNet-18/50 on CIFAR.

These use the CNN family (``repro.models.cnn``), not the LM transformer —
exposed here so the paper-reproduction examples and benchmarks resolve
configs through one registry.
"""
from repro.models import cnn


def resnet18(**kw) -> cnn.ResNetConfig:
    return cnn.resnet18(**kw)


def resnet50(**kw) -> cnn.ResNetConfig:
    return cnn.resnet50(**kw)
