"""StableLM-2-12B [hf:stabilityai]: dense GQA decoder."""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        arch_id="stablelm-12b", family="dense",
        n_layers=40, d_model=5120, n_heads=32, n_kv=8, d_ff=13824,
        vocab=100352,
    )
