"""Architecture registry: ``get_config(arch_id)``, reduced ``smoke_config``,
and ``input_specs`` (ShapeDtypeStruct stand-ins for the dry-run)."""
from __future__ import annotations

import dataclasses
import importlib
from typing import Optional

import jax
import jax.numpy as jnp

from repro.models.config import (EncoderConfig, MLAConfig, ModelConfig,
                                 MoEConfig, SSMConfig)

ARCHS = [
    "deepseek_moe_16b", "granite_moe_3b_a800m", "stablelm_12b",
    "minicpm3_4b", "glm4_9b", "llama3_8b", "whisper_base", "hymba_1_5b",
    "qwen2_vl_2b", "mamba2_130m",
]

# canonical ids use dashes (CLI); module names use underscores
def _mod(arch_id: str) -> str:
    return arch_id.replace("-", "_").replace(".", "_")


def list_archs() -> list[str]:
    return [a.replace("_", "-") for a in ARCHS]


def get_config(arch_id: str) -> ModelConfig:
    mod = importlib.import_module(f"repro.configs.{_mod(arch_id)}")
    return mod.get_config()


# ---------------------------------------------------------------------------
# Reduced smoke variants: same family/code paths, laptop-sized.
# ---------------------------------------------------------------------------
def smoke_config(arch_id: str) -> ModelConfig:
    cfg = get_config(arch_id)
    kw: dict = dict(
        n_layers=2, d_model=64, n_heads=4, n_kv=min(cfg.n_kv, 2) or 0,
        d_ff=128 if cfg.d_ff else 0, vocab=256, head_dim=16,
        global_layers=(0,) if cfg.global_layers else (),
        window=16 if cfg.window else 0,
    )
    if cfg.moe is not None:
        kw["moe"] = dataclasses.replace(
            cfg.moe, num_experts=8, top_k=2, d_expert=32,
            d_shared=64 if cfg.moe.num_shared else 0)
        kw["d_ff"] = 0
    if cfg.mla is not None:
        kw["mla"] = MLAConfig(q_lora_rank=32, kv_lora_rank=16, qk_nope_dim=8,
                              qk_rope_dim=8, v_head_dim=8)
        kw["head_dim"] = 16
    if cfg.ssm is not None:
        kw["ssm"] = SSMConfig(d_state=16, d_inner=64, head_p=16, chunk=32)
    if cfg.encoder is not None:
        kw["encoder"] = EncoderConfig(n_layers=2, n_frames=32)
    if cfg.mrope_sections is not None:
        kw["mrope_sections"] = (4, 2, 2)
    return dataclasses.replace(cfg, **kw)


# ---------------------------------------------------------------------------
# Assigned input shapes.
# ---------------------------------------------------------------------------
SHAPES = {
    "train_4k": dict(kind="train", seq=4096, batch=256),
    "prefill_32k": dict(kind="prefill", seq=32768, batch=32),
    "decode_32k": dict(kind="decode", seq=32768, batch=128),
    "long_500k": dict(kind="decode", seq=524288, batch=1),
}


def applicable_shapes(cfg: ModelConfig) -> list[str]:
    """Per instructions: long_500k only for sub-quadratic archs."""
    shapes = ["train_4k", "prefill_32k", "decode_32k"]
    if cfg.subquadratic:
        shapes.append("long_500k")
    return shapes


def input_specs(cfg: ModelConfig, shape_name: str, *, reduced: bool = False
                ) -> dict:
    """ShapeDtypeStruct stand-ins for every model input of this cell.

    ``kind='train'``  -> train_step inputs {tokens, labels[, frames/patches]}
    ``kind='prefill'``-> forward(+build_cache) inputs
    ``kind='decode'`` -> serve_step inputs {tokens_t, cache[, enc_out]}
    """
    sh = dict(SHAPES[shape_name])
    if reduced:
        sh.update(seq=min(sh["seq"], 64), batch=min(sh["batch"], 4))
    b, s = sh["batch"], sh["seq"]
    f32, i32 = jnp.float32, jnp.int32
    sds = jax.ShapeDtypeStruct

    def _extras(specs: dict, seq: int) -> dict:
        if cfg.encoder is not None:
            specs["frames"] = sds((b, cfg.encoder.n_frames, cfg.d_model), f32)
        if cfg.family == "vlm":
            sp = min(1024, seq // 4)
            specs["patches"] = sds((b, sp, cfg.d_model), f32)
            specs["positions"] = sds((3, b, seq), i32)
        return specs

    if sh["kind"] in ("train", "prefill"):
        specs = {"tokens": sds((b, s), i32)}
        if sh["kind"] == "train":
            specs["labels"] = sds((b, s), i32)
        return _extras(specs, s)

    # decode: one new token against a cache of length seq
    from repro.models import transformer
    cache = jax.eval_shape(
        lambda: transformer.init_cache(cfg, b, s, quantized=True))
    specs = {"tokens_t": sds((b,), i32), "cache": cache}
    if cfg.encoder is not None:
        specs["enc_out"] = sds((b, cfg.encoder.n_frames, cfg.d_model), f32)
    if cfg.family == "vlm":
        pass  # decode steps are pure-text continuation (positions tracked 1D)
    return specs
