"""IBM Granite-3.0 MoE (3b-a800m class) [hf:ibm-granite]: 40 experts top-8,
per-expert FFN 512."""
from repro.models.config import ModelConfig, MoEConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        arch_id="granite-moe-3b-a800m", family="moe",
        n_layers=32, d_model=1536, n_heads=24, n_kv=8, d_ff=0,
        vocab=49155,
        moe=MoEConfig(num_experts=40, top_k=8, d_expert=512),
    )
