"""Qwen2-VL-2B [arXiv:2409.12191]: M-RoPE decoder; vision tower is a STUB —
input_specs provide precomputed patch embeddings."""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        arch_id="qwen2-vl-2b", family="vlm",
        n_layers=28, d_model=1536, n_heads=12, n_kv=2, d_ff=8960,
        vocab=151936, head_dim=128, rope_theta=1000000.0,
        mrope_sections=(16, 24, 24), tie_embeddings=True,
    )
