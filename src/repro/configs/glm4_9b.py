"""GLM-4-9B [hf:THUDM]: GQA kv=2, half-dim rotary."""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        arch_id="glm4-9b", family="dense",
        n_layers=40, d_model=4096, n_heads=32, n_kv=2, d_ff=13696,
        vocab=151552, rope_fraction=0.5,
    )
