"""Hymba-1.5B [arXiv:2411.13676]: hybrid parallel attention+mamba heads,
sliding-window attention except 3 global layers (meta-tokens omitted —
DESIGN.md §5).  Sub-quadratic -> eligible for long_500k."""
from repro.models.config import ModelConfig, SSMConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        arch_id="hymba-1.5b", family="hybrid", mixer="hybrid",
        n_layers=32, d_model=1600, n_heads=25, n_kv=5, d_ff=5504,
        vocab=32001, head_dim=64, window=1024, global_layers=(0, 15, 31),
        ssm=SSMConfig(d_state=16, d_inner=1600, head_p=64),
        subquadratic=True,
    )
