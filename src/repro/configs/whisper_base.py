"""Whisper-base [arXiv:2212.04356]: enc-dec; conv frontend is a STUB —
input_specs provide precomputed frame embeddings (B, 1500, d_model)."""
from repro.models.config import EncoderConfig, ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        arch_id="whisper-base", family="encdec",
        n_layers=6, d_model=512, n_heads=8, n_kv=8, d_ff=2048,
        vocab=51865, mlp_kind="gelu",
        encoder=EncoderConfig(n_layers=6, n_frames=1500),
    )
