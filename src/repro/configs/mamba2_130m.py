"""Mamba2-130M [arXiv:2405.21060]: attention-free SSD; O(1) decode state.
Sub-quadratic -> eligible for long_500k."""
from repro.models.config import ModelConfig, SSMConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        arch_id="mamba2-130m", family="ssm", mixer="ssm",
        n_layers=24, d_model=768, n_heads=0, n_kv=0, d_ff=0,
        vocab=50280, head_dim=0,
        ssm=SSMConfig(d_state=128, d_inner=1536, head_p=64),
        subquadratic=True,
    )
