"""DeepSeek-MoE-16B [arXiv:2401.06066]: fine-grained MoE, 2 shared + 64
routed experts, top-6.  (HF layer 0 is dense-MLP; we keep a uniform MoE
stack for scan homogeneity — see DESIGN.md §5.)"""
from repro.models.config import ModelConfig, MoEConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        arch_id="deepseek-moe-16b", family="moe",
        n_layers=28, d_model=2048, n_heads=16, n_kv=16, d_ff=0,
        vocab=102400, rope_theta=10000.0,
        moe=MoEConfig(num_experts=64, top_k=6, d_expert=1408,
                      num_shared=2, d_shared=2816),
    )
