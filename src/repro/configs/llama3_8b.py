"""Llama-3-8B [arXiv:2407.21783]: GQA kv=8, 128k vocab, theta 500k."""
from repro.models.config import ModelConfig


def get_config() -> ModelConfig:
    return ModelConfig(
        arch_id="llama3-8b", family="dense",
        n_layers=32, d_model=4096, n_heads=32, n_kv=8, d_ff=14336,
        vocab=128256, rope_theta=500000.0,
    )
