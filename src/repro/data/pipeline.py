"""Parallel Encoding-Decoding (E-D) pipeline — paper Fig. 1.

While epoch *e* trains, a background thread shuffles, pre-processes (SBS +
per-class augmentation), encodes and "dumps" the batches of epoch *e+1*
into a bounded queue — double-buffering the host-side work exactly as the
paper's flow chart describes.  On first use the loader blocks until the
first epoch's batches are dumped ("training will start after data is dumped
for the first time").

The loader is deterministic and *resumable*: its state is
(seed, epoch, batch_index), which the checkpointing layer persists so a
preempted job replays the data stream exactly.
"""
from __future__ import annotations

import dataclasses
import queue
import threading
from typing import Callable, Iterator, Mapping, Optional

import numpy as np

from repro.core import encoding


@dataclasses.dataclass
class LoaderState:
    """Resumable position in the data stream (persisted in checkpoints)."""

    seed: int = 0
    epoch: int = 0
    batch: int = 0


class ParallelEncodedLoader:
    """Background-thread batch encoder with double buffering.

    Parameters
    ----------
    images, labels : full dataset (uint8 images NHWC, int labels)
    batch_size     : decoded batch size (images per step)
    codec          : 'u32' (deployed, bit-exact 4x) | 'base256' | 'none'
    class_weights  : optional SBS weights (paper Algorithm 2)
    preprocess     : optional per-class augmentation hooks {class: fn}
    prefetch       : queue depth in batches
    """

    def __init__(
        self,
        images: np.ndarray,
        labels: np.ndarray,
        batch_size: int,
        *,
        codec: str = "u32",
        class_weights=None,
        preprocess: Optional[Mapping[int, Callable]] = None,
        prefetch: int = 4,
        state: LoaderState | None = None,
        drop_remainder: bool = True,
    ):
        if codec not in ("u32", "base256", "none"):
            raise ValueError(f"unknown codec {codec!r}")
        if codec == "u32" and batch_size % encoding.PACK:
            raise ValueError(f"batch_size must be a multiple of {encoding.PACK}")
        self.images, self.labels = images, labels
        self.batch_size = batch_size
        self.codec = codec
        self.class_weights = class_weights
        self.preprocess = dict(preprocess or {})
        self.state = state or LoaderState()
        self.steps_per_epoch = len(images) // batch_size if drop_remainder else -(
            -len(images) // batch_size
        )
        self._q: queue.Queue = queue.Queue(maxsize=prefetch)
        self._stop = threading.Event()
        self._thread = threading.Thread(target=self._producer, daemon=True)
        self._thread.start()

    # ---------------------------------------------------------- producer ---
    def _epoch_order(self, epoch: int) -> np.ndarray:
        rng = np.random.default_rng((self.state.seed, epoch))
        if self.class_weights is not None:
            idx = np.concatenate(
                [
                    encoding.selective_batch_indices(
                        self.labels, self.class_weights, self.batch_size, rng
                    )
                    for _ in range(self.steps_per_epoch)
                ]
            )
            return idx
        order = rng.permutation(len(self.images))
        return order[: self.steps_per_epoch * self.batch_size]

    def _encode(self, batch_imgs: np.ndarray):
        if self.codec == "none":
            return batch_imgs.astype(np.float32) / 255.0
        if self.codec == "u32":
            return np.asarray(encoding.pack_u8_to_u32(batch_imgs))
        # base256: split into float64 containers of <=6 images each
        n = batch_imgs.shape[0]
        cap = encoding.MAX_BASE256_F64
        return np.stack(
            [
                encoding.encode_base256(batch_imgs[i : i + cap])
                for i in range(0, n, cap)
            ]
        )

    def _producer(self):
        epoch, start_batch = self.state.epoch, self.state.batch
        while not self._stop.is_set():
            order = self._epoch_order(epoch)
            for b in range(start_batch, self.steps_per_epoch):
                idx = order[b * self.batch_size : (b + 1) * self.batch_size]
                imgs = self.images[idx]
                labs = self.labels[idx]
                for cls, fn in self.preprocess.items():
                    m = labs == cls
                    if m.any():
                        imgs = imgs.copy()
                        imgs[m] = fn(imgs[m])
                enc = self._encode(imgs)
                while not self._stop.is_set():
                    try:
                        self._q.put((epoch, b, enc, labs.copy()), timeout=0.1)
                        break
                    except queue.Full:
                        continue
                if self._stop.is_set():
                    return
            epoch, start_batch = epoch + 1, 0

    # ---------------------------------------------------------- consumer ---
    def __iter__(self) -> Iterator:
        return self

    def __next__(self):
        epoch, b, enc, labs = self._q.get()
        self.state = LoaderState(self.state.seed, epoch, b + 1)
        if self.state.batch >= self.steps_per_epoch:
            self.state = LoaderState(self.state.seed, epoch + 1, 0)
        return enc, labs

    def close(self):
        self._stop.set()
        try:
            while True:
                self._q.get_nowait()
        except queue.Empty:
            pass
        self._thread.join(timeout=2.0)

    def __enter__(self):
        return self

    def __exit__(self, *exc):
        self.close()
