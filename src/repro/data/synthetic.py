"""Synthetic datasets (offline container: no downloads).

``make_cifar_like`` produces a learnable image-classification task with the
CIFAR-10 geometry (32x32x3 uint8, 10 classes): each class has a distinct
smooth template + noise, so small CNNs reach high accuracy within a few
hundred steps — enough to demonstrate the paper's "same accuracy" parity
claims between pipelines without the real dataset.

``token_stream`` produces a deterministic pseudo-corpus for LM smoke tests.
"""
from __future__ import annotations

import numpy as np


def make_cifar_like(n: int = 2048, num_classes: int = 10, hw: int = 32,
                    channels: int = 3, seed: int = 0, noise: float = 24.0):
    rng = np.random.default_rng(seed)
    yy, xx = np.mgrid[0:hw, 0:hw].astype(np.float64) / hw
    templates = []
    for c in range(num_classes):
        freq = 1 + c % 5
        phase = 2 * np.pi * c / num_classes
        base = 127 + 100 * np.sin(2 * np.pi * freq * xx + phase) * np.cos(
            2 * np.pi * (c // 5 + 1) * yy
        )
        templates.append(np.stack([np.roll(base, k * 3, axis=1) for k in range(channels)], -1))
    templates = np.stack(templates)  # (C, H, W, ch)
    labels = rng.integers(0, num_classes, size=n)
    imgs = templates[labels] + rng.normal(0, noise, size=(n, hw, hw, channels))
    return np.clip(imgs, 0, 255).astype(np.uint8), labels.astype(np.int32)


def token_stream(n_tokens: int, vocab: int, seed: int = 0) -> np.ndarray:
    """Markov-ish deterministic token stream (learnable bigram structure)."""
    rng = np.random.default_rng(seed)
    toks = np.empty(n_tokens, dtype=np.int32)
    t = rng.integers(0, vocab)
    for i in range(n_tokens):
        toks[i] = t
        # strongly-biased successor: learnable structure
        t = (t * 31 + 7) % vocab if rng.random() < 0.8 else rng.integers(0, vocab)
    return toks
