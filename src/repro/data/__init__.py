from repro.data.pipeline import LoaderState, ParallelEncodedLoader
from repro.data.synthetic import make_cifar_like, token_stream

__all__ = ["LoaderState", "ParallelEncodedLoader", "make_cifar_like", "token_stream"]
