"""Sequential-checkpoint (S-C) training — OpTorch's Gradient-flow optimization.

The paper's core idea: a sequential network is executed as a list of
*segments*; only segment-boundary activations are stored and everything
else is recomputed during the backward pass.  In JAX this is ``jax.checkpoint``
(remat).  This module provides:

  * ``checkpoint_sequential``   — paper Algorithm analogue: wrap an explicit
    list of layer functions into ``num_segments`` remat segments.
  * ``remat_scan``              — S-C over a ``lax.scan`` layer stack (the
    form every ``repro.models`` stack uses); one remat segment per scanned
    block, with a saveable-names policy.
  * ``optimal_segments``        — dynamic program that places checkpoints at
    *narrow* activations, formalizing the paper's Fig. 11 recommendation
    ("design a small middle layer and checkpoint there").
  * ``Policy`` registry         — named XLA remat policies.

All of this is composable: ``sc(model_apply)`` from ``repro.core.api`` is the
one-line wrapper the paper advertises (``scmodel = sc(model)``).
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Named remat policies.
# ---------------------------------------------------------------------------
# 'full'       : save nothing inside a segment (paper's S-C; max recompute)
# 'none'       : save everything (standard pipeline; no recompute)
# 'dots'       : save matmul outputs only (XLA's dots_saveable)
# 'dots_nobatch': save only non-batch matmuls (good default for LMs)
# 'names'      : save only activations tagged with checkpoint_name(...)
POLICIES: dict[str, Any] = {
    "full": None,
    "none": jax.checkpoint_policies.everything_saveable,
    "nothing": jax.checkpoint_policies.nothing_saveable,
    "dots": jax.checkpoint_policies.dots_saveable,
    "dots_nobatch": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
}


def resolve_policy(policy: str | Any | None, save_names: Sequence[str] = ()):
    """Resolve a policy name (or pass a policy callable through).

    ``save_names`` composes with a base policy: tensors tagged via
    jax.ad_checkpoint.checkpoint_name are saved IN ADDITION to whatever the
    base policy saves (e.g. save post-all-reduce block outputs so the
    backward never re-runs forward collectives).
    """
    if save_names:
        names_pol = jax.checkpoint_policies.save_only_these_names(*save_names)
        base = resolve_policy(policy) if policy not in (None, "full") else None
        if base is None:
            return names_pol
        return jax.checkpoint_policies.save_from_both_policies(base, names_pol)
    if policy is None or callable(policy):
        return policy
    if isinstance(policy, str):
        if policy in POLICIES:
            return POLICIES[policy]
        raise ValueError(f"unknown remat policy {policy!r}; have {sorted(POLICIES)}")
    raise TypeError(f"bad policy {policy!r}")


@dataclasses.dataclass(frozen=True)
class CheckpointConfig:
    """How S-C is applied to a layer stack.

    enabled:       master switch (False == paper's "standard pipeline").
    policy:        intra-segment saveable policy name (see POLICIES).
    save_names:    if non-empty, overrides policy with save_only_these_names.
    segment_size:  scanned blocks per remat segment (1 = remat every block).
    """

    enabled: bool = True
    policy: str = "full"
    save_names: tuple[str, ...] = ()
    segment_size: int = 1

    def wrap(self, fn: Callable) -> Callable:
        if not self.enabled:
            return fn
        pol = resolve_policy(self.policy, self.save_names)
        return jax.checkpoint(fn, policy=pol)


# ---------------------------------------------------------------------------
# Explicit layer-list form (paper's Algorithm: segments of a Sequential).
# ---------------------------------------------------------------------------
def checkpoint_sequential(
    layer_fns: Sequence[Callable[[Any], Any]],
    num_segments: int,
    *,
    policy: str | None = "full",
    boundaries: Sequence[int] | None = None,
) -> Callable[[Any], Any]:
    """Compose ``layer_fns`` into a single function with S-C applied.

    Layers are grouped into ``num_segments`` contiguous segments (or at the
    explicit ``boundaries``, e.g. from :func:`optimal_segments`).  Each
    segment except the last is wrapped in ``jax.checkpoint``: its inputs are
    stored, its intermediates recomputed on the backward pass — exactly the
    paper's scheme ("the inputs of each segment will be saved for re-running
    the segment in the backward pass").
    """
    n = len(layer_fns)
    if boundaries is None:
        num_segments = max(1, min(num_segments, n))
        # Even split, same convention as torch.utils.checkpoint_sequential.
        bounds = [round(i * n / num_segments) for i in range(num_segments + 1)]
    else:
        bounds = [0, *sorted(boundaries), n]
    pol = resolve_policy(policy)

    def make_segment(fns):
        def seg(x):
            for f in fns:
                x = f(x)
            return x
        return seg

    segments = []
    for lo, hi in zip(bounds[:-1], bounds[1:]):
        if lo == hi:
            continue
        segments.append(make_segment(layer_fns[lo:hi]))

    def apply(x):
        # The last segment is NOT checkpointed: its activations feed the loss
        # directly and would be recomputed immediately anyway (paper: "all
        # segments except the last").
        for seg in segments[:-1]:
            x = jax.checkpoint(seg, policy=pol)(x)
        return segments[-1](x)

    return apply


# ---------------------------------------------------------------------------
# Scan form: S-C over a homogeneous stacked-params layer stack.
# ---------------------------------------------------------------------------
def remat_scan(
    body: Callable[[Any, Any], tuple[Any, Any]],
    carry: Any,
    xs: Any,
    *,
    config: CheckpointConfig = CheckpointConfig(),
    length: int | None = None,
    unroll: int = 1,
):
    """``lax.scan`` over stacked per-layer params with S-C applied to the body.

    With ``segment_size > 1`` the stack is reshaped to
    ``(n_segments, segment_size, ...)`` and an inner (rematted) scan runs the
    segment — one checkpoint per *segment*, matching the paper's segment
    granularity rather than per-layer granularity.
    """
    seg = config.segment_size if config.enabled else 1
    if seg <= 1:
        return jax.lax.scan(config.wrap(body), carry, xs, length=length, unroll=unroll)

    import math
    n = length if length is not None else jax.tree_util.tree_leaves(xs)[0].shape[0]
    if n % seg != 0:
        # fall back to the largest divisor (keeps shallow probe configs and
        # odd layer counts working; segment_size is a perf knob, not a
        # semantic one)
        seg = math.gcd(n, seg)
    if seg <= 1:
        return jax.lax.scan(config.wrap(body), carry, xs, length=length,
                            unroll=unroll)

    def reshape_leaf(a):
        return a.reshape((n // seg, seg) + a.shape[1:])

    xs_seg = jax.tree_util.tree_map(reshape_leaf, xs)

    def segment_body(c, xs_inner):
        return jax.lax.scan(body, c, xs_inner, length=seg, unroll=unroll)

    return jax.lax.scan(config.wrap(segment_body), carry, xs_seg, length=n // seg)


# ---------------------------------------------------------------------------
# Optimal checkpoint placement (paper Fig. 11, formalized).
# ---------------------------------------------------------------------------
def optimal_segments(activation_bytes: Sequence[int], num_checkpoints: int) -> list[int]:
    """Choose checkpoint boundaries minimizing peak stored activation bytes.

    ``activation_bytes[i]`` is the size of the activation produced by layer
    ``i`` (a candidate checkpoint site).  Peak memory under S-C is modelled
    as  sum(stored checkpoints) + max over segments of (recompute live set),
    where the recompute live set of a segment is the sum of its internal
    activation sizes (they are all live at once during that segment's
    backward pass).

    This is the paper's "checkpoint the narrow middle layer" advice as a DP:
    on a UNet-shaped size profile the solver picks the bottleneck layers.
    Returns sorted boundary indices (exclusive of 0 and n).
    """
    n = len(activation_bytes)
    k = min(num_checkpoints, n - 1)
    if k <= 0 or n <= 1:
        return []
    sizes = list(activation_bytes)
    # prefix[i] = sum(sizes[:i])
    prefix = [0]
    for s in sizes:
        prefix.append(prefix[-1] + s)

    def seg_cost(lo, hi):  # live recompute bytes for segment (lo, hi]
        return prefix[hi] - prefix[lo]

    INF = float("inf")
    # dp[j][i] = (stored_bytes, max_seg) best over placements of j checkpoints
    # in the first i layers, scoring stored + max_seg at the end.  We track
    # the full frontier per (j, i) on the two objectives via minimizing
    # stored + max_seg directly with memo over last boundary.
    # n is small (layer counts ≤ 64) so an O(n^2 k) DP with the combined
    # objective evaluated lazily is fine.
    import math

    best_choice: dict[tuple[int, int], tuple[float, tuple[int, ...]]] = {}

    def solve(j: int, i: int) -> list[tuple[int, tuple[int, ...], int]]:
        """Return list of (stored, boundaries, max_seg) Pareto states for
        j checkpoints placed all < i, segments closed up to boundary i."""
        key = (j, i)
        if key in best_choice:
            return best_choice[key]  # type: ignore[return-value]
        if j == 0:
            states = [(0, (), seg_cost(0, i))]
        else:
            states = []
            for b in range(j, i):  # last checkpoint at layer b (1-indexed site b)
                for stored, bounds, mx in solve(j - 1, b):
                    states.append(
                        (stored + sizes[b - 1], bounds + (b,), max(mx, seg_cost(b, i)))
                    )
            # Pareto-prune on (stored, max_seg)
            states.sort(key=lambda s: (s[0], s[2]))
            pruned, best_mx = [], math.inf
            for s in states:
                if s[2] < best_mx:
                    pruned.append(s)
                    best_mx = s[2]
            states = pruned
        best_choice[key] = states  # type: ignore[assignment]
        return states

    final = solve(k, n)
    best = min(final, key=lambda s: s[0] + s[2])
    return list(best[1])


def activation_bytes_of(fn: Callable, *args, **kwargs) -> int:
    """Static helper: bytes of fn's output pytree (for the placement DP)."""
    out = jax.eval_shape(fn, *args, **kwargs)
    return sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(out))
