"""Sequential-checkpoint (S-C) training — OpTorch's Gradient-flow optimization.

The paper's core idea: a sequential network is executed as a list of
*segments*; only segment-boundary activations are stored and everything
else is recomputed during the backward pass.  In JAX this is ``jax.checkpoint``
(remat).  This module provides:

  * ``checkpoint_sequential``   — paper Algorithm analogue: wrap an explicit
    list of layer functions into ``num_segments`` remat segments.
  * ``remat_scan``              — S-C over a ``lax.scan`` layer stack (the
    form every ``repro.models`` stack uses); one remat segment per scanned
    block, with a saveable-names policy.
  * ``optimal_segments``        — dynamic program that places checkpoints at
    *narrow* activations, formalizing the paper's Fig. 11 recommendation
    ("design a small middle layer and checkpoint there").
  * ``Policy`` registry         — named XLA remat policies.

All of this is composable: ``sc(model_apply)`` from ``repro.core.api`` is the
one-line wrapper the paper advertises (``scmodel = sc(model)``).
"""
from __future__ import annotations

import dataclasses
import functools
import warnings
from typing import Any, Callable, Sequence

import jax
import jax.numpy as jnp

from repro.plan.solver import RematPlan

# ---------------------------------------------------------------------------
# Named remat policies.
# ---------------------------------------------------------------------------
# 'full'       : save nothing inside a segment (paper's S-C; max recompute)
# 'none'       : save everything (standard pipeline; no recompute)
# 'dots'       : save matmul outputs only (XLA's dots_saveable)
# 'dots_nobatch': save only non-batch matmuls (good default for LMs)
# 'names'      : save only activations tagged with checkpoint_name(...)
POLICIES: dict[str, Any] = {
    "full": None,
    "none": jax.checkpoint_policies.everything_saveable,
    "nothing": jax.checkpoint_policies.nothing_saveable,
    "dots": jax.checkpoint_policies.dots_saveable,
    "dots_nobatch": jax.checkpoint_policies.dots_with_no_batch_dims_saveable,
}


def resolve_policy(policy: str | Any | None, save_names: Sequence[str] = ()):
    """Resolve a policy name (or pass a policy callable through).

    ``save_names`` composes with a base policy: tensors tagged via
    jax.ad_checkpoint.checkpoint_name are saved IN ADDITION to whatever the
    base policy saves (e.g. save post-all-reduce block outputs so the
    backward never re-runs forward collectives).
    """
    if save_names:
        names_pol = jax.checkpoint_policies.save_only_these_names(*save_names)
        base = resolve_policy(policy) if policy not in (None, "full") else None
        if base is None:
            return names_pol
        return jax.checkpoint_policies.save_from_both_policies(base, names_pol)
    if policy is None or callable(policy):
        return policy
    if isinstance(policy, str):
        if policy in POLICIES:
            return POLICIES[policy]
        raise ValueError(f"unknown remat policy {policy!r}; have {sorted(POLICIES)}")
    raise TypeError(f"bad policy {policy!r}")


@dataclasses.dataclass(frozen=True)
class CheckpointConfig:
    """How S-C is applied to a layer stack — the single remat entry point.

    enabled:       master switch (False == paper's "standard pipeline").
    policy:        intra-segment saveable policy name (see POLICIES).
    save_names:    if non-empty, overrides policy with save_only_these_names.
    segment_size:  uniform fallback: scanned blocks per remat segment
                   (1 = remat every block).  Ignored when ``plan`` is set.
    plan:          a :class:`repro.plan.RematPlan` — profile-driven,
                   possibly non-uniform checkpoint boundaries (+ optional
                   per-segment policies).  Produced by ``repro.plan``'s
                   solvers; serializable for reproducible runs.
    """

    enabled: bool = True
    policy: str = "full"
    save_names: tuple[str, ...] = ()
    segment_size: int = 1
    plan: RematPlan | None = None

    def wrap(self, fn: Callable) -> Callable:
        if not self.enabled:
            return fn
        pol = resolve_policy(self.policy, self.save_names)
        return jax.checkpoint(fn, policy=pol)

    def segment_policy(self, j: int):
        """Resolved policy for plan segment j.

        A plan carries its own policy (scalar or per-segment) as part of
        the solved artifact, so when a plan is present it WINS over
        ``self.policy`` — identically in the scan and sequential paths.
        ``save_names`` always composes on top.
        """
        if self.plan is not None:
            return resolve_policy(self.plan.segment_policy(j),
                                  self.save_names)
        return resolve_policy(self.policy, self.save_names)

    def validated_plan(self, n_layers: int) -> RematPlan | None:
        """The plan, checked against the actual chain depth."""
        if self.plan is None:
            return None
        if self.plan.n_layers != n_layers:
            raise ValueError(
                f"RematPlan was solved for {self.plan.n_layers} layers but "
                f"the model has {n_layers}; re-run the planner "
                f"(plan source: {self.plan.source!r})")
        return self.plan


# ---------------------------------------------------------------------------
# Explicit layer-list form (paper's Algorithm: segments of a Sequential).
# ---------------------------------------------------------------------------
def checkpoint_sequential(
    layer_fns: Sequence[Callable[[Any], Any]],
    num_segments: int = 0,
    *,
    policy: str | None = "full",
    boundaries: Sequence[int] | None = None,
    plan: RematPlan | None = None,
    save_names: Sequence[str] = (),
) -> Callable[[Any], Any]:
    """Compose ``layer_fns`` into a single function with S-C applied.

    Layers are grouped into ``num_segments`` contiguous segments, at the
    explicit ``boundaries``, or per a solved :class:`RematPlan`.  A plan's
    policy (scalar or per-segment) overrides ``policy`` — the plan is one
    artifact, boundaries + policy; ``save_names`` composes on top either
    way.  Each segment except the last is wrapped in ``jax.checkpoint``:
    its inputs are stored, its intermediates recomputed on the backward
    pass — exactly the paper's scheme ("the inputs of each segment will be
    saved for re-running the segment in the backward pass").
    """
    n = len(layer_fns)
    save_names = tuple(save_names)
    seg_policies: list[Any] | None = None
    if plan is not None:
        if plan.n_layers != n:
            raise ValueError(
                f"RematPlan solved for {plan.n_layers} layers applied to a "
                f"{n}-layer chain (plan source: {plan.source!r})")
        bounds = [0, *plan.boundaries, n]
        seg_policies = [resolve_policy(plan.segment_policy(j), save_names)
                        for j in range(plan.n_segments)]
    elif boundaries is None:
        num_segments = max(1, min(num_segments, n))
        # Even split, same convention as torch.utils.checkpoint_sequential.
        bounds = [round(i * n / num_segments) for i in range(num_segments + 1)]
    else:
        bounds = [0, *sorted(boundaries), n]
    pol = resolve_policy(policy, save_names)

    def make_segment(fns):
        def seg(x):
            for f in fns:
                x = f(x)
            return x
        return seg

    segments, policies = [], []
    for j, (lo, hi) in enumerate(zip(bounds[:-1], bounds[1:])):
        if lo == hi:
            continue
        segments.append(make_segment(layer_fns[lo:hi]))
        policies.append(seg_policies[j] if seg_policies is not None else pol)

    def apply(x):
        # The last segment is NOT checkpointed: its activations feed the loss
        # directly and would be recomputed immediately anyway (paper: "all
        # segments except the last").
        for seg, p in zip(segments[:-1], policies[:-1]):
            x = jax.checkpoint(seg, policy=p)(x)
        return segments[-1](x)

    return apply


# ---------------------------------------------------------------------------
# Scan form: S-C over a homogeneous stacked-params layer stack.
# ---------------------------------------------------------------------------
def _largest_divisor_leq(n: int, k: int) -> int:
    """Largest d with d | n and d <= k (>= 1)."""
    for d in range(min(n, k), 1, -1):
        if n % d == 0:
            return d
    return 1


def remat_scan(
    body: Callable[[Any, Any], tuple[Any, Any]],
    carry: Any,
    xs: Any,
    *,
    config: CheckpointConfig = CheckpointConfig(),
    length: int | None = None,
    unroll: int = 1,
):
    """``lax.scan`` over stacked per-layer params with S-C applied to the body.

    Three granularities, all selected via ``config``:

      * per-block (default): every scanned block is its own remat segment;
      * uniform ``segment_size``: the stack is reshaped to
        ``(n_segments, segment_size, ...)`` and an inner (rematted) scan
        runs each segment — one checkpoint per *segment*, the paper's
        segment granularity;
      * a solved ``config.plan``: non-uniform boundaries from the memory
        planner — one (possibly per-segment-policied) remat segment per
        plan segment.  EVERY segment is rematted (matching the uniform
        scan path, where only segment-input carries are stored); an empty
        plan (no boundaries) means the planner found everything fits and
        runs a plain, un-rematted scan.
    """
    n = length if length is not None else \
        jax.tree_util.tree_leaves(xs)[0].shape[0]

    if config.enabled and config.plan is not None:
        plan = config.validated_plan(n)
        if not plan.boundaries:
            # planner says everything fits: standard pipeline, no remat
            return jax.lax.scan(body, carry, xs, length=n, unroll=unroll)
        segments = plan.segments()
        ys_parts = []
        for j, (lo, hi) in enumerate(segments):
            xs_seg = jax.tree_util.tree_map(lambda a, _lo=lo, _hi=hi:
                                            a[_lo:_hi], xs)

            def seg_fn(c, xsg, _len=hi - lo):
                return jax.lax.scan(body, c, xsg, length=_len, unroll=unroll)

            seg_fn = jax.checkpoint(seg_fn, policy=config.segment_policy(j))
            carry, ys = seg_fn(carry, xs_seg)
            ys_parts.append(ys)
        ys_all = jax.tree_util.tree_map(
            lambda *parts: jnp.concatenate(parts, axis=0), *ys_parts)
        return carry, ys_all

    seg = config.segment_size if config.enabled else 1
    if seg <= 1:
        return jax.lax.scan(config.wrap(body), carry, xs, length=length,
                            unroll=unroll)

    if n % seg != 0:
        # fall back to the LARGEST divisor <= requested (48 layers @ segment
        # 5 -> 4, not gcd's 1 == per-layer remat); segment_size is a perf
        # knob, not a semantic one, but silently degrading to per-layer
        # storage defeats its purpose — so warn.
        new_seg = _largest_divisor_leq(n, seg)
        warnings.warn(
            f"remat_scan: segment_size={seg} does not divide {n} scanned "
            f"layers; using largest divisor {new_seg} (use a RematPlan for "
            f"non-uniform segments)", stacklevel=2)
        seg = new_seg
    if seg <= 1:
        return jax.lax.scan(config.wrap(body), carry, xs, length=length,
                            unroll=unroll)

    def reshape_leaf(a):
        return a.reshape((n // seg, seg) + a.shape[1:])

    xs_seg = jax.tree_util.tree_map(reshape_leaf, xs)

    def segment_body(c, xs_inner):
        return jax.lax.scan(body, c, xs_inner, length=seg, unroll=unroll)

    return jax.lax.scan(config.wrap(segment_body), carry, xs_seg, length=n // seg)


# ---------------------------------------------------------------------------
# Optimal checkpoint placement (paper Fig. 11, formalized).
# ---------------------------------------------------------------------------
def optimal_segments(activation_bytes: Sequence[int], num_checkpoints: int) -> list[int]:
    """Choose checkpoint boundaries minimizing peak stored activation bytes.

    ``activation_bytes[i]`` is the size of the activation produced by layer
    ``i`` (a candidate checkpoint site).  Peak memory under S-C is modelled
    as  sum(stored checkpoints) + max over segments of (recompute live set),
    where the recompute live set of a segment is the sum of its internal
    activation sizes (they are all live at once during that segment's
    backward pass).

    This is the paper's "checkpoint the narrow middle layer" advice as a DP:
    on a UNet-shaped size profile the solver picks the bottleneck layers.
    Returns sorted boundary indices (exclusive of 0 and n).

    (Thin wrapper: the DP lives in ``repro.plan.solver`` alongside the
    budget-aware primal solver; see ``repro.plan`` for profile-driven use.)
    """
    from repro.plan.solver import min_peak_boundaries
    return min_peak_boundaries(activation_bytes, num_checkpoints)


def activation_bytes_of(fn: Callable, *args, **kwargs) -> int:
    """Static helper: bytes of fn's output pytree (for the placement DP)."""
    out = jax.eval_shape(fn, *args, **kwargs)
    return sum(x.size * x.dtype.itemsize for x in jax.tree_util.tree_leaves(out))
