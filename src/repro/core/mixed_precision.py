"""Mixed-precision (M-P) training — OpTorch's second Gradient-flow optimization.

Paper mechanism (Fig. 3): weights are *stored* in FP16, *cast up* to FP32
around loss/gradient computation, and updates are applied against FP32
master weights.  On TPU the storage dtype of choice is bf16 (same exponent
range as fp32 → no loss scaling needed); the fp16 path is kept for paper
fidelity and ships with static & dynamic loss scaling.

Pieces:
  * ``Policy``           — (param_dtype, compute_dtype, output_dtype) triple.
  * ``cast_to_compute``  — cast a param tree to the compute dtype at use.
  * ``LossScale``        — static or dynamic (2x up / 2x down on non-finite).
  * ``scaled_value_and_grad`` — drop-in value_and_grad with master-weight
    semantics: grads are returned in fp32 regardless of storage dtype.
"""
from __future__ import annotations

import dataclasses
from typing import Any, Callable

import jax
import jax.numpy as jnp

_FLOAT_KINDS = ("f",)  # jnp floating kinds we cast


def _is_float(x) -> bool:
    return hasattr(x, "dtype") and jnp.issubdtype(x.dtype, jnp.floating)


@dataclasses.dataclass(frozen=True)
class Policy:
    """Dtype policy applied around a model function.

    ``flash_resid_dtype`` extends the policy to the flash-attention
    custom_vjp residual tuple: the saved (q, k, v, o) — the dominant
    O(S*D) term of what lives between forward and backward — are stored
    in this dtype while the (m, l) softmax stats always stay f32.  None
    means residuals simply follow the compute dtype of their inputs (the
    pre-policy behavior); the interesting setting is f32 compute with
    bf16-stored residuals, trading backward recompute precision for
    halved attention residual memory (see ``kernels/flash/ops.py``).
    """

    param_dtype: Any = jnp.float32    # storage
    compute_dtype: Any = jnp.bfloat16  # matmuls / activations
    output_dtype: Any = jnp.float32    # logits / loss accumulation
    flash_resid_dtype: Any = None      # saved flash (q,k,v,o); None=follow

    @staticmethod
    def full() -> "Policy":  # the paper's "standard pipeline" (pure FP32)
        return Policy(jnp.float32, jnp.float32, jnp.float32)

    @staticmethod
    def bf16() -> "Policy":  # TPU-native mixed precision
        return Policy(jnp.float32, jnp.bfloat16, jnp.float32)

    @staticmethod
    def fp16() -> "Policy":  # paper-faithful FP16 storage (needs loss scale)
        return Policy(jnp.float16, jnp.float16, jnp.float32)

    @staticmethod
    def bf16_params() -> "Policy":  # aggressive: bf16 storage too (half memory)
        return Policy(jnp.bfloat16, jnp.bfloat16, jnp.float32)

    @staticmethod
    def resid_bf16() -> "Policy":  # f32 compute, bf16-SAVED flash residuals
        return Policy(jnp.float32, jnp.float32, jnp.float32,
                      flash_resid_dtype=jnp.bfloat16)

    def cast_to_compute(self, tree):
        return jax.tree_util.tree_map(
            lambda x: x.astype(self.compute_dtype) if _is_float(x) else x, tree
        )

    def cast_to_param(self, tree):
        return jax.tree_util.tree_map(
            lambda x: x.astype(self.param_dtype) if _is_float(x) else x, tree
        )

    def cast_to_output(self, tree):
        return jax.tree_util.tree_map(
            lambda x: x.astype(self.output_dtype) if _is_float(x) else x, tree
        )


def get_policy(name: str) -> Policy:
    try:
        return {
            "full": Policy.full(),
            "fp32": Policy.full(),
            "bf16": Policy.bf16(),
            "fp16": Policy.fp16(),
            "bf16_params": Policy.bf16_params(),
            "resid_bf16": Policy.resid_bf16(),
        }[name]
    except KeyError:
        raise ValueError(f"unknown mixed-precision policy {name!r}") from None


# ---------------------------------------------------------------------------
# Loss scaling (needed for the paper-faithful fp16 path).
# ---------------------------------------------------------------------------
@dataclasses.dataclass
class LossScale:
    """Dynamic loss scale state (static if ``growth_interval == 0``)."""

    scale: jax.Array                      # current multiplier
    growth_counter: jax.Array             # consecutive finite steps
    growth_interval: int = 2000           # 0 => static scaling
    growth_factor: float = 2.0
    backoff_factor: float = 0.5
    max_scale: float = 2.0 ** 24

    @staticmethod
    def init(initial: float = 2.0 ** 15, growth_interval: int = 2000) -> "LossScale":
        return LossScale(
            scale=jnp.float32(initial),
            growth_counter=jnp.int32(0),
            growth_interval=growth_interval,
        )

    @staticmethod
    def noop() -> "LossScale":
        return LossScale(scale=jnp.float32(1.0), growth_counter=jnp.int32(0),
                         growth_interval=0)

    def scale_loss(self, loss):
        return loss * self.scale.astype(loss.dtype)

    def unscale(self, grads):
        inv = (1.0 / self.scale).astype(jnp.float32)
        return jax.tree_util.tree_map(lambda g: g.astype(jnp.float32) * inv, grads)

    def update(self, grads_finite: jax.Array) -> "LossScale":
        if self.growth_interval == 0:
            return self
        counter = jnp.where(grads_finite, self.growth_counter + 1, 0).astype(jnp.int32)
        grow = counter >= self.growth_interval
        new_scale = jnp.where(
            grads_finite,
            jnp.where(grow, jnp.minimum(self.scale * self.growth_factor, self.max_scale),
                      self.scale),
            jnp.maximum(self.scale * self.backoff_factor, 1.0),
        )
        return dataclasses.replace(
            self, scale=new_scale, growth_counter=jnp.where(grow, 0, counter).astype(jnp.int32)
        )


jax.tree_util.register_dataclass(
    LossScale,
    data_fields=["scale", "growth_counter"],
    meta_fields=["growth_interval", "growth_factor", "backoff_factor",
                 "max_scale"],
)


def all_finite(tree) -> jax.Array:
    leaves = [jnp.all(jnp.isfinite(x)) for x in jax.tree_util.tree_leaves(tree) if _is_float(x)]
    if not leaves:
        return jnp.bool_(True)
    return jnp.stack(leaves).all()


def scaled_value_and_grad(
    loss_fn: Callable[..., jax.Array],
    policy: Policy,
    loss_scale: LossScale | None = None,
):
    """``value_and_grad`` with the paper's master-weight M-P semantics.

    ``loss_fn(params, *args)`` is differentiated w.r.t. fp32 master params;
    params are cast to ``policy.compute_dtype`` *inside* the diff so grads
    come back fp32 (cast-of-constant rule), the loss is scaled/unscaled, and
    a ``grads_finite`` flag is returned for the LossScale update / step skip.
    """
    def wrapped(master_params, *args):
        def scaled_loss(p, *a):
            loss, aux = loss_fn(policy.cast_to_compute(p), *a)
            s = loss_scale.scale_loss(loss) if loss_scale is not None else loss
            return s.astype(jnp.float32), aux

        (loss, aux), grads = jax.value_and_grad(scaled_loss, has_aux=True)(
            master_params, *args
        )
        if loss_scale is not None:
            grads = loss_scale.unscale(grads)
            loss = loss / loss_scale.scale
        finite = all_finite(grads)
        return (loss, aux), grads, finite

    return wrapped
