"""OpTorch core: the paper's Gradient-flow and Data-flow optimizations."""
from repro.core.api import mp, sc, sc_mp
from repro.core.checkpoint import (
    CheckpointConfig,
    checkpoint_sequential,
    optimal_segments,
    remat_scan,
)
from repro.core.mixed_precision import LossScale, Policy, get_policy, scaled_value_and_grad
from repro.core import encoding

__all__ = [
    "mp", "sc", "sc_mp", "CheckpointConfig", "checkpoint_sequential",
    "optimal_segments", "remat_scan", "LossScale", "Policy", "get_policy",
    "scaled_value_and_grad", "encoding",
]
