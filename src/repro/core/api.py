"""One-line OpTorch-style wrappers: ``scmodel = sc(model)`` etc.

The paper advertises single-command composition of its pipelines; this is
the JAX equivalent over pure apply functions.
"""
from __future__ import annotations

import functools
from typing import Callable

import jax

from repro.core.checkpoint import CheckpointConfig, checkpoint_sequential, resolve_policy
from repro.core.mixed_precision import Policy, get_policy


def sc(apply_fn: Callable, *, policy: str = "full", save_names=()) -> Callable:
    """Sequential-checkpoint a model apply function (whole-fn remat)."""
    return jax.checkpoint(apply_fn, policy=resolve_policy(policy, tuple(save_names)))


def mp(apply_fn: Callable, *, policy: str | Policy = "bf16") -> Callable:
    """Mixed-precision a model apply function: params/inputs are cast to the
    compute dtype on entry, outputs cast back to the output dtype."""
    pol = get_policy(policy) if isinstance(policy, str) else policy

    @functools.wraps(apply_fn)
    def wrapped(params, *args, **kwargs):
        out = apply_fn(pol.cast_to_compute(params),
                       *pol.cast_to_compute(args), **kwargs)
        return pol.cast_to_output(out)

    return wrapped


def sc_mp(apply_fn: Callable, *, remat_policy: str = "full",
          mp_policy: str = "bf16") -> Callable:
    """The paper's best FP-mixed pipeline: S-C + M-P composed."""
    return sc(mp(apply_fn, policy=mp_policy), policy=remat_policy)
