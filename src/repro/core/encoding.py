"""Encoding-Decoding (E-D) — OpTorch's Data-flow optimization.

Three codecs:

1. ``encode_base256`` / ``decode_base256`` — paper Algorithm 1 & 3, verbatim:
   the same positional pixel of N uint8 images is packed into one float64
   value  sum_i 256^i * M[i].  Exact for N <= 16 in the paper's float64
   framing only because 256^16 overflows the 53-bit mantissa at N=7 — the
   paper's "up-to 16X" claim holds for the *int64-valued* interpretation, so
   we implement the accumulator in float64 for fidelity AND in int64/uint32
   limbs for exactness (see below).  The paper's published code uses numpy
   float64; we keep that path on host (numpy), never on TPU.

2. ``encode_lossless`` / ``decode_lossless`` — paper Algorithm 4: base-128
   packing + a 1-bit offset plane per image (the parity bit), doubling the
   image capacity of the container dtype.

3. ``pack_u8_to_u32`` / ``unpack_u32_to_u8`` — the TPU-native adaptation:
   4 uint8 pixels per uint32 lane via shifts/masks.  Bit-exact for any N
   (multiple containers), VPU-friendly, and the layout the Pallas decode
   kernel (``repro.kernels.pack``) consumes.  This is the codec the
   framework actually deploys; the base-256 codecs are the paper-faithful
   references and oracles.

Plus Selective-batch-sampling (SBS, Algorithm 2): class-weighted batch
composition with per-class pre-processing hooks.
"""
from __future__ import annotations

from typing import Callable, Mapping, Sequence

import numpy as np
import jax
import jax.numpy as jnp

# ---------------------------------------------------------------------------
# Paper Algorithm 1 & 3: positional base-256 packing (host-side, float64).
# ---------------------------------------------------------------------------
MAX_BASE256_F64 = 6   # 256^7 > 2^53: float64 mantissa limit for exactness
MAX_BASE256_I64 = 7   # 256^8 overflows signed int64


def encode_base256(batch: np.ndarray, *, dtype=np.float64) -> np.ndarray:
    """Paper Algorithm 1: A = sum_i 256^i * X[i].

    batch: uint8 array (N, H, W, C) with N <= capacity of ``dtype``.
    Returns an (H, W, C) container of ``dtype``.
    """
    batch = np.asarray(batch)
    if batch.dtype != np.uint8:
        raise TypeError("base-256 codec packs uint8 images")
    n = batch.shape[0]
    cap = MAX_BASE256_F64 if dtype == np.float64 else MAX_BASE256_I64
    if n > cap:
        raise ValueError(f"{n} images exceed exact capacity {cap} of {dtype}")
    acc = np.zeros(batch.shape[1:], dtype=dtype)
    for i in range(n):
        acc = acc + batch[i].astype(dtype) * (dtype(256) ** i)
    return acc


def decode_base256(container: np.ndarray, n: int) -> np.ndarray:
    """Paper Algorithm 3: X[i] = A mod 256; A = A div 256 (integer div)."""
    a = np.asarray(container).astype(np.int64)
    out = np.empty((n,) + a.shape, dtype=np.uint8)
    for i in range(n):
        out[i] = (a % 256).astype(np.uint8)
        a = a // 256
    return out


# ---------------------------------------------------------------------------
# Paper Algorithm 4: loss-less forced encoding (base-128 + offset plane).
# ---------------------------------------------------------------------------
def encode_lossless(batch: np.ndarray, *, dtype=np.float64):
    """Base-128 packing with a parity-offset bit plane.

    Returns (container, offsets) where offsets is a packed bool plane
    (N, H, W, C).  Halving the per-image domain to 0..127 doubles capacity.
    """
    batch = np.asarray(batch)
    if batch.dtype != np.uint8:
        raise TypeError("lossless codec packs uint8 images")
    n = batch.shape[0]
    cap = 7 if dtype == np.float64 else 9  # 128^8 > 2^53; 128^9 < 2^63
    if n > cap:
        raise ValueError(f"{n} images exceed exact capacity {cap} of {dtype}")
    acc = np.zeros(batch.shape[1:], dtype=dtype)
    offsets = np.empty((n,) + batch.shape[1:], dtype=bool)
    for i in range(n):
        img = batch[i]
        offsets[i] = (img % 2).astype(bool)   # the parity offset
        half = (img // 2).astype(dtype)       # domain 0..127
        acc = acc + half * (dtype(128) ** i)
    return acc, offsets


def decode_lossless(container: np.ndarray, offsets: np.ndarray) -> np.ndarray:
    a = np.asarray(container).astype(np.int64)
    n = offsets.shape[0]
    out = np.empty_like(offsets, dtype=np.uint8)
    for i in range(n):
        half = (a % 128).astype(np.uint8)
        out[i] = half * 2 + offsets[i].astype(np.uint8)
        a = a // 128
    return out


# ---------------------------------------------------------------------------
# TPU-native codec: 4x uint8 -> uint32 bit packing (always exact).
# ---------------------------------------------------------------------------
PACK = 4  # u8 lanes per u32 container


def pack_u8_to_u32(batch: np.ndarray | jax.Array):
    """Pack groups of 4 images into uint32 containers.

    batch: uint8 (N, ...) with N % 4 == 0  ->  uint32 (N//4, ...).
    Grouping is along the leading axis: container j holds images
    4j..4j+3 at byte lanes 0..3.  Works on numpy or jnp inputs.
    """
    xp = jnp if isinstance(batch, jax.Array) else np
    n = batch.shape[0]
    if n % PACK:
        raise ValueError(f"N={n} not a multiple of {PACK}")
    x = batch.astype(xp.uint32).reshape((n // PACK, PACK) + batch.shape[1:])
    shifts = xp.arange(PACK, dtype=xp.uint32) * 8
    shifts = shifts.reshape((1, PACK) + (1,) * (batch.ndim - 1))
    return (x << shifts).sum(axis=1).astype(xp.uint32)


def unpack_u32_to_u8(packed: np.ndarray | jax.Array):
    """Inverse of :func:`pack_u8_to_u32` -> uint8 (4*M, ...)."""
    xp = jnp if isinstance(packed, jax.Array) else np
    m = packed.shape[0]
    out_shape = (m, PACK) + packed.shape[1:]
    shifts = xp.arange(PACK, dtype=xp.uint32) * 8
    shifts = shifts.reshape((1, PACK) + (1,) * (packed.ndim - 1))
    vals = (packed[:, None] >> shifts) & xp.uint32(0xFF)
    return vals.astype(xp.uint8).reshape((m * PACK,) + packed.shape[1:])


def unpack_u32_to_f32(packed: jax.Array, *, scale: float = 1.0 / 255.0,
                      shift: float = 0.0) -> jax.Array:
    """Decode + normalize in one op — the paper's "custom decode layer".

    This is the pure-jnp oracle for the Pallas kernel in
    ``repro.kernels.pack``; models use ``repro.kernels.pack.ops.decode``
    which dispatches between the two.
    """
    u8 = unpack_u32_to_u8(packed)
    return u8.astype(jnp.float32) * scale + shift


def compression_ratio(n_images: int, codec: str = "u32") -> float:
    """Host->device byte ratio vs sending raw float32 images (the paper's
    'saves up-to 16X memory and passage time' accounting)."""
    if codec == "u32":      # u32 container carries 4 u8 images vs 4 f32 images
        return 16.0         # 4 imgs * 4 B/px f32  ->  1 * 4 B/px u32
    if codec == "base256":  # f64 container, N imgs vs N f32 images
        return n_images * 4.0 / 8.0
    raise ValueError(codec)


# ---------------------------------------------------------------------------
# Selective-batch-sampling (SBS) — paper Algorithm 2.
# ---------------------------------------------------------------------------
def selective_batch_indices(
    labels: np.ndarray,
    class_weights: Mapping[int, float] | Sequence[float],
    batch_size: int,
    rng: np.random.Generator,
) -> np.ndarray:
    """Select ``batch_size`` example indices honouring per-class weights.

    ``W[i] * batch_size`` examples of class ``UC[i]`` per batch (Alg. 2).
    Rounding residue is assigned to the highest-weight classes.
    """
    labels = np.asarray(labels)
    classes = np.unique(labels)
    if not isinstance(class_weights, Mapping):
        class_weights = {int(c): float(w) for c, w in zip(classes, class_weights)}
    w = np.array([class_weights.get(int(c), 0.0) for c in classes], dtype=np.float64)
    if w.sum() <= 0:
        raise ValueError("class weights sum to zero")
    w = w / w.sum()
    counts = np.floor(w * batch_size).astype(int)
    # distribute the remainder by largest fractional part
    frac = w * batch_size - counts
    for i in np.argsort(-frac)[: batch_size - counts.sum()]:
        counts[i] += 1
    picks = []
    for c, k in zip(classes, counts):
        if k == 0:
            continue
        pool = np.flatnonzero(labels == c)
        picks.append(rng.choice(pool, size=k, replace=len(pool) < k))
    idx = np.concatenate(picks) if picks else np.empty((0,), np.int64)
    rng.shuffle(idx)
    return idx


def sbs_batches(
    labels: np.ndarray,
    class_weights,
    batch_size: int,
    num_batches: int,
    seed: int = 0,
    preprocess: Mapping[int, Callable[[np.ndarray], np.ndarray]] | None = None,
):
    """Yield (indices, class_fn_map) per batch; per-class augmentation hooks
    (MixUp/CutMix/AugMix slots in the paper) are applied by the loader."""
    rng = np.random.default_rng(seed)
    for _ in range(num_batches):
        yield selective_batch_indices(labels, class_weights, batch_size, rng), (
            preprocess or {}
        )
