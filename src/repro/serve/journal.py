"""Write-ahead request journal: the durable half of fleet serving.

PR 8's router survives a REPLICA kill because it mirrors every healthy
token in process memory — but that mirror dies with the router.  This
module is the replicated-request-log replacement the ROADMAP called
for: every fleet-visible request transition is appended (fsync'd) to a
JSONL journal BEFORE the in-memory state changes, so after a
whole-router ``kill -9`` the fleet can be rebuilt from disk and every
in-flight request replayed from its prompt + durably-logged tokens —
OpTorch's sequential-checkpoint principle (persist minimal state,
recompute the rest) applied to the serving control plane.

Record schema (one JSONL record per append, on ``repro.events``):

``wal_submit``   gid, prompt, max_new_tokens, eos_id, deadline_steps —
                 appended BEFORE placement, so a crash between append
                 and placement still recovers the request.
``wal_place``    gid, replica, rid, front, emitted — informational
                 (placement is rebuilt at recovery, not replayed).
``wal_tokens``   gid, start, toks — the per-step HEALTHY token deltas
                 (``tokens[start:start+len(toks)] = toks``; the start
                 index makes re-emission after a recovery idempotent).
``wal_migrate``  gid, reason — informational failover marker.
``wal_terminal`` gid, state, n_tokens — exactly one per submit; a
                 second terminal for the same gid is counted as a
                 ``duplicate_terminal`` and fails ``Router.reconcile``.

Durability contract: with ``fsync=True`` (the default) every append is
``os.fsync``'d, so a token the journal returned from ``tokens()`` is
never lost.  Tokens generated after the last durable record — the
fsync-lag window under ``flush_every > 1``, or the torn final record of
a crash — are NOT restored: recovery re-submits the request with the
durable prefix and the engine REGENERATES them (token-exact under
greedy decode, key-exact under ``sampler_keys="request"`` sampling).

Snapshot + compaction: ``snapshot()`` atomically writes ``path +
".snap"`` holding the reduced :class:`JournalState` (live requests +
terminal COUNTS — O(live), not O(history)) plus the byte offset it
covers.  Recovery (:func:`load_state`) loads the snapshot and tails
only the records after its offset via ``read_events(offset=)``, so
recovery cost is proportional to the live request set no matter how
long the journal has been running.  The journal file itself stays
append-only (crash-safe by construction); the snapshot is the
compaction.

``hooks["post_append"]`` is the crash-at-every-point seam: the fault
harness (``serve/faults.py``) installs a hook that raises
:class:`~repro.serve.faults.SimulatedCrash` after the N-th append —
AFTER the record hit disk, BEFORE the router acted on it — which is
exactly the "kill -9 between journal append and placement" window.
"""
from __future__ import annotations

import dataclasses
import json
import os
from typing import Callable, Optional

from repro.events import EventSink, read_events

#: journal record kinds (the ``kind`` field of each JSONL record)
WAL_KINDS = ("wal_submit", "wal_place", "wal_tokens", "wal_migrate",
             "wal_terminal")


@dataclasses.dataclass
class JournalState:
    """The reduction of a journal: what recovery needs, nothing more.

    ``live`` maps gid -> the request's durable record (prompt, budget,
    tokens so far); terminals are kept as COUNTS per state (plus the
    goodput token sum), so the state stays O(live requests) and a
    snapshot of it compacts arbitrarily long history."""
    next_gid: int = 0
    n_submits: int = 0
    n_terminals: int = 0
    duplicate_terminals: int = 0
    goodput_tokens: int = 0               # tokens of DONE requests
    terminal_counts: dict = dataclasses.field(default_factory=dict)
    live: dict = dataclasses.field(default_factory=dict)

    @property
    def n_live(self) -> int:
        return len(self.live)

    def apply(self, kind: str, rec: dict) -> None:
        """Fold one journal record into the state (the same reducer runs
        at append time and at recovery time, so the two can never
        disagree)."""
        gid = rec["gid"]
        if kind == "wal_submit":
            self.live[gid] = {
                "prompt": list(rec["prompt"]),
                "max_new_tokens": rec["max_new_tokens"],
                "eos_id": rec["eos_id"],
                "deadline_steps": rec["deadline_steps"],
                "tokens": [], "migrations": 0, "placements": 0,
            }
            self.n_submits += 1
            self.next_gid = max(self.next_gid, gid + 1)
        elif kind == "wal_place":
            r = self.live.get(gid)
            if r is not None:
                r["placements"] += 1
        elif kind == "wal_tokens":
            r = self.live.get(gid)
            if r is not None:
                start, toks = rec["start"], list(rec["toks"])
                # start-indexed splice: a re-emission after recovery
                # overwrites the regenerated overlap instead of
                # double-appending (the streams agree by determinism)
                r["tokens"] = r["tokens"][:start] + toks
        elif kind == "wal_migrate":
            r = self.live.get(gid)
            if r is not None:
                r["migrations"] += 1
        elif kind == "wal_terminal":
            r = self.live.pop(gid, None)
            if r is None:
                self.duplicate_terminals += 1
                return
            state = rec["state"]
            self.n_terminals += 1
            self.terminal_counts[state] = \
                self.terminal_counts.get(state, 0) + 1
            if state == "DONE":
                self.goodput_tokens += rec.get("n_tokens", 0)

    def to_json(self) -> dict:
        return {"next_gid": self.next_gid, "n_submits": self.n_submits,
                "n_terminals": self.n_terminals,
                "duplicate_terminals": self.duplicate_terminals,
                "goodput_tokens": self.goodput_tokens,
                "terminal_counts": dict(self.terminal_counts),
                "live": {str(g): r for g, r in self.live.items()}}

    @classmethod
    def from_json(cls, d: dict) -> "JournalState":
        return cls(next_gid=d["next_gid"], n_submits=d["n_submits"],
                   n_terminals=d["n_terminals"],
                   duplicate_terminals=d["duplicate_terminals"],
                   goodput_tokens=d["goodput_tokens"],
                   terminal_counts=dict(d["terminal_counts"]),
                   live={int(g): r for g, r in d["live"].items()})


def load_state(path: str) -> tuple[JournalState, int]:
    """Recover a journal's state from disk: snapshot (if any) + tail.

    Returns ``(state, next_offset)``.  Tolerates a torn final record
    (``read_events`` tail mode stops before it) and a missing/stale
    snapshot (falls back to a full-history scan — same reducer, same
    state, just O(history) instead of O(live))."""
    state, offset = JournalState(), 0
    snap = path + ".snap"
    if os.path.exists(snap):
        try:
            with open(snap) as f:
                d = json.load(f)
            state = JournalState.from_json(d["state"])
            offset = d["offset"]
        except (json.JSONDecodeError, KeyError):
            # half-written snapshot (crash mid-rename is impossible —
            # the write is atomic — but a hand-torn file is not): fall
            # back to the full scan
            state, offset = JournalState(), 0
    recs, end = read_events(path, offset=offset, with_offset=True)
    for rec in recs:
        if rec.get("kind") in WAL_KINDS:
            state.apply(rec["kind"], rec)
    return state, end


class RequestJournal:
    """Fsync'd write-ahead journal of fleet request transitions.

    Opening an existing journal REPLAYS it (snapshot + tail) into
    ``self.state`` and then appends — the restart path.  ``state`` is
    maintained incrementally on every append, so ``Router.reconcile``
    can cross-check the fleet table against the journal at any time
    without re-reading the file.
    """

    def __init__(self, path: str, *, fsync: bool = True,
                 flush_every: int = 1, snapshot_every: int = 0):
        if snapshot_every < 0:
            raise ValueError("RequestJournal: snapshot_every must be >= 0")
        self.path = path
        self.snapshot_every = snapshot_every
        self.state, _ = load_state(path) if os.path.exists(path) \
            else (JournalState(), 0)
        self._sink = EventSink(path, fsync=fsync, flush_every=flush_every)
        self.appends = 0
        self.snapshots = 0
        #: crash-at-every-point seam: fn(journal, kind, rec), called
        #: AFTER the record is durable and reduced into ``state``
        self.hooks: dict[str, Callable] = {}
        #: optional repro.obs Tracer: every append (and its group-commit
        #: fsync) becomes a ``journal_append`` span on the event stream,
        #: so WAL latency shows up in the same timeline as the requests
        #: paying for it
        self.tracer = None

    # -- append side -------------------------------------------------------
    def _append(self, kind: str, **fields) -> None:
        if self.tracer is not None:
            with self.tracer.span("journal_append", trace=fields.get("gid"),
                                  wal=kind):
                self._sink.emit(kind, **fields)
        else:
            self._sink.emit(kind, **fields)
        self.state.apply(kind, fields)
        self.appends += 1
        hook = self.hooks.get("post_append")
        if hook is not None:
            hook(self, kind, fields)
        if self.snapshot_every and self.appends % self.snapshot_every == 0:
            self.snapshot()

    def submit(self, gid: int, prompt, max_new_tokens: int,
               eos_id: Optional[int], deadline_steps: Optional[int]) -> None:
        self._append("wal_submit", gid=gid,
                     prompt=[int(t) for t in prompt],
                     max_new_tokens=int(max_new_tokens),
                     eos_id=None if eos_id is None else int(eos_id),
                     deadline_steps=(None if deadline_steps is None
                                     else int(deadline_steps)))

    def place(self, gid: int, replica: int, rid: int, *,
              front: bool, emitted: int) -> None:
        self._append("wal_place", gid=gid, replica=replica, rid=rid,
                     front=front, emitted=emitted)

    def tokens(self, gid: int, start: int, toks) -> None:
        self._append("wal_tokens", gid=gid, start=int(start),
                     toks=[int(t) for t in toks])

    def migrate(self, gid: int, reason: str) -> None:
        self._append("wal_migrate", gid=gid, reason=reason)

    def terminal(self, gid: int, state: str, n_tokens: int = 0) -> None:
        self._append("wal_terminal", gid=gid, state=state,
                     n_tokens=int(n_tokens))

    # -- compaction --------------------------------------------------------
    def snapshot(self) -> str:
        """Atomically write the compaction snapshot (state + covered
        offset) to ``path + ".snap"``.  Recovery after this point reads
        the snapshot plus only the journal tail."""
        sid = None if self.tracer is None else \
            self.tracer.begin("journal_snapshot", live=self.state.n_live)
        offset = self._sink.tell()
        tmp = self.path + ".snap.tmp"
        with open(tmp, "w") as f:
            json.dump({"offset": offset, "state": self.state.to_json()}, f)
            f.flush()
            os.fsync(f.fileno())
        os.replace(tmp, self.path + ".snap")
        self.snapshots += 1
        if self.tracer is not None:
            self.tracer.end(sid, offset=offset)
        return self.path + ".snap"

    def close(self) -> None:
        self._sink.close()

    def __enter__(self) -> "RequestJournal":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
