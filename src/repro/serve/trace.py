"""Synthetic request traces: seeded arrivals + length distributions.

The engine's unit of time is the engine STEP (one decode round): arrivals
land on step boundaries, which keeps traces deterministic and replayable
across machines — no wall-clock sleeps baked into a benchmark input.
Prompt and generation lengths draw from clipped geometric distributions
(the classic heavy-ish tail of chat traffic, cheap to reason about).
"""
from __future__ import annotations

import dataclasses

import numpy as np


@dataclasses.dataclass(frozen=True)
class TraceRequest:
    arrival_step: int
    prompt: np.ndarray            # (prompt_len,) int32
    max_new_tokens: int


def synthetic_trace(n_requests: int, *, seed: int = 0, vocab: int = 256,
                    mean_prompt: int = 24, max_prompt: int = 48,
                    mean_gen: int = 12, max_gen: int = 32,
                    arrival_rate: float = 0.5,
                    min_prompt: int = 4) -> list[TraceRequest]:
    """``arrival_rate`` is requests per engine step, capped at one
    arrival per step (Bernoulli thinning: inter-arrival gaps are
    geometric with mean ``1/arrival_rate`` steps, minimum 1; rates > 1
    clamp to 1).  The first request arrives at step 0.  Same seed, same
    trace."""
    if not (0 < arrival_rate):
        raise ValueError("synthetic_trace: arrival_rate must be > 0")
    rng = np.random.default_rng(seed)
    reqs: list[TraceRequest] = []
    step = 0
    for i in range(n_requests):
        if i:
            step += int(rng.geometric(min(1.0, arrival_rate)))
        p_len = int(np.clip(rng.geometric(1.0 / max(1, mean_prompt)),
                            min_prompt, max_prompt))
        g_len = int(np.clip(rng.geometric(1.0 / max(1, mean_gen)),
                            1, max_gen))
        prompt = rng.integers(0, vocab, (p_len,), dtype=np.int32)
        reqs.append(TraceRequest(arrival_step=step, prompt=prompt,
                                 max_new_tokens=g_len))
    return reqs
