"""Continuous-batching serve engine over the prefill/decode steps.

One engine step = (bounded) admissions + one decode round:

* admission: FCFS requests claim a pool slot, prefill at a static prompt
  BUCKET (padded; the bucket's suffix positions never contaminate the
  prefix under causal attention, so cache rows and the last-valid logit
  are token-exact vs an unpadded prefill), get scattered into the slot
  with one fused update, and sample their first token (TTFT);
* decode: ONE jitted step over the whole pool — every shape is static at
  ``(max_slots, max_len)``, occupancy lives purely in the per-slot
  ``pos`` lengths and the active mask, and the split-K decode kernel's
  length-aware early-outs make the padded tail of every slot cost ~no
  compute.  Joining and retiring requests therefore NEVER re-jits: after
  ``warmup()`` the program cache is frozen (asserted in tests via the
  jit cache counters).

Retirement (EOS or max-new-tokens) frees the slot back to the pool; the
row's stale bytes are simply never read again and are fully overwritten
by the next scatter.
"""
from __future__ import annotations

import functools
from typing import Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mixed_precision import get_policy
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.serve import sampling
from repro.serve.cache_pool import SlotPool, scatter_request
from repro.serve.metrics import ServeMetrics
from repro.serve.scheduler import DECODE, Request, Scheduler
from repro.serve.trace import TraceRequest


def default_buckets(max_len: int, lo: int = 16) -> tuple[int, ...]:
    """Power-of-two prompt buckets up to max_len (one compile each)."""
    out = []
    b = lo
    while b < max_len:
        out.append(b)
        b *= 2
    return tuple(out) or (max_len,)


def supports(cfg: ModelConfig) -> bool:
    """Engine eligibility: the slot-pooled per-row decode path needs the
    GQA kvq cache layout and a uniform window schedule."""
    return (cfg.mixer == "attn" and cfg.mla is None
            and cfg.encoder is None and not cfg.global_layers)


class ServeEngine:
    """Slot-pooled continuous-batching engine (see module docstring)."""

    def __init__(self, params, cfg: ModelConfig, *, max_slots: int,
                 max_len: int, prompt_buckets: Sequence[int] | None = None,
                 policy_name: str = "bf16", quantized: bool = True,
                 kv_backend: str = "ref", kv_splits: int = 1,
                 temperature: float = 0.0, top_k: int = 0, seed: int = 0,
                 eos_id: Optional[int] = None,
                 max_prefill_per_step: int = 1,
                 mem_budget_bytes: Optional[int] = None, mesh=None):
        if not supports(cfg):
            raise NotImplementedError(
                "ServeEngine needs a GQA attention arch with a uniform "
                "window schedule (no MLA latents, SSM state, encoder "
                "cross-attention, or per-layer global overrides) — those "
                "serve through the lockstep driver")
        self.cfg = cfg
        self.mesh = mesh
        self.max_len = max_len
        self.quantized = quantized
        self.eos_id = eos_id
        self.temperature, self.top_k = float(temperature), int(top_k)
        self.capacity_report = None
        if mem_budget_bytes is not None:
            from repro import plan as plan_mod
            # with a mesh the budget means bytes PER CHIP — the same
            # contract the training planner applies to --mem-budget-mb
            self.capacity_report = plan_mod.serve_capacity_report(
                cfg, max_len, mem_budget_bytes, quantized=quantized,
                mesh=mesh)
            cap = self.capacity_report["max_slots"]
            if cap < 1:
                raise ValueError(
                    f"ServeEngine: memory budget {mem_budget_bytes} admits "
                    f"0 slots at max_len={max_len} "
                    f"({self.capacity_report['bytes_per_slot_per_device']} "
                    f"B/slot/device)")
            max_slots = min(max_slots, cap)
        self.pool = SlotPool(cfg, max_slots, max_len, quantized=quantized,
                             mesh=mesh)
        if mesh is not None:
            from repro.distributed import sharding as shd
            p_specs = shd.param_specs(cfg, params, mesh=mesh)
            self._p_shard = shd.to_shardings(mesh, p_specs)
            params = jax.device_put(params, self._p_shard)
        self.params = params
        self.scheduler = Scheduler(
            max_slots, bytes_per_slot=self.pool.bytes_per_slot_per_device(),
            byte_budget=mem_budget_bytes,
            max_prefill_per_step=max_prefill_per_step)
        self.metrics = ServeMetrics()
        self.buckets = tuple(sorted(prompt_buckets
                                    if prompt_buckets is not None
                                    else default_buckets(max_len)))
        if self.buckets[-1] > max_len:
            raise ValueError(f"prompt bucket {self.buckets[-1]} exceeds "
                             f"max_len {max_len}")

        policy = get_policy(policy_name)

        def _decode(params, cache, tokens, active, key):
            # sampling is FUSED into the decode program: one dispatch per
            # engine step, and the token/active buffers never round-trip
            # through the host on the steady-state path
            logits, cache = transformer.decode_step(
                params, cfg, cache, tokens, policy=policy,
                quantized=quantized, kvq_backend=kv_backend,
                kvq_splits=kv_splits, active=active, mesh=mesh)
            sampled = sampling.sample_tokens(
                logits, key, temperature=self.temperature, top_k=self.top_k)
            return jnp.where(active, sampled, tokens), cache

        def _prefill(bucket, params, tokens, true_len):
            # mesh: _kv_entry pins each cache entry's sharding as it is
            # built, so the prefill scan carries the pool's layout from the
            # start instead of XLA re-sharding the finished cache
            logits, aux = transformer.forward(
                params, cfg, {"tokens": tokens}, policy=policy,
                build_cache=True, cache_quantized=quantized, mesh=mesh)
            # last VALID position, not bucket-1: padded suffix logits are
            # garbage by contract
            last = jax.lax.dynamic_index_in_dim(logits, true_len - 1, axis=1,
                                                keepdims=False)
            cache = transformer.grow_cache(aux["cache"], self.max_len)
            return last, cache

        def _join(tokens, active, slot, tok):
            return tokens.at[slot].set(tok), active.at[slot].set(True)

        def _leave(active, slot):
            return active.at[slot].set(False)

        # donate cache + tokens (both returned); active is reused across
        # steps and must NOT be donated
        self._rep = None
        if mesh is None:
            self._decode_fn = jax.jit(_decode, donate_argnums=(1, 2))
            self._scatter_fn = jax.jit(scatter_request, donate_argnums=(0,))
            self._prefill_fns = {
                b: jax.jit(functools.partial(_prefill, b))
                for b in self.buckets}
            self._join_fn = jax.jit(_join, donate_argnums=(0, 1))
            self._leave_fn = jax.jit(_leave, donate_argnums=(0,))
        else:
            # every program pins its shardings explicitly, so the cache's
            # placement is an INPUT contract, not an XLA choice — decode
            # and scatter are sharding-preserving end to end and nothing
            # on the steady-state path can re-gather the pool (asserted
            # against the compiled HLO via decode_hlo() in tests)
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.distributed import sharding as shd
            rep = NamedSharding(mesh, P())
            c_shard = shd.to_shardings(mesh, self.pool.specs)
            req_sds = jax.eval_shape(
                lambda: transformer.init_cache(cfg, 1, max_len,
                                               quantized=quantized))
            req_shard = shd.to_shardings(
                mesh, shd.serve_cache_specs(cfg, req_sds, mesh))
            self._decode_fn = jax.jit(
                _decode, donate_argnums=(1, 2),
                in_shardings=(self._p_shard, c_shard, rep, rep, rep),
                out_shardings=(rep, c_shard))
            self._scatter_fn = jax.jit(
                scatter_request, donate_argnums=(0,),
                in_shardings=(c_shard, req_shard, rep, rep),
                out_shardings=c_shard)
            self._prefill_fns = {
                b: jax.jit(functools.partial(_prefill, b),
                           in_shardings=(self._p_shard, rep, rep),
                           out_shardings=(rep, req_shard))
                for b in self.buckets}
            # join/leave must pin shardings too: an unspecified jit would
            # commit tokens/active to one device, and every downstream
            # program keyed on the committed layout would recompile
            self._join_fn = jax.jit(
                _join, donate_argnums=(0, 1),
                in_shardings=(rep, rep, rep, rep), out_shardings=(rep, rep))
            self._leave_fn = jax.jit(
                _leave, donate_argnums=(0,),
                in_shardings=(rep, rep), out_shardings=rep)
            self._rep = rep
        self._sampler = sampling.make_sampler(temperature=self.temperature,
                                              top_k=self.top_k)

        self._key = jax.random.PRNGKey(seed)
        self._draws = 0
        self._step_no = 0
        self._next_rid = 0
        self._slot_req: dict[int, Request] = {}
        self._requests_done: list[Request] = []
        self._tokens_dev = self._replicated(jnp.zeros((max_slots,), jnp.int32))
        self._active_dev = self._replicated(jnp.zeros((max_slots,), bool))
        self._active_buf = np.zeros((max_slots,), bool)    # host mirror

    # -- public API --------------------------------------------------------
    @property
    def step_no(self) -> int:
        return self._step_no

    def submit(self, prompt, max_new_tokens: int,
               eos_id: Optional[int] = None,
               arrival_step: Optional[int] = None) -> int:
        """Queue a request; returns its rid.  FCFS from here on."""
        prompt = np.asarray(prompt, np.int32)
        req = Request(rid=self._next_rid, prompt=prompt,
                      max_new_tokens=max_new_tokens,
                      arrival_step=(self._step_no if arrival_step is None
                                    else arrival_step),
                      eos_id=eos_id if eos_id is not None else self.eos_id)
        if req.prompt_len > self.buckets[-1]:
            raise ValueError(f"request {req.rid}: prompt_len "
                             f"{req.prompt_len} exceeds largest bucket "
                             f"{self.buckets[-1]}")
        if req.total_len() > self.max_len:
            raise ValueError(f"request {req.rid}: prompt+gen "
                             f"{req.total_len()} exceeds max_len "
                             f"{self.max_len}")
        self._next_rid += 1
        self.scheduler.submit(req)
        self.metrics.on_submit(req.rid, self._step_no)
        return req.rid

    def decode_hlo(self) -> str:
        """Compiled-HLO text of the decode round, at the live buffers'
        exact shapes/shardings — what tests grep to assert the KV cache
        is never all-gathered after warmup."""
        return self._decode_fn.lower(
            self.params, self.pool.cache, self._tokens_dev,
            self._active_dev, self._key).compile().as_text()

    def compile_counts(self) -> dict:
        """jit program-cache sizes — the zero-recompile contract's meter."""
        counts = {"decode": self._decode_fn._cache_size(),
                  "scatter": self._scatter_fn._cache_size(),
                  "join": self._join_fn._cache_size(),
                  "leave": self._leave_fn._cache_size(),
                  "sampler": self._sampler._cache_size()}
        for b, fn in self._prefill_fns.items():
            counts[f"prefill_{b}"] = fn._cache_size()
        return counts

    def warmup(self) -> dict:
        """Compile every program the engine can ever need, then reset all
        request state.  After this, joins/retirements are recompile-free
        (``compile_counts`` is frozen; tests assert it)."""
        for b, fn in self._prefill_fns.items():
            # compile each prompt-bucket program directly: the admission
            # path can't exercise a bucket b == max_len (prompt b plus one
            # generated token would exceed max_len), and a shorter probe
            # prompt could fall into an adjacent bucket instead
            jax.block_until_ready(
                fn(self.params, jnp.zeros((1, b), jnp.int32), jnp.int32(b)))
        if self.max_len >= 3:
            # one real request drives admission + one decode round, which
            # compiles decode/scatter/join/leave/sampler; eos_id=-1 (no
            # vocab token is negative) so an engine-level eos_id can't
            # retire the zeros probe at admission before decode compiles
            plen = min(self.buckets[0], self.max_len - 2)
            self.submit(np.zeros((plen,), np.int32), 2, eos_id=-1)
            guard = 8 * (self.max_len + len(self.buckets))
            for _ in range(guard):
                if not self.scheduler.has_work():
                    break
                self.step()
        assert not self.scheduler.has_work(), "warmup trace did not drain"
        self.reset()
        return self.compile_counts()

    def reset(self) -> None:
        """Drop all request state; keep the compiled programs."""
        assert self.scheduler.resident == 0 and not self.scheduler.has_work(), \
            "reset with in-flight requests"
        self.pool = SlotPool(self.cfg, self.pool.max_slots, self.max_len,
                             quantized=self.quantized, mesh=self.mesh)
        self.scheduler = Scheduler(
            self.pool.max_slots,
            bytes_per_slot=self.pool.bytes_per_slot_per_device(),
            byte_budget=self.scheduler.byte_budget,
            max_prefill_per_step=self.scheduler.max_prefill_per_step)
        self.metrics = ServeMetrics()
        self._draws = 0
        self._step_no = 0
        self._next_rid = 0
        self._slot_req.clear()
        self._requests_done.clear()
        self._tokens_dev = self._replicated(
            jnp.zeros((self.pool.max_slots,), jnp.int32))
        self._active_dev = self._replicated(
            jnp.zeros((self.pool.max_slots,), bool))
        self._active_buf[:] = False

    # -- engine internals --------------------------------------------------
    def _replicated(self, x):
        """Commit a host-built buffer to the mesh (replicated) so every
        program sees one consistent placement; no-op without a mesh."""
        return x if self._rep is None else jax.device_put(x, self._rep)

    def _bucket_for(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        raise ValueError(f"prompt_len {n} exceeds largest bucket")

    def _next_key(self):
        if self.temperature <= 0.0:
            return self._key              # greedy never consumes the key
        k = jax.random.fold_in(self._key, self._draws)
        self._draws += 1
        return k

    def _emit(self, req: Request, tok: int) -> None:
        """Record one sampled token; retire the request when finished."""
        req.tokens.append(tok)
        self.metrics.on_token(req.rid, self._step_no)
        hit_eos = req.eos_id is not None and tok == req.eos_id
        if hit_eos or len(req.tokens) >= req.max_new_tokens:
            self.scheduler.retire(req)
            self.metrics.on_done(req.rid)
            self.pool.free(req.slot)
            self._active_buf[req.slot] = False
            self._active_dev = self._leave_fn(self._active_dev,
                                              jnp.int32(req.slot))
            del self._slot_req[req.slot]
            self._requests_done.append(req)

    def step(self) -> None:
        """Admissions (bounded prefills) + one decode round."""
        admitted = self.scheduler.pop_admissible(self.pool.free_slots,
                                                 self._step_no)
        for req in admitted:
            slot = self.pool.alloc()
            assert slot is not None       # pop_admissible checked free_slots
            b = self._bucket_for(req.prompt_len)
            padded = np.zeros((1, b), np.int32)
            padded[0, :req.prompt_len] = req.prompt
            logits, req_cache = self._prefill_fns[b](
                self.params, jnp.asarray(padded), jnp.int32(req.prompt_len))
            self.pool.cache = self._scatter_fn(
                self.pool.cache, req_cache, jnp.int32(slot),
                jnp.int32(req.prompt_len))
            tok = int(np.asarray(self._sampler(logits, self._next_key()))[0])
            req.state = DECODE
            req.slot = slot
            self._slot_req[slot] = req
            self._tokens_dev, self._active_dev = self._join_fn(
                self._tokens_dev, self._active_dev, jnp.int32(slot),
                jnp.int32(tok))
            self._active_buf[slot] = True
            self._emit(req, tok)          # first token: the TTFT sample

        if self._active_buf.any():
            live = np.nonzero(self._active_buf)[0]      # snapshot pre-emit
            self._tokens_dev, self.pool.cache = self._decode_fn(
                self.params, self.pool.cache, self._tokens_dev,
                self._active_dev, self._next_key())
            toks = np.asarray(self._tokens_dev)
            for slot in live:
                self._emit(self._slot_req[int(slot)], int(toks[slot]))

        self.metrics.on_step(self._step_no, self.scheduler.queue_depth,
                             self.pool.occupancy)
        self._step_no += 1

    def run(self, trace: Sequence[TraceRequest], *,
            max_steps: Optional[int] = None) -> dict:
        """Drive a trace to completion; returns the metrics summary.

        Arrivals are step-indexed: a request is submitted once the engine
        reaches its ``arrival_step``; idle gaps (empty pool, nothing
        arrived) fast-forward instead of burning decode rounds.
        """
        pending = sorted(trace, key=lambda r: r.arrival_step)
        i = 0
        budget = max_steps if max_steps is not None else (
            sum(r.max_new_tokens + 2 for r in pending)
            + (pending[-1].arrival_step if pending else 0) + 16)
        while i < len(pending) or self.scheduler.has_work():
            while (i < len(pending)
                   and pending[i].arrival_step <= self._step_no):
                r = pending[i]
                self.submit(r.prompt, r.max_new_tokens)
                i += 1
            if not self.scheduler.has_work() and i < len(pending):
                self._step_no = pending[i].arrival_step   # fast-forward idle
                continue
            self.step()
            budget -= 1
            if budget < 0:
                raise RuntimeError("ServeEngine.run exceeded its step "
                                   "budget — scheduler stuck?")
        return self.metrics.summary(max_slots=self.pool.max_slots)
