"""Continuous-batching serve engine over the prefill/decode steps.

One engine step = deadline shedding + (bounded) admissions + one decode
round:

* admission: FCFS requests claim a pool slot, prefill at a static prompt
  BUCKET (padded; the bucket's suffix positions never contaminate the
  prefix under causal attention, so cache rows and the last-valid logit
  are token-exact vs an unpadded prefill), get scattered into the slot
  with one fused update, and sample their first token (TTFT);
* decode: ONE jitted step over the whole pool — every shape is static at
  ``(max_slots, max_len)``, occupancy lives purely in the per-slot
  ``pos`` lengths and the active mask, and the split-K decode kernel's
  length-aware early-outs make the padded tail of every slot cost ~no
  compute.  Joining and retiring requests therefore NEVER re-jits: after
  ``warmup()`` the program cache is frozen (asserted in tests via the
  jit cache counters).

Retirement (EOS or max-new-tokens) frees the slot back to the pool; the
row's stale bytes are simply never read again and are fully overwritten
by the next scatter.

Fault tolerance (ISSUE 7) — detect, degrade, recover:

* a health sentinel is FUSED into the jitted decode program: per slot,
  all-finite logits AND sampled-token-in-vocab AND a scattered prompt
  (``pos > 0``).  The verdict rides IN the fetched token value (a
  tripped slot yields -1; no vocab id is negative), so the steady-state
  path fetches the same single ``(max_slots,)`` int32 it always did —
  no extra host sync, no recompile (asserted via ``compile_counts``);
* a tripped sentinel quarantines the poisoned slot
  (``SlotPool.quarantine``), audits the pool's alloc/free invariant
  (``SlotPool.audit``), and releases the slot only after the audit
  passes — the next scatter fully overwrites the row;
* the victim request replays deterministically from its prompt plus the
  already-emitted (healthy) tokens: it re-enters the queue at the HEAD
  with a retry backoff, re-prefills over the extended prompt, and keeps
  generating.  A bounded per-request retry budget (``max_retries``)
  escalates persistent faults to ``FAILED``;
* per-request deadlines (queue TTL) shed stale queued requests to
  ``DROPPED``; a bounded queue rejects submits (``AdmissionRejected``);
  ``cancel`` and ``drain`` give callers explicit control; ``run`` on a
  stuck trace returns a partial summary flagged ``stalled`` instead of
  discarding every metric in a raise.

``hooks`` is the seam the fault-injection harness (``serve/faults.py``)
uses: optional host-side callables consulted around the jit boundaries
("pre_step", "pre_decode", "scatter_filter") — they never touch compiled
programs, so injection cannot recompile anything.
"""
from __future__ import annotations

import functools
from typing import Callable, Optional, Sequence

import jax
import jax.numpy as jnp
import numpy as np

from repro.core.mixed_precision import get_policy
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.serve import sampling
from repro.serve.cache_pool import SlotPool, scatter_request
from repro.serve.metrics import ServeMetrics
from repro.serve.scheduler import (CANCELLED, DECODE, FAILED, MIGRATED,
                                   QUEUED, TERMINAL, AdmissionRejected,
                                   Request, Scheduler)
from repro.serve.trace import TraceRequest


def default_buckets(max_len: int, lo: int = 16) -> tuple[int, ...]:
    """Power-of-two prompt buckets up to max_len (one compile each)."""
    out = []
    b = lo
    while b < max_len:
        out.append(b)
        b *= 2
    return tuple(out) or (max_len,)


def supports(cfg: ModelConfig) -> bool:
    """Engine eligibility: the slot-pooled per-row decode path needs the
    GQA kvq cache layout and a uniform window schedule."""
    return (cfg.mixer == "attn" and cfg.mla is None
            and cfg.encoder is None and not cfg.global_layers)


class ServeEngine:
    """Slot-pooled continuous-batching engine (see module docstring)."""

    def __init__(self, params, cfg: ModelConfig, *, max_slots: int,
                 max_len: int, prompt_buckets: Sequence[int] | None = None,
                 policy_name: str = "bf16", quantized: bool = True,
                 kv_backend: str = "ref", kv_splits: int = 1,
                 temperature: float = 0.0, top_k: int = 0, seed: int = 0,
                 eos_id: Optional[int] = None,
                 max_prefill_per_step: int = 1,
                 mem_budget_bytes: Optional[int] = None, mesh=None,
                 max_queue: Optional[int] = None,
                 deadline_steps: Optional[int] = None,
                 max_retries: int = 2, retry_backoff_steps: int = 1,
                 sampler_keys: str = "step", sink=None):
        if not supports(cfg):
            raise NotImplementedError(
                "ServeEngine needs a GQA attention arch with a uniform "
                "window schedule (no MLA latents, SSM state, encoder "
                "cross-attention, or per-layer global overrides) — those "
                "serve through the lockstep driver")
        if max_retries < 0 or retry_backoff_steps < 0:
            raise ValueError("ServeEngine: max_retries and "
                             "retry_backoff_steps must be >= 0")
        if sampler_keys not in ("step", "request"):
            raise ValueError(f"ServeEngine: sampler_keys must be 'step' or "
                             f"'request', got {sampler_keys!r}")
        # "step": one key per decode round, folded on a global draw
        # counter (the PR 5 behavior — deterministic for a fixed engine
        # but placement-dependent).  "request": every row samples with
        # fold_in(fold_in(base, key_id), draw) — token `draw` of request
        # `key_id` gets the same key on any replica/slot/step, which is
        # what makes fleet migration trajectory-preserving under
        # sampling (the router's mode).
        self.sampler_keys = sampler_keys
        self.cfg = cfg
        self.mesh = mesh
        self.max_len = max_len
        self.quantized = quantized
        self.eos_id = eos_id
        self.deadline_steps = deadline_steps
        self.max_retries = max_retries
        self.retry_backoff_steps = retry_backoff_steps
        self.temperature, self.top_k = float(temperature), int(top_k)
        #: host-side interception points around the jit boundaries (the
        #: fault-injection seam; see module docstring) — never compiled
        self.hooks: dict[str, Callable] = {}
        self._tracer = None               # repro.obs.Tracer via .tracer
        self.capacity_report = None
        if mem_budget_bytes is not None:
            from repro import plan as plan_mod
            # with a mesh the budget means bytes PER CHIP — the same
            # contract the training planner applies to --mem-budget-mb
            self.capacity_report = plan_mod.serve_capacity_report(
                cfg, max_len, mem_budget_bytes, quantized=quantized,
                mesh=mesh)
            cap = self.capacity_report["max_slots"]
            if cap < 1:
                raise ValueError(
                    f"ServeEngine: memory budget {mem_budget_bytes} admits "
                    f"0 slots at max_len={max_len} "
                    f"({self.capacity_report['bytes_per_slot_per_device']} "
                    f"B/slot/device)")
            max_slots = min(max_slots, cap)
        self.pool = SlotPool(cfg, max_slots, max_len, quantized=quantized,
                             mesh=mesh)
        if mesh is not None:
            from repro.distributed import sharding as shd
            p_specs = shd.param_specs(cfg, params, mesh=mesh)
            self._p_shard = shd.to_shardings(mesh, p_specs)
            params = jax.device_put(params, self._p_shard)
        self.params = params
        self.scheduler = Scheduler(
            max_slots, bytes_per_slot=self.pool.bytes_per_slot_per_device(),
            byte_budget=mem_budget_bytes,
            max_prefill_per_step=max_prefill_per_step, max_queue=max_queue)
        self.metrics = ServeMetrics(sink=sink)
        self.buckets = tuple(sorted(prompt_buckets
                                    if prompt_buckets is not None
                                    else default_buckets(max_len)))
        if self.buckets[-1] > max_len:
            raise ValueError(f"prompt bucket {self.buckets[-1]} exceeds "
                             f"max_len {max_len}")

        policy = get_policy(policy_name)
        self._key = jax.random.PRNGKey(seed)
        per_req = sampler_keys == "request"

        def _decode_logits(params, cache, tokens, active):
            # sampling is FUSED into the decode program: one dispatch per
            # engine step, and the token/active buffers never round-trip
            # through the host on the steady-state path
            pos_before = cache["pos"]
            logits, cache = transformer.decode_step(
                params, cfg, cache, tokens, policy=policy,
                quantized=quantized, kvq_backend=kv_backend,
                kvq_splits=kv_splits, active=active, mesh=mesh)
            return pos_before, logits, cache

        def _verdict(pos_before, logits, sampled, active, tokens):
            # health sentinel, fused into the same program: a live slot is
            # healthy iff its logits are all finite (the padded-vocab mask
            # is a finite -1e30 by design), its sampled token is a real
            # vocab id, and a prompt was actually scattered into the row
            # (pos > 0 pre-increment — a dropped scatter leaves 0).  The
            # verdict rides IN the token value: a tripped slot yields -1
            # (no vocab id is negative), so the steady-state path still
            # fetches exactly one (max_slots,) int32 — no second device
            # array, no extra host sync, no recompile.  A faulted slot's
            # -1 never feeds a real decode: the engine deactivates the
            # slot before its next step and re-joins it with a fresh
            # token.
            healthy = (jnp.isfinite(logits).all(axis=-1)
                       & (sampled >= 0) & (sampled < cfg.vocab)
                       & (pos_before > 0))
            return jnp.where(active & healthy, sampled,
                             jnp.where(active, jnp.int32(-1), tokens))

        def _decode(params, cache, tokens, active, key):
            pos_before, logits, cache = _decode_logits(params, cache,
                                                       tokens, active)
            sampled = sampling.sample_tokens(
                logits, key, temperature=self.temperature, top_k=self.top_k)
            return _verdict(pos_before, logits, sampled, active,
                            tokens), cache

        base_key = self._key

        def _decode_req(params, cache, tokens, active, kids, draws):
            # "request" key mode: each row folds its OWN key from the
            # request identity and per-request draw counter, both living
            # on device — the draw counter increments inside the same
            # program, so per-request keys add no host traffic
            pos_before, logits, cache = _decode_logits(params, cache,
                                                       tokens, active)
            keys = jax.vmap(sampling.fold_request_key,
                            in_axes=(None, 0, 0))(base_key, kids, draws)
            sampled = sampling.sample_tokens_per_row(
                logits, keys, temperature=self.temperature,
                top_k=self.top_k)
            new_draws = jnp.where(active, draws + 1, draws)
            return _verdict(pos_before, logits, sampled, active,
                            tokens), cache, new_draws

        def _prefill(bucket, params, tokens, true_len):
            # mesh: _kv_entry pins each cache entry's sharding as it is
            # built, so the prefill scan carries the pool's layout from the
            # start instead of XLA re-sharding the finished cache
            logits, aux = transformer.forward(
                params, cfg, {"tokens": tokens}, policy=policy,
                build_cache=True, cache_quantized=quantized, mesh=mesh)
            # last VALID position, not bucket-1: padded suffix logits are
            # garbage by contract
            last = jax.lax.dynamic_index_in_dim(logits, true_len - 1, axis=1,
                                                keepdims=False)
            cache = transformer.grow_cache(aux["cache"], self.max_len)
            return last, cache

        def _join(tokens, active, slot, tok):
            return tokens.at[slot].set(tok), active.at[slot].set(True)

        def _join_req(tokens, active, kids, draws, slot, tok, kid, draw0):
            # request-key mode also stamps the row's sampler identity and
            # its next draw index (len(emitted) + 1 at join time)
            return (tokens.at[slot].set(tok), active.at[slot].set(True),
                    kids.at[slot].set(kid), draws.at[slot].set(draw0))

        def _leave(active, slot):
            return active.at[slot].set(False)

        # donate cache + tokens (both returned); active is reused across
        # steps and must NOT be donated
        self._rep = None
        if mesh is None:
            if per_req:
                self._decode_fn = jax.jit(_decode_req,
                                          donate_argnums=(1, 2, 5))
                self._join_fn = jax.jit(_join_req,
                                        donate_argnums=(0, 1, 2, 3))
            else:
                self._decode_fn = jax.jit(_decode, donate_argnums=(1, 2))
                self._join_fn = jax.jit(_join, donate_argnums=(0, 1))
            self._scatter_fn = jax.jit(scatter_request, donate_argnums=(0,))
            self._prefill_fns = {
                b: jax.jit(functools.partial(_prefill, b))
                for b in self.buckets}
            self._leave_fn = jax.jit(_leave, donate_argnums=(0,))
        else:
            # every program pins its shardings explicitly, so the cache's
            # placement is an INPUT contract, not an XLA choice — decode
            # and scatter are sharding-preserving end to end and nothing
            # on the steady-state path can re-gather the pool (asserted
            # against the compiled HLO via decode_hlo() in tests)
            from jax.sharding import NamedSharding, PartitionSpec as P
            from repro.distributed import sharding as shd
            rep = NamedSharding(mesh, P())
            c_shard = shd.to_shardings(mesh, self.pool.specs)
            req_sds = jax.eval_shape(
                lambda: transformer.init_cache(cfg, 1, max_len,
                                               quantized=quantized))
            req_shard = shd.to_shardings(
                mesh, shd.serve_cache_specs(cfg, req_sds, mesh))
            if per_req:
                self._decode_fn = jax.jit(
                    _decode_req, donate_argnums=(1, 2, 5),
                    in_shardings=(self._p_shard, c_shard, rep, rep, rep,
                                  rep),
                    out_shardings=(rep, c_shard, rep))
            else:
                self._decode_fn = jax.jit(
                    _decode, donate_argnums=(1, 2),
                    in_shardings=(self._p_shard, c_shard, rep, rep, rep),
                    out_shardings=(rep, c_shard))
            self._scatter_fn = jax.jit(
                scatter_request, donate_argnums=(0,),
                in_shardings=(c_shard, req_shard, rep, rep),
                out_shardings=c_shard)
            self._prefill_fns = {
                b: jax.jit(functools.partial(_prefill, b),
                           in_shardings=(self._p_shard, rep, rep),
                           out_shardings=(rep, req_shard))
                for b in self.buckets}
            # join/leave must pin shardings too: an unspecified jit would
            # commit tokens/active to one device, and every downstream
            # program keyed on the committed layout would recompile
            if per_req:
                self._join_fn = jax.jit(
                    _join_req, donate_argnums=(0, 1, 2, 3),
                    in_shardings=(rep,) * 8,
                    out_shardings=(rep, rep, rep, rep))
            else:
                self._join_fn = jax.jit(
                    _join, donate_argnums=(0, 1),
                    in_shardings=(rep, rep, rep, rep),
                    out_shardings=(rep, rep))
            self._leave_fn = jax.jit(
                _leave, donate_argnums=(0,),
                in_shardings=(rep, rep), out_shardings=rep)
            self._rep = rep
        self._sampler = sampling.make_sampler(temperature=self.temperature,
                                              top_k=self.top_k)

        self._draws = 0
        self._step_no = 0
        self._next_rid = 0
        self._draining = False
        self._slot_req: dict[int, Request] = {}
        self._requests: dict[int, Request] = {}            # every rid ever
        self._requests_done: list[Request] = []
        self._tokens_dev = self._replicated(jnp.zeros((max_slots,), jnp.int32))
        self._active_dev = self._replicated(jnp.zeros((max_slots,), bool))
        self._kids_dev = self._replicated(jnp.zeros((max_slots,), jnp.int32))
        self._draws_dev = self._replicated(jnp.zeros((max_slots,), jnp.int32))
        self._active_buf = np.zeros((max_slots,), bool)    # host mirror

    # -- public API --------------------------------------------------------
    @property
    def step_no(self) -> int:
        return self._step_no

    @property
    def tracer(self):
        """repro.obs Tracer, or None (tracing off — the default).  All
        span emission is host-side and guarded on this being set, so the
        untraced path pays nothing and nothing traced runs inside jit.
        Attach AFTER ``warmup()`` (the warmup probe would otherwise leave
        a phantom rid-0 trace)."""
        return self._tracer

    @tracer.setter
    def tracer(self, t) -> None:
        self._tracer = t
        self.scheduler.tracer = t         # queue-wait spans live there

    def _end_req_span(self, req: Request, state: str) -> None:
        """Close a request's open decode + root spans at terminal time."""
        if self._tracer is not None:
            self._tracer.end(req.span_ids.pop("decode", None), state=state)
            self._tracer.end(req.span_ids.pop("req", None), state=state,
                             tokens=len(req.tokens))

    def submit(self, prompt, max_new_tokens: int,
               eos_id: Optional[int] = None,
               arrival_step: Optional[int] = None,
               deadline_steps: Optional[int] = None,
               front: bool = False, key_id: Optional[int] = None,
               emitted: Optional[Sequence[int]] = None) -> int:
        """Queue a request; returns its rid.  FCFS from here on.

        Raises :class:`AdmissionRejected` when the bounded queue is full
        (backpressure — the request never entered the system).
        ``deadline_steps`` is a queue TTL in engine steps (None falls
        back to the engine default): a request still queued past it is
        shed to ``DROPPED`` instead of waiting forever.  ``front`` joins
        at the queue HEAD (the router's migration path); ``key_id``
        overrides the sampler-key identity in ``sampler_keys="request"``
        mode (the router passes the fleet-global rid); ``emitted`` seeds
        the already-generated healthy tokens of a request migrating IN
        from another replica — admission then rides the engine's own
        replay path (prefill over prompt+emitted, first new draw index
        = len(emitted)), so the continuation is token-exact under greedy
        and key-exact in "request" mode."""
        prompt = np.asarray(prompt, np.int32)
        req = Request(rid=self._next_rid, prompt=prompt,
                      max_new_tokens=max_new_tokens,
                      arrival_step=(self._step_no if arrival_step is None
                                    else arrival_step),
                      eos_id=eos_id if eos_id is not None else self.eos_id,
                      deadline_steps=(deadline_steps
                                      if deadline_steps is not None
                                      else self.deadline_steps),
                      key_id=key_id)
        if emitted:
            if len(emitted) >= max_new_tokens:
                raise ValueError(f"request {req.rid}: emitted prefix "
                                 f"{len(emitted)} leaves no tokens to "
                                 f"generate (max_new_tokens "
                                 f"{max_new_tokens})")
            req.tokens = [int(t) for t in emitted]
        if req.prompt_len + len(req.tokens) > self.buckets[-1]:
            raise ValueError(f"request {req.rid}: prompt_len "
                             f"{req.prompt_len}+{len(req.tokens)} emitted "
                             f"exceeds largest bucket {self.buckets[-1]}")
        if req.total_len() > self.max_len:
            raise ValueError(f"request {req.rid}: prompt+gen "
                             f"{req.total_len()} exceeds max_len "
                             f"{self.max_len}")
        if self._tracer is not None:
            req.span_ids["req"] = self._tracer.begin(
                "req", trace=self._kid(req), rid=req.rid,
                prompt_len=req.prompt_len, max_new_tokens=max_new_tokens,
                replay=bool(emitted))
        try:
            self.scheduler.submit(req, front=front)
        except AdmissionRejected:
            self.metrics.on_reject()
            if self._tracer is not None:
                self._tracer.end(req.span_ids.pop("req", None),
                                 state="REJECTED", tokens=0)
            raise
        self._next_rid += 1
        self._requests[req.rid] = req
        self.metrics.on_submit(req.rid, self._step_no)
        return req.rid

    def evict_request(self, rid: int,
                      state: str = MIGRATED) -> Optional[Request]:
        """Remove a queued or resident request into a terminal state and
        return it (None if unknown or already terminal).  The router's
        migration path: the returned request's ``tokens`` are the
        healthy emitted prefix, which — prepended to the prompt — is the
        deterministic replay input on another replica.  A resident
        request's slot goes straight back to the pool (its cache bytes
        are dead by contract; the next scatter overwrites them)."""
        req = self._requests.get(rid)
        if req is None or req.state in TERMINAL:
            return None
        if req.state == QUEUED:
            self.scheduler.remove_queued(req, state)
        else:
            self.scheduler.retire(req, state=state)
            self._evict(req)
        self.metrics.on_terminal(rid, state)
        self._end_req_span(req, state)
        return req

    def cancel(self, rid: int) -> bool:
        """Cancel a queued or resident request.  Returns True if it was
        cancelled, False if unknown or already terminal (idempotent —
        cancelling a request that retired in the same step is a safe
        no-op)."""
        return self.evict_request(rid, CANCELLED) is not None

    def drain(self, *, cancel_queued: bool = True,
              max_steps: Optional[int] = None) -> dict:
        """Graceful shutdown: admit nothing new, let resident requests
        finish, and return the final summary.  Queued requests are
        cancelled by default (with ``cancel_queued=False`` they stay
        queued for a later ``run``/``step``)."""
        if cancel_queued:
            for req in list(self._requests.values()):
                if req.state == QUEUED:
                    self.cancel(req.rid)
        self._draining = True
        try:
            budget = max_steps if max_steps is not None else \
                8 * (self.max_len + 1) * max(1, self.scheduler.resident)
            while self.scheduler.resident > 0:
                self.step()
                budget -= 1
                if budget < 0:
                    return self.summary(stalled=True)
        finally:
            self._draining = False
        if cancel_queued:
            # a fault mid-drain can requeue a replay; it can't be admitted
            # while draining, so cancel it rather than strand it
            for req in list(self._requests.values()):
                if req.state == QUEUED:
                    self.cancel(req.rid)
        return self.summary()

    def decode_hlo(self) -> str:
        """Compiled-HLO text of the decode round, at the live buffers'
        exact shapes/shardings — what tests grep to assert the KV cache
        is never all-gathered after warmup."""
        if self.sampler_keys == "request":
            return self._decode_fn.lower(
                self.params, self.pool.cache, self._tokens_dev,
                self._active_dev, self._kids_dev,
                self._draws_dev).compile().as_text()
        return self._decode_fn.lower(
            self.params, self.pool.cache, self._tokens_dev,
            self._active_dev, self._key).compile().as_text()

    def compile_counts(self) -> dict:
        """jit program-cache sizes — the zero-recompile contract's meter."""
        counts = {"decode": self._decode_fn._cache_size(),
                  "scatter": self._scatter_fn._cache_size(),
                  "join": self._join_fn._cache_size(),
                  "leave": self._leave_fn._cache_size(),
                  "sampler": self._sampler._cache_size()}
        for b, fn in self._prefill_fns.items():
            counts[f"prefill_{b}"] = fn._cache_size()
        return counts

    def warmup(self) -> dict:
        """Compile every program the engine can ever need, then reset all
        request state.  After this, joins/retirements are recompile-free
        (``compile_counts`` is frozen; tests assert it)."""
        for b, fn in self._prefill_fns.items():
            # compile each prompt-bucket program directly: the admission
            # path can't exercise a bucket b == max_len (prompt b plus one
            # generated token would exceed max_len), and a shorter probe
            # prompt could fall into an adjacent bucket instead
            jax.block_until_ready(
                fn(self.params, jnp.zeros((1, b), jnp.int32), jnp.int32(b)))
        if self.max_len >= 3:
            # one real request drives admission + one decode round, which
            # compiles decode/scatter/join/leave/sampler; eos_id=-1 (no
            # vocab token is negative) so an engine-level eos_id can't
            # retire the zeros probe at admission before decode compiles
            plen = min(self.buckets[0], self.max_len - 2)
            self.submit(np.zeros((plen,), np.int32), 2, eos_id=-1)
            guard = 8 * (self.max_len + len(self.buckets))
            for _ in range(guard):
                if not self.scheduler.has_work():
                    break
                self.step()
        assert not self.scheduler.has_work(), "warmup trace did not drain"
        self.reset()
        return self.compile_counts()

    def reset(self) -> None:
        """Drop all request state; keep the compiled programs."""
        assert self.scheduler.resident == 0 and not self.scheduler.has_work(), \
            "reset with in-flight requests"
        self.pool = SlotPool(self.cfg, self.pool.max_slots, self.max_len,
                             quantized=self.quantized, mesh=self.mesh)
        self.scheduler = Scheduler(
            self.pool.max_slots,
            bytes_per_slot=self.pool.bytes_per_slot_per_device(),
            byte_budget=self.scheduler.byte_budget,
            max_prefill_per_step=self.scheduler.max_prefill_per_step,
            max_queue=self.scheduler.max_queue)
        self.scheduler.tracer = self._tracer
        self.metrics = ServeMetrics(sink=self.metrics.sink,
                                    replica=self.metrics.replica)
        self._draws = 0
        self._step_no = 0
        self._next_rid = 0
        self._draining = False
        self._slot_req.clear()
        self._requests.clear()
        self._requests_done.clear()
        self._tokens_dev = self._replicated(
            jnp.zeros((self.pool.max_slots,), jnp.int32))
        self._active_dev = self._replicated(
            jnp.zeros((self.pool.max_slots,), bool))
        self._kids_dev = self._replicated(
            jnp.zeros((self.pool.max_slots,), jnp.int32))
        self._draws_dev = self._replicated(
            jnp.zeros((self.pool.max_slots,), jnp.int32))
        self._active_buf[:] = False

    # -- engine internals --------------------------------------------------
    def _replicated(self, x):
        """Commit a host-built buffer to the mesh (replicated) so every
        program sees one consistent placement; no-op without a mesh."""
        return x if self._rep is None else jax.device_put(x, self._rep)

    def _bucket_for(self, n: int) -> int:
        for b in self.buckets:
            if n <= b:
                return b
        raise ValueError(f"prompt_len {n} exceeds largest bucket")

    def _next_key(self):
        if self.temperature <= 0.0:
            return self._key              # greedy never consumes the key
        k = jax.random.fold_in(self._key, self._draws)
        self._draws += 1
        return k

    def _kid(self, req: Request) -> int:
        """The request's sampler-key identity ("request" mode): the
        fleet-global id if the router set one, else the local rid."""
        return req.key_id if req.key_id is not None else req.rid

    def _first_key(self, req: Request):
        """PRNG key for a request's FIRST token after (re-)prefill.  In
        "request" mode it folds on the request identity and the emitted
        count — so a replay's first new token draws the same key it
        would have drawn on the original placement."""
        if self.sampler_keys != "request":
            return self._next_key()
        if self.temperature <= 0.0:
            return self._key              # greedy never consumes the key
        return sampling.fold_request_key(self._key, self._kid(req),
                                         len(req.tokens))

    def _evict(self, req: Request) -> None:
        """Release a resident request's slot + device state (terminal
        transitions and replays share this; the scheduler transition
        happens at the caller)."""
        self.pool.free(req.slot)
        self._active_buf[req.slot] = False
        self._active_dev = self._leave_fn(self._active_dev,
                                          jnp.int32(req.slot))
        del self._slot_req[req.slot]
        req.slot = None

    def _emit(self, req: Request, tok: int) -> None:
        """Record one sampled token; retire the request when finished."""
        req.tokens.append(tok)
        self.metrics.on_token(req.rid, self._step_no)
        hit_eos = req.eos_id is not None and tok == req.eos_id
        if hit_eos or len(req.tokens) >= req.max_new_tokens:
            self.scheduler.retire(req)
            self.metrics.on_done(req.rid)
            self._evict(req)
            self._requests_done.append(req)
            self._end_req_span(req, req.state)

    def _replay_prompt(self, req: Request) -> np.ndarray:
        """Prompt + already-emitted (healthy) tokens: the deterministic
        replay input.  Under greedy decode the continuation is
        token-exact; under sampling it is seeded-deterministic (same
        seed + same fault schedule -> same tokens)."""
        if not req.tokens:
            return req.prompt
        return np.concatenate([req.prompt,
                               np.asarray(req.tokens, np.int32)])

    def _fault(self, req: Request) -> None:
        """The decode sentinel tripped on ``req``'s slot: quarantine the
        poisoned row, audit the pool, then replay or fail the victim.

        The faulted step's sampled token is NEVER emitted — the client
        only ever sees healthy tokens, which is what makes the replay
        prefix exact."""
        slot = req.slot
        self.metrics.on_fault(req.rid)
        if self._tracer is not None:
            self._tracer.end(req.span_ids.pop("decode", None), state="FAULT",
                             fault=True)
        self.pool.quarantine(slot)
        self._active_buf[slot] = False
        self._active_dev = self._leave_fn(self._active_dev, jnp.int32(slot))
        del self._slot_req[slot]
        req.slot = None
        self.pool.audit()                 # alloc/free invariant still holds?
        self.pool.release_quarantined()   # row is dead; next scatter overwrites

        reason = None
        if req.retries >= self.max_retries:
            reason = (f"retry budget exhausted "
                      f"({req.retries}/{self.max_retries})")
        elif len(self._replay_prompt(req)) > self.buckets[-1]:
            reason = (f"replay prompt {len(self._replay_prompt(req))} "
                      f"exceeds largest bucket {self.buckets[-1]}")
        if reason is not None:
            self.scheduler.retire(req, state=FAILED)
            req.fail_reason = reason
            self.metrics.on_terminal(req.rid, FAILED)
            self._end_req_span(req, FAILED)
            return
        req.retries += 1
        # backoff: the replay waits retries * backoff steps at the head
        # of the line before re-prefilling
        self.scheduler.requeue(
            req, self._step_no + 1 + self.retry_backoff_steps * req.retries)
        self.metrics.on_retry(req.rid)

    def step(self) -> None:
        """Deadline shedding + admissions (bounded prefills) + one decode
        round with the fused health sentinel."""
        hook = self.hooks.get("pre_step")
        if hook is not None:
            hook(self)
        step_sid = None if self._tracer is None else \
            self._tracer.begin("step", step=self._step_no)
        for req in self.scheduler.shed_expired(self._step_no):
            self.metrics.on_terminal(req.rid, req.state)
            self._end_req_span(req, req.state)

        admitted = [] if self._draining else \
            self.scheduler.pop_admissible(self.pool.free_slots, self._step_no)
        scatter_ok = self.hooks.get("scatter_filter")
        for req in admitted:
            if self._tracer is not None:
                req.span_ids["prefill"] = self._tracer.begin(
                    "prefill", trace=self._kid(req),
                    parent=req.span_ids.get("req"))
            slot = self.pool.alloc()
            assert slot is not None       # pop_admissible checked free_slots
            prompt = self._replay_prompt(req)   # == req.prompt first time
            plen = len(prompt)
            b = self._bucket_for(plen)
            padded = np.zeros((1, b), np.int32)
            padded[0, :plen] = prompt
            logits, req_cache = self._prefill_fns[b](
                self.params, jnp.asarray(padded), jnp.int32(plen))
            if scatter_ok is None or scatter_ok(self, req, slot):
                self.pool.cache = self._scatter_fn(
                    self.pool.cache, req_cache, jnp.int32(slot),
                    jnp.int32(plen))
            tok = int(np.asarray(self._sampler(logits, self._first_key(req)))[0])
            req.state = DECODE
            req.slot = slot
            self._slot_req[slot] = req
            if self.sampler_keys == "request":
                # stamp identity + next draw index (the first token drew
                # at index len(tokens); join runs before _emit appends it)
                (self._tokens_dev, self._active_dev, self._kids_dev,
                 self._draws_dev) = self._join_fn(
                    self._tokens_dev, self._active_dev, self._kids_dev,
                    self._draws_dev, jnp.int32(slot), jnp.int32(tok),
                    jnp.int32(self._kid(req)),
                    jnp.int32(len(req.tokens) + 1))
            else:
                self._tokens_dev, self._active_dev = self._join_fn(
                    self._tokens_dev, self._active_dev, jnp.int32(slot),
                    jnp.int32(tok))
            self._active_buf[slot] = True
            if self._tracer is not None:
                # prefill closes at the first sampled token (the TTFT
                # edge); decode residency is its own span from here
                self._tracer.end(req.span_ids.pop("prefill", None),
                                 bucket=b, plen=plen, slot=int(slot))
            self._emit(req, tok)          # first token: the TTFT sample
            if self._tracer is not None and req.state == DECODE:
                req.span_ids["decode"] = self._tracer.begin(
                    "decode", trace=self._kid(req),
                    parent=req.span_ids.get("req"), slot=int(slot))

        if self._active_buf.any():
            hook = self.hooks.get("pre_decode")
            if hook is not None:
                hook(self)
            live = np.nonzero(self._active_buf)[0]      # snapshot pre-emit
            if self.sampler_keys == "request":
                (self._tokens_dev, self.pool.cache,
                 self._draws_dev) = self._decode_fn(
                    self.params, self.pool.cache, self._tokens_dev,
                    self._active_dev, self._kids_dev, self._draws_dev)
            else:
                self._tokens_dev, self.pool.cache = self._decode_fn(
                    self.params, self.pool.cache, self._tokens_dev,
                    self._active_dev, self._next_key())
            # one host sync, same as the fault-free path: the sentinel
            # verdict is encoded in the token sign (-1 = tripped)
            toks = np.asarray(self._tokens_dev)
            for slot in live:
                req = self._slot_req[int(slot)]
                if toks[slot] >= 0:
                    self._emit(req, int(toks[slot]))
                else:
                    self._fault(req)

        self.metrics.on_step(self._step_no, self.scheduler.queue_depth,
                             self.pool.occupancy)
        if self._tracer is not None:
            self._tracer.end(step_sid, admitted=len(admitted),
                             occupancy=self.pool.occupancy)
        self._step_no += 1

    def request_states(self) -> dict:
        """Light host-side view of every request: ``rid -> {state,
        tokens, slot}``.  The subprocess worker's harvest payload (the
        router's ``_harvest`` reads the same fields off in-process
        engines directly), and the WAL's token-delta source."""
        return {rid: {"state": r.state, "tokens": list(r.tokens),
                      "slot": r.slot}
                for rid, r in self._requests.items()}

    def summary(self, *, stalled: bool = False) -> dict:
        """Metrics summary + live scheduler/pool diagnostics.  Always
        complete — a stalled run flags ``stalled=True`` instead of
        throwing the metrics away."""
        out = self.metrics.summary(max_slots=self.pool.max_slots)
        out["stalled"] = stalled
        out["diagnostics"] = {
            "step_no": self._step_no,
            "queue_depth": self.scheduler.queue_depth,
            "resident": self.scheduler.resident,
            "state_counts": self.scheduler.state_counts(),
            "pool": {"occupancy": self.pool.occupancy,
                     "free": self.pool.free_slots,
                     "quarantined": self.pool.quarantined,
                     "allocs": self.pool.allocs, "frees": self.pool.frees,
                     "quarantines": self.pool.quarantines},
        }
        return out

    def run(self, trace: Sequence[TraceRequest], *,
            max_steps: Optional[int] = None) -> dict:
        """Drive a trace to completion; returns the metrics summary.

        Arrivals are step-indexed: a request is submitted once the engine
        reaches its ``arrival_step``; idle gaps (empty pool, nothing
        arrived) fast-forward instead of burning decode rounds.  Trace
        submits hitting a full bounded queue are REJECTED (counted in
        the summary), and a run that exceeds its step budget returns a
        partial summary flagged ``stalled`` with scheduler/pool
        diagnostics instead of raising away every metric.
        """
        pending = sorted(trace, key=lambda r: r.arrival_step)
        i = 0
        budget = max_steps if max_steps is not None else (
            sum((r.max_new_tokens + 2) * (self.max_retries + 1)
                for r in pending)
            + (pending[-1].arrival_step if pending else 0) + 16)
        while i < len(pending) or self.scheduler.has_work():
            while (i < len(pending)
                   and pending[i].arrival_step <= self._step_no):
                r = pending[i]
                try:
                    self.submit(r.prompt, r.max_new_tokens)
                except AdmissionRejected:
                    pass                  # backpressure: counted, shed
                i += 1
            if not self.scheduler.has_work() and i < len(pending):
                self._step_no = pending[i].arrival_step   # fast-forward idle
                continue
            self.step()
            budget -= 1
            if budget < 0:
                return self.summary(stalled=True)
        return self.summary()
