"""Request lifecycle + FCFS admission under a slot/byte budget.

States move strictly ``QUEUED -> PREFILL -> DECODE -> DONE``.  Admission
is first-come-first-served: a queued request joins only when (a) a pool
slot is free, (b) the byte budget admits one more resident slot, and
(c) the per-step prefill quota has room — the quota is the
prefill-vs-decode interleave knob: prefills are the expensive joins, so
capping them per engine step bounds the inter-token latency the resident
decodes pay while new requests stream in.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import numpy as np

QUEUED, PREFILL, DECODE, DONE = "QUEUED", "PREFILL", "DECODE", "DONE"


@dataclasses.dataclass
class Request:
    """One generation request moving through the engine."""
    rid: int
    prompt: np.ndarray                    # (prompt_len,) int32
    max_new_tokens: int
    arrival_step: int = 0                 # engine step at which it exists
    eos_id: Optional[int] = None          # per-request EOS override
    # -- engine-owned state -----------------------------------------------
    state: str = QUEUED
    slot: Optional[int] = None
    tokens: list[int] = dataclasses.field(default_factory=list)

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32)
        if self.prompt.ndim != 1 or self.prompt.size == 0:
            raise ValueError(f"Request {self.rid}: prompt must be a "
                             f"non-empty 1-D token array")
        if self.max_new_tokens < 1:
            raise ValueError(f"Request {self.rid}: max_new_tokens must be "
                             f">= 1, got {self.max_new_tokens}")

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    def total_len(self) -> int:
        """Worst-case resident length (prompt + full generation)."""
        return self.prompt_len + self.max_new_tokens


class Scheduler:
    """FCFS queue with slot/byte-budget admission.

    ``byte_budget``/``bytes_per_slot`` bound resident slots by memory (the
    planner's ``serve_capacity_report`` derives the same number ahead of
    time); ``max_prefill_per_step`` is the interleave quota.
    """

    def __init__(self, max_slots: int, *, bytes_per_slot: int = 0,
                 byte_budget: Optional[int] = None,
                 max_prefill_per_step: int = 1):
        if max_prefill_per_step < 1:
            raise ValueError("Scheduler: max_prefill_per_step must be >= 1")
        self.max_slots = max_slots
        self.bytes_per_slot = bytes_per_slot
        self.byte_budget = byte_budget
        self.max_prefill_per_step = max_prefill_per_step
        self._queue: deque[Request] = deque()
        self._resident = 0
        self.admitted = 0

    # ----------------------------------------------------------------------
    def submit(self, req: Request) -> None:
        if req.state != QUEUED:
            raise ValueError(f"Scheduler.submit: request {req.rid} is "
                             f"{req.state}, expected {QUEUED}")
        self._queue.append(req)

    @property
    def queue_depth(self) -> int:
        return len(self._queue)

    @property
    def resident(self) -> int:
        return self._resident

    def has_work(self) -> bool:
        return bool(self._queue) or self._resident > 0

    def _budget_admits(self) -> bool:
        if self.byte_budget is None or self.bytes_per_slot <= 0:
            return True
        return (self._resident + 1) * self.bytes_per_slot <= self.byte_budget

    def pop_admissible(self, free_slots: int, now_step: int) -> list[Request]:
        """FCFS head-of-line admission for this engine step.

        Strict FCFS: if the head request can't join (no slot, budget, not
        yet arrived), nothing behind it jumps the line — latency stays
        predictable and starvation-free.
        """
        out: list[Request] = []
        while (self._queue and free_slots > 0
               and len(out) < self.max_prefill_per_step
               and self._queue[0].arrival_step <= now_step
               and self._budget_admits()):
            req = self._queue.popleft()
            req.state = PREFILL
            self._resident += 1
            self.admitted += 1
            free_slots -= 1
            out.append(req)
        return out

    def retire(self, req: Request) -> None:
        if req.state not in (PREFILL, DECODE):
            raise ValueError(f"Scheduler.retire: request {req.rid} is "
                             f"{req.state}")
        req.state = DONE
        self._resident -= 1
        assert self._resident >= 0, "scheduler resident count underflow"
