"""Request lifecycle + FCFS admission under a slot/byte budget.

The full state machine (ISSUE 7 added the failure half):

    QUEUED -> PREFILL -> DECODE -> DONE
      |  \\                  |  \\
      |   +-> DROPPED       |   +-> CANCELLED
      +-> CANCELLED         +-> FAILED
                            |
                            +-> QUEUED   (replay after a detected fault)

``DONE``/``CANCELLED``/``DROPPED``/``FAILED`` are terminal.  Admission is
first-come-first-served: a queued request joins only when (a) a pool
slot is free, (b) the byte budget admits one more resident slot, and
(c) the per-step prefill quota has room — the quota is the
prefill-vs-decode interleave knob: prefills are the expensive joins, so
capping them per engine step bounds the inter-token latency the resident
decodes pay while new requests stream in.

Overload is handled explicitly instead of queueing forever:

* a bounded queue (``max_queue``) rejects submits with
  :class:`AdmissionRejected` — backpressure the caller can see;
* per-request deadlines (``deadline_steps``, a queue TTL in engine
  steps) shed expired queued requests to ``DROPPED`` — load shedding;
* ``cancel_queued`` / ``retire(state=CANCELLED)`` support caller-side
  cancellation, and ``requeue`` puts a faulted resident request back at
  the HEAD of the line for deterministic replay (it already waited its
  turn; the backoff rides its new ``arrival_step``).
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Optional

import numpy as np

QUEUED, PREFILL, DECODE, DONE = "QUEUED", "PREFILL", "DECODE", "DONE"
CANCELLED, DROPPED, FAILED = "CANCELLED", "DROPPED", "FAILED"
#: the request left THIS engine for another replica (fleet router); it
#: is terminal locally — the fleet-level request lives on elsewhere
MIGRATED = "MIGRATED"
#: states a request can never leave
TERMINAL = frozenset({DONE, CANCELLED, DROPPED, FAILED, MIGRATED})


class AdmissionRejected(RuntimeError):
    """Bounded-queue backpressure: the scheduler refused a submit."""


@dataclasses.dataclass
class Request:
    """One generation request moving through the engine."""
    rid: int
    prompt: np.ndarray                    # (prompt_len,) int32
    max_new_tokens: int
    arrival_step: int = 0                 # engine step at which it exists
    eos_id: Optional[int] = None          # per-request EOS override
    deadline_steps: Optional[int] = None  # queue TTL in engine steps
    # sampler-key identity: the PRNG stream this request draws from in
    # the engine's "request" key mode.  The fleet router passes the
    # GLOBAL request id here so a migrated request keeps sampling the
    # same trajectory on any replica; None falls back to the local rid.
    key_id: Optional[int] = None
    # -- engine-owned state -----------------------------------------------
    state: str = QUEUED
    slot: Optional[int] = None
    tokens: list[int] = dataclasses.field(default_factory=list)
    retries: int = 0                      # replay attempts consumed
    fail_reason: Optional[str] = None     # set on FAILED
    # open span ids by name ("req"/"queue"/"decode") when tracing is on
    span_ids: dict = dataclasses.field(default_factory=dict)

    def __post_init__(self):
        self.prompt = np.asarray(self.prompt, np.int32)
        if self.prompt.ndim != 1 or self.prompt.size == 0:
            raise ValueError(f"Request {self.rid}: prompt must be a "
                             f"non-empty 1-D token array")
        if self.max_new_tokens < 1:
            raise ValueError(f"Request {self.rid}: max_new_tokens must be "
                             f">= 1, got {self.max_new_tokens}")
        if self.deadline_steps is not None and self.deadline_steps < 0:
            raise ValueError(f"Request {self.rid}: deadline_steps must be "
                             f">= 0, got {self.deadline_steps}")

    @property
    def prompt_len(self) -> int:
        return int(self.prompt.shape[0])

    def total_len(self) -> int:
        """Worst-case resident length (prompt + full generation)."""
        return self.prompt_len + self.max_new_tokens


class Scheduler:
    """FCFS queue with slot/byte-budget admission and explicit overload.

    ``byte_budget``/``bytes_per_slot`` bound resident slots by memory (the
    planner's ``serve_capacity_report`` derives the same number ahead of
    time); ``max_prefill_per_step`` is the interleave quota;
    ``max_queue`` bounds the queue (None = unbounded, the pre-ISSUE-7
    behavior).
    """

    def __init__(self, max_slots: int, *, bytes_per_slot: int = 0,
                 byte_budget: Optional[int] = None,
                 max_prefill_per_step: int = 1,
                 max_queue: Optional[int] = None):
        if max_prefill_per_step < 1:
            raise ValueError("Scheduler: max_prefill_per_step must be >= 1")
        if max_queue is not None and max_queue < 1:
            raise ValueError("Scheduler: max_queue must be >= 1 (or None)")
        self.max_slots = max_slots
        self.bytes_per_slot = bytes_per_slot
        self.byte_budget = byte_budget
        self.max_prefill_per_step = max_prefill_per_step
        self.max_queue = max_queue
        self._queue: deque[Request] = deque()
        self._resident = 0
        self.admitted = 0
        self.rejected = 0
        self.terminal_counts = {DONE: 0, CANCELLED: 0, DROPPED: 0,
                                FAILED: 0, MIGRATED: 0}
        #: optional repro.obs Tracer; queue-wait spans are owned here
        #: because every QUEUED<->resident transition runs through the
        #: scheduler, so TTFT's queue segment can't drift from the real
        #: state machine
        self.tracer = None

    # -- queue-wait spans --------------------------------------------------
    @staticmethod
    def _tid(req: Request):
        # the trace id spans carry: the fleet gid when the router set one
        # (key_id), else the local rid — same rule as the sampler keys
        return req.key_id if req.key_id is not None else req.rid

    def _queue_begin(self, req: Request, reason: str) -> None:
        if self.tracer is not None:
            req.span_ids["queue"] = self.tracer.begin(
                "queue", trace=self._tid(req),
                parent=req.span_ids.get("req"), reason=reason)

    def _queue_end(self, req: Request, **attrs) -> None:
        if self.tracer is not None:
            self.tracer.end(req.span_ids.pop("queue", None), **attrs)

    # ----------------------------------------------------------------------
    def submit(self, req: Request, *, front: bool = False) -> None:
        """Queue a request; ``front=True`` joins at the HEAD of the line
        (the fleet router's migration path — the request already waited
        its FCFS turn on the replica it left)."""
        if req.state != QUEUED:
            raise ValueError(f"Scheduler.submit: request {req.rid} is "
                             f"{req.state}, expected {QUEUED}")
        if self.max_queue is not None and len(self._queue) >= self.max_queue:
            self.rejected += 1
            raise AdmissionRejected(
                f"Scheduler: queue full ({len(self._queue)}/{self.max_queue})"
                f" — request {req.rid} rejected (backpressure)")
        if front:
            self._queue.appendleft(req)
        else:
            self._queue.append(req)
        self._queue_begin(req, "replay" if front else "submit")

    @property
    def queue_depth(self) -> int:
        return len(self._queue)

    @property
    def resident(self) -> int:
        return self._resident

    def has_work(self) -> bool:
        return bool(self._queue) or self._resident > 0

    def _budget_admits(self) -> bool:
        if self.byte_budget is None or self.bytes_per_slot <= 0:
            return True
        return (self._resident + 1) * self.bytes_per_slot <= self.byte_budget

    def shed_expired(self, now_step: int) -> list[Request]:
        """Drop queued requests whose queue wait exceeded their deadline.

        The TTL counts from ``arrival_step`` — a replayed request's
        backoff resets it.  Expired requests are shed wherever they sit
        in the line (a dead head must not block live requests behind
        it).  Returns the shed requests, now ``DROPPED``.
        """
        shed: list[Request] = []
        keep: deque[Request] = deque()
        for req in self._queue:
            if (req.deadline_steps is not None
                    and now_step - req.arrival_step > req.deadline_steps):
                req.state = DROPPED
                self.terminal_counts[DROPPED] += 1
                self._queue_end(req, state=DROPPED)
                shed.append(req)
            else:
                keep.append(req)
        self._queue = keep
        return shed

    def remove_queued(self, req: Request, state: str = CANCELLED) -> None:
        """Remove a still-queued request from the line into a terminal
        state (``CANCELLED`` by default; the router uses ``MIGRATED``)."""
        if req.state != QUEUED:
            raise ValueError(f"Scheduler.remove_queued: request {req.rid} "
                             f"is {req.state}")
        if state not in TERMINAL:
            raise ValueError(f"Scheduler.remove_queued: {state} is not "
                             f"terminal")
        self._queue.remove(req)
        req.state = state
        self.terminal_counts[state] += 1
        self._queue_end(req, state=state)

    def cancel_queued(self, req: Request) -> None:
        """Remove a still-queued request from the line -> ``CANCELLED``."""
        self.remove_queued(req, CANCELLED)

    def pop_admissible(self, free_slots: int, now_step: int) -> list[Request]:
        """FCFS head-of-line admission for this engine step.

        Strict FCFS: if the head request can't join (no slot, budget, not
        yet arrived), nothing behind it jumps the line — latency stays
        predictable and starvation-free.  A replayed request backing off
        at the head blocks the line for its backoff window; that keeps
        replay deterministic and is documented in serve/README.md.
        """
        out: list[Request] = []
        while (self._queue and free_slots > 0
               and len(out) < self.max_prefill_per_step
               and self._queue[0].arrival_step <= now_step
               and self._budget_admits()):
            req = self._queue.popleft()
            req.state = PREFILL
            self._resident += 1
            self.admitted += 1
            free_slots -= 1
            self._queue_end(req, state=PREFILL)
            out.append(req)
        return out

    def requeue(self, req: Request, arrival_step: int) -> None:
        """Put a resident request back at the HEAD of the queue (replay
        path): it already waited its FCFS turn, so it does not go to the
        back; ``arrival_step`` carries the retry backoff."""
        if req.state not in (PREFILL, DECODE):
            raise ValueError(f"Scheduler.requeue: request {req.rid} is "
                             f"{req.state}")
        req.state = QUEUED
        req.arrival_step = arrival_step
        self._resident -= 1
        assert self._resident >= 0, "scheduler resident count underflow"
        self._queue.appendleft(req)
        self._queue_begin(req, "replay")

    def retire(self, req: Request, state: str = DONE) -> None:
        """Move a resident request to a terminal state (default DONE)."""
        if req.state not in (PREFILL, DECODE):
            raise ValueError(f"Scheduler.retire: request {req.rid} is "
                             f"{req.state}")
        if state not in TERMINAL:
            raise ValueError(f"Scheduler.retire: {state} is not terminal")
        req.state = state
        self.terminal_counts[state] += 1
        self._resident -= 1
        assert self._resident >= 0, "scheduler resident count underflow"

    def state_counts(self) -> dict:
        """Live + terminal request counts — the stall diagnostic."""
        return {QUEUED: len(self._queue), "RESIDENT": self._resident,
                **dict(self.terminal_counts), "REJECTED": self.rejected}
