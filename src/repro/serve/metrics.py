"""Serving observability: TTFT, inter-token latency, throughput, goodput,
queue depth, slot occupancy, and failure-path counters.

Latencies are wall-clock (``time.perf_counter``); scheduling quantities
(queue depth, occupancy) are sampled once per engine step, so their means
are per-step averages.  TTFT for a request counts from the moment the
engine first SEES it (submit) to its first sampled token — queueing delay
included, which is the honest serving number.

Throughput vs goodput: ``total_tokens``/``tokens_per_s`` count every
emitted token, including tokens from requests that were later cancelled,
dropped, or failed; ``goodput_tokens``/``goodput_tokens_per_s`` count
only tokens of requests that reached ``DONE`` — the number a client
actually got value from.  Under faults the gap between the two is the
cost of the failure paths.

With a ``sink`` (``repro.events.EventSink``) the failure-path counters
also stream to the append-only JSONL log as they happen — the long-run
metrics record PR 7 left open.  ``fleet_summary`` is the replica
aggregation the router uses: per-replica summaries roll up into fleet
goodput/throughput plus the failover-specific counters.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

from repro.serve.scheduler import CANCELLED, DONE, DROPPED, FAILED, MIGRATED


@dataclasses.dataclass
class _ReqStats:
    t_submit: float
    submit_step: int
    t_first: Optional[float] = None
    first_step: Optional[int] = None
    t_last: Optional[float] = None
    t_done: Optional[float] = None
    n_tokens: int = 0
    itl_sum: float = 0.0
    itl_n: int = 0
    terminal: Optional[str] = None        # DONE/CANCELLED/DROPPED/FAILED
    retries: int = 0
    faults: int = 0


def _mean(xs):
    xs = list(xs)
    return sum(xs) / len(xs) if xs else 0.0


def _percentile(xs, q):
    xs = sorted(xs)
    if not xs:
        return 0.0
    i = min(len(xs) - 1, max(0, int(round(q * (len(xs) - 1)))))
    return xs[i]


class ServeMetrics:
    """Per-request latency accounting + per-step gauges + fault counters."""

    def __init__(self, clock=time.perf_counter, *, sink=None,
                 replica: Optional[int] = None):
        self._clock = clock
        self._reqs: dict[int, _ReqStats] = {}
        self._gauges: list[tuple[int, int, int]] = []  # (step, queue, occ)
        self._t0: Optional[float] = None
        self._t_end: Optional[float] = None
        self.rejected = 0                  # bounded-queue backpressure
        self.faults = 0                    # decode sentinel trips
        self.retries = 0                   # replays scheduled
        self.tokens_emitted = 0            # running total (stall detector)
        self.sink = sink                   # optional EventSink (JSONL)
        self.replica = replica             # fleet: which replica emits

    def _event(self, kind: str, **fields) -> None:
        if self.sink is not None:
            if self.replica is not None:
                fields["replica"] = self.replica
            self.sink.emit(kind, **fields)

    def now(self) -> float:
        return self._clock()

    # -- request lifecycle -------------------------------------------------
    def on_submit(self, rid: int, step: int) -> None:
        t = self.now()
        if self._t0 is None:
            self._t0 = t
        self._reqs[rid] = _ReqStats(t_submit=t, submit_step=step)

    def on_token(self, rid: int, step: int) -> None:
        r = self._reqs[rid]
        t = self.now()
        if r.t_first is None:
            r.t_first, r.first_step = t, step
        elif r.t_last is not None:
            r.itl_sum += t - r.t_last
            r.itl_n += 1
        r.t_last = t
        r.n_tokens += 1
        self.tokens_emitted += 1
        self._t_end = t

    def on_done(self, rid: int) -> None:
        r = self._reqs[rid]
        r.t_done = self.now()
        r.terminal = DONE

    def on_terminal(self, rid: int, state: str) -> None:
        """A request left the system without finishing (CANCELLED /
        DROPPED / FAILED / MIGRATED)."""
        r = self._reqs[rid]
        r.t_done = self.now()
        r.terminal = state
        self._event("terminal", rid=rid, state=state, tokens=r.n_tokens)

    def on_reject(self) -> None:
        self.rejected += 1
        self._event("reject")

    def on_fault(self, rid: int) -> None:
        self.faults += 1
        self._reqs[rid].faults += 1
        self._event("fault", rid=rid)

    def on_retry(self, rid: int) -> None:
        self.retries += 1
        self._reqs[rid].retries += 1
        self._event("retry", rid=rid, attempt=self._reqs[rid].retries)

    # -- per-step gauges ---------------------------------------------------
    def on_step(self, step: int, queue_depth: int, occupancy: int) -> None:
        self._gauges.append((step, queue_depth, occupancy))

    # -- aggregation -------------------------------------------------------
    def summary(self, *, max_slots: int = 0) -> dict:
        done = [r for r in self._reqs.values() if r.terminal == DONE]
        ttfts = [r.t_first - r.t_submit for r in done if r.t_first is not None]
        ttft_steps = [r.first_step - r.submit_step for r in done
                      if r.first_step is not None]
        itls = [r.itl_sum / r.itl_n for r in done if r.itl_n]
        total_tokens = sum(r.n_tokens for r in self._reqs.values())
        goodput_tokens = sum(r.n_tokens for r in done)
        wall = ((self._t_end - self._t0)
                if self._t0 is not None and self._t_end is not None else 0.0)
        occ = [o for (_, _, o) in self._gauges]
        by_terminal = {s: sum(1 for r in self._reqs.values()
                              if r.terminal == s)
                       for s in (CANCELLED, DROPPED, FAILED, MIGRATED)}
        # a request migrated off this replica is judged at FLEET level —
        # it must not count against the local replay success rate
        retried = [r for r in self._reqs.values()
                   if r.retries and r.terminal != MIGRATED]
        out = {
            "n_requests": len(self._reqs),
            "n_done": len(done),
            "n_cancelled": by_terminal[CANCELLED],
            "n_dropped": by_terminal[DROPPED],
            "n_failed": by_terminal[FAILED],
            "n_migrated_out": by_terminal[MIGRATED],
            "n_rejected": self.rejected,
            "n_faults": self.faults,
            "n_retried": self.retries,
            # of the requests that needed at least one replay, how many
            # still finished — the replay path's success rate
            "retry_success_rate": (
                sum(1 for r in retried if r.terminal == DONE) / len(retried)
                if retried else 1.0),
            "total_tokens": total_tokens,
            "goodput_tokens": goodput_tokens,
            "wall_s": wall,
            "tokens_per_s": total_tokens / wall if wall > 0 else 0.0,
            "goodput_tokens_per_s": (goodput_tokens / wall
                                     if wall > 0 else 0.0),
            "ttft_mean_s": _mean(ttfts),
            "ttft_p50_s": _percentile(ttfts, 0.5),
            "ttft_p95_s": _percentile(ttfts, 0.95),
            "ttft_mean_steps": _mean(ttft_steps),
            "itl_mean_s": _mean(itls),
            "queue_depth_mean": _mean(q for (_, q, _) in self._gauges),
            "queue_depth_max": max((q for (_, q, _) in self._gauges),
                                   default=0),
            "occupancy_mean": _mean(occ),
            "occupancy_max": max(occ, default=0),
            "n_steps": len(self._gauges),
        }
        if max_slots:
            out["occupancy_frac"] = out["occupancy_mean"] / max_slots
        return out


def fleet_summary(replica_summaries: Sequence[dict]) -> dict:
    """Aggregate per-replica :meth:`ServeMetrics.summary` dicts into the
    fleet view the router builds on.

    Counts SUM (each locally-terminal request is terminal on exactly one
    replica; a migrated request is ``n_migrated_out`` on its source and
    live or terminal on its target, so fleet-level dedup happens in the
    router's own request table — this helper only rolls up the replica
    ledgers).  Rates re-derive from the summed tokens and the widest
    wall-clock span rather than averaging averages."""
    keys_sum = ("n_requests", "n_done", "n_cancelled", "n_dropped",
                "n_failed", "n_migrated_out", "n_rejected", "n_faults",
                "n_retried", "total_tokens", "goodput_tokens", "n_steps")
    out = {k: sum(s.get(k, 0) for s in replica_summaries) for k in keys_sum}
    wall = max((s.get("wall_s", 0.0) for s in replica_summaries),
               default=0.0)
    out["wall_s"] = wall
    out["tokens_per_s"] = out["total_tokens"] / wall if wall > 0 else 0.0
    out["goodput_tokens_per_s"] = (out["goodput_tokens"] / wall
                                   if wall > 0 else 0.0)
    out["per_replica"] = list(replica_summaries)
    return out
