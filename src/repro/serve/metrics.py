"""Serving observability: TTFT, inter-token latency, throughput, goodput,
queue depth, slot occupancy, and failure-path counters.

Latencies are wall-clock (``time.perf_counter``); scheduling quantities
(queue depth, occupancy) are sampled once per engine step, so their means
are per-step averages.  TTFT for a request counts from the moment the
engine first SEES it (submit) to its first sampled token — queueing delay
included, which is the honest serving number.

Throughput vs goodput: ``total_tokens``/``tokens_per_s`` count every
emitted token, including tokens from requests that were later cancelled,
dropped, or failed; ``goodput_tokens``/``goodput_tokens_per_s`` count
only tokens of requests that reached ``DONE`` — the number a client
actually got value from.  Under faults the gap between the two is the
cost of the failure paths.

Metric state is **O(live), not O(history)** (ISSUE 10): per-request
stats exist only while the request is in flight; at terminal time they
retire into a :class:`repro.obs.MetricsRegistry` — counters plus
bounded-memory streaming histograms — so a router that serves millions
of requests holds a fixed-size ledger.  Means stay exact (histograms
carry exact n/sum); ``ttft_p50_s``/``ttft_p95_s`` are streaming
log2-bucket quantiles.  The registry snapshot also crosses the worker
RPC boundary and merges fleet-wide (see ``serve/worker.py``).

With a ``sink`` (``repro.events.EventSink``) the failure-path counters
also stream to the append-only JSONL log as they happen — the long-run
metrics record PR 7 left open.  ``fleet_summary`` is the replica
aggregation the router uses: per-replica summaries roll up into fleet
goodput/throughput plus the failover-specific counters.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional, Sequence

from repro.obs.registry import MetricsRegistry
from repro.serve.scheduler import CANCELLED, DONE, DROPPED, FAILED, MIGRATED


@dataclasses.dataclass
class _ReqStats:
    t_submit: float
    submit_step: int
    t_first: Optional[float] = None
    first_step: Optional[int] = None
    t_last: Optional[float] = None
    n_tokens: int = 0
    itl_sum: float = 0.0
    itl_n: int = 0
    retries: int = 0
    faults: int = 0


def _mean(xs):
    xs = list(xs)
    return sum(xs) / len(xs) if xs else 0.0


_TERMINALS = (DONE, CANCELLED, DROPPED, FAILED, MIGRATED)


class ServeMetrics:
    """Per-request latency accounting + per-step gauges + fault counters.

    Live requests keep a small :class:`_ReqStats`; everything else lives
    in ``self.registry``.  The legacy counter attributes (``rejected``,
    ``faults``, ``retries``, ``tokens_emitted``) are read-only views of
    the registry so existing callers (the router's breaker, the stall
    detector, the tests) keep working unchanged.
    """

    def __init__(self, clock=time.perf_counter, *, sink=None,
                 replica: Optional[int] = None, registry=None):
        self._clock = clock
        self._live: dict[int, _ReqStats] = {}
        self._t0: Optional[float] = None
        self._t_end: Optional[float] = None
        self.registry = registry if registry is not None else \
            MetricsRegistry()
        self.sink = sink                   # optional EventSink (JSONL)
        self.replica = replica             # fleet: which replica emits

    # legacy counters, now registry-backed ---------------------------------
    @property
    def rejected(self) -> int:             # bounded-queue backpressure
        return self.registry.count("serve.rejected")

    @property
    def faults(self) -> int:               # decode sentinel trips
        return self.registry.count("serve.faults")

    @property
    def retries(self) -> int:              # replays scheduled
        return self.registry.count("serve.retries")

    @property
    def tokens_emitted(self) -> int:       # running total (stall detector)
        return self.registry.count("serve.tokens")

    def _event(self, kind: str, **fields) -> None:
        if self.sink is not None:
            if self.replica is not None:
                fields["replica"] = self.replica
            self.sink.emit(kind, **fields)

    def now(self) -> float:
        return self._clock()

    # -- request lifecycle -------------------------------------------------
    def on_submit(self, rid: int, step: int) -> None:
        t = self.now()
        if self._t0 is None:
            self._t0 = t
        self._live[rid] = _ReqStats(t_submit=t, submit_step=step)
        self.registry.inc("serve.submitted")

    def on_token(self, rid: int, step: int) -> None:
        r = self._live[rid]
        t = self.now()
        if r.t_first is None:
            r.t_first, r.first_step = t, step
        elif r.t_last is not None:
            r.itl_sum += t - r.t_last
            r.itl_n += 1
        r.t_last = t
        r.n_tokens += 1
        self.registry.inc("serve.tokens")
        self._t_end = t

    def _retire(self, rid: int, state: str) -> _ReqStats:
        """Fold a finished request's stats into the registry and free it."""
        r = self._live.pop(rid)
        reg = self.registry
        reg.inc(f"serve.terminal.{state}")
        if state == DONE:
            reg.inc("serve.goodput_tokens", r.n_tokens)
            if r.t_first is not None:
                reg.observe("serve.ttft_s", r.t_first - r.t_submit)
            if r.first_step is not None:
                reg.observe("serve.ttft_steps", r.first_step - r.submit_step)
            if r.itl_n:
                reg.observe("serve.itl_s", r.itl_sum / r.itl_n)
        # a request migrated off this replica is judged at FLEET level —
        # it must not count against the local replay success rate
        if r.retries and state != MIGRATED:
            reg.inc("serve.retired_retried")
            if state == DONE:
                reg.inc("serve.retired_retried_done")
        return r

    def on_done(self, rid: int) -> None:
        self._t_end = self.now()
        self._retire(rid, DONE)

    def on_terminal(self, rid: int, state: str) -> None:
        """A request left the system without finishing (CANCELLED /
        DROPPED / FAILED / MIGRATED)."""
        r = self._retire(rid, state)
        self._event("terminal", rid=rid, state=state, tokens=r.n_tokens)

    def on_reject(self) -> None:
        self.registry.inc("serve.rejected")
        self._event("reject")

    def on_fault(self, rid: int) -> None:
        self.registry.inc("serve.faults")
        self._live[rid].faults += 1
        self._event("fault", rid=rid)

    def on_retry(self, rid: int) -> None:
        self.registry.inc("serve.retries")
        self._live[rid].retries += 1
        self._event("retry", rid=rid, attempt=self._live[rid].retries)

    # -- per-step gauges ---------------------------------------------------
    def on_step(self, step: int, queue_depth: int, occupancy: int) -> None:
        self.registry.inc("serve.steps")
        self.registry.observe("serve.queue_depth", queue_depth)
        self.registry.observe("serve.occupancy", occupancy)

    def registry_snapshot(self) -> dict:
        return self.registry.snapshot()

    # -- aggregation -------------------------------------------------------
    def summary(self, *, max_slots: int = 0) -> dict:
        reg = self.registry
        count = reg.count
        n_done = count(f"serve.terminal.{DONE}")
        total_tokens = count("serve.tokens")
        goodput_tokens = count("serve.goodput_tokens")
        wall = ((self._t_end - self._t0)
                if self._t0 is not None and self._t_end is not None else 0.0)
        ttft = reg.histogram("serve.ttft_s")
        ttft_steps = reg.histogram("serve.ttft_steps")
        itl = reg.histogram("serve.itl_s")
        qd = reg.histogram("serve.queue_depth")
        occ = reg.histogram("serve.occupancy")
        # of the requests that needed at least one replay, how many still
        # finished — the replay path's success rate.  Still-live retried
        # requests count in the denominator (they haven't succeeded yet).
        n_retried_judged = count("serve.retired_retried") + \
            sum(1 for r in self._live.values() if r.retries)
        out = {
            "n_requests": count("serve.submitted"),
            "n_done": n_done,
            "n_cancelled": count(f"serve.terminal.{CANCELLED}"),
            "n_dropped": count(f"serve.terminal.{DROPPED}"),
            "n_failed": count(f"serve.terminal.{FAILED}"),
            "n_migrated_out": count(f"serve.terminal.{MIGRATED}"),
            "n_rejected": self.rejected,
            "n_faults": self.faults,
            "n_retried": self.retries,
            "retry_success_rate": (
                count("serve.retired_retried_done") / n_retried_judged
                if n_retried_judged else 1.0),
            "total_tokens": total_tokens,
            "goodput_tokens": goodput_tokens,
            "wall_s": wall,
            "tokens_per_s": total_tokens / wall if wall > 0 else 0.0,
            "goodput_tokens_per_s": (goodput_tokens / wall
                                     if wall > 0 else 0.0),
            "ttft_mean_s": ttft.mean,
            "ttft_p50_s": ttft.quantile(0.5),
            "ttft_p95_s": ttft.quantile(0.95),
            "ttft_mean_steps": ttft_steps.mean,
            "itl_mean_s": itl.mean,
            "queue_depth_mean": qd.mean,
            "queue_depth_max": int(qd.max) if qd.n else 0,
            "occupancy_mean": occ.mean,
            "occupancy_max": int(occ.max) if occ.n else 0,
            "n_steps": count("serve.steps"),
        }
        if max_slots:
            out["occupancy_frac"] = out["occupancy_mean"] / max_slots
        return out


def fleet_summary(replica_summaries: Sequence[dict]) -> dict:
    """Aggregate per-replica :meth:`ServeMetrics.summary` dicts into the
    fleet view the router builds on.

    Counts SUM (each locally-terminal request is terminal on exactly one
    replica; a migrated request is ``n_migrated_out`` on its source and
    live or terminal on its target, so fleet-level dedup happens in the
    router's own request table — this helper only rolls up the replica
    ledgers).  Rates re-derive from the summed tokens and the widest
    wall-clock span rather than averaging averages."""
    keys_sum = ("n_requests", "n_done", "n_cancelled", "n_dropped",
                "n_failed", "n_migrated_out", "n_rejected", "n_faults",
                "n_retried", "total_tokens", "goodput_tokens", "n_steps")
    out = {k: sum(s.get(k, 0) for s in replica_summaries) for k in keys_sum}
    wall = max((s.get("wall_s", 0.0) for s in replica_summaries),
               default=0.0)
    out["wall_s"] = wall
    out["tokens_per_s"] = out["total_tokens"] / wall if wall > 0 else 0.0
    out["goodput_tokens_per_s"] = (out["goodput_tokens"] / wall
                                   if wall > 0 else 0.0)
    out["per_replica"] = list(replica_summaries)
    return out
