"""Serving observability: TTFT, inter-token latency, throughput, goodput,
queue depth, slot occupancy, and failure-path counters.

Latencies are wall-clock (``time.perf_counter``); scheduling quantities
(queue depth, occupancy) are sampled once per engine step, so their means
are per-step averages.  TTFT for a request counts from the moment the
engine first SEES it (submit) to its first sampled token — queueing delay
included, which is the honest serving number.

Throughput vs goodput: ``total_tokens``/``tokens_per_s`` count every
emitted token, including tokens from requests that were later cancelled,
dropped, or failed; ``goodput_tokens``/``goodput_tokens_per_s`` count
only tokens of requests that reached ``DONE`` — the number a client
actually got value from.  Under faults the gap between the two is the
cost of the failure paths.
"""
from __future__ import annotations

import dataclasses
import time
from typing import Optional

from repro.serve.scheduler import CANCELLED, DONE, DROPPED, FAILED


@dataclasses.dataclass
class _ReqStats:
    t_submit: float
    submit_step: int
    t_first: Optional[float] = None
    first_step: Optional[int] = None
    t_last: Optional[float] = None
    t_done: Optional[float] = None
    n_tokens: int = 0
    itl_sum: float = 0.0
    itl_n: int = 0
    terminal: Optional[str] = None        # DONE/CANCELLED/DROPPED/FAILED
    retries: int = 0
    faults: int = 0


def _mean(xs):
    xs = list(xs)
    return sum(xs) / len(xs) if xs else 0.0


def _percentile(xs, q):
    xs = sorted(xs)
    if not xs:
        return 0.0
    i = min(len(xs) - 1, max(0, int(round(q * (len(xs) - 1)))))
    return xs[i]


class ServeMetrics:
    """Per-request latency accounting + per-step gauges + fault counters."""

    def __init__(self, clock=time.perf_counter):
        self._clock = clock
        self._reqs: dict[int, _ReqStats] = {}
        self._gauges: list[tuple[int, int, int]] = []  # (step, queue, occ)
        self._t0: Optional[float] = None
        self._t_end: Optional[float] = None
        self.rejected = 0                  # bounded-queue backpressure
        self.faults = 0                    # decode sentinel trips
        self.retries = 0                   # replays scheduled

    def now(self) -> float:
        return self._clock()

    # -- request lifecycle -------------------------------------------------
    def on_submit(self, rid: int, step: int) -> None:
        t = self.now()
        if self._t0 is None:
            self._t0 = t
        self._reqs[rid] = _ReqStats(t_submit=t, submit_step=step)

    def on_token(self, rid: int, step: int) -> None:
        r = self._reqs[rid]
        t = self.now()
        if r.t_first is None:
            r.t_first, r.first_step = t, step
        elif r.t_last is not None:
            r.itl_sum += t - r.t_last
            r.itl_n += 1
        r.t_last = t
        r.n_tokens += 1
        self._t_end = t

    def on_done(self, rid: int) -> None:
        r = self._reqs[rid]
        r.t_done = self.now()
        r.terminal = DONE

    def on_terminal(self, rid: int, state: str) -> None:
        """A request left the system without finishing (CANCELLED /
        DROPPED / FAILED)."""
        r = self._reqs[rid]
        r.t_done = self.now()
        r.terminal = state

    def on_reject(self) -> None:
        self.rejected += 1

    def on_fault(self, rid: int) -> None:
        self.faults += 1
        self._reqs[rid].faults += 1

    def on_retry(self, rid: int) -> None:
        self.retries += 1
        self._reqs[rid].retries += 1

    # -- per-step gauges ---------------------------------------------------
    def on_step(self, step: int, queue_depth: int, occupancy: int) -> None:
        self._gauges.append((step, queue_depth, occupancy))

    # -- aggregation -------------------------------------------------------
    def summary(self, *, max_slots: int = 0) -> dict:
        done = [r for r in self._reqs.values() if r.terminal == DONE]
        ttfts = [r.t_first - r.t_submit for r in done if r.t_first is not None]
        ttft_steps = [r.first_step - r.submit_step for r in done
                      if r.first_step is not None]
        itls = [r.itl_sum / r.itl_n for r in done if r.itl_n]
        total_tokens = sum(r.n_tokens for r in self._reqs.values())
        goodput_tokens = sum(r.n_tokens for r in done)
        wall = ((self._t_end - self._t0)
                if self._t0 is not None and self._t_end is not None else 0.0)
        occ = [o for (_, _, o) in self._gauges]
        by_terminal = {s: sum(1 for r in self._reqs.values()
                              if r.terminal == s)
                       for s in (CANCELLED, DROPPED, FAILED)}
        retried = [r for r in self._reqs.values() if r.retries]
        out = {
            "n_requests": len(self._reqs),
            "n_done": len(done),
            "n_cancelled": by_terminal[CANCELLED],
            "n_dropped": by_terminal[DROPPED],
            "n_failed": by_terminal[FAILED],
            "n_rejected": self.rejected,
            "n_faults": self.faults,
            "n_retried": self.retries,
            # of the requests that needed at least one replay, how many
            # still finished — the replay path's success rate
            "retry_success_rate": (
                sum(1 for r in retried if r.terminal == DONE) / len(retried)
                if retried else 1.0),
            "total_tokens": total_tokens,
            "goodput_tokens": goodput_tokens,
            "wall_s": wall,
            "tokens_per_s": total_tokens / wall if wall > 0 else 0.0,
            "goodput_tokens_per_s": (goodput_tokens / wall
                                     if wall > 0 else 0.0),
            "ttft_mean_s": _mean(ttfts),
            "ttft_p50_s": _percentile(ttfts, 0.5),
            "ttft_p95_s": _percentile(ttfts, 0.95),
            "ttft_mean_steps": _mean(ttft_steps),
            "itl_mean_s": _mean(itls),
            "queue_depth_mean": _mean(q for (_, q, _) in self._gauges),
            "queue_depth_max": max((q for (_, q, _) in self._gauges),
                                   default=0),
            "occupancy_mean": _mean(occ),
            "occupancy_max": max(occ, default=0),
            "n_steps": len(self._gauges),
        }
        if max_slots:
            out["occupancy_frac"] = out["occupancy_mean"] / max_slots
        return out
