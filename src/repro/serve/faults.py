"""Seeded fault injection for the serve engine — the proof harness for
the detect → quarantine → recover path.

A :class:`FaultPlan` is a deterministic schedule of :class:`FaultEvent`\\ s
keyed by engine step; a :class:`FaultInjector` installs itself into
``ServeEngine.hooks`` and fires the events as the engine crosses each
step.  Everything here is HOST-side: injection pokes the pool cache's
arrays between dispatches or filters a scatter call — it never wraps or
retraces a compiled program, so ``compile_counts()`` stays frozen under
injection (asserted in tests).

Fault kinds and what they exercise:

``nan_logits``
    NaN the victim slot's cache scale rows (or raw K/V rows on an
    unquantized pool) → the next decode's logits for that slot are NaN →
    the all-finite sentinel trips.  Per-slot attention means ONLY the
    poisoned slot trips; neighbors keep decoding.
``corrupt_row``
    Overwrite the rows with ``3.4e38`` → the attention matmul overflows
    to inf → non-finite logits.  Same detection path, different poison —
    models a corrupted (not merely NaN'd) cache row.
``drop_scatter``
    Suppress the admission-time ``scatter_request`` call via the
    ``scatter_filter`` hook → the slot's ``pos`` stays 0 → the
    sentinel's scattered-prompt check (``pos > 0``) trips on the first
    decode round.
``cancel``
    Call ``engine.cancel(rid)`` at the scheduled step (queued or
    resident) — cancellation storms.

Replica-scoped kinds (ISSUE 8) target a whole fleet member and are fired
by :class:`FleetFaultInjector` against a ``Router`` (a per-engine
:class:`FaultInjector` ignores them):

``replica_crash``
    ``router.kill(replica)`` — the replica dies mid-flight; its queued
    AND resident requests fail over to the survivors from the router's
    mirrored token log.
``replica_sick``
    Poison one resident slot's cache rows on the replica → its decode
    sentinel trips → the fault feeds the router's error-budget circuit
    breaker (HEALTHY → DEGRADED → QUARANTINED as faults accumulate).
``replica_slow``
    ``router.pause(replica, duration)`` — the replica stops making
    progress for ``duration`` router steps; the breaker's stall detector
    (resident > 0, zero tokens emitted) quarantines it if the pause
    outlasts ``stall_steps``.
``worker_sigkill``
    ``engine.terminate()`` on a subprocess replica
    (:class:`~repro.serve.worker.WorkerProxy`) — a REAL ``SIGKILL``
    fired WITHOUT telling the router (unlike ``replica_crash``, which
    is the router's own kill path).  The breaker has to notice on its
    own: the proxy's heartbeat stops, its counters freeze, the stall
    detector trips, and quarantine evacuates the victims.  Kept in
    ``WORKER_KINDS`` (not ``REPLICA_KINDS``) so :func:`chaos_plan`'s
    seeded draws over the default kind set are unchanged.

Crash-at-every-point harness (ISSUE 9), for the DURABLE serving plane:
:class:`SimulatedCrash` + :func:`crash_after_appends` arm the journal's
``post_append`` hook to kill the router at the N-th write-ahead append —
after the record hit disk, before the router acted on it (the
append-vs-placement window); :func:`tear_tail` truncates a journal
mid-final-record to model a crash mid-write.  Sweeping N over a seeded
subset of append indices is the "kill -9 at an arbitrary point" proof.

Recovery contract (what the tests assert): the quarantined slot passes a
pool audit and returns to the free list; the victim replays from prompt
+ already-emitted tokens, so a surviving request's final token stream is
exactly the fault-free greedy stream; drained pools show zero slot leaks
(``allocs == frees``, occupancy 0).
"""
from __future__ import annotations

import dataclasses
import os
from collections import Counter
from typing import Iterable, Optional

import jax.numpy as jnp
import numpy as np

KINDS = ("nan_logits", "corrupt_row", "drop_scatter", "cancel")
#: fleet-level kinds, fired by FleetFaultInjector at ROUTER steps
REPLICA_KINDS = ("replica_crash", "replica_sick", "replica_slow")
#: subprocess-worker kinds — separate tuple: appending to REPLICA_KINDS
#: would shift chaos_plan's seeded rng.randint(len(kinds)) draws
WORKER_KINDS = ("worker_sigkill",)


class SimulatedCrash(RuntimeError):
    """Raised by the crash harness to model ``kill -9``: the process is
    gone mid-operation, no cleanup runs, only the journal survives."""


@dataclasses.dataclass(frozen=True)
class FaultEvent:
    """One scheduled fault.  ``step`` is the engine step it fires at;
    the victim is named by ``rid`` (preferred — slots get recycled) or a
    raw ``slot``; ``drop_scatter`` with neither hits every admission at
    that step."""
    step: int
    kind: str
    rid: Optional[int] = None
    slot: Optional[int] = None
    replica: Optional[int] = None         # fleet kinds: which replica
    duration: Optional[int] = None        # replica_slow: pause length

    def __post_init__(self):
        known = KINDS + REPLICA_KINDS + WORKER_KINDS
        if self.kind not in known:
            raise ValueError(f"FaultEvent: unknown kind {self.kind!r} "
                             f"(expected one of {known})")
        if self.step < 0:
            raise ValueError("FaultEvent: step must be >= 0")
        if self.kind == "cancel" and self.rid is None:
            raise ValueError("FaultEvent: cancel needs a rid")
        if self.kind in REPLICA_KINDS + WORKER_KINDS \
                and self.replica is None:
            raise ValueError(f"FaultEvent: {self.kind} needs a replica")


class FaultPlan:
    """A deterministic, step-keyed schedule of faults.

    Build with the fluent helpers::

        plan = (FaultPlan()
                .nan_logits(step=4, rid=0)
                .corrupt_row(step=9, rid=2)
                .drop_scatter(step=2)
                .cancel(step=6, rid=3))
    """

    def __init__(self, events: Iterable[FaultEvent] = ()):
        self.events: list[FaultEvent] = list(events)

    def add(self, step: int, kind: str, *, rid: Optional[int] = None,
            slot: Optional[int] = None, replica: Optional[int] = None,
            duration: Optional[int] = None) -> "FaultPlan":
        self.events.append(FaultEvent(step=step, kind=kind, rid=rid,
                                      slot=slot, replica=replica,
                                      duration=duration))
        return self

    def nan_logits(self, step: int, *, rid: Optional[int] = None,
                   slot: Optional[int] = None) -> "FaultPlan":
        return self.add(step, "nan_logits", rid=rid, slot=slot)

    def corrupt_row(self, step: int, *, rid: Optional[int] = None,
                    slot: Optional[int] = None) -> "FaultPlan":
        return self.add(step, "corrupt_row", rid=rid, slot=slot)

    def drop_scatter(self, step: int,
                     rid: Optional[int] = None) -> "FaultPlan":
        return self.add(step, "drop_scatter", rid=rid)

    def cancel(self, step: int, rid: int) -> "FaultPlan":
        return self.add(step, "cancel", rid=rid)

    def replica_crash(self, step: int, replica: int) -> "FaultPlan":
        return self.add(step, "replica_crash", replica=replica)

    def replica_sick(self, step: int, replica: int, *,
                     rid: Optional[int] = None) -> "FaultPlan":
        return self.add(step, "replica_sick", replica=replica, rid=rid)

    def replica_slow(self, step: int, replica: int, *,
                     duration: int = 8) -> "FaultPlan":
        return self.add(step, "replica_slow", replica=replica,
                        duration=duration)

    def worker_sigkill(self, step: int, replica: int) -> "FaultPlan":
        return self.add(step, "worker_sigkill", replica=replica)

    def at(self, step: int, kind: Optional[str] = None) -> list[FaultEvent]:
        return [e for e in self.events
                if e.step == step and (kind is None or e.kind == kind)]

    def counts(self) -> Counter:
        return Counter(e.kind for e in self.events)

    def __len__(self) -> int:
        return len(self.events)


def poison_slot(engine, slot: int, value: float) -> None:
    """Overwrite one slot's cache rows host-side.  Shapes and dtypes
    are unchanged (``.at[].set`` on the existing leaves), so the
    donated-buffer decode program is reused as-is — injection cannot
    recompile anything."""
    cache = engine.pool.cache
    names = [n for n in ("k_scale", "v_scale") if n in cache]
    if not names:                           # unquantized pool: raw K/V rows
        names = [n for n in ("k", "v") if n in cache]
    for n in names:
        # every leaf is (L, B, ...) with the slot axis at B
        cache[n] = cache[n].at[:, slot].set(
            jnp.asarray(value, cache[n].dtype))


class FaultInjector:
    """Wires a :class:`FaultPlan` into an engine's host-side hooks.

    ``injected`` counts the faults that actually LANDED (a nan_logits
    aimed at a request that already finished lands nowhere), and
    ``victims`` records the rids hit by cache poison / dropped scatters —
    tests reconcile both against the engine summary.
    """

    def __init__(self, engine, plan: FaultPlan):
        self.engine = engine
        self.plan = plan
        self.injected: Counter = Counter()
        self.victims: set[int] = set()
        engine.hooks["pre_step"] = self._pre_step
        engine.hooks["pre_decode"] = self._pre_decode
        engine.hooks["scatter_filter"] = self._scatter_filter

    def uninstall(self) -> None:
        for name in ("pre_step", "pre_decode", "scatter_filter"):
            self.engine.hooks.pop(name, None)

    # -- hook bodies ---------------------------------------------------------
    def _pre_step(self, engine) -> None:
        for e in self.plan.at(engine.step_no, "cancel"):
            if engine.cancel(e.rid):
                self.injected["cancel"] += 1

    def _resolve_slot(self, e: FaultEvent) -> Optional[int]:
        """Victim slot for a cache-poison event, or None if it has no
        resident target right now (request finished / not yet admitted)."""
        if e.rid is not None:
            req = self.engine._requests.get(e.rid)
            return req.slot if req is not None else None
        if e.slot is not None and e.slot in self.engine._slot_req:
            return e.slot
        return None

    def _pre_decode(self, engine) -> None:
        for e in self.plan.at(engine.step_no, "nan_logits"):
            slot = self._resolve_slot(e)
            if slot is not None:
                poison_slot(engine, slot, float("nan"))
                self.injected["nan_logits"] += 1
                self.victims.add(engine._slot_req[slot].rid)
        for e in self.plan.at(engine.step_no, "corrupt_row"):
            slot = self._resolve_slot(e)
            if slot is not None:
                poison_slot(engine, slot, 3.4e38)
                self.injected["corrupt_row"] += 1
                self.victims.add(engine._slot_req[slot].rid)

    def _scatter_filter(self, engine, req, slot) -> bool:
        for e in self.plan.at(engine.step_no, "drop_scatter"):
            if e.rid is None or e.rid == req.rid:
                self.injected["drop_scatter"] += 1
                self.victims.add(req.rid)
                return False
        return True


class FleetFaultInjector:
    """Wires a :class:`FaultPlan`'s replica-scoped events into a
    ``Router``'s ``pre_step`` hook (events fire at ROUTER steps).

    ``injected`` counts events that landed; ``crashed``/``paused``/
    ``sickened`` record which replicas were hit — the chaos acceptance
    tests reconcile these against the fleet summary.
    """

    def __init__(self, router, plan: FaultPlan):
        self.router = router
        self.plan = plan
        self.injected: Counter = Counter()
        self.crashed: set[int] = set()
        self.sickened: set[int] = set()
        self.paused: set[int] = set()
        self.sigkilled: set[int] = set()
        router.hooks["pre_step"] = self._pre_step

    def uninstall(self) -> None:
        self.router.hooks.pop("pre_step", None)

    def _pre_step(self, router) -> None:
        step = router.step_no
        for e in self.plan.at(step, "replica_crash"):
            if router.kill(e.replica):
                self.injected["replica_crash"] += 1
                self.crashed.add(e.replica)
        for e in self.plan.at(step, "worker_sigkill"):
            # a REAL SIGKILL behind the router's back: only subprocess
            # replicas (WorkerProxy.terminate) can take one — the router
            # finds out through its own stall detector, not from us
            term = getattr(router.engines[e.replica], "terminate", None)
            if callable(term) and term():
                self.injected["worker_sigkill"] += 1
                self.sigkilled.add(e.replica)
        for e in self.plan.at(step, "replica_sick"):
            engine = router.engines[e.replica]
            if router.health[e.replica] == "DEAD":
                continue
            # poison one resident slot (rid-targeted if asked, else the
            # lowest live slot) — the replica's OWN sentinel detects it
            slot = None
            if hasattr(engine, "_slot_req"):          # in-process engine
                if e.rid is not None:
                    req = engine._requests.get(e.rid)
                    slot = req.slot if req is not None else None
                elif engine._slot_req:
                    slot = min(engine._slot_req)
                if slot is not None:
                    poison_slot(engine, slot, float("nan"))
            else:
                # subprocess replica: resolve the victim from the
                # proxy's request mirror and poison over the RPC — the
                # sentinel trips INSIDE the worker process
                views = getattr(engine, "_requests", {})
                if e.rid is not None:
                    v = views.get(e.rid)
                    slot = v.slot if v is not None else None
                else:
                    slots = [v.slot for v in views.values()
                             if v.slot is not None
                             and v.state not in ("DONE", "CANCELLED",
                                                 "DROPPED", "FAILED",
                                                 "MIGRATED")]
                    slot = min(slots) if slots else None
                if slot is not None and not engine.poison_slot(
                        slot, float("nan")):
                    slot = None
            if slot is not None:
                self.injected["replica_sick"] += 1
                self.sickened.add(e.replica)
        for e in self.plan.at(step, "replica_slow"):
            if router.pause(e.replica, e.duration or 8):
                self.injected["replica_slow"] += 1
                self.paused.add(e.replica)


def crash_after_appends(journal, n: int) -> dict:
    """Arm a :class:`SimulatedCrash` at the ``n``-th write-ahead append
    (1-indexed, counted from arming).

    The journal fires ``post_append`` AFTER the record is durable and
    reduced into its state, BEFORE the caller acts on it — so crashing
    there at a ``wal_submit`` is precisely the "kill -9 between journal
    append and placement" window.  The hook uninstalls itself when it
    fires (the process is "dead"; nothing else runs).  Returns a live
    counter dict: ``{"appends": seen, "fired": bool}``."""
    if n < 1:
        raise ValueError("crash_after_appends: n must be >= 1")
    state = {"appends": 0, "fired": False}

    def _hook(j, kind, rec):
        state["appends"] += 1
        if state["appends"] >= n:
            state["fired"] = True
            j.hooks.pop("post_append", None)
            raise SimulatedCrash(
                f"kill -9 after append {state['appends']} ({kind})")

    journal.hooks["post_append"] = _hook
    return state


def tear_tail(path: str, nbytes: Optional[int] = None) -> int:
    """Truncate a journal mid-final-record — the torn tail a crash
    leaves when it lands inside a write.  Cuts ``nbytes`` off the end
    (default: half the final record, at least 1 byte, keeping the
    record's leading bytes so the tail is INVALID JSON rather than
    merely absent).  Returns the new file size."""
    size = os.path.getsize(path)
    with open(path, "rb") as f:
        data = f.read()
    body = data[:-1] if data.endswith(b"\n") else data
    last_nl = body.rfind(b"\n")
    last_len = len(data) - (last_nl + 1)
    if nbytes is None:
        nbytes = max(1, last_len // 2)
    nbytes = min(nbytes, size)
    with open(path, "r+b") as f:
        f.truncate(size - nbytes)
    return size - nbytes


def chaos_plan(seed: int, *, steps: int, replicas: int,
               n_events: int = 4,
               kinds: tuple = REPLICA_KINDS) -> FaultPlan:
    """Seeded random replica-fault schedule: the chaos harness.  Same
    seed -> same plan, so a chaos run is exactly replayable."""
    rng = np.random.RandomState(seed)
    plan = FaultPlan()
    for _ in range(n_events):
        kind = kinds[int(rng.randint(len(kinds)))]
        step = int(rng.randint(1, max(2, steps)))
        replica = int(rng.randint(replicas))
        if kind == "replica_slow":
            plan.replica_slow(step, replica,
                              duration=int(rng.randint(2, 10)))
        else:
            plan.add(step, kind, replica=replica)
    return plan
