"""Process-isolated ServeEngine replicas behind a pipe RPC.

PR 8's fleet replicas share the router's process, so a "crash" was a
simulation (``Router.kill`` closing a ledger) and the breaker only ever
saw in-process state.  This module puts a replica in a REAL subprocess:

* the child (``python -m repro.serve.worker``) builds its own engine
  from an importable factory spec (a worker loads its own weights, the
  same way a real deployment replica would), warms it, and serves a
  small length-prefixed pickle RPC over stdin/stdout — submit / step /
  harvest / evict / cancel / drain / summary / poison / ping;
* the parent-side :class:`WorkerProxy` exposes the SAME replica surface
  the router consumes from in-process engines — ``submit`` /
  ``evict_request`` / ``step`` / ``summary`` / ``compile_counts``, a
  ``scheduler``/``pool``/``metrics`` view, and a ``_requests`` mirror
  refreshed from each step's harvest payload — so ``Router`` fronts a
  mixed fleet of engines and workers without knowing which is which;
* ``terminate()`` is an actual ``SIGKILL``.  After a kill (or any pipe
  EOF / RPC timeout — a missed heartbeat) the proxy marks itself dead:
  submits raise :class:`AdmissionRejected`, steps are no-ops, and the
  token counter freezes, so the router's stall detector sees a replica
  with resident work and no progress and the breaker quarantines it
  ACROSS the process boundary — evacuation then replays the victims
  from the router's journal/mirror on the survivors;
* every successful RPC reply doubles as a heartbeat
  (``heartbeat_age()``); the dead proxy's ledger is synthesized from
  the ``_requests`` mirror (evictions counted as ``MIGRATED``), and its
  pool reports zero leaks — the OS reclaimed the process, there is no
  slot left to leak.

Protocol frames are ``4-byte big-endian length + pickle`` over the
child's stdin/stdout; the child re-points ``sys.stdout`` at stderr
before anything else runs so library prints can never corrupt the
stream.  Pickle is fine here: both ends are the same trusted codebase
on one machine (prompts are numpy arrays — JSON would copy them
through lists on the hot path).
"""
from __future__ import annotations

import importlib
import os
import pickle
import select
import signal
import struct
import subprocess
import sys
import time
from typing import Optional

import numpy as np

from repro.serve.scheduler import (DECODE, MIGRATED, PREFILL, QUEUED,
                                   TERMINAL, AdmissionRejected)

_LEN = struct.Struct(">I")

#: exception types the RPC re-raises by name on the parent side
_RAISABLE = {"AdmissionRejected": AdmissionRejected,
             "ValueError": ValueError,
             "NotImplementedError": NotImplementedError}


class WorkerDied(RuntimeError):
    """The worker subprocess is gone (SIGKILL, EOF, or RPC timeout)."""


def engine_factory(arch: str = "llama3-8b", smoke: bool = True,
                   init_seed: int = 0, **engine_kwargs):
    """Default worker factory: build config + params + engine from
    scratch inside the child (a replica owns its own weights)."""
    import jax

    from repro import configs
    from repro.models import transformer
    from repro.serve.engine import ServeEngine
    cfg = configs.smoke_config(arch) if smoke else configs.get_config(arch)
    params = transformer.init_params(cfg, jax.random.PRNGKey(init_seed))
    return ServeEngine(params, cfg, **engine_kwargs)


# -- framing ----------------------------------------------------------------
def _write_frame(stream, obj) -> None:
    payload = pickle.dumps(obj, protocol=pickle.HIGHEST_PROTOCOL)
    stream.write(_LEN.pack(len(payload)) + payload)
    stream.flush()


def _read_exact_blocking(stream, n: int) -> bytes:
    buf = b""
    while len(buf) < n:
        chunk = stream.read(n - len(buf))
        if not chunk:
            raise EOFError("pipe closed")
        buf += chunk
    return buf


def _read_frame_blocking(stream):
    (n,) = _LEN.unpack(_read_exact_blocking(stream, _LEN.size))
    return pickle.loads(_read_exact_blocking(stream, n))


# -- child side -------------------------------------------------------------
def _snapshot(engine) -> dict:
    """The harvest payload: everything the proxy mirrors per step."""
    m, s, p = engine.metrics, engine.scheduler, engine.pool
    return {
        "step_no": engine.step_no,
        "requests": engine.request_states(),
        "metrics": {"faults": m.faults, "tokens_emitted": m.tokens_emitted,
                    "rejected": m.rejected, "retries": m.retries},
        # full obs registry (counters + streaming histograms): the proxy
        # keeps the latest copy so fleet metrics survive a SIGKILL
        "registry": m.registry_snapshot(),
        "sched": {"queue_depth": s.queue_depth, "resident": s.resident},
        "pool": {"free_slots": p.free_slots, "occupancy": p.occupancy,
                 "allocs": p.allocs, "frees": p.frees,
                 "quarantines": p.quarantines,
                 "quarantined": p.quarantined},
    }


def _dispatch(engine, op: str, msg: dict):
    if op == "submit":
        rid = engine.submit(msg["prompt"], msg["max_new_tokens"],
                            eos_id=msg.get("eos_id"),
                            deadline_steps=msg.get("deadline_steps"),
                            front=msg.get("front", False),
                            key_id=msg.get("key_id"),
                            emitted=msg.get("emitted"))
        return {"rid": rid, "snap": _snapshot(engine)}
    if op == "step":
        if engine.scheduler.has_work():
            engine.step()
        return _snapshot(engine)
    if op == "harvest":
        return _snapshot(engine)
    if op == "evict":
        req = engine.evict_request(msg["rid"], msg["state"])
        out = None if req is None else {"state": req.state,
                                        "tokens": list(req.tokens)}
        return {"req": out, "snap": _snapshot(engine)}
    if op == "cancel":
        ok = engine.cancel(msg["rid"])
        return {"ok": ok, "snap": _snapshot(engine)}
    if op == "drain":
        summary = engine.drain(
            cancel_queued=msg.get("cancel_queued", True),
            max_steps=msg.get("max_steps"))
        return {"summary": summary, "snap": _snapshot(engine)}
    if op == "summary":
        return engine.summary(stalled=msg.get("stalled", False))
    if op == "compile_counts":
        return engine.compile_counts()
    if op == "reset":
        engine.reset()
        return _snapshot(engine)
    if op == "poison":
        from repro.serve.faults import poison_slot
        poison_slot(engine, msg["slot"], msg["value"])
        return True
    if op == "ping":
        return {"t": time.time(), "step_no": engine.step_no}
    raise ValueError(f"worker: unknown op {op!r}")


def _serve(engine, inp, out) -> None:
    while True:
        try:
            msg = _read_frame_blocking(inp)
        except EOFError:
            return                         # parent went away: exit quietly
        op = msg.get("op")
        if op == "shutdown":
            _write_frame(out, {"ok": True, "result": None})
            return
        try:
            result = _dispatch(engine, op, msg)
            _write_frame(out, {"ok": True, "result": result})
        except Exception as e:             # errors cross the pipe by name
            _write_frame(out, {"ok": False, "error": type(e).__name__,
                               "msg": str(e)})


def main() -> int:
    out = sys.stdout.buffer
    sys.stdout = sys.stderr    # protocol owns the real stdout; prints -> err
    inp = sys.stdin.buffer
    hello = _read_frame_blocking(inp)
    try:
        mod, _, fn = hello["factory"].partition(":")
        factory = getattr(importlib.import_module(mod), fn)
        engine = factory(**hello.get("kwargs", {}))
        counts = engine.warmup() if hello.get("warmup", True) \
            else engine.compile_counts()
        _write_frame(out, {"ok": True, "result": {
            "pid": os.getpid(),
            "temperature": engine.temperature,
            "sampler_keys": engine.sampler_keys,
            "eos_id": engine.eos_id,
            "max_len": engine.max_len,
            "buckets": tuple(engine.buckets),
            "max_slots": engine.pool.max_slots,
            "max_queue": engine.scheduler.max_queue,
            "compile_counts": counts,
        }})
    except Exception as e:
        _write_frame(out, {"ok": False, "error": type(e).__name__,
                           "msg": str(e)})
        return 1
    _serve(engine, inp, out)
    return 0


# -- parent side ------------------------------------------------------------
class _SchedView:
    """Mirror of the worker scheduler's router-facing numbers."""

    def __init__(self, max_queue: Optional[int]):
        self.queue_depth = 0
        self.resident = 0
        self.max_queue = max_queue

    def has_work(self) -> bool:
        return self.queue_depth > 0 or self.resident > 0


class _PoolView:
    """Mirror of the worker pool's counters.  ``close_dead()`` zeroes
    the residency: the process is gone, so by definition no slot of its
    pool is still held (the OS reclaimed it) — the fleet-level leak
    check then only measures the survivors."""

    def __init__(self, max_slots: int):
        self.max_slots = max_slots
        self.free_slots = max_slots
        self.occupancy = 0
        self.allocs = 0
        self.frees = 0
        self.quarantines = 0
        self.quarantined = 0

    def update(self, d: dict) -> None:
        for k, v in d.items():
            setattr(self, k, v)

    def close_dead(self) -> None:
        self.frees = self.allocs
        self.occupancy = 0
        self.quarantined = 0
        self.free_slots = self.max_slots

    def audit(self) -> bool:
        return True


class _MetricsView:
    """Mirror of the worker metrics the router's breaker reads.  The
    counters freeze at death — which is exactly what the stall detector
    needs to see."""

    def __init__(self):
        self.replica: Optional[int] = None
        self.faults = 0
        self.tokens_emitted = 0
        self.rejected = 0
        self.retries = 0
        # last absorbed registry snapshot; persists after death so the
        # dead replica's histograms still merge into the fleet view
        self._registry_snap: Optional[dict] = None

    def registry_snapshot(self) -> dict:
        if self._registry_snap is None:
            return {"counters": {}, "gauges": {}, "hists": {}}
        return self._registry_snap


class _ReqView:
    """Mirror of one worker-side request (state + healthy tokens)."""

    __slots__ = ("rid", "state", "tokens", "slot")

    def __init__(self, rid: int, state: str, tokens, slot=None):
        self.rid = rid
        self.state = state
        self.tokens = list(tokens)
        self.slot = slot


class WorkerProxy:
    """Router-facing handle to one subprocess replica.

    Construct N proxies back to back, then ``wait_ready()`` each — the
    children build and warm their engines concurrently.  Or use
    :func:`spawn_worker` for the one-shot path.
    """

    def __init__(self, factory: str = "repro.serve.worker:engine_factory",
                 kwargs: Optional[dict] = None, *, warmup: bool = True,
                 rpc_timeout_s: float = 120.0,
                 spawn_timeout_s: float = 600.0):
        self.rpc_timeout_s = rpc_timeout_s
        self.spawn_timeout_s = spawn_timeout_s
        self.alive = False
        self.death_reason: Optional[str] = None
        self.pid: Optional[int] = None
        self._ready = False
        self._requests: dict[int, _ReqView] = {}
        self._dead_evictions = 0
        self._m_steps = 0
        self._compile_counts: Optional[dict] = None
        self._last_beat = time.monotonic()
        self.metrics = _MetricsView()
        self.scheduler = _SchedView(max_queue=None)
        #: optional repro.obs Tracer: each RPC round-trip becomes an
        #: ``rpc`` span, so cross-process overhead shows on the timeline
        self.tracer = None

        env = dict(os.environ)
        src = os.path.dirname(os.path.dirname(
            os.path.abspath(__import__("repro").__file__)))
        env["PYTHONPATH"] = src + (os.pathsep + env["PYTHONPATH"]
                                   if env.get("PYTHONPATH") else "")
        # -c (not -m): the package __init__ imports this module, and
        # runpy would warn about executing an already-imported module
        self._proc = subprocess.Popen(
            [sys.executable, "-c",
             "from repro.serve.worker import main; "
             "raise SystemExit(main())"],
            stdin=subprocess.PIPE, stdout=subprocess.PIPE, env=env)
        _write_frame(self._proc.stdin,
                     {"factory": factory, "kwargs": kwargs or {},
                      "warmup": warmup})

    # -- lifecycle ---------------------------------------------------------
    def wait_ready(self) -> "WorkerProxy":
        """Block until the child finished building + warming its engine
        (the hello reply), then adopt its static attributes."""
        if self._ready:
            return self
        reply = self._read_frame(self.spawn_timeout_s)
        if not reply.get("ok"):
            self._mark_dead(f"spawn failed: {reply.get('msg')}")
            raise WorkerDied(f"worker failed to start: {reply.get('msg')}")
        h = reply["result"]
        self.pid = h["pid"]
        self.temperature = h["temperature"]
        self.sampler_keys = h["sampler_keys"]
        self.eos_id = h["eos_id"]
        self.max_len = h["max_len"]
        self.buckets = tuple(h["buckets"])
        self.scheduler.max_queue = h["max_queue"]
        self.pool = _PoolView(h["max_slots"])
        self._compile_counts = dict(h["compile_counts"])
        self.alive = True
        self._ready = True
        self._last_beat = time.monotonic()
        return self

    def heartbeat_age(self) -> float:
        """Seconds since the worker last answered an RPC — the stall
        signal the breaker reads across the process boundary."""
        return time.monotonic() - self._last_beat

    def terminate(self) -> bool:
        """SIGKILL the worker — ``Router.kill`` on a subprocess replica
        is a real kill, not a simulation.  Returns False if already
        dead."""
        if not self.alive:
            return False
        self._mark_dead("SIGKILL")
        return True

    def shutdown(self) -> None:
        """Graceful exit: ask the child to stop, then reap it."""
        if self.alive:
            try:
                _write_frame(self._proc.stdin, {"op": "shutdown"})
                self._read_frame(self.rpc_timeout_s)
            except (OSError, EOFError, TimeoutError):
                pass
            self.alive = False
            self.death_reason = "shutdown"
        self._reap()

    def _reap(self) -> None:
        if self._proc.poll() is None:
            try:
                self._proc.kill()
            except OSError:
                pass
        try:
            self._proc.wait(timeout=10)
        except subprocess.TimeoutExpired:
            pass

    def _mark_dead(self, reason: str) -> None:
        if self.alive or self.death_reason is None:
            self.death_reason = reason
        self.alive = False
        if self._proc.poll() is None:
            try:
                os.kill(self._proc.pid, signal.SIGKILL)
            except OSError:
                pass
        try:
            self._proc.wait(timeout=10)
        except subprocess.TimeoutExpired:
            pass

    # -- framing with timeout ----------------------------------------------
    def _read_frame(self, timeout: float):
        def read_exact(n: int) -> bytes:
            buf = b""
            deadline = time.monotonic() + timeout
            fd = self._proc.stdout
            while len(buf) < n:
                left = deadline - time.monotonic()
                if left <= 0:
                    raise TimeoutError(f"worker RPC timed out ({timeout}s)")
                r, _, _ = select.select([fd], [], [], left)
                if not r:
                    continue
                chunk = os.read(fd.fileno(), n - len(buf))
                if not chunk:
                    raise EOFError("worker pipe closed")
                buf += chunk
            return buf

        (n,) = _LEN.unpack(read_exact(_LEN.size))
        return pickle.loads(read_exact(n))

    def _rpc(self, op: str, **kw):
        """One request/reply round.  Any transport failure (EOF after a
        SIGKILL, a hung child) marks the proxy dead and returns None —
        the router then sees frozen counters, not an exception."""
        if not self.alive:
            return None
        sid = None if self.tracer is None else \
            self.tracer.begin("rpc", op=op)
        try:
            _write_frame(self._proc.stdin, {"op": op, **kw})
            reply = self._read_frame(self.rpc_timeout_s)
        except (OSError, EOFError, TimeoutError) as e:
            self._mark_dead(f"{type(e).__name__} during {op!r}")
            if self.tracer is not None:
                self.tracer.end(sid, ok=False, error=type(e).__name__)
            return None
        if self.tracer is not None:
            self.tracer.end(sid, ok=bool(reply.get("ok")))
        self._last_beat = time.monotonic()
        if not reply.get("ok"):
            exc = _RAISABLE.get(reply.get("error"), RuntimeError)
            raise exc(reply.get("msg"))
        return reply["result"]

    # -- mirrors -----------------------------------------------------------
    def _absorb(self, snap: Optional[dict]) -> None:
        if snap is None:
            return
        for rid, d in snap["requests"].items():
            self._requests[rid] = _ReqView(rid, d["state"], d["tokens"],
                                           d["slot"])
        m = snap["metrics"]
        self.metrics.faults = m["faults"]
        self.metrics.tokens_emitted = m["tokens_emitted"]
        self.metrics.rejected = m["rejected"]
        self.metrics.retries = m["retries"]
        if snap.get("registry") is not None:
            self.metrics._registry_snap = snap["registry"]
        self.scheduler.queue_depth = snap["sched"]["queue_depth"]
        self.scheduler.resident = snap["sched"]["resident"]
        self.pool.update(snap["pool"])

    def _mirror_summary(self) -> dict:
        """Ledger synthesized from the mirror once the worker is dead —
        the 'close the dead ledger' path ``Router.reconcile`` sums."""
        reqs = list(self._requests.values())
        by = {s: sum(1 for r in reqs if r.state == s)
              for s in ("DONE", "CANCELLED", "DROPPED", "FAILED",
                        "MIGRATED")}
        done_tokens = sum(len(r.tokens) for r in reqs if r.state == "DONE")
        return {
            "n_requests": len(reqs), "n_done": by["DONE"],
            "n_cancelled": by["CANCELLED"], "n_dropped": by["DROPPED"],
            "n_failed": by["FAILED"], "n_migrated_out": by["MIGRATED"],
            "n_rejected": self.metrics.rejected,
            "n_faults": self.metrics.faults,
            "n_retried": self.metrics.retries,
            "retry_success_rate": 1.0,
            "total_tokens": sum(len(r.tokens) for r in reqs),
            "goodput_tokens": done_tokens,
            "wall_s": 0.0, "tokens_per_s": 0.0,
            "goodput_tokens_per_s": 0.0, "n_steps": self._m_steps,
            "dead": True, "death_reason": self.death_reason,
        }

    # -- the replica surface the Router consumes ---------------------------
    def submit(self, prompt, max_new_tokens: int, eos_id=None,
               arrival_step=None, deadline_steps=None, front: bool = False,
               key_id=None, emitted=None) -> int:
        if not self.alive:
            raise AdmissionRejected(
                f"worker {self.pid} is dead ({self.death_reason})")
        res = self._rpc("submit", prompt=np.asarray(prompt, np.int32),
                        max_new_tokens=max_new_tokens, eos_id=eos_id,
                        deadline_steps=deadline_steps, front=front,
                        key_id=key_id,
                        emitted=None if emitted is None else
                        [int(t) for t in emitted])
        if res is None:                    # died mid-submit
            raise AdmissionRejected(
                f"worker {self.pid} died during submit")
        self._absorb(res["snap"])
        rid = res["rid"]
        if rid not in self._requests:      # snapshot races are impossible
            self._requests[rid] = _ReqView(rid, QUEUED,
                                           emitted or [], None)
        return rid

    def step(self) -> None:
        snap = self._rpc("step")
        if snap is not None:
            self._m_steps += 1
            self._absorb(snap)

    def evict_request(self, rid: int, state: str = MIGRATED):
        mirror = self._requests.get(rid)
        if not self.alive:
            # dead path: close the ledger from the mirror — a real
            # deployment cannot read a dead process's memory, so the
            # healthy-token source of truth is the caller's journal;
            # the mirror is the same stream (it only ever held
            # harvested healthy tokens)
            if mirror is None or mirror.state in TERMINAL:
                return None
            was_resident = mirror.state in (PREFILL, DECODE)
            mirror.state = state
            self._dead_evictions += 1
            if was_resident:
                self.scheduler.resident = max(
                    0, self.scheduler.resident - 1)
            else:
                self.scheduler.queue_depth = max(
                    0, self.scheduler.queue_depth - 1)
            if self.scheduler.resident == 0:
                self.pool.close_dead()
            return mirror
        res = self._rpc("evict", rid=rid, state=state)
        if res is None:
            return self.evict_request(rid, state)   # died: dead path
        self._absorb(res["snap"])
        if res["req"] is None:
            return None
        view = self._requests.get(rid)
        if view is None:
            view = self._requests[rid] = _ReqView(rid, res["req"]["state"],
                                                  res["req"]["tokens"])
        view.state = res["req"]["state"]
        view.tokens = list(res["req"]["tokens"])
        return view

    def cancel(self, rid: int) -> bool:
        if not self.alive:
            return self.evict_request(rid, "CANCELLED") is not None
        res = self._rpc("cancel", rid=rid)
        if res is None:
            return False
        self._absorb(res["snap"])
        return res["ok"]

    def drain(self, *, cancel_queued: bool = True, max_steps=None) -> dict:
        res = self._rpc("drain", cancel_queued=cancel_queued,
                        max_steps=max_steps)
        if res is None:
            return self._mirror_summary()
        self._absorb(res["snap"])
        return res["summary"]

    def harvest(self) -> None:
        """Refresh the mirror without stepping (an explicit heartbeat)."""
        self._absorb(self._rpc("harvest"))

    def request_states(self) -> dict:
        """Same shape as ``ServeEngine.request_states``, served from the
        mirror (refreshed first when the worker is alive) — usable on a
        dead worker, where it is the surviving ledger."""
        if self.alive:
            self.harvest()
        return {rid: {"state": v.state, "tokens": list(v.tokens),
                      "slot": v.slot}
                for rid, v in self._requests.items()}

    def ping(self) -> bool:
        return self._rpc("ping") is not None

    def poison_slot(self, slot: int, value: float) -> bool:
        """Remote cache poison — lets the fault harness trip the
        worker's OWN decode sentinel across the process boundary."""
        return bool(self._rpc("poison", slot=slot, value=value))

    def warmup(self) -> dict:
        """Workers warm at spawn; this is the idempotent re-entry
        ``make_fleet`` calls."""
        self.wait_ready()
        return dict(self._compile_counts)

    def reset(self) -> None:
        snap = self._rpc("reset")
        if snap is not None:
            self._requests.clear()
            self._dead_evictions = 0
            self._m_steps = 0
            self._absorb(snap)

    def compile_counts(self) -> dict:
        if not self.alive:
            return dict(self._compile_counts or {})
        res = self._rpc("compile_counts")
        return dict(self._compile_counts or {}) if res is None else res

    def summary(self, *, stalled: bool = False) -> dict:
        if not self.alive:
            return self._mirror_summary()
        res = self._rpc("summary", stalled=stalled)
        return self._mirror_summary() if res is None else res


def spawn_worker(factory: str = "repro.serve.worker:engine_factory",
                 kwargs: Optional[dict] = None, *, warmup: bool = True,
                 rpc_timeout_s: float = 120.0,
                 spawn_timeout_s: float = 600.0) -> WorkerProxy:
    """Spawn one worker and block until its engine is warm."""
    return WorkerProxy(factory, kwargs, warmup=warmup,
                       rpc_timeout_s=rpc_timeout_s,
                       spawn_timeout_s=spawn_timeout_s).wait_ready()


def spawn_workers(n: int,
                  factory: str = "repro.serve.worker:engine_factory",
                  kwargs: Optional[dict] = None, *, warmup: bool = True,
                  rpc_timeout_s: float = 120.0,
                  spawn_timeout_s: float = 600.0) -> list[WorkerProxy]:
    """Spawn N workers CONCURRENTLY (children build + warm in parallel;
    the readiness waits are sequential but overlap the builds)."""
    ws = [WorkerProxy(factory, kwargs, warmup=warmup,
                      rpc_timeout_s=rpc_timeout_s,
                      spawn_timeout_s=spawn_timeout_s) for _ in range(n)]
    return [w.wait_ready() for w in ws]


if __name__ == "__main__":
    raise SystemExit(main())
