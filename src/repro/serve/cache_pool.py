"""Slot-indexed two-tier KV pool: explicit array-lifetime management.

The pool preallocates ONE decode cache at ``(max_slots, max_len)`` and
treats each batch row as an allocatable slot whose lifetime is a request
lifetime (OLLA's array-lifetime idea applied to serving: the cache rows
are the arrays, alloc/free is the plan).  Two tiers of state live here:

* device: the cache pytree itself (int8 K/V + f32 scales, per-slot
  ``pos`` lengths) — shapes NEVER change, so the decode step compiled
  against it is reused for the whole process lifetime;
* host: the free-list and alloc/free accounting — pure Python, no
  device sync on the scheduling path.

``scatter_request`` is the jitted join: it writes a freshly prefilled
single-request cache (already grown to ``max_len``) into a free slot with
one ``dynamic_update_slice`` per leaf and stamps the slot's length.
Retirement is free: the slot's rows simply stop being read (the engine
drops it from the active mask) and the host free-list gets the slot back.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models import transformer
from repro.models.config import ModelConfig


def scatter_request(pool_cache: dict, req_cache: dict, slot, length) -> dict:
    """Write a prefilled request cache (leading batch dim 1, sequence axis
    already grown to the pool's ``max_len``) into ``slot``.

    ``slot``/``length`` may be traced scalars — joining a request never
    triggers a recompile.  Functional: returns a new cache pytree (jit
    with ``donate_argnums=(0,)`` to update in place).
    """
    out = dict(pool_cache)
    for name, ax in transformer.CACHE_SEQ_AXES.items():
        if name not in pool_cache:
            continue
        upd = req_cache[name]
        if upd.shape[ax] != pool_cache[name].shape[ax]:
            raise ValueError(
                f"scatter_request: {name} has {upd.shape[ax]} sequence "
                f"slots, pool holds {pool_cache[name].shape[ax]} — grow the "
                f"prefill cache to max_len first (transformer.grow_cache)")
        start = [0] * upd.ndim
        start[1] = slot                       # (L, B, ...) batch axis
        out[name] = jax.lax.dynamic_update_slice(
            pool_cache[name], upd.astype(pool_cache[name].dtype),
            tuple(start))
    out["pos"] = pool_cache["pos"].at[slot].set(
        jnp.asarray(length, jnp.int32))
    return out


class SlotPool:
    """Preallocated slot-pooled decode cache + host-side free-list.

    Every ``alloc`` must be matched by exactly one ``free``; the engine's
    slot-leak invariant (`allocs == frees` and ``occupancy == 0`` once a
    trace drains) is asserted in tests.
    """

    def __init__(self, cfg: ModelConfig, max_slots: int, max_len: int, *,
                 quantized: bool = True, mesh=None):
        if max_slots < 1:
            raise ValueError(f"SlotPool: max_slots must be >= 1, "
                             f"got {max_slots}")
        self.cfg = cfg
        self.max_slots = max_slots
        self.max_len = max_len
        self.quantized = quantized
        self.mesh = mesh
        self.cache = transformer.init_cache(cfg, max_slots, max_len,
                                            quantized=quantized)
        # per-slot lengths replace the lockstep scalar position: occupancy
        # is data, not shape
        self.cache["pos"] = jnp.zeros((max_slots,), jnp.int32)
        # mesh mode: K/V shard over "model" (kv-heads, or the sequence dim
        # as serve_kv_shard falls back); the slot axis stays whole — DP in
        # serving is separate engine replicas, not a sharded pool
        self.specs = None
        if mesh is not None:
            from repro.distributed import sharding as shd
            self.specs = shd.serve_cache_specs(cfg, self.cache, mesh)
            self.cache = jax.device_put(
                self.cache, shd.to_shardings(mesh, self.specs))
        self._free = list(range(max_slots - 1, -1, -1))   # pop() -> slot 0 first
        self._live: set[int] = set()
        self._quarantined: set[int] = set()
        self.allocs = 0
        self.frees = 0
        self.quarantines = 0

    # -- host-side lifetime management ------------------------------------
    @property
    def free_slots(self) -> int:
        return len(self._free)

    @property
    def occupancy(self) -> int:
        return len(self._live)

    @property
    def quarantined(self) -> int:
        return len(self._quarantined)

    def alloc(self) -> int | None:
        """Claim a free slot id, or None when the pool is full."""
        if not self._free:
            return None
        slot = self._free.pop()
        self._live.add(slot)
        self.allocs += 1
        return slot

    def free(self, slot: int) -> None:
        if slot not in self._live:
            raise ValueError(f"SlotPool.free: slot {slot} is not live "
                             f"(double free or foreign slot)")
        self._live.remove(slot)
        self._free.append(slot)
        self.frees += 1

    # -- fault quarantine --------------------------------------------------
    def quarantine(self, slot: int) -> None:
        """Pull a poisoned live slot OUT of circulation: it is neither
        live (its request is gone) nor free (it must not be handed to a
        new request until the engine has audited the pool).  Release via
        :meth:`release_quarantined` — that is when the matching ``free``
        is counted, so ``allocs == frees`` still holds once a drained
        pool has released its quarantine."""
        if slot not in self._live:
            raise ValueError(f"SlotPool.quarantine: slot {slot} is not "
                             f"live")
        self._live.remove(slot)
        self._quarantined.add(slot)
        self.quarantines += 1

    def release_quarantined(self) -> list[int]:
        """Return quarantined slots to the free list (their bytes are
        dead by contract — the next ``scatter_request`` fully overwrites
        a slot's rows and re-stamps its length).  Call after
        :meth:`audit` passes."""
        released = sorted(self._quarantined)
        for slot in released:
            self._quarantined.remove(slot)
            self._free.append(slot)
            self.frees += 1
        return released

    def audit(self) -> dict:
        """Verify the pool's alloc/free invariant; raise on corruption.

        Checks: the free / live / quarantined sets partition the slot
        space exactly, and the alloc/free counters reconcile with what
        is currently outstanding.  Returns the accounting snapshot the
        engine attaches to its diagnostics."""
        free = set(self._free)
        report = {"free": len(free), "live": len(self._live),
                  "quarantined": len(self._quarantined),
                  "allocs": self.allocs, "frees": self.frees}
        if len(free) != len(self._free):
            raise RuntimeError(f"SlotPool.audit: duplicate slots on the "
                               f"free list ({sorted(self._free)})")
        overlap = (free & self._live) | (free & self._quarantined) \
            | (self._live & self._quarantined)
        if overlap:
            raise RuntimeError(f"SlotPool.audit: slots in two states: "
                               f"{sorted(overlap)}")
        missing = set(range(self.max_slots)) - free - self._live \
            - self._quarantined
        if missing:
            raise RuntimeError(f"SlotPool.audit: slots leaked out of all "
                               f"states: {sorted(missing)}")
        outstanding = len(self._live) + len(self._quarantined)
        if self.allocs - self.frees != outstanding:
            raise RuntimeError(
                f"SlotPool.audit: allocs({self.allocs}) - "
                f"frees({self.frees}) != live+quarantined({outstanding})")
        return report

    # -- accounting --------------------------------------------------------
    def bytes_per_slot(self) -> int:
        """Exact device bytes one resident request pins (cache bytes /
        max_slots — every leaf's batch axis is the slot axis)."""
        total = sum(x.size * x.dtype.itemsize
                    for k, x in self.cache.items() if k != "pos")
        return total // self.max_slots

    def bytes_per_slot_per_device(self) -> int:
        """Bytes one resident request pins on EACH chip: the sharded
        leaves divide by their shard count, so this is what a per-chip
        byte budget must admit against.  Equals :meth:`bytes_per_slot`
        on an unsharded pool."""
        if self.specs is None:
            return self.bytes_per_slot()
        from repro.distributed import sharding as shd
        total = sum(
            x.size * x.dtype.itemsize
            // shd.spec_shards(self.mesh, self.specs[k])
            for k, x in self.cache.items() if k != "pos")
        return total // self.max_slots
