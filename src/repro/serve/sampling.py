"""Seeded token sampling shared by the engine and the lockstep driver.

Greedy (``temperature <= 0``) stays the default everywhere; temperature
and top-k are STATIC Python values closed over at jit time, so changing
them builds a new program but stepping never does.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample_tokens(logits, key=None, *, temperature: float = 0.0,
                  top_k: int = 0):
    """logits: (B, V) -> (B,) int32 sampled token per row.

    ``temperature <= 0`` is exact greedy (argmax; ``key`` unused).
    Otherwise softmax sampling at ``temperature``, optionally restricted
    to the ``top_k`` highest-logit tokens per row (0 = full vocab).
    Deterministic for a fixed key: drive with
    ``jax.random.fold_in(base_key, step)``.
    """
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if key is None:
        raise ValueError("sample_tokens: temperature > 0 needs a PRNG key")
    scaled = logits.astype(jnp.float32) / float(temperature)
    if top_k > 0 and top_k < logits.shape[-1]:
        kth = jax.lax.top_k(scaled, top_k)[0][..., -1:]   # per-row threshold
        scaled = jnp.where(scaled < kth, -jnp.inf, scaled)
    return jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)


def sample_tokens_per_row(logits, keys=None, *, temperature: float = 0.0,
                          top_k: int = 0):
    """logits: (B, V), keys: (B,) PRNG keys -> (B,) int32, each row
    sampled with ITS OWN key.

    This is the fleet router's sampling mode: row i's key derives from
    the request's identity (``fold_in(fold_in(base, key_id), draw)``)
    rather than the engine step, so the sampled trajectory is a pure
    function of the request — independent of which replica, slot, or
    step serves it.  ``temperature <= 0`` is exact greedy (keys unused,
    identical to :func:`sample_tokens`)."""
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if keys is None:
        raise ValueError("sample_tokens_per_row: temperature > 0 needs "
                         "per-row PRNG keys")
    return jax.vmap(
        lambda k, row: sample_tokens(row[None], k, temperature=temperature,
                                     top_k=top_k)[0])(keys, logits)


def fold_request_key(base_key, key_id, draw):
    """The per-request key schedule: token ``draw`` of request
    ``key_id`` always samples with the same key, wherever it runs."""
    return jax.random.fold_in(jax.random.fold_in(base_key, key_id), draw)


def make_sampler(*, temperature: float = 0.0, top_k: int = 0):
    """A jitted (logits, key) -> tokens closure with static knobs."""
    return jax.jit(lambda logits, key: sample_tokens(
        logits, key, temperature=temperature, top_k=top_k))
