"""Seeded token sampling shared by the engine and the lockstep driver.

Greedy (``temperature <= 0``) stays the default everywhere; temperature
and top-k are STATIC Python values closed over at jit time, so changing
them builds a new program but stepping never does.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def sample_tokens(logits, key=None, *, temperature: float = 0.0,
                  top_k: int = 0):
    """logits: (B, V) -> (B,) int32 sampled token per row.

    ``temperature <= 0`` is exact greedy (argmax; ``key`` unused).
    Otherwise softmax sampling at ``temperature``, optionally restricted
    to the ``top_k`` highest-logit tokens per row (0 = full vocab).
    Deterministic for a fixed key: drive with
    ``jax.random.fold_in(base_key, step)``.
    """
    if temperature <= 0.0:
        return jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if key is None:
        raise ValueError("sample_tokens: temperature > 0 needs a PRNG key")
    scaled = logits.astype(jnp.float32) / float(temperature)
    if top_k > 0 and top_k < logits.shape[-1]:
        kth = jax.lax.top_k(scaled, top_k)[0][..., -1:]   # per-row threshold
        scaled = jnp.where(scaled < kth, -jnp.inf, scaled)
    return jax.random.categorical(key, scaled, axis=-1).astype(jnp.int32)


def make_sampler(*, temperature: float = 0.0, top_k: int = 0):
    """A jitted (logits, key) -> tokens closure with static knobs."""
    return jax.jit(lambda logits, key: sample_tokens(
        logits, key, temperature=temperature, top_k=top_k))
