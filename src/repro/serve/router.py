"""Replicated serving fleet: an engine-replica router with health-based
failover, cross-replica request migration, and elastic drain/rejoin.

The slot axis never shards (PR 6's mesh work shards heads/kv_heads
INSIDE one engine) — so the fleet dimension of serving is replication:
N independent :class:`ServeEngine` replicas behind one
:class:`Router`.  This is serving's data parallelism, and like training
DP it only pays off if a replica can fail without losing work.

Admission
    ``least_loaded`` scores each accepting replica by
    ``queue_depth - free_slots`` (lower = more headroom) with health as
    the primary key (HEALTHY before DEGRADED) and the replica index as
    the deterministic tie-break; ``round_robin`` rotates.  When EVERY
    replica rejects (bounded queues full), the router raises
    :class:`AdmissionRejected` — fleet-level backpressure the caller
    can see.

Health — an error-budget circuit breaker per replica
    Each engine already detects its own faults (the fused decode
    sentinel, PR 7).  The router folds those per-step fault counts plus
    a stall detector (resident requests but zero tokens emitted) into a
    per-replica state machine::

        HEALTHY -> DEGRADED      faults in window >= degrade_faults
        *       -> QUARANTINED   faults in window >= quarantine_faults,
                                 or stalled >= stall_steps
        QUARANTINED -> DEGRADED  after cooldown_steps (probation rejoin)
        DEGRADED -> HEALTHY      fault window empty again

    Quarantine evacuates the replica: every queued AND resident request
    migrates to the survivors.

Migration — the replay contract, fleet edition
    A migrating request re-enters a healthy replica AT THE QUEUE HEAD
    (it already waited its FCFS turn) with ``emitted=`` its healthy
    token prefix, riding the engine's own replay path: prefill over
    prompt+emitted, continue from there.  Under greedy decode the
    continuation is token-exact vs an uninterrupted run; under sampling
    the fleet requires ``sampler_keys="request"`` engines, whose
    per-request key schedule ``fold_in(fold_in(base, gid), draw)``
    makes token ``draw`` of request ``gid`` sample identically on ANY
    replica/slot/step — the trajectory is a pure function of the
    request, independent of placement.

Crash harvest
    ``kill(replica)`` simulates a crashed replica.  Replays come from
    the router's OWN per-step token mirror (standing in for a
    replicated request log — a real deployment cannot read a dead
    process's memory); the dead engine's ledger is closed out with
    ``MIGRATED`` evictions so both pools still audit to zero leaks.

Elasticity
    ``drain_replica`` stops admission, migrates the queued requests
    off, and lets residents finish (DRAINING -> DRAINED);
    ``rejoin`` puts a DRAINED replica back in rotation as HEALTHY with
    warm compiled programs — zero recompiles, asserted in tests.

``summary()`` aggregates per-replica :class:`ServeMetrics` into fleet
metrics (goodput vs throughput, failovers, migrations, time in
quarantine) and ``reconcile()`` cross-checks the fleet request table
against every replica's ledger — each request terminal exactly once.

Durability (ISSUE 9) — the write-ahead journal and whole-router crashes
    With ``journal=`` (a :class:`~repro.serve.journal.RequestJournal`)
    every fleet transition is logged BEFORE the router acts on it:
    SUBMIT before placement, the healthy token deltas at every harvest,
    exactly one TERMINAL per request.  After a whole-router ``kill -9``,
    ``Router.recover()`` on a FRESH fleet rebuilds the request table
    from the journal's reduced state and re-submits every live request
    from its prompt + durably-logged tokens — the engine regenerates
    the (possibly lost) fsync-lag suffix deterministically, so greedy
    recovery is token-exact and sampled recovery key-exact under
    ``sampler_keys="request"``.  ``reconcile()`` then additionally
    proves every journaled SUBMIT reached exactly one TERMINAL.

    Subprocess replicas (:class:`~repro.serve.worker.WorkerProxy`) slot
    into the same fleet: ``kill()`` becomes a real SIGKILL, and the
    stall detector treats a dead worker holding work as stalled (its
    RPC heartbeat stopped), so the breaker quarantines and evacuates it
    across the process boundary.
"""
from __future__ import annotations

import dataclasses
from collections import deque
from typing import Callable, Optional, Sequence

import numpy as np

from repro.obs.registry import MetricsRegistry
from repro.serve.metrics import fleet_summary
from repro.serve.scheduler import (CANCELLED, DONE, DROPPED, FAILED,
                                   MIGRATED, QUEUED, TERMINAL,
                                   AdmissionRejected)
from repro.serve.trace import TraceRequest

#: replica health states (the circuit breaker's machine)
HEALTHY, DEGRADED, QUARANTINED = "HEALTHY", "DEGRADED", "QUARANTINED"
DRAINING, DRAINED, DEAD = "DRAINING", "DRAINED", "DEAD"
#: states in which a replica accepts new work
ACCEPTING = frozenset({HEALTHY, DEGRADED})

ROUTE_POLICIES = ("least_loaded", "round_robin")


@dataclasses.dataclass
class BreakerConfig:
    """Error-budget circuit breaker knobs (see module docstring)."""
    window_steps: int = 32        # sliding fault window (router steps)
    degrade_faults: int = 1       # faults in window -> DEGRADED
    quarantine_faults: int = 3    # faults in window -> QUARANTINED
    cooldown_steps: int = 16      # quarantine length before probation
    stall_steps: int = 8          # no-progress steps -> QUARANTINED

    def __post_init__(self):
        if self.window_steps < 1 or self.cooldown_steps < 1 \
                or self.stall_steps < 1:
            raise ValueError("BreakerConfig: window/cooldown/stall steps "
                             "must be >= 1")
        if not (1 <= self.degrade_faults <= self.quarantine_faults):
            raise ValueError("BreakerConfig: need 1 <= degrade_faults <= "
                             "quarantine_faults")


@dataclasses.dataclass
class FleetRequest:
    """One request at FLEET scope.  ``gid`` is the fleet-global id (and
    the sampler-key identity on every replica); ``tokens`` is the
    router's mirror of the healthy emitted stream — the crash-harvest
    source."""
    gid: int
    prompt: np.ndarray
    max_new_tokens: int
    eos_id: Optional[int] = None
    deadline_steps: Optional[int] = None
    state: str = QUEUED                   # fleet-level lifecycle
    replica: Optional[int] = None         # current placement
    local_rid: Optional[int] = None       # rid on that replica
    tokens: list = dataclasses.field(default_factory=list)
    migrations: int = 0                   # successful re-placements
    placements: list = dataclasses.field(default_factory=list)
    # open span ids ("fleet_req"/"migrate") when tracing is on
    span_ids: dict = dataclasses.field(default_factory=dict)


class Router:
    """Fronts N warmed ServeEngine replicas (see module docstring)."""

    def __init__(self, engines: Sequence, *, policy: str = "least_loaded",
                 breaker: Optional[BreakerConfig] = None,
                 max_migrations: int = 2, sink=None, journal=None,
                 journal_tokens_every: int = 1):
        if journal_tokens_every < 1:
            raise ValueError("Router: journal_tokens_every must be >= 1")
        if not engines:
            raise ValueError("Router: need at least one engine replica")
        if policy not in ROUTE_POLICIES:
            raise ValueError(f"Router: unknown policy {policy!r} "
                             f"(expected one of {ROUTE_POLICIES})")
        for i, e in enumerate(engines):
            if e.scheduler.has_work():
                raise ValueError(f"Router: replica {i} has in-flight "
                                 f"requests — pass freshly warmed engines")
            if e.temperature > 0.0 and e.sampler_keys != "request":
                raise ValueError(
                    f"Router: replica {i} samples with per-step keys; a "
                    f"fleet needs sampler_keys='request' so migrated "
                    f"trajectories are placement-independent")
            if e.metrics.replica is None:
                e.metrics.replica = i
        self.engines = list(engines)
        self.policy = policy
        self.breaker = breaker if breaker is not None else BreakerConfig()
        self.max_migrations = max_migrations
        self.sink = sink
        n = len(self.engines)
        self.health: list[str] = [HEALTHY] * n
        self.hooks: dict[str, Callable] = {}   # chaos harness seam
        self._step_no = 0
        self._next_gid = 0
        self._rr = 0                           # round-robin cursor
        self._reqs: dict[int, FleetRequest] = {}
        self._local2gid: list[dict] = [dict() for _ in range(n)]
        self._pending: deque[FleetRequest] = deque()  # awaiting placement
        self._fault_marks: list[deque] = [deque() for _ in range(n)]
        self._fault_seen: list[int] = [0] * n  # engine fault counter snap
        self._tokens_seen: list[int] = [0] * n # progress snapshot
        self._stalled: list[int] = [0] * n     # consecutive no-progress
        self._quarantined_at: list[Optional[int]] = [None] * n
        self._paused: list[int] = [0] * n      # replica_slow countdown
        #: fleet-scope metrics (rejected/failovers/migrations/health
        #: transitions); per-replica registries merge in via
        #: ``registry_snapshot()``
        self.registry = MetricsRegistry()
        #: optional repro.obs Tracer for fleet_req/place/migrate/recover
        #: spans — attach before submitting (every emission is guarded,
        #: so leaving it None costs nothing)
        self.tracer = None
        self.time_in_quarantine: list[int] = [0] * n
        #: write-ahead request journal (attach at construction so every
        #: SUBMIT is journaled — a mid-run attach would leave earlier
        #: terminals unaccounted)
        self.journal = journal
        #: token-journaling cadence: wal_tokens deltas flush every N
        #: router steps (and always at a terminal).  Token records only
        #: bound how much a recovery must REGENERATE — replay is
        #: deterministic either way — so a cadence > 1 trades a wider
        #: fsync-lag window for one append per request per N steps
        self.journal_tokens_every = journal_tokens_every
        self._recovered_done = 0        # DONE straight from the journal
        self._journal_recovered: list[int] = []   # gids recover() rebuilt

    # legacy counters, now registry-backed ----------------------------------
    @property
    def rejected(self) -> int:             # fleet-level backpressure
        return self.registry.count("fleet.rejected")

    @property
    def failovers(self) -> int:            # crash/quarantine/FAILED moves
        return self.registry.count("fleet.failovers")

    @property
    def migrations(self) -> int:           # successful re-placements
        return self.registry.count("fleet.migrations")

    def registry_snapshot(self) -> dict:
        """Fleet-wide registry view: the router's own counters merged
        with every replica's snapshot — across the RPC boundary for
        subprocess workers (their ``_MetricsView`` caches the snapshot
        from the last harvest, so a dead worker's last-known metrics
        still count)."""
        snap = self.registry.snapshot()
        for e in self.engines:
            get = getattr(e.metrics, "registry_snapshot", None)
            s = get() if get is not None else None
            if s:
                snap = MetricsRegistry.merge(snap, s)
        return snap

    # -- events ------------------------------------------------------------
    def _event(self, kind: str, **fields) -> None:
        if self.sink is not None:
            self.sink.emit(kind, step=self._step_no, **fields)

    def _set_health(self, i: int, state: str, reason: str = "") -> None:
        if self.health[i] == state:
            return
        self._event("health", replica=i, frm=self.health[i], to=state,
                    reason=reason)
        self.registry.inc(f"fleet.health.{state}")
        self.health[i] = state

    def _fleet_terminal(self, fr: FleetRequest, state: str,
                        **fields) -> None:
        """The ONE place a fleet request goes terminal: set the state,
        emit the event, and close the journal entry (exactly one
        wal_terminal per journaled submit — ``reconcile`` proves it)."""
        fr.state = state
        self._event("fleet_terminal", gid=fr.gid, state=state, **fields)
        if self.journal is not None:
            self.journal.terminal(fr.gid, state, n_tokens=len(fr.tokens))
        if self.tracer is not None:
            self.tracer.end(fr.span_ids.pop("migrate", None), state=state)
            self.tracer.end(fr.span_ids.pop("recover", None), state=state)
            self.tracer.end(fr.span_ids.pop("fleet_req", None), state=state,
                            tokens=len(fr.tokens))

    # -- placement ---------------------------------------------------------
    @property
    def step_no(self) -> int:
        return self._step_no

    def _accepting(self) -> list[int]:
        return [i for i, h in enumerate(self.health) if h in ACCEPTING]

    def _rank(self, candidates: list[int]) -> list[int]:
        """Admission order over accepting replicas."""
        if self.policy == "round_robin":
            n = len(self.engines)
            order = sorted(candidates, key=lambda i: (i - self._rr) % n)
            return order
        # least_loaded: HEALTHY first, then most headroom, then index
        def score(i):
            e = self.engines[i]
            load = e.scheduler.queue_depth - e.pool.free_slots
            return (0 if self.health[i] == HEALTHY else 1, load, i)
        return sorted(candidates, key=score)

    def _place(self, fr: FleetRequest, *, front: bool) -> bool:
        """Try to put ``fr`` on some accepting replica.  Returns False
        when every candidate rejected (callers decide between fleet
        backpressure and the pending-migration queue)."""
        sid = None if self.tracer is None else self.tracer.begin(
            "place", trace=fr.gid, parent=fr.span_ids.get("fleet_req"),
            front=front)
        try:
            for i in self._rank(self._accepting()):
                try:
                    rid = self.engines[i].submit(
                        fr.prompt, fr.max_new_tokens, eos_id=fr.eos_id,
                        deadline_steps=fr.deadline_steps, front=front,
                        key_id=fr.gid,
                        emitted=fr.tokens if fr.tokens else None)
                except AdmissionRejected:
                    continue
                if self.policy == "round_robin":
                    self._rr = (i + 1) % len(self.engines)
                fr.replica, fr.local_rid = i, rid
                fr.placements.append((i, rid))
                self._local2gid[i][rid] = fr.gid
                self._event("place", gid=fr.gid, replica=i, rid=rid,
                            front=front, emitted=len(fr.tokens))
                if self.journal is not None:
                    self.journal.place(fr.gid, i, rid, front=front,
                                       emitted=len(fr.tokens))
                if self.tracer is not None:
                    self.tracer.end(sid, placed=True, replica=i, rid=rid)
                return True
        except ValueError:
            # replay prompt outgrew the buckets — close the span before
            # the caller escalates to a fleet-level FAILED
            if self.tracer is not None:
                self.tracer.end(sid, placed=False, error="bucket")
            raise
        if self.tracer is not None:
            self.tracer.end(sid, placed=False)
        return False

    def submit(self, prompt, max_new_tokens: int,
               eos_id: Optional[int] = None,
               deadline_steps: Optional[int] = None) -> int:
        """Admit one request to the fleet; returns its gid.  Raises
        :class:`AdmissionRejected` when every accepting replica's
        bounded queue is full (fleet backpressure)."""
        fr = FleetRequest(gid=self._next_gid,
                          prompt=np.asarray(prompt, np.int32),
                          max_new_tokens=max_new_tokens, eos_id=eos_id,
                          deadline_steps=deadline_steps)
        if self.tracer is not None:
            fr.span_ids["fleet_req"] = self.tracer.begin(
                "fleet_req", trace=fr.gid, prompt_len=len(fr.prompt),
                max_new_tokens=max_new_tokens)
        if self.journal is not None:
            # WRITE-AHEAD: the submit hits disk BEFORE placement, so a
            # crash between the two still recovers the request — which
            # also means the gid is consumed (and a rejection must close
            # the journal entry with its own terminal)
            self.journal.submit(fr.gid, fr.prompt, fr.max_new_tokens,
                                fr.eos_id, fr.deadline_steps)
        if not self._place(fr, front=False):
            self.registry.inc("fleet.rejected")
            self._event("fleet_reject", gid=fr.gid)
            if self.journal is not None:
                self._next_gid += 1
                self.journal.terminal(fr.gid, "REJECTED")
            if self.tracer is not None:
                self.tracer.end(fr.span_ids.pop("fleet_req", None),
                                state="REJECTED", tokens=0)
            raise AdmissionRejected(
                f"Router: every accepting replica rejected request "
                f"{fr.gid} (fleet backpressure)")
        self._next_gid += 1
        self._reqs[fr.gid] = fr
        return fr.gid

    def cancel(self, gid: int) -> bool:
        """Cancel a fleet request wherever it lives.  Idempotent."""
        fr = self._reqs.get(gid)
        if fr is None or fr.state in TERMINAL:
            return False
        if fr in self._pending:
            self._pending.remove(fr)
        elif fr.replica is not None:
            self.engines[fr.replica].evict_request(fr.local_rid, CANCELLED)
            self._local2gid[fr.replica].pop(fr.local_rid, None)
        self._fleet_terminal(fr, CANCELLED)
        return True

    # -- failover ----------------------------------------------------------
    def _migrate(self, fr: FleetRequest, reason: str) -> None:
        """Queue ``fr`` for re-placement on a healthy replica (queue
        HEAD on arrival).  Over-budget requests fail at fleet level
        instead of ping-ponging forever."""
        fr.replica, fr.local_rid = None, None
        if fr.migrations >= self.max_migrations:
            self._fleet_terminal(
                fr, FAILED,
                reason=f"migration budget exhausted ({reason})")
            return
        self.registry.inc("fleet.failovers")
        self._event("failover", gid=fr.gid, reason=reason,
                    emitted=len(fr.tokens))
        if self.tracer is not None and "migrate" not in fr.span_ids:
            # one migrate span covers failover -> successful re-placement,
            # including any time parked in the pending queue
            fr.span_ids["migrate"] = self.tracer.begin(
                "migrate", trace=fr.gid,
                parent=fr.span_ids.get("fleet_req"), reason=reason,
                emitted=len(fr.tokens))
        if self.journal is not None:
            self.journal.migrate(fr.gid, reason)
        try:
            placed = self._place(fr, front=True)
        except ValueError:
            # replay prompt outgrew every replica's buckets — the same
            # escalation the engine-internal replay path takes
            self._fleet_terminal(fr, FAILED,
                                 reason="replay prompt exceeds buckets")
            return
        if placed:
            fr.migrations += 1
            self.registry.inc("fleet.migrations")
            if self.tracer is not None:
                self.tracer.end(fr.span_ids.pop("migrate", None),
                                replica=fr.replica)
        else:
            self._pending.append(fr)      # retried every router step

    def _evacuate(self, i: int, reason: str) -> int:
        """Migrate every live request off replica ``i`` (quarantine /
        crash / drain-queued paths).  Replays harvest from the ROUTER's
        token mirror, not the replica's memory."""
        moved = 0
        for rid, gid in list(self._local2gid[i].items()):
            self.engines[i].evict_request(rid, MIGRATED)
            self._local2gid[i].pop(rid, None)
            self._migrate(self._reqs[gid], reason)
            moved += 1
        return moved

    def kill(self, i: int) -> bool:
        """Replica crash: evacuate everything (from the router's
        mirrored token log), close the dead ledger, and stop scheduling
        the replica.  On a subprocess replica
        (:class:`~repro.serve.worker.WorkerProxy`) this is a REAL
        ``SIGKILL`` — the proxy's mirror then stands in for the dead
        process's memory, exactly like a real deployment's request log.
        Returns False if already dead."""
        if self.health[i] == DEAD:
            return False
        term = getattr(self.engines[i], "terminate", None)
        if callable(term):
            term()                       # SIGKILL the worker subprocess
        self._set_health(i, DEAD, "crash")
        self._evacuate(i, f"replica {i} crashed")
        return True

    def pause(self, i: int, steps: int) -> bool:
        """Stop stepping replica ``i`` for ``steps`` router steps (the
        ``replica_slow`` chaos event).  The stall detector decides
        whether the pause is long enough to quarantine."""
        if self.health[i] in (DEAD,) or steps < 1:
            return False
        self._paused[i] = max(self._paused[i], steps)
        self._event("pause", replica=i, steps=steps)
        return True

    def drain_replica(self, i: int) -> None:
        """Elastic scale-down: stop admitting to replica ``i``, migrate
        its QUEUED requests to the survivors, and let residents finish
        (DRAINING -> DRAINED as they retire)."""
        if self.health[i] in (DEAD, DRAINED, DRAINING):
            return
        self._set_health(i, DRAINING, "drain requested")
        for rid, gid in list(self._local2gid[i].items()):
            req = self.engines[i]._requests.get(rid)
            if req is not None and req.state == QUEUED:
                self.engines[i].evict_request(rid, MIGRATED)
                self._local2gid[i].pop(rid, None)
                self._migrate(self._reqs[gid], f"replica {i} draining")

    def rejoin(self, i: int) -> None:
        """Warm rejoin of a DRAINED replica: compiled programs are still
        hot, so it re-enters rotation with zero recompiles."""
        if self.health[i] != DRAINED:
            raise ValueError(f"Router.rejoin: replica {i} is "
                             f"{self.health[i]}, only DRAINED replicas "
                             f"rejoin (quarantine rejoins itself after "
                             f"cooldown; DEAD replicas need a restart)")
        self._fault_marks[i].clear()
        self._stalled[i] = 0
        self._fault_seen[i] = self.engines[i].metrics.faults
        self._tokens_seen[i] = self.engines[i].metrics.tokens_emitted
        self._set_health(i, HEALTHY, "rejoin")

    # -- whole-router crash recovery ----------------------------------------
    def recover(self, journal=None) -> dict:
        """Rebuild fleet state from the write-ahead journal after a
        whole-router crash (this router object is a FRESH fleet; the
        crashed one is gone — ``kill -9`` leaves nothing else).

        Every request the journal shows live — submitted, not yet
        terminal, at ANY crash point including between the wal_submit
        append and its placement — is re-entered with its durably-logged
        token prefix (``emitted=``), riding the engine's deterministic
        replay path: tokens past the last durable record (the fsync-lag
        window) are REGENERATED, token-exact under greedy and key-exact
        under ``sampler_keys="request"`` (the gid is the key identity).
        A recovered request whose durable tokens already meet its budget
        goes straight to ``DONE`` — its output is complete on disk; no
        engine needs to run.

        Idempotent: gids already in the fleet table are skipped, so
        running ``recover`` twice (or recovering into a router that
        already re-submitted some requests) changes nothing."""
        if journal is not None:
            self.journal = journal
        if self.journal is None:
            raise ValueError("Router.recover: no journal attached")
        st = self.journal.state
        self._next_gid = max(self._next_gid, st.next_gid)
        info = {"n_live": st.n_live, "n_recovered": 0, "n_done": 0,
                "n_placed": 0, "n_pending": 0, "n_failed": 0,
                "n_skipped": 0}
        for gid in sorted(st.live):
            if gid in self._reqs:
                info["n_skipped"] += 1     # idempotence: already rebuilt
                continue
            rec = st.live[gid]
            fr = FleetRequest(
                gid=gid, prompt=np.asarray(rec["prompt"], np.int32),
                max_new_tokens=rec["max_new_tokens"],
                eos_id=rec["eos_id"],
                deadline_steps=rec["deadline_steps"],
                tokens=list(rec["tokens"]),
                migrations=rec.get("migrations", 0))
            self._reqs[gid] = fr
            self._journal_recovered.append(gid)
            info["n_recovered"] += 1
            self._event("recover", gid=gid, emitted=len(fr.tokens))
            if self.tracer is not None:
                # recovered requests get a fresh root span (the crashed
                # router's span died open with it); replay=True marks the
                # timeline as a post-recovery continuation
                fr.span_ids["fleet_req"] = self.tracer.begin(
                    "fleet_req", trace=fr.gid, prompt_len=len(fr.prompt),
                    max_new_tokens=fr.max_new_tokens, replay=True,
                    emitted=len(fr.tokens))
                fr.span_ids["recover"] = self.tracer.begin(
                    "recover", trace=fr.gid,
                    parent=fr.span_ids["fleet_req"],
                    emitted=len(fr.tokens))
            if len(fr.tokens) >= fr.max_new_tokens:
                # complete on disk — the engine would (rightly) reject
                # an emitted prefix that leaves nothing to generate
                self._fleet_terminal(fr, DONE, tokens=len(fr.tokens),
                                     recovered=True)
                self._recovered_done += 1
                info["n_done"] += 1
                continue
            try:
                # front=False in ascending-gid order into empty queues:
                # recovery REBUILDS the FCFS order (front=True would
                # reverse it)
                placed = self._place(fr, front=False)
            except ValueError:
                self._fleet_terminal(fr, FAILED,
                                     reason="replay prompt exceeds buckets")
                info["n_failed"] += 1
                continue
            if placed:
                if self.tracer is not None:
                    self.tracer.end(fr.span_ids.pop("recover", None),
                                    replica=fr.replica)
                info["n_placed"] += 1
            else:
                self._pending.append(fr)
                info["n_pending"] += 1
        return info

    # -- the breaker -------------------------------------------------------
    def _update_health(self, i: int) -> None:
        b, marks = self.breaker, self._fault_marks[i]
        e = self.engines[i]
        # new faults since last look -> timestamped marks in the window
        new = e.metrics.faults - self._fault_seen[i]
        self._fault_seen[i] = e.metrics.faults
        for _ in range(new):
            marks.append(self._step_no)
        while marks and marks[0] <= self._step_no - b.window_steps:
            marks.popleft()
        # stall detector: residents but no progress.  A dead subprocess
        # worker (SIGKILL — its RPC heartbeat stopped and the proxy
        # marked itself dead) holding ANY work counts as stalled too:
        # its token counter froze at death, so queued-only work would
        # otherwise never trip the resident-based detector.
        alive = getattr(e, "alive", True)
        progressed = e.metrics.tokens_emitted > self._tokens_seen[i]
        self._tokens_seen[i] = e.metrics.tokens_emitted
        holding = e.scheduler.resident > 0 or e.scheduler.queue_depth > 0
        if (e.scheduler.resident > 0 and not progressed) \
                or (not alive and holding):
            self._stalled[i] += 1
        else:
            self._stalled[i] = 0

        h = self.health[i]
        if h == QUARANTINED:
            self.time_in_quarantine[i] += 1
            if not alive:
                # a dead process never earns probation — the quarantine
                # was the breaker noticing the SIGKILL; finalize it
                self._set_health(i, DEAD, "process dead in quarantine")
                return
            if (self._step_no - self._quarantined_at[i]
                    >= b.cooldown_steps):
                marks.clear()
                self._stalled[i] = 0
                self._set_health(i, DEGRADED, "cooldown over (probation)")
            return
        if h == DRAINING:
            if not self.engines[i].scheduler.has_work():
                self._set_health(i, DRAINED, "drained")
            return
        if h not in ACCEPTING:
            return
        if len(marks) >= b.quarantine_faults \
                or self._stalled[i] >= b.stall_steps:
            why = ("fault budget" if len(marks) >= b.quarantine_faults
                   else f"stalled {self._stalled[i]} steps")
            self._set_health(i, QUARANTINED, why)
            self._quarantined_at[i] = self._step_no
            self._paused[i] = 0
            self._evacuate(i, f"replica {i} quarantined ({why})")
        elif h == HEALTHY and len(marks) >= b.degrade_faults:
            self._set_health(i, DEGRADED, "fault in window")
        elif h == DEGRADED and not marks and self._stalled[i] == 0:
            self._set_health(i, HEALTHY, "window clean")

    # -- the step loop -----------------------------------------------------
    def _harvest(self, i: int) -> None:
        """Mirror emitted tokens and resolve locally-terminal requests
        into fleet outcomes."""
        eng = self.engines[i]
        for rid, gid in list(self._local2gid[i].items()):
            req = eng._requests[rid]
            fr = self._reqs[gid]
            if self.journal is not None:
                # journal the healthy token DELTA before mirroring it:
                # the start index makes post-recovery re-emission an
                # idempotent splice, not a double-append.  The durable
                # length is the REDUCER's view (not fr.tokens — the
                # cadence below lets the mirror run ahead of the WAL)
                rec = self.journal.state.live.get(gid)
                jlen = len(rec["tokens"]) if rec is not None else None
                due = (req.state in TERMINAL
                       or self._step_no % self.journal_tokens_every == 0)
                if jlen is not None and due and len(req.tokens) > jlen:
                    self.journal.tokens(gid, jlen, req.tokens[jlen:])
            fr.tokens = list(req.tokens)   # the replicated request log
            if req.state not in TERMINAL:
                continue
            self._local2gid[i].pop(rid, None)
            fr.replica, fr.local_rid = None, None
            if req.state == DONE:
                self._fleet_terminal(fr, DONE, tokens=len(fr.tokens))
            elif req.state in (CANCELLED, DROPPED):
                # deadline shedding and engine-side cancels are FINAL —
                # a request that timed out queueing does not get a
                # second queue on another replica
                self._fleet_terminal(fr, req.state)
            elif req.state == FAILED:
                # local retry budget exhausted: one fleet-level failover
                self._migrate(fr, f"replica {i} FAILED rid {rid}")
            # MIGRATED locals are resolved at the evacuation site

    def step(self) -> None:
        """One fleet step: chaos hook, step live replicas, harvest
        outcomes, update breakers, retry pending migrations."""
        hook = self.hooks.get("pre_step")
        if hook is not None:
            hook(self)
        for i, eng in enumerate(self.engines):
            if self.health[i] in (DEAD, QUARANTINED, DRAINED):
                continue
            if self._paused[i] > 0:
                self._paused[i] -= 1
            elif eng.scheduler.has_work():
                eng.step()
            self._harvest(i)
        for i in range(len(self.engines)):
            if self.health[i] != DEAD:
                self._update_health(i)
        for _ in range(len(self._pending)):
            fr = self._pending.popleft()
            if fr.state in TERMINAL:
                continue
            try:
                placed = self._place(fr, front=True)
            except ValueError:
                self._fleet_terminal(fr, FAILED,
                                     reason="replay prompt exceeds buckets")
                continue
            if placed:
                fr.migrations += 1
                self.registry.inc("fleet.migrations")
                if self.tracer is not None:
                    self.tracer.end(fr.span_ids.pop("migrate", None),
                                    replica=fr.replica)
                    self.tracer.end(fr.span_ids.pop("recover", None),
                                    replica=fr.replica)
            else:
                self._pending.append(fr)
        self._step_no += 1

    def live_requests(self) -> int:
        return sum(1 for fr in self._reqs.values()
                   if fr.state not in TERMINAL)

    def run(self, trace: Sequence[TraceRequest], *,
            max_steps: Optional[int] = None) -> dict:
        """Drive a step-indexed trace through the fleet (same contract
        as ``ServeEngine.run``: backpressured submits are shed and
        counted; a stuck fleet returns a summary flagged ``stalled``)."""
        pending = sorted(trace, key=lambda r: r.arrival_step)
        i = 0
        budget = max_steps if max_steps is not None else (
            sum((r.max_new_tokens + 4) * (self.max_migrations + 2)
                for r in pending)
            # recovered/in-flight requests already in the fleet table
            # (e.g. rebuilt by recover() before an empty post-crash
            # trace) need step budget too, or the drain is misflagged
            # as a stall
            + sum((fr.max_new_tokens - len(fr.tokens) + 4)
                  * (self.max_migrations + 2)
                  for fr in self._reqs.values()
                  if fr.state not in TERMINAL)
            + (pending[-1].arrival_step if pending else 0) + 32)
        while i < len(pending) or self.live_requests() > 0:
            while (i < len(pending)
                   and pending[i].arrival_step <= self._step_no):
                r = pending[i]
                try:
                    self.submit(r.prompt, r.max_new_tokens)
                except AdmissionRejected:
                    pass                  # fleet backpressure: counted
                i += 1
            if self.live_requests() == 0 and i < len(pending):
                self._step_no = pending[i].arrival_step
                continue
            self.step()
            budget -= 1
            if budget < 0:
                return self.summary(stalled=True)
        return self.summary()

    # -- accounting --------------------------------------------------------
    def request(self, gid: int) -> FleetRequest:
        return self._reqs[gid]

    def reconcile(self) -> dict:
        """Cross-check the fleet request table against every replica
        ledger.  Every placement must be terminal on exactly one
        replica (or still live), and the per-replica DONE/MIGRATED
        counts must sum to the fleet's."""
        per = [e.summary() for e in self.engines]
        fleet_done = sum(1 for fr in self._reqs.values()
                         if fr.state == DONE)
        fleet_failed = sum(1 for fr in self._reqs.values()
                           if fr.state == FAILED)
        local_done = sum(s["n_done"] for s in per)
        local_migrated = sum(s["n_migrated_out"] for s in per)
        placements = sum(len(fr.placements) for fr in self._reqs.values())
        local_requests = sum(s["n_requests"] for s in per)
        # a placement ends in exactly one local terminal state or is live
        live = self.live_requests() - len(self._pending)
        local_terminal = sum(
            s["n_done"] + s["n_cancelled"] + s["n_dropped"]
            + s["n_failed"] + s["n_migrated_out"] for s in per)
        checks = {
            # recovered-complete requests go DONE straight from the
            # journal, with no local placement to match
            "done_matches":
                fleet_done == local_done + self._recovered_done,
            "placements_match": placements == local_requests,
            "terminals_match": local_terminal == placements - live,
            "migrations_bounded": self.migrations <= local_migrated,
            "failed_bounded":
                fleet_failed <= sum(s["n_failed"] for s in per)
                + self.failovers,
        }
        out = {"fleet_done": fleet_done, "local_done": local_done,
               "placements": placements, "local_requests": local_requests,
               "local_terminal": local_terminal, "live": live}
        if self.journal is not None:
            # the durability half: every journaled SUBMIT is either
            # still live or reached EXACTLY ONE terminal record
            st = self.journal.state
            checks["journal_accounted"] = (
                st.duplicate_terminals == 0
                and st.n_submits == st.n_terminals + st.n_live)
            out["journal"] = {
                "n_submits": st.n_submits,
                "n_terminals": st.n_terminals,
                "n_live": st.n_live,
                "duplicate_terminals": st.duplicate_terminals,
                "terminal_counts": dict(st.terminal_counts),
                "appends": self.journal.appends,
                "snapshots": self.journal.snapshots,
            }
        out.update(ok=all(checks.values()), checks=checks)
        return out

    def summary(self, *, stalled: bool = False) -> dict:
        """Fleet metrics: per-replica summaries rolled up via
        ``fleet_summary`` plus the router's own ledger (failovers,
        migrations, replay success, health, reconciliation)."""
        per = [e.summary() for e in self.engines]
        out = fleet_summary(per)
        by_state = {s: sum(1 for fr in self._reqs.values()
                           if fr.state == s)
                    for s in (DONE, CANCELLED, DROPPED, FAILED)}
        migrated = [fr for fr in self._reqs.values() if fr.migrations > 0]
        out["fleet"] = {
            "n_requests": len(self._reqs),
            "n_done": by_state[DONE],
            "n_cancelled": by_state[CANCELLED],
            "n_dropped": by_state[DROPPED],
            "n_failed": by_state[FAILED],
            "n_live": self.live_requests(),
            "n_pending_migration": len(self._pending),
            "n_rejected": self.rejected,
            "failovers": self.failovers,
            "n_migrations": self.migrations,
            "n_migrated_requests": len(migrated),
            # of the requests that had to move replicas, how many still
            # finished — the fleet replay path's success rate
            "replay_success_rate": (
                sum(1 for fr in migrated if fr.state == DONE)
                / len(migrated) if migrated else 1.0),
            "goodput_tokens": sum(len(fr.tokens)
                                  for fr in self._reqs.values()
                                  if fr.state == DONE),
            "n_recovered": len(self._journal_recovered),
            # of the requests recover() rebuilt from the journal, how
            # many reached DONE — the crash-recovery success rate the
            # CI ratchet floors
            "recovery_replay_success": (
                sum(1 for g in self._journal_recovered
                    if self._reqs[g].state == DONE)
                / len(self._journal_recovered)
                if self._journal_recovered else 1.0),
        }
        out["health"] = list(self.health)
        out["time_in_quarantine"] = list(self.time_in_quarantine)
        out["stalled"] = stalled
        out["step_no"] = self._step_no
        out["reconcile"] = self.reconcile()
        return out


def make_fleet(build_engine: Callable[[int], object], n_replicas: int,
               **router_kwargs) -> Router:
    """Build + warm ``n_replicas`` engines (``build_engine(i)`` must
    return an UNwarmed ServeEngine; warmup happens here so the router
    only ever sees hot programs) and front them with a Router."""
    engines = []
    for i in range(n_replicas):
        e = build_engine(i)
        e.warmup()
        engines.append(e)
    return Router(engines, **router_kwargs)
