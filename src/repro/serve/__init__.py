"""Continuous-batching serve engine: slot-pooled int8 KV cache, FCFS
scheduler, recompile-free join/evict step loop, and the fault-tolerance
layer (deadlines, cancellation, quarantine + replay).  See README.md in
this package for the architecture, the static-shape contract, and the
failure semantics."""
from repro.serve.cache_pool import SlotPool, scatter_request
from repro.serve.engine import ServeEngine, default_buckets, supports
from repro.serve.faults import FaultEvent, FaultInjector, FaultPlan
from repro.serve.metrics import ServeMetrics
from repro.serve.sampling import make_sampler, sample_tokens
from repro.serve.scheduler import (CANCELLED, DECODE, DONE, DROPPED, FAILED,
                                   PREFILL, QUEUED, TERMINAL,
                                   AdmissionRejected, Request, Scheduler)
from repro.serve.trace import TraceRequest, synthetic_trace

__all__ = [
    "ServeEngine", "SlotPool", "Scheduler", "Request", "ServeMetrics",
    "TraceRequest", "synthetic_trace", "scatter_request", "sample_tokens",
    "make_sampler", "default_buckets", "supports",
    "FaultPlan", "FaultEvent", "FaultInjector", "AdmissionRejected",
    "QUEUED", "PREFILL", "DECODE", "DONE",
    "CANCELLED", "DROPPED", "FAILED", "TERMINAL",
]
