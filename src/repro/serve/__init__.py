"""Continuous-batching serve engine: slot-pooled int8 KV cache, FCFS
scheduler, recompile-free join/evict step loop, the fault-tolerance
layer (deadlines, cancellation, quarantine + replay), the replica
fleet (router, health-based failover, cross-replica migration), and
the durable serving plane (write-ahead request journal, subprocess
replica workers, whole-fleet crash recovery).  See README.md in this
package for the architecture, the static-shape contract, and the
failure semantics."""
from repro.serve.cache_pool import SlotPool, scatter_request
from repro.serve.engine import ServeEngine, default_buckets, supports
from repro.serve.faults import (FaultEvent, FaultInjector, FaultPlan,
                                FleetFaultInjector, SimulatedCrash,
                                chaos_plan, crash_after_appends,
                                poison_slot, tear_tail)
from repro.serve.journal import (JournalState, RequestJournal, load_state,
                                 WAL_KINDS)
from repro.serve.metrics import ServeMetrics, fleet_summary
from repro.serve.router import (ACCEPTING, DEAD, DEGRADED, DRAINED,
                                DRAINING, HEALTHY, QUARANTINED,
                                BreakerConfig, FleetRequest, Router,
                                make_fleet)
from repro.serve.sampling import (fold_request_key, make_sampler,
                                  sample_tokens, sample_tokens_per_row)
from repro.serve.scheduler import (CANCELLED, DECODE, DONE, DROPPED, FAILED,
                                   MIGRATED, PREFILL, QUEUED, TERMINAL,
                                   AdmissionRejected, Request, Scheduler)
from repro.serve.trace import TraceRequest, synthetic_trace
from repro.serve.worker import (WorkerDied, WorkerProxy, engine_factory,
                                spawn_worker, spawn_workers)

__all__ = [
    "ServeEngine", "SlotPool", "Scheduler", "Request", "ServeMetrics",
    "TraceRequest", "synthetic_trace", "scatter_request", "sample_tokens",
    "sample_tokens_per_row", "fold_request_key",
    "make_sampler", "default_buckets", "supports",
    "FaultPlan", "FaultEvent", "FaultInjector", "FleetFaultInjector",
    "chaos_plan", "poison_slot", "AdmissionRejected",
    "SimulatedCrash", "crash_after_appends", "tear_tail",
    "RequestJournal", "JournalState", "load_state", "WAL_KINDS",
    "WorkerProxy", "WorkerDied", "spawn_worker", "spawn_workers",
    "engine_factory",
    "Router", "BreakerConfig", "FleetRequest", "make_fleet",
    "fleet_summary",
    "HEALTHY", "DEGRADED", "QUARANTINED", "DRAINING", "DRAINED", "DEAD",
    "ACCEPTING",
    "QUEUED", "PREFILL", "DECODE", "DONE",
    "CANCELLED", "DROPPED", "FAILED", "MIGRATED", "TERMINAL",
]
