"""Continuous-batching serve engine: slot-pooled int8 KV cache, FCFS
scheduler, and a recompile-free join/evict step loop.  See README.md in
this package for the architecture and the static-shape contract."""
from repro.serve.cache_pool import SlotPool, scatter_request
from repro.serve.engine import ServeEngine, default_buckets, supports
from repro.serve.metrics import ServeMetrics
from repro.serve.sampling import make_sampler, sample_tokens
from repro.serve.scheduler import (DECODE, DONE, PREFILL, QUEUED, Request,
                                   Scheduler)
from repro.serve.trace import TraceRequest, synthetic_trace

__all__ = [
    "ServeEngine", "SlotPool", "Scheduler", "Request", "ServeMetrics",
    "TraceRequest", "synthetic_trace", "scatter_request", "sample_tokens",
    "make_sampler", "default_buckets", "supports",
    "QUEUED", "PREFILL", "DECODE", "DONE",
]
