"""Shared tile-bounds machinery for the sparse Pallas grids.

Both kernel families that walk a KV axis — ``kernels/flash`` (prefill /
training attention) and ``kernels/kvq`` (split-K int8 decode) — shape their
grids from the same idea: masked schedules (causal, sliding window, padded
``kv_len``, per-batch decode ``lengths``) leave whole tiles with no live
position, and the bounds that say *which* tiles are live are pure
arithmetic that can run on Python ints (static grid sizing, planner
budgets, analytic visit counts) and on traced values (BlockSpec index
maps, scalar-prefetch refs, kernel bodies) alike.  This module is that one
arithmetic source; the kernels, the memory planner and the tests all
import it so measured and budgeted tile counts can never drift apart
silently.

Flash (prefill/training) bounds: :func:`kv_tile_bounds`,
:func:`q_tile_bounds`, :func:`tile_step_counts` — see
``kernels/flash/kernel.py`` for how the wedge grids consume them.

Decode (split-K) bounds: :func:`resolve_decode_grid` sizes the
(splits, steps-per-split) axes, :func:`decode_last_live_tile` turns a
per-batch ``length`` into the last KV tile worth visiting (Python int or
traced scalar-prefetch read), and :func:`decode_tile_step_counts` is the
analytic twin of the decode kernel's ``debug_counts`` counters.
"""
from __future__ import annotations

import jax.numpy as jnp

NEG_INF = -1e30
DEFAULT_BQ = 128
DEFAULT_BK = 128
DEFAULT_DECODE_BS = 512


def imin(a, b):
    """min that stays a Python int on Python ints (static grid sizing)
    and lowers to jnp.minimum on traced indices (index maps, kernels)."""
    if isinstance(a, int) and isinstance(b, int):
        return min(a, b)
    return jnp.minimum(a, b)


def imax(a, b):
    if isinstance(a, int) and isinstance(b, int):
        return max(a, b)
    return jnp.maximum(a, b)


def when(pred, fn):
    """pl.when that constant-folds Python-bool predicates."""
    from jax.experimental import pallas as pl
    if pred is True:
        fn()
    elif pred is not False:
        pl.when(pred)(fn)


# ---------------------------------------------------------------------------
# Flash (prefill / training) grids.
# ---------------------------------------------------------------------------
def kv_tile_bounds(qi, *, bq, bk, causal, window, kv_len):
    """Inclusive KV-tile range [lo, hi] that q tile ``qi`` must visit.

    Derived from the same geometry as the flash kernels' position mask: a
    KV tile outside [lo, hi] contains no (q_pos, k_pos) pair that the mask
    admits for any row of q tile ``qi``.  Pure arithmetic — ``qi`` may be
    a Python int (static grid sizing, visit counting) or a traced grid
    index (BlockSpec index maps, kernel bodies); non-causal bounds are
    always Python ints, so a padded KV tail shrinks the grid statically.

    ``hi`` is clamped >= ``lo`` so every q tile visits at least one step
    (the online-softmax finalize needs a step to run on; a fully-masked
    row zeroes itself through the in-tile mask).
    """
    hi_valid = -(-kv_len // bk) - 1            # last non-padded KV tile
    if not causal:
        return 0, hi_valid
    hi = imin(hi_valid, ((qi + 1) * bq - 1) // bk)
    lo = 0
    if window > 0:
        lo = imax(0, (qi * bq - (window - 1)) // bk)
        hi = imax(hi, lo)
    return lo, hi


def q_tile_bounds(ki, *, bq, bk, causal, window, n_q, kv_len):
    """Inclusive Q-tile range [lo, hi] that KV tile ``ki`` must visit on
    the dKV grid (which q tiles can attend into this KV tile).  Same
    contract as :func:`kv_tile_bounds`; the window reach is measured from
    the last LIVE position of the tile (``kv_len`` ragged tail), so the
    bounds are tight even on the ragged tile.  Fully-padded KV tiles
    (beyond ``kv_len``) keep a one-step range and are compute-skipped
    in-kernel via the ``pl.when`` early-out instead (their dK/dV are
    zeros)."""
    if not causal:
        return 0, n_q - 1
    lo = imin((ki * bk) // bq, n_q - 1)
    hi = n_q - 1
    if window > 0:
        khi = imax(imin((ki + 1) * bk, kv_len), ki * bk + 1) - 1
        hi = imin(hi, (khi + window - 1) // bq)
        hi = imax(hi, lo)
    return lo, hi


def kv_visits(s_len, *, bq, bk, causal, window, kv_len):
    """Per-q-tile visited KV-step counts (Python ints; fwd and dQ grids)."""
    return [hi - lo + 1 for lo, hi in
            (kv_tile_bounds(i, bq=bq, bk=bk, causal=causal, window=window,
                            kv_len=kv_len) for i in range(s_len // bq))]


def q_visits(s_len, *, bq, bk, causal, window, kv_len):
    """Per-KV-tile visited Q-step counts (dKV grid, per GQA group member).
    Fully-padded KV tiles count 0 — the kernel's early-out skips them."""
    n_q = s_len // bq
    out = []
    for j in range(s_len // bk):
        if j * bk >= kv_len:
            out.append(0)
            continue
        lo, hi = q_tile_bounds(j, bq=bq, bk=bk, causal=causal, window=window,
                               n_q=n_q, kv_len=kv_len)
        out.append(hi - lo + 1)
    return out


def tile_step_counts(s_len, *, bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                     causal: bool = True, window: int = 0,
                     kv_len: int | None = None) -> dict:
    """Analytic visited-vs-dense tile-step counts, per attention head.

    The exact twin of the flash kernels' ``debug_counts`` counters:
    ``fwd`` and ``dq`` sum the wedge-grid KV steps whose matmuls execute,
    ``dkv`` the Q steps per GQA group member, and ``dense`` is the
    nQ * nK rectangle a mask-blind grid would run.  The planner's flash
    FLOP budgets (``repro.plan.flash_bwd_recompute_flops``) and the
    benchmark claw-back numbers are both computed from these counts, so
    kernel, planner and report can never drift apart silently.
    """
    kv_len = s_len if kv_len is None else kv_len
    bq, bk = min(bq, s_len), min(bk, s_len)
    kw = dict(bq=bq, bk=bk, causal=causal, window=window, kv_len=kv_len)
    fwd = sum(kv_visits(s_len, **kw))
    dkv = sum(q_visits(s_len, **kw))
    return {"fwd": fwd, "dq": fwd, "dkv": dkv,
            "dense": (s_len // bq) * (s_len // bk),
            "bq": bq, "bk": bk}


# ---------------------------------------------------------------------------
# Split-K decode grid (kernels/kvq).
# ---------------------------------------------------------------------------
def resolve_decode_block(s: int, block_s: int) -> int:
    """Largest power-of-two-ish shrink of ``block_s`` that divides S."""
    bs = min(block_s, s)
    while s % bs:
        bs //= 2
    assert bs >= 1, (s, block_s)
    return bs


def resolve_decode_grid(s: int, *, block_s: int = DEFAULT_DECODE_BS,
                        splits: int = 1) -> tuple[int, int, int, int]:
    """-> (bs, ns, splits_eff, steps_per_split) for a length-S KV cache.

    ``splits`` is clamped to the tile count (a split with no tiles would
    be pure overhead); the last split's structural padding tiles
    (``splits_eff * steps_per_split > ns``) are early-outed in-kernel and
    never counted by :func:`decode_tile_step_counts`.
    """
    bs = resolve_decode_block(s, block_s)
    ns = s // bs
    splits_eff = max(1, min(int(splits), ns))
    spt = -(-ns // splits_eff)
    return bs, ns, splits_eff, spt


def decode_last_live_tile(length, *, bs, ns):
    """Last KV tile a batch row with ``length`` valid slots must visit
    (inclusive; clamped to [0, ns-1] so index maps always point at a real
    tile).  ``length`` may be a Python int or a traced scalar-prefetch
    read — same dual contract as :func:`kv_tile_bounds`."""
    return imin(ns - 1, imax(0, (length + bs - 1) // bs - 1))


def decode_tile_step_counts(s: int, lengths=None, *,
                            block_s: int = DEFAULT_DECODE_BS,
                            splits: int = 1) -> dict:
    """Analytic twin of the split-K decode kernel's ``debug_counts``.

    ``lengths``: per-batch valid cache lengths (ints), or None (= every
    slot valid).  ``counts[b][k]`` is the number of KV tile-steps split
    ``k`` of batch row ``b`` actually executes — tiles whose start lies
    below ``lengths[b]`` — exactly the kernel's ``pl.when`` predicate.
    ``dense`` is the B * ns tile-steps a length-blind sequential sweep
    pays per kv head.  The planner's decode report
    (``repro.plan.decode_tile_report``) and BENCH_decode.json both build
    on these counts.
    """
    bs, ns, splits_eff, spt = resolve_decode_grid(s, block_s=block_s,
                                                  splits=splits)
    lens = [s] if lengths is None else [int(x) for x in lengths]
    counts = []
    for ln in lens:
        if ln <= 0:
            counts.append([0] * splits_eff)
            continue
        hi = decode_last_live_tile(ln, bs=bs, ns=ns)
        counts.append([max(0, min(hi, min((k + 1) * spt, ns) - 1)
                           - k * spt + 1)
                       for k in range(splits_eff)])
    visited = sum(sum(row) for row in counts)
    return {"bs": bs, "ns": ns, "splits": splits_eff, "spt": spt,
            "counts": counts, "visited": visited,
            "dense": len(lens) * ns}
