"""Oracle for the flash-attention prefill kernel: exact GQA softmax."""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def flash_ref(q, k, v, *, causal: bool = True, window: int = 0,
              sm_scale: float | None = None):
    """q: (B, H, S, D); k, v: (B, Hkv, S, D) -> (B, H, S, D), f32 math."""
    b, h, s, d = q.shape
    hkv = k.shape[1]
    g = h // hkv
    scale = sm_scale if sm_scale is not None else d ** -0.5
    qg = q.reshape(b, hkv, g, s, d).astype(jnp.float32)
    logits = jnp.einsum("bhgqd,bhkd->bhgqk", qg,
                        k.astype(jnp.float32)) * scale
    if causal:
        qpos = jnp.arange(s)[:, None]
        kpos = jnp.arange(s)[None, :]
        ok = qpos >= kpos
        if window > 0:
            ok &= (qpos - kpos) < window
        logits = jnp.where(ok[None, None, None], logits, NEG_INF)
    p = jax.nn.softmax(logits, axis=-1)
    out = jnp.einsum("bhgqk,bhkd->bhgqd", p, v.astype(jnp.float32))
    return out.reshape(b, h, s, d).astype(q.dtype)
