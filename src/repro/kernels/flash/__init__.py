from repro.kernels.flash import ops, ref  # noqa: F401
