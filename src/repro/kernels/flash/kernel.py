"""Pallas TPU flash-attention: forward AND backward (trainable).

Forward — classic tiling: grid (B*H, nQ, nK) with the KV axis innermost
(sequential on TPU), online-softmax running stats in VMEM scratch per Q
tile.  GQA is handled in the BlockSpec index maps (KV tiles load from head
h // group).  The forward also emits the per-row softmax stats (m, l) so
the backward can recompute probabilities without the (S x S) matrix.

Backward — the Chen et al. recompute-over-store trade applied inside the
attention op, split into three kernels:

  * ``_bwd_delta_kernel``  D_i = rowsum(dO_i * O_i), grid (B*H, nQ) — the
    softmax-backward correction term, one f32 per row.
  * ``_bwd_dq_kernel``     grid (B*H, nQ, nK), KV innermost: recompute
    P = exp(S - lse) from (m, l), dP = dO V^T, dS = P (dP - D), and
    accumulate dQ += dS K * scale in VMEM scratch.
  * ``_bwd_dkv_kernel``    grid (B*Hkv, nK, group, nQ), Q innermost with
    the GQA group as the next-inner axis so dK/dV accumulate over every
    query head sharing the KV head before the single output write:
    dV += P^T dO, dK += dS^T Q * scale.

Residuals between fwd and bwd are q, k, v, o, m, l — O(S*D) per head, not
O(S^2); the score/probability matrices are recomputed tile-by-tile (an
extra ~2x of the forward QK^T FLOPs across dQ+dKV, the flash trade).

MXU shapes: every contraction is (128, D) x (D, 128) or (128, 128) x
(128, D) with D in {64, 128} — lane-aligned (ops.py guards other shapes).

Causal/window masking compares absolute positions built from grid indices;
whole-tile-masked steps still execute (Pallas grids are dense) but their
contribution is zeroed.  ``kv_len`` masks padded KV columns so ops.py's
length padding is safe for non-causal attention too.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BQ = 128
DEFAULT_BK = 128


def _position_mask(qi, ki, *, bq, bk, causal, window, kv_len, s_len):
    """(BQ, BK) bool validity mask from grid indices, or None if trivial."""
    if not causal and kv_len >= s_len:
        return None
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    ok = k_pos < kv_len
    if causal:
        ok &= q_pos >= k_pos
        if window > 0:
            ok &= (q_pos - k_pos) < window
    return ok


# ---------------------------------------------------------------------------
# Forward.
# ---------------------------------------------------------------------------
def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_out_ref, l_out_ref,
                  m_ref, l_ref, acc_ref, *,
                  sm_scale, n_k, bq, bk, causal, window, kv_len, s_len):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full(m_ref.shape, NEG_INF, jnp.float32)
        l_ref[...] = jnp.zeros(l_ref.shape, jnp.float32)
        acc_ref[...] = jnp.zeros(acc_ref.shape, jnp.float32)

    q = q_ref[...][0].astype(jnp.float32)                  # (BQ, D)
    k = k_ref[...][0].astype(jnp.float32)                  # (BK, D)
    v = v_ref[...][0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * sm_scale

    ok = _position_mask(qi, ki, bq=bq, bk=bk, causal=causal, window=window,
                        kv_len=kv_len, s_len=s_len)
    if ok is not None:
        s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _done():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = (acc_ref[...] / denom[:, None])[None].astype(o_ref.dtype)
        m_out_ref[...] = m_ref[...][None]
        l_out_ref[...] = l_ref[...][None]


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "sm_scale", "bq", "bk", "kv_len", "interpret"))
def flash_attention_fwd_pallas(q, k, v, *, causal: bool = True,
                               window: int = 0,
                               sm_scale: float | None = None,
                               bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                               kv_len: int | None = None,
                               interpret: bool = False):
    """q: (BH, S, D); k, v: (BHkv, S, D) with BH = BHkv * group.

    Returns (o, m, l): output plus the per-row online-softmax stats
    (running max, running denominator), both (BH, S) f32 — the residuals
    the backward kernels recompute probabilities from.

    Flat batch*head layout; the wrapper in ops.py folds (B, H) and GQA.
    S % bq == 0 and S % bk == 0 (ops.py pads); ``kv_len`` (< S when ops.py
    padded) masks the padded KV columns.
    """
    bh, s_len, d = q.shape
    bhkv = k.shape[0]
    group = bh // bhkv
    bq = min(bq, s_len)
    bk = min(bk, s_len)
    assert s_len % bq == 0 and s_len % bk == 0, (s_len, bq, bk)
    n_q, n_k = s_len // bq, s_len // bk
    scale = sm_scale if sm_scale is not None else d ** -0.5
    kv_len = s_len if kv_len is None else kv_len

    return pl.pallas_call(
        functools.partial(_flash_kernel, sm_scale=scale, n_k=n_k, bq=bq,
                          bk=bk, causal=causal, window=window, kv_len=kv_len,
                          s_len=s_len),
        grid=(bh, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j, g=group: (h // g, j, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j, g=group: (h // g, j, 0)),
        ],
        out_specs=[
            pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bq), lambda h, i, j: (h, i)),
            pl.BlockSpec((1, bq), lambda h, i, j: (h, i)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bh, s_len, d), q.dtype),
            jax.ShapeDtypeStruct((bh, s_len), jnp.float32),
            jax.ShapeDtypeStruct((bh, s_len), jnp.float32),
        ],
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),        # running max
            pltpu.VMEM((bq,), jnp.float32),        # running denom
            pltpu.VMEM((bq, d), jnp.float32),      # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)


# ---------------------------------------------------------------------------
# Backward.
# ---------------------------------------------------------------------------
def _bwd_delta_kernel(o_ref, do_ref, delta_ref):
    """D = rowsum(dO * O): the softmax-backward correction, (BQ,) f32."""
    o = o_ref[...][0].astype(jnp.float32)
    do = do_ref[...][0].astype(jnp.float32)
    delta_ref[...] = (o * do).sum(axis=-1)[None]


def _recompute_probs(q, k, m, l, ok, *, sm_scale):
    """P = exp(S - lse) from saved stats; masked entries exactly zero."""
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * sm_scale
    lse = m + jnp.log(jnp.maximum(l, 1e-30))
    p = jnp.exp(s - lse[:, None])
    if ok is not None:
        p = jnp.where(ok, p, 0.0)
    return p


def _bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, m_ref, l_ref, delta_ref,
                   dq_ref, acc_ref, *,
                   sm_scale, n_k, bq, bk, causal, window, kv_len, s_len):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        acc_ref[...] = jnp.zeros(acc_ref.shape, jnp.float32)

    q = q_ref[...][0].astype(jnp.float32)                  # (BQ, D)
    k = k_ref[...][0].astype(jnp.float32)                  # (BK, D)
    v = v_ref[...][0].astype(jnp.float32)
    do = do_ref[...][0].astype(jnp.float32)
    m = m_ref[...][0]
    l = l_ref[...][0]
    delta = delta_ref[...][0]

    ok = _position_mask(qi, ki, bq=bq, bk=bk, causal=causal, window=window,
                        kv_len=kv_len, s_len=s_len)
    p = _recompute_probs(q, k, m, l, ok, sm_scale=sm_scale)      # (BQ, BK)
    dp = jnp.dot(do, v.T, preferred_element_type=jnp.float32)    # (BQ, BK)
    ds = p * (dp - delta[:, None])
    acc_ref[...] += jnp.dot(ds, k, preferred_element_type=jnp.float32)

    @pl.when(ki == n_k - 1)
    def _done():
        dq_ref[...] = (acc_ref[...] * sm_scale)[None].astype(dq_ref.dtype)


def _bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, m_ref, l_ref, delta_ref,
                    dk_ref, dv_ref, dk_acc, dv_acc, *,
                    sm_scale, n_q, group, bq, bk, causal, window, kv_len,
                    s_len):
    # grid (B*Hkv, nK, group, nQ): Q tiles innermost, then the GQA group so
    # dK/dV accumulate over every query head sharing this KV head before
    # the single output write.
    ki = pl.program_id(1)
    gi = pl.program_id(2)
    qi = pl.program_id(3)

    @pl.when((gi == 0) & (qi == 0))
    def _init():
        dk_acc[...] = jnp.zeros(dk_acc.shape, jnp.float32)
        dv_acc[...] = jnp.zeros(dv_acc.shape, jnp.float32)

    q = q_ref[...][0].astype(jnp.float32)                  # (BQ, D)
    k = k_ref[...][0].astype(jnp.float32)                  # (BK, D)
    v = v_ref[...][0].astype(jnp.float32)
    do = do_ref[...][0].astype(jnp.float32)
    m = m_ref[...][0]
    l = l_ref[...][0]
    delta = delta_ref[...][0]

    ok = _position_mask(qi, ki, bq=bq, bk=bk, causal=causal, window=window,
                        kv_len=kv_len, s_len=s_len)
    p = _recompute_probs(q, k, m, l, ok, sm_scale=sm_scale)      # (BQ, BK)
    dv_acc[...] += jnp.dot(p.T, do, preferred_element_type=jnp.float32)
    dp = jnp.dot(do, v.T, preferred_element_type=jnp.float32)
    ds = p * (dp - delta[:, None])
    dk_acc[...] += jnp.dot(ds.T, q, preferred_element_type=jnp.float32)

    @pl.when((gi == group - 1) & (qi == n_q - 1))
    def _done():
        dk_ref[...] = (dk_acc[...] * sm_scale)[None].astype(dk_ref.dtype)
        dv_ref[...] = dv_acc[...][None].astype(dv_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "sm_scale", "bq", "bk", "kv_len", "interpret"))
def flash_attention_bwd_pallas(q, k, v, o, m, l, do, *, causal: bool = True,
                               window: int = 0,
                               sm_scale: float | None = None,
                               bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                               kv_len: int | None = None,
                               interpret: bool = False):
    """Backward from saved residuals: (dq, dk, dv).

    q, do: (BH, S, D); k, v: (BHkv, S, D); o: (BH, S, D); m, l: (BH, S)
    f32 stats from ``flash_attention_fwd_pallas``.  The score matrix is
    recomputed tile-by-tile in both the dQ and dKV kernels — residual
    memory stays O(S*D).
    """
    bh, s_len, d = q.shape
    bhkv = k.shape[0]
    group = bh // bhkv
    bq = min(bq, s_len)
    bk = min(bk, s_len)
    assert s_len % bq == 0 and s_len % bk == 0, (s_len, bq, bk)
    n_q, n_k = s_len // bq, s_len // bk
    scale = sm_scale if sm_scale is not None else d ** -0.5
    kv_len = s_len if kv_len is None else kv_len
    mask_kw = dict(causal=causal, window=window, kv_len=kv_len, s_len=s_len)

    delta = pl.pallas_call(
        _bwd_delta_kernel,
        grid=(bh, n_q),
        in_specs=[pl.BlockSpec((1, bq, d), lambda h, i: (h, i, 0)),
                  pl.BlockSpec((1, bq, d), lambda h, i: (h, i, 0))],
        out_specs=pl.BlockSpec((1, bq), lambda h, i: (h, i)),
        out_shape=jax.ShapeDtypeStruct((bh, s_len), jnp.float32),
        interpret=interpret,
    )(o, do)

    dq = pl.pallas_call(
        functools.partial(_bwd_dq_kernel, sm_scale=scale, n_k=n_k, bq=bq,
                          bk=bk, **mask_kw),
        grid=(bh, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j, g=group: (h // g, j, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j, g=group: (h // g, j, 0)),
            pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bq), lambda h, i, j: (h, i)),
            pl.BlockSpec((1, bq), lambda h, i, j: (h, i)),
            pl.BlockSpec((1, bq), lambda h, i, j: (h, i)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s_len, d), q.dtype),
        scratch_shapes=[pltpu.VMEM((bq, d), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, m, l, delta)

    def _q_head(hk, j, gi, i, g=group):
        del j, i
        return hk * g + gi

    dk, dv = pl.pallas_call(
        functools.partial(_bwd_dkv_kernel, sm_scale=scale, n_q=n_q,
                          group=group, bq=bq, bk=bk, **mask_kw),
        grid=(bhkv, n_k, group, n_q),
        in_specs=[
            pl.BlockSpec((1, bq, d),
                         lambda hk, j, gi, i: (_q_head(hk, j, gi, i), i, 0)),
            pl.BlockSpec((1, bk, d), lambda hk, j, gi, i: (hk, j, 0)),
            pl.BlockSpec((1, bk, d), lambda hk, j, gi, i: (hk, j, 0)),
            pl.BlockSpec((1, bq, d),
                         lambda hk, j, gi, i: (_q_head(hk, j, gi, i), i, 0)),
            pl.BlockSpec((1, bq),
                         lambda hk, j, gi, i: (_q_head(hk, j, gi, i), i)),
            pl.BlockSpec((1, bq),
                         lambda hk, j, gi, i: (_q_head(hk, j, gi, i), i)),
            pl.BlockSpec((1, bq),
                         lambda hk, j, gi, i: (_q_head(hk, j, gi, i), i)),
        ],
        out_specs=[
            pl.BlockSpec((1, bk, d), lambda hk, j, gi, i: (hk, j, 0)),
            pl.BlockSpec((1, bk, d), lambda hk, j, gi, i: (hk, j, 0)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((bhkv, s_len, d), k.dtype),
            jax.ShapeDtypeStruct((bhkv, s_len, d), v.dtype),
        ],
        scratch_shapes=[pltpu.VMEM((bk, d), jnp.float32),
                        pltpu.VMEM((bk, d), jnp.float32)],
        interpret=interpret,
    )(q, k, v, do, m, l, delta)
    return dq, dk, dv
