"""Pallas TPU flash-attention: forward AND backward (trainable), on
SPARSITY-AWARE grids.

Forward — classic tiling: grid (B*H, nQ, kv_steps) with the KV axis
innermost (sequential on TPU), online-softmax running stats in VMEM scratch
per Q tile.  GQA is handled in the BlockSpec index maps (KV tiles load from
head h // group).  The forward also emits the per-row softmax stats (m, l)
so the backward can recompute probabilities without the (S x S) matrix.

Backward — the Chen et al. recompute-over-store trade applied inside the
attention op, split into three kernels:

  * ``_bwd_delta_kernel``  D_i = rowsum(dO_i * O_i), grid (B*H, nQ) — the
    softmax-backward correction term, one f32 per row.
  * ``_bwd_dq_kernel``     grid (B*H, nQ, kv_steps), KV innermost:
    recompute P = exp(S - lse) from (m, l), dP = dO V^T, dS = P (dP - D),
    and accumulate dQ += dS K * scale in VMEM scratch.
  * ``_bwd_dkv_kernel``    grid (B*Hkv, nK, group, q_steps), Q innermost
    with the GQA group as the next-inner axis so dK/dV accumulate over
    every query head sharing the KV head before the single output write:
    dV += P^T dO, dK += dS^T Q * scale.

Residuals between fwd and bwd are q, k, v, o, m, l — O(S*D) per head, not
O(S^2); the score/probability matrices are recomputed tile-by-tile (an
extra ~2x of the forward QK^T FLOPs across dQ+dKV, the flash trade).

Sparse grids — Pallas grids are dense rectangles, but masked schedules
(causal / sliding window / padded kv_len) leave whole tiles with no live
position.  ``kv_tile_bounds`` / ``q_tile_bounds`` (hoisted into
``repro.kernels.tiling``, shared with the kvq split-K decode kernel)
derive, from the same geometry as ``_position_mask``, the inclusive tile
range each grid row actually has to visit, and the kernels exploit them
three ways:

  1. the forward and dQ grids remap their KV axis to a *wedge*: step ``j``
     of q tile ``qi`` loads KV tile ``lo(qi) + j`` and the axis extent is
     ``max_i (hi(i) - lo(i) + 1)`` — for windowed schedules the grid itself
     shrinks to ~W/S of the dense step count;
  2. the dKV grid mirrors the trick on its innermost Q axis
     (``qi ∈ [first_unmasked_q(ki), nQ)`` for causal, banded for window);
  3. where the extent cannot shrink statically (causal: the last q tile
     still needs every KV tile), a ``pl.when`` whole-tile early-out skips
     the QK/PV matmuls of unvisited steps while the online-softmax carry /
     accumulators thread through untouched.  The online-softmax init /
     finalize move to the remapped first / last *visited* step.

Skipped steps clamp their BlockSpec index to the last visited tile, so
Pallas re-uses the resident block instead of issuing a new DMA.  With
``debug_counts=True`` (interpret or compiled) every kernel additionally
returns per-tile-row counters of how many inner steps actually executed
their matmuls — the measured visited-tile counts that tests, benchmarks
and the memory planner's FLOP budgets are validated against
(:func:`tile_step_counts` is the analytic twin).

MXU shapes: every contraction is (128, D) x (D, 128) or (128, 128) x
(128, D) with D in {64, 128} — lane-aligned (ops.py guards other shapes).

Causal/window masking inside a visited tile still compares absolute
positions built from the (remapped) grid indices; ``kv_len`` masks padded
KV columns so ops.py's length padding is safe for non-causal attention too.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

# The tile-bounds machinery lives in repro.kernels.tiling (shared with the
# kvq split-K decode grids); re-exported here because this module is the
# flash family's historical home for it.
from repro.kernels.tiling import (DEFAULT_BK, DEFAULT_BQ, NEG_INF,  # noqa: F401
                                  imax as _imax, imin as _imin,
                                  kv_tile_bounds, q_tile_bounds,
                                  kv_visits as _kv_visits,
                                  q_visits as _q_visits, tile_step_counts,
                                  when as _when)


def _position_mask(qi, ki, *, bq, bk, causal, window, kv_len, s_len):
    """(BQ, BK) bool validity mask from grid indices, or None if trivial.

    ``qi``/``ki`` are LOGICAL tile indices — on the sparse grids they are
    the remapped values (e.g. ``lo(qi) + j``), not raw program ids."""
    if not causal and kv_len >= s_len:
        return None
    q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
    k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    ok = k_pos < kv_len
    if causal:
        ok &= q_pos >= k_pos
        if window > 0:
            ok &= (q_pos - k_pos) < window
    return ok


# ---------------------------------------------------------------------------
# Forward.
# ---------------------------------------------------------------------------
def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_out_ref, l_out_ref, *refs,
                  sm_scale, bq, bk, causal, window, kv_len, s_len, count):
    if count:
        (cnt_ref, m_ref, l_ref, acc_ref, cnt_acc) = refs
    else:
        (m_ref, l_ref, acc_ref) = refs
    qi = pl.program_id(1)
    ji = pl.program_id(2)                      # wedge step, NOT the KV tile
    lo, hi = kv_tile_bounds(qi, bq=bq, bk=bk, causal=causal, window=window,
                            kv_len=kv_len)
    ki = lo + ji                               # logical KV tile this step
    n_vis = hi - lo + 1
    # Static bounds (non-causal) shrink the grid axis to exactly n_vis, so
    # every step is visited; traced bounds (causal) keep a dense axis and
    # early-out the unvisited tail.
    visited = True if isinstance(n_vis, int) else ji < n_vis

    @pl.when(ji == 0)
    def _init():
        m_ref[...] = jnp.full(m_ref.shape, NEG_INF, jnp.float32)
        l_ref[...] = jnp.zeros(l_ref.shape, jnp.float32)
        acc_ref[...] = jnp.zeros(acc_ref.shape, jnp.float32)
        if count:
            cnt_acc[...] = jnp.zeros(cnt_acc.shape, jnp.int32)

    def _step():
        q = q_ref[...][0].astype(jnp.float32)                  # (BQ, D)
        k = k_ref[...][0].astype(jnp.float32)                  # (BK, D)
        v = v_ref[...][0].astype(jnp.float32)
        s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * sm_scale

        ok = _position_mask(qi, ki, bq=bq, bk=bk, causal=causal,
                            window=window, kv_len=kv_len, s_len=s_len)
        if ok is not None:
            s = jnp.where(ok, s, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, s.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(s - m_new[:, None])
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
        m_ref[...] = m_new
        if count:
            cnt_acc[...] += 1

    _when(visited, _step)

    @pl.when(ji == n_vis - 1)
    def _done():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = (acc_ref[...] / denom[:, None])[None].astype(o_ref.dtype)
        m_out_ref[...] = m_ref[...][None]
        l_out_ref[...] = l_ref[...][None]
        if count:
            cnt_ref[...] = cnt_acc[...].reshape(cnt_ref.shape)


def _kv_wedge_index(group, bounds_kw):
    """Index map for K/V on the (h, qi, j) wedge grids: step j of q tile i
    loads logical KV tile min(lo(i) + j, hi(i)) — clamping the unvisited
    tail to the last visited tile makes Pallas re-use the resident block
    (no DMA) on exactly the steps the kernel early-outs."""
    def index(h, i, j, g=group, kw=bounds_kw):
        lo, hi = kv_tile_bounds(i, **kw)
        return (h // g, _imin(lo + j, hi), 0)
    return index


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "sm_scale", "bq", "bk", "kv_len", "interpret",
    "debug_counts"))
def flash_attention_fwd_pallas(q, k, v, *, causal: bool = True,
                               window: int = 0,
                               sm_scale: float | None = None,
                               bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                               kv_len: int | None = None,
                               interpret: bool = False,
                               debug_counts: bool = False):
    """q: (BH, S, D); k, v: (BHkv, S, D) with BH = BHkv * group.

    Returns (o, m, l): output plus the per-row online-softmax stats
    (running max, running denominator), both (BH, S) f32 — the residuals
    the backward kernels recompute probabilities from.  With
    ``debug_counts`` also returns a (BH, nQ) int32 array counting the KV
    steps whose matmuls executed per q tile (the measured sparse-grid
    visit counts; compare against :func:`tile_step_counts`).

    Flat batch*head layout; the wrapper in ops.py folds (B, H) and GQA.
    S % bq == 0 and S % bk == 0 (ops.py pads); ``kv_len`` (< S when ops.py
    padded) masks the padded KV columns.
    """
    bh, s_len, d = q.shape
    bhkv = k.shape[0]
    group = bh // bhkv
    bq = min(bq, s_len)
    bk = min(bk, s_len)
    assert s_len % bq == 0 and s_len % bk == 0, (s_len, bq, bk)
    n_q = s_len // bq
    scale = sm_scale if sm_scale is not None else d ** -0.5
    kv_len = s_len if kv_len is None else kv_len
    bounds_kw = dict(bq=bq, bk=bk, causal=causal, window=window,
                     kv_len=kv_len)
    kv_steps = max(_kv_visits(s_len, **bounds_kw))

    out_specs = [
        pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
        pl.BlockSpec((1, bq), lambda h, i, j: (h, i)),
        pl.BlockSpec((1, bq), lambda h, i, j: (h, i)),
    ]
    out_shape = [
        jax.ShapeDtypeStruct((bh, s_len, d), q.dtype),
        jax.ShapeDtypeStruct((bh, s_len), jnp.float32),
        jax.ShapeDtypeStruct((bh, s_len), jnp.float32),
    ]
    if debug_counts:
        out_specs.append(pl.BlockSpec((1, 1), lambda h, i, j: (h, i)))
        out_shape.append(jax.ShapeDtypeStruct((bh, n_q), jnp.int32))

    return pl.pallas_call(
        functools.partial(_flash_kernel, sm_scale=scale, s_len=s_len,
                          count=debug_counts, **bounds_kw),
        grid=(bh, n_q, kv_steps),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, d), _kv_wedge_index(group, bounds_kw)),
            pl.BlockSpec((1, bk, d), _kv_wedge_index(group, bounds_kw)),
        ],
        out_specs=out_specs,
        out_shape=out_shape,
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),        # running max
            pltpu.VMEM((bq,), jnp.float32),        # running denom
            pltpu.VMEM((bq, d), jnp.float32),      # output accumulator
        ] + ([pltpu.SMEM((1,), jnp.int32)] if debug_counts else []),
        interpret=interpret,
    )(q, k, v)


# ---------------------------------------------------------------------------
# Backward.
# ---------------------------------------------------------------------------
def _bwd_delta_kernel(o_ref, do_ref, delta_ref):
    """D = rowsum(dO * O): the softmax-backward correction, (BQ,) f32."""
    o = o_ref[...][0].astype(jnp.float32)
    do = do_ref[...][0].astype(jnp.float32)
    delta_ref[...] = (o * do).sum(axis=-1)[None]


def _recompute_probs(q, k, m, l, ok, *, sm_scale):
    """P = exp(S - lse) from saved stats; masked entries exactly zero."""
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * sm_scale
    lse = m + jnp.log(jnp.maximum(l, 1e-30))
    p = jnp.exp(s - lse[:, None])
    if ok is not None:
        p = jnp.where(ok, p, 0.0)
    return p


def _bwd_dq_kernel(q_ref, k_ref, v_ref, do_ref, m_ref, l_ref, delta_ref,
                   dq_ref, *refs, sm_scale, bq, bk, causal, window, kv_len,
                   s_len, count):
    if count:
        (cnt_ref, acc_ref, cnt_acc) = refs
    else:
        (acc_ref,) = refs
    qi = pl.program_id(1)
    ji = pl.program_id(2)
    lo, hi = kv_tile_bounds(qi, bq=bq, bk=bk, causal=causal, window=window,
                            kv_len=kv_len)
    ki = lo + ji
    n_vis = hi - lo + 1
    visited = True if isinstance(n_vis, int) else ji < n_vis

    @pl.when(ji == 0)
    def _init():
        acc_ref[...] = jnp.zeros(acc_ref.shape, jnp.float32)
        if count:
            cnt_acc[...] = jnp.zeros(cnt_acc.shape, jnp.int32)

    def _step():
        q = q_ref[...][0].astype(jnp.float32)                  # (BQ, D)
        k = k_ref[...][0].astype(jnp.float32)                  # (BK, D)
        v = v_ref[...][0].astype(jnp.float32)
        do = do_ref[...][0].astype(jnp.float32)
        m = m_ref[...][0]
        l = l_ref[...][0]
        delta = delta_ref[...][0]

        ok = _position_mask(qi, ki, bq=bq, bk=bk, causal=causal,
                            window=window, kv_len=kv_len, s_len=s_len)
        p = _recompute_probs(q, k, m, l, ok, sm_scale=sm_scale)  # (BQ, BK)
        dp = jnp.dot(do, v.T, preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None])
        acc_ref[...] += jnp.dot(ds, k, preferred_element_type=jnp.float32)
        if count:
            cnt_acc[...] += 1

    _when(visited, _step)

    @pl.when(ji == n_vis - 1)
    def _done():
        dq_ref[...] = (acc_ref[...] * sm_scale)[None].astype(dq_ref.dtype)
        if count:
            cnt_ref[...] = cnt_acc[...].reshape(cnt_ref.shape)


def _bwd_dkv_kernel(q_ref, k_ref, v_ref, do_ref, m_ref, l_ref, delta_ref,
                    dk_ref, dv_ref, *refs, sm_scale, group, n_q, bq, bk,
                    causal, window, kv_len, s_len, count):
    # grid (B*Hkv, nK, group, q_steps): Q tiles innermost, then the GQA
    # group so dK/dV accumulate over every query head sharing this KV head
    # before the single output write.  The Q axis is the wedge: step ii of
    # KV tile ki touches logical q tile lo(ki) + ii.
    if count:
        (cnt_ref, dk_acc, dv_acc, cnt_acc) = refs
    else:
        (dk_acc, dv_acc) = refs
    ki = pl.program_id(1)
    gi = pl.program_id(2)
    ii = pl.program_id(3)
    lo, hi = q_tile_bounds(ki, bq=bq, bk=bk, causal=causal, window=window,
                           n_q=n_q, kv_len=kv_len)
    qi = lo + ii
    n_vis = hi - lo + 1
    visited = True if isinstance(n_vis, int) else ii < n_vis
    if kv_len < s_len:
        # whole-KV-tile early-out: a fully padded tile has no live q tile
        # at all (its dK/dV are zeros) — this axis can't shrink statically
        # because its neighbours still need their full Q range.
        live = ki * bk < kv_len
        visited = live if visited is True else visited & live

    @pl.when((gi == 0) & (ii == 0))
    def _init():
        dk_acc[...] = jnp.zeros(dk_acc.shape, jnp.float32)
        dv_acc[...] = jnp.zeros(dv_acc.shape, jnp.float32)
        if count:
            cnt_acc[...] = jnp.zeros(cnt_acc.shape, jnp.int32)

    def _step():
        q = q_ref[...][0].astype(jnp.float32)                  # (BQ, D)
        k = k_ref[...][0].astype(jnp.float32)                  # (BK, D)
        v = v_ref[...][0].astype(jnp.float32)
        do = do_ref[...][0].astype(jnp.float32)
        m = m_ref[...][0]
        l = l_ref[...][0]
        delta = delta_ref[...][0]

        ok = _position_mask(qi, ki, bq=bq, bk=bk, causal=causal,
                            window=window, kv_len=kv_len, s_len=s_len)
        p = _recompute_probs(q, k, m, l, ok, sm_scale=sm_scale)  # (BQ, BK)
        dv_acc[...] += jnp.dot(p.T, do, preferred_element_type=jnp.float32)
        dp = jnp.dot(do, v.T, preferred_element_type=jnp.float32)
        ds = p * (dp - delta[:, None])
        dk_acc[...] += jnp.dot(ds.T, q, preferred_element_type=jnp.float32)
        if count:
            cnt_acc[...] += 1

    _when(visited, _step)

    @pl.when((gi == group - 1) & (ii == n_vis - 1))
    def _done():
        dk_ref[...] = (dk_acc[...] * sm_scale)[None].astype(dk_ref.dtype)
        dv_ref[...] = dv_acc[...][None].astype(dv_ref.dtype)
        if count:
            cnt_ref[...] = cnt_acc[...].reshape(cnt_ref.shape)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "sm_scale", "bq", "bk", "kv_len", "interpret",
    "debug_counts", "grad_dtypes"))
def flash_attention_bwd_pallas(q, k, v, o, m, l, do, *, causal: bool = True,
                               window: int = 0,
                               sm_scale: float | None = None,
                               bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                               kv_len: int | None = None,
                               interpret: bool = False,
                               debug_counts: bool = False,
                               grad_dtypes: "tuple | None" = None):
    """Backward from saved residuals: (dq, dk, dv).

    q, do: (BH, S, D); k, v: (BHkv, S, D); o: (BH, S, D); m, l: (BH, S)
    f32 stats from ``flash_attention_fwd_pallas``.  The score matrix is
    recomputed tile-by-tile in both the dQ and dKV kernels — residual
    memory stays O(S*D) — and both grids are sparse (see module docs).
    With ``debug_counts`` additionally returns (dq_counts (BH, nQ),
    dkv_counts (BHkv, nK)) of executed inner steps (the dKV counter sums
    over the GQA group: group * visited q tiles when the KV tile is live).

    ``grad_dtypes`` (dtype names for dq, dk, dv) overrides the output
    dtypes, which default to following q/k/v — under a residual policy
    the saved q/k/v are bf16 but the gradients should leave the f32 VMEM
    accumulators at the PRIMAL precision, not round-trip through bf16.
    """
    bh, s_len, d = q.shape
    bhkv = k.shape[0]
    group = bh // bhkv
    bq = min(bq, s_len)
    bk = min(bk, s_len)
    assert s_len % bq == 0 and s_len % bk == 0, (s_len, bq, bk)
    n_q, n_k = s_len // bq, s_len // bk
    scale = sm_scale if sm_scale is not None else d ** -0.5
    kv_len = s_len if kv_len is None else kv_len
    bounds_kw = dict(bq=bq, bk=bk, causal=causal, window=window,
                     kv_len=kv_len)
    mask_kw = dict(causal=causal, window=window, kv_len=kv_len, s_len=s_len)
    kv_steps = max(_kv_visits(s_len, **bounds_kw))
    q_steps = max(hi - lo + 1 for lo, hi in
                  (q_tile_bounds(j, bq=bq, bk=bk, causal=causal,
                                 window=window, n_q=n_q, kv_len=kv_len)
                   for j in range(n_k)))
    dq_dt, dk_dt, dv_dt = (q.dtype, k.dtype, v.dtype) if grad_dtypes is \
        None else (jnp.dtype(t) for t in grad_dtypes)

    delta = pl.pallas_call(
        _bwd_delta_kernel,
        grid=(bh, n_q),
        in_specs=[pl.BlockSpec((1, bq, d), lambda h, i: (h, i, 0)),
                  pl.BlockSpec((1, bq, d), lambda h, i: (h, i, 0))],
        out_specs=pl.BlockSpec((1, bq), lambda h, i: (h, i)),
        out_shape=jax.ShapeDtypeStruct((bh, s_len), jnp.float32),
        interpret=interpret,
    )(o, do)

    dq_out_specs = [pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0))]
    dq_out_shape = [jax.ShapeDtypeStruct((bh, s_len, d), dq_dt)]
    if debug_counts:
        dq_out_specs.append(pl.BlockSpec((1, 1), lambda h, i, j: (h, i)))
        dq_out_shape.append(jax.ShapeDtypeStruct((bh, n_q), jnp.int32))

    dq_out = pl.pallas_call(
        functools.partial(_bwd_dq_kernel, sm_scale=scale, s_len=s_len,
                          count=debug_counts, **bounds_kw),
        grid=(bh, n_q, kv_steps),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, d), _kv_wedge_index(group, bounds_kw)),
            pl.BlockSpec((1, bk, d), _kv_wedge_index(group, bounds_kw)),
            pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bq), lambda h, i, j: (h, i)),
            pl.BlockSpec((1, bq), lambda h, i, j: (h, i)),
            pl.BlockSpec((1, bq), lambda h, i, j: (h, i)),
        ],
        out_specs=dq_out_specs,
        out_shape=dq_out_shape,
        scratch_shapes=[pltpu.VMEM((bq, d), jnp.float32)]
        + ([pltpu.SMEM((1,), jnp.int32)] if debug_counts else []),
        interpret=interpret,
    )(q, k, v, do, m, l, delta)
    dq = dq_out[0]                 # out_shape is a list even without counts

    def _q_head(hk, j, gi, i, g=group):
        del j, i
        return hk * g + gi

    def _q_tile(hk, j, gi, i):
        del hk, gi
        lo, hi = q_tile_bounds(j, bq=bq, bk=bk, causal=causal, window=window,
                               n_q=n_q, kv_len=kv_len)
        return _imin(lo + i, hi)

    dkv_out_specs = [
        pl.BlockSpec((1, bk, d), lambda hk, j, gi, i: (hk, j, 0)),
        pl.BlockSpec((1, bk, d), lambda hk, j, gi, i: (hk, j, 0)),
    ]
    dkv_out_shape = [
        jax.ShapeDtypeStruct((bhkv, s_len, d), dk_dt),
        jax.ShapeDtypeStruct((bhkv, s_len, d), dv_dt),
    ]
    if debug_counts:
        dkv_out_specs.append(pl.BlockSpec((1, 1),
                                          lambda hk, j, gi, i: (hk, j)))
        dkv_out_shape.append(jax.ShapeDtypeStruct((bhkv, n_k), jnp.int32))

    dkv_out = pl.pallas_call(
        functools.partial(_bwd_dkv_kernel, sm_scale=scale, n_q=n_q,
                          group=group, count=debug_counts, bq=bq, bk=bk,
                          **mask_kw),
        grid=(bhkv, n_k, group, q_steps),
        in_specs=[
            pl.BlockSpec((1, bq, d),
                         lambda hk, j, gi, i: (_q_head(hk, j, gi, i),
                                               _q_tile(hk, j, gi, i), 0)),
            pl.BlockSpec((1, bk, d), lambda hk, j, gi, i: (hk, j, 0)),
            pl.BlockSpec((1, bk, d), lambda hk, j, gi, i: (hk, j, 0)),
            pl.BlockSpec((1, bq, d),
                         lambda hk, j, gi, i: (_q_head(hk, j, gi, i),
                                               _q_tile(hk, j, gi, i), 0)),
            pl.BlockSpec((1, bq),
                         lambda hk, j, gi, i: (_q_head(hk, j, gi, i),
                                               _q_tile(hk, j, gi, i))),
            pl.BlockSpec((1, bq),
                         lambda hk, j, gi, i: (_q_head(hk, j, gi, i),
                                               _q_tile(hk, j, gi, i))),
            pl.BlockSpec((1, bq),
                         lambda hk, j, gi, i: (_q_head(hk, j, gi, i),
                                               _q_tile(hk, j, gi, i))),
        ],
        out_specs=dkv_out_specs,
        out_shape=dkv_out_shape,
        scratch_shapes=[pltpu.VMEM((bk, d), jnp.float32),
                        pltpu.VMEM((bk, d), jnp.float32)]
        + ([pltpu.SMEM((1,), jnp.int32)] if debug_counts else []),
        interpret=interpret,
    )(q, k, v, do, m, l, delta)
    if debug_counts:
        dk, dv, dkv_counts = dkv_out
        return dq, dk, dv, dq_out[1], dkv_counts
    dk, dv = dkv_out
    return dq, dk, dv
