"""Pallas TPU flash-attention (prefill/training forward).

Classic tiling: grid (B*H, nQ, nK) with the KV axis innermost (sequential
on TPU), online-softmax running stats in VMEM scratch per Q tile.  GQA is
handled in the BlockSpec index maps (KV tiles load from head h // group).

MXU shapes: (BQ, D) x (D, BK) and (BQ, BK) x (BK, D) with BQ = BK = 128
and D in {64, 128} — every contraction is lane-aligned.

VMEM per step (BQ=BK=128, D=128, f32 compute):
  q tile 64 KiB + k,v tiles 128 KiB + scores 64 KiB + acc/m/l ~66 KiB
  (double-buffered well under a v5e core's ~16 MiB).

Causal masking compares absolute positions built from the grid indices;
whole-tile-masked KV steps still execute (Pallas grids are dense) but the
mask zeroes their contribution — a ~2x FLOP overhead the scheduler would
claw back with a custom grid order (left as future work; the dry-run costs
the jnp path anyway).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30
DEFAULT_BQ = 128
DEFAULT_BK = 128


def _flash_kernel(q_ref, k_ref, v_ref, o_ref, m_ref, l_ref, acc_ref, *,
                  sm_scale, n_k, bq, bk, causal, window):
    qi = pl.program_id(1)
    ki = pl.program_id(2)

    @pl.when(ki == 0)
    def _init():
        m_ref[...] = jnp.full(m_ref.shape, NEG_INF, jnp.float32)
        l_ref[...] = jnp.zeros(l_ref.shape, jnp.float32)
        acc_ref[...] = jnp.zeros(acc_ref.shape, jnp.float32)

    q = q_ref[...][0].astype(jnp.float32)                  # (BQ, D)
    k = k_ref[...][0].astype(jnp.float32)                  # (BK, D)
    v = v_ref[...][0].astype(jnp.float32)
    s = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * sm_scale

    if causal:
        q_pos = qi * bq + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 0)
        k_pos = ki * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
        ok = q_pos >= k_pos
        if window > 0:
            ok &= (q_pos - k_pos) < window
        s = jnp.where(ok, s, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, s.max(axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(s - m_new[:, None])
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    m_ref[...] = m_new

    @pl.when(ki == n_k - 1)
    def _done():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = (acc_ref[...] / denom[:, None])[None].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=(
    "causal", "window", "sm_scale", "bq", "bk", "interpret"))
def flash_attention_pallas(q, k, v, *, causal: bool = True, window: int = 0,
                           sm_scale: float | None = None,
                           bq: int = DEFAULT_BQ, bk: int = DEFAULT_BK,
                           interpret: bool = False):
    """q: (BH, S, D); k, v: (BHkv, S, D) with BH = BHkv * group.

    Flat batch*head layout; the wrapper in ops.py folds (B, H) and GQA.
    S % bq == 0 and S % bk == 0 (ops.py pads).
    """
    bh, s_len, d = q.shape
    bhkv = k.shape[0]
    group = bh // bhkv
    bq = min(bq, s_len)
    bk = min(bk, s_len)
    assert s_len % bq == 0 and s_len % bk == 0, (s_len, bq, bk)
    n_q, n_k = s_len // bq, s_len // bk
    scale = sm_scale if sm_scale is not None else d ** -0.5

    return pl.pallas_call(
        functools.partial(_flash_kernel, sm_scale=scale, n_k=n_k, bq=bq,
                          bk=bk, causal=causal, window=window),
        grid=(bh, n_q, n_k),
        in_specs=[
            pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j, g=group: (h // g, j, 0)),
            pl.BlockSpec((1, bk, d), lambda h, i, j, g=group: (h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, bq, d), lambda h, i, j: (h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((bh, s_len, d), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),        # running max
            pltpu.VMEM((bq,), jnp.float32),        # running denom
            pltpu.VMEM((bq, d), jnp.float32),      # output accumulator
        ],
        interpret=interpret,
    )(q, k, v)
