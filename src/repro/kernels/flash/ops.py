"""Public flash-attention op: (B, H, S, D) GQA layout, backend dispatch."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.flash import kernel, ref


def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    sm_scale: float | None = None, backend: str = "ref"):
    """q: (B, H, S, D); k, v: (B, Hkv, S, D) -> (B, H, S, D)."""
    if backend == "ref":
        return ref.flash_ref(q, k, v, causal=causal, window=window,
                             sm_scale=sm_scale)
    b, h, s, d = q.shape
    hkv = k.shape[1]
    pad = (-s) % 128 if s > 128 else (-s) % 8
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    out = kernel.flash_attention_pallas(
        q.reshape(b * h, s + pad, d), k.reshape(b * hkv, s + pad, d),
        v.reshape(b * hkv, s + pad, d), causal=causal, window=window,
        sm_scale=sm_scale, interpret=(backend == "interpret"))
    out = out.reshape(b, h, s + pad, d)
    return out[:, :, :s] if pad else out
