"""Public flash-attention op: (B, H, S, D) GQA layout, backend dispatch.

One differentiable entry point for all three backends:

  * ``ref``        exact jnp softmax (``ref.flash_ref``), differentiated by
                   plain jax autodiff — the gradient oracle.  O(S^2)
                   residuals.
  * ``interpret``  the Pallas kernels run through the Pallas interpreter —
                   same tiling/masking semantics as TPU, runs anywhere.
  * ``pallas``     compiled Mosaic TPU kernels.

For ``interpret``/``pallas`` the op is a ``jax.custom_vjp``: the forward
saves residuals (q, k, v, o, m, l) — O(S*D) per head instead of the
O(S^2) probability matrix — and the backward runs the recompute-based
Pallas kernels (``kernel.flash_attention_bwd_pallas``), so
``jax.grad`` through ``attn_backend="pallas"`` is legal and memory-cheap.

Shapes the compiled Mosaic pipeline cannot lower (head_dim not in
{64, 128}, sequences shorter than one 128-lane block) fall back to the
``ref`` path with a one-time warning instead of crashing.

``resid_dtype`` applies a mixed-precision policy to the SAVED residual
tuple: (q, k, v, o) are stored between forward and backward in that dtype
(e.g. bf16 — halving the dominant O(S*D) term of f32 training) while the
(m, l) softmax stats always stay f32 (they sit inside an exp/log and the
two rows are byte-trivial).  Gradients are cast back to the primal input
dtypes, so the trade is purely recompute precision in the backward score
recomputation.
"""
from __future__ import annotations

import functools
import warnings
from typing import NamedTuple, Optional

import jax
import jax.numpy as jnp

from repro.kernels.flash import kernel, ref

SUPPORTED_HEAD_DIMS = (64, 128)
_WARNED_FALLBACKS: set[str] = set()


class _FlashSpec(NamedTuple):
    """Hashable static config threaded through the custom_vjp as a
    nondiff arg (causal/window/scale/kv_len are compile-time for the
    kernels; ``interpret`` picks the Pallas interpreter vs Mosaic).

    ``resid_dtype`` (a dtype NAME, kept hashable) is the storage dtype of
    the saved (q, k, v, o) residuals; ``grad_dtypes`` are the primal
    (q, k, v) dtypes the backward must cast its cotangents back to when a
    residual policy is active."""

    causal: bool
    window: int
    sm_scale: Optional[float]
    kv_len: int
    interpret: bool
    resid_dtype: Optional[str] = None
    grad_dtypes: Optional[tuple] = None


def padded_seq_len(s: int) -> int:
    """Sequence length after ``flash_attention``'s lane padding (S rounded
    to a 128 block, or to 8 sublanes below one block).  The planner and
    benchmarks size tile grids with this so their visited-tile counts
    match what the kernels actually execute."""
    return s + ((-s) % 128 if s > 128 else (-s) % 8)


def unsupported_reason(q, k, v, *, backend: str) -> Optional[str]:
    """Why the *compiled* Mosaic kernel can't run this shape (None = fine).

    Only ``backend="pallas"`` is constrained: the interpreter executes any
    shape, and ``ref`` is pure jnp.  (Indivisible GQA head counts are an
    invalid *input* on every backend — ``flash_attention`` raises rather
    than falls back.)  Padding in ``flash_attention`` already rounds S up
    to a multiple of the 128 block for S >= 128, so the sequence-length
    guard only rejects sub-block sequences (which would lower to
    non-lane-aligned tiles Mosaic refuses).
    """
    if backend != "pallas":
        return None
    d = q.shape[-1]
    s = q.shape[2]
    if d not in SUPPORTED_HEAD_DIMS:
        return (f"head_dim={d} is not MXU lane-aligned (supported: "
                f"{SUPPORTED_HEAD_DIMS}) for q{tuple(q.shape)}")
    if s < kernel.DEFAULT_BQ and s % kernel.DEFAULT_BQ:
        return (f"sequence length {s} of q{tuple(q.shape)} is not a "
                f"multiple of the flash block size {kernel.DEFAULT_BQ}; "
                f"sub-block tiles are not lane-aligned")
    return None


def _warn_fallback_once(reason: str) -> None:
    if reason not in _WARNED_FALLBACKS:
        _WARNED_FALLBACKS.add(reason)
        warnings.warn(
            f"flash_attention: falling back to backend='ref' — {reason}",
            stacklevel=3)


# ---------------------------------------------------------------------------
# custom_vjp core (flat (B*H, S, D) layout; padding/GQA folding outside).
# ---------------------------------------------------------------------------
@functools.partial(jax.custom_vjp, nondiff_argnums=(0,))
def _flash(spec: _FlashSpec, q, k, v):
    o, _, _ = kernel.flash_attention_fwd_pallas(
        q, k, v, causal=spec.causal, window=spec.window,
        sm_scale=spec.sm_scale, kv_len=spec.kv_len,
        interpret=spec.interpret)
    return o


def _flash_fwd(spec: _FlashSpec, q, k, v):
    o, m, l = kernel.flash_attention_fwd_pallas(
        q, k, v, causal=spec.causal, window=spec.window,
        sm_scale=spec.sm_scale, kv_len=spec.kv_len,
        interpret=spec.interpret)
    if spec.resid_dtype is not None:       # policy-cast saved (q, k, v, o);
        rd = jnp.dtype(spec.resid_dtype)   # (m, l) stats stay f32
        q, k, v, o_r = (x.astype(rd) for x in (q, k, v, o))
    else:
        o_r = o
    return o, (q, k, v, o_r, m, l)        # O(S*D) residuals + f32 stat rows


def _flash_bwd(spec: _FlashSpec, residuals, do):
    q, k, v, o, m, l = residuals
    # grad_dtypes makes the kernels emit cotangents at the PRIMAL dtypes
    # straight from their f32 accumulators — bf16-stored residuals must
    # not round-trip the gradients through bf16 on the way out.
    dq, dk, dv = kernel.flash_attention_bwd_pallas(
        q, k, v, o, m, l, do, causal=spec.causal, window=spec.window,
        sm_scale=spec.sm_scale, kv_len=spec.kv_len,
        interpret=spec.interpret, grad_dtypes=spec.grad_dtypes)
    return dq, dk, dv


_flash.defvjp(_flash_fwd, _flash_bwd)


# ---------------------------------------------------------------------------
# Public op.
# ---------------------------------------------------------------------------
def flash_attention(q, k, v, *, causal: bool = True, window: int = 0,
                    sm_scale: float | None = None, backend: str = "ref",
                    resid_dtype=None):
    """q: (B, H, S, D); k, v: (B, Hkv, S, D) -> (B, H, S, D).

    Differentiable on every backend; ``interpret``/``pallas`` use the
    recompute-based Pallas backward via ``jax.custom_vjp``.

    ``resid_dtype`` (dtype or name, e.g. ``"bfloat16"``) stores the saved
    (q, k, v, o) residual tuple in that dtype between forward and backward
    — the mixed-precision residual policy; (m, l) stats stay f32 and
    gradients come back in the primal dtypes.  Ignored on the ``ref``
    path (plain autodiff owns its residuals there).
    """
    if backend not in ("ref", "interpret", "pallas"):
        raise ValueError(f"flash_attention: unknown backend {backend!r} "
                         "(expected 'ref', 'interpret' or 'pallas')")
    if k.shape[1] == 0 or q.shape[1] % k.shape[1]:
        raise ValueError(
            f"flash_attention: n_heads={q.shape[1]} must be a non-zero "
            f"multiple of n_kv={k.shape[1]} (GQA) for q{tuple(q.shape)}, "
            f"k{tuple(k.shape)} — every backend groups query heads over "
            f"KV heads")
    if backend != "ref":
        reason = unsupported_reason(q, k, v, backend=backend)
        if reason is not None:
            _warn_fallback_once(reason)
            backend = "ref"
    if backend == "ref":
        return ref.flash_ref(q, k, v, causal=causal, window=window,
                             sm_scale=sm_scale)
    b, h, s, d = q.shape
    hkv = k.shape[1]
    pad = padded_seq_len(s) - s
    if pad:
        q = jnp.pad(q, ((0, 0), (0, 0), (0, pad), (0, 0)))
        k = jnp.pad(k, ((0, 0), (0, 0), (0, pad), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, 0), (0, pad), (0, 0)))
    rd = None if resid_dtype is None else jnp.dtype(resid_dtype).name
    if rd is not None and all(jnp.dtype(x.dtype).name == rd
                              for x in (q, k, v)):
        rd = None                          # residuals already follow inputs
    spec = _FlashSpec(causal=bool(causal), window=int(window),
                      sm_scale=sm_scale, kv_len=s,
                      interpret=(backend == "interpret"),
                      resid_dtype=rd,
                      grad_dtypes=None if rd is None else tuple(
                          jnp.dtype(x.dtype).name for x in (q, k, v)))
    out = _flash(spec, q.reshape(b * h, s + pad, d),
                 k.reshape(b * hkv, s + pad, d),
                 v.reshape(b * hkv, s + pad, d))
    out = out.reshape(b, h, s + pad, d)
    return out[:, :, :s] if pad else out
