from repro.kernels.kvq import ops, ref  # noqa: F401
