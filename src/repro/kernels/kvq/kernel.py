"""Pallas flash-decode over an int8-quantized KV cache.

One grid step processes one (batch, kv-head) pair and one KV-chunk of BS
tokens, with the classic online-softmax recurrence kept in VMEM scratch.
The int8->f32 dequant happens *after* the chunk is resident in VMEM, so HBM
sees only 1 byte/elem + 4 B/token scales — the paper's store-encoded /
decode-on-read trade applied to the decode-latency-dominant stream.

VMEM per step (BS=512, D<=128, G<=32):
  K,V chunks int8: 2*BS*D      = 128 KiB
  dequant f32:     2*BS*D*4    = 512 KiB
  scratch acc:     G*D*4       <= 16 KiB         (fits VMEM with headroom)

MXU shapes: (G, D) x (D, BS) and (G, BS) x (BS, D); D=64..128, BS multiple
of 128 lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BS = 512
NEG_INF = -1e30


def _flash_decode_kernel(q_ref, kq_ref, ks_ref, vq_ref, vs_ref, *refs,
                         sm_scale, ns, has_bias):
    # bias is an OPTIONAL input: the no-mask case (lengths=None, bias=None
    # in ops.decode_attention) never materializes a (B, S) zero tensor —
    # the kernel simply has no bias operand to add.
    if has_bias:
        bias_ref, o_ref, m_ref, l_ref, acc_ref = refs
    else:
        o_ref, m_ref, l_ref, acc_ref = refs
    s = pl.program_id(2)

    @pl.when(s == 0)
    def _init():
        m_ref[...] = jnp.full(m_ref.shape, NEG_INF, jnp.float32)
        l_ref[...] = jnp.zeros(l_ref.shape, jnp.float32)
        acc_ref[...] = jnp.zeros(acc_ref.shape, jnp.float32)

    q = q_ref[...][0, 0].astype(jnp.float32)                     # (G, D)
    k = kq_ref[...][0, 0].astype(jnp.float32) * ks_ref[...][0, 0][:, None]
    v = vq_ref[...][0, 0].astype(jnp.float32) * vs_ref[...][0, 0][:, None]
    logits = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * sm_scale
    if has_bias:
        logits = logits + bias_ref[...][0][None, :]               # (G, BS)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, logits.max(axis=-1))
    alpha = jnp.exp(m_prev - m_new)
    p = jnp.exp(logits - m_new[:, None])
    acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
        p, v, preferred_element_type=jnp.float32)
    l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
    m_ref[...] = m_new

    @pl.when(s == ns - 1)
    def _finish():
        denom = jnp.maximum(l_ref[...], 1e-30)
        o_ref[...] = (acc_ref[...] / denom[:, None])[None, None].astype(o_ref.dtype)


@functools.partial(jax.jit, static_argnames=("sm_scale", "block_s", "interpret"))
def flash_decode_pallas(q, k_q, k_s, v_q, v_s, bias=None, *, sm_scale: float,
                        block_s: int = DEFAULT_BS, interpret: bool = False):
    """Shapes as in ref.decode_attention_ref; S % block_s == 0.
    ``bias=None`` runs the unmasked kernel variant (no bias operand)."""
    b, hkv, g, d = q.shape
    s = k_q.shape[2]
    bs = min(block_s, s)
    while s % bs:                      # largest power-of-two-ish divisor
        bs //= 2
    assert bs >= 1, (s, block_s)
    ns = s // bs
    grid = (b, hkv, ns)
    kv_spec = pl.BlockSpec((1, 1, bs, d), lambda i, j, k: (i, j, k, 0))
    sc_spec = pl.BlockSpec((1, 1, bs), lambda i, j, k: (i, j, k))
    in_specs = [
        pl.BlockSpec((1, 1, g, d), lambda i, j, k: (i, j, 0, 0)),      # q
        kv_spec, sc_spec, kv_spec, sc_spec,                             # k, v
    ]
    args = [q, k_q, k_s, v_q, v_s]
    if bias is not None:
        in_specs.append(pl.BlockSpec((1, bs), lambda i, j, k: (i, k)))
        args.append(bias)
    return pl.pallas_call(
        functools.partial(_flash_decode_kernel, sm_scale=sm_scale, ns=ns,
                          has_bias=bias is not None),
        grid=grid,
        in_specs=in_specs,
        out_specs=pl.BlockSpec((1, 1, g, d), lambda i, j, k: (i, j, 0, 0)),
        out_shape=jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype),
        scratch_shapes=[
            _vmem((g,), jnp.float32),                                    # m
            _vmem((g,), jnp.float32),                                    # l
            _vmem((g, d), jnp.float32),                                  # acc
        ],
        interpret=interpret,
    )(*args)


def _vmem(shape, dtype):
    from jax.experimental.pallas import tpu as pltpu
    return pltpu.VMEM(shape, dtype)
