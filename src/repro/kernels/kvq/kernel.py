"""Pallas split-K flash-decode over an int8-quantized KV cache.

Grid ``(B, Hkv, splits, steps_per_split)``: the KV axis is sharded over a
parallel split-K axis, each split running the classic online-softmax
recurrence over its KV shard in VMEM scratch and emitting *partial*
(acc, m, l) accumulators; a jnp reduction (:func:`combine_splits`) merges
the partials with the standard online-softmax merge.  Decode latency at
large S goes from O(S) sequential chunks to O(S / splits) + O(splits).
The single-split case (every default call site) keeps the pre-split-K
fast path: normalize-and-cast happens in the kernel finalize and no
partial arrays ever reach HBM.

The int8->f32 dequant happens *after* the chunk is resident in VMEM, so
HBM sees only 1 byte/elem + 4 B/token scales — the paper's store-encoded /
decode-on-read trade applied to the decode-latency-dominant stream.

Length-aware tile skipping: per-batch ``lengths`` arrive as a
scalar-prefetch operand (``pltpu.PrefetchScalarGridSpec``), so

  * the kernel body ``pl.when``-early-outs every KV tile whose start lies
    beyond ``lengths[b]`` (plus the last split's structural padding tiles
    when splits don't divide the tile count) — a ragged batch stops
    paying for the longest sequence in it;
  * the BlockSpec index maps clamp skipped steps to the batch row's last
    live tile (``tiling.decode_last_live_tile``), so Pallas re-uses the
    resident block instead of issuing a DMA for data the kernel won't
    touch;
  * in-tile masking of the straddling tile compares a per-tile iota
    against ``lengths[b]`` — no dense (B, S) bias tensor exists anywhere
    on this path (the sole remaining ``bias`` operand serves the
    traced-window decode fallback).

``debug_counts=True`` additionally returns a (B, Hkv, splits) int32 array
counting the KV tile-steps whose matmuls executed — the measured twin of
:func:`repro.kernels.tiling.decode_tile_step_counts`, asserted
tile-for-tile in tests and benchmarks, same contract as the flash grids.

VMEM per step (BS=512, D<=128, G<=32):
  K,V chunks int8: 2*BS*D      = 128 KiB
  dequant f32:     2*BS*D*4    = 512 KiB
  scratch acc:     G*D*4       <= 16 KiB         (fits VMEM with headroom)

MXU shapes: (G, D) x (D, BS) and (G, BS) x (BS, D); D=64..128, BS multiple
of 128 lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import tiling
from repro.kernels.tiling import NEG_INF, imin as _imin

DEFAULT_BS = tiling.DEFAULT_DECODE_BS


def _flash_decode_kernel(*refs, sm_scale, bs, ns, spt, has_bias,
                         has_lengths, fused, count):
    # arg order: [lengths (scalar prefetch)] q, k_q, k_s, v_q, v_s, [bias],
    #            o[, m, l][, counts], scratch (m, l, acc, [count acc]).
    # ``fused`` (single split): normalize in-kernel and write the final
    # output — no partial (o, m, l) HBM round-trip, no jnp combine.
    if has_lengths:
        lengths_ref, *refs = refs
    q_ref, kq_ref, ks_ref, vq_ref, vs_ref, *refs = refs
    if has_bias:
        bias_ref, *refs = refs
    if not fused:
        o_ref, m_out_ref, l_out_ref, *refs = refs
    else:
        o_ref, *refs = refs
    if count:
        cnt_ref, m_ref, l_ref, acc_ref, cnt_acc = refs
    else:
        m_ref, l_ref, acc_ref = refs

    i = pl.program_id(0)
    split = pl.program_id(2)
    step = pl.program_id(3)
    t = split * spt + step                     # global KV tile this step

    @pl.when(step == 0)
    def _init():
        m_ref[...] = jnp.full(m_ref.shape, NEG_INF, jnp.float32)
        l_ref[...] = jnp.zeros(l_ref.shape, jnp.float32)
        acc_ref[...] = jnp.zeros(acc_ref.shape, jnp.float32)
        if count:
            cnt_acc[...] = jnp.zeros(cnt_acc.shape, jnp.int32)

    # early-out: structural padding tiles of the last split, and (with
    # lengths) every tile fully beyond this batch row's valid prefix
    live = t < ns
    if has_lengths:
        live &= t * bs < lengths_ref[i]

    def _step():
        q = q_ref[...][0, 0].astype(jnp.float32)                 # (G, D)
        k = kq_ref[...][0, 0].astype(jnp.float32) * ks_ref[...][0, 0][:, None]
        v = vq_ref[...][0, 0].astype(jnp.float32) * vs_ref[...][0, 0][:, None]
        logits = jnp.dot(q, k.T, preferred_element_type=jnp.float32) * sm_scale
        if has_bias:
            logits = logits + bias_ref[...][0][None, :]           # (G, BS)
        if has_lengths:
            # straddling tile: mask the tail with a per-tile iota compare —
            # never a materialized (B, S) bias tensor
            kpos = t * bs + jax.lax.broadcasted_iota(
                jnp.int32, logits.shape, 1)
            logits = jnp.where(kpos < lengths_ref[i], logits, NEG_INF)

        m_prev = m_ref[...]
        m_new = jnp.maximum(m_prev, logits.max(axis=-1))
        alpha = jnp.exp(m_prev - m_new)
        p = jnp.exp(logits - m_new[:, None])
        acc_ref[...] = acc_ref[...] * alpha[:, None] + jnp.dot(
            p, v, preferred_element_type=jnp.float32)
        l_ref[...] = l_ref[...] * alpha + p.sum(axis=-1)
        m_ref[...] = m_new
        if count:
            cnt_acc[...] += 1

    pl.when(live)(_step)

    @pl.when(step == spt - 1)
    def _finish():
        if fused:
            # single split owns every tile: normalize and cast in VMEM,
            # exactly the pre-split-K finalize
            denom = jnp.maximum(l_ref[...], 1e-30)
            o_ref[...] = (acc_ref[...] / denom[:, None])[None, None].astype(
                o_ref.dtype)
        else:
            # UNNORMALIZED partials: combine_splits owns the final divide.
            # A split with zero executed steps writes its init state
            # (acc=0, l=0, m=NEG_INF) and contributes nothing to the merge.
            o_ref[...] = acc_ref[...][None, None, None]
            m_out_ref[...] = m_ref[...][None, None, None]
            l_out_ref[...] = l_ref[...][None, None, None]
        if count:
            cnt_ref[...] = cnt_acc[...].reshape(cnt_ref.shape)


def combine_splits(o_p, m_p, l_p, dtype):
    """Online-softmax merge of split-K partials (the lax-reduction half).

    o_p: (B, Hkv, splits, G, D) unnormalized accumulators;
    m_p, l_p: (B, Hkv, splits, G) running max / denominator.
    Dead splits carry (0, NEG_INF, 0) and drop out of the merge (their
    alpha underflows to 0 against any live max).
    """
    m_max = m_p.max(axis=2)                                   # (B, Hkv, G)
    alpha = jnp.exp(m_p - m_max[:, :, None])                  # (B,Hkv,S,G)
    l_tot = (l_p * alpha).sum(axis=2)
    acc = (o_p * alpha[..., None]).sum(axis=2)
    return (acc / jnp.maximum(l_tot, 1e-30)[..., None]).astype(dtype)


@functools.partial(jax.jit, static_argnames=(
    "sm_scale", "block_s", "splits", "interpret", "debug_counts"))
def flash_decode_pallas(q, k_q, k_s, v_q, v_s, bias=None, lengths=None, *,
                        sm_scale: float, block_s: int = DEFAULT_BS,
                        splits: int = 1, interpret: bool = False,
                        debug_counts: bool = False):
    """Shapes as in ref.decode_attention_ref; block size shrinks to divide S.

    ``lengths`` (B,) int32 rides the scalar-prefetch lane and drives the
    tile early-outs + in-tile iota mask; ``bias`` (B, S) f32 is the dense
    fallback for masks lengths can't express (mutually exclusive).  With
    neither, the unmasked kernel variant runs (no mask operand at all).
    With ``debug_counts`` also returns (B, Hkv, splits) executed-step
    counters.
    """
    assert bias is None or lengths is None, "bias and lengths are exclusive"
    b, hkv, g, d = q.shape
    s = k_q.shape[2]
    bs, ns, n_sp, spt = tiling.resolve_decode_grid(s, block_s=block_s,
                                                   splits=splits)
    grid = (b, hkv, n_sp, spt)
    has_lengths = lengths is not None
    has_bias = bias is not None

    def _tile(i, split, step, len_ref=None):
        t = split * spt + step
        hi = ns - 1 if len_ref is None else tiling.decode_last_live_tile(
            len_ref[i], bs=bs, ns=ns)
        return _imin(t, hi)

    if has_lengths:
        q_map = lambda i, j, k, st, lr: (i, j, 0, 0)
        kv_map = lambda i, j, k, st, lr: (i, j, _tile(i, k, st, lr), 0)
        sc_map = lambda i, j, k, st, lr: (i, j, _tile(i, k, st, lr))
        o_map = lambda i, j, k, st, lr: (i, j, k, 0, 0)
        ml_map = lambda i, j, k, st, lr: (i, j, k, 0)
        cnt_map = lambda i, j, k, st, lr: (i, j, k)
    else:
        q_map = lambda i, j, k, st: (i, j, 0, 0)
        kv_map = lambda i, j, k, st: (i, j, _tile(i, k, st), 0)
        sc_map = lambda i, j, k, st: (i, j, _tile(i, k, st))
        o_map = lambda i, j, k, st: (i, j, k, 0, 0)
        ml_map = lambda i, j, k, st: (i, j, k, 0)
        cnt_map = lambda i, j, k, st: (i, j, k)

    kv_spec = pl.BlockSpec((1, 1, bs, d), kv_map)
    sc_spec = pl.BlockSpec((1, 1, bs), sc_map)
    in_specs = [pl.BlockSpec((1, 1, g, d), q_map),
                kv_spec, sc_spec, kv_spec, sc_spec]
    args = [q, k_q, k_s, v_q, v_s]
    if has_bias:
        bias_map = (lambda i, j, k, st: (i, _tile(i, k, st)))
        in_specs.append(pl.BlockSpec((1, bs), bias_map))
        args.append(bias)

    fused = n_sp == 1            # single split: finalize in-kernel, no
    if fused:                    # partial HBM round-trip or jnp combine
        out_specs = [pl.BlockSpec((1, 1, g, d), q_map)]
        out_shape = [jax.ShapeDtypeStruct((b, hkv, g, d), q.dtype)]
    else:
        out_specs = [
            pl.BlockSpec((1, 1, 1, g, d), o_map),
            pl.BlockSpec((1, 1, 1, g), ml_map),
            pl.BlockSpec((1, 1, 1, g), ml_map),
        ]
        out_shape = [
            jax.ShapeDtypeStruct((b, hkv, n_sp, g, d), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, n_sp, g), jnp.float32),
            jax.ShapeDtypeStruct((b, hkv, n_sp, g), jnp.float32),
        ]
    if debug_counts:
        out_specs.append(pl.BlockSpec((1, 1, 1), cnt_map))
        out_shape.append(jax.ShapeDtypeStruct((b, hkv, n_sp), jnp.int32))

    scratch_shapes = [
        pltpu.VMEM((g,), jnp.float32),                               # m
        pltpu.VMEM((g,), jnp.float32),                               # l
        pltpu.VMEM((g, d), jnp.float32),                             # acc
    ] + ([pltpu.SMEM((1,), jnp.int32)] if debug_counts else [])

    kern = functools.partial(
        _flash_decode_kernel, sm_scale=sm_scale, bs=bs, ns=ns, spt=spt,
        has_bias=has_bias, has_lengths=has_lengths, fused=fused,
        count=debug_counts)
    # the split-K point: (batch, kv-head, split) are PARALLEL — Mosaic may
    # run the splits concurrently (this is where O(S) -> O(S/splits) comes
    # from on hardware); only the per-split KV sweep is sequential
    params = pltpu.TPUCompilerParams(
        dimension_semantics=("parallel", "parallel", "parallel",
                             "arbitrary"))
    if has_lengths:
        grid_spec = pltpu.PrefetchScalarGridSpec(
            num_scalar_prefetch=1, grid=grid, in_specs=in_specs,
            out_specs=out_specs, scratch_shapes=scratch_shapes)
        out = pl.pallas_call(kern, grid_spec=grid_spec, out_shape=out_shape,
                             compiler_params=params, interpret=interpret)(
            jnp.asarray(lengths, jnp.int32), *args)
    else:
        out = pl.pallas_call(kern, grid=grid, in_specs=in_specs,
                             out_specs=out_specs, out_shape=out_shape,
                             scratch_shapes=scratch_shapes,
                             compiler_params=params,
                             interpret=interpret)(*args)

    if fused:
        o = out[0]
    else:
        o = combine_splits(*out[:3], q.dtype)
    return (o, out[-1]) if debug_counts else o
