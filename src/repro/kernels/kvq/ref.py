"""Oracle for int8-KV flash-decode: quantization + exact softmax attention.

E-D applied to serving: the KV cache is *stored encoded* (int8 + per-token,
per-head scales = 2.06 bytes/elem vs 2 bytes bf16 -> ~2x vs fp32, ~1.94x vs
bf16 counting scales) and *decoded on read* inside the attention kernel,
halving the HBM stream that dominates decode latency.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def quantize_kv(x: jax.Array):
    """(..., S, D) float -> (int8 values, float32 scales (..., S))."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.round(x.astype(jnp.float32) / scale[..., None])
    return q.astype(jnp.int8), scale.astype(jnp.float32)


def dequantize_kv(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale[..., None]


def decode_attention_ref(q, k_q, k_s, v_q, v_s, bias, sm_scale: float):
    """Exact reference.

    q:   (B, Hkv, G, D) f32      — G = query heads per KV head (GQA group)
    k_q: (B, Hkv, S, D) int8,  k_s: (B, Hkv, S) f32
    v_q: (B, Hkv, S, D) int8,  v_s: (B, Hkv, S) f32
    bias:(B, S) f32 additive mask (0 valid / -inf padded), or None for the
         no-mask case (every cache slot valid — nothing is materialized)
    ->   (B, Hkv, G, D) f32
    """
    k = dequantize_kv(k_q, k_s)
    v = dequantize_kv(v_q, v_s)
    logits = jnp.einsum("bhgd,bhsd->bhgs", q.astype(jnp.float32), k) * sm_scale
    if bias is not None:
        logits = logits + bias[:, None, None, :]
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhgs,bhsd->bhgd", p, v)
