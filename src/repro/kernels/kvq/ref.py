"""Oracle for int8-KV flash-decode: quantization + exact softmax attention.

E-D applied to serving: the KV cache is *stored encoded* (int8 + per-token,
per-head scales = 2.06 bytes/elem vs 2 bytes bf16 -> ~2x vs fp32, ~1.94x vs
bf16 counting scales) and *decoded on read* inside the attention kernel,
halving the HBM stream that dominates decode latency.

Two oracles:

  * :func:`decode_attention_ref` — one exact softmax over the whole cache.
    ``lengths`` masks via an in-body iota compare (no (B, S) bias tensor
    is ever materialized on the lengths path, mirroring the kernel).
  * :func:`decode_attention_splitk_ref` — the split-K oracle: per-split
    masked-softmax partials merged with the same online-softmax merge as
    ``kernel.combine_splits``, in pure jnp.  Validates the split/merge
    arithmetic independently of Pallas; must agree with the plain oracle
    to float tolerance for every split count.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import tiling
from repro.kernels.tiling import NEG_INF


def quantize_kv(x: jax.Array):
    """(..., S, D) float -> (int8 values, float32 scales (..., S))."""
    amax = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1)
    scale = jnp.where(amax > 0, amax / 127.0, 1.0)
    q = jnp.round(x.astype(jnp.float32) / scale[..., None])
    return q.astype(jnp.int8), scale.astype(jnp.float32)


def dequantize_kv(q: jax.Array, scale: jax.Array) -> jax.Array:
    return q.astype(jnp.float32) * scale[..., None]


def masked_decode_logits(q, k, sm_scale, bias, lengths):
    """(B, Hkv, G, S) masked decode logits; lengths mask via an in-body
    iota compare only (never a (B, S) bias tensor).  The ONE jnp source of
    the decode mask contract — the ref oracles here and the unquantized
    fallback in ``models.attention.attn_decode`` both call it, so the
    lengths semantics cannot drift between serve paths."""
    logits = jnp.einsum("bhgd,bhsd->bhgs", q.astype(jnp.float32), k) * sm_scale
    if lengths is not None:
        kpos = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 3)
        logits = jnp.where(kpos < lengths[:, None, None, None], logits,
                           NEG_INF)
    elif bias is not None:
        logits = logits + bias[:, None, None, :]
    return logits


def decode_attention_ref(q, k_q, k_s, v_q, v_s, bias, sm_scale: float,
                         lengths=None):
    """Exact reference.

    q:   (B, Hkv, G, D) f32      — G = query heads per KV head (GQA group)
    k_q: (B, Hkv, S, D) int8,  k_s: (B, Hkv, S) f32
    v_q: (B, Hkv, S, D) int8,  v_s: (B, Hkv, S) f32
    bias:(B, S) f32 additive mask (0 valid / -inf padded), or None
    lengths: (B,) int32 valid prefix lengths — masked with an in-body iota
         compare, never a broadcast bias tensor (exclusive with ``bias``)
    ->   (B, Hkv, G, D) f32
    """
    assert bias is None or lengths is None
    k = dequantize_kv(k_q, k_s)
    v = dequantize_kv(v_q, v_s)
    logits = masked_decode_logits(q, k, sm_scale, bias, lengths)
    p = jax.nn.softmax(logits, axis=-1)
    return jnp.einsum("bhgs,bhsd->bhgd", p, v)


def decode_attention_splitk_ref(q, k_q, k_s, v_q, v_s, sm_scale: float, *,
                                lengths=None, bias=None,
                                block_s: int = tiling.DEFAULT_DECODE_BS,
                                splits: int = 1):
    """Split-K oracle: partials over each KV shard + online-softmax merge.

    Mirrors the kernel's split/merge arithmetic in pure jnp — same shard
    boundaries (``tiling.resolve_decode_grid``), unnormalized per-split
    accumulators, same merge as ``kernel.combine_splits`` — so the merge
    math has an oracle of its own rather than only the end-to-end output.
    """
    assert bias is None or lengths is None
    b, hkv, g, d = q.shape
    s = k_q.shape[2]
    bs, ns, n_sp, spt = tiling.resolve_decode_grid(s, block_s=block_s,
                                                   splits=splits)
    k = dequantize_kv(k_q, k_s)
    v = dequantize_kv(v_q, v_s)
    logits = masked_decode_logits(q, k, sm_scale, bias, lengths)   # (B,Hkv,G,S)
    valid = logits > NEG_INF / 2                  # live positions, post-mask

    m_p, l_p, o_p = [], [], []
    for sp in range(n_sp):
        sl = slice(sp * spt * bs, min((sp + 1) * spt, ns) * bs)
        if sl.start >= sl.stop:
            # empty final shard (splits don't divide the tile count): the
            # kernel's t < ns early-out leaves its init state — dead partials
            m_p.append(jnp.full(logits.shape[:-1], NEG_INF))
            l_p.append(jnp.zeros(logits.shape[:-1]))
            o_p.append(jnp.zeros(q.shape))
            continue
        lg, ok = logits[..., sl], valid[..., sl]
        m = jnp.where(ok.any(-1), lg.max(-1), NEG_INF)
        p = jnp.where(ok, jnp.exp(lg - m[..., None]), 0.0)
        m_p.append(m)
        l_p.append(p.sum(-1))
        o_p.append(jnp.einsum("bhgs,bhsd->bhgd", p, v[:, :, sl]))
    stack = lambda xs, ax=2: jnp.stack(xs, axis=ax)
    from repro.kernels.kvq import kernel
    return kernel.combine_splits(stack(o_p), stack(m_p), stack(l_p),
                                 q.dtype)
