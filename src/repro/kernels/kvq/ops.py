"""Public op: GQA decode attention over an int8 KV cache.

``decode_attention`` accepts the *deployed* layout — query heads flat,
cache pre-quantized — reshapes to the kernel's grouped layout, and
dispatches pallas / interpret / ref.  ``splits`` selects the split-K
decode grid (``kernel.flash_decode_pallas``); ``lengths`` rides the
scalar-prefetch lane and skips fully-padded KV tiles instead of paying a
dense (B, S) bias add — on EVERY backend, ref included, the lengths path
never materializes a bias tensor.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels import tiling
from repro.kernels.kvq import kernel, ref
from repro.kernels.kvq.ref import dequantize_kv, quantize_kv  # re-export

BACKENDS = ("ref", "interpret", "pallas")


def resolve_splits(s: int, splits: int,
                   block_s: int = kernel.DEFAULT_BS) -> int:
    """The split count the kernel will actually run for a length-S cache
    (clamped to the KV tile count) — what honest banners should print."""
    return tiling.resolve_decode_grid(s, block_s=block_s, splits=splits)[2]


def decode_attention(q, k_q, k_s, v_q, v_s, *, lengths=None, bias=None,
                     sm_scale: float | None = None, backend: str = "ref",
                     splits: int = 1, block_s: int | None = None,
                     debug_counts: bool = False):
    """q: (B, H, D); cache: (B, Hkv, S, D) int8 (+ (B, Hkv, S) scales).

    lengths: (B,) valid cache lengths — compared against a per-tile iota
    inside the kernel/ref body (never a broadcast bias tensor) and, on the
    kernel backends, used to early-out fully-padded KV tiles and shrink
    their DMAs.  bias: explicit (B, S) f32 additive mask for schedules
    lengths can't express (exclusive with ``lengths``).  With neither,
    every cache slot is valid and NO mask operand exists at all.

    ``splits`` fans the KV axis over a parallel split-K grid axis
    (kernel backends; ref is a single exact softmax).  ``debug_counts``
    (kernel backends only) also returns (B, Hkv, splits) executed
    tile-step counters — the measured twin of
    ``tiling.decode_tile_step_counts``.
    Returns (B, H, D) f32 (or (out, counts) with ``debug_counts``).
    """
    if backend not in BACKENDS:
        raise ValueError(f"decode_attention: unknown backend {backend!r} "
                         f"(expected one of {BACKENDS})")
    if lengths is not None and bias is not None:
        raise ValueError("decode_attention: lengths and bias are exclusive")
    b, h, d = q.shape
    _, hkv, s, _ = k_q.shape
    assert h % hkv == 0, (h, hkv)
    g = h // hkv
    sm = sm_scale if sm_scale is not None else d ** -0.5
    if lengths is not None:
        lengths = jnp.asarray(lengths, jnp.int32)
    qg = q.astype(jnp.float32).reshape(b, hkv, g, d)
    if backend == "ref":
        if debug_counts:
            raise ValueError("decode_attention: debug_counts needs a kernel "
                             "backend (interpret/pallas); ref runs no grid")
        out = ref.decode_attention_ref(qg, k_q, k_s, v_q, v_s, bias, sm,
                                       lengths=lengths)
    else:
        kw = dict(sm_scale=sm, splits=splits,
                  interpret=(backend == "interpret"),
                  debug_counts=debug_counts)
        if block_s is not None:
            kw["block_s"] = block_s
        out = kernel.flash_decode_pallas(qg, k_q, k_s, v_q, v_s, bias,
                                         lengths, **kw)
        if debug_counts:
            out, counts = out
            return out.reshape(b, h, d), counts
    return out.reshape(b, h, d)
