"""Public op: GQA decode attention over an int8 KV cache.

``decode_attention`` accepts the *deployed* layout — query heads flat,
cache pre-quantized — reshapes to the kernel's grouped layout, and
dispatches pallas / interpret / ref.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.kvq import kernel, ref
from repro.kernels.kvq.ref import dequantize_kv, quantize_kv  # re-export


def decode_attention(q, k_q, k_s, v_q, v_s, *, lengths=None, bias=None,
                     sm_scale: float | None = None, backend: str = "ref"):
    """q: (B, H, D); cache: (B, Hkv, S, D) int8 (+ (B, Hkv, S) scales).

    lengths: (B,) valid cache lengths -> padding mask; or explicit bias (B,S).
    With neither, every cache slot is valid and NO bias tensor is built or
    added — the unmasked case passes straight through instead of paying a
    dense (B, S) f32 zero materialization + broadcast add per call.
    Returns (B, H, D) f32.
    """
    b, h, d = q.shape
    _, hkv, s, _ = k_q.shape
    assert h % hkv == 0, (h, hkv)
    g = h // hkv
    sm = sm_scale if sm_scale is not None else d ** -0.5
    if bias is None and lengths is not None:
        pos = jnp.arange(s)[None, :]
        bias = jnp.where(pos < lengths[:, None], 0.0, kernel.NEG_INF
                         ).astype(jnp.float32)
    qg = q.astype(jnp.float32).reshape(b, hkv, g, d)
    if backend == "ref":
        out = ref.decode_attention_ref(qg, k_q, k_s, v_q, v_s, bias, sm)
    else:
        out = kernel.flash_decode_pallas(qg, k_q, k_s, v_q, v_s, bias,
                                         sm_scale=sm,
                                         interpret=(backend == "interpret"))
    return out.reshape(b, h, d)
