from repro.kernels.ssd import ops, ref  # noqa: F401
