"""Public SSD op: full chunked scan with kernel/ref/interpret dispatch.

``ssd`` runs the mamba2 sequence mixer: the FLOPs-heavy intra-chunk part
goes through the Pallas kernel (or its jnp oracle), the tiny inter-chunk
state recurrence through a lax.scan.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssd import kernel, ref


def ssd(x, dt, a, b, c, d, *, chunk: int = 128, backend: str = "ref",
        initial_state=None, return_state: bool = False):
    """Chunked SSD.

    x: (B, L, H, P); dt: (B, L, H) (>=0); a: (H,) negative log-decay rates;
    b, c: (B, L, N); d: (H,) skip.  L % chunk == 0.
    Returns y (B, L, H, P) [, final_state (B, H, N, P)].
    """
    bsz, L, h, p = x.shape
    n = b.shape[-1]
    assert L % chunk == 0, (L, chunk)
    t = L // chunk

    dtype = jnp.float32  # state recurrences are precision-critical
    xbar = (x * dt[..., None]).astype(dtype)
    alog = (dt * a[None, None, :]).astype(dtype)                    # (B, L, H)
    acum = jnp.cumsum(alog.reshape(bsz, t, chunk, h), axis=2)       # (B,T,Q,H)

    # fold (B, H) -> G for the kernel; B/C are head-shared (1 group)
    def fold(z, feat):  # (B, L, F) -> (B*H, T, Q, F) broadcast over heads
        z = z.reshape(bsz, 1, t, chunk, feat).astype(dtype)
        return jnp.broadcast_to(z, (bsz, h, t, chunk, feat)).reshape(
            bsz * h, t, chunk, feat)

    c_f = fold(c, n)
    b_f = fold(b, n)
    x_f = jnp.moveaxis(xbar.reshape(bsz, t, chunk, h, p), 3, 1).reshape(
        bsz * h, t, chunk, p)
    a_f = jnp.moveaxis(acum, 3, 1).reshape(bsz * h, t, chunk)

    if backend == "ref":
        y_intra, chunk_states = ref.ssd_chunk_ref(c_f, b_f, x_f, a_f)
    else:
        y_intra, chunk_states = kernel.ssd_chunk_pallas(
            c_f, b_f, x_f, a_f, interpret=(backend == "interpret"))

    # inter-chunk state recurrence: S_{j+1} = exp(sum_j) S_j + state_j
    chunk_decay = jnp.exp(a_f[:, :, -1])                            # (G, T)
    s0 = (jnp.zeros((bsz * h, n, p), dtype) if initial_state is None
          else initial_state.reshape(bsz * h, n, p).astype(dtype))

    def step(s, inp):
        dec, st = inp
        return s * dec[:, None, None] + st, s  # emit state *entering* chunk

    final_state, s_in = jax.lax.scan(
        step, s0, (jnp.moveaxis(chunk_decay, 1, 0),
                   jnp.moveaxis(chunk_states, 1, 0)))
    s_in = jnp.moveaxis(s_in, 0, 1)                                 # (G, T, N, P)

    y_inter = jnp.einsum("gtqn,gtnp->gtqp", c_f * jnp.exp(a_f)[..., None], s_in)
    y = (y_intra + y_inter).reshape(bsz, h, t, chunk, p)
    y = jnp.moveaxis(y, 1, 3).reshape(bsz, L, h, p)
    y = y + x.astype(dtype) * d[None, None, :, None]
    y = y.astype(x.dtype)
    if return_state:
        return y, final_state.reshape(bsz, h, n, p)
    return y


def ssd_decode_step(state, x_t, dt_t, a, b_t, c_t, d):
    """Single-token recurrent step for serving.

    state: (B, H, N, P); x_t: (B, H, P); dt_t: (B, H); b_t, c_t: (B, N).
    Returns (new_state, y_t (B, H, P)).
    """
    da = jnp.exp(dt_t * a[None, :])[..., None, None]                # (B,H,1,1)
    xbar = x_t * dt_t[..., None]
    state = state * da + jnp.einsum("bn,bhp->bhnp", b_t, xbar)
    y = jnp.einsum("bn,bhnp->bhp", c_t, state) + x_t * d[None, :, None]
    return state, y
