"""Pallas TPU kernel for the SSD intra-chunk computation (mamba2 hotspot).

The intra-chunk term is the FLOPs-dominant part of SSD — two (Q,N)x(N,Q)
/ (Q,Q)x(Q,P) matmuls per chunk, MXU-shaped when Q, N, P are multiples of
the 128 lane width (we use Q=128 chunks, N=128 state, P=64.. heads).
The O(L) inter-chunk state recurrence is tiny ((N,P) per head) and stays in
a lax.scan outside the kernel.

Grid: (G, T) over folded batch*heads and chunks — fully parallel, no
cross-step scratch.  VMEM per step (Q=128, N=128, P=64):
  C,B blocks 2*Q*N*4 = 128 KiB; x,y Q*P*4 = 32 KiB each; decay Q*Q*4 = 64 KiB.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl


def _ssd_chunk_kernel(c_ref, b_ref, x_ref, da_ref, y_ref, st_ref):
    c = c_ref[...][0, 0]        # (Q, N)
    b = b_ref[...][0, 0]        # (Q, N)
    x = x_ref[...][0, 0]        # (Q, P)
    da = da_ref[...][0, 0]      # (Q,) inclusive cumulative log-decay
    q = c.shape[0]

    scores = jnp.dot(c, b.T, preferred_element_type=jnp.float32)     # (Q, Q)
    decay = jnp.exp(da[:, None] - da[None, :])
    rows = jax.lax.broadcasted_iota(jnp.int32, (q, q), 0)
    cols = jax.lax.broadcasted_iota(jnp.int32, (q, q), 1)
    g = jnp.where(rows >= cols, scores * decay, 0.0)
    y_ref[...] = jnp.dot(g, x, preferred_element_type=jnp.float32)[None, None]

    w = jnp.exp(da[q - 1] - da)                                       # (Q,)
    st_ref[...] = jnp.dot(b.T, x * w[:, None],
                          preferred_element_type=jnp.float32)[None, None]


@functools.partial(jax.jit, static_argnames=("interpret",))
def ssd_chunk_pallas(c, b, xbar, acum, *, interpret: bool = False):
    """Shapes as in ref.ssd_chunk_ref: (G,T,Q,N)x2, (G,T,Q,P), (G,T,Q)."""
    g_sz, t, q, n = c.shape
    p = xbar.shape[-1]
    grid = (g_sz, t)
    spec_qn = pl.BlockSpec((1, 1, q, n), lambda i, j: (i, j, 0, 0))
    spec_qp = pl.BlockSpec((1, 1, q, p), lambda i, j: (i, j, 0, 0))
    return pl.pallas_call(
        _ssd_chunk_kernel,
        grid=grid,
        in_specs=[spec_qn, spec_qn, spec_qp,
                  pl.BlockSpec((1, 1, q), lambda i, j: (i, j, 0))],
        out_specs=[spec_qp,
                   pl.BlockSpec((1, 1, n, p), lambda i, j: (i, j, 0, 0))],
        out_shape=[jax.ShapeDtypeStruct((g_sz, t, q, p), jnp.float32),
                   jax.ShapeDtypeStruct((g_sz, t, n, p), jnp.float32)],
        interpret=interpret,
    )(c, b, xbar, acum)
