"""Pure-jnp oracle for the SSD (state-space duality) chunk kernel.

Mamba-2 SSD semantics, per head: with per-step log-decay a_t = dt_t * A and
inclusive cumsum Acum, the sequence output is

  h_t = exp(a_t) h_{t-1} + B_t xbar_t ;   y_t = C_t^T h_t + D x_t

The chunked form splits L into chunks of Q and computes, per chunk,
  intra  : y_t += sum_{s<=t} (C_t.B_s) exp(Acum_t - Acum_s) xbar_s
  state  : S'   = exp(Acum_Q) S + sum_s exp(Acum_Q - Acum_s) B_s^T xbar_s
  inter  : y_t += exp(Acum_t) (C_t @ S)

``ssd_chunk_ref`` covers the intra + state terms (what the Pallas kernel
fuses); ``ssd_scan_ref`` is the full O(L) recurrence oracle.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp


def ssd_chunk_ref(c, b, xbar, acum):
    """c,b: (G, T, Q, N); xbar: (G, T, Q, P); acum: (G, T, Q) inclusive cumsum.

    Returns (y_intra (G,T,Q,P), chunk_state (G,T,N,P)).
    G folds batch*heads; T = number of chunks.
    """
    q = c.shape[-2]
    scores = jnp.einsum("gtqn,gtsn->gtqs", c, b)
    decay = jnp.exp(acum[..., :, None] - acum[..., None, :])           # (G,T,Q,Q)
    mask = jnp.tril(jnp.ones((q, q), bool))
    g = jnp.where(mask, scores * decay, 0.0)
    y_intra = jnp.einsum("gtqs,gtsp->gtqp", g, xbar)
    w = jnp.exp(acum[..., -1:] - acum)                                 # (G,T,Q)
    state = jnp.einsum("gtqn,gtqp->gtnp", b * w[..., None], xbar)
    return y_intra, state


def ssd_scan_ref(x, dt, a, b, c, d):
    """Exact sequential recurrence (the ground-truth oracle).

    x: (B, L, H, P); dt: (B, L, H); a: (H,) (negative);
    b, c: (B, L, N); d: (H,).  Returns y: (B, L, H, P).
    """
    bsz, L, h, p = x.shape
    n = b.shape[-1]
    da = jnp.exp(dt * a[None, None, :])                    # (B, L, H)
    xbar = x * dt[..., None]

    def step(s, inp):
        da_t, xb_t, b_t, c_t = inp                         # (B,H) (B,H,P) (B,N) (B,N)
        s = s * da_t[..., None, None] + jnp.einsum("bn,bhp->bhnp", b_t, xb_t)
        y = jnp.einsum("bn,bhnp->bhp", c_t, s)
        return s, y

    s0 = jnp.zeros((bsz, h, n, p), x.dtype)
    xs = (jnp.moveaxis(da, 1, 0), jnp.moveaxis(xbar, 1, 0),
          jnp.moveaxis(b, 1, 0), jnp.moveaxis(c, 1, 0))
    _, ys = jax.lax.scan(step, s0, xs)
    y = jnp.moveaxis(ys, 0, 1)                             # (B, L, H, P)
    return y + x * d[None, None, :, None]
