from repro.kernels.pack import ops, ref  # noqa: F401
