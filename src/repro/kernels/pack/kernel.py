"""Pallas TPU kernels for the E-D codec (paper's custom decode layer).

The decode is HBM-bandwidth-bound: each uint32 read expands to four
normalized float32 pixels.  Packing therefore cuts the HBM (and host->device)
traffic of the input stream 4x at the cost of two VPU ops per pixel —
exactly the paper's trade ("compression reduces at-least 20% training
time"), re-tiled for VMEM:

  * input tile  (BR, BC)      uint32  -> 4*BR*BC bytes in VMEM
  * output tile (4, BR, BC)   float32 -> 16*BR*BC bytes in VMEM

Default BR=64, BC=512 keeps a tile pair < 5 MiB (double-buffered) in the
~16 MiB VMEM of a v5e core, with the last dim a multiple of 128 lanes.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

from repro.kernels.pack.ref import LANES

DEFAULT_BR = 64
DEFAULT_BC = 512


def _decode_kernel(packed_ref, out_ref, *, scale: float, shift: float):
    x = packed_ref[...]  # (BR, BC) uint32
    for i in range(LANES):  # unrolled VPU shifts/masks
        lane = ((x >> jnp.uint32(8 * i)) & jnp.uint32(0xFF)).astype(jnp.float32)
        out_ref[i, :, :] = lane * scale + shift


def _encode_kernel(lanes_ref, out_ref):
    acc = jnp.zeros(out_ref.shape, jnp.uint32)
    for i in range(LANES):
        acc = acc | (lanes_ref[i, :, :].astype(jnp.uint32) << jnp.uint32(8 * i))
    out_ref[...] = acc


@functools.partial(jax.jit, static_argnames=("scale", "shift", "br", "bc", "interpret"))
def decode_pallas(packed: jax.Array, *, scale: float = 1.0 / 255.0,
                  shift: float = 0.0, br: int = DEFAULT_BR, bc: int = DEFAULT_BC,
                  interpret: bool = False) -> jax.Array:
    """(R, C) uint32 -> (4, R, C) f32; R % br == 0, C % bc == 0 (ops.py pads)."""
    r, c = packed.shape
    br, bc = min(br, r), min(bc, c)
    assert r % br == 0 and c % bc == 0, (r, c, br, bc)
    grid = (r // br, c // bc)
    return pl.pallas_call(
        functools.partial(_decode_kernel, scale=scale, shift=shift),
        grid=grid,
        in_specs=[pl.BlockSpec((br, bc), lambda i, j: (i, j))],
        out_specs=pl.BlockSpec((LANES, br, bc), lambda i, j: (0, i, j)),
        out_shape=jax.ShapeDtypeStruct((LANES, r, c), jnp.float32),
        interpret=interpret,
    )(packed)


@functools.partial(jax.jit, static_argnames=("br", "bc", "interpret"))
def encode_pallas(lanes_u8: jax.Array, *, br: int = DEFAULT_BR,
                  bc: int = DEFAULT_BC, interpret: bool = False) -> jax.Array:
    """(4, R, C) uint8 -> (R, C) uint32."""
    _, r, c = lanes_u8.shape
    br, bc = min(br, r), min(bc, c)
    assert r % br == 0 and c % bc == 0, (r, c, br, bc)
    grid = (r // br, c // bc)
    return pl.pallas_call(
        _encode_kernel,
        grid=grid,
        in_specs=[pl.BlockSpec((LANES, br, bc), lambda i, j: (0, i, j))],
        out_specs=pl.BlockSpec((br, bc), lambda i, j: (i, j)),
        out_shape=jax.ShapeDtypeStruct((r, c), jnp.uint32),
        interpret=interpret,
    )(lanes_u8)
