"""Public E-D codec ops: shape-polymorphic wrappers with backend dispatch.

``decode(packed, out_batch)`` is the network's input adapter (the paper's
"custom deep learning layer to decode each input matrix").  Dispatch:

  backend='pallas'     compiled TPU kernel
  backend='interpret'  Pallas interpret mode (CPU tests)
  backend='ref'        pure jnp (dry-run lowering; numerically identical)
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.pack import kernel, ref
from repro.kernels.pack.ref import LANES


def _to_2d(x: jax.Array, bc: int):
    """Flatten to (R, C) with C a multiple of 128 and R of 8; pad with zeros."""
    flat = x.reshape(-1)
    c = min(bc, max(128, 1 << (len(flat) - 1).bit_length() // 2))
    c = max(128, (c // 128) * 128)
    r = -(-flat.size // c)
    r_pad = -(-r // 8) * 8
    pad = r_pad * c - flat.size
    flat = jnp.pad(flat, (0, pad))
    return flat.reshape(r_pad, c), pad


def decode(packed: jax.Array, *, scale: float = 1.0 / 255.0, shift: float = 0.0,
           backend: str = "ref") -> jax.Array:
    """uint32 (M, ...) -> float32 (4*M, ...): unpack + normalize.

    The leading axis is the container axis (container j holds images
    4j..4j+3), matching ``repro.core.encoding.pack_u8_to_u32``.
    """
    if packed.dtype != jnp.uint32:
        raise TypeError(f"decode expects uint32, got {packed.dtype}")
    m = packed.shape[0]
    rest = packed.shape[1:]
    if backend == "ref":
        lanes = ref.decode_ref(packed.reshape(m, -1), scale, shift)
    else:
        x2d, pad = _to_2d(packed, kernel.DEFAULT_BC)
        out = kernel.decode_pallas(
            x2d, scale=scale, shift=shift, interpret=(backend == "interpret")
        )
        flat = out.reshape(LANES, -1)
        flat = flat[:, : flat.shape[1] - pad] if pad else flat
        lanes = flat.reshape(LANES, m, -1)
    # (4, M, prod(rest)) -> (4*M, ...): image i = container i//4, lane i%4
    out = jnp.swapaxes(lanes, 0, 1).reshape((LANES * m,) + rest)
    return out


def encode(images_u8: jax.Array, *, backend: str = "ref") -> jax.Array:
    """uint8 (N, ...) with N%4==0 -> uint32 (N//4, ...)."""
    if images_u8.dtype != jnp.uint8:
        raise TypeError(f"encode expects uint8, got {images_u8.dtype}")
    n = images_u8.shape[0]
    rest = images_u8.shape[1:]
    lanes = images_u8.reshape((n // LANES, LANES) + rest)
    lanes = jnp.swapaxes(lanes, 0, 1).reshape(LANES, n // LANES, -1)
    if backend == "ref":
        out = ref.encode_ref(lanes.reshape(LANES, -1)[:, None, :]
                             ).reshape(n // LANES, -1)
    else:
        x2d = lanes.reshape(LANES, -1)
        pad_src, pad = _to_2d(x2d[0], kernel.DEFAULT_BC)
        stacked = jnp.stack([_to_2d(x2d[i], kernel.DEFAULT_BC)[0] for i in range(LANES)])
        out2d = kernel.encode_pallas(stacked, interpret=(backend == "interpret"))
        flat = out2d.reshape(-1)
        flat = flat[: flat.size - pad] if pad else flat
        out = flat.reshape(n // LANES, -1)
    return out.reshape((n // LANES,) + rest)
