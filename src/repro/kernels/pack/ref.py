"""Pure-jnp oracle for the E-D codec kernels (paper Alg. 1/3, u32 form)."""
from __future__ import annotations

import jax.numpy as jnp

LANES = 4  # u8 images per u32 container


def decode_ref(packed: jnp.ndarray, scale: float = 1.0 / 255.0,
               shift: float = 0.0) -> jnp.ndarray:
    """(R, C) uint32 -> (LANES, R, C) float32, decode + normalize fused."""
    shifts = (jnp.arange(LANES, dtype=jnp.uint32) * 8)[:, None, None]
    lanes = (packed[None] >> shifts) & jnp.uint32(0xFF)
    return lanes.astype(jnp.float32) * scale + shift


def encode_ref(lanes_u8: jnp.ndarray) -> jnp.ndarray:
    """(LANES, R, C) uint8 -> (R, C) uint32."""
    shifts = (jnp.arange(LANES, dtype=jnp.uint32) * 8)[:, None, None]
    return (lanes_u8.astype(jnp.uint32) << shifts).sum(0).astype(jnp.uint32)
