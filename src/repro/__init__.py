"""OpTorch reproduction: optimized training/serving framework in JAX.

Core paper features: repro.core (S-C, M-P, E-D, SBS).
Framework: repro.models / distributed / train / checkpointing / launch.
"""
__version__ = "1.0.0"
