"""Request-scoped tracing: span_begin/span_end records on the event
stream, reconstructed into timelines by ``tools/tracelens.py``.

A span is two events sharing an ``sid``:

    span_begin  name, sid, trace, parent, pid, ts, **attrs
    span_end    sid, ts, **attrs

``ts`` is ``time.perf_counter()`` — monotonic, comparable across every
tracer in one process (the fleet tests run replicas in-process for
exactly this reason).  ``trace`` is the request identity the span
belongs to: the engine uses ``key_id or rid``, the router uses ``gid``,
and because migrated/recovered requests keep their gid the whole
lifetime stitches together across replicas.  Both halves are emitted
(not one folded "complete" record) so a crash leaves the open spans
visible in the stream — an unclosed ``decode`` span after kill -9 is
the observation, not a bug.

Every call site guards ``if tracer is not None`` so the traced-off path
costs nothing; the overhead contract (tokens/s >= 0.95x untraced,
compile_counts frozen) is ratcheted via BENCH_obs.json.
"""
from __future__ import annotations

import itertools
import os
import time
from contextlib import contextmanager, nullcontext

from repro.obs.schema import SPAN_NAMES

#: per-process tracer instance counter: two tracers with the same pid
#: label (e.g. a restarted "router" appending to the same event file)
#: must never reuse span ids, or the new run's span_end records would
#: pair against the crashed run's still-open begins
_INSTANCES = itertools.count()


class Tracer:
    """Emits span records for one process/component to an EventSink.

    ``pid`` namespaces the span ids (and becomes the Perfetto process
    lane), so multiple tracers can share one sink: the router traces as
    ``router``, replica ``i`` as ``r{i}``, the journal as ``journal``.
    """

    def __init__(self, sink, *, pid: str = "main",
                 clock=time.perf_counter) -> None:
        self.sink = sink
        self.pid = pid
        self.clock = clock
        self._ns = f"{os.getpid()}.{next(_INSTANCES)}"
        self._n = 0

    def begin(self, name: str, *, trace=None, parent=None, **attrs) -> str:
        if name not in SPAN_NAMES:
            raise ValueError(f"undeclared span name {name!r}; add it to "
                             f"repro.obs.schema.SPAN_NAMES")
        self._n += 1
        sid = f"{self.pid}:{self._ns}:{self._n}"
        self.sink.emit("span_begin", name=name, sid=sid, trace=trace,
                       parent=parent, pid=self.pid, ts=self.clock(),
                       **attrs)
        return sid

    def end(self, sid, **attrs) -> None:
        if sid is None:          # begin was skipped (tracer attached late)
            return
        self.sink.emit("span_end", sid=sid, ts=self.clock(), **attrs)

    @contextmanager
    def span(self, name: str, *, trace=None, parent=None, **attrs):
        sid = self.begin(name, trace=trace, parent=parent, **attrs)
        try:
            yield sid
        finally:
            self.end(sid)


def maybe_span(tracer, name: str, **kw):
    """``with maybe_span(self.tracer, "step"):`` — a no-op context when
    tracing is off, so call sites stay one line."""
    if tracer is None:
        return nullcontext()
    return tracer.span(name, **kw)
