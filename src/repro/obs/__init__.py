"""repro.obs — the unified observability plane (ISSUE 10).

* :mod:`repro.obs.registry` — counters/gauges/streaming histograms with
  exact order-independent snapshot merges (safe across the worker RPC
  boundary).
* :mod:`repro.obs.trace` — request-scoped span records on the event
  stream; ``tools/tracelens.py`` turns them into timelines and Perfetto
  ``trace.json``.
* :mod:`repro.obs.schema` — the closed-world registry of event kinds and
  span names (CI fails on undeclared kinds).
* :mod:`repro.obs.memstat` — planner-vs-live memory reconciliation.
"""
from repro.obs.memstat import MemStat
from repro.obs.registry import (Counter, Gauge, Histogram, MetricsRegistry,
                                hist_quantile)
from repro.obs.schema import EVENT_KINDS, SPAN_NAMES
from repro.obs.trace import Tracer, maybe_span

__all__ = [
    "Counter", "Gauge", "Histogram", "MetricsRegistry", "hist_quantile",
    "Tracer", "maybe_span", "MemStat", "EVENT_KINDS", "SPAN_NAMES",
]
