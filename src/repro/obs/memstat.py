"""Planner-vs-live memory reconciliation.

The planner (``repro.plan``) predicts peak bytes; nothing so far checked
the prediction against what is actually resident.  ``MemStat.sample``
sums ``jax.live_arrays()`` (host-visible handle bytes — the honest
"what is still alive" number on every backend, including the CPU CI
where ``device.memory_stats()`` is None), folds in allocator stats when
the backend exposes them, and scores the result against the plan
budget: ``mem_sample`` events carry ``frac_of_plan`` so a trace shows
exactly when live bytes cross the planned peak.
"""
from __future__ import annotations


class MemStat:
    def __init__(self, *, sink=None, registry=None, plan_bytes=None,
                 replica=None) -> None:
        self.sink = sink
        self.registry = registry
        self.plan_bytes = plan_bytes
        self.replica = replica
        self.peak_bytes = 0
        self.samples = 0

    def sample(self, step: int) -> dict:
        import jax

        live = n = 0
        try:
            for a in jax.live_arrays():
                live += a.nbytes
                n += 1
        except Exception:            # backend without live_arrays support
            live = n = -1
        dev_peak = None
        try:
            stats = jax.devices()[0].memory_stats()
            if stats:
                dev_peak = int(stats.get("peak_bytes_in_use", 0))
        except Exception:            # CPU backend: memory_stats is None
            pass
        rec = {"step": step, "live_bytes": live, "n_arrays": n}
        if dev_peak is not None:
            rec["device_peak_bytes"] = dev_peak
        if self.plan_bytes:
            rec["plan_bytes"] = int(self.plan_bytes)
            rec["frac_of_plan"] = round(live / self.plan_bytes, 4) \
                if live >= 0 else None
        if self.replica is not None:
            rec["replica"] = self.replica
        self.samples += 1
        if live > self.peak_bytes:
            self.peak_bytes = live
        if self.registry is not None:
            self.registry.set("mem.live_bytes", live)
            self.registry.observe("mem.live_mb", live / 2**20)
        if self.sink is not None:
            self.sink.emit("mem_sample", **rec)
        return rec

    def banner(self) -> str:
        """One line for the launch banner."""
        peak_mb = self.peak_bytes / 2**20
        if self.plan_bytes:
            return (f"mem: live peak {peak_mb:.1f} MB, plan "
                    f"{self.plan_bytes / 2**20:.1f} MB "
                    f"({self.peak_bytes / self.plan_bytes:.2f}x) "
                    f"over {self.samples} samples")
        return f"mem: live peak {peak_mb:.1f} MB over {self.samples} samples"
