"""The single registry of event kinds and span names.

Every ``kind`` that can appear in a ``repro.events`` JSONL stream is
declared here — CI scans the source tree for literal emit callsites and
fails on any kind that is not in :data:`EVENT_KINDS` (see
``tools/ci_ratchet.py``), so a new subsystem cannot quietly invent a
private vocabulary that ``tools/tracelens.py`` and downstream consumers
do not understand.  Span *names* get the same treatment via
:data:`SPAN_NAMES`: ``obs.trace.Tracer`` refuses names that are not
declared, which keeps the timeline exporter's segment classification
closed-world.
"""
from __future__ import annotations

import re

# kind -> one-line description (the contract tracelens + dashboards read)
EVENT_KINDS = {
    # --- serve metrics (ServeMetrics._event) -------------------------
    "terminal": "a request reached a terminal state (rid, state, tokens)",
    "reject": "admission rejected a submit (backpressure)",
    "fault": "decode sentinel tripped on a request (rid)",
    "retry": "a faulted request was requeued for replay (rid, attempt)",
    # --- train guards (TrainGuard._emit) -----------------------------
    "guard_skip": "guard skipped an update (reason, loss, streak)",
    "guard_rollback": "guard escalated to checkpoint rollback",
    "watchdog_alert": "a train step overran the watchdog budget",
    # --- router (Router._event) --------------------------------------
    "health": "replica health transition (replica, frm, to)",
    "place": "fleet request placed on a replica (gid, replica, rid)",
    "failover": "fleet request evacuated off a replica (gid, reason)",
    "fleet_terminal": "fleet request reached a terminal state (gid, state)",
    "fleet_reject": "every replica rejected a submit (gid)",
    "recover": "journal recovery re-submitted a live request (gid)",
    "pause": "chaos/operator paused a replica (replica, steps)",
    # --- write-ahead request journal (RequestJournal._append) --------
    "wal_submit": "WAL: request accepted by the fleet",
    "wal_place": "WAL: request placed on a replica",
    "wal_tokens": "WAL: durable token batch (gid, start, toks)",
    "wal_migrate": "WAL: request evacuated, will be re-placed",
    "wal_terminal": "WAL: request reached a terminal state",
    # --- observability plane (repro.obs) -----------------------------
    "span_begin": "trace span opened (name, sid, trace, parent, pid, ts)",
    "span_end": "trace span closed (sid, ts, + outcome attrs)",
    "metrics_snapshot": "periodic registry snapshot (counters/gauges/hists)",
    "mem_sample": "live-bytes sample scored against the plan budget",
}

# span name -> one-line description.  Segment classification in
# tools/tracelens.py keys off these names, so they are closed-world too.
SPAN_NAMES = {
    # engine / scheduler (trace = rid, or gid when key_id is set)
    "req": "whole request: submit -> terminal (root span)",
    "queue": "QUEUED: waiting for a slot (reason=submit|replay)",
    "prefill": "prompt prefill + scatter + first token",
    "decode": "DECODE residency: first token -> retirement",
    "step": "one engine step (admissions + fused decode + harvest)",
    # router (trace = gid)
    "fleet_req": "whole fleet request: fleet submit -> fleet terminal",
    "place": "placement attempt on a replica",
    "migrate": "evacuation -> successful re-placement elsewhere",
    "recover": "journal recovery replay of one live request",
    # infrastructure
    "rpc": "one worker RPC round-trip (op=...)",
    "journal_append": "one WAL append (+ group-commit fsync when due)",
    "journal_snapshot": "atomic .snap compaction",
    # train driver
    "data": "host data step: next(loader) + device put",
    "train_step": "jitted train step dispatch + loss sync",
    "guard": "guard verdict on the synced loss/grads",
    "checkpoint": "checkpoint save (or rollback restore)",
}


# literal emit callsites: EventSink.emit / the private wrappers every
# subsystem routes through (ServeMetrics._event, Router._event,
# RequestJournal._append, TrainGuard._emit, Tracer's own emits)
_EMIT_RE = re.compile(
    r"(?:\.emit|self\._event|self\._append|self\._emit)\(\s*"
    r"[\"']([a-z_]+)[\"']")


def undeclared_kinds_in_source(src_root: str):
    """Scan ``src_root`` for literal event-kind emit callsites and return
    ``{kind: [file:line, ...]}`` for any kind not in EVENT_KINDS."""
    import os

    bad: dict = {}
    for dirpath, _dirnames, filenames in os.walk(src_root):
        for fn in filenames:
            if not fn.endswith(".py"):
                continue
            path = os.path.join(dirpath, fn)
            with open(path) as f:
                for lineno, line in enumerate(f, 1):
                    for m in _EMIT_RE.finditer(line):
                        kind = m.group(1)
                        if kind not in EVENT_KINDS:
                            bad.setdefault(kind, []).append(
                                f"{path}:{lineno}")
    return bad


def validate_events(path: str):
    """Return the set of undeclared kinds found in an events file."""
    from repro.events import read_events

    return {e["kind"] for e in read_events(path)} - set(EVENT_KINDS)
