"""Streaming metrics registry: counters, gauges, and bounded-memory
histograms whose snapshots merge exactly across the worker RPC boundary.

Design constraints (ISSUE 10):

* **Bounded memory.**  A histogram is a sparse dict of log2 buckets plus
  exact ``n/sum/min/max`` — O(number of distinct magnitudes), never
  O(samples).  ``ServeMetrics`` retires per-request stats into these at
  terminal time, so a long-running router holds O(live) metric state.
* **Exact merges.**  Fixed log2 buckets (unlike P²/t-digest centroids)
  merge by elementwise count addition, which is commutative AND
  associative — ``merge(a, b) == merge(b, a)`` holds bit-for-bit, so the
  router can fold per-replica RPC snapshots in any arrival order.
* **Plain-JSON snapshots.**  ``snapshot()`` returns nothing but dicts,
  strings, ints and floats: it pickles across the worker pipe, survives
  a round-trip through the JSONL event stream (``metrics_snapshot``
  events), and merges on either side of the boundary.

Means are exact (``sum / n``); quantiles interpolate inside a bucket and
are clamped to the observed ``[min, max]`` — a log2 bucket bounds the
relative quantile error at 2x, plenty for latency breakdowns.
"""
from __future__ import annotations

import math
import threading

# log2 bucket span: bucket e covers [2^(e-1), 2^e).  Clamp keeps the
# vocabulary finite for adversarial values (denormals, +inf).
_E_MIN, _E_MAX = -30, 33
_ZERO = _E_MIN - 1          # bucket for v <= 0


def _bucket(v: float) -> int:
    if not v > 0.0 or math.isinf(v):
        return _ZERO if not v > 0.0 else _E_MAX
    return min(max(math.frexp(v)[1], _E_MIN), _E_MAX)


def _bucket_hi(e: int) -> float:
    return 0.0 if e == _ZERO else 2.0 ** e


def _bucket_lo(e: int) -> float:
    return 0.0 if e <= _E_MIN else 2.0 ** (e - 1)


class Counter:
    __slots__ = ("value",)

    def __init__(self) -> None:
        self.value = 0

    def inc(self, n: int = 1) -> None:
        self.value += n


class Gauge:
    """Last-write-wins sample; ``updates`` orders merges deterministically."""
    __slots__ = ("value", "updates")

    def __init__(self) -> None:
        self.value = 0.0
        self.updates = 0

    def set(self, v: float) -> None:
        self.value = float(v)
        self.updates += 1


class Histogram:
    """Sparse log2-bucket streaming histogram with exact n/sum/min/max."""
    __slots__ = ("n", "sum", "min", "max", "counts")

    def __init__(self) -> None:
        self.n = 0
        self.sum = 0.0
        self.min = math.inf
        self.max = -math.inf
        self.counts: dict = {}

    def observe(self, v: float) -> None:
        v = float(v)
        self.n += 1
        self.sum += v
        if v < self.min:
            self.min = v
        if v > self.max:
            self.max = v
        e = _bucket(v)
        self.counts[e] = self.counts.get(e, 0) + 1

    @property
    def mean(self) -> float:
        return self.sum / self.n if self.n else 0.0

    def quantile(self, q: float) -> float:
        if not self.n:
            return 0.0
        rank = q * (self.n - 1)
        seen = 0
        for e in sorted(self.counts):
            c = self.counts[e]
            if seen + c > rank:
                lo, hi = _bucket_lo(e), _bucket_hi(e)
                frac = (rank - seen + 1) / c          # position in bucket
                v = lo + (hi - lo) * min(frac, 1.0)
                return min(max(v, self.min), self.max)
            seen += c
        return self.max

    def to_dict(self) -> dict:
        return {"n": self.n, "sum": self.sum,
                "min": self.min if self.n else 0.0,
                "max": self.max if self.n else 0.0,
                "counts": {str(e): c for e, c in sorted(self.counts.items())}}


def hist_quantile(h: dict, q: float) -> float:
    """Quantile straight off a histogram *snapshot* dict."""
    n = h.get("n", 0)
    if not n:
        return 0.0
    rank = q * (n - 1)
    seen = 0
    for e in sorted(int(k) for k in h["counts"]):
        c = h["counts"][str(e)]
        if seen + c > rank:
            lo, hi = _bucket_lo(e), _bucket_hi(e)
            v = lo + (hi - lo) * min((rank - seen + 1) / c, 1.0)
            return min(max(v, h["min"]), h["max"])
        seen += c
    return h["max"]


class MetricsRegistry:
    """Create-on-demand named counters/gauges/histograms with JSON
    snapshots and an exact, order-independent snapshot merge."""

    def __init__(self) -> None:
        self._lock = threading.Lock()
        self._counters: dict = {}
        self._gauges: dict = {}
        self._hists: dict = {}

    # --- create-on-demand accessors ----------------------------------
    def counter(self, name: str) -> Counter:
        with self._lock:
            c = self._counters.get(name)
            if c is None:
                c = self._counters[name] = Counter()
            return c

    def gauge(self, name: str) -> Gauge:
        with self._lock:
            g = self._gauges.get(name)
            if g is None:
                g = self._gauges[name] = Gauge()
            return g

    def histogram(self, name: str) -> Histogram:
        with self._lock:
            h = self._hists.get(name)
            if h is None:
                h = self._hists[name] = Histogram()
            return h

    # --- conveniences -------------------------------------------------
    def inc(self, name: str, n: int = 1) -> None:
        self.counter(name).inc(n)

    def set(self, name: str, v: float) -> None:
        self.gauge(name).set(v)

    def observe(self, name: str, v: float) -> None:
        self.histogram(name).observe(v)

    def count(self, name: str) -> int:
        return self._counters[name].value if name in self._counters else 0

    # --- snapshots -----------------------------------------------------
    def snapshot(self) -> dict:
        with self._lock:
            return {
                "counters": {k: c.value for k, c in self._counters.items()},
                "gauges": {k: {"value": g.value, "updates": g.updates}
                           for k, g in self._gauges.items()},
                "hists": {k: h.to_dict() for k, h in self._hists.items()},
            }

    @staticmethod
    def merge(a: dict, b: dict) -> dict:
        """Merge two snapshot dicts.  Commutative and associative:
        counters/hist-counts add, gauges keep the sample with the most
        updates (value breaks ties), min/max fold through min/max."""
        out = {"counters": dict(a.get("counters", {})),
               "gauges": {k: dict(v)
                          for k, v in a.get("gauges", {}).items()},
               "hists": {k: {**v, "counts": dict(v["counts"])}
                         for k, v in a.get("hists", {}).items()}}
        for k, v in b.get("counters", {}).items():
            out["counters"][k] = out["counters"].get(k, 0) + v
        for k, g in b.get("gauges", {}).items():
            cur = out["gauges"].get(k)
            # max on (updates, value): a deterministic, order-independent
            # winner even though gauges are last-write-wins in spirit
            if cur is None or (g["updates"], g["value"]) > \
                    (cur["updates"], cur["value"]):
                out["gauges"][k] = dict(g)
        for k, h in b.get("hists", {}).items():
            cur = out["hists"].get(k)
            if cur is None:
                out["hists"][k] = {**h, "counts": dict(h["counts"])}
                continue
            # empty snapshots carry min=max=0.0 placeholders; only fold
            # extrema from sides that actually observed samples
            if not cur["n"]:
                cur["min"], cur["max"] = h["min"], h["max"]
            elif h["n"]:
                cur["min"] = min(cur["min"], h["min"])
                cur["max"] = max(cur["max"], h["max"])
            cur["n"] += h["n"]
            cur["sum"] += h["sum"]
            for e, c in h["counts"].items():
                cur["counts"][e] = cur["counts"].get(e, 0) + c
        return out

    def emit(self, sink, **extra) -> None:
        """Write a ``metrics_snapshot`` event to an EventSink."""
        if sink is not None:
            sink.emit("metrics_snapshot", snapshot=self.snapshot(), **extra)
