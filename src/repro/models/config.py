"""Model configuration dataclasses for every supported family."""
from __future__ import annotations

import dataclasses
from typing import Optional, Tuple


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    num_experts: int
    top_k: int
    d_expert: int                 # per-expert FFN hidden
    num_shared: int = 0           # always-on shared experts (deepseek)
    d_shared: int = 0             # shared-expert FFN hidden (total)
    router_dtype: str = "float32"
    expert_mode: str = "tp"       # 'tp' (shard d_expert) | 'ep' (shard experts)
    capacity_factor: float = 1.25  # 0 => dropless (sort + ragged_dot)


@dataclasses.dataclass(frozen=True)
class MLAConfig:                  # Multi-head Latent Attention (MiniCPM3/DeepSeek)
    q_lora_rank: int
    kv_lora_rank: int
    qk_nope_dim: int
    qk_rope_dim: int
    v_head_dim: int


@dataclasses.dataclass(frozen=True)
class SSMConfig:                  # mamba2 / SSD
    d_state: int
    d_inner: int                  # = heads * head_p
    head_p: int = 64              # P, per-head channels
    conv_kernel: int = 4
    chunk: int = 128
    dt_min: float = 0.001
    dt_max: float = 0.1

    @property
    def heads(self) -> int:
        return self.d_inner // self.head_p


@dataclasses.dataclass(frozen=True)
class EncoderConfig:              # whisper-style frame encoder (frontend = stub)
    n_layers: int
    n_frames: int = 1500          # post-conv frame count the stub emits


@dataclasses.dataclass(frozen=True)
class ModelConfig:
    arch_id: str
    family: str                   # dense | moe | ssm | hybrid | encdec | vlm
    n_layers: int
    d_model: int
    n_heads: int
    n_kv: int
    d_ff: int
    vocab: int
    head_dim: int = 0             # 0 => d_model // n_heads
    mixer: str = "attn"           # attn | ssm | hybrid
    mlp_kind: str = "swiglu"      # swiglu | gelu
    rope_theta: float = 10000.0
    rope_fraction: float = 1.0    # glm4 rotates half the head dim
    mrope_sections: Optional[Tuple[int, int, int]] = None  # qwen2-vl
    norm_eps: float = 1e-5
    window: int = 0               # 0 => full causal; else sliding window
    global_layers: Tuple[int, ...] = ()   # layers that override window -> full
    moe: Optional[MoEConfig] = None
    mla: Optional[MLAConfig] = None
    ssm: Optional[SSMConfig] = None
    encoder: Optional[EncoderConfig] = None
    tie_embeddings: bool = False
    subquadratic: bool = False    # eligible for long_500k shapes
    norm_bf16_grad: bool = False  # perf: bf16 cotangent out of RMSNorm
    # jnp | interpret | pallas — kernels/flash is fwd+bwd differentiable
    # (custom_vjp with O(S*D) residuals), so "pallas" is legal for training
    attn_backend: str = "jnp"

    ATTN_BACKENDS = ("jnp", "interpret", "pallas")

    def __post_init__(self):
        if self.head_dim == 0 and self.n_heads:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.attn_backend not in self.ATTN_BACKENDS:
            raise ValueError(
                f"attn_backend={self.attn_backend!r} not in "
                f"{self.ATTN_BACKENDS}")

    @property
    def padded_vocab(self) -> int:
        """Vocab rounded up to a multiple of 256 so the embedding/LM-head
        can always shard over a <=256-way model axis (standard TP padding;
        rows beyond ``vocab`` are dead weight, logits there are masked)."""
        return -(-self.vocab // 256) * 256

    # ------------------------------------------------------------- sizing --
    def param_count(self) -> int:
        """Analytic parameter count (embeddings included once if tied)."""
        d, l = self.d_model, self.n_layers
        total = self.vocab * d                     # embed
        if not self.tie_embeddings:
            total += self.vocab * d                # lm head
        total += d                                 # final norm
        per_layer = 0
        if self.mixer in ("attn", "hybrid"):
            per_layer += d                         # ln1
            if self.mla is not None:
                m = self.mla
                per_layer += d * m.q_lora_rank + m.q_lora_rank
                per_layer += m.q_lora_rank * self.n_heads * (m.qk_nope_dim + m.qk_rope_dim)
                per_layer += d * (m.kv_lora_rank + m.qk_rope_dim) + m.kv_lora_rank
                per_layer += m.kv_lora_rank * self.n_heads * (m.qk_nope_dim + m.v_head_dim)
                per_layer += self.n_heads * m.v_head_dim * d
            else:
                hd = self.head_dim
                per_layer += d * self.n_heads * hd          # wq
                per_layer += 2 * d * self.n_kv * hd         # wk, wv
                per_layer += self.n_heads * hd * d          # wo
        if self.mixer in ("ssm", "hybrid"):
            s = self.ssm
            per_layer += d  # ln (shared with ln1 in hybrid; close enough)
            conv_dim = s.d_inner + 2 * s.d_state
            per_layer += d * (2 * s.d_inner + 2 * s.d_state + s.heads)  # in_proj
            per_layer += conv_dim * s.conv_kernel                        # conv
            per_layer += 3 * s.heads                                     # A, D, dt_bias
            per_layer += s.d_inner                                       # gated norm
            per_layer += s.d_inner * d                                   # out_proj
        # FFN
        per_layer += d                             # ln2
        if self.moe is not None:
            m = self.moe
            per_layer += d * m.num_experts                               # router
            per_layer += m.num_experts * 3 * d * m.d_expert              # experts
            if m.num_shared:
                per_layer += 3 * d * m.d_shared                          # shared
        elif self.d_ff:
            mult = 3 if self.mlp_kind == "swiglu" else 2
            per_layer += mult * d * self.d_ff
        total += l * per_layer
        if self.encoder is not None:
            hd = self.head_dim
            enc_layer = 2 * d + d * self.n_heads * hd + 2 * d * self.n_kv * hd \
                + self.n_heads * hd * d + 2 * d * self.d_ff
            # decoder cross-attention adds another attn block per layer
            total += self.encoder.n_layers * enc_layer + d
            total += l * (d + d * self.n_heads * hd + 2 * d * self.n_kv * hd
                          + self.n_heads * hd * d)
        return total

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top-k + shared only)."""
        if self.moe is None:
            return self.param_count()
        m = self.moe
        dense_experts = self.n_layers * m.num_experts * 3 * self.d_model * m.d_expert
        active_experts = self.n_layers * m.top_k * 3 * self.d_model * m.d_expert
        return self.param_count() - dense_experts + active_experts
