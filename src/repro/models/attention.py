"""Attention: GQA with full / sliding-window masks, memory-efficient chunked
softmax for long prefill, MLA (multi-head latent attention), and cached
decode paths (optionally over an int8-quantized cache via kernels/kvq)."""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp

from repro.kernels.kvq import ops as kvq_ops
from repro.models.layers import apply_rope, rms_norm

NEG_INF = -1e30
CHUNKED_THRESHOLD = 4096   # S*S f32 scores above this use the chunked path
KV_CHUNK = 1024


def _mask_bias(q_pos, k_pos, window, dtype):
    """(..., Sq, Sk) additive bias: causal + optional sliding window.

    ``window`` may be a python int or a traced scalar (hybrid archs switch
    window/global per layer inside a scan); window <= 0 means full causal.
    """
    dist = q_pos[..., :, None] - k_pos[..., None, :]
    ok = dist >= 0
    if isinstance(window, int):
        if window > 0:
            ok &= dist < window
    else:
        ok &= jnp.where(window > 0, dist < window, True)
    return jnp.where(ok, 0.0, NEG_INF).astype(dtype)


def gqa_attention(q, k, v, *, q_pos, k_pos, window: int = 0,
                  causal: bool = True, sm_scale: Optional[float] = None):
    """q: (B, Sq, H, D); k, v: (B, Sk, Hkv, D) -> (B, Sq, H, D).

    Uses a one-shot einsum for short sequences and a KV-chunked
    online-softmax scan (flash-style, O(Sq * chunk) live scores) for long
    ones — the S-C idea (recompute over store) applied to attention scores.
    """
    b, sq, h, d = q.shape
    sk, hkv = k.shape[1], k.shape[2]
    dv = v.shape[-1]
    g = h // hkv
    scale = sm_scale if sm_scale is not None else d ** -0.5
    qg = q.reshape(b, sq, hkv, g, d)

    if sq * sk <= CHUNKED_THRESHOLD ** 2 // 4 or sk <= KV_CHUNK:
        logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg.astype(jnp.float32),
                            k.astype(jnp.float32)) * scale
        if causal:
            logits += _mask_bias(q_pos, k_pos, window, jnp.float32
                                 )[:, None, None]
        p = jax.nn.softmax(logits, axis=-1)
        out = jnp.einsum("bhgqk,bkhd->bqhgd", p, v.astype(jnp.float32))
        return out.reshape(b, sq, h, dv).astype(q.dtype)

    # ---- chunked path (python loop: unrolled in HLO so dry-run cost
    # analysis counts every chunk; XLA's buffer allocator still reuses the
    # per-chunk score buffers, keeping live scores O(Sq x chunk)) ----
    nchunk = -(-sk // KV_CHUNK)
    pad = nchunk * KV_CHUNK - sk
    if pad:
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))
        k_pos = jnp.pad(k_pos, ((0, 0), (0, pad)), constant_values=2 ** 30)

    m = jnp.full((b, hkv, g, sq), NEG_INF, jnp.float32)
    l = jnp.zeros((b, hkv, g, sq), jnp.float32)
    acc = jnp.zeros((b, hkv, g, sq, dv), jnp.float32)
    qf = qg.astype(jnp.float32)
    for c in range(nchunk):
        sl = slice(c * KV_CHUNK, (c + 1) * KV_CHUNK)
        kc, vc, pc = k[:, sl], v[:, sl], k_pos[:, sl]
        logits = jnp.einsum("bqhgd,bkhd->bhgqk", qf,
                            kc.astype(jnp.float32)) * scale
        if causal:
            logits += _mask_bias(q_pos, pc, window, jnp.float32)[:, None, None]
        m_new = jnp.maximum(m, logits.max(-1))
        alpha = jnp.exp(m - m_new)
        p = jnp.exp(logits - m_new[..., None])
        acc = acc * alpha[..., None] + jnp.einsum(
            "bhgqk,bkhd->bhgqd", p, vc.astype(jnp.float32))
        l = l * alpha + p.sum(-1)
        m = m_new
    out = acc / jnp.maximum(l, 1e-30)[..., None]          # (B,Hkv,G,Sq,Dv)
    out = jnp.moveaxis(out, 3, 1).reshape(b, sq, h, dv)
    return out.astype(q.dtype)


# ---------------------------------------------------------------------------
# Standard GQA block (projections + rope + attention).
# ---------------------------------------------------------------------------
def attn_block(p, x, cfg, *, positions, window: int = 0, layer_window=None,
               causal: bool = True, mesh=None, flash_resid_dtype=None):
    """x: (B, S, D_model).  p holds wq/wk/wv/wo.  Returns (out, (k, v)).

    ``flash_resid_dtype`` is the mixed-precision policy for the flash
    custom_vjp's saved (q, k, v, o) residuals (see Policy.flash_resid_dtype);
    it only matters on the flash branch — jnp autodiff owns its own
    residuals."""
    b, s, _ = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv, cfg.head_dim
    q = (x @ p["wq"]).reshape(b, s, h, hd)
    k = (x @ p["wk"]).reshape(b, s, hkv, hd)
    v = (x @ p["wv"]).reshape(b, s, hkv, hd)
    # NOTE (tried & refuted, EXPERIMENTS §Perf): forcing MQA-style TP here
    # (q head-sharded, k/v replicated) when kv-heads don't divide the model
    # axis made llama3/glm4 15% MORE collective-bound — XLA's own hybrid
    # layout beats forced replication.  The deployed fix for mismatched
    # head counts is a per-arch mesh shape (TP width divides kv-heads;
    # e.g. granite trains on (32, 8): collective 7502 -> 538 ms).
    rope_pos = positions
    q = apply_rope(q, rope_pos, cfg.rope_theta, cfg.rope_fraction,
                   cfg.mrope_sections)
    k = apply_rope(k, rope_pos, cfg.rope_theta, cfg.rope_fraction,
                   cfg.mrope_sections)
    pos1d = positions[0] if positions.ndim == 3 else positions
    w = window if layer_window is None else layer_window
    if (cfg.attn_backend != "jnp" and causal and isinstance(w, int)
            and positions.ndim < 3):
        # Pallas flash kernel (prefill/training hot path) — differentiable
        # via its custom_vjp with O(S*D) residuals, so this branch is legal
        # under jax.grad.  Falls back to the jnp paths for traced per-layer
        # windows (hybrid scan) and M-RoPE; unsupported shapes fall back to
        # ref inside the op (one-time warning).
        from repro.kernels.flash import ops as flash_ops
        fa = functools.partial(
            flash_ops.flash_attention, causal=True, window=w,
            backend=cfg.attn_backend, resid_dtype=flash_resid_dtype)
        if mesh is not None:
            # shard_map over (data, model): batch rows and whole GQA groups
            # stay shard-local, so each device runs the UNCHANGED kernel on
            # its slice — no XLA partitioning decisions inside the kernel,
            # and the custom_vjp residuals are per-device by construction.
            # flash_shard_specs is None when the mesh can't split cleanly
            # (then the unsharded dispatch below lets XLA place it).
            from repro.distributed import sharding as shd
            spec = shd.flash_shard_specs(mesh, b, h, hkv)
            if spec is not None:
                from jax.experimental.shard_map import shard_map
                fa = shard_map(fa, mesh=mesh, in_specs=(spec, spec, spec),
                               out_specs=spec, check_rep=False)
        out = fa(jnp.swapaxes(q, 1, 2), jnp.swapaxes(k, 1, 2),
                 jnp.swapaxes(v, 1, 2))
        out = jnp.swapaxes(out, 1, 2)
    else:
        out = gqa_attention(q, k, v, q_pos=pos1d, k_pos=pos1d, window=w,
                            causal=causal)
    return out.reshape(b, s, h * hd) @ p["wo"], (k, v)


def cross_attn_block(p, x, enc_kv, cfg):
    """Decoder cross-attention over precomputed encoder K/V (no rope)."""
    b, s, _ = x.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv, cfg.head_dim
    k, v = enc_kv                                  # (B, Se, Hkv, hd)
    q = (x @ p["wq"]).reshape(b, s, h, hd)
    se = k.shape[1]
    g = h // hkv
    qg = q.reshape(b, s, hkv, g, hd).astype(jnp.float32)
    logits = jnp.einsum("bqhgd,bkhd->bhgqk", qg, k.astype(jnp.float32))
    logits = logits * hd ** -0.5
    pr = jax.nn.softmax(logits, -1)
    out = jnp.einsum("bhgqk,bkhd->bqhgd", pr, v.astype(jnp.float32))
    out = out.reshape(b, s, h * hd).astype(x.dtype)
    return out @ p["wo"]


# ---------------------------------------------------------------------------
# MLA (MiniCPM3 / DeepSeek-V2 style multi-head latent attention).
# ---------------------------------------------------------------------------
def mla_block(p, x, cfg, *, positions):
    """Latent-compressed attention; returns (out, (kv_latent, k_rope))."""
    m = cfg.mla
    b, s, _ = x.shape
    h = cfg.n_heads
    dn, dr, dv = m.qk_nope_dim, m.qk_rope_dim, m.v_head_dim

    q_lat = rms_norm(x @ p["q_a"], p["q_a_norm"], cfg.norm_eps, bf16_grad=cfg.norm_bf16_grad)
    q = (q_lat @ p["q_b"]).reshape(b, s, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]

    kv_all = x @ p["kv_a"]                               # (B,S,kv_lora+dr)
    kv_lat = rms_norm(kv_all[..., : m.kv_lora_rank], p["kv_a_norm"], cfg.norm_eps, bf16_grad=cfg.norm_bf16_grad)
    k_rope = kv_all[..., m.kv_lora_rank:].reshape(b, s, 1, dr)

    kv = (kv_lat @ p["kv_b"]).reshape(b, s, h, dn + dv)
    k_nope, v = kv[..., :dn], kv[..., dn:]

    q_rope = apply_rope(q_rope, positions, cfg.rope_theta)
    k_rope = apply_rope(k_rope, positions, cfg.rope_theta)
    qf = jnp.concatenate([q_nope, q_rope], -1)
    kf = jnp.concatenate([k_nope, jnp.broadcast_to(k_rope, (b, s, h, dr))], -1)
    out = gqa_attention(qf, kf, v, q_pos=positions, k_pos=positions,
                        sm_scale=(dn + dr) ** -0.5)
    out = out.reshape(b, s, h * dv)
    return out @ p["wo"], (kv_lat, k_rope)


def mla_decode(p, x_t, cfg, cache_lat, cache_rope, pos):
    """One-token MLA decode with weight absorption.

    The latent cache stores only (kv_lora + rope_dim) floats/token — MLA's
    whole point.  Scores and outputs are computed in latent space:
      score = (q_nope @ Wk_b) . kv_lat + q_rope . k_rope
      out   = (softmax . kv_lat) @ Wv_b
    cache_lat: (B, S, kv_lora); cache_rope: (B, S, dr); pos scalar.
    """
    m = cfg.mla
    b = x_t.shape[0]
    h = cfg.n_heads
    dn, dr, dv = m.qk_nope_dim, m.qk_rope_dim, m.v_head_dim
    s_max = cache_lat.shape[1]

    q_lat = rms_norm(x_t @ p["q_a"], p["q_a_norm"], cfg.norm_eps, bf16_grad=cfg.norm_bf16_grad)
    q = (q_lat @ p["q_b"]).reshape(b, h, dn + dr)
    q_nope, q_rope = q[..., :dn], q[..., dn:]
    pos_arr = jnp.full((b, 1), pos, jnp.int32)
    q_rope = apply_rope(q_rope[:, None], pos_arr, cfg.rope_theta)[:, 0]

    kv_all = x_t @ p["kv_a"]
    lat_new = rms_norm(kv_all[..., : m.kv_lora_rank], p["kv_a_norm"], cfg.norm_eps, bf16_grad=cfg.norm_bf16_grad)
    kr_new = apply_rope(kv_all[..., m.kv_lora_rank:][:, None, None],
                        pos_arr, cfg.rope_theta)[:, 0, 0]

    cl = jax.lax.dynamic_update_slice(
        cache_lat, lat_new[:, None].astype(cache_lat.dtype), (0, pos, 0))
    cr = jax.lax.dynamic_update_slice(
        cache_rope, kr_new[:, None].astype(cache_rope.dtype), (0, pos, 0))

    kv_b = p["kv_b"].reshape(m.kv_lora_rank, h, dn + dv)
    wk_b, wv_b = kv_b[..., :dn], kv_b[..., dn:]
    q_abs = jnp.einsum("bhd,lhd->bhl", q_nope.astype(jnp.float32),
                       wk_b.astype(jnp.float32))
    scores = jnp.einsum("bhl,bsl->bhs", q_abs, cl.astype(jnp.float32))
    scores += jnp.einsum("bhd,bsd->bhs", q_rope.astype(jnp.float32),
                         cr.astype(jnp.float32))
    scores = scores * (dn + dr) ** -0.5
    valid = jnp.arange(s_max)[None, :] <= pos
    scores = jnp.where(valid[:, None], scores, NEG_INF)
    pr = jax.nn.softmax(scores, -1)
    o_lat = jnp.einsum("bhs,bsl->bhl", pr, cl.astype(jnp.float32))
    out = jnp.einsum("bhl,lhd->bhd", o_lat, wv_b.astype(jnp.float32))
    out = out.reshape(b, h * dv).astype(x_t.dtype)
    return out @ p["wo"], (cl, cr)


# ---------------------------------------------------------------------------
# Cached single-token decode.
# ---------------------------------------------------------------------------
def _write_token(cache, new, at):
    """Write one token into the S axis of a per-layer cache leaf.

    cache: (B, Hkv, S, hd) or (B, Hkv, S); new: (B, Hkv, hd) / (B, Hkv);
    at: scalar int32 (lockstep batch — every row writes the same slot) or
    (B,) int32 (slot-pooled serving — each row writes at its own length).
    The vector case lowers to a per-row dynamic_update_slice under vmap
    (a scatter), keeping the write O(1) in S instead of a full-cache
    ``where`` rewrite.
    """
    if at.ndim == 0:
        return jax.lax.dynamic_update_slice(
            cache, new[:, :, None], (0, 0, at, 0)[:cache.ndim])
    if cache.ndim == 4:
        return jax.vmap(lambda c, n, a: jax.lax.dynamic_update_slice(
            c, n[:, None], (0, a, 0)))(cache, new, at)
    return jax.vmap(lambda c, n, a: jax.lax.dynamic_update_slice(
        c, n[:, None], (0, a)))(cache, new, at)


def attn_decode(p, x_t, cfg, cache_k, cache_s_k, cache_v, cache_s_v, pos,
                *, window: int = 0, quantized: bool = True, backend: str = "ref",
                splits: int = 1, rolling: bool = False, mesh=None,
                kv_shard: str = "none"):
    """One-token GQA decode against a (possibly int8) cache.

    x_t: (B, D_model); cache_k/v: (B, Hkv, S, hd) int8 (or bf16 when not
    quantized, scales ignored); pos: scalar int32 current position, or a
    per-row (B,) int32 vector for slot-pooled continuous batching
    (``repro.serve``) — each batch row then RoPE-rotates, writes, and
    masks at its OWN position, so one jitted step serves a ragged pool of
    in-flight requests with static shapes.
    ``rolling``: the cache is a circular window buffer of size S — writes
    land at ``pos % S`` and every filled slot is in-window by construction
    (two-tier cache for windowed layers; EXPERIMENTS §Perf).

    Masking is length-first: rolling buffers and full-causal (static
    window <= 0) schedules pass per-batch ``lengths`` through to
    ``decode_attention`` — the split-K kernel skips fully-padded KV tiles
    and masks the straddling tile with an in-kernel iota compare, and no
    (B, S) f32 bias tensor is built on ANY backend.  Only schedules
    lengths can't express (a window band over a non-rolling cache, or a
    traced per-layer window) fall back to the dense bias.  ``splits``
    selects the kernel's split-K fan-out.

    ``kv_shard`` (from ``sharding.serve_kv_shard``) names how the cache is
    laid out under ``mesh``: "heads" needs no code change here — XLA keeps
    the per-kv-head einsums and token write shard-local — while "seq"
    routes through ``collectives.sp_decode_attention_int8`` so the token
    write and softmax run per-shard with one flash-combine, instead of XLA
    re-sharding the cache around a dynamic_update_slice on its sharded
    sequence axis.  "seq" requires a quantized cache (the serve pool's
    only layout).
    Returns (attn_out (B, D_model), new k/v token (B, Hkv, hd)).
    """
    b, _ = x_t.shape
    h, hkv, hd = cfg.n_heads, cfg.n_kv, cfg.head_dim
    s_max = cache_k.shape[2]
    pos = jnp.asarray(pos, jnp.int32)
    per_row = pos.ndim == 1                       # slot-pooled ragged batch
    q = (x_t @ p["wq"]).reshape(b, 1, h, hd)
    k_t = (x_t @ p["wk"]).reshape(b, 1, hkv, hd)
    v_t = (x_t @ p["wv"]).reshape(b, 1, hkv, hd)
    pos_arr = jnp.broadcast_to(pos[:, None] if per_row else pos, (b, 1))
    if cfg.mrope_sections is not None:
        pos3 = jnp.broadcast_to(pos_arr[None], (3, b, 1))
        q = apply_rope(q, pos3, cfg.rope_theta, cfg.rope_fraction,
                       cfg.mrope_sections)
        k_t = apply_rope(k_t, pos3, cfg.rope_theta, cfg.rope_fraction,
                         cfg.mrope_sections)
    else:
        q = apply_rope(q, pos_arr, cfg.rope_theta, cfg.rope_fraction)
        k_t = apply_rope(k_t, pos_arr, cfg.rope_theta, cfg.rope_fraction)
    q = q[:, 0]                                            # (B, H, hd)
    k_new = k_t[:, 0]
    v_new = v_t[:, 0]

    kv_pos = jnp.arange(s_max)
    pos_col = pos[:, None] if per_row else pos    # broadcasts vs (·, S)
    lengths = bias = None
    if rolling:
        write_at = pos % s_max
        # slot j is filled iff j <= pos (pre-wrap) or always (post-wrap);
        # all filled slots are within the window by construction
        lengths = jnp.broadcast_to(jnp.minimum(pos + 1, s_max), (b,))
    else:
        write_at = pos
        if isinstance(window, int) and window <= 0:
            lengths = jnp.broadcast_to(pos + 1, (b,))      # includes current
        else:
            valid = kv_pos[None, :] <= pos_col             # includes current
            if isinstance(window, int):
                valid &= kv_pos[None, :] > pos_col - window
            else:
                valid &= jnp.where(window > 0,
                                   kv_pos[None, :] > pos_col - window, True)
            bias = jnp.where(valid, 0.0, NEG_INF).astype(jnp.float32)
            bias = jnp.broadcast_to(bias, (b, s_max))

    if quantized:
        kq_new, ks_new = kvq_ops.quantize_kv(k_new)
        vq_new, vs_new = kvq_ops.quantize_kv(v_new)
        if kv_shard == "seq" and mesh is not None and not rolling:
            from repro.distributed import collectives
            out, ck, csk, cv, csv = collectives.sp_decode_attention_int8(
                q, cache_k, cache_s_k, cache_v, cache_s_v,
                (kq_new, ks_new, vq_new, vs_new),
                jnp.broadcast_to(write_at, (b,)), mesh,
                sm_scale=hd ** -0.5, lengths=lengths, bias=bias)
        else:
            ck = _write_token(cache_k, kq_new, write_at)
            cv = _write_token(cache_v, vq_new, write_at)
            csk = _write_token(cache_s_k, ks_new, write_at)
            csv = _write_token(cache_s_v, vs_new, write_at)
            out = kvq_ops.decode_attention(q, ck, csk, cv, csv,
                                           lengths=lengths, bias=bias,
                                           backend=backend, splits=splits)
    else:
        ck = _write_token(cache_k, k_new.astype(cache_k.dtype), write_at)
        cv = _write_token(cache_v, v_new.astype(cache_v.dtype), write_at)
        csk, csv = cache_s_k, cache_s_v
        g = h // hkv
        qg = q.reshape(b, hkv, g, hd).astype(jnp.float32)
        # one arithmetic source for the decode mask (lengths iota compare /
        # bias add): shared with the kvq ref oracle so paths can't drift
        from repro.kernels.kvq.ref import masked_decode_logits
        logits = masked_decode_logits(qg, ck.astype(jnp.float32),
                                      hd ** -0.5, bias, lengths)
        pr = jax.nn.softmax(logits, -1)
        out = jnp.einsum("bhgs,bhsd->bhgd", pr, cv.astype(jnp.float32)
                         ).reshape(b, h, hd)
    out = out.reshape(b, h * hd).astype(x_t.dtype)
    return out @ p["wo"], (ck, csk, cv, csv)
