"""Mamba-2 (SSD) sequence mixer: full-sequence training path through the
chunked SSD kernel, plus O(1)-state single-token decode."""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.ssd import ops as ssd_ops
from repro.models.layers import gated_rms_norm


def _split_in_proj(cfg, proj):
    s = cfg.ssm
    di, n, h = s.d_inner, s.d_state, s.heads
    z, xc, b, c, dt = jnp.split(proj, [di, 2 * di, 2 * di + n, 2 * di + 2 * n],
                                axis=-1)
    return z, xc, b, c, dt


def _causal_conv(x, w, cache=None):
    """Depthwise causal conv1d.  x: (B, S, C); w: (K, C).

    With ``cache`` (B, K-1, C) the last K-1 inputs are prepended (decode /
    chunked prefill); returns (y, new_cache).
    """
    k = w.shape[0]
    if cache is None:
        ctx = jnp.pad(x, ((0, 0), (k - 1, 0), (0, 0)))
    else:
        ctx = jnp.concatenate([cache.astype(x.dtype), x], axis=1)
    y = sum(ctx[:, i : i + x.shape[1]] * w[i][None, None] for i in range(k))
    new_cache = ctx[:, -(k - 1):] if k > 1 else None
    return jax.nn.silu(y), new_cache


def ssm_block(p, x, cfg, *, ssd_backend: str = "ref",
              return_state: bool = False):
    """x: (B, S, D) -> (B, S, D) [, {'conv': (B,K-1,C), 'ssm': (B,H,N,P)}]."""
    s = cfg.ssm
    b, L, _ = x.shape
    proj = x @ p["in_proj"]
    z, xc, bm, cm, dt = _split_in_proj(cfg, proj)
    conv_in = jnp.concatenate([xc, bm, cm], axis=-1)
    conv_out, _ = _causal_conv(conv_in, p["conv_w"])
    conv_tail = conv_in[:, -(s.conv_kernel - 1):]           # decode cache
    xc, bm, cm = jnp.split(conv_out, [s.d_inner, s.d_inner + s.d_state], -1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None, None])
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    y = ssd_ops.ssd(
        xc.reshape(b, L, s.heads, s.head_p), dt, a, bm, cm, p["d_skip"],
        chunk=min(s.chunk, L), backend=ssd_backend,
        return_state=return_state)
    if return_state:
        y, final_state = y
    y = y.reshape(b, L, s.d_inner)
    y = gated_rms_norm(y, z, p["norm_w"], cfg.norm_eps)
    out = y @ p["out_proj"]
    if return_state:
        return out, {"conv": conv_tail, "ssm": final_state}
    return out, None


def ssm_decode_step(p, x_t, cfg, conv_cache, ssm_state):
    """x_t: (B, D).  conv_cache: (B, K-1, conv_dim); ssm_state: (B, H, N, P)."""
    s = cfg.ssm
    b = x_t.shape[0]
    proj = x_t @ p["in_proj"]
    z, xc, bm, cm, dt = _split_in_proj(cfg, proj)
    conv_in = jnp.concatenate([xc, bm, cm], axis=-1)[:, None]     # (B, 1, C)
    conv_out, new_conv = _causal_conv(conv_in, p["conv_w"], cache=conv_cache)
    xc, bm, cm = jnp.split(conv_out[:, 0], [s.d_inner, s.d_inner + s.d_state], -1)
    dt = jax.nn.softplus(dt.astype(jnp.float32) + p["dt_bias"][None])
    a = -jnp.exp(p["a_log"].astype(jnp.float32))
    new_state, y = ssd_ops.ssd_decode_step(
        ssm_state.astype(jnp.float32),
        xc.reshape(b, s.heads, s.head_p).astype(jnp.float32),
        dt, a, bm.astype(jnp.float32), cm.astype(jnp.float32), p["d_skip"])
    y = y.reshape(b, s.d_inner).astype(x_t.dtype)
    y = gated_rms_norm(y, z, p["norm_w"], cfg.norm_eps)
    return y @ p["out_proj"], new_conv.astype(conv_cache.dtype), \
        new_state.astype(ssm_state.dtype)
