"""Shared building blocks: norms, MLPs, rotary embeddings, initializers."""
from __future__ import annotations

import jax
import jax.numpy as jnp


def rms_norm(x, w, eps: float = 1e-5, *, bf16_grad: bool = False):
    """RMSNorm with f32 internals.

    ``bf16_grad`` swaps in a custom-vjp variant whose input cotangent is
    emitted in ``x.dtype`` instead of f32: under tensor parallelism the
    backward all-reduce of dx then moves half the bytes (perf iteration;
    see EXPERIMENTS.md §Perf).  Forward values are bit-identical.
    """
    if bf16_grad:
        return _rms_norm_bf16g(x, w, eps)
    return _rms_norm_fwd_value(x, w, eps)


def _rms_norm_fwd_value(x, w, eps):
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    return (x * jax.lax.rsqrt(var + eps)).astype(dtype) * w.astype(dtype)


import functools as _functools


@_functools.partial(jax.custom_vjp, nondiff_argnums=(2,))
def _rms_norm_bf16g(x, w, eps):
    return _rms_norm_fwd_value(x, w, eps)


def _rms_norm_bf16g_fwd(x, w, eps):
    return _rms_norm_fwd_value(x, w, eps), (x, w)


def _rms_norm_bf16g_bwd(eps, res, g):
    x, w = res
    xf = x.astype(jnp.float32)
    gf = g.astype(jnp.float32)
    wf = w.astype(jnp.float32)
    var = jnp.mean(jnp.square(xf), axis=-1, keepdims=True)
    inv = jax.lax.rsqrt(var + eps)
    xhat = xf * inv
    dw = jnp.sum(gf * xhat, axis=tuple(range(x.ndim - 1)))
    gw = gf * wf
    dx = inv * (gw - xhat * jnp.mean(gw * xhat, axis=-1, keepdims=True))
    # the one deliberate change: cotangent leaves in x.dtype (bf16 under
    # M-P), so TP's dx all-reduce runs at half width
    return dx.astype(x.dtype), dw.astype(w.dtype)


_rms_norm_bf16g.defvjp(_rms_norm_bf16g_fwd, _rms_norm_bf16g_bwd)


def gated_rms_norm(x, z, w, eps: float = 1e-5):
    """Mamba2 output norm: RMSNorm(x * silu(z))."""
    return rms_norm(x * jax.nn.silu(z.astype(jnp.float32)).astype(x.dtype), w, eps)


def swiglu(x, w_gate, w_up, w_down):
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


def gelu_mlp(x, w1, b1, w2, b2):
    return jax.nn.gelu(x @ w1 + b1, approximate=True) @ w2 + b2


# ---------------------------------------------------------------------------
# Rotary position embeddings (standard, fractional, and M-RoPE).
# ---------------------------------------------------------------------------
def rope_freqs(head_dim: int, theta: float, fraction: float = 1.0):
    """Inverse frequencies for the rotated sub-dimension."""
    rot = int(head_dim * fraction) // 2 * 2
    inv = 1.0 / (theta ** (jnp.arange(0, rot, 2, dtype=jnp.float32) / rot))
    return inv, rot


def apply_rope(x, positions, theta: float, fraction: float = 1.0,
               mrope_sections=None):
    """x: (..., S, H, D); positions: (..., S) int or (3, ..., S) for M-RoPE."""
    d = x.shape[-1]
    inv, rot = rope_freqs(d, theta, fraction)
    if mrope_sections is not None:
        # positions (3, B, S): temporal/height/width streams; each frequency
        # band uses the stream its section assigns (Qwen2-VL M-RoPE).
        sec = jnp.concatenate([
            jnp.full((s,), i, jnp.int32) for i, s in enumerate(mrope_sections)
        ])  # (rot/2,)
        onehot = jax.nn.one_hot(sec, 3, dtype=jnp.float32)  # (rot/2, 3)
        ang = jnp.einsum("tbs,ft->bsf", positions.astype(jnp.float32),
                         onehot) * inv[None, None, :]
    else:
        ang = positions.astype(jnp.float32)[..., None] * inv  # (..., S, rot/2)
    cos, sin = jnp.cos(ang), jnp.sin(ang)
    cos = cos[..., None, :].astype(x.dtype)  # broadcast over heads
    sin = sin[..., None, :].astype(x.dtype)
    x_rot, x_pass = x[..., :rot], x[..., rot:]
    x1, x2 = x_rot[..., : rot // 2], x_rot[..., rot // 2:]
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    if x_pass.shape[-1]:
        out = jnp.concatenate([out, x_pass], axis=-1)
    return out


# ---------------------------------------------------------------------------
# Initializers.
# ---------------------------------------------------------------------------
def dense_init(key, shape, in_axis: int = 0, dtype=jnp.float32):
    fan_in = shape[in_axis] if isinstance(in_axis, int) else 1
    scale = (1.0 / max(1, fan_in)) ** 0.5
    return jax.random.normal(key, shape, dtype) * scale


def embed_init(key, shape, dtype=jnp.float32):
    return jax.random.normal(key, shape, dtype) * 0.02
