"""Mixture-of-Experts FFN: dropless sort + ragged_dot dispatch.

Baseline sharding is TP-experts (expert hidden dim sharded over the model
axis; every device holds a slice of every expert).  ``expert_mode='ep'``
switches to expert parallelism via shard_map + all_to_all — a perf-iteration
path (see EXPERIMENTS.md §Perf).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import swiglu


def router_topk(x, w_router, k: int):
    """Returns (weights (T,k) f32, idx (T,k) i32, aux load-balance loss)."""
    logits = x.astype(jnp.float32) @ w_router.astype(jnp.float32)   # (T, E)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_i = jax.lax.top_k(probs, k)
    weights = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)
    # Switch-style load-balance aux: E * sum_e f_e * p_e
    e = w_router.shape[-1]
    f = jnp.zeros((e,), jnp.float32).at[top_i.reshape(-1)].add(1.0)
    f = f / jnp.maximum(f.sum(), 1.0)
    p_mean = probs.mean(0)
    aux = e * jnp.sum(f * p_mean)
    return weights, top_i, aux


def _moe_capacity_local(p, x, cfg, expert_offset=None):
    """Capacity-based dispatch on LOCAL tokens (runs per data shard).

    Tokens scatter into a fixed (E, C, D) buffer (C = T*k/E * capacity
    factor; overflow drops, Switch-style), experts run as one batched
    matmul, results gather back and combine with router weights.  Static
    shapes everywhere -> XLA-friendly on every backend, and the FLOP count
    is exactly E*C*D*F (the deployed TPU cost), unlike ragged_dot whose
    CPU lowering densifies to all experts.
    """
    m = cfg.moe
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    weights, top_i, aux = router_topk(xf, p["router"], m.top_k)

    tk = t * m.top_k
    cap = max(8, int(tk / m.num_experts * m.capacity_factor) // 8 * 8)
    e_local = p["w_gate"].shape[0]                            # E or E/shards
    flat_e = top_i.reshape(-1)                                # (Tk,)
    if expert_offset is not None:                             # EP: own a slice
        flat_e = flat_e - expert_offset
    in_range = (flat_e >= 0) & (flat_e < e_local)
    flat_e_c = jnp.clip(flat_e, 0, e_local - 1)
    oh = jax.nn.one_hot(flat_e_c, e_local, dtype=jnp.int32) \
        * in_range[:, None].astype(jnp.int32)
    pos = (jnp.cumsum(oh, axis=0) * oh).sum(-1) - 1           # rank in expert
    keep = (pos < cap) & in_range
    dst = jnp.where(keep, flat_e_c * cap + pos, e_local * cap)  # OOB -> drop
    xs = jnp.repeat(xf, m.top_k, axis=0)                      # (Tk, D)
    buf = jnp.zeros((e_local * cap, d), xs.dtype)
    buf = buf.at[dst].set(xs * keep[:, None].astype(xs.dtype), mode="drop")
    buf = buf.reshape(e_local, cap, d)

    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", buf, p["w_gate"])) * \
        jnp.einsum("ecd,edf->ecf", buf, p["w_up"])
    y = jnp.einsum("ecf,efd->ecd", h, p["w_down"])
    ys = y.reshape(e_local * cap, d)
    ys = jnp.take(ys, jnp.clip(dst, 0, ys.shape[0] - 1), axis=0) \
        * keep[:, None].astype(y.dtype)

    w_flat = weights.reshape(-1).astype(ys.dtype)
    tok_idx = jnp.repeat(jnp.arange(t), m.top_k)
    out = jnp.zeros((t, d), ys.dtype).at[tok_idx].add(ys * w_flat[:, None])
    if m.num_shared:
        out = out + swiglu(xf, p["shared_gate"], p["shared_up"],
                           p["shared_down"])
    return out.reshape(b, s, d).astype(x.dtype), aux


def _moe_local(p, x, cfg):
    """Dispatch + grouped matmuls on LOCAL tokens (runs per data shard).

    Dropless: replicate each token k times, sort the T*k rows by expert id,
    run grouped matmuls with lax.ragged_dot, un-sort, combine with router
    weights.  Shared experts (DeepSeek) run densely on the side.
    The expert FFN hidden shard may be a TP shard; the caller psums.
    """
    m = cfg.moe
    if m.capacity_factor > 0:
        return _moe_capacity_local(p, x, cfg)
    b, s, d = x.shape
    t = b * s
    xf = x.reshape(t, d)
    weights, top_i, aux = router_topk(xf, p["router"], m.top_k)

    flat_expert = top_i.reshape(-1)                         # (T*k,)
    flat_token = jnp.repeat(jnp.arange(t), m.top_k)
    order = jnp.argsort(flat_expert)
    tok_sorted = flat_token[order]
    exp_sorted = flat_expert[order]
    group_sizes = jnp.zeros((m.num_experts,), jnp.int32).at[exp_sorted].add(1)

    xs = xf[tok_sorted]                                     # (T*k, D)
    h = jax.nn.silu(jax.lax.ragged_dot(xs, p["w_gate"], group_sizes)) * \
        jax.lax.ragged_dot(xs, p["w_up"], group_sizes)
    ys = jax.lax.ragged_dot(h, p["w_down"], group_sizes)    # (T*k, D)

    w_sorted = weights.reshape(-1)[order].astype(ys.dtype)
    out = jnp.zeros((t, d), ys.dtype).at[tok_sorted].add(ys * w_sorted[:, None])

    if m.num_shared:
        out = out + swiglu(xf, p["shared_gate"], p["shared_up"], p["shared_down"])
    return out.reshape(b, s, d).astype(x.dtype), aux


def moe_ffn(p, x, cfg, mesh=None):
    """x: (B, S, D) -> (B, S, D), plus aux loss.

    Without a mesh: single-shard path (tests/CPU).  With a mesh: the
    token sort/gather/scatter runs INSIDE shard_map so dispatch stays local
    to each data shard (a global argsort under pjit would replicate the
    whole token stream), and the TP-expert hidden shard is psum-combined
    over the model axis.
    """
    if mesh is None or "model" not in mesh.axis_names:
        return _moe_local(p, x, cfg)

    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as P

    dp = ("pod", "data") if "pod" in mesh.axis_names else ("data",)
    n_dp = 1
    for a in dp:
        n_dp *= mesh.shape[a]
    b = x.shape[0]
    b_ax = dp if b % n_dp == 0 else None

    ep = cfg.moe.expert_mode == "ep" and cfg.moe.capacity_factor > 0

    def local(x_l, p_l):
        if ep:
            e_local = p_l["w_gate"].shape[0]
            off = jax.lax.axis_index("model") * e_local
            out, aux = _moe_capacity_local(p_l, x_l, cfg, expert_offset=off)
        else:
            out, aux = _moe_local(p_l, x_l, cfg)
        out = jax.lax.psum(out, "model")
        aux = jax.lax.pmean(aux, dp) if b_ax is not None else aux
        return out, aux

    def w_spec(path_leaf_name, leaf):
        nd = leaf.ndim
        name = path_leaf_name
        if name in ("w_gate", "w_up") and nd == 3:
            return P("model", None, None) if ep else P(None, None, "model")
        if name == "w_down" and nd == 3:
            return P("model", None, None) if ep else P(None, "model", None)
        if name in ("shared_gate", "shared_up"):
            return P(None, "model")
        if name == "shared_down":
            return P("model", None)
        return P()

    p_specs = {k: w_spec(k, v) for k, v in p.items()}
    return shard_map(
        local, mesh=mesh,
        in_specs=(P(b_ax, None, None), p_specs),
        out_specs=(P(b_ax, None, None), P()),
        check_rep=False,
    )(x, p)
