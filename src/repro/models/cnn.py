"""ResNet family (paper's own experiment models, Figs. 8-10).

Built as an explicit *layer list* so OpTorch's ``checkpoint_sequential``
applies exactly as in the paper: segments of the sequential stack are
rematted, only segment inputs are stored.  GroupNorm replaces BatchNorm
(stateless — no running stats to thread through pjit; accuracy-neutral at
paper scale, noted in DESIGN.md).

The first layer is the E-D *decode layer* when the input is a packed
uint32 batch (paper II.A.2: "a custom deep learning layer to decode").
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Callable, Sequence

import jax
import jax.numpy as jnp

from repro.kernels.pack import ops as pack_ops
from repro.models.layers import dense_init


@dataclasses.dataclass(frozen=True)
class ResNetConfig:
    arch_id: str = "resnet18"
    stage_sizes: Sequence[int] = (2, 2, 2, 2)
    widths: Sequence[int] = (64, 128, 256, 512)
    bottleneck: bool = False
    num_classes: int = 10
    groups: int = 8
    stem_stride: int = 1          # 1 for CIFAR, 2 for 512x512 memory runs


def resnet18(num_classes=10, **kw) -> ResNetConfig:
    return ResNetConfig("resnet18", (2, 2, 2, 2), (64, 128, 256, 512),
                        False, num_classes, **kw)


def resnet50(num_classes=10, **kw) -> ResNetConfig:
    return ResNetConfig("resnet50", (3, 4, 6, 3), (64, 128, 256, 512),
                        True, num_classes, **kw)


def _conv(x, w, stride=1):
    return jax.lax.conv_general_dilated(
        x.astype(w.dtype), w, (stride, stride), "SAME",
        dimension_numbers=("NHWC", "HWIO", "NHWC"))


def _group_norm(x, scale, bias, groups):
    n, h, w, c = x.shape
    g = min(groups, c)
    xg = x.reshape(n, h, w, g, c // g).astype(jnp.float32)
    mean = xg.mean((1, 2, 4), keepdims=True)
    var = xg.var((1, 2, 4), keepdims=True)
    xg = (xg - mean) * jax.lax.rsqrt(var + 1e-5)
    return (xg.reshape(n, h, w, c) * scale + bias).astype(x.dtype)


def _conv_init(key, kh, kw, cin, cout):
    return dense_init(key, (kh, kw, cin, cout), in_axis=0) / (kh * kw) ** 0.5


def init_params(cfg: ResNetConfig, key) -> dict:
    keys = iter(jax.random.split(key, 256))
    p: dict = {"stem": {"w": _conv_init(next(keys), 3, 3, 3, cfg.widths[0]),
                        "s": jnp.ones((cfg.widths[0],)),
                        "b": jnp.zeros((cfg.widths[0],))}}
    cin = cfg.widths[0]
    blocks = []
    for stage, (n_blocks, width) in enumerate(zip(cfg.stage_sizes, cfg.widths)):
        for b in range(n_blocks):
            stride = 2 if (b == 0 and stage > 0) else 1
            cout = width * (4 if cfg.bottleneck else 1)
            bp = {}
            if cfg.bottleneck:
                bp["w1"] = _conv_init(next(keys), 1, 1, cin, width)
                bp["w2"] = _conv_init(next(keys), 3, 3, width, width)
                bp["w3"] = _conv_init(next(keys), 1, 1, width, cout)
                dims = (width, width, cout)
            else:
                bp["w1"] = _conv_init(next(keys), 3, 3, cin, width)
                bp["w2"] = _conv_init(next(keys), 3, 3, width, cout)
                dims = (width, cout)
            for i, dci in enumerate(dims):
                bp[f"s{i+1}"] = jnp.ones((dci,))
                bp[f"b{i+1}"] = jnp.zeros((dci,))
            if stride != 1 or cin != cout:
                bp["proj"] = _conv_init(next(keys), 1, 1, cin, cout)
            blocks.append(bp)
            cin = cout
    p["blocks"] = blocks
    p["head"] = {"w": dense_init(next(keys), (cin, cfg.num_classes)),
                 "b": jnp.zeros((cfg.num_classes,))}
    return p


def num_layer_fns(cfg: ResNetConfig) -> int:
    """Chain length ``layer_fns`` produces (stem + blocks + head) — the
    ``n_layers`` a RematPlan for this model must be solved for."""
    return 2 + sum(cfg.stage_sizes)


def block_strides(cfg: ResNetConfig) -> list[int]:
    strides = []
    for stage, n_blocks in enumerate(cfg.stage_sizes):
        for b in range(n_blocks):
            strides.append(2 if (b == 0 and stage > 0) else 1)
    return strides


def _block_fn(bp, cfg: ResNetConfig, stride: int):
    def fn(x):
        g = cfg.groups
        if cfg.bottleneck:
            h = jax.nn.relu(_group_norm(_conv(x, bp["w1"]), bp["s1"], bp["b1"], g))
            h = jax.nn.relu(_group_norm(_conv(h, bp["w2"], stride), bp["s2"], bp["b2"], g))
            h = _group_norm(_conv(h, bp["w3"]), bp["s3"], bp["b3"], g)
        else:
            h = jax.nn.relu(_group_norm(_conv(x, bp["w1"], stride), bp["s1"], bp["b1"], g))
            h = _group_norm(_conv(h, bp["w2"]), bp["s2"], bp["b2"], g)
        sc = _conv(x, bp["proj"], stride) if "proj" in bp else x
        return jax.nn.relu(h + sc)

    return fn


def layer_fns(params: dict, cfg: ResNetConfig) -> list[Callable]:
    """The sequential layer list ``checkpoint_sequential`` consumes."""
    fns: list[Callable] = [
        lambda x: jax.nn.relu(_group_norm(
            _conv(x, params["stem"]["w"], cfg.stem_stride),
            params["stem"]["s"], params["stem"]["b"], cfg.groups))
    ]
    fns += [_block_fn(bp, cfg, st)
           for bp, st in zip(params["blocks"], block_strides(cfg))]

    def head(x):
        x = x.mean((1, 2))
        return x @ params["head"]["w"] + params["head"]["b"]

    fns.append(head)
    return fns


def forward(params, cfg: ResNetConfig, images, *, remat=None,
            decode_backend: str | None = None):
    """images: f32 (B,H,W,C) or packed u32 (B/4,H,W,C) when decode_backend set.

    ``remat`` is the plan-bearing ``repro.core.checkpoint.CheckpointConfig``
    (None or ``enabled=False`` -> standard pipeline).  With ``remat.plan``
    set, S-C segments follow the planner's (possibly non-uniform)
    boundaries; otherwise layers are grouped uniformly, ``segment_size``
    layers per segment.  The old raw ``num_segments`` knob is gone — build
    an even plan with ``RematPlan.uniform(n_layers, k)`` if you need one.
    """
    x = images
    if decode_backend is not None:
        x = pack_ops.decode(x, backend=decode_backend)  # the E-D decode layer
    fns = layer_fns(params, cfg)
    if remat is not None and remat.enabled:
        from repro.core.checkpoint import checkpoint_sequential
        if remat.plan is not None:  # the plan carries its own policy
            return checkpoint_sequential(fns, plan=remat.plan,
                                         save_names=remat.save_names)(x)
        n_seg = -(-len(fns) // max(1, remat.segment_size))
        if n_seg > 1:
            return checkpoint_sequential(fns, n_seg, policy=remat.policy,
                                         save_names=remat.save_names)(x)
    for f in fns:
        x = f(x)
    return x


def loss_fn(params, cfg: ResNetConfig, images, labels, *, remat=None,
            decode_backend=None):
    logits = forward(params, cfg, images, remat=remat,
                     decode_backend=decode_backend)
    logp = jax.nn.log_softmax(logits.astype(jnp.float32))
    nll = -jnp.take_along_axis(logp, labels[:, None], axis=-1).mean()
    acc = (logits.argmax(-1) == labels).mean()
    return nll, {"acc": acc}
