"""The unified model: one scan-based block stack covering every assigned
family (dense/GQA, MLA, MoE, SSM, hybrid, encoder-decoder, VLM).

Params are pure pytrees; per-layer params are *stacked* along a leading
layer axis and executed with ``repro.core.checkpoint.remat_scan`` so depth
never inflates the HLO and OpTorch's S-C applies per segment.

Public entry points:
  init_params(cfg, key)                -> params
  forward(params, cfg, batch, ...)     -> logits (B, S, V)
  loss_fn(params, cfg, batch, ...)     -> (scalar, aux)
  init_cache(cfg, batch, s_max, ...)   -> decode cache pytree
  decode_step(params, cfg, cache, ...) -> (logits (B, V), cache)
"""
from __future__ import annotations

import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp

from jax.ad_checkpoint import checkpoint_name as _checkpoint_name

from repro.core.checkpoint import CheckpointConfig, remat_scan
from repro.core.mixed_precision import Policy
from repro.kernels.kvq import ops as kvq_ops
from repro.models import attention as attn
from repro.models import moe as moe_mod
from repro.models import ssm as ssm_mod
from repro.models.config import ModelConfig
from repro.models.layers import (dense_init, embed_init, gelu_mlp, rms_norm,
                                 swiglu)

# ---------------------------------------------------------------------------
# Initialization.
# ---------------------------------------------------------------------------
def _init_attn(cfg: ModelConfig, key) -> dict:
    d, h, hkv, hd = cfg.d_model, cfg.n_heads, cfg.n_kv, cfg.head_dim
    ks = jax.random.split(key, 8)
    if cfg.mla is not None:
        m = cfg.mla
        return {
            "q_a": dense_init(ks[0], (d, m.q_lora_rank)),
            "q_a_norm": jnp.ones((m.q_lora_rank,)),
            "q_b": dense_init(ks[1], (m.q_lora_rank,
                                      h * (m.qk_nope_dim + m.qk_rope_dim))),
            "kv_a": dense_init(ks[2], (d, m.kv_lora_rank + m.qk_rope_dim)),
            "kv_a_norm": jnp.ones((m.kv_lora_rank,)),
            "kv_b": dense_init(ks[3], (m.kv_lora_rank,
                                       h * (m.qk_nope_dim + m.v_head_dim))),
            "wo": dense_init(ks[4], (h * m.v_head_dim, d)),
        }
    return {
        "wq": dense_init(ks[0], (d, h * hd)),
        "wk": dense_init(ks[1], (d, hkv * hd)),
        "wv": dense_init(ks[2], (d, hkv * hd)),
        "wo": dense_init(ks[3], (h * hd, d)),
    }


def _init_ssm(cfg: ModelConfig, key) -> dict:
    s = cfg.ssm
    d = cfg.d_model
    conv_dim = s.d_inner + 2 * s.d_state
    ks = jax.random.split(key, 4)
    return {
        "in_proj": dense_init(ks[0], (d, 2 * s.d_inner + 2 * s.d_state + s.heads)),
        "conv_w": dense_init(ks[1], (s.conv_kernel, conv_dim), in_axis=0),
        "dt_bias": jnp.zeros((s.heads,)),
        "a_log": jnp.zeros((s.heads,)),         # A = -exp(0) = -1
        "d_skip": jnp.ones((s.heads,)),
        "norm_w": jnp.ones((s.d_inner,)),
        "out_proj": dense_init(ks[2], (s.d_inner, d)),
    }


def _init_ffn(cfg: ModelConfig, key) -> dict:
    d = cfg.d_model
    ks = jax.random.split(key, 8)
    if cfg.moe is not None:
        m = cfg.moe
        p = {
            "router": dense_init(ks[0], (d, m.num_experts)),
            "w_gate": dense_init(ks[1], (m.num_experts, d, m.d_expert), in_axis=1),
            "w_up": dense_init(ks[2], (m.num_experts, d, m.d_expert), in_axis=1),
            "w_down": dense_init(ks[3], (m.num_experts, m.d_expert, d), in_axis=1),
        }
        if m.num_shared:
            p.update(
                shared_gate=dense_init(ks[4], (d, m.d_shared)),
                shared_up=dense_init(ks[5], (d, m.d_shared)),
                shared_down=dense_init(ks[6], (m.d_shared, d)),
            )
        return p
    if cfg.mlp_kind == "gelu":
        return {
            "w1": dense_init(ks[0], (d, cfg.d_ff)), "b1": jnp.zeros((cfg.d_ff,)),
            "w2": dense_init(ks[1], (cfg.d_ff, d)), "b2": jnp.zeros((d,)),
        }
    return {
        "w_gate": dense_init(ks[0], (d, cfg.d_ff)),
        "w_up": dense_init(ks[1], (d, cfg.d_ff)),
        "w_down": dense_init(ks[2], (cfg.d_ff, d)),
    }


def _init_block(cfg: ModelConfig, key) -> dict:
    ks = jax.random.split(key, 4)
    p: dict[str, Any] = {"ln1": jnp.ones((cfg.d_model,)),
                         "ln2": jnp.ones((cfg.d_model,))}
    if cfg.mixer in ("attn", "hybrid"):
        p["attn"] = _init_attn(cfg, ks[0])
    if cfg.mixer in ("ssm", "hybrid"):
        p["ssm"] = _init_ssm(cfg, ks[1])
    if cfg.mixer == "hybrid":
        p["mix_norm_attn"] = jnp.ones((cfg.d_model,))
        p["mix_norm_ssm"] = jnp.ones((cfg.d_model,))
    if cfg.moe is not None or cfg.d_ff:
        p["ffn"] = _init_ffn(cfg, ks[2])
    if cfg.encoder is not None:  # decoder cross-attention
        p["xattn"] = _init_attn(dataclass_no_mla(cfg), ks[3])
        p["ln_x"] = jnp.ones((cfg.d_model,))
    return p


def dataclass_no_mla(cfg):
    import dataclasses
    return dataclasses.replace(cfg, mla=None) if cfg.mla is not None else cfg


def _kv_entry(k, v, cfg, mesh, *, quantized: bool = True):
    """Per-layer prefill cache entry: quantize + reshard INSIDE the scan.

    Quantizing per layer (int8 + scales) before the layer stack is stacked
    quarters the bytes that must move when XLA reshards the (head-sharded)
    attention K/V into the (sequence-sharded) cache layout; the sharding
    constraint makes that reshard happen on the small per-layer slice
    instead of the full (L, ...) stack (perf iteration, EXPERIMENTS §Perf).
    k, v: (B, S, Hkv, hd) -> int8 entries in cache axis order (B, Hkv, S, hd).
    """
    k = jnp.moveaxis(k, 2, 1)                        # (B, Hkv, S, hd)
    v = jnp.moveaxis(v, 2, 1)
    if quantized:
        kq, ks = kvq_ops.quantize_kv(k)
        vq, vs = kvq_ops.quantize_kv(v)
    else:
        kq, vq = k.astype(jnp.bfloat16), v.astype(jnp.bfloat16)
        ks = jnp.zeros(k.shape[:-1], jnp.float32)
        vs = jnp.zeros(v.shape[:-1], jnp.float32)
    if mesh is not None and "model" in mesh.axis_names:
        from jax.sharding import NamedSharding, PartitionSpec as P
        from repro.distributed import sharding as shd
        dp = shd.dp_axes(mesh)
        b = k.shape[0]
        b_ax = dp if b % shd.dp_size(mesh) == 0 else None
        if cfg.n_kv % mesh.shape["model"] == 0:
            kv_spec = P(b_ax, "model", None, None)
            sc_spec = P(b_ax, "model", None)
        else:
            seq_ax = "model" if b_ax is not None else ("data", "model")
            kv_spec = P(b_ax, None, seq_ax, None)
            sc_spec = P(b_ax, None, seq_ax)
        cons = lambda x, s: jax.lax.with_sharding_constraint(
            x, NamedSharding(mesh, s))
        kq, vq = cons(kq, kv_spec), cons(vq, kv_spec)
        ks, vs = cons(ks, sc_spec), cons(vs, sc_spec)
    return {"k": kq, "k_scale": ks, "v": vq, "v_scale": vs}


def _init_enc_block(cfg: ModelConfig, key) -> dict:
    ks = jax.random.split(key, 2)
    return {
        "ln1": jnp.ones((cfg.d_model,)), "ln2": jnp.ones((cfg.d_model,)),
        "attn": _init_attn(dataclass_no_mla(cfg), ks[0]),
        "ffn": _init_ffn(dataclass_no_moe(cfg), ks[1]),
    }


def dataclass_no_moe(cfg):
    import dataclasses
    return dataclasses.replace(cfg, moe=None) if cfg.moe is not None else cfg


def init_params(cfg: ModelConfig, key) -> dict:
    k_embed, k_blocks, k_head, k_enc = jax.random.split(key, 4)
    blocks = jax.vmap(lambda k: _init_block(cfg, k))(
        jax.random.split(k_blocks, cfg.n_layers))
    params = {
        "embed": embed_init(k_embed, (cfg.padded_vocab, cfg.d_model)),
        "blocks": blocks,
        "final_norm": jnp.ones((cfg.d_model,)),
    }
    if not cfg.tie_embeddings:
        params["lm_head"] = dense_init(k_head, (cfg.d_model, cfg.padded_vocab))
    if cfg.encoder is not None:
        params["enc_blocks"] = jax.vmap(lambda k: _init_enc_block(cfg, k))(
            jax.random.split(k_enc, cfg.encoder.n_layers))
        params["enc_norm"] = jnp.ones((cfg.d_model,))
    if cfg.family == "vlm":
        params["patch_proj"] = dense_init(k_enc, (cfg.d_model, cfg.d_model))
    return params


def _mask_padded_vocab(logits, cfg: ModelConfig):
    """-inf the dead padded-vocab tail (shards cleanly: iota compare)."""
    if cfg.padded_vocab == cfg.vocab:
        return logits
    vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                          logits.ndim - 1)
    return jnp.where(vocab_iota < cfg.vocab, logits,
                     jnp.asarray(-1e30, logits.dtype))


# ---------------------------------------------------------------------------
# Per-layer window schedule (hybrid / windowed archs).
# ---------------------------------------------------------------------------
def layer_windows(cfg: ModelConfig) -> jnp.ndarray:
    """(L,) int32: 0 = full causal, else sliding-window size for that layer."""
    w = jnp.full((cfg.n_layers,), cfg.window, jnp.int32)
    if cfg.global_layers:
        w = w.at[jnp.array(cfg.global_layers)].set(0)
    return w


# ---------------------------------------------------------------------------
# Forward.
# ---------------------------------------------------------------------------
def _ffn_apply(p, x, cfg, mesh=None):
    if cfg.moe is not None:
        return moe_mod.moe_ffn(p, x, cfg, mesh=mesh)
    if cfg.mlp_kind == "gelu":
        return gelu_mlp(x, p["w1"], p["b1"], p["w2"], p["b2"]), 0.0
    return swiglu(x, p["w_gate"], p["w_up"], p["w_down"]), 0.0


def _block_apply(p, x, cfg, *, positions, window, ssd_backend="ref",
                 enc_kv=None, collect_cache: bool = False, mesh=None,
                 cache_quantized: bool = True, flash_resid_dtype=None):
    cache_entry = {}
    h = rms_norm(x, p["ln1"], cfg.norm_eps, bf16_grad=cfg.norm_bf16_grad)
    if cfg.mixer == "attn":
        if cfg.mla is not None:
            mix, (lat, kr) = attn.mla_block(p["attn"], h, cfg,
                                            positions=positions)
            if collect_cache:
                cache_entry = {"mla_lat": lat, "mla_rope": kr[:, :, 0]}
        else:
            mix, (k, v) = attn.attn_block(p["attn"], h, cfg,
                                          positions=positions,
                                          layer_window=window, mesh=mesh,
                                          flash_resid_dtype=flash_resid_dtype)
            if collect_cache:
                cache_entry = _kv_entry(k, v, cfg, mesh,
                                        quantized=cache_quantized)
    elif cfg.mixer == "ssm":
        mix, st = ssm_mod.ssm_block(p["ssm"], h, cfg, ssd_backend=ssd_backend,
                                    return_state=collect_cache)
        if collect_cache:
            cache_entry = st
    else:  # hybrid: parallel attention + SSM heads, norm-and-average fusion
        a_out, (k, v) = attn.attn_block(p["attn"], h, cfg, positions=positions,
                                        layer_window=window, mesh=mesh,
                                        flash_resid_dtype=flash_resid_dtype)
        s_out, st = ssm_mod.ssm_block(p["ssm"], h, cfg, ssd_backend=ssd_backend,
                                      return_state=collect_cache)
        if collect_cache:
            cache_entry = {**_kv_entry(k, v, cfg, mesh,
                                       quantized=cache_quantized), **st}
        mix = 0.5 * (rms_norm(a_out, p["mix_norm_attn"], cfg.norm_eps, bf16_grad=cfg.norm_bf16_grad)
                     + rms_norm(s_out, p["mix_norm_ssm"], cfg.norm_eps, bf16_grad=cfg.norm_bf16_grad))
    x = x + _checkpoint_name(mix, "attn_out")
    if enc_kv is not None:
        hx = rms_norm(x, p["ln_x"], cfg.norm_eps, bf16_grad=cfg.norm_bf16_grad)
        x = x + attn.cross_attn_block(p["xattn"], hx, enc_kv, cfg)
    if "ffn" not in p:                       # pure-SSM blocks have no MLP
        return x, 0.0, cache_entry
    h2 = rms_norm(x, p["ln2"], cfg.norm_eps, bf16_grad=cfg.norm_bf16_grad)
    ffn_out, aux = _ffn_apply(p["ffn"], h2, cfg, mesh=mesh)
    return x + _checkpoint_name(ffn_out, "ffn_out"), aux, \
        cache_entry


def _run_encoder(params, cfg, frames, policy: Policy):
    """Whisper-style encoder over precomputed (stub) frame embeddings."""
    x = frames.astype(policy.compute_dtype)
    b, se, _ = x.shape
    pos = jnp.broadcast_to(jnp.arange(se)[None], (b, se))

    # python loop (encoder stacks are shallow): every layer appears in the
    # HLO, so dry-run cost analysis counts the encoder exactly.
    n_enc = jax.tree_util.tree_leaves(params["enc_blocks"])[0].shape[0]
    for i in range(n_enc):
        p_layer = jax.tree_util.tree_map(lambda a: a[i], params["enc_blocks"])
        h = rms_norm(x, p_layer["ln1"], cfg.norm_eps, bf16_grad=cfg.norm_bf16_grad)
        a_out, _ = attn.attn_block(p_layer["attn"], h, cfg, positions=pos,
                                   causal=False)  # bidirectional encoder
        x = x + a_out
        h2 = rms_norm(x, p_layer["ln2"], cfg.norm_eps, bf16_grad=cfg.norm_bf16_grad)
        f, _ = _ffn_apply(p_layer["ffn"], h2, dataclass_no_moe(cfg))
        x = x + f

    return rms_norm(x, params["enc_norm"], cfg.norm_eps, bf16_grad=cfg.norm_bf16_grad)


def forward(params, cfg: ModelConfig, batch: dict, *,
            policy: Policy = Policy.full(),
            remat: CheckpointConfig = CheckpointConfig(),
            ssd_backend: str = "ref", build_cache: bool = False,
            cache_quantized: bool = True, scan_unroll: int = 1, mesh=None,
            return_hidden: bool = False):
    """batch: {tokens (B,S)[, positions, frames (B,Se,D), patches (B,Sp,D)]}.

    Returns (logits (B, S, V) in policy.output_dtype, aux dict).  With
    ``build_cache`` (serving prefill) aux carries a decode cache positioned
    at S, in the ``init_cache`` layout (int8-quantized when requested).
    ``return_hidden`` skips the LM head (chunked-CE path in loss_fn).

    ``remat`` is the single S-C entry point: a plan-bearing
    ``CheckpointConfig`` (``remat.plan`` from ``repro.plan``) applies
    profile-solved, possibly non-uniform segment boundaries to the block
    scan; ``segment_size`` is the uniform fallback.  The plan is validated
    against ``cfg.n_layers`` inside ``remat_scan``.
    """
    tokens = batch["tokens"]
    b, s = tokens.shape
    params = policy.cast_to_compute(params)

    x = params["embed"][tokens]                             # (B, S, D)
    if cfg.family == "vlm" and "patches" in batch:
        # stub frontend: precomputed patch embeddings occupy the prefix
        patches = batch["patches"].astype(x.dtype) @ params["patch_proj"]
        sp = patches.shape[1]
        x = jnp.concatenate([patches, x[:, sp:]], axis=1)

    if "positions" in batch:
        positions = batch["positions"]
    else:
        positions = jnp.broadcast_to(jnp.arange(s)[None], (b, s))
        if cfg.mrope_sections is not None:
            positions = jnp.broadcast_to(positions[None], (3, b, s))

    enc_kv = None
    if cfg.encoder is not None:
        enc_out = _run_encoder(params, cfg, batch["frames"], policy)
        # precompute cross K/V once (shared by all decoder layers' xattn via
        # per-layer projections — so pass encoder output and project inside).
        enc_kv = enc_out

    # Uniform window schedules (no per-layer overrides) pass the window as
    # a STATIC python int so attn_block can dispatch to the flash kernel
    # (its gate requires a non-traced window); hybrid archs with
    # global-layer overrides scan the (L,) window array and take the jnp
    # attention path — the documented fallback.
    static_window = int(cfg.window) if not cfg.global_layers else None
    windows = None if static_window is not None else layer_windows(cfg)

    def body(carry, xs):
        if static_window is None:
            p_layer, win = xs
        else:
            p_layer, win = xs, static_window
        ekv = None
        if enc_kv is not None:
            hkv, hd = cfg.n_kv, cfg.head_dim
            bb, se, _ = enc_kv.shape
            k = (enc_kv @ p_layer["xattn"]["wk"]).reshape(bb, se, hkv, hd)
            v = (enc_kv @ p_layer["xattn"]["wv"]).reshape(bb, se, hkv, hd)
            ekv = (k, v)
        out, aux, entry = _block_apply(
            p_layer, carry, cfg, positions=positions, window=win,
            ssd_backend=ssd_backend, enc_kv=ekv, collect_cache=build_cache,
            mesh=mesh, cache_quantized=cache_quantized,
            flash_resid_dtype=policy.flash_resid_dtype)
        return out, (aux, entry)

    x, (auxes, entries) = remat_scan(
        body, x,
        params["blocks"] if static_window is not None
        else (params["blocks"], windows),
        config=remat, unroll=scan_unroll)
    x = rms_norm(x, params["final_norm"], cfg.norm_eps, bf16_grad=cfg.norm_bf16_grad)
    aux_out = {"moe_aux": jnp.mean(auxes) if cfg.moe is not None else 0.0}
    if build_cache:
        aux_out["cache"] = _assemble_cache(cfg, entries, s,
                                           quantized=cache_quantized)
    if return_hidden:
        return x, aux_out
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = (x @ head).astype(policy.output_dtype)
    logits = _mask_padded_vocab(logits, cfg)
    return logits, aux_out


def _assemble_cache(cfg: ModelConfig, entries: dict, s: int, *,
                    quantized: bool) -> dict:
    """Stacked per-layer prefill outputs -> init_cache layout, pos = S."""
    cache: dict[str, Any] = {"pos": jnp.int32(s)}
    if "k" in entries:
        # entries are per-layer quantized + laid out by _kv_entry already:
        # stacked to (L, B, Hkv, S, hd) by the scan
        cache.update(k=entries["k"], k_scale=entries["k_scale"],
                     v=entries["v"], v_scale=entries["v_scale"])
    if "mla_lat" in entries:
        cache.update(mla_lat=entries["mla_lat"].astype(jnp.bfloat16),
                     mla_rope=entries["mla_rope"].astype(jnp.bfloat16))
    if "ssm" in entries:
        cache.update(ssm=entries["ssm"].astype(jnp.float32),
                     conv=entries["conv"].astype(jnp.bfloat16))
    return cache


def loss_fn(params, cfg: ModelConfig, batch: dict, *,
            policy: Policy = Policy.full(),
            remat: CheckpointConfig = CheckpointConfig(),
            ssd_backend: str = "ref", moe_aux_weight: float = 0.01,
            scan_unroll: int = 1, mesh=None, ce_chunk: int = 0):
    labels = batch["labels"]
    mask = batch.get("loss_mask", jnp.ones_like(labels, jnp.float32))
    if ce_chunk > 0:
        # Chunked CE (perf iteration): the LM head + softmax runs per
        # sequence chunk under remat, so the (B, S, V) logits never
        # materialize — peak is (B, chunk, V) + recompute in bwd.
        hidden, aux = forward(params, cfg, batch, policy=policy, remat=remat,
                              ssd_backend=ssd_backend,
                              scan_unroll=scan_unroll, mesh=mesh,
                              return_hidden=True)
        head = (params["embed"].T if cfg.tie_embeddings
                else params["lm_head"]).astype(policy.compute_dtype)

        @jax.checkpoint
        def chunk_nll(x_c, lab_c, mask_c):
            logits = _mask_padded_vocab(
                (x_c @ head).astype(jnp.float32), cfg)
            m = jax.lax.stop_gradient(logits.max(-1, keepdims=True))
            shifted = logits - m
            lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1))
            vi = jax.lax.broadcasted_iota(jnp.int32, logits.shape, 2)
            ll = jnp.sum(jnp.where(vi == lab_c[..., None], shifted, 0.0), -1)
            return ((lse - ll) * mask_c).sum()

        s = hidden.shape[1]
        n_chunks = -(-s // ce_chunk)
        total = jnp.float32(0)
        for c in range(n_chunks):
            sl = slice(c * ce_chunk, (c + 1) * ce_chunk)
            total += chunk_nll(hidden[:, sl], labels[:, sl], mask[:, sl])
        loss = total / jnp.maximum(mask.sum(), 1.0)
    else:
        logits, aux = forward(params, cfg, batch, policy=policy, remat=remat,
                              ssd_backend=ssd_backend,
                              scan_unroll=scan_unroll, mesh=mesh)
        # Sharding-friendly CE: never gathers the (model-sharded) vocab dim.
        # label logit via a masked sum (iota compare shards cleanly; a
        # take_along_axis gather would force an all-gather of the logits).
        logits32 = logits.astype(jnp.float32)
        m = jax.lax.stop_gradient(logits32.max(-1, keepdims=True))
        shifted = logits32 - m
        lse = jnp.log(jnp.sum(jnp.exp(shifted), axis=-1))
        vocab_iota = jax.lax.broadcasted_iota(jnp.int32, logits.shape,
                                              logits.ndim - 1)
        label_logit = jnp.sum(
            jnp.where(vocab_iota == labels[..., None], shifted, 0.0), axis=-1)
        nll = lse - label_logit
        loss = (nll * mask).sum() / jnp.maximum(mask.sum(), 1.0)
    if cfg.moe is not None:
        loss = loss + moe_aux_weight * aux["moe_aux"]
    return loss, {"nll": loss, **aux}


# ---------------------------------------------------------------------------
# Two-tier cache (windowed archs): global layers keep the full context,
# window layers keep a rolling buffer of `window` slots.  For hymba @ 500k
# this shrinks the attention cache 29/32 layers x 512 = ~10x (EXPERIMENTS
# §Perf, cell C).
# ---------------------------------------------------------------------------
def layer_runs(cfg: ModelConfig):
    """Contiguous layer runs [(lo, hi, is_global)] preserving order."""
    glob = set(cfg.global_layers)
    runs: list[tuple[int, int, bool]] = []
    for i in range(cfg.n_layers):
        is_g = i in glob
        if runs and runs[-1][2] == is_g:
            runs[-1] = (runs[-1][0], i + 1, is_g)
        else:
            runs.append((i, i + 1, is_g))
    return runs


def init_cache_two_tier(cfg: ModelConfig, batch: int, s_max: int, *,
                        quantized: bool = True, dtype=jnp.bfloat16) -> dict:
    assert cfg.window > 0 and cfg.global_layers and cfg.mixer in (
        "attn", "hybrid"), "two-tier cache needs a windowed attention arch"
    L = cfg.n_layers
    n_g = len([g for g in cfg.global_layers if g < L])
    n_w = L - n_g
    hkv, hd = cfg.n_kv, cfg.head_dim
    kv_dtype = jnp.int8 if quantized else dtype
    w = min(cfg.window, s_max)
    cache: dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}
    for tier, n_t, s_t in (("g", n_g, s_max), ("w", n_w, w)):
        cache[f"{tier}k"] = jnp.zeros((n_t, batch, hkv, s_t, hd), kv_dtype)
        cache[f"{tier}v"] = jnp.zeros((n_t, batch, hkv, s_t, hd), kv_dtype)
        cache[f"{tier}k_scale"] = jnp.zeros((n_t, batch, hkv, s_t), jnp.float32)
        cache[f"{tier}v_scale"] = jnp.zeros((n_t, batch, hkv, s_t), jnp.float32)
    if cfg.mixer == "hybrid":
        s = cfg.ssm
        conv_dim = s.d_inner + 2 * s.d_state
        cache["conv"] = jnp.zeros((L, batch, s.conv_kernel - 1, conv_dim), dtype)
        cache["ssm"] = jnp.zeros((L, batch, s.heads, s.d_state, s.head_p),
                                 jnp.float32)
    return cache


def decode_step_two_tier(params, cfg: ModelConfig, cache: dict, tokens_t, *,
                         policy: Policy = Policy.full(), quantized: bool = True,
                         kvq_backend: str = "ref", kvq_splits: int = 1,
                         mesh=None):
    """Single-token decode over a two-tier cache (see init_cache_two_tier).

    Every layer takes the lengths-aware decode path: window layers roll a
    W-slot buffer (their split-K axis statically shrinks to ~W/BS tiles),
    global layers pass ``lengths = pos + 1`` — no bias tensors anywhere.
    """
    params = policy.cast_to_compute(params)
    pos = cache["pos"]
    x = params["embed"][tokens_t]

    def make_body(rolling: bool):
        def body(carry, xs):
            p_layer, lc = xs["p"], xs["c"]
            x = carry
            h = rms_norm(x[:, None], p_layer["ln1"], cfg.norm_eps,
                         bf16_grad=cfg.norm_bf16_grad)[:, 0]
            new_lc = dict(lc)
            mix, (ck, csk, cv, csv) = attn.attn_decode(
                p_layer["attn"], h, cfg, lc["k"], lc["k_scale"], lc["v"],
                lc["v_scale"], pos, window=0, quantized=quantized,
                backend=kvq_backend, splits=kvq_splits, rolling=rolling)
            new_lc.update(k=ck, k_scale=csk, v=cv, v_scale=csv)
            if cfg.mixer == "hybrid":
                s_mix, nconv, nssm = ssm_mod.ssm_decode_step(
                    p_layer["ssm"], h, cfg, lc["conv"], lc["ssm"])
                new_lc.update(conv=nconv, ssm=nssm)
                mix = 0.5 * (
                    rms_norm(mix[:, None], p_layer["mix_norm_attn"],
                             cfg.norm_eps)[:, 0]
                    + rms_norm(s_mix[:, None], p_layer["mix_norm_ssm"],
                               cfg.norm_eps)[:, 0])
            x = x + mix
            if "ffn" in p_layer:
                h2 = rms_norm(x[:, None], p_layer["ln2"], cfg.norm_eps,
                              bf16_grad=cfg.norm_bf16_grad)
                ffn_out, _ = _ffn_apply(p_layer["ffn"], h2, cfg, mesh=mesh)
                x = x + ffn_out[:, 0]
            return x, new_lc
        return body

    new_cache = dict(cache)
    g_off = w_off = 0
    sl = jax.tree_util.tree_map
    for lo, hi, is_global in layer_runs(cfg):
        n = hi - lo
        tier = "g" if is_global else "w"
        off = g_off if is_global else w_off
        p_run = sl(lambda a: a[lo:hi], params["blocks"])
        lc_run = {"k": cache[f"{tier}k"][off:off + n],
                  "k_scale": cache[f"{tier}k_scale"][off:off + n],
                  "v": cache[f"{tier}v"][off:off + n],
                  "v_scale": cache[f"{tier}v_scale"][off:off + n]}
        if cfg.mixer == "hybrid":
            lc_run["conv"] = cache["conv"][lo:hi]
            lc_run["ssm"] = cache["ssm"][lo:hi]
        x, updated = jax.lax.scan(make_body(rolling=not is_global), x,
                                  {"p": p_run, "c": lc_run})
        for key_src, key_dst in (("k", f"{tier}k"), ("k_scale", f"{tier}k_scale"),
                                 ("v", f"{tier}v"), ("v_scale", f"{tier}v_scale")):
            new_cache[key_dst] = jax.lax.dynamic_update_slice_in_dim(
                new_cache[key_dst], updated[key_src], off, axis=0)
        if cfg.mixer == "hybrid":
            new_cache["conv"] = jax.lax.dynamic_update_slice_in_dim(
                new_cache["conv"], updated["conv"], lo, axis=0)
            new_cache["ssm"] = jax.lax.dynamic_update_slice_in_dim(
                new_cache["ssm"], updated["ssm"], lo, axis=0)
        if is_global:
            g_off += n
        else:
            w_off += n

    x = rms_norm(x[:, None], params["final_norm"], cfg.norm_eps,
                 bf16_grad=cfg.norm_bf16_grad)[:, 0]
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = _mask_padded_vocab((x @ head).astype(policy.output_dtype), cfg)
    new_cache["pos"] = pos + 1
    return logits, new_cache


# ---------------------------------------------------------------------------
# KV / state cache and single-token decode.
# ---------------------------------------------------------------------------
def init_cache(cfg: ModelConfig, batch: int, s_max: int, *,
               quantized: bool = True, dtype=jnp.bfloat16) -> dict:
    L = cfg.n_layers
    cache: dict[str, Any] = {"pos": jnp.zeros((), jnp.int32)}
    if cfg.mixer in ("attn", "hybrid"):
        if cfg.mla is not None:
            m = cfg.mla
            cache["mla_lat"] = jnp.zeros((L, batch, s_max, m.kv_lora_rank), dtype)
            cache["mla_rope"] = jnp.zeros((L, batch, s_max, m.qk_rope_dim), dtype)
        else:
            hkv, hd = cfg.n_kv, cfg.head_dim
            kv_dtype = jnp.int8 if quantized else dtype
            cache["k"] = jnp.zeros((L, batch, hkv, s_max, hd), kv_dtype)
            cache["v"] = jnp.zeros((L, batch, hkv, s_max, hd), kv_dtype)
            cache["k_scale"] = jnp.zeros((L, batch, hkv, s_max), jnp.float32)
            cache["v_scale"] = jnp.zeros((L, batch, hkv, s_max), jnp.float32)
    if cfg.mixer in ("ssm", "hybrid"):
        s = cfg.ssm
        conv_dim = s.d_inner + 2 * s.d_state
        cache["conv"] = jnp.zeros((L, batch, s.conv_kernel - 1, conv_dim), dtype)
        cache["ssm"] = jnp.zeros((L, batch, s.heads, s.d_state, s.head_p),
                                 jnp.float32)
    return cache


#: cache leaves with a sequence axis, and which axis it is — the single
#: source for growing / scattering caches (serve pool, prefill prealloc).
CACHE_SEQ_AXES = {"k": 3, "v": 3, "k_scale": 3, "v_scale": 3,
                  "mla_lat": 2, "mla_rope": 2}


def grow_cache(cache: dict, s_max: int) -> dict:
    """Zero-pad every sequence-bearing cache leaf out to ``s_max`` slots.

    Replaces the post-hoc ``tree_map_with_path`` pad the serve driver used
    to apply OUTSIDE the jit: growing inside the prefill step means the
    decode cache is preallocated at its final length in one compiled
    program and no second buffer materializes at the host boundary.
    SSM/conv state and ``pos`` have no sequence axis and pass through.
    """
    out = dict(cache)
    for name, ax in CACHE_SEQ_AXES.items():
        if name not in cache:
            continue
        x = cache[name]
        pad = s_max - x.shape[ax]
        if pad < 0:
            raise ValueError(f"grow_cache: {name} already has "
                             f"{x.shape[ax]} > {s_max} slots")
        if pad:
            out[name] = jnp.pad(
                x, [(0, pad) if i == ax else (0, 0) for i in range(x.ndim)])
    return out


def decode_step(params, cfg: ModelConfig, cache: dict, tokens_t, *,
                policy: Policy = Policy.full(), quantized: bool = True,
                kvq_backend: str = "ref", kvq_splits: int = 1, enc_out=None,
                active=None, scan_unroll: int = 1, mesh=None):
    """tokens_t: (B,) int32 current token.  Returns (logits (B,V), cache).

    Uniform window schedules pass the window as a STATIC python int (same
    gate as ``forward``), so ``attn_decode`` can take the lengths-aware
    kvq path — per-batch lengths + split-K tile skipping instead of a
    dense (B, S) bias; per-layer overrides (``cfg.global_layers``) scan a
    traced window and keep the documented bias fallback (hybrid archs
    serve through ``decode_step_two_tier`` to avoid it entirely).

    Slot-pooled serving (``repro.serve``): when ``cache['pos']`` is a
    per-row (B,) vector, every row decodes at its OWN position — RoPE,
    cache write, and length mask are all per-row, so one compiled step
    serves a ragged pool of in-flight requests.  ``active`` ((B,) bool)
    then gates the position increment: inactive (free) slots stay frozen
    instead of drifting, and their lengths clamp to >= 1 so the masked
    softmax never normalizes over an empty row (their logits are garbage
    by contract and never read).  Occupancy is pure data — joining or
    retiring a request never changes a traced shape, hence no recompile.
    """
    params = policy.cast_to_compute(params)
    pos = cache["pos"]
    per_slot = getattr(pos, "ndim", 0) == 1
    if per_slot and (cfg.mixer != "attn" or cfg.mla is not None):
        raise NotImplementedError(
            "per-slot decode (vector cache['pos']) is only supported for "
            "GQA attention caches (the kvq layout); MLA/SSM/hybrid archs "
            "serve through the scalar-pos paths")
    if active is not None and not per_slot:
        raise ValueError("decode_step: active mask requires a per-slot "
                         "(vector) cache['pos']")
    # per-slot pos is >= 0 by construction (pool zeros / scatter lengths),
    # so lengths = pos+1 >= 1 and every row's softmax normalizer is
    # non-empty on every backend — free slots never produce NaNs
    x = params["embed"][tokens_t]                           # (B, D)
    static_window = int(cfg.window) if not cfg.global_layers else None
    windows = None if static_window is not None else layer_windows(cfg)

    # mesh-aware cache layout (serve pool): "heads" needs no special
    # handling (XLA keeps per-kv-head work local), "seq" switches
    # attn_decode to the write+flash-combine collective
    kv_shard = "none"
    if mesh is not None and "k" in cache and cfg.mla is None:
        from repro.distributed import sharding as shd
        kv_shard = shd.serve_kv_shard(mesh, cfg.n_kv, cache["k"].shape[3])

    layer_caches = {k: v for k, v in cache.items() if k != "pos"}

    def body(carry, xs):
        p_layer, lc = xs["p"], xs["c"]
        win = static_window if static_window is not None else xs["w"]
        x = carry
        h = rms_norm(x[:, None], p_layer["ln1"], cfg.norm_eps, bf16_grad=cfg.norm_bf16_grad)[:, 0]
        new_lc = dict(lc)
        if cfg.mixer in ("attn", "hybrid") and cfg.mla is not None:
            mix, (cl, cr) = attn.mla_decode(p_layer["attn"], h, cfg,
                                            lc["mla_lat"], lc["mla_rope"], pos)
            new_lc.update(mla_lat=cl, mla_rope=cr)
        elif cfg.mixer in ("attn", "hybrid"):
            mix, (ck, csk, cv, csv) = attn.attn_decode(
                p_layer["attn"], h, cfg, lc["k"], lc["k_scale"], lc["v"],
                lc["v_scale"], pos, window=win, quantized=quantized,
                backend=kvq_backend, splits=kvq_splits, mesh=mesh,
                kv_shard=kv_shard)
            new_lc.update(k=ck, k_scale=csk, v=cv, v_scale=csv)
        if cfg.mixer == "ssm":
            mix, nconv, nssm = ssm_mod.ssm_decode_step(
                p_layer["ssm"], h, cfg, lc["conv"], lc["ssm"])
            new_lc.update(conv=nconv, ssm=nssm)
        elif cfg.mixer == "hybrid":
            s_mix, nconv, nssm = ssm_mod.ssm_decode_step(
                p_layer["ssm"], h, cfg, lc["conv"], lc["ssm"])
            new_lc.update(conv=nconv, ssm=nssm)
            mix = 0.5 * (
                rms_norm(mix[:, None], p_layer["mix_norm_attn"], cfg.norm_eps, bf16_grad=cfg.norm_bf16_grad)[:, 0]
                + rms_norm(s_mix[:, None], p_layer["mix_norm_ssm"], cfg.norm_eps, bf16_grad=cfg.norm_bf16_grad)[:, 0])
        x = x + mix
        if cfg.encoder is not None:
            hx = rms_norm(x[:, None], p_layer["ln_x"], cfg.norm_eps, bf16_grad=cfg.norm_bf16_grad)
            hkv, hd = cfg.n_kv, cfg.head_dim
            bb, se, _ = enc_out.shape
            k = (enc_out @ p_layer["xattn"]["wk"]).reshape(bb, se, hkv, hd)
            v = (enc_out @ p_layer["xattn"]["wv"]).reshape(bb, se, hkv, hd)
            x = x + attn.cross_attn_block(p_layer["xattn"], hx, (k, v), cfg)[:, 0]
        if "ffn" in p_layer:
            h2 = rms_norm(x[:, None], p_layer["ln2"], cfg.norm_eps, bf16_grad=cfg.norm_bf16_grad)
            ffn_out, _ = _ffn_apply(p_layer["ffn"], h2, cfg, mesh=mesh)
            x = x + ffn_out[:, 0]
        return x, new_lc

    xs = {"p": params["blocks"], "c": layer_caches}
    if static_window is None:
        xs["w"] = windows
    x, new_caches = jax.lax.scan(body, x, xs, unroll=scan_unroll)
    x = rms_norm(x[:, None], params["final_norm"], cfg.norm_eps, bf16_grad=cfg.norm_bf16_grad)[:, 0]
    head = params["embed"].T if cfg.tie_embeddings else params["lm_head"]
    logits = _mask_padded_vocab((x @ head).astype(policy.output_dtype), cfg)
    if active is not None:
        new_caches["pos"] = pos + active.astype(jnp.int32)
    else:
        new_caches["pos"] = pos + 1
    return logits, new_caches
