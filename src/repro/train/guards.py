"""Training fault guards: NaN/Inf grad sentinel + rolling-median
loss-spike detection with escalating skip-step → rollback.

Detection is two-tier, matching where each fault is cheapest to catch:

* **non-finite grads** are caught IN-JIT: ``core.mixed_precision``'s
  all-finite tree check already rides every train step (it drives fp16
  loss scaling), and ``adamw.update(skip=...)`` zeroes the update when
  it trips — ``TrainConfig.skip_nonfinite`` turns that on outside the
  fp16 path.  The guard only *counts* these (via the step's
  ``grads_finite`` metric) and escalates;
* **loss spikes** are caught HOST-side after the step, by comparing the
  step loss against a rolling median of recent *healthy* losses
  (HomebrewNLP-Jax's wandblog idiom: median, not mean — one spike must
  not drag the baseline up).  A spiked step's params are already
  updated; the guard quarantines the loss out of the history and
  escalates instead of pretending it can un-apply the update.

Escalation: each bad step (non-finite or spike) grows ``bad_streak``;
an isolated bad step is **skipped** (logged, excluded from history),
``rollback_after`` consecutive bad steps return ``ROLLBACK`` — the
driver restores the last good checkpoint via ``CheckpointManager`` and
replays from there (``launch/train.py --guard``).  Healthy steps reset
the streak.

With a ``sink`` (``repro.events.EventSink``) every non-OK verdict
streams to the append-only JSONL log as it happens — over a multi-hour
run the skip/rollback history survives the process (the long-run
metrics seam PR 7 left open; ``launch/train.py --events`` wires it).
With a ``registry`` (:class:`repro.obs.MetricsRegistry`) every verdict
ALSO retires into bounded-memory counters + streaming histograms
(loss, grad norm) that periodic ``metrics_snapshot`` events carry to
the same log — the ISSUE-10 close of the "streaming those guard
verdicts to a metrics sink over long runs" ROADMAP item.
"""
from __future__ import annotations

import dataclasses
import math
import statistics
from collections import deque


@dataclasses.dataclass(frozen=True)
class GuardConfig:
    window: int = 32          # healthy losses kept for the rolling median
    spike_factor: float = 4.0  # loss > factor * median(window) => spike
    min_history: int = 5      # no spike verdicts until this many healthy
    rollback_after: int = 3   # consecutive bad steps that trigger rollback

    def __post_init__(self):
        if self.window < 1 or self.min_history < 1:
            raise ValueError("GuardConfig: window and min_history must be "
                             ">= 1")
        if self.spike_factor <= 1.0:
            raise ValueError("GuardConfig: spike_factor must be > 1 "
                             "(a factor <= 1 flags ordinary noise)")
        if self.rollback_after < 1:
            raise ValueError("GuardConfig: rollback_after must be >= 1")


class TrainGuard:
    """Per-step verdicts: ``OK`` | ``SKIP`` | ``ROLLBACK`` (see module
    docstring for the escalation contract)."""

    OK, SKIP, ROLLBACK = "ok", "skip", "rollback"

    def __init__(self, cfg: GuardConfig = GuardConfig(), *, sink=None,
                 registry=None):
        self.cfg = cfg
        self.sink = sink                  # optional EventSink (JSONL)
        self.registry = registry          # optional obs.MetricsRegistry
        self._window: deque[float] = deque(maxlen=cfg.window)
        self._step = 0
        self.bad_streak = 0
        self.nonfinite = 0
        self.spikes = 0
        self.skipped = 0
        self.rollbacks = 0

    def median(self) -> float | None:
        return statistics.median(self._window) if self._window else None

    def observe(self, loss: float, grads_finite: bool = True,
                grad_norm: float | None = None) -> str:
        """Judge one completed step.  Healthy losses enter the rolling
        window; bad ones never do (a spike must not poison the baseline
        that detects the next spike).  ``grad_norm`` is optional — pass
        it only if the driver already has it on host (the guard never
        forces a device sync)."""
        reason = None
        if not grads_finite or not math.isfinite(loss):
            reason = "nonfinite"
            self.nonfinite += 1
        elif (len(self._window) >= self.cfg.min_history
              and loss > self.cfg.spike_factor
              * statistics.median(self._window)):
            reason = "spike"
            self.spikes += 1
        self._step += 1
        reg = self.registry
        if reg is not None:
            if math.isfinite(loss):
                reg.observe("train.loss", float(loss))
            if grad_norm is not None and math.isfinite(grad_norm):
                reg.observe("train.grad_norm", float(grad_norm))
        if reason is None:
            self._window.append(float(loss))
            self.bad_streak = 0
            if reg is not None:
                reg.inc("guard.ok")
            return self.OK
        self.bad_streak += 1
        if self.bad_streak >= self.cfg.rollback_after:
            self.rollbacks += 1
            self.bad_streak = 0
            if reg is not None:
                reg.inc("guard.rollback")
            self._emit("guard_rollback", reason=reason, loss=float(loss))
            return self.ROLLBACK
        self.skipped += 1
        if reg is not None:
            reg.inc("guard.skip")
        self._emit("guard_skip", reason=reason, loss=float(loss),
                   streak=self.bad_streak)
        return self.SKIP

    def _emit(self, kind: str, **fields) -> None:
        if self.sink is not None:
            self.sink.emit(kind, guard_step=self._step, **fields)

    def reset_history(self) -> None:
        """Forget the loss window + streak — call after a rollback: the
        restored params' losses get a fresh baseline."""
        self._window.clear()
        self.bad_streak = 0

    def counters(self) -> dict:
        return {"nonfinite": self.nonfinite, "spikes": self.spikes,
                "skipped": self.skipped, "rollbacks": self.rollbacks,
                "bad_streak": self.bad_streak,
                "window": len(self._window)}
