"""Sharded training step factory: pjit(DP+TP) x OpTorch S-C x M-P x accum.

``make_train_step`` assembles the full production step:
  - mixed precision (Policy + optional fp16 dynamic loss scaling),
  - sequential-checkpoint remat over the layer scan,
  - gradient accumulation (lax.scan over microbatches, fp32 accumulators),
  - AdamW with clipping/schedule,
and jits it with explicit in/out shardings from repro.distributed.sharding.
"""
from __future__ import annotations

import dataclasses
import functools
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.checkpoint import CheckpointConfig
from repro.core.mixed_precision import LossScale, Policy, get_policy, \
    scaled_value_and_grad
from repro.distributed import sharding as shd
from repro.models import transformer
from repro.models.config import ModelConfig
from repro.optim import adamw


@dataclasses.dataclass(frozen=True)
class TrainConfig:
    policy: str = "bf16"
    remat: CheckpointConfig = CheckpointConfig(enabled=True, policy="full",
                                               segment_size=1)
    accum: int = 1                      # gradient-accumulation microbatches
    scan_unroll: int = 1                # layer-scan unroll (dry-run costing)
    use_loss_scale: bool = False        # fp16 path
    skip_nonfinite: bool = False        # NaN/Inf-grad steps apply no update
    #   (fp16 loss scaling always skips; this extends the in-jit guard to
    #   the other policies — see train/guards.py for the escalation layer)
    opt: adamw.AdamWConfig = adamw.AdamWConfig()
    mem_budget_mb: int = 0              # >0: auto-solve a RematPlan to fit


def microbatch_specs(batch_sds: dict, *, accum: int = 1, mesh=None) -> dict:
    """PER-DEVICE microbatch token spec — the unit the remat planner must
    budget for: global batch / (data-parallel shards x accum steps).
    The ONE place this formula lives; launch/train and dryrun reuse it."""
    b, s = batch_sds["tokens"].shape
    dp = shd.dp_size(mesh) if mesh is not None else 1
    return {"tokens": jax.ShapeDtypeStruct(
        (max(1, b // (dp * max(1, accum))), s), jnp.int32)}


def plan_profile(cfg: ModelConfig, tc: TrainConfig, batch_sds: dict,
                 mesh=None):
    """The ChainProfile the planner budgets against for this train config:
    per-device microbatch, in the policy's compute dtype.  Single source —
    resolve_remat and the launcher's --remat auto both use it."""
    from repro import plan as plan_mod
    pol = get_policy(tc.policy)
    dtype_bytes = jnp.dtype(pol.compute_dtype).itemsize
    flash_resid_bytes = None if pol.flash_resid_dtype is None else \
        jnp.dtype(pol.flash_resid_dtype).itemsize
    model_shards = 1
    if mesh is not None and "model" in mesh.axis_names:
        model_shards = mesh.shape["model"]
    return plan_mod.profile_transformer(
        cfg, microbatch_specs(batch_sds, accum=tc.accum, mesh=mesh),
        dtype_bytes=dtype_bytes, flash_resid_bytes=flash_resid_bytes,
        model_shards=model_shards)


def resolve_remat(cfg: ModelConfig, tc: TrainConfig, batch_sds: dict,
                  mesh=None) -> TrainConfig:
    """Fill ``tc.remat.plan`` from the memory planner when a budget is set.

    Profiles the block scan at per-device MICROBATCH shape (the remat'd
    unit under DP sharding + gradient accumulation) in the policy's
    compute dtype, and solves min-recompute s.t. peak <= budget.  A plan
    already present (e.g. loaded from a run's plan.json) wins; an explicit
    plan is validated against the model depth either way.
    """
    if tc.remat.plan is not None:
        tc.remat.validated_plan(cfg.n_layers)
        return tc
    if tc.mem_budget_mb <= 0 or not tc.remat.enabled:
        return tc
    from repro import plan as plan_mod
    prof = plan_profile(cfg, tc, batch_sds, mesh=mesh)
    rp = plan_mod.plan_for_budget(prof, tc.mem_budget_mb * 2 ** 20,
                                  policy=tc.remat.policy)
    return dataclasses.replace(
        tc, remat=dataclasses.replace(tc.remat, plan=rp))


def _tree_add(a, b):
    return jax.tree_util.tree_map(jnp.add, a, b)


def build_train_step(cfg: ModelConfig, tc: TrainConfig, mesh=None):
    """The pure step function (jit-agnostic; used by tests directly)."""
    policy = get_policy(tc.policy)
    loss_scale_proto = LossScale.init() if tc.use_loss_scale else None

    def loss_for(p, mb):
        return transformer.loss_fn(p, cfg, mb, policy=policy, remat=tc.remat,
                                    scan_unroll=tc.scan_unroll, mesh=mesh)

    vg = scaled_value_and_grad(loss_for, policy, loss_scale_proto)

    def compute_grads(params, ls: Optional[LossScale], batch):
        nonlocal_vg = scaled_value_and_grad(loss_for, policy, ls) \
            if ls is not None else vg
        if tc.accum <= 1:
            (loss, _aux), grads, finite = nonlocal_vg(params, batch)
            return loss, grads, finite
        # microbatch split along the batch axis (positions: (3, B, S))
        def split(path, x):
            name = str(path[-1].key) if path else ""
            if name == "positions" and x.ndim == 3:
                return x.reshape(3, tc.accum, -1, *x.shape[2:]).swapaxes(0, 1)
            return x.reshape(tc.accum, x.shape[0] // tc.accum, *x.shape[1:])

        mbs = jax.tree_util.tree_map_with_path(split, batch)

        def body(carry, mb):
            loss_acc, grad_acc, fin = carry
            (loss, _aux), grads, finite = nonlocal_vg(params, mb)
            grads = jax.tree_util.tree_map(
                lambda a, g: a + g.astype(jnp.float32), grad_acc, grads)
            return (loss_acc + loss, grads, fin & finite), None

        zero_grads = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
        (loss, grads, finite), _ = jax.lax.scan(
            body, (jnp.float32(0), zero_grads, jnp.bool_(True)), mbs)
        inv = 1.0 / tc.accum
        grads = jax.tree_util.tree_map(lambda g: g * inv, grads)
        return loss * inv, grads, finite

    def train_step(params, opt_state, loss_scale, batch):
        ls = loss_scale if tc.use_loss_scale else None
        loss, grads, finite = compute_grads(params, ls, batch)
        skip = ~finite if (tc.use_loss_scale or tc.skip_nonfinite) else None
        new_params, new_opt, metrics = adamw.update(
            tc.opt, grads, opt_state, params, skip=skip)
        new_ls = loss_scale.update(finite) if tc.use_loss_scale else loss_scale
        metrics = {"loss": loss, "grads_finite": finite, **metrics}
        return new_params, new_opt, new_ls, metrics

    return train_step


def make_train_step(cfg: ModelConfig, mesh, tc: TrainConfig,
                    batch_sds: dict, *, donate: bool = True):
    """jit-compiled sharded step + the sharding trees used to place state."""
    tc = resolve_remat(cfg, tc, batch_sds, mesh=mesh)
    step = build_train_step(cfg, tc, mesh=mesh)
    params_sds = jax.eval_shape(
        lambda: transformer.init_params(cfg, jax.random.PRNGKey(0)))
    p_spec = shd.param_specs(cfg, params_sds, mesh=mesh)
    p_shard = shd.to_shardings(mesh, p_spec)
    opt_shard = adamw.AdamWState(mu=p_shard, nu=p_shard,
                                 count=NamedSharding(mesh, P()))
    b_spec = shd.batch_specs(cfg, batch_sds, mesh)
    b_shard = shd.to_shardings(mesh, b_spec)

    # loss-scale state is tiny and replicated: leave its sharding to jax
    jitted = jax.jit(
        step,
        in_shardings=(p_shard, opt_shard, None, b_shard),
        out_shardings=(p_shard, opt_shard, None, None),
        donate_argnums=(0, 1) if donate else (),
    )
    return jitted, dict(params=p_shard, opt=opt_shard, batch=b_shard)
