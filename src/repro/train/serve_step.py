"""Sharded serving steps: prefill (forward + cache build) and decode.

Decode is the paper's E-D insight deployed: the KV cache lives int8-encoded
(kernels/kvq) and is dequantized inside the attention read.
"""
from __future__ import annotations

import functools
from typing import Optional

import jax
import jax.numpy as jnp
from jax.sharding import NamedSharding, PartitionSpec as P

from repro.core.mixed_precision import Policy, get_policy
from repro.distributed import sharding as shd
from repro.models import transformer
from repro.models.config import ModelConfig


def build_prefill_step(cfg: ModelConfig, *, policy_name: str = "bf16",
                       quantized: bool = True, scan_unroll: int = 1,
                       s_max: int | None = None, mesh=None):
    """``s_max``: preallocate the decode cache at its FINAL length (prompt
    + generation) inside the compiled prefill — the sequence-bearing
    leaves are grown with ``transformer.grow_cache`` before they ever
    reach the host, so no second buffer (and no post-hoc tree_map pad)
    materializes at the jit boundary."""
    policy = get_policy(policy_name)

    def prefill_step(params, batch):
        logits, aux = transformer.forward(
            params, cfg, batch, policy=policy, build_cache=True,
            cache_quantized=quantized, scan_unroll=scan_unroll, mesh=mesh)
        cache = aux["cache"]
        if s_max is not None:
            cache = transformer.grow_cache(cache, s_max)
        # serving returns only the last-position logits + the primed cache
        return logits[:, -1], cache

    return prefill_step


def build_decode_step(cfg: ModelConfig, *, policy_name: str = "bf16",
                      quantized: bool = True, kvq_backend: str = "ref",
                      kvq_splits: int = 1, scan_unroll: int = 1, mesh=None):
    policy = get_policy(policy_name)

    def step(params, cache, tokens_t, enc_out=None):
        kw = {"enc_out": enc_out} if cfg.encoder is not None else {}
        logits, cache = transformer.decode_step(
            params, cfg, cache, tokens_t, policy=policy,
            quantized=quantized, kvq_backend=kvq_backend,
            kvq_splits=kvq_splits, scan_unroll=scan_unroll, mesh=mesh, **kw)
        return logits, cache

    return step


def make_serve_steps(cfg: ModelConfig, mesh, input_sds: dict, *,
                     kind: str, policy_name: str = "bf16",
                     quantized: bool = True, donate: bool = True,
                     kvq_backend: str = "ref", kvq_splits: int = 1,
                     scan_unroll: int = 1):
    """jit the prefill or decode step with explicit shardings.

    ``input_sds`` comes from repro.configs.input_specs for the cell.
    ``kvq_backend``/``kvq_splits`` select the int8 decode-attention kernel
    and its split-K fan-out (decode cells only).
    """
    params_sds = jax.eval_shape(
        lambda: transformer.init_params(cfg, jax.random.PRNGKey(0)))
    p_shard = shd.to_shardings(mesh, shd.param_specs(cfg, params_sds))
    dp = shd.dp_axes(mesh)
    n_dp = shd.dp_size(mesh)

    if kind == "prefill":
        fn = build_prefill_step(cfg, policy_name=policy_name,
                                quantized=quantized, scan_unroll=scan_unroll,
                                mesh=mesh)
        b_shard = shd.to_shardings(
            mesh, shd.batch_specs(cfg, input_sds, mesh))
        cache_sds = jax.eval_shape(fn, params_sds, input_sds)[1]
        c_shard = shd.to_shardings(mesh, shd.cache_specs(cfg, cache_sds, mesh))
        logit_shard = NamedSharding(mesh, P(dp, "model"))
        return jax.jit(fn, in_shardings=(p_shard, b_shard),
                       out_shardings=(logit_shard, c_shard)), p_shard

    assert kind == "decode", kind
    fn = build_decode_step(cfg, policy_name=policy_name, quantized=quantized,
                           kvq_backend=kvq_backend, kvq_splits=kvq_splits,
                           scan_unroll=scan_unroll, mesh=mesh)
    cache_sds = input_sds["cache"]
    c_shard = shd.to_shardings(mesh, shd.cache_specs(cfg, cache_sds, mesh))
    b = input_sds["tokens_t"].shape[0]
    tok_shard = NamedSharding(mesh, P(dp) if b % n_dp == 0 else P())
    logit_shard = NamedSharding(
        mesh, P(dp if b % n_dp == 0 else None, "model"))
    in_sh = [p_shard, c_shard, tok_shard]
    args = [None, None, None]
    if cfg.encoder is not None:
        enc = input_sds["enc_out"]
        in_sh.append(NamedSharding(
            mesh, P(dp if enc.shape[0] % n_dp == 0 else None, None, None)))
    return jax.jit(fn, in_shardings=tuple(in_sh),
                   out_shardings=(logit_shard, c_shard),
                   donate_argnums=(1,) if donate else ()), p_shard
