"""Fault-tolerant checkpointing: atomic, sharded, resharding restore.

Design (multi-host ready, exercised single-host here):
  * Each process writes ONLY its addressable shards, as one .npz per
    process, plus a manifest.json (step, tree structure, global shapes,
    dtypes, config fingerprint, loader state).
  * Writes go to ``step_XXXXXXXX.tmp/`` then os.rename -> atomic: a crash
    mid-write never corrupts the latest checkpoint.
  * ``restore`` accepts ANY target mesh/sharding: arrays are rebuilt from
    the saved global values and re-placed with jax.device_put against the
    new sharding -> elastic scaling (checkpoint from 512 chips restores
    onto 8, or onto a different mesh shape).
  * keep_last limits disk; ``latest_step`` finds the resume point.
  * Every shard file's sha256 goes into the manifest and is re-verified
    on restore — silent bit-rot surfaces as a named
    :class:`CheckpointMismatchError`, not a garbage parameter tree.
  * ``latest_intact_step`` / ``restore_latest`` fall back PAST a
    damaged newest checkpoint to the newest one that still verifies
    (with a warning) — a torn or corrupted write costs one checkpoint
    interval, never the run.
  * SIGTERM handler (launcher) triggers a final save -> preemption safe.
"""
from __future__ import annotations

import dataclasses
import hashlib
import json
import os
import shutil
import time
import warnings
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


class CheckpointMismatchError(ValueError):
    """The checkpoint on disk disagrees with what the caller expects —
    restoring one model's checkpoint into another's tree, or re-saving a
    different state over an existing step."""


def _flatten_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(
            str(p.key) if isinstance(p, jax.tree_util.DictKey) else str(p.idx)
            if isinstance(p, jax.tree_util.SequenceKey) else str(p)
            for p in path)
        out[key] = leaf
    return out


def tree_paths(tree) -> list[str]:
    return sorted(_flatten_with_paths(tree))


def _leaf_sig(tree) -> dict[str, dict]:
    """Manifest-style {path: {shape, dtype}} for a pytree (arrays or
    ShapeDtypeStructs)."""
    out = {}
    for key, leaf in _flatten_with_paths(tree).items():
        dtype = getattr(leaf, "dtype", None) or np.asarray(leaf).dtype
        out[key] = {"shape": [int(s) for s in jnp.shape(leaf)],
                    "dtype": str(dtype)}
    return out


def _sig_fingerprint(sig: dict[str, dict]) -> str:
    items = [[k, sig[k]["shape"], sig[k]["dtype"]] for k in sorted(sig)]
    return hashlib.sha256(json.dumps(items).encode()).hexdigest()


def tree_fingerprint(tree) -> str:
    """Structure fingerprint: sha256 over the sorted (leaf path, shape,
    dtype) triples.  Values don't enter — the fingerprint identifies the
    ARCHITECTURE a checkpoint belongs to, cheap enough to verify on
    every save/restore."""
    return _sig_fingerprint(_leaf_sig(tree))


def _file_sha256(path: str, chunk: int = 1 << 20) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        while True:
            block = f.read(chunk)
            if not block:
                break
            h.update(block)
    return h.hexdigest()


def _sig_diff(a: dict[str, dict], b: dict[str, dict], n: int = 5) -> str:
    """Human-readable first differences between two leaf signatures."""
    lines = []
    for k in sorted(set(a) | set(b)):
        if a.get(k) != b.get(k):
            lines.append(f"  {k}: checkpoint={a.get(k)} target={b.get(k)}")
        if len(lines) >= n:
            lines.append("  ...")
            break
    return "\n".join(lines) or "  (tree structures identical?)"


@dataclasses.dataclass
class CheckpointManager:
    directory: str
    keep_last: int = 3

    def __post_init__(self):
        os.makedirs(self.directory, exist_ok=True)

    # ------------------------------------------------------------- paths --
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:08d}")

    def all_steps(self) -> list[int]:
        steps = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    steps.append(int(name.split("_")[1]))
                except ValueError:
                    continue
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # ------------------------------------------------------------ verify --
    def verify(self, step: int) -> bool:
        """True iff the checkpoint's manifest parses and every shard
        file listed in it exists with a matching sha256.  Quiet — the
        fallback helpers do the warning."""
        d = self._step_dir(step)
        try:
            with open(os.path.join(d, "manifest.json")) as f:
                manifest = json.load(f)
        except (OSError, json.JSONDecodeError):
            return False
        checksums = manifest.get("checksums")
        if checksums is None:
            return True                   # pre-checksum checkpoint: trust
        for name, want in checksums.items():
            path = os.path.join(d, name)
            if not os.path.exists(path) or _file_sha256(path) != want:
                return False
        return True

    def latest_intact_step(self) -> Optional[int]:
        """Newest step that passes :meth:`verify`, warning (not
        raising) past damaged ones — a corrupted final checkpoint costs
        one save interval, never the run."""
        for step in reversed(self.all_steps()):
            if self.verify(step):
                return step
            warnings.warn(f"checkpoint step {step} at "
                          f"{self._step_dir(step)} failed verification "
                          f"(corrupt or torn write) — falling back")
        return None

    # -------------------------------------------------------------- save --
    def save(self, step: int, state: Any, *, extra: dict | None = None,
             config: Optional[str] = None):
        """Atomic save of a pytree of jax/np arrays.

        ``config`` is an architecture identity string (e.g.
        ``cfg.arch_id``) stored in the manifest and verified on restore.
        Re-saving an existing step is a no-op ONLY if the manifest
        matches (step + leaf shapes/dtypes + config); a conflicting
        re-save raises :class:`CheckpointMismatchError` instead of
        silently pretending it succeeded."""
        final = self._step_dir(step)
        sig = _leaf_sig(state)
        if os.path.exists(final):
            with open(os.path.join(final, "manifest.json")) as f:
                have = json.load(f)
            mismatch = []
            if have["step"] != step:
                mismatch.append(f"step: on-disk {have['step']} != {step}")
            if have.get("leaves") != sig:
                mismatch.append("leaf shapes/dtypes differ:\n"
                                + _sig_diff(have.get("leaves", {}), sig))
            if (config is not None and have.get("config") is not None
                    and have["config"] != config):
                mismatch.append(f"config: on-disk {have['config']!r} "
                                f"!= {config!r}")
            if mismatch:
                raise CheckpointMismatchError(
                    f"save: step {step} already exists at {final} with a "
                    f"DIFFERENT state — refusing the silent no-op:\n"
                    + "\n".join(mismatch))
            return                      # identical manifest: idempotent save
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)

        flat = _flatten_with_paths(state)
        arrays = {}
        for key, leaf in flat.items():
            arrays[key.replace("/", "__")] = np.asarray(jax.device_get(leaf))
        proc = jax.process_index()
        shard_name = f"shards_{proc:05d}.npz"
        np.savez(os.path.join(tmp, shard_name), **arrays)
        manifest = {
            "step": step,
            "time": time.time(),
            "process_count": jax.process_count(),
            "leaves": sig,
            "fingerprint": _sig_fingerprint(sig),
            # per-shard content checksums, re-verified on restore: a
            # flipped bit on disk fails loudly instead of loading as a
            # silently-garbage parameter tree
            "checksums": {shard_name:
                          _file_sha256(os.path.join(tmp, shard_name))},
            "config": config,
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        os.rename(tmp, final)          # atomic publish
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: max(0, len(steps) - self.keep_last)]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
        # remove stale tmp dirs from crashed writers
        for name in os.listdir(self.directory):
            if name.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.directory, name),
                              ignore_errors=True)

    # ------------------------------------------------------------ restore --
    def restore(self, step: int, like: Any, *, shardings: Any = None,
                config: Optional[str] = None) -> Any:
        """Restore into the structure of ``like``; place onto ``shardings``
        (any mesh — resharding restore) or leave on default device.

        The manifest's structure fingerprint (leaf paths + shapes +
        dtypes) must match ``like``, and the stored ``config`` identity
        must match a caller-provided one — a llama3 checkpoint restored
        into a whisper tree fails HERE with the differing leaves named,
        not deep in a shape error (or worse, silently)."""
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        for name, want in (manifest.get("checksums") or {}).items():
            path = os.path.join(d, name)
            if not os.path.exists(path):
                raise CheckpointMismatchError(
                    f"restore: checkpoint step {step} shard {name} is "
                    f"missing (torn write?)")
            got = _file_sha256(path)
            if got != want:
                raise CheckpointMismatchError(
                    f"restore: checkpoint step {step} shard {name} "
                    f"checksum mismatch (sha256 {got[:12]}… != manifest "
                    f"{want[:12]}…) — on-disk corruption")
        if (config is not None and manifest.get("config") is not None
                and manifest["config"] != config):
            raise CheckpointMismatchError(
                f"restore: checkpoint step {step} was saved for config "
                f"{manifest['config']!r}, caller expects {config!r}")
        if manifest.get("fingerprint") is not None:
            sig = _leaf_sig(like)
            missing = set(sig) - set(manifest.get("leaves", {}))
            if missing:
                raise KeyError(f"checkpoint {step} missing leaves: "
                               f"{sorted(missing)[:5]}")
            if _sig_fingerprint(sig) != manifest["fingerprint"]:
                raise CheckpointMismatchError(
                    f"restore: checkpoint step {step} does not fit the "
                    f"target tree (config "
                    f"{manifest.get('config')!r}):\n"
                    + _sig_diff(manifest.get("leaves", {}), sig))
        data: dict[str, np.ndarray] = {}
        for name in sorted(os.listdir(d)):
            if name.startswith("shards_") and name.endswith(".npz"):
                with np.load(os.path.join(d, name)) as z:
                    for k in z.files:
                        data[k.replace("__", "/")] = z[k]

        flat_like = _flatten_with_paths(like)
        missing = set(flat_like) - set(data)
        if missing:
            raise KeyError(f"checkpoint {step} missing leaves: {sorted(missing)[:5]}")
        shard_flat = _flatten_with_paths(shardings) if shardings is not None \
            else {}

        leaves_out = {}
        for key, leaf in flat_like.items():
            arr = data[key]
            want_shape = tuple(jnp.shape(leaf))
            if tuple(arr.shape) != want_shape:
                raise ValueError(
                    f"{key}: checkpoint shape {arr.shape} != target {want_shape}")
            if key in shard_flat:
                leaves_out[key] = jax.device_put(arr, shard_flat[key])
            else:
                leaves_out[key] = jnp.asarray(arr)

        # rebuild the tree in `like`'s structure
        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        ordered = []
        for path, _ in flat:
            key = "/".join(
                str(p.key) if isinstance(p, jax.tree_util.DictKey) else str(p.idx)
                if isinstance(p, jax.tree_util.SequenceKey) else str(p)
                for p in path)
            ordered.append(leaves_out[key])
        return jax.tree_util.tree_unflatten(treedef, ordered), manifest["extra"]

    def restore_latest(self, like: Any, *, shardings: Any = None,
                       config: Optional[str] = None):
        """Restore the newest INTACT checkpoint: a damaged tail
        checkpoint is warned past (``latest_intact_step``), never
        fatal.  Returns ``(step, state, extra)``; raises
        ``FileNotFoundError`` only when NO checkpoint verifies."""
        step = self.latest_intact_step()
        if step is None:
            raise FileNotFoundError(
                f"restore_latest: no intact checkpoint under "
                f"{self.directory}")
        state, extra = self.restore(step, like, shardings=shardings,
                                    config=config)
        return step, state, extra
