"""Fault-tolerant checkpointing: atomic, sharded, resharding restore.

Design (multi-host ready, exercised single-host here):
  * Each process writes ONLY its addressable shards, as one .npz per
    process, plus a manifest.json (step, tree structure, global shapes,
    dtypes, config fingerprint, loader state).
  * Writes go to ``step_XXXXXXXX.tmp/`` then os.rename -> atomic: a crash
    mid-write never corrupts the latest checkpoint.
  * ``restore`` accepts ANY target mesh/sharding: arrays are rebuilt from
    the saved global values and re-placed with jax.device_put against the
    new sharding -> elastic scaling (checkpoint from 512 chips restores
    onto 8, or onto a different mesh shape).
  * keep_last limits disk; ``latest_step`` finds the resume point.
  * SIGTERM handler (launcher) triggers a final save -> preemption safe.
"""
from __future__ import annotations

import dataclasses
import json
import os
import shutil
import time
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np


def _flatten_with_paths(tree):
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = {}
    for path, leaf in flat:
        key = "/".join(
            str(p.key) if isinstance(p, jax.tree_util.DictKey) else str(p.idx)
            if isinstance(p, jax.tree_util.SequenceKey) else str(p)
            for p in path)
        out[key] = leaf
    return out


def tree_paths(tree) -> list[str]:
    return sorted(_flatten_with_paths(tree))


@dataclasses.dataclass
class CheckpointManager:
    directory: str
    keep_last: int = 3

    def __post_init__(self):
        os.makedirs(self.directory, exist_ok=True)

    # ------------------------------------------------------------- paths --
    def _step_dir(self, step: int) -> str:
        return os.path.join(self.directory, f"step_{step:08d}")

    def all_steps(self) -> list[int]:
        steps = []
        for name in os.listdir(self.directory):
            if name.startswith("step_") and not name.endswith(".tmp"):
                try:
                    steps.append(int(name.split("_")[1]))
                except ValueError:
                    continue
        return sorted(steps)

    def latest_step(self) -> Optional[int]:
        steps = self.all_steps()
        return steps[-1] if steps else None

    # -------------------------------------------------------------- save --
    def save(self, step: int, state: Any, *, extra: dict | None = None):
        """Atomic save of a pytree of jax/np arrays."""
        final = self._step_dir(step)
        if os.path.exists(final):      # re-save of an existing step: no-op
            return
        tmp = final + ".tmp"
        if os.path.exists(tmp):
            shutil.rmtree(tmp)
        os.makedirs(tmp)

        flat = _flatten_with_paths(state)
        arrays, manifest_leaves = {}, {}
        for key, leaf in flat.items():
            arr = np.asarray(jax.device_get(leaf))
            arrays[key.replace("/", "__")] = arr
            manifest_leaves[key] = {"shape": list(arr.shape),
                                    "dtype": str(arr.dtype)}
        proc = jax.process_index()
        np.savez(os.path.join(tmp, f"shards_{proc:05d}.npz"), **arrays)
        manifest = {
            "step": step,
            "time": time.time(),
            "process_count": jax.process_count(),
            "leaves": manifest_leaves,
            "extra": extra or {},
        }
        with open(os.path.join(tmp, "manifest.json"), "w") as f:
            json.dump(manifest, f)
        os.rename(tmp, final)          # atomic publish
        self._gc()

    def _gc(self):
        steps = self.all_steps()
        for s in steps[: max(0, len(steps) - self.keep_last)]:
            shutil.rmtree(self._step_dir(s), ignore_errors=True)
        # remove stale tmp dirs from crashed writers
        for name in os.listdir(self.directory):
            if name.endswith(".tmp"):
                shutil.rmtree(os.path.join(self.directory, name),
                              ignore_errors=True)

    # ------------------------------------------------------------ restore --
    def restore(self, step: int, like: Any, *, shardings: Any = None) -> Any:
        """Restore into the structure of ``like``; place onto ``shardings``
        (any mesh — resharding restore) or leave on default device."""
        d = self._step_dir(step)
        with open(os.path.join(d, "manifest.json")) as f:
            manifest = json.load(f)
        data: dict[str, np.ndarray] = {}
        for name in sorted(os.listdir(d)):
            if name.startswith("shards_") and name.endswith(".npz"):
                with np.load(os.path.join(d, name)) as z:
                    for k in z.files:
                        data[k.replace("__", "/")] = z[k]

        flat_like = _flatten_with_paths(like)
        missing = set(flat_like) - set(data)
        if missing:
            raise KeyError(f"checkpoint {step} missing leaves: {sorted(missing)[:5]}")
        shard_flat = _flatten_with_paths(shardings) if shardings is not None \
            else {}

        leaves_out = {}
        for key, leaf in flat_like.items():
            arr = data[key]
            want_shape = tuple(jnp.shape(leaf))
            if tuple(arr.shape) != want_shape:
                raise ValueError(
                    f"{key}: checkpoint shape {arr.shape} != target {want_shape}")
            if key in shard_flat:
                leaves_out[key] = jax.device_put(arr, shard_flat[key])
            else:
                leaves_out[key] = jnp.asarray(arr)

        # rebuild the tree in `like`'s structure
        flat, treedef = jax.tree_util.tree_flatten_with_path(like)
        ordered = []
        for path, _ in flat:
            key = "/".join(
                str(p.key) if isinstance(p, jax.tree_util.DictKey) else str(p.idx)
                if isinstance(p, jax.tree_util.SequenceKey) else str(p)
                for p in path)
            ordered.append(leaves_out[key])
        return jax.tree_util.tree_unflatten(treedef, ordered), manifest["extra"]
