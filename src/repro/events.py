"""Append-only JSONL event sink: the metrics stream for long runs.

PR 7 left "streaming guard verdicts to a metrics sink over long runs"
open — counters lived in process memory and died with it.  This module
closes that half: every producer (the training guards, the serve
metrics' fault/retry/reject counters, the fleet router's health
transitions) appends one JSON object per line to a shared sink, so a
multi-hour run leaves a replayable, greppable record even if the
process is later killed.

Design constraints:

* **append-only**: the file is opened in append mode and never seeked —
  two producers (e.g. a router and its replicas' metrics) can share one
  sink object; a crashed run's sink is still valid JSONL up to the last
  flushed line;
* **cheap on the hot path**: ``emit`` formats one dict and writes one
  line; ``flush_every`` batches the fsync-ish flush (default every
  line, because the whole point is surviving a crash);
* **monotonic sequence**: every event carries ``seq`` (per-sink
  counter) and ``t`` (wall clock) so interleaved producers can be
  ordered deterministically after the fact.

``read_events`` is the consumer half: it tolerates a truncated final
line (a crash mid-write) by skipping it with a warning rather than
raising away the run's history.
"""
from __future__ import annotations

import json
import time
import warnings
from typing import Optional


class EventSink:
    """Append-only JSONL writer shared by every event producer."""

    def __init__(self, path: str, *, flush_every: int = 1,
                 clock=time.time):
        if flush_every < 1:
            raise ValueError("EventSink: flush_every must be >= 1")
        self.path = path
        self._clock = clock
        self._flush_every = flush_every
        self._file = open(path, "a")
        self._seq = 0
        self._unflushed = 0
        self.emitted = 0

    def emit(self, kind: str, **fields) -> None:
        """Append one event.  ``kind`` names the event type; ``fields``
        must be JSON-serializable (producers pass plain ints/floats/str
        — device arrays must be pulled to host first)."""
        if self._file is None:
            raise RuntimeError(f"EventSink: {self.path} is closed")
        rec = {"seq": self._seq, "t": self._clock(), "kind": kind, **fields}
        self._file.write(json.dumps(rec) + "\n")
        self._seq += 1
        self.emitted += 1
        self._unflushed += 1
        if self._unflushed >= self._flush_every:
            self._file.flush()
            self._unflushed = 0

    def flush(self) -> None:
        if self._file is not None:
            self._file.flush()
            self._unflushed = 0

    def close(self) -> None:
        if self._file is not None:
            self._file.flush()
            self._file.close()
            self._file = None

    def __enter__(self) -> "EventSink":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def read_events(path: str, kind: Optional[str] = None) -> list[dict]:
    """Load a sink's events (optionally filtered by ``kind``).  A
    truncated final line — a writer crashed mid-record — is skipped
    with a warning instead of poisoning the whole read."""
    out: list[dict] = []
    with open(path) as f:
        for i, line in enumerate(f):
            line = line.strip()
            if not line:
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                warnings.warn(f"read_events: {path}:{i + 1} is not valid "
                              f"JSON (truncated write?) — skipped")
                continue
            if kind is None or rec.get("kind") == kind:
                out.append(rec)
    return out
