"""Append-only JSONL event sink: the metrics stream for long runs.

PR 7 left "streaming guard verdicts to a metrics sink over long runs"
open — counters lived in process memory and died with it.  This module
closes that half: every producer (the training guards, the serve
metrics' fault/retry/reject counters, the fleet router's health
transitions) appends one JSON object per line to a shared sink, so a
multi-hour run leaves a replayable, greppable record even if the
process is later killed.

Design constraints:

* **append-only**: the file is opened in append mode and never seeked —
  two producers (e.g. a router and its replicas' metrics) can share one
  sink object; a crashed run's sink is still valid JSONL up to the last
  flushed line;
* **cheap on the hot path**: ``emit`` formats one dict and writes one
  line; ``flush_every`` batches the flush (default every line, because
  the whole point is surviving a crash).  A buffer flush survives a
  PROCESS crash but not a machine/kernel one — writers that need real
  durability (the serve request journal is one) pass ``fsync=True`` to
  force ``os.fsync`` on every flush;
* **monotonic sequence**: every event carries ``seq`` (per-sink
  counter) and ``t`` (wall clock) so interleaved producers can be
  ordered deterministically after the fact.  ``emit`` is thread-safe
  (the training watchdog alerts from its monitor thread while the main
  loop emits guard verdicts into the same sink).

``read_events`` is the consumer half: it tolerates a truncated final
line (a crash mid-write) by skipping it with a warning rather than
raising away the run's history.  With ``offset=`` it resumes from a
byte offset instead of re-reading the whole file, and with
``with_offset=True`` it returns ``(records, next_offset)`` where
``next_offset`` sits after the last COMPLETE line — an in-progress
torn tail is left for the next incremental read instead of being
skipped forever (the journal's tail-scan mode, and the live-monitor
mode: poll the file, keep only the new events).
"""
from __future__ import annotations

import json
import os
import threading
import time
import warnings
from typing import Optional, Union


class EventSink:
    """Append-only JSONL writer shared by every event producer."""

    def __init__(self, path: str, *, flush_every: int = 1,
                 fsync: bool = False, clock=time.time):
        if flush_every < 1:
            raise ValueError("EventSink: flush_every must be >= 1")
        self.path = path
        self._clock = clock
        self._flush_every = flush_every
        self._fsync = fsync
        self._file = open(path, "a")
        self._seq = 0
        self._unflushed = 0
        self._lock = threading.Lock()
        self.emitted = 0
        self.fsyncs = 0

    def _flush_locked(self) -> None:
        self._file.flush()
        if self._fsync:
            os.fsync(self._file.fileno())
            self.fsyncs += 1
        self._unflushed = 0

    def emit(self, kind: str, **fields) -> None:
        """Append one event.  ``kind`` names the event type; ``fields``
        must be JSON-serializable (producers pass plain ints/floats/str
        — device arrays must be pulled to host first)."""
        with self._lock:
            if self._file is None:
                raise RuntimeError(f"EventSink: {self.path} is closed")
            rec = {"seq": self._seq, "t": self._clock(), "kind": kind,
                   **fields}
            # compact separators: emit sits on serving/training hot paths
            # (span records fire every engine step when tracing is on),
            # and the default ", " spacing costs ~15% of the dump
            self._file.write(json.dumps(rec, separators=(",", ":")) + "\n")
            self._seq += 1
            self.emitted += 1
            self._unflushed += 1
            if self._unflushed >= self._flush_every:
                self._flush_locked()

    def flush(self) -> None:
        with self._lock:
            if self._file is not None:
                self._flush_locked()

    def tell(self) -> int:
        """Byte offset after the last WRITTEN record (flushes first) —
        the journal snapshots this so recovery can tail from here."""
        with self._lock:
            if self._file is None:
                raise RuntimeError(f"EventSink: {self.path} is closed")
            self._flush_locked()
            return self._file.tell()

    def close(self) -> None:
        with self._lock:
            if self._file is not None:
                self._flush_locked()
                self._file.close()
                self._file = None

    def __enter__(self) -> "EventSink":
        return self

    def __exit__(self, *exc) -> None:
        self.close()


def read_events(path: str, kind: Optional[str] = None, *,
                offset: int = 0, with_offset: bool = False
                ) -> Union[list[dict], tuple[list[dict], int]]:
    """Load a sink's events (optionally filtered by ``kind``).

    A truncated final line — a writer crashed mid-record — is skipped
    with a warning instead of poisoning the whole read.  ``offset``
    starts the scan at a byte offset (incremental tail reads: pass the
    ``next_offset`` a previous call returned).  With
    ``with_offset=True`` the return value is ``(records, next_offset)``
    and the torn tail is NOT warned about: the offset stops before it,
    so a still-in-flight write is simply retried by the next read —
    this is the mode a live consumer (or the journal's snapshot+tail
    recovery) uses under fsync batching, where a partial final line is
    the expected steady state, not a crash."""
    out: list[dict] = []
    with open(path, "rb") as f:
        if offset:
            f.seek(offset)
        data = f.read()
    end = offset                    # offset after the last COMPLETE line
    pos = 0
    while True:
        nl = data.find(b"\n", pos)
        if nl < 0:
            # incomplete trailing chunk: a torn (or in-flight) record
            if data[pos:].strip() and not with_offset:
                warnings.warn(f"read_events: {path} byte {offset + pos} "
                              f"is not valid JSON (truncated write?) — "
                              f"skipped")
            break
        line = data[pos:nl].strip()
        pos = nl + 1
        end = offset + pos
        if not line:
            continue
        try:
            rec = json.loads(line)
        except json.JSONDecodeError:
            warnings.warn(f"read_events: {path} byte "
                          f"{offset + pos - len(line) - 1} is not valid "
                          f"JSON (truncated write?) — skipped")
            continue
        if kind is None or rec.get("kind") == kind:
            out.append(rec)
    if with_offset:
        return out, end
    return out
