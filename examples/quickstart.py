"""Quickstart: OpTorch-style one-line optimization wrappers in JAX.

Composes the paper's three pipelines on a small model and shows the
memory/parity story in under a minute on CPU:

    python examples/quickstart.py
"""
import sys, os
sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import sc, mp, sc_mp                      # the paper's API
from repro.core.checkpoint import CheckpointConfig
from repro.core.mixed_precision import get_policy
from repro import configs
from repro.models import transformer


def temp_mb(fn, *sds):
    c = jax.jit(fn).lower(*sds).compile()
    return c.memory_analysis().temp_size_in_bytes / 2 ** 20


def main():
    cfg = configs.smoke_config("llama3-8b")
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    batch_sds = {"tokens": jax.ShapeDtypeStruct((8, 512), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((8, 512), jnp.int32)}

    def grads(remat, policy):
        def loss(p, b):
            l, _ = transformer.loss_fn(
                p, cfg, b, policy=get_policy(policy),
                remat=CheckpointConfig(enabled=remat))
            return l
        return jax.grad(loss)

    print("pipeline            temp-MB   (paper Fig. 10 analogue)")
    for name, remat, pol in [("standard (B)", False, "full"),
                             ("M-P", False, "bf16"),
                             ("S-C", True, "full"),
                             ("S-C + M-P", True, "bf16")]:
        mb = temp_mb(grads(remat, pol), params, batch_sds)
        print(f"{name:18s} {mb:8.0f}")

    # numerical parity: S-C is exact, the paper's 'same accuracy' claim
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (8, 512)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (8, 512)),
                                   jnp.int32)}
    l_std, _ = transformer.loss_fn(params, cfg, batch,
                                   remat=CheckpointConfig(enabled=False))
    l_sc, _ = transformer.loss_fn(params, cfg, batch,
                                  remat=CheckpointConfig(enabled=True))
    print(f"\nloss standard={float(l_std):.6f}  S-C={float(l_sc):.6f} "
          f"(identical: {abs(float(l_std) - float(l_sc)) < 1e-5})")

    # one-line wrappers, as the paper advertises (`scmodel = sc(model)`)
    fwd = lambda p, b: transformer.forward(p, cfg, b)[0]
    scmodel = sc(fwd)
    mpmodel = mp(fwd, policy="bf16")
    both = sc_mp(fwd)
    out = both(params, batch)
    print(f"sc_mp(model) logits: {out.shape} {out.dtype}")


if __name__ == "__main__":
    main()
