"""Paper-faithful end-to-end driver: ResNet-18 on CIFAR-shaped data with the
full OpTorch pipeline — Parallel E-D (background encode thread, u32 codec),
Selective-batch-sampling, Sequential checkpoints, Mixed precision.

Reproduces the paper's Fig. 9 claim at reduced scale: the optimized
pipelines reach the SAME accuracy as the standard pipeline.

    python examples/cifar_optorch.py [--steps 200]
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro.data.pipeline import ParallelEncodedLoader
from repro.data.synthetic import make_cifar_like
from repro.models import cnn
from repro.optim import adamw


def train(pipeline: str, imgs, labels, steps: int, seed=0):
    cfg = cnn.resnet18()
    params = cnn.init_params(cfg, jax.random.PRNGKey(seed))
    opt = adamw.init(params)
    ocfg = adamw.AdamWConfig(lr=3e-3, warmup_steps=10, total_steps=steps,
                             weight_decay=0.0)
    use_ed = "ED" in pipeline
    use_sc = "SC" in pipeline
    use_mp = "MP" in pipeline
    codec = "u32" if use_ed else "none"
    remat = None
    if use_sc:
        # profile-driven S-C: measure the layer chain, put the 5 checkpoints
        # at the byte-optimal sites (paper Fig. 11, automated by repro.plan)
        from repro import plan as plan_mod
        from repro.core.checkpoint import CheckpointConfig
        img_sds = jax.ShapeDtypeStruct((32, 32, 32, 3), jnp.float32)
        prof = plan_mod.profile_resnet(params, cfg, img_sds)
        remat = CheckpointConfig(plan=plan_mod.plan_min_peak(prof, 5))

    @jax.jit
    def step(params, opt, im, lb):
        def lossp(p):
            if use_mp:
                p = jax.tree_util.tree_map(
                    lambda x: x.astype(jnp.bfloat16)
                    if jnp.issubdtype(x.dtype, jnp.floating) else x, p)
            return cnn.loss_fn(p, cfg, im, lb, remat=remat,
                               decode_backend="ref" if use_ed else None)
        (l, aux), g = jax.value_and_grad(lossp, has_aux=True)(params)
        g = jax.tree_util.tree_map(lambda x: x.astype(jnp.float32), g)
        params, opt, _ = adamw.update(ocfg, g, opt, params)
        return params, opt, l, aux["acc"]

    # SBS: oversample class 0 2x (paper II.A.1) to show batch control
    weights = {c: (2.0 if c == 0 else 1.0) for c in range(10)}
    t0 = time.time()
    accs = []
    with ParallelEncodedLoader(imgs, labels, 32, codec=codec,
                               class_weights=weights, prefetch=4) as dl:
        for i in range(steps):
            enc, lb = next(dl)
            im = jnp.asarray(enc)
            params, opt, l, acc = step(params, opt, im, jnp.asarray(lb))
            accs.append(float(acc))
            if i % 50 == 0:
                print(f"  [{pipeline}] step {i:4d} "
                      f"loss {float(l):.3f} acc {float(acc):.3f}")
    return time.time() - t0, float(np.mean(accs[-20:]))


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=100)
    args = ap.parse_args()
    imgs, labels = make_cifar_like(n=2048, seed=0)

    print("pipeline       time(s)  final-acc   (paper Fig. 9 analogue)")
    results = {}
    for pipe in ["baseline", "ED", "ED+SC", "ED+SC+MP"]:
        dt, acc = train(pipe, imgs, labels, args.steps)
        results[pipe] = (dt, acc)
        print(f"{pipe:13s} {dt:7.1f}  {acc:9.3f}")

    base_acc = results["baseline"][1]
    for pipe, (dt, acc) in results.items():
        assert acc > base_acc - 0.1, \
            f"{pipe} accuracy regressed vs baseline ({acc} vs {base_acc})"
    print("\nAll optimized pipelines within 0.1 accuracy of baseline — the "
          "paper's parity claim reproduces.")


if __name__ == "__main__":
    main()
