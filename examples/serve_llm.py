"""Batched serving driver: prefill + decode with an int8-encoded KV cache.

The paper's E-D idea deployed for inference: the KV cache is *stored
encoded* (int8 + scales, via kernels/kvq) and decoded inside the attention
read, halving cache bytes vs bf16.  Runs a small model end-to-end on CPU:

    python examples/serve_llm.py [--arch llama3-8b] [--batch 4] [--gen 24]
"""
import argparse
import os
import sys
import time

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import jax
import jax.numpy as jnp
import numpy as np

from repro import configs
from repro.models import transformer
from repro.train.serve_step import build_decode_step, build_prefill_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="llama3-8b")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=24)
    ap.add_argument("--no-quantize", action="store_true")
    args = ap.parse_args()

    cfg = configs.smoke_config(args.arch)
    quant = not args.no_quantize
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    rng = np.random.default_rng(0)
    prompts = jnp.asarray(
        rng.integers(0, cfg.vocab, (args.batch, args.prompt_len)), jnp.int32)

    # prefill preallocates the decode cache at prompt + gen inside the jit
    prefill = jax.jit(build_prefill_step(
        cfg, policy_name="bf16", quantized=quant,
        s_max=args.prompt_len + args.gen))
    decode = jax.jit(build_decode_step(cfg, policy_name="bf16",
                                       quantized=quant))

    t0 = time.time()
    last_logits, cache = prefill(params, {"tokens": prompts})
    tok = jnp.asarray(last_logits.argmax(-1), jnp.int32)
    t_prefill = time.time() - t0

    out_tokens = [tok]
    t0 = time.time()
    for _ in range(args.gen - 1):
        logits, cache = decode(params, cache, tok)
        tok = jnp.asarray(logits.argmax(-1), jnp.int32)
        out_tokens.append(tok)
    jax.block_until_ready(tok)
    t_decode = time.time() - t0

    gen = np.stack([np.asarray(t) for t in out_tokens], 1)
    kv_bytes = sum(
        x.size * x.dtype.itemsize for k, x in cache.items()
        if k in ("k", "v", "k_scale", "v_scale", "mla_lat", "mla_rope"))
    print(f"arch={cfg.arch_id} quantized_cache={quant}")
    print(f"prefill {args.batch}x{args.prompt_len}: {t_prefill*1e3:.0f} ms")
    print(f"decode  {args.gen} tokens: {t_decode*1e3:.0f} ms "
          f"({t_decode/max(1,args.gen-1)*1e3:.1f} ms/tok)")
    print(f"cache bytes: {kv_bytes/2**20:.2f} MiB "
          f"({'int8+scales' if quant else 'bf16'})")
    print(f"generated (first row): {gen[0][:16].tolist()}")
    assert np.isfinite(np.asarray(out_tokens[-1])).all()


if __name__ == "__main__":
    main()
