"""End-to-end LM training driver: a ~100M-param llama-style model with the
full production stack (S-C remat, bf16 M-P, grad accumulation, AdamW,
atomic checkpointing + resume, preemption handling, step watchdog).

Scaled for this container by default (--tiny). Drop --tiny on a real host
to train the full ~100M config for a few hundred steps:

    python examples/train_llm.py [--tiny] [--steps 300]
"""
import argparse
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

import dataclasses

from repro import configs
from repro.launch import train as launch_train
from repro.models.config import ModelConfig


def model_100m() -> ModelConfig:
    # ~115M params: 12L x 768, GQA 12/4 heads, vocab 32k
    return ModelConfig(arch_id="llama-100m", family="dense", n_layers=12,
                       d_model=768, n_heads=12, n_kv=4, d_ff=2048,
                       vocab=32000)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--tiny", action="store_true",
                    help="CPU-sized variant of the 100M config")
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--ckpt-dir", default="/tmp/train_llm_ckpt")
    args = ap.parse_args()

    cfg = model_100m()
    if args.tiny:
        cfg = dataclasses.replace(cfg, n_layers=4, d_model=128, n_heads=4,
                                  n_kv=2, d_ff=256, vocab=2048)

    # register the config so the production launcher can resolve it
    import repro.configs as C
    orig = C.get_config
    C.get_config = lambda a, _o=orig: cfg if a == cfg.arch_id else _o(a)

    argv = ["--arch", cfg.arch_id, "--steps", str(args.steps),
            "--batch", "8", "--seq", "256" if not args.tiny else "128",
            "--accum", "2", "--policy", "bf16",
            "--ckpt-dir", args.ckpt_dir, "--ckpt-every", "100",
            "--log-every", "20"]
    sys.argv = [sys.argv[0]] + argv
    return launch_train.main()


if __name__ == "__main__":
    raise SystemExit(main())
