#!/usr/bin/env python
"""tracelens: render repro.obs span records from a JSONL event stream.

The serving/training drivers (``--trace``) emit paired ``span_begin`` /
``span_end`` records (see ``repro.obs.trace``).  This tool reconstructs
them into:

* per-request timelines (``--trace GID``): the request's root span with
  its queue / prefill / decode / migrate / recover segments and explicit
  ``(gap)`` fillers, so the segments SUM to the end-to-end latency by
  construction;
* a fleet Gantt (``--gantt``): one row per request root span on a
  shared wall-clock axis;
* a latency-breakdown table (``--table``): per span name, streaming
  log2-bucket percentiles (the same ``repro.obs.Histogram`` the serving
  metrics use — this tool never holds per-sample lists either);
* a Chrome/Perfetto ``trace.json`` (``--json out.json``): complete
  ("X") events per closed span, "B" events for spans a crash left open,
  one Perfetto process lane per tracer pid (r0/r1/router/journal/...).

Usage:
    python tools/tracelens.py events.jsonl
    python tools/tracelens.py events.jsonl --table --gantt
    python tools/tracelens.py events.jsonl --trace 3
    python tools/tracelens.py events.jsonl --json trace.json
"""
from __future__ import annotations

import argparse
import json
import os
import sys

sys.path.insert(0, os.path.join(os.path.dirname(os.path.abspath(__file__)),
                                "..", "src"))

from repro.events import read_events               # noqa: E402
from repro.obs.registry import Histogram           # noqa: E402

#: span_begin fields that are structure, not user attrs
_META = ("kind", "seq", "t", "name", "sid", "trace", "parent", "pid", "ts")


def _mk(b: dict, e: dict | None) -> dict:
    return {
        "name": b["name"], "sid": b["sid"], "trace": b.get("trace"),
        "parent": b.get("parent"), "pid": b.get("pid", "main"),
        "t0": b["ts"], "t1": None if e is None else e["ts"],
        "dur": None if e is None else e["ts"] - b["ts"],
        "attrs": {**{k: v for k, v in b.items() if k not in _META},
                  **({} if e is None else
                     {k: v for k, v in e.items()
                      if k not in ("kind", "seq", "t", "sid", "ts")})},
    }


def load_spans(path: str) -> tuple[list[dict], list[dict]]:
    """Pair span records from an event file.

    Returns ``(closed, open)`` — open spans are begins whose end never
    hit the stream (a crash, or work still in flight at close); they are
    an observation, not an error."""
    begins: dict = {}
    closed: list[dict] = []
    for r in read_events(path):
        kind = r.get("kind")
        if kind == "span_begin":
            begins[r["sid"]] = r
        elif kind == "span_end":
            b = begins.pop(r["sid"], None)
            if b is not None:
                closed.append(_mk(b, r))
    return closed, [_mk(b, None) for b in begins.values()]


def by_trace(spans: list[dict]) -> dict:
    out: dict = {}
    for s in spans:
        if s["trace"] is not None:
            out.setdefault(s["trace"], []).append(s)
    for v in out.values():
        v.sort(key=lambda s: s["t0"])
    return out


def _root(spans: list[dict]) -> dict:
    """The request's root span: a parentless fleet_req/req if present,
    else the earliest span."""
    roots = [s for s in spans if s["parent"] is None
             and s["name"] in ("fleet_req", "req")]
    if roots:
        return min(roots, key=lambda s: s["t0"])
    return min(spans, key=lambda s: s["t0"])


def segments(spans: list[dict], root: dict | None = None) -> list[dict]:
    """Decompose a request's root span into non-overlapping labelled
    segments (children in t0 order, ``(gap)`` fillers between them).
    The segment durations sum to the root duration EXACTLY — gaps make
    unattributed time explicit instead of silently absorbing it."""
    root = _root(spans) if root is None else root
    end = root["t1"] if root["t1"] is not None else \
        max((s["t1"] for s in spans if s["t1"] is not None),
            default=root["t0"])
    segs: list[dict] = []
    cur = root["t0"]

    def _push(name, a, b, span=None):
        if b > a:
            segs.append({"name": name, "t0": a, "t1": b, "dur": b - a,
                         "pid": None if span is None else span["pid"]})

    for s in sorted(spans, key=lambda s: s["t0"]):
        if s is root or s["t0"] >= end:
            continue
        s1 = min(s["t1"] if s["t1"] is not None else end, end)
        if s["t0"] > cur:
            _push("(gap)", cur, s["t0"])
        # overlapping children (e.g. a step span crossing a decode) are
        # clipped to the uncovered remainder so the sum stays exact
        _push(s["name"], max(s["t0"], cur), max(s1, cur), s)
        cur = max(cur, s1)
    _push("(gap)", cur, end)
    return segs


def timeline_text(trace, spans: list[dict]) -> str:
    root = _root(spans)
    e2e = (root["dur"] if root["dur"] is not None
           else sum(s["dur"] for s in segments(spans, root)))
    lines = [f"trace {trace}: {root['name']} on {root['pid']} "
             f"{'%.3f ms' % (e2e * 1e3)}"
             f"{' (OPEN)' if root['t1'] is None else ''} "
             f"{root['attrs']}"]
    for seg in segments(spans, root):
        off = (seg["t0"] - root["t0"]) * 1e3
        lane = f" [{seg['pid']}]" if seg["pid"] else ""
        lines.append(f"  +{off:9.3f} ms  {seg['name']:<12} "
                     f"{seg['dur']*1e3:9.3f} ms{lane}")
    total = sum(s["dur"] for s in segments(spans, root))
    lines.append(f"  {'segments sum':>25} {total*1e3:9.3f} ms")
    return "\n".join(lines)


def latency_table(spans: list[dict]) -> str:
    hists: dict[str, Histogram] = {}
    for s in spans:
        if s["dur"] is not None:
            hists.setdefault(s["name"], Histogram()).observe(s["dur"])
    rows = [f"{'span':<16} {'n':>6} {'mean ms':>9} {'p50 ms':>9} "
            f"{'p95 ms':>9} {'max ms':>9}"]
    for name in sorted(hists):
        h = hists[name]
        rows.append(f"{name:<16} {h.n:>6} {h.mean*1e3:>9.3f} "
                    f"{h.quantile(0.5)*1e3:>9.3f} "
                    f"{h.quantile(0.95)*1e3:>9.3f} {h.max*1e3:>9.3f}")
    return "\n".join(rows)


def gantt(spans: list[dict], width: int = 64) -> str:
    """One row per request root span against the shared clock."""
    groups = by_trace(spans)
    if not groups:
        return "(no request spans)"
    roots = {t: _root(g) for t, g in groups.items()}
    t_lo = min(r["t0"] for r in roots.values())
    t_hi = max((r["t1"] if r["t1"] is not None else r["t0"])
               for r in roots.values())
    span_s = max(t_hi - t_lo, 1e-9)
    rows = [f"fleet gantt ({span_s*1e3:.1f} ms window, {len(roots)} "
            f"requests)"]
    for t in sorted(roots, key=lambda t: roots[t]["t0"]):
        r = roots[t]
        a = int((r["t0"] - t_lo) / span_s * (width - 1))
        b = a if r["t1"] is None else \
            int((r["t1"] - t_lo) / span_s * (width - 1))
        bar = " " * a + "#" * max(1, b - a + 1)
        state = r["attrs"].get("state", "OPEN" if r["t1"] is None else "?")
        rows.append(f"  {str(t):>4} |{bar:<{width}}| {state}")
    return "\n".join(rows)


def perfetto(closed: list[dict], open_spans: list[dict]) -> dict:
    """Chrome trace-event JSON (load in ui.perfetto.dev or
    chrome://tracing).  One process lane per tracer pid; ts/dur in µs,
    normalized to the earliest span."""
    all_spans = closed + open_spans
    if not all_spans:
        return {"traceEvents": []}
    t_lo = min(s["t0"] for s in all_spans)
    pids = {p: i + 1 for i, p in
            enumerate(sorted({s["pid"] for s in all_spans}))}
    ev = [{"ph": "M", "name": "process_name", "pid": n, "tid": 0,
           "args": {"name": p}} for p, n in pids.items()]
    for s in all_spans:
        args = {"trace": s["trace"], "sid": s["sid"], **s["attrs"]}
        base = {"name": s["name"], "pid": pids[s["pid"]],
                "tid": 0 if s["trace"] is None else int(s["trace"]),
                "ts": (s["t0"] - t_lo) * 1e6, "cat": "repro",
                "args": args}
        if s["t1"] is None:
            ev.append({**base, "ph": "B"})      # left open by a crash
        else:
            ev.append({**base, "ph": "X", "dur": s["dur"] * 1e6})
    return {"traceEvents": ev, "displayTimeUnit": "ms"}


def main() -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("events", help="JSONL event file (--events output)")
    ap.add_argument("--trace", default=None,
                    help="render one request's timeline (gid/rid)")
    ap.add_argument("--table", action="store_true",
                    help="latency breakdown per span name")
    ap.add_argument("--gantt", action="store_true",
                    help="one-row-per-request fleet Gantt")
    ap.add_argument("--json", default=None, metavar="OUT",
                    help="write Chrome/Perfetto trace JSON")
    args = ap.parse_args()

    closed, open_spans = load_spans(args.events)
    groups = by_trace(closed + open_spans)
    print(f"{args.events}: {len(closed)} spans "
          f"({len(open_spans)} left open), {len(groups)} traces")
    if args.trace is not None:
        key = int(args.trace) if args.trace.lstrip("-").isdigit() \
            else args.trace
        if key not in groups:
            print(f"no spans for trace {key!r} "
                  f"(have {sorted(groups)[:16]})")
            return 1
        print(timeline_text(key, groups[key]))
    if args.gantt:
        print(gantt(closed + open_spans))
    if args.table:
        print(latency_table(closed))
    if args.json:
        with open(args.json, "w") as f:
            json.dump(perfetto(closed, open_spans), f, indent=1,
                      sort_keys=True)
        print(f"wrote {args.json} "
              f"({len(closed) + len(open_spans)} events)")
    if not (args.trace or args.gantt or args.table or args.json):
        print(latency_table(closed))
    return 0


if __name__ == "__main__":
    raise SystemExit(main())
