#!/usr/bin/env python
"""Failure-count ratchet for the tier-1 suite.

Parses a pytest junit XML report and fails the build when the suite does
worse than the committed baseline.  The baseline below locks in the current
tree's state; the seed repo was 7 failed / 106 passed with 2 modules
uncollectable without hypothesis — only ever move these numbers in the
good direction.

Usage: python tools/ci_ratchet.py report.xml [--max-failed N] [--min-passed M]
"""
from __future__ import annotations

import argparse
import sys
import xml.etree.ElementTree as ET

# Ratchet baseline (update when the suite legitimately improves/grows).
# Seed repo: 7 failed / 106 passed; PR 1: 0 failed / 160 passed;
# PR 2 (trainable flash attention: kernel-gradient + planner-residual
# tests): 0 failed / 185 passed; PR 3 (sparse flash grids: tile-bound
# sweep, counter-vs-analytic, skip-ratio acceptance, resid policy, kvq
# no-bias): 0 failed / 239 passed; PR 4 (split-K int8 flash decode:
# ragged-length parity, split/merge oracle, decode counters, skip-ratio
# floor, no-bias jaxprs, planner decode reports, serve CLI): 0 failed /
# 275 passed; PR 5 (continuous-batching serve engine: slot pool
# alloc/free + scatter, scheduler admission, token-exact parity vs
# isolated decode across staggered joins/retirements, zero-recompile
# counters, slot-leak drain, sampler, capacity report, trace driver):
# 0 failed / 304 passed.
MAX_FAILED = 0
MIN_PASSED = 304


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("report")
    ap.add_argument("--max-failed", type=int, default=MAX_FAILED)
    ap.add_argument("--min-passed", type=int, default=MIN_PASSED)
    args = ap.parse_args()

    root = ET.parse(args.report).getroot()
    suites = root.iter("testsuite")
    tests = failures = errors = skipped = 0
    for s in suites:
        tests += int(s.get("tests", 0))
        failures += int(s.get("failures", 0))
        errors += int(s.get("errors", 0))
        skipped += int(s.get("skipped", 0))
    failed = failures + errors
    passed = tests - failed - skipped
    print(f"tier-1: {passed} passed, {failed} failed/errored, "
          f"{skipped} skipped (ratchet: <= {args.max_failed} failed, "
          f">= {args.min_passed} passed)")
    if failed > args.max_failed:
        print(f"RATCHET VIOLATION: {failed} > {args.max_failed} failures")
        return 1
    if passed < args.min_passed:
        print(f"RATCHET VIOLATION: {passed} < {args.min_passed} passes "
              f"(tests deleted or newly skipped?)")
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
