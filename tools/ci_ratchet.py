#!/usr/bin/env python
"""Failure-count + benchmark ratchet for the tier-1 suite.

Parses a pytest junit XML report and fails the build when the suite does
worse than the committed baseline.  The baseline below locks in the current
tree's state; the seed repo was 7 failed / 106 passed with 2 modules
uncollectable without hypothesis — only ever move these numbers in the
good direction.

With ``--bench-dir``, also ratchets the committed BENCH_*.json results:
serve-engine throughput speedup, the flash/decode kernels' tile-skip
fractions, and the mesh-sharding parity/capacity flags.  A perf
optimization that quietly re-densifies a kernel grid or melts engine
throughput then fails CI even though every correctness test still passes.

Usage: python tools/ci_ratchet.py report.xml [--max-failed N]
           [--min-passed M] [--bench-dir DIR]
"""
from __future__ import annotations

import argparse
import json
import os
import sys
import xml.etree.ElementTree as ET

# Ratchet baseline (update when the suite legitimately improves/grows).
# Seed repo: 7 failed / 106 passed; PR 1: 0 failed / 160 passed;
# PR 2 (trainable flash attention: kernel-gradient + planner-residual
# tests): 0 failed / 185 passed; PR 3 (sparse flash grids: tile-bound
# sweep, counter-vs-analytic, skip-ratio acceptance, resid policy, kvq
# no-bias): 0 failed / 239 passed; PR 4 (split-K int8 flash decode:
# ragged-length parity, split/merge oracle, decode counters, skip-ratio
# floor, no-bias jaxprs, planner decode reports, serve CLI): 0 failed /
# 275 passed; PR 5 (continuous-batching serve engine: slot pool
# alloc/free + scatter, scheduler admission, token-exact parity vs
# isolated decode across staggered joins/retirements, zero-recompile
# counters, slot-leak drain, sampler, capacity report, trace driver):
# 0 failed / 304 passed; PR 6 (mesh-parallel hot paths: rule tables on
# 1/2/8-device meshes, 8-device flash train grad parity, token-exact
# mesh serving heads+seq with no-all-gather HLO assertion, int8 decode
# collective vs oracle, compressed psum-grad parity/unbiasedness,
# per-device planner budgets): 0 failed / 420 passed on one device;
# PR 7 (fault tolerance: scheduler terminal states + bounded queue +
# deadlines, decode health sentinel + quarantine/replay under seeded
# fault injection, train guards with NaN-skip + rollback, checkpoint
# fingerprint/config identity + conflicting-resave rejection):
# 0 failed / 451 passed on one device — the 8-device CI grid unskips 8
# more (7 mesh + the cross-mesh checkpoint round-trip); the lock stays
# at the 1-device floor so the suite passes anywhere.
MAX_FAILED = 0
# PR 9 (durable serving: write-ahead request journal append/snapshot/
# torn-tail + crash-at-every-append harness, subprocess worker RPC +
# SIGKILL failover, whole-router kill -9 recovery token-exact with one
# terminal per journaled SUBMIT, watchdog race regression): 0 failed /
# 531 passed on the CI 8-device grid (523 pass on one device; the same
# 8 mesh/checkpoint tests as before skip without the emulated grid).
# PR 10 (observability: metrics registry + merge laws, span tracing
# with crash-visible open spans + fleet crash/recovery timeline
# acceptance, tracelens, memstat, event-schema closed world,
# fleet_summary/read_events edge cases): 0 failed / 559 passed on the
# CI grid (551 on one device).
MIN_PASSED = 559

# Benchmark floors (path into the committed BENCH json, minimum value or
# required flag).  Floors sit safely under the committed results so normal
# run-to-run noise passes, but a structural regression (a kernel grid
# re-densifying, the engine losing its continuous-batching win, mesh
# sharding losing parity) trips them.
BENCH_FLOORS = [
    # serve engine: continuous batching must keep a real throughput win
    # over lockstep.  PR 7 re-based 1.55x -> 1.1 (single-shot timing had
    # charged lockstep its cold start); PR 8 re-based again to 1.0: both
    # walls are ~50 ms on CPU and lockstep's is bimodal ACROSS processes
    # (observed 1.03x-1.34x over repeated interleaved best-of-5 runs), so
    # any floor above parity flakes on regeneration.  The structural win
    # is ratcheted deterministically below via slot_step_efficiency
    # (useful tokens per executed slot-step on the seeded trace, arrival
    # gaps included: engine 0.764 vs lockstep's 0.57 — no wall clock
    # involved, exact on the seeded trace).
    ("BENCH_serve.json", ("speedup_tokens_per_s",), 1.0),
    ("BENCH_serve.json", ("continuous", "slot_step_efficiency"), 0.75),
    # fault tolerance (ISSUE 7): under the canonical seeded fault plan
    # (NaN logits + corrupt cache row + dropped scatter) the engine must
    # recover every victim (no slot leaks, every retry reaches DONE) and
    # keep real goodput (committed: 4116 tok/s, 0.78x fault-free)
    ("BENCH_serve.json", ("fault_trace", "zero_slot_leaks"), True),
    ("BENCH_serve.json", ("fault_trace", "retry_success_rate"), 0.99),
    ("BENCH_serve.json", ("fault_trace", "goodput_tokens_per_s"), 3000),
    ("BENCH_serve.json", ("fault_trace", "goodput_frac_of_fault_free"),
     0.55),
    # replica fleet (ISSUE 8): under the canonical seeded replica-kill
    # (2 replicas, replica 1 crashed at router step 4) every migrated
    # request must replay to DONE on the survivor, neither pool may leak,
    # and fleet goodput must hold at least half the fault-free fleet's
    # (committed: replay 1.0, ratio ~1.0 — the survivor's steps cost less
    # than stepping two engines on CPU)
    ("BENCH_serve.json", ("fleet", "replica_kill", "zero_slot_leaks"),
     True),
    ("BENCH_serve.json",
     ("fleet", "replica_kill", "failover_replay_success"), 0.99),
    ("BENCH_serve.json",
     ("fleet", "replica_kill", "goodput_frac_of_fault_free"), 0.5),
    # durable serving (ISSUE 9): the canonical seeded router-crash run
    # (kill -9 after 12 router steps, fresh router recovers from the
    # write-ahead journal) must finish every recovered request
    # (committed: replay 1.0, one terminal per journaled SUBMIT, zero
    # leaks), and the fsync'd journal — group commit flush_every=16,
    # token cadence 4 — must keep >= 0.8 of unjournaled fleet goodput
    # on the interleaved min-of-3 comparison (committed: ~0.9)
    ("BENCH_serve.json", ("recovery", "recovery_replay_success"), 0.99),
    ("BENCH_serve.json",
     ("recovery", "journaled_goodput_frac_of_unjournaled"), 0.8),
    ("BENCH_serve.json",
     ("recovery", "router_crash", "one_terminal_per_submit"), True),
    ("BENCH_serve.json",
     ("recovery", "router_crash", "zero_slot_leaks"), True),
    # split-K int8 decode: ragged-batch tile claw-back (committed: 0.75)
    ("BENCH_decode.json", ("tile_clawback_s2048_ragged", "skip_frac"), 0.70),
    # sparse flash grids (committed: 0.47 causal, 0.82 windowed)
    ("BENCH_flash.json", ("flop_clawback_s2048", "tile_skip_frac"), 0.45),
    ("BENCH_flash.json", ("sparsity", "causal_s2048", "skipped_frac"), 0.45),
    ("BENCH_flash.json", ("sparsity", "window256_s2048", "skipped_frac"),
     0.80),
    # mesh sharding: single-device parity and per-device capacity scaling
    ("BENCH_shard.json", ("train", "parity"), True),
    ("BENCH_shard.json", ("serve", "token_parity"), True),
    ("BENCH_shard.json", ("capacity", "slots_times_devices_ge_single"),
     True),
    # observability (ISSUE 10): the tracing overhead contract.  All span
    # instrumentation is host-side and guarded on ``tracer is not None``,
    # so a traced run must keep >= 0.95x untraced tokens/s (median of 7
    # interleaved pairs — per-pair walls swing +-10% with CPU scheduler
    # noise, the median sits at the true ~1-3% cost) with a frozen jit
    # cache, and every DONE request must reconstruct to exactly one
    # complete submit -> terminal span chain whose segments sum to the
    # end-to-end latency
    ("BENCH_obs.json", ("overhead", "tokens_per_s_ratio"), 0.95),
    ("BENCH_obs.json", ("overhead", "compile_counts_frozen"), True),
    ("BENCH_obs.json", ("reconcile", "done_span_chains_complete"), True),
    ("BENCH_obs.json", ("reconcile", "segments_sum_to_e2e"), True),
]


def check_event_schema(repo_root: str) -> int:
    """Closed-world event schema: every ``sink.emit("kind", ...)`` call
    site under src/ must name a kind declared in ``repro.obs.schema``.
    An undeclared kind means a producer was added without extending the
    schema — tracelens and downstream consumers would silently drop it."""
    sys.path.insert(0, os.path.join(repo_root, "src"))
    try:
        from repro.obs.schema import undeclared_kinds_in_source
    except ImportError as e:
        print(f"SCHEMA CHECK SKIPPED: repro.obs unimportable ({e})")
        return 1
    undeclared = undeclared_kinds_in_source(os.path.join(repo_root, "src"))
    if undeclared:
        for kind, sites in sorted(undeclared.items()):
            print(f"SCHEMA VIOLATION: event kind {kind!r} emitted at "
                  f"{sites} but not declared in repro/obs/schema.py")
        return len(undeclared)
    print("schema: every emitted event kind is declared in "
          "repro/obs/schema.py")
    return 0


def check_bench(bench_dir: str) -> int:
    bad = 0
    for fname, path, floor in BENCH_FLOORS:
        fpath = os.path.join(bench_dir, fname)
        label = f"{fname}:{'.'.join(path)}"
        try:
            with open(fpath) as f:
                val = json.load(f)
            for key in path:
                val = val[key]
        except (OSError, KeyError, TypeError) as e:
            print(f"BENCH RATCHET VIOLATION: {label} unreadable ({e})")
            bad += 1
            continue
        if floor is True:
            ok = val is True
            print(f"bench: {label} = {val} (required: true)"
                  + ("" if ok else "  <-- VIOLATION"))
        else:
            ok = isinstance(val, (int, float)) and val >= floor
            print(f"bench: {label} = {val} (floor: {floor})"
                  + ("" if ok else "  <-- VIOLATION"))
        bad += 0 if ok else 1
    return bad


def main() -> int:
    ap = argparse.ArgumentParser()
    ap.add_argument("report")
    ap.add_argument("--max-failed", type=int, default=MAX_FAILED)
    ap.add_argument("--min-passed", type=int, default=MIN_PASSED)
    ap.add_argument("--bench-dir", default=None,
                    help="also ratchet the committed BENCH_*.json results "
                         "in this directory")
    args = ap.parse_args()

    root = ET.parse(args.report).getroot()
    suites = root.iter("testsuite")
    tests = failures = errors = skipped = 0
    for s in suites:
        tests += int(s.get("tests", 0))
        failures += int(s.get("failures", 0))
        errors += int(s.get("errors", 0))
        skipped += int(s.get("skipped", 0))
    failed = failures + errors
    passed = tests - failed - skipped
    print(f"tier-1: {passed} passed, {failed} failed/errored, "
          f"{skipped} skipped (ratchet: <= {args.max_failed} failed, "
          f">= {args.min_passed} passed)")
    if failed > args.max_failed:
        print(f"RATCHET VIOLATION: {failed} > {args.max_failed} failures")
        return 1
    if passed < args.min_passed:
        print(f"RATCHET VIOLATION: {passed} < {args.min_passed} passes "
              f"(tests deleted or newly skipped?)")
        return 1
    if args.bench_dir is not None:
        if check_bench(args.bench_dir):
            return 1
        if check_event_schema(args.bench_dir):
            return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
