"""Format dry-run JSON into the EXPERIMENTS.md roofline tables.

    PYTHONPATH=src python -m benchmarks.roofline_report dryrun_singlepod.json

Definitions:
  ideal_s        = MODEL_FLOPS / (chips x 197 TF/s)  (6ND train, 2ND infer)
  roofline frac  = ideal_s / max(compute_s, memory_s, collective_s)
                   -> "how close the dominant roofline term is to the
                   model-FLOP ideal"; 1.0 = perfectly compute-bound with
                   zero overhead.
  collective_s is clamped to the raw full-compile parse when the L-probe
  extrapolation is unstable (SPMD can make different sharding choices at
  L=1 vs L=2; a negative delta means the probe disagreed).
"""
from __future__ import annotations

import json
import sys

PEAK = 197e12
ICI = 50e9


def _chips(mesh_str: str) -> int:
    n = 1
    for part in mesh_str.split(" x "):
        n *= int(part.split("=")[1])
    return n


def load(path):
    with open(path) as f:
        d = json.load(f)
    return d.get("results", []), d.get("failures", [])


def enrich(r):
    n_chips = _chips(r["mesh"])
    ideal = r["model_flops"] / (n_chips * PEAK)
    coll = r["collective_s"]
    raw_coll = r.get("raw_uncorrected", {}).get("coll", 0) / ICI
    if coll < raw_coll:          # unstable extrapolation -> raw lower bound
        coll = raw_coll
    terms = {"compute_s": r["compute_s"], "memory_s": r["memory_s"],
             "collective_s": coll}
    dom = max(terms, key=terms.get)
    frac = ideal / max(terms.values()) if max(terms.values()) > 0 else 0.0
    return {**r, "collective_s": coll, "ideal_s": ideal,
            "bottleneck": dom, "roofline_frac": frac}


def table(results):
    hdr = ("| arch | shape | ideal ms | compute ms | memory(lb) ms | "
           "collective ms | bottleneck | useful-FLOP | roofline frac |")
    sep = "|" + "---|" * 9
    rows = [hdr, sep]
    for r in sorted(results, key=lambda r: (r["arch"], r["shape"])):
        rows.append(
            f"| {r['arch']} | {r['shape']} | {r['ideal_s']*1e3:.2f} | "
            f"{r['compute_s']*1e3:.2f} | {r['memory_s']*1e3:.2f} | "
            f"{r['collective_s']*1e3:.2f} | "
            f"{r['bottleneck'].replace('_s','')} | "
            f"{r['useful_flops_frac']:.2f} | {r['roofline_frac']:.3f} |")
    return "\n".join(rows)


def main():
    for path in sys.argv[1:]:
        results, failures = load(path)
        results = [enrich(r) for r in results]
        if results:
            print(f"\n## {path} ({results[0]['mesh']})\n")
        print(table(results))
        print(f"\ncells OK: {len(results)}, failed: {len(failures)}")
        for f in failures:
            print(f"  FAIL {f['arch']} x {f['shape']}: {f['error'][:100]}")
        if results:
            worst = min(results, key=lambda r: r["roofline_frac"])
            coll_bound = max(results, key=lambda r: r["collective_s"])
            print(f"\nworst roofline frac : {worst['arch']} x {worst['shape']}"
                  f" ({worst['roofline_frac']:.4f})")
            print(f"most collective-bound: {coll_bound['arch']} x "
                  f"{coll_bound['shape']} ({coll_bound['collective_s']*1e3:.0f} ms)")


if __name__ == "__main__":
    main()
