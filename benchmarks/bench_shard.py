"""Mesh-sharding benchmark (ISSUE 6 acceptance): single-device parity of
the sharded hot paths plus per-device capacity scaling, on 8 emulated host
devices.  Writes BENCH_shard.json.

Standalone on purpose: the device count is frozen the moment jax
initializes its backend, so the 8-device grid must be requested via
XLA_FLAGS *before* ``import jax`` — ``benchmarks/run.py`` invokes this
file as a subprocess for exactly that reason.

  * train: flash train grads on a (4, 2) data x model mesh vs (1, 1) —
    the shard_map'd flash custom_vjp under remat + scan + grad must match
    to <= 1e-3 (losses to 1e-4);
  * serve: the continuous-batching engine on a (1, 8) mesh (kv-heads
    sharded over "model") vs the unsharded engine on the same trace —
    token streams must be EXACT, tokens/s recorded for both;
  * capacity: ``plan.serve_capacity_report`` under a per-chip budget —
    per-device slot capacity x devices must admit at least the
    single-device capacity (sharding the cache never loses slots).
"""
from __future__ import annotations

import os

_FLAG = "--xla_force_host_platform_device_count=8"
if _FLAG not in os.environ.get("XLA_FLAGS", ""):
    os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                               + " " + _FLAG).strip()
os.environ.setdefault("JAX_PLATFORMS", "cpu")

import dataclasses
import json
import sys
import time

import jax
import jax.numpy as jnp
import numpy as np

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro import configs, plan as plan_mod
from repro.core.mixed_precision import get_policy
from repro.distributed import sharding as shd
from repro.launch.mesh import describe, make_mesh
from repro.models import transformer
from repro.serve import ServeEngine
from repro.serve.trace import TraceRequest
from repro.train import train_step as ts


def bench_train() -> dict:
    cfg = dataclasses.replace(configs.smoke_config("llama3-8b"),
                              attn_backend="interpret")
    b, s = 8, 64
    rng = np.random.default_rng(0)
    batch = {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)),
                                   jnp.int32),
             "labels": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)),
                                   jnp.int32)}
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    tc = ts.TrainConfig(policy="full")
    pol = get_policy("full")

    def grads_for(mesh):
        def loss(p, mb):
            return transformer.loss_fn(p, cfg, mb, policy=pol,
                                       remat=tc.remat, mesh=mesh)[0]
        p_shard = shd.to_shardings(mesh,
                                   shd.param_specs(cfg, params, mesh=mesh))
        b_shard = shd.to_shardings(mesh, shd.batch_specs(cfg, batch, mesh))
        pp = jax.device_put(params, p_shard)
        bb = jax.device_put(batch, b_shard)
        fn = jax.jit(jax.value_and_grad(loss),
                     in_shardings=(p_shard, b_shard))
        l, g = jax.block_until_ready(fn(pp, bb))
        t0 = time.perf_counter()
        jax.block_until_ready(fn(pp, bb))
        return float(l), jax.device_get(g), time.perf_counter() - t0

    mesh1 = make_mesh((1, 1), ("data", "model"))
    mesh8 = make_mesh((4, 2), ("data", "model"))
    l1, g1, t1 = grads_for(mesh1)
    l8, g8, t8 = grads_for(mesh8)
    diffs = jax.tree_util.tree_map(
        lambda a, b_: float(np.abs(a - b_).max()), g1, g8)
    max_diff = max(jax.tree_util.tree_leaves(diffs))
    loss_diff = abs(l1 - l8)
    parity = loss_diff < 1e-4 and max_diff < 1e-3
    print(f"train: mesh {describe(mesh8)} loss_diff={loss_diff:.2e} "
          f"max_grad_diff={max_diff:.2e} parity={parity}", flush=True)
    return {"mesh": describe(mesh8), "batch": b, "seq": s,
            "loss_diff": loss_diff, "max_grad_diff": max_diff,
            "step_s_single": round(t1, 3), "step_s_mesh": round(t8, 3),
            "parity": parity}


def bench_serve() -> dict:
    # n_kv=8 divides model=8: the natural kv-heads shard
    cfg = dataclasses.replace(configs.smoke_config("llama3-8b"),
                              n_heads=8, n_kv=8, window=0)
    mesh = make_mesh((1, 8), ("data", "model"))
    rng = np.random.default_rng(0)
    lens = [(5, 0), (9, 0), (13, 2), (3, 4), (7, 5), (11, 6), (6, 8),
            (14, 9)]
    trace = [TraceRequest(prompt=list(rng.integers(1, 200, (pl,))),
                          max_new_tokens=8, arrival_step=st)
             for pl, st in lens]
    useful = sum(r.max_new_tokens for r in trace)
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))

    def run(m):
        eng = ServeEngine(params, cfg, max_slots=4, max_len=64,
                          prompt_buckets=(8, 16), policy_name="full",
                          mesh=m)
        compiles = eng.warmup()
        t0 = time.perf_counter()
        eng.run(list(trace))
        wall = time.perf_counter() - t0
        assert eng.compile_counts() == compiles, "re-jit mid-trace"
        return {r.rid: list(r.tokens) for r in eng._requests_done}, wall

    t_single, w_single = run(None)
    t_mesh, w_mesh = run(mesh)
    parity = t_single == t_mesh
    kv_mode = shd.serve_kv_shard(mesh, cfg.n_kv, 64)
    print(f"serve: mesh {describe(mesh)} kv_shard={kv_mode} "
          f"token_parity={parity} tok/s single={useful/w_single:.0f} "
          f"mesh={useful/w_mesh:.0f}", flush=True)
    return {"mesh": describe(mesh), "kv_shard": kv_mode,
            "requests": len(trace), "useful_tokens": useful,
            "token_parity": parity,
            "tokens_per_s_single": round(useful / w_single, 1),
            "tokens_per_s_mesh": round(useful / w_mesh, 1)}


def bench_capacity() -> dict:
    cfg = configs.get_config("llama3-8b")
    mesh = make_mesh((1, 8), ("data", "model"))
    budget = 8 * 2 ** 30                       # 8 GiB per chip
    r1 = plan_mod.serve_capacity_report(cfg, 4096, budget)
    r8 = plan_mod.serve_capacity_report(cfg, 4096, budget, mesh=mesh)
    scales = r8["max_slots"] * 1 >= r1["max_slots"] and \
        r8["bytes_per_slot_per_device"] * r8["model_shards"] >= \
        r8["bytes_per_slot"]
    print(f"capacity: {r1['max_slots']} slots/chip unsharded -> "
          f"{r8['max_slots']} slots at "
          f"{r8['bytes_per_slot_per_device']/2**20:.1f} MiB/slot/device "
          f"({r8['kv_shard']} over {r8['model_shards']} shards)",
          flush=True)
    return {"s_max": 4096, "budget_gib_per_device": 8,
            "kv_shard": r8["kv_shard"], "devices": r8["devices"],
            "model_shards": r8["model_shards"],
            "bytes_per_slot": r1["bytes_per_slot"],
            "bytes_per_slot_per_device": r8["bytes_per_slot_per_device"],
            "max_slots_single": r1["max_slots"],
            "max_slots_per_device_budget": r8["max_slots"],
            "slots_times_devices_ge_single": scales}


def main() -> int:
    assert len(jax.devices()) >= 8, \
        f"need 8 emulated devices, got {len(jax.devices())}"
    out = {"devices": len(jax.devices()),
           "train": bench_train(),
           "serve": bench_serve(),
           "capacity": bench_capacity()}
    path = os.path.join(os.path.dirname(__file__), "..", "BENCH_shard.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1, sort_keys=True)
    print(f"# wrote {os.path.normpath(path)}", flush=True)
    ok = out["train"]["parity"] and out["serve"]["token_parity"] and \
        out["capacity"]["slots_times_devices_ge_single"]
    return 0 if ok else 1


if __name__ == "__main__":
    raise SystemExit(main())
