"""Benchmark harness — one benchmark per paper table/figure.

  fig8_memory     GPU-memory-in-1-iteration analogue (paper Fig. 8):
                  compiled temp bytes for ResNet-18, standard vs S-C.
  fig9_time_acc   time+accuracy parity for 10-epoch CIFAR runs (paper
                  Fig. 9), reduced to CPU scale: baseline vs E-D vs S-C
                  vs E-D+S-C vs +M-P on synthetic CIFAR.
  fig10_pipelines memory across pipelines B / E-D / M-P / S-C /
                  S-C + M-P for ResNet and an LM (paper Fig. 10).
  tbl_codec       encode/decode throughput + compression ratios for
                  Algorithms 1/3/4 and the u32 codec (paper II.A claims:
                  16x passage saving, >=20% time saving).
  tbl_pipeline    parallel E-D loader: epoch time with/without the
                  background encode thread (paper Fig. 1).
  tbl_compression gradient-compression payload bytes vs fp32 (framework
                  distributed-optimization feature).
  plan_vs_uniform profile-driven RematPlan vs uniform even-split remat at
                  the same checkpoint count (repro.plan acceptance table;
                  writes BENCH_plan.json).
  flash_fwd_bwd   trainable flash attention: fwd / fwd+bwd residual bytes
                  (pallas custom_vjp vs jnp S^2 path) across S, and wall
                  time in interpret mode (writes BENCH_flash.json).
  flash_decode    split-K int8 KV decode: sequential vs split-K wall time
                  (interpret mode), dense-vs-visited tile claw-back on a
                  ragged S=2048 batch, and the planner's serve-side
                  reports (writes BENCH_decode.json).
  serve_trace     continuous batching vs the lockstep driver on the same
                  ragged request trace: useful tokens/s, TTFT (steps),
                  slot occupancy and wasted slot-steps (writes
                  BENCH_serve.json).
  mesh_shard      sharded hot paths on 8 emulated devices: flash train
                  grads and engine token streams vs single device, plus
                  per-device slot capacity (subprocess — the device grid
                  must be set before jax initializes; writes
                  BENCH_shard.json).
  obs_overhead    tracing overhead contract (ISSUE 10): the same seeded
                  trace traced vs untraced, interleaved best-of-5 —
                  tokens/s ratio, frozen compile counts, and span
                  reconciliation (every DONE request has exactly one
                  complete submit->terminal span chain; writes
                  BENCH_obs.json).

Prints ``name,us_per_call,derived`` CSV rows (plus derived metrics).
"""
from __future__ import annotations

import time

import jax
import jax.numpy as jnp
import numpy as np


def _rows(name, us, derived=""):
    print(f"{name},{us:.1f},{derived}", flush=True)


def _timeit(fn, *args, warmup=1, iters=3):
    for _ in range(warmup):
        jax.block_until_ready(fn(*args))
    t0 = time.perf_counter()
    for _ in range(iters):
        out = jax.block_until_ready(fn(*args))
    return (time.perf_counter() - t0) / iters * 1e6, out


def _temp_bytes(fn, *sds):
    c = jax.jit(fn).lower(*sds).compile()
    m = c.memory_analysis()
    return int(getattr(m, "temp_size_in_bytes", 0))


def _residual_mb(loss_of_params, params, *rest):
    """Bytes saved between forward and backward (the paper's 'extra memory
    to back-propagate'): size of the vjp residual pytree, via eval_shape
    (no allocation).  Unlike XLA temp bytes on CPU, this directly reflects
    what S-C changes."""
    out = jax.eval_shape(
        lambda p, *r: jax.vjp(lambda pp: loss_of_params(pp, *r), p),
        params, *rest)
    leaves = jax.tree_util.tree_leaves(out)
    return sum(x.size * x.dtype.itemsize for x in leaves) / 2 ** 20


# ---------------------------------------------------------------------------
def fig8_memory():
    """ResNet-18 activation memory, standard vs sequential checkpoints."""
    from repro.core.checkpoint import CheckpointConfig
    from repro.models import cnn
    from repro.plan import RematPlan
    cfg = cnn.resnet18(stem_stride=2)
    params = cnn.init_params(cfg, jax.random.PRNGKey(0))
    imgs = jax.ShapeDtypeStruct((16, 512, 512, 3), jnp.float32)
    labels = jax.ShapeDtypeStruct((16,), jnp.int32)
    n = cnn.num_layer_fns(cfg)

    for name, seg in [("fig8_resnet18_standard", 0),
                      ("fig8_resnet18_sc2", 2),
                      ("fig8_resnet18_sc4", 4),
                      ("fig8_resnet18_sc8", 8)]:
        remat = CheckpointConfig(plan=RematPlan.uniform(n, seg)) if seg \
            else None
        def loss(p, im, lb, _r=remat):
            return cnn.loss_fn(p, cfg, im, lb, remat=_r)[0]
        mb = _residual_mb(loss, params, imgs, labels)
        _rows(name, 0.0, f"residual_mb={mb:.0f}")


def fig10_pipelines():
    """Memory across optimization pipelines for ResNet-50 and a small LM."""
    from repro.models import cnn
    from repro import configs
    from repro.models import transformer
    from repro.core.checkpoint import CheckpointConfig
    from repro.core.mixed_precision import get_policy

    from repro.plan import RematPlan
    cfg = cnn.resnet50(stem_stride=2)
    params = cnn.init_params(cfg, jax.random.PRNGKey(0))
    imgs_f = jax.ShapeDtypeStruct((16, 512, 512, 3), jnp.float32)
    imgs_p = jax.ShapeDtypeStruct((4, 512, 512, 3), jnp.uint32)
    labels = jax.ShapeDtypeStruct((16,), jnp.int32)
    sc8 = CheckpointConfig(plan=RematPlan.uniform(cnn.num_layer_fns(cfg), 8))

    cases = [
        ("fig10_resnet50_B", dict(remat=None), imgs_f),
        ("fig10_resnet50_ED", dict(remat=None, decode_backend="ref"),
         imgs_p),
        ("fig10_resnet50_SC", dict(remat=sc8), imgs_f),
        ("fig10_resnet50_ED_SC", dict(remat=sc8, decode_backend="ref"),
         imgs_p),
    ]
    for name, kw, im_sds in cases:
        def loss(p, im, lb, _kw=kw):
            return cnn.loss_fn(p, cfg, im, lb, **_kw)[0]
        mb = _residual_mb(loss, params, im_sds, labels)
        # E-D also cuts the host->device stream 4x (u32 vs f32 input bytes)
        inp_mb = np.prod(im_sds.shape) * im_sds.dtype.itemsize / 2 ** 20
        _rows(name, 0.0, f"residual_mb={mb:.0f},input_mb={inp_mb:.0f}")

    # LM variant: remat on/off x M-P on/off (smoke-sized llama)
    lcfg = configs.smoke_config("llama3-8b")
    lp = transformer.init_params(lcfg, jax.random.PRNGKey(0))
    batch = {"tokens": jax.ShapeDtypeStruct((8, 256), jnp.int32),
             "labels": jax.ShapeDtypeStruct((8, 256), jnp.int32)}
    for name, remat, pol in [
            ("fig10_lm_B", False, "full"), ("fig10_lm_MP", False, "bf16"),
            ("fig10_lm_SC", True, "full"), ("fig10_lm_SC_MP", True, "bf16")]:
        def loss(p, b, _r=remat, _p=pol):
            return transformer.loss_fn(
                p, lcfg, b, policy=get_policy(_p),
                remat=CheckpointConfig(enabled=_r))[0]
        mb = _residual_mb(loss, lp, batch)
        _rows(name, 0.0, f"residual_mb={mb:.0f}")


def fig9_time_acc():
    """Accuracy/time parity across pipelines (reduced CIFAR run)."""
    from repro.data.synthetic import make_cifar_like
    from repro.data.pipeline import ParallelEncodedLoader
    from repro.models import cnn
    from repro.optim import adamw

    imgs, labels = make_cifar_like(n=1024, seed=0)
    cfg = cnn.resnet18()
    steps = 60

    def run(num_segments, codec, policy="full"):
        from repro.core.checkpoint import CheckpointConfig
        from repro.plan import RematPlan
        params = cnn.init_params(cfg, jax.random.PRNGKey(0))
        opt = adamw.init(params)
        ocfg = adamw.AdamWConfig(lr=3e-3, warmup_steps=5, total_steps=steps,
                                 weight_decay=0.0)
        remat = CheckpointConfig(plan=RematPlan.uniform(
            cnn.num_layer_fns(cfg), num_segments)) if num_segments else None

        @jax.jit
        def step(params, opt, im, lb):
            decode = "ref" if codec == "u32" else None

            def lossp(p):
                if policy == "bf16":
                    p = jax.tree_util.tree_map(
                        lambda x: x.astype(jnp.bfloat16)
                        if jnp.issubdtype(x.dtype, jnp.floating) else x, p)
                return cnn.loss_fn(p, cfg, im, lb, remat=remat,
                                   decode_backend=decode)

            (l, aux), g = jax.value_and_grad(lossp, has_aux=True)(params)
            g = jax.tree_util.tree_map(lambda x: x.astype(jnp.float32), g)
            params2, opt2, _ = adamw.update(ocfg, g, opt, params)
            return params2, opt2, l, aux["acc"]

        t0 = time.perf_counter()
        accs = []
        with ParallelEncodedLoader(imgs, labels, 32, codec=codec,
                                   prefetch=2) as dl:
            for _ in range(steps):
                enc, lb = next(dl)
                im = jnp.asarray(enc)
                params, opt, l, acc = step(params, opt, im, jnp.asarray(lb))
                accs.append(float(acc))
        dt = time.perf_counter() - t0
        return dt, float(np.mean(accs[-10:]))

    for name, seg, codec, pol in [
            ("fig9_baseline", 0, "none", "full"),
            ("fig9_ED", 0, "u32", "full"),
            ("fig9_SC", 6, "none", "full"),
            ("fig9_ED_SC", 6, "u32", "full"),
            ("fig9_ED_SC_MP", 6, "u32", "bf16")]:
        dt, acc = run(seg, codec, pol)
        _rows(name, dt * 1e6 / steps, f"acc={acc:.3f},total_s={dt:.1f}")


def plan_vs_uniform():
    """Profile-driven RematPlan vs uniform even-split remat at the same
    requested checkpoint count (acceptance benchmark for ``repro.plan``;
    paper Fig. 11 automated).  Writes BENCH_plan.json next to the repo root
    so the perf trajectory is tracked.

      * ResNet-18 (pyramid byte profile): the DP puts checkpoints at the
        narrow late activations -> strictly fewer stored residual bytes
        than the even split with the SAME number of checkpoints.
      * transformer, 14-layer smoke config: a uniform ``segment_size`` can
        only realize divisors of L (requesting ~4 segments of 14 layers
        degrades to 7 segments = 7 stored carries); the plan realizes
        exactly 4 non-uniform segments -> fewer stored carries.
    """
    import dataclasses
    import json
    import os
    import warnings

    from repro import configs, plan as plan_mod
    from repro.core.checkpoint import CheckpointConfig
    from repro.models import cnn, transformer

    out: dict = {}

    # ---- ResNet-18 ------------------------------------------------------
    cfg = cnn.resnet18(stem_stride=2)
    params = cnn.init_params(cfg, jax.random.PRNGKey(0))
    imgs_sds = jax.ShapeDtypeStruct((8, 256, 256, 3), jnp.float32)
    labels_sds = jax.ShapeDtypeStruct((8,), jnp.int32)
    prof = plan_mod.profile_resnet(params, cfg, imgs_sds)
    k = 5
    planned = plan_mod.plan_min_peak(prof, k)
    uniform = plan_mod.RematPlan.uniform(prof.n_layers, k + 1)
    assert len(planned.boundaries) == len(uniform.boundaries) == k

    im_t = jnp.asarray(np.random.default_rng(0).normal(
        size=(8, 64, 64, 3)).astype(np.float32))
    lb_t = jnp.asarray(np.arange(8) % 10)

    res_entry = {"checkpoints": k, "shape": list(imgs_sds.shape)}
    for name, plan in (("uniform", uniform), ("planned", planned)):
        remat = CheckpointConfig(plan=plan)

        def loss(p, im, lb, _r=remat):
            return cnn.loss_fn(p, cfg, im, lb, remat=_r)[0]

        mb = _residual_mb(loss, params, imgs_sds, labels_sds)
        step = jax.jit(jax.grad(
            lambda p: cnn.loss_fn(p, cfg, im_t, lb_t, remat=remat)[0]))
        us, _ = _timeit(lambda: step(params), iters=3)
        res_entry[name] = {
            "boundaries": list(plan.boundaries),
            "residual_mb": round(mb, 2),
            "us_per_step_64px": round(us, 1),
        }
        _rows(f"plan_vs_uniform_resnet18_{name}", us,
              f"residual_mb={mb:.0f},boundaries={list(plan.boundaries)}")
    assert res_entry["planned"]["residual_mb"] < \
        res_entry["uniform"]["residual_mb"], "planner must beat even split"
    out["resnet18"] = res_entry

    # ---- transformer (smoke config deepened to 14 layers) ---------------
    lcfg = dataclasses.replace(configs.smoke_config("llama3-8b"), n_layers=14)
    lp = transformer.init_params(lcfg, jax.random.PRNGKey(0))
    batch_sds = {"tokens": jax.ShapeDtypeStruct((4, 128), jnp.int32),
                 "labels": jax.ShapeDtypeStruct((4, 128), jnp.int32)}
    lprof = plan_mod.profile_transformer(lcfg, batch_sds)
    req_segments = 4                       # what the user asks for
    tplan = plan_mod.plan_min_peak(lprof, req_segments - 1)
    # legacy knob: ~L/4 blocks per segment; 14 % 4 != 0 -> divisor fallback
    from repro.core.checkpoint import _largest_divisor_leq
    seg_size = -(-lcfg.n_layers // req_segments)
    seg_size_executed = _largest_divisor_leq(lcfg.n_layers, seg_size)
    rng = np.random.default_rng(1)
    batch = {"tokens": jnp.asarray(rng.integers(0, 255, (4, 128), np.int32)),
             "labels": jnp.asarray(rng.integers(0, 255, (4, 128), np.int32))}

    tf_entry = {"requested_segments": req_segments, "n_layers": lcfg.n_layers,
                "shape": [4, 128]}
    cases = (("uniform", CheckpointConfig(segment_size=seg_size)),
             ("planned", CheckpointConfig(plan=tplan)))
    for name, remat in cases:
        def loss(p, b, _r=remat):
            with warnings.catch_warnings():
                warnings.simplefilter("ignore")  # divisor fallback, expected
                return transformer.loss_fn(p, lcfg, b, remat=_r)[0]

        mb = _residual_mb(loss, lp, batch)
        step = jax.jit(jax.grad(lambda p: loss(p, batch)))
        us, _ = _timeit(lambda: step(lp), iters=3)
        tf_entry[name] = {
            # record what actually EXECUTES: the uniform knob degrades to
            # the largest divisor of L, not the requested size
            "segment_sizes": (tplan.segment_sizes() if name == "planned"
                              else [seg_size_executed]
                              * (lcfg.n_layers // seg_size_executed)),
            "residual_mb": round(mb, 2),
            "us_per_step": round(us, 1),
        }
        _rows(f"plan_vs_uniform_transformer_{name}", us,
              f"residual_mb={mb:.0f}")
    assert tf_entry["planned"]["residual_mb"] < \
        tf_entry["uniform"]["residual_mb"], \
        "plan must beat the degraded uniform split"
    out["transformer_smoke14"] = tf_entry

    path = os.path.join(os.path.dirname(__file__), "..", "BENCH_plan.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1, sort_keys=True)
    print(f"# wrote {os.path.normpath(path)}", flush=True)


def flash_fwd_bwd():
    """Trainable flash attention (ISSUE 2 + 3 acceptance): fwd-only vs
    fwd+bwd, pallas custom_vjp vs the jnp O(S^2) path — residual ("peak
    between fwd and bwd") bytes across S, wall time where the kernels
    execute on CPU (interpret mode), and the sparse-grid tile/FLOP
    claw-back (visited vs dense KV tile-steps, measured via the kernels'
    debug counters at a CPU-feasible size and analytic across S).
    Writes BENCH_flash.json.

    The pallas rows use ``backend="pallas"`` under ``jax.eval_shape`` (the
    custom_vjp residual structure is backend-independent; abstract eval
    never lowers to Mosaic), so the recorded bytes are exactly what a TPU
    run would save between forward and backward.
    """
    import json
    import os

    from repro.kernels.flash import kernel as flash_kernel, \
        ops as flash_ops, ref as flash_ref

    b, h, hkv, d = 1, 4, 2, 64
    out: dict = {"shape": {"batch": b, "heads": h, "kv_heads": hkv,
                           "head_dim": d}, "cases": {}}

    def fwd_bytes(fn, *sds):
        o = jax.eval_shape(fn, *sds)
        return sum(x.size * x.dtype.itemsize
                   for x in jax.tree_util.tree_leaves(o))

    def fwd_bwd_bytes(fn, *sds):
        # output + vjp residuals: everything alive between fwd and bwd
        o = jax.eval_shape(lambda *a: jax.vjp(fn, *a), *sds)
        return sum(x.size * x.dtype.itemsize
                   for x in jax.tree_util.tree_leaves(o))

    for s in (512, 1024, 2048):
        sds = (jax.ShapeDtypeStruct((b, h, s, d), jnp.float32),
               jax.ShapeDtypeStruct((b, hkv, s, d), jnp.float32),
               jax.ShapeDtypeStruct((b, hkv, s, d), jnp.float32))
        fns = {
            "jnp": lambda q, k, v: flash_ref.flash_ref(q, k, v),
            "pallas": lambda q, k, v: flash_ops.flash_attention(
                q, k, v, backend="pallas"),
        }
        entry = {}
        for name, fn in fns.items():
            entry[name] = {
                "fwd_bytes": fwd_bytes(fn, *sds),
                "fwd_bwd_peak_bytes": fwd_bwd_bytes(fn, *sds),
            }
            _rows(f"flash_fwd_bwd_s{s}_{name}", 0.0,
                  f"fwd_mb={entry[name]['fwd_bytes']/2**20:.1f},"
                  f"fwd_bwd_mb={entry[name]['fwd_bwd_peak_bytes']/2**20:.1f}")
        if s >= 1024:
            assert entry["pallas"]["fwd_bwd_peak_bytes"] < \
                entry["jnp"]["fwd_bwd_peak_bytes"], \
                "flash custom_vjp must beat the jnp S^2 residuals"
        out["cases"][f"s{s}"] = entry

    # ---- sparse grids (ISSUE 3): visited vs dense tile-steps ----------
    # analytic counts across S for the two schedules that matter, plus a
    # measured interpret-mode run (debug counters) to prove the kernels
    # execute exactly the analytic schedule.
    sparsity: dict = {}
    for s in (512, 1024, 2048):
        for name, w in (("causal", 0), ("window256", 256)):
            if w >= s:
                continue
            c = flash_kernel.tile_step_counts(s, causal=True, window=w)
            steps = {g: c[g] for g in ("fwd", "dq", "dkv")}
            visited = sum(steps.values())
            dense = 3 * c["dense"]
            sparsity[f"{name}_s{s}"] = {
                **steps, "dense_per_grid": c["dense"],
                "skipped_frac": round(1 - visited / dense, 4),
            }
            _rows(f"flash_sparse_{name}_s{s}", 0.0,
                  f"visited={visited},dense={dense},"
                  f"skipped={1 - visited/dense:.3f}")
    # measured counters at S=512 (cheap in interpret mode): must equal
    # the analytic schedule tile-for-tile
    s_m, h_m = 512, 2
    qm = jnp.asarray(np.random.default_rng(5).normal(
        size=(h_m, s_m, d)).astype(np.float32))
    o_m, m_m, l_m, cnt = flash_kernel.flash_attention_fwd_pallas(
        qm, qm, qm, causal=True, interpret=True, debug_counts=True)
    *_, dqc, dkvc = flash_kernel.flash_attention_bwd_pallas(
        qm, qm, qm, o_m, m_m, l_m, jnp.ones_like(o_m), causal=True,
        interpret=True, debug_counts=True)
    c = flash_kernel.tile_step_counts(s_m, causal=True, window=0)
    measured = {"fwd": int(cnt[0].sum()), "dq": int(dqc[0].sum()),
                "dkv": int(dkvc[0].sum())}
    assert measured == {g: c[g] for g in ("fwd", "dq", "dkv")}, \
        (measured, c)
    sparsity["measured_causal_s512"] = measured
    out["sparsity"] = sparsity

    # FLOP claw-back the planner now budgets (causal smoke config @ 2048)
    import dataclasses as dc_mod

    from repro import configs, plan as plan_mod
    cfg_cb = dc_mod.replace(configs.smoke_config("llama3-8b"),
                            attn_backend="pallas", head_dim=64)
    rep = plan_mod.flash_attn_flop_report(cfg_cb, 1, 2048)
    assert rep["eligible"] and rep["skip_frac"] >= 0.45
    out["flop_clawback_s2048"] = {
        "dense_gflops": round(rep["dense_flops"] / 1e9, 2),
        "visited_gflops": round(rep["visited_flops"] / 1e9, 2),
        "clawback_x": round(rep["dense_flops"] / rep["visited_flops"], 3),
        "tile_skip_frac": round(rep["skip_frac"], 4),
    }
    _rows("flash_flop_clawback_s2048", 0.0,
          f"dense_gflops={rep['dense_flops']/1e9:.1f},"
          f"visited_gflops={rep['visited_flops']/1e9:.1f},"
          f"clawback={rep['dense_flops']/rep['visited_flops']:.2f}x")

    # wall time at a CPU-executable size: interpret-mode kernels vs jnp
    s = 256
    rng = np.random.default_rng(3)
    q = jnp.asarray(rng.normal(size=(b, h, s, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, hkv, s, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, hkv, s, d)).astype(np.float32))
    timing = {}
    for name, backend in (("jnp", "ref"), ("interpret", "interpret")):
        fwd = jax.jit(lambda q, k, v, _b=backend: flash_ops.flash_attention(
            q, k, v, backend=_b))
        grad = jax.jit(jax.grad(
            lambda q, k, v, _b=backend: jnp.sum(flash_ops.flash_attention(
                q, k, v, backend=_b) ** 2), argnums=(0, 1, 2)))
        us_f, _ = _timeit(fwd, q, k, v)
        us_g, _ = _timeit(grad, q, k, v)
        timing[name] = {"fwd_us": round(us_f, 1),
                        "fwd_bwd_us": round(us_g, 1)}
        _rows(f"flash_wall_s{s}_{name}", us_g, f"fwd_us={us_f:.0f}")
    out["wall_s256"] = timing

    path = os.path.join(os.path.dirname(__file__), "..", "BENCH_flash.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1, sort_keys=True)
    print(f"# wrote {os.path.normpath(path)}", flush=True)


def flash_decode():
    """Split-K int8 flash decode (ISSUE 4 acceptance): sequential vs
    split-K wall time where the kernels execute on CPU (interpret mode),
    and the dense-vs-visited tile claw-back of length-aware skipping on a
    ragged S=2048 batch (mean length S/4) — measured via the kernel's
    debug counters and asserted against the analytic twin.  Writes
    BENCH_decode.json.
    """
    import json
    import os

    from repro import configs, plan as plan_mod
    from repro.kernels import tiling
    from repro.kernels.kvq import ops as kvq_ops, ref as kvq_ref

    b, h, hkv, d, s, bs = 4, 8, 2, 64, 2048, 256
    rng = np.random.default_rng(9)
    q = jnp.asarray(rng.normal(size=(b, h, d)).astype(np.float32))
    k = jnp.asarray(rng.normal(size=(b, hkv, s, d)).astype(np.float32))
    v = jnp.asarray(rng.normal(size=(b, hkv, s, d)).astype(np.float32))
    kq, ks = kvq_ref.quantize_kv(k)
    vq, vs = kvq_ref.quantize_kv(v)
    lengths = jnp.asarray([256, 512, 512, 768], jnp.int32)  # mean S/4
    out: dict = {"shape": {"batch": b, "heads": h, "kv_heads": hkv,
                           "head_dim": d, "seq": s, "block_s": bs,
                           "lengths": [int(x) for x in lengths]}}

    # ---- tile claw-back: measured counters == analytic, >= 70% skipped
    o_cnt, cnt = kvq_ops.decode_attention(
        q, kq, ks, vq, vs, lengths=lengths, backend="interpret", splits=4,
        block_s=bs, debug_counts=True)
    executed = int(np.asarray(cnt)[:, 0].sum())          # per kv head
    dense = b * (s // bs)
    c = tiling.decode_tile_step_counts(s, [int(x) for x in lengths],
                                       block_s=bs, splits=4)
    assert executed == c["visited"], (executed, c)
    skip = 1 - executed / dense
    assert skip >= 0.70, skip
    out["tile_clawback_s2048_ragged"] = {
        "visited": executed, "dense": dense, "skip_frac": round(skip, 4)}
    _rows("flash_decode_tiles_s2048_ragged", 0.0,
          f"visited={executed},dense={dense},skipped={skip:.3f}")

    # ---- sequential vs split-K wall time (interpret mode; the schedule
    # restructuring, not TPU latency — that needs hardware)
    timing = {}
    for name, splits in (("sequential", 1), ("splitk4", 4)):
        fn = jax.jit(lambda q, kq, ks, vq, vs, _s=splits:
                     kvq_ops.decode_attention(
                         q, kq, ks, vq, vs, lengths=lengths,
                         backend="interpret", splits=_s, block_s=bs))
        us, o = _timeit(fn, q, kq, ks, vq, vs)
        timing[name] = round(us, 1)
        _rows(f"flash_decode_wall_s2048_{name}", us, f"splits={splits}")
    o_seq = jax.jit(lambda *a: kvq_ops.decode_attention(
        *a, lengths=lengths, backend="ref"))(q, kq, ks, vq, vs)
    assert float(jnp.abs(o_cnt - o_seq).max()) < 1e-3
    out["wall_us_interpret"] = timing

    # ---- planner decode report at a serving shape (llama3 @ decode_32k
    # geometry, reduced batch): visited-vs-dense tiles + int8 cache bytes
    cfg = configs.get_config("llama3-8b")
    rep = plan_mod.decode_tile_report(cfg, 4, 32768,
                                      lengths=[8192] * 4, splits=8)
    cache_rep = plan_mod.kv_cache_report(cfg, 4, 32768)
    out["planner_llama3_32k_quarter"] = {
        "visited_tile_steps": rep["visited_tile_steps"],
        "dense_tile_steps": rep["dense_tile_steps"],
        "skip_frac": round(rep["skip_frac"], 4),
        "visited_kv_gbytes": round(rep["visited_kv_bytes"] / 1e9, 3),
        "dense_kv_gbytes": round(rep["dense_kv_bytes"] / 1e9, 3),
        "kv_cache_int8_gbytes": round(cache_rep["int8_bytes"] / 1e9, 3),
        "kv_cache_f32_gbytes": round(cache_rep["f32_bytes"] / 1e9, 3),
    }
    _rows("flash_decode_planner_llama3_32k", 0.0,
          f"skip={rep['skip_frac']:.3f},"
          f"kv_int8_gb={cache_rep['int8_bytes']/1e9:.2f},"
          f"kv_f32_gb={cache_rep['f32_bytes']/1e9:.2f}")

    path = os.path.join(os.path.dirname(__file__), "..", "BENCH_decode.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1, sort_keys=True)
    print(f"# wrote {os.path.normpath(path)}", flush=True)


def serve_trace():
    """Continuous batching vs lockstep on one ragged trace (ISSUE 5
    acceptance): the engine joins requests mid-flight and retires them at
    their own length, so no slot pays for the slowest request; lockstep
    groups the same requests into fixed batches, pads every prompt to the
    group max, and decodes the group's max generation length for
    everyone.  Useful tokens (each request's own gen budget) per wall
    second is the headline; wasted slot-steps make the padding cost
    explicit.  Writes BENCH_serve.json.
    """
    import json
    import os

    from repro import configs
    from repro.models import transformer
    from repro.serve import ServeEngine, synthetic_trace
    from repro.train.serve_step import build_decode_step, build_prefill_step

    cfg = configs.smoke_config("llama3-8b")
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    slots, max_len, bucket = 4, 96, 16
    trace = synthetic_trace(12, seed=7, vocab=cfg.vocab, mean_prompt=10,
                            max_prompt=bucket, mean_gen=16, max_gen=48,
                            arrival_rate=1.0)
    useful = sum(r.max_new_tokens for r in trace)

    # ---- continuous batching vs lockstep, interleaved best-of-5: both
    # sides are ~50 ms walls on CPU, so OS/allocator noise between two
    # separately-timed blocks can swing the ratio by 20%+ (observed while
    # re-basing for ISSUE 8).  Alternating one engine pass with one
    # lockstep pass inside the SAME loop makes any machine-state drift
    # hit both sides equally; min-of-5 then compares steady-state floors.
    eng = ServeEngine(params, cfg, max_slots=slots, max_len=max_len,
                      prompt_buckets=(bucket,), seed=0)
    compiles = eng.warmup()

    prefill = jax.jit(build_prefill_step(cfg, quantized=True,
                                         s_max=max_len))
    decode = jax.jit(build_decode_step(cfg, quantized=True))
    groups = [trace[i:i + slots] for i in range(0, len(trace), slots)]

    def run_lockstep():
        slot_steps = ttfts = 0
        step_clock = 0
        for g in groups:
            toks = np.zeros((slots, bucket), np.int32)
            for j, r in enumerate(g):
                toks[j, :len(r.prompt)] = r.prompt      # pad to the bucket
            # the whole group must have arrived before a lockstep batch
            # can prefill, and it holds all slots for the group max
            step_clock = max(step_clock, max(r.arrival_step for r in g))
            logits, cache = prefill(params, {"tokens": jnp.asarray(toks)})
            tok = jnp.asarray(logits.argmax(-1), jnp.int32)
            np.asarray(tok)          # serving streams every token out
            ttfts += sum(step_clock + 1 - r.arrival_step for r in g)
            g_steps = max(r.max_new_tokens for r in g)
            for _ in range(g_steps - 1):
                lg, cache = decode(params, cache, tok)
                tok = jnp.asarray(lg.argmax(-1), jnp.int32)
                np.asarray(tok)      # same per-step delivery the engine pays
            step_clock += g_steps
            slot_steps += g_steps * slots
        return slot_steps, ttfts / len(trace)

    run_lockstep()                                      # compile warmup
    wall_e = wall_l = float("inf")
    for _ in range(5):
        eng.reset()
        t0 = time.perf_counter()
        summary = eng.run(trace)
        wall_e = min(wall_e, time.perf_counter() - t0)
        t0 = time.perf_counter()
        slot_steps, ttft_lock = run_lockstep()
        wall_l = min(wall_l, time.perf_counter() - t0)
    assert eng.compile_counts() == compiles, "engine re-jitted mid-trace"
    assert summary["total_tokens"] == useful

    # ---- same trace under seeded faults (ISSUE 7): the canonical
    # detect -> quarantine -> replay run.  Victims and steps are pinned to
    # this seeded trace (replay prompts must fit the 16-token bucket; a
    # drop_scatter victim must land on a first-use slot for the pos>0
    # sentinel); the injected-count asserts catch any drift.
    from repro.serve import FaultInjector, FaultPlan
    wall_f = float("inf")
    for _ in range(3):
        eng.reset()
        plan = (FaultPlan().drop_scatter(3, rid=3).nan_logits(5, rid=0)
                .corrupt_row(15, rid=6))
        inj = FaultInjector(eng, plan)
        t0 = time.perf_counter()
        fsum = eng.run(trace)
        wall_f = min(wall_f, time.perf_counter() - t0)
        inj.uninstall()
        assert dict(inj.injected) == {"drop_scatter": 1, "nan_logits": 1,
                                      "corrupt_row": 1}, inj.injected
    assert eng.compile_counts() == compiles, "fault injection re-jitted"
    assert fsum["n_failed"] == 0 and fsum["n_done"] == len(trace)
    leaks = eng.pool.allocs - eng.pool.frees + eng.pool.occupancy
    goodput_f = fsum["goodput_tokens"] / wall_f

    # ---- replica fleet (ISSUE 8): 2 engines behind the router, same
    # trace, one replica killed mid-trace.  The canonical seeded failover
    # run: every request still completes (migrated ones replay from
    # prompt + emitted tokens on the survivor), and the ratchet floors
    # failover_replay_success and the goodput ratio vs the fault-free
    # fleet.
    from repro.serve import FleetFaultInjector, Router

    # a mid-trace failover replays prompt + emitted tokens, so fleet
    # replicas carry a second prefill bucket big enough for any replay
    # (max_prompt 16 + max_gen 48 = 64); single-engine runs above pin
    # faults early enough to fit one bucket, a killed replica can't
    fleet_eng = [ServeEngine(params, cfg, max_slots=slots, max_len=max_len,
                             prompt_buckets=(bucket, 64), seed=0,
                             sampler_keys="request")
                 for _ in range(2)]
    fleet_compiles = [e.warmup() for e in fleet_eng]

    wall_ff = float("inf")
    for _ in range(3):
        for e in fleet_eng:
            e.reset()
        router = Router(fleet_eng)
        t0 = time.perf_counter()
        ffsum = router.run(trace)
        wall_ff = min(wall_ff, time.perf_counter() - t0)
    assert ffsum["fleet"]["n_done"] == len(trace)

    wall_k = float("inf")
    for _ in range(3):
        for e in fleet_eng:
            e.reset()
        router = Router(fleet_eng)
        kplan = FaultPlan().replica_crash(4, 1)
        kinj = FleetFaultInjector(router, kplan)
        t0 = time.perf_counter()
        ksum = router.run(trace)
        wall_k = min(wall_k, time.perf_counter() - t0)
        assert kinj.crashed == {1}, kinj.injected
    for e, c in zip(fleet_eng, fleet_compiles):
        assert e.compile_counts() == c, "fleet replica re-jitted"
    fleet_leaks = sum(e.pool.allocs - e.pool.frees + e.pool.occupancy
                      for e in fleet_eng)
    assert ksum["fleet"]["n_done"] == len(trace), ksum["fleet"]
    assert ksum["reconcile"]["ok"], ksum["reconcile"]
    goodput_ff = ffsum["fleet"]["goodput_tokens"] / wall_ff
    goodput_k = ksum["fleet"]["goodput_tokens"] / wall_k

    # ---- durable serving (ISSUE 9): the canonical seeded router-crash
    # run.  (a) journaled-but-uncrashed fleet run on the same trace —
    # the fsync'd WAL must cost < 20% goodput vs the unjournaled fleet
    # (the ratchet floors the ratio); (b) the run is killed -9 after a
    # fixed step budget (router abandoned, engine-side requests vanish),
    # then a FRESH router reopens the journal, recovers every live
    # request, and drives the fleet dry — the ratchet floors
    # recovery_replay_success.
    import tempfile

    from repro.serve import RequestJournal, TERMINAL

    def _force_drain():
        # kill -9 semantics: engine-side state vanishes, jit cache stays
        for e in fleet_eng:
            for rid, st in list(e.request_states().items()):
                if st["state"] not in TERMINAL:
                    e.evict_request(rid)
            e.reset()

    wal_dir = tempfile.mkdtemp(prefix="bench_wal_")
    # interleaved like continuous-vs-lockstep above: the journal's cost
    # is a few ms on a ~50 ms wall, well inside machine noise between
    # separately-timed blocks, so each iteration times one unjournaled
    # pass and one journaled pass back to back and the ratio compares
    # min-of-3 floors
    wall_j = wall_ffi = float("inf")
    for it in range(3):
        for e in fleet_eng:
            e.reset()
        router = Router(fleet_eng)
        t0 = time.perf_counter()
        ffisum = router.run(trace)
        wall_ffi = min(wall_ffi, time.perf_counter() - t0)
        for e in fleet_eng:
            e.reset()
        jp = os.path.join(wal_dir, f"journaled_{it}.jsonl")
        # group commit (flush_every=16): one fsync amortizes a batch of
        # appends.  The fsync-lag window this opens is exactly what
        # recovery tolerates — lost tail records are regenerated
        # deterministically — so the serving price of durability is the
        # batched write, not an fsync per token
        with RequestJournal(jp, snapshot_every=64,
                            flush_every=16) as jrn:
            router = Router(fleet_eng, journal=jrn,
                            journal_tokens_every=4)
            t0 = time.perf_counter()
            jsum = router.run(trace)
            wall_j = min(wall_j, time.perf_counter() - t0)
            assert router.reconcile()["ok"]
            j_appends = jrn.appends
    assert jsum["fleet"]["n_done"] == len(trace)
    assert ffisum["fleet"]["n_done"] == len(trace)
    goodput_j = jsum["fleet"]["goodput_tokens"] / wall_j
    journal_overhead_ratio = goodput_j / (
        ffisum["fleet"]["goodput_tokens"] / wall_ffi)

    crash_step = 12
    jp = os.path.join(wal_dir, "crash.jsonl")
    jrn = RequestJournal(jp, snapshot_every=64, flush_every=16)
    router = Router(fleet_eng, journal=jrn, journal_tokens_every=4)
    t0 = time.perf_counter()
    router.run(trace, max_steps=crash_step)      # stalled = "crashed"
    n_live_at_crash = router.live_requests()
    del router                                   # kill -9
    _force_drain()
    jrn.close()

    j2 = RequestJournal(jp)
    router = Router(fleet_eng, journal=j2)
    rinfo = router.recover()
    guard = 2000
    while router.live_requests() > 0 and guard:
        router.step()
        guard -= 1
    wall_r = time.perf_counter() - t0
    rsum = router.summary()
    rrec = router.reconcile()
    j2.close()
    assert guard, "recovered fleet failed to drain"
    assert rrec["ok"], rrec
    assert rrec["checks"]["journal_accounted"]
    for e, c in zip(fleet_eng, fleet_compiles):
        assert e.compile_counts() == c, "recovery re-jitted"
    recovery_leaks = sum(e.pool.allocs - e.pool.frees + e.pool.occupancy
                         for e in fleet_eng)

    tps_e = useful / wall_e
    tps_l = useful / wall_l
    out = {
        "trace": {"requests": len(trace), "useful_tokens": useful,
                  "slots": slots, "max_len": max_len,
                  "gen_lengths": [r.max_new_tokens for r in trace]},
        "continuous": {
            "tokens_per_s": round(tps_e, 1), "wall_s": round(wall_e, 3),
            "ttft_mean_steps": round(summary["ttft_mean_steps"], 2),
            "occupancy_mean": round(summary["occupancy_mean"], 2),
            "engine_steps": summary["n_steps"],
            "wasted_slot_steps": summary["n_steps"] * slots - useful,
            # deterministic packing quality on the seeded trace (no wall
            # clock involved): useful tokens per slot-step the engine
            # actually ran — the structural win continuous batching
            # ratchets regardless of machine noise
            "slot_step_efficiency":
                round(useful / (summary["n_steps"] * slots), 3),
        },
        "lockstep": {
            "tokens_per_s": round(tps_l, 1), "wall_s": round(wall_l, 3),
            "ttft_mean_steps": round(ttft_lock, 2),
            "decode_slot_steps": slot_steps,
            "wasted_slot_steps": slot_steps - useful,
        },
        "speedup_tokens_per_s": round(tps_e / tps_l, 2),
        "fault_trace": {
            "injected": dict(inj.injected),
            "n_faults": fsum["n_faults"], "n_retried": fsum["n_retried"],
            "n_done": fsum["n_done"], "n_failed": fsum["n_failed"],
            "retry_success_rate": fsum["retry_success_rate"],
            "goodput_tokens": fsum["goodput_tokens"],
            "goodput_tokens_per_s": round(goodput_f, 1),
            "goodput_frac_of_fault_free": round(goodput_f / tps_e, 3),
            "quarantines": eng.pool.quarantines,
            "zero_slot_leaks": leaks == 0,
            "engine_steps": fsum["n_steps"],
        },
        "fleet": {
            "replicas": 2,
            "fault_free": {
                "wall_s": round(wall_ff, 3),
                "router_steps": ffsum["step_no"],
                "goodput_tokens": ffsum["fleet"]["goodput_tokens"],
                "goodput_tokens_per_s": round(goodput_ff, 1),
            },
            "replica_kill": {
                "kill_step": 4, "replica": 1,
                "wall_s": round(wall_k, 3),
                "router_steps": ksum["step_no"],
                "failovers": ksum["fleet"]["failovers"],
                "n_migrations": ksum["fleet"]["n_migrations"],
                "failover_replay_success":
                    ksum["fleet"]["replay_success_rate"],
                "n_done": ksum["fleet"]["n_done"],
                "goodput_tokens": ksum["fleet"]["goodput_tokens"],
                "goodput_tokens_per_s": round(goodput_k, 1),
                "goodput_frac_of_fault_free":
                    round(goodput_k / goodput_ff, 3),
                "zero_slot_leaks": fleet_leaks == 0,
            },
        },
        "recovery": {
            "journaled": {
                "wall_s": round(wall_j, 3),
                "goodput_tokens": jsum["fleet"]["goodput_tokens"],
                "goodput_tokens_per_s": round(goodput_j, 1),
                "appends": j_appends,
            },
            "journaled_goodput_frac_of_unjournaled":
                round(journal_overhead_ratio, 3),
            "router_crash": {
                "crash_step": crash_step,
                "n_live_at_crash": n_live_at_crash,
                "n_recovered": rinfo["n_recovered"],
                "n_placed": rinfo["n_placed"],
                "n_done_from_disk": rinfo["n_done"],
                "wall_s_end_to_end": round(wall_r, 3),
                "n_done": rsum["fleet"]["n_done"],
                "terminal_counts":
                    dict(j2.state.terminal_counts),
                "one_terminal_per_submit":
                    rrec["checks"]["journal_accounted"],
                "zero_slot_leaks": recovery_leaks == 0,
            },
            "recovery_replay_success":
                rsum["fleet"]["recovery_replay_success"],
        },
    }
    _rows("serve_trace_faulted", wall_f * 1e6,
          f"goodput_tok_s={goodput_f:.1f},faults={fsum['n_faults']}")
    _rows("serve_fleet_fault_free", wall_ff * 1e6,
          f"goodput_tok_s={goodput_ff:.1f},replicas=2")
    _rows("serve_fleet_replica_kill", wall_k * 1e6,
          f"goodput_tok_s={goodput_k:.1f},"
          f"failovers={ksum['fleet']['failovers']}")
    _rows("serve_fleet_journaled", wall_j * 1e6,
          f"goodput_tok_s={goodput_j:.1f},"
          f"frac_of_unjournaled={journal_overhead_ratio:.3f}")
    _rows("serve_router_crash_recover", wall_r * 1e6,
          f"recovered={rinfo['n_recovered']},"
          f"replay_success={rsum['fleet']['recovery_replay_success']:.2f}")
    _rows("serve_trace_continuous", wall_e * 1e6,
          f"tok_s={tps_e:.1f},occ={summary['occupancy_mean']:.2f}")
    _rows("serve_trace_lockstep", wall_l * 1e6, f"tok_s={tps_l:.1f}")
    _rows("serve_trace_speedup", 0.0, f"{tps_e/tps_l:.2f}x")
    assert tps_e > tps_l, (
        f"continuous batching ({tps_e:.1f} tok/s) must beat lockstep "
        f"({tps_l:.1f} tok/s) on a ragged trace")

    path = os.path.join(os.path.dirname(__file__), "..", "BENCH_serve.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1, sort_keys=True)
    print(f"# wrote {os.path.normpath(path)}", flush=True)


def obs_overhead():
    """Observability overhead + reconciliation (ISSUE 10 acceptance).

    Runs the same seeded ragged trace twice per iteration — untraced,
    then traced (span records + scheduler/engine instrumentation to a
    JSONL sink) — interleaved best-of-5 like serve_trace, so machine
    drift hits both sides equally.  The contract being ratcheted:

      * tokens/s traced >= 0.95x untraced (all instrumentation is
        host-side Python around the jit boundary);
      * compile_counts() frozen — attaching a tracer must not introduce
        a single new jit trace;
      * every DONE request reconstructs to exactly ONE closed ``req``
        root span whose children include >=1 queue, exactly 1 prefill
        and >=1 decode, and whose segments sum to the root duration.

    Writes BENCH_obs.json.
    """
    import dataclasses
    import importlib.util
    import json
    import os
    import tempfile

    from repro import configs
    from repro.events import EventSink
    from repro.models import transformer
    from repro.obs import Tracer
    from repro.serve import ServeEngine, synthetic_trace

    spec = importlib.util.spec_from_file_location(
        "tracelens", os.path.join(os.path.dirname(__file__), "..",
                                  "tools", "tracelens.py"))
    tracelens = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(tracelens)

    # the 2-layer/64-dim smoke step is ~0.5 ms on CPU — an order of
    # magnitude below any real decode step, which would overstate the
    # fixed ~30 us/step host-side span cost.  Widen to a step wall in
    # the low-ms range so the measured ratio reflects the contract's
    # regime (instrumentation cost amortized against model compute).
    cfg = dataclasses.replace(configs.smoke_config("llama3-8b"),
                              n_layers=4, d_model=128, d_ff=384)
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    slots, max_len, bucket = 4, 128, 16
    # a longer trace than serve_trace's: each timed wall is ~0.5 s, so
    # scheduler jitter moves the ratio by well under the 5% contract
    trace = synthetic_trace(24, seed=7, vocab=cfg.vocab, mean_prompt=10,
                            max_prompt=bucket, mean_gen=32, max_gen=64,
                            arrival_rate=1.0)
    useful = sum(r.max_new_tokens for r in trace)

    eng = ServeEngine(params, cfg, max_slots=slots, max_len=max_len,
                      prompt_buckets=(bucket,), seed=0)
    compiles = eng.warmup()

    ev_dir = tempfile.mkdtemp(prefix="bench_obs_")
    wall_u = wall_t = float("inf")
    ratios = []
    ev_path = None
    for it in range(7):
        # paired design: each iteration times one untraced and one traced
        # pass back to back (order alternating — the second run of a pair
        # sees warmer caches) and contributes ONE traced/untraced ratio.
        # Per-pair ratios still swing ±10% with CPU scheduler noise (a
        # profiled traced run has come out FASTER than untraced); the
        # MEDIAN of 7 pairs is stable at the true ~1-3% overhead, where
        # min-of-min walls from different pairs flake the 0.95 floor.
        walls = {}
        for side in (("untraced", "traced") if it % 2 == 0
                     else ("traced", "untraced")):
            eng.reset()
            if side == "untraced":
                eng.tracer = None
                t0 = time.perf_counter()
                usum = eng.run(trace)
                walls[side] = time.perf_counter() - t0
            else:
                ev_path = os.path.join(ev_dir, f"trace_{it}.jsonl")
                sink = EventSink(ev_path, flush_every=16)
                eng.tracer = Tracer(sink, pid="r0")
                t0 = time.perf_counter()
                tsum = eng.run(trace)
                walls[side] = time.perf_counter() - t0
                eng.tracer = None
                sink.close()
        ratios.append(walls["untraced"] / walls["traced"])
        wall_u = min(wall_u, walls["untraced"])
        wall_t = min(wall_t, walls["traced"])
    frozen = eng.compile_counts() == compiles
    assert frozen, "attaching a tracer re-jitted the engine"
    assert usum["n_done"] == tsum["n_done"] == len(trace)

    ratios.sort()
    ratio = ratios[len(ratios) // 2]
    _rows("obs_traced", wall_t * 1e6, f"tok_s={useful/wall_t:.1f}")
    _rows("obs_untraced", wall_u * 1e6, f"tok_s={useful/wall_u:.1f}")
    _rows("obs_overhead_ratio", 0.0, f"{ratio:.3f}x,frozen={frozen}")
    assert ratio >= 0.95, (
        f"traced goodput {ratio:.3f}x untraced (pairs {ratios}) — "
        f"overhead contract broken")

    # ---- reconcile the last traced run's spans against its summary
    closed, open_spans = tracelens.load_spans(ev_path)
    groups = tracelens.by_trace(closed)
    done_chains = 0
    for rid, spans in groups.items():
        names = [s["name"] for s in spans]
        roots = [s for s in spans
                 if s["name"] == "req" and s["parent"] is None]
        if not (roots and roots[0]["attrs"].get("state") == "DONE"):
            continue
        assert len(roots) == 1, f"rid {rid}: {len(roots)} req roots"
        assert names.count("prefill") == 1 and "queue" in names, \
            f"rid {rid}: incomplete chain {names}"
        # a request whose whole budget was the prefill token never enters
        # decode residency — no decode span is the correct timeline
        if roots[0]["attrs"].get("tokens", 0) > 1:
            assert "decode" in names, f"rid {rid}: missing decode {names}"
        segs = tracelens.segments(spans, roots[0])
        assert abs(sum(s["dur"] for s in segs) - roots[0]["dur"]) \
            <= 1e-9 * max(roots[0]["dur"], 1e-12), \
            f"rid {rid}: segments do not sum to e2e"
        done_chains += 1
    reconciled = done_chains == tsum["n_done"]
    assert reconciled, (done_chains, tsum["n_done"])
    assert not open_spans, f"{len(open_spans)} spans left open"
    _rows("obs_span_reconcile", 0.0,
          f"done_chains={done_chains},open={len(open_spans)}")

    out = {
        "trace": {"requests": len(trace), "useful_tokens": useful,
                  "slots": slots},
        "overhead": {
            "tokens_per_s_traced": round(useful / wall_t, 1),
            "tokens_per_s_untraced": round(useful / wall_u, 1),
            "tokens_per_s_ratio": round(ratio, 3),
            "compile_counts_frozen": frozen,
        },
        "spans": {"closed": len(closed), "open": len(open_spans),
                  "traces": len(groups)},
        "reconcile": {
            "n_done": tsum["n_done"],
            "done_span_chains": done_chains,
            "done_span_chains_complete": reconciled,
            "segments_sum_to_e2e": True,
        },
    }
    path = os.path.join(os.path.dirname(__file__), "..", "BENCH_obs.json")
    with open(path, "w") as f:
        json.dump(out, f, indent=1, sort_keys=True)
    print(f"# wrote {os.path.normpath(path)}", flush=True)


def tbl_codec():
    """Codec throughput + ratios (paper claims up-to 16x passage saving)."""
    from repro.core import encoding
    rng = np.random.default_rng(0)
    batch = rng.integers(0, 256, (16, 512, 512, 3), dtype=np.uint8)

    us, _ = _timeit(lambda: encoding.pack_u8_to_u32(batch), iters=5)
    _rows("codec_u32_pack_16x512x512x3", us,
          f"ratio_vs_f32={encoding.compression_ratio(4, 'u32'):.0f}x")
    packed = np.asarray(encoding.pack_u8_to_u32(batch))
    us, _ = _timeit(lambda: encoding.unpack_u32_to_u8(packed), iters=5)
    _rows("codec_u32_unpack", us, "exact=True")

    sub = batch[:6]
    us, _ = _timeit(lambda: encoding.encode_base256(sub), iters=3)
    _rows("codec_base256_encode_6imgs", us, "ratio=3x,f64")
    enc = encoding.encode_base256(sub)
    us, _ = _timeit(lambda: encoding.decode_base256(enc, 6), iters=3)
    _rows("codec_base256_decode", us, "exact=True")

    sub7 = batch[:7]
    us, _ = _timeit(lambda: encoding.encode_lossless(sub7), iters=3)
    _rows("codec_lossless_encode_7imgs", us, "alg4,f64+offsets")

    # jit'd fused decode layer (the network's first layer)
    from repro.kernels.pack import ops as pack_ops
    pj = jnp.asarray(packed)
    us, _ = _timeit(lambda: pack_ops.decode(pj, backend="ref"), iters=5)
    _rows("codec_decode_layer_jit", us, "fused_normalize=True")


def tbl_pipeline():
    """Parallel E-D: background-thread encoding vs inline (paper Fig. 1)."""
    from repro.data.synthetic import make_cifar_like
    from repro.data.pipeline import ParallelEncodedLoader
    from repro.core import encoding

    imgs, labels = make_cifar_like(n=2048, seed=0)
    bs, steps = 32, 64
    train_ms = 3.0  # simulated device step time

    def consume_parallel():
        with ParallelEncodedLoader(imgs, labels, bs, codec="u32",
                                   prefetch=4) as dl:
            t0 = time.perf_counter()
            for _ in range(steps):
                next(dl)
                time.sleep(train_ms / 1e3)
            return time.perf_counter() - t0

    def consume_inline():
        rng = np.random.default_rng(0)
        t0 = time.perf_counter()
        for _ in range(steps):
            idx = rng.integers(0, len(imgs), bs)
            encoding.pack_u8_to_u32(imgs[idx])
            time.sleep(train_ms / 1e3)
        return time.perf_counter() - t0

    tp = consume_parallel()
    ti = consume_inline()
    _rows("pipeline_parallel_ED", tp / steps * 1e6,
          f"speedup_vs_inline={ti/tp:.2f}x")
    _rows("pipeline_inline_ED", ti / steps * 1e6, "")


def tbl_compression():
    from repro.optim import compression
    g = {"w": jnp.asarray(np.random.default_rng(0)
                          .normal(size=(1 << 20,)).astype(np.float32))}
    us, (payload, _) = _timeit(
        lambda: compression.compress_with_feedback(
            g, None, jax.random.PRNGKey(0), codec="int8"), iters=3)
    raw = 4 * (1 << 20)
    _rows("grad_compress_int8_1M", us,
          f"payload_ratio={raw/compression.payload_bytes(payload):.1f}x")
    us, (payload, _) = _timeit(
        lambda: compression.compress_with_feedback(
            g, None, jax.random.PRNGKey(0), codec="topk", topk_frac=0.01),
        iters=3)
    _rows("grad_compress_topk1pct_1M", us,
          f"payload_ratio={raw/compression.payload_bytes(payload):.1f}x")


def mesh_shard():
    """Mesh-sharding parity + capacity (ISSUE 6 acceptance), via
    subprocess: this process already initialized jax with however many
    devices exist, and the 8-device emulated grid can only be requested
    through XLA_FLAGS before backend init — so bench_shard.py runs in a
    fresh interpreter and this wrapper just relays its result."""
    import os
    import subprocess
    import sys as _sys

    script = os.path.join(os.path.dirname(__file__), "bench_shard.py")
    t0 = time.perf_counter()
    proc = subprocess.run([_sys.executable, script], text=True,
                          capture_output=True)
    _sys.stdout.write(proc.stdout)
    if proc.returncode:
        _sys.stderr.write(proc.stderr)
        raise SystemExit(f"bench_shard failed ({proc.returncode})")
    _rows("mesh_shard_total", (time.perf_counter() - t0) * 1e6,
          "devices=8,see=BENCH_shard.json")


BENCHES = [tbl_codec, tbl_pipeline, tbl_compression, fig8_memory,
           fig10_pipelines, plan_vs_uniform, flash_fwd_bwd, flash_decode,
           serve_trace, mesh_shard, obs_overhead, fig9_time_acc]


def main() -> None:
    import sys
    wanted = set(sys.argv[1:])
    benches = [b for b in BENCHES if not wanted or b.__name__ in wanted]
    if wanted and not benches:
        raise SystemExit(f"unknown benchmark(s) {sorted(wanted)}; "
                         f"known: {[b.__name__ for b in BENCHES]}")
    print("name,us_per_call,derived")
    for b in benches:
        t0 = time.time()
        b()
        print(f"# {b.__name__} done in {time.time()-t0:.1f}s", flush=True)


if __name__ == "__main__":
    main()
