"""Perf-iteration driver: compile one cell under a named variant and print
the roofline terms (used to produce EXPERIMENTS.md §Perf).

    PYTHONPATH=src python -m benchmarks.hillclimb <arch> <shape> <variant>

Variants (composable with '+'):
  baseline        paper-faithful: S-C 'full' remat, bf16 M-P, TP experts,
                  int8 KV cache
  normbf16        bf16-cotangent RMSNorm (halves TP dx all-reduce width)
  dots            remat policy 'dots_nobatch' (save matmul outs, less
                  recompute)
  cechunk         chunked cross-entropy (512-token chunks)
  ep              MoE expert parallelism (experts sharded, full FFN width)
  seg2/seg4       S-C segment size 2/4 (checkpoint every 2nd/4th layer)
  budget<MB>      profile-driven RematPlan solved to fit <MB> MiB of
                  activations per microbatch (repro.plan; e.g. budget512)
"""
from __future__ import annotations

import sys

import os
os.environ["XLA_FLAGS"] = (os.environ.get("XLA_FLAGS", "")
                           + " --xla_force_host_platform_device_count=512").strip()

import dataclasses as dc


def apply_variant(cfg, variant: str):
    """Returns (cfg, train_kwargs)."""
    from repro.core.checkpoint import CheckpointConfig
    tags = variant.split("+")
    remat = CheckpointConfig(enabled=True, policy="full", segment_size=1)
    ce_chunk = 0
    mem_budget_mb = 0
    for t in tags:
        if t in ("baseline", ""):
            continue
        elif t.startswith("budget"):
            mem_budget_mb = int(t[len("budget"):])
        elif t == "normbf16":
            cfg = dc.replace(cfg, norm_bf16_grad=True)
        elif t == "dots":
            remat = dc.replace(remat, policy="dots_nobatch")
        elif t == "savear":
            remat = dc.replace(remat, save_names=("attn_out", "ffn_out"))
        elif t == "cechunk":
            ce_chunk = 512
        elif t == "ep":
            cfg = dc.replace(cfg, moe=dc.replace(cfg.moe, expert_mode="ep"))
        elif t.startswith("seg"):
            remat = dc.replace(remat, segment_size=int(t[3:]))
        elif t == "mesh32x8":
            import repro.launch.mesh as _mesh2
            _mesh2.make_production_mesh = (
                lambda *, multi_pod=False, _mk=_mesh2.make_mesh: _mk(
                    (32, 8), ("data", "model")))
        elif t == "mesh256x1":
            import repro.launch.mesh as _mesh
            _mesh.make_production_mesh = (
                lambda *, multi_pod=False, _mk=_mesh.make_mesh: _mk(
                    (256, 1), ("data", "model")))
        elif t == "dponly":
            # tiny models: drop TP entirely (replicate over the model axis);
            # only the DP weight-grad all-reduce remains
            from jax.sharding import PartitionSpec as P
            import jax as _jax
            import repro.distributed.sharding as _shd

            def all_repl(cfg2, params_shape):
                return _jax.tree_util.tree_map(lambda _: P(), params_shape)
            _shd.param_specs = all_repl
        elif t == "twotier":
            import repro.models.transformer as tr
            tr.init_cache = (lambda cfg2, b, s, quantized=True,
                             dtype=None, _f=tr.init_cache_two_tier:
                             _f(cfg2, b, s, quantized=quantized))

            def decode_patched(params, cfg2, cache, tokens_t, *, policy,
                               quantized=True, kvq_backend="ref",
                               scan_unroll=1, mesh=None, enc_out=None,
                               _f=tr.decode_step_two_tier):
                return _f(params, cfg2, cache, tokens_t, policy=policy,
                          quantized=quantized, kvq_backend=kvq_backend,
                          mesh=mesh)
            tr.decode_step = decode_patched
        else:
            raise ValueError(f"unknown variant tag {t!r}")
    return cfg, dict(remat=remat, ce_chunk=ce_chunk,
                     mem_budget_mb=mem_budget_mb)


def main():
    arch, shape, variant = sys.argv[1], sys.argv[2], sys.argv[3]
    import repro.configs as C
    from repro.launch import dryrun as dr
    import repro.launch.mesh as mesh_mod
    import repro.train.train_step as ts

    base_cfg = C.get_config(arch)
    cfg, kw = apply_variant(base_cfg, variant)

    # patch the config registry + TrainConfig defaults for this run
    C.get_config = lambda a, _c=cfg: _c
    orig_tc = ts.TrainConfig

    def patched_tc(*a, **k):
        k.setdefault("remat", kw["remat"])
        k.setdefault("mem_budget_mb", kw["mem_budget_mb"])
        return orig_tc(*a, **k)
    ts.TrainConfig = patched_tc

    if kw["ce_chunk"]:
        import repro.models.transformer as tr
        orig_loss = tr.loss_fn

        def loss_patched(*a, **k2):
            k2.setdefault("ce_chunk", kw["ce_chunk"])
            return orig_loss(*a, **k2)
        tr.loss_fn = loss_patched

    mesh = mesh_mod.make_production_mesh()
    r = dr.dryrun_cell(arch, shape, mesh, verbose=True)
    print(f"VARIANT={variant} compute={r['compute_s']*1e3:.1f}ms "
          f"memory_lb={r['memory_s']*1e3:.1f}ms "
          f"collective={r['collective_s']*1e3:.1f}ms "
          f"useful={r['useful_flops_frac']:.2f} "
          f"raw_coll={r['raw_uncorrected']['coll']/1e9:.2f}GB")


if __name__ == "__main__":
    main()
