"""Shared fixtures + a per-test wall-clock timeout.

The timeout (ISSUE 8 satellite) guards tier-1 against the failure mode
the fleet work makes possible: a router/engine loop that deadlocks
instead of failing.  It is SIGALRM-based (no pytest-timeout dependency;
main-thread only, POSIX only — both true for this suite) and covers
setup + call of every test.  Override per-run with
``REPRO_TEST_TIMEOUT_S`` (0 disables; default 300 s — the slowest
legitimate tests are module-scoped engine warmups well under 120 s).
"""
import os
import signal

import numpy as np
import pytest

_TIMEOUT_S = int(os.environ.get("REPRO_TEST_TIMEOUT_S", "300"))
_HAVE_ALARM = hasattr(signal, "SIGALRM")


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


class _TestTimeout(Exception):
    pass


def _install(item, phase):
    def _fire(signum, frame):
        raise _TestTimeout(
            f"{item.nodeid} exceeded {_TIMEOUT_S}s during {phase} "
            f"(REPRO_TEST_TIMEOUT_S to adjust; 0 disables)")
    prev = signal.signal(signal.SIGALRM, _fire)
    signal.alarm(_TIMEOUT_S)
    return prev


def _uninstall(prev):
    signal.alarm(0)
    signal.signal(signal.SIGALRM, prev)


@pytest.hookimpl(hookwrapper=True)
def pytest_runtest_setup(item):
    if not _HAVE_ALARM or _TIMEOUT_S <= 0:
        yield
        return
    prev = _install(item, "setup")
    try:
        yield
    finally:
        _uninstall(prev)


@pytest.hookimpl(hookwrapper=True)
def pytest_runtest_call(item):
    if not _HAVE_ALARM or _TIMEOUT_S <= 0:
        yield
        return
    prev = _install(item, "call")
    try:
        yield
    finally:
        _uninstall(prev)
