"""S-C (remat) core: gradient equivalence, segment placement DP, policies."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.core.checkpoint import (CheckpointConfig, checkpoint_sequential,
                                   optimal_segments, remat_scan)


def _layer_fns(n, width=4):
    return [lambda x, i=i: jnp.tanh(x @ jnp.full((width, width), 0.08 + 0.01 * i))
            for i in range(n)]


class TestCheckpointSequential:
    @pytest.mark.parametrize("n_layers,segments", [(4, 2), (6, 3), (6, 6), (5, 1)])
    def test_grad_equivalence(self, n_layers, segments):
        fns = _layer_fns(n_layers)
        x = jnp.linspace(-1, 1, 8).reshape(2, 4)

        def plain(x):
            for f in fns:
                x = f(x)
            return x.sum()

        ck = checkpoint_sequential(fns, segments)
        g1 = jax.grad(plain)(x)
        g2 = jax.grad(lambda x: ck(x).sum())(x)
        np.testing.assert_allclose(g1, g2, rtol=1e-6)

    def test_explicit_boundaries(self):
        fns = _layer_fns(5)
        ck = checkpoint_sequential(fns, 0, boundaries=[2, 4])
        x = jnp.ones((2, 4))
        y = ck(x)
        def plain(x):
            for f in fns:
                x = f(x)
            return x
        np.testing.assert_allclose(y, plain(x), rtol=1e-6)


class TestRematScan:
    @pytest.mark.parametrize("segment_size", [1, 2, 4])
    def test_segmented_scan_matches(self, segment_size):
        n = 4
        w = jnp.stack([jnp.eye(4) * (0.9 + 0.01 * i) for i in range(n)])

        def body(c, wi):
            return jnp.tanh(c @ wi), c.sum()

        x = jnp.ones((2, 4))
        ref, ys_ref = jax.lax.scan(body, x, w)
        cfg = CheckpointConfig(enabled=True, segment_size=segment_size)
        out, ys = remat_scan(body, x, w, config=cfg)
        np.testing.assert_allclose(ref, out, rtol=1e-6)
        np.testing.assert_allclose(np.asarray(ys_ref),
                                   np.asarray(ys).reshape(-1), rtol=1e-6)

    def test_grads_match_plain_scan(self):
        n = 6
        w = jnp.stack([jnp.eye(3) * 0.9 for _ in range(n)])
        x = jnp.ones((3,))

        def loss(x, w, seg):
            cfg = CheckpointConfig(enabled=seg > 0, segment_size=max(seg, 1))
            def body(c, wi):
                return jnp.tanh(c @ wi), None
            out, _ = remat_scan(body, x, w, config=cfg)
            return out.sum()

        g0 = jax.grad(loss)(x, w, 0)
        for seg in (1, 2, 3):
            np.testing.assert_allclose(jax.grad(loss)(x, w, seg), g0, rtol=1e-6)

    def test_indivisible_segment_falls_back(self):
        """Odd layer counts degrade to the largest divisor, not an error."""
        w = jnp.stack([jnp.eye(2) * 0.9 for _ in range(5)])
        x = jnp.ones((2,))
        out, _ = remat_scan(lambda c, wi: (jnp.tanh(c @ wi), None), x, w,
                            config=CheckpointConfig(segment_size=2))
        ref, _ = jax.lax.scan(lambda c, wi: (jnp.tanh(c @ wi), None), x, w)
        np.testing.assert_allclose(out, ref, rtol=1e-6)


class TestOptimalSegments:
    def test_prefers_narrow_layers(self):
        # UNet-like profile (paper Fig. 11): bottleneck in the middle
        sizes = [100, 50, 4, 50, 100]
        b = optimal_segments(sizes, 1)
        assert b == [3]  # checkpoint right after the narrow layer

    @given(st.lists(st.integers(1, 100), min_size=3, max_size=12),
           st.integers(1, 4))
    @settings(deadline=None, max_examples=30)
    def test_boundaries_valid_and_beats_worst(self, sizes, k):
        b = optimal_segments(sizes, k)
        n = len(sizes)
        assert all(0 < x < n for x in b)
        assert len(b) == len(set(b)) <= k
        # objective never exceeds the no-checkpoint peak (sum of all)
        prefix = np.concatenate([[0], np.cumsum(sizes)])
        bounds = [0, *sorted(b), n]
        stored = sum(sizes[x - 1] for x in b)
        max_seg = max(prefix[hi] - prefix[lo]
                      for lo, hi in zip(bounds[:-1], bounds[1:]))
        assert stored + max_seg <= sum(sizes) + max(sizes)
