"""S-C (remat) core: gradient equivalence, segment placement DP, policies."""
import warnings

import jax
import jax.numpy as jnp
import numpy as np
import pytest

try:
    from hypothesis import given, settings, strategies as st
except ImportError:  # deterministic fixed-seed fallback (requirements-dev)
    from hypothesis_fallback import given, settings, strategies as st

from repro.core.checkpoint import (CheckpointConfig, checkpoint_sequential,
                                   optimal_segments, remat_scan)
from repro.plan import RematPlan


def _layer_fns(n, width=4):
    return [lambda x, i=i: jnp.tanh(x @ jnp.full((width, width), 0.08 + 0.01 * i))
            for i in range(n)]


class TestCheckpointSequential:
    @pytest.mark.parametrize("n_layers,segments", [(4, 2), (6, 3), (6, 6), (5, 1)])
    def test_grad_equivalence(self, n_layers, segments):
        fns = _layer_fns(n_layers)
        x = jnp.linspace(-1, 1, 8).reshape(2, 4)

        def plain(x):
            for f in fns:
                x = f(x)
            return x.sum()

        ck = checkpoint_sequential(fns, segments)
        g1 = jax.grad(plain)(x)
        g2 = jax.grad(lambda x: ck(x).sum())(x)
        np.testing.assert_allclose(g1, g2, rtol=1e-6)

    def test_explicit_boundaries(self):
        fns = _layer_fns(5)
        ck = checkpoint_sequential(fns, 0, boundaries=[2, 4])
        x = jnp.ones((2, 4))
        y = ck(x)
        def plain(x):
            for f in fns:
                x = f(x)
            return x
        np.testing.assert_allclose(y, plain(x), rtol=1e-6)


class TestRematScan:
    @pytest.mark.parametrize("segment_size", [1, 2, 4])
    def test_segmented_scan_matches(self, segment_size):
        n = 4
        w = jnp.stack([jnp.eye(4) * (0.9 + 0.01 * i) for i in range(n)])

        def body(c, wi):
            return jnp.tanh(c @ wi), c.sum()

        x = jnp.ones((2, 4))
        ref, ys_ref = jax.lax.scan(body, x, w)
        cfg = CheckpointConfig(enabled=True, segment_size=segment_size)
        out, ys = remat_scan(body, x, w, config=cfg)
        np.testing.assert_allclose(ref, out, rtol=1e-6)
        np.testing.assert_allclose(np.asarray(ys_ref),
                                   np.asarray(ys).reshape(-1), rtol=1e-6)

    def test_grads_match_plain_scan(self):
        n = 6
        w = jnp.stack([jnp.eye(3) * 0.9 for _ in range(n)])
        x = jnp.ones((3,))

        def loss(x, w, seg):
            cfg = CheckpointConfig(enabled=seg > 0, segment_size=max(seg, 1))
            def body(c, wi):
                return jnp.tanh(c @ wi), None
            out, _ = remat_scan(body, x, w, config=cfg)
            return out.sum()

        g0 = jax.grad(loss)(x, w, 0)
        for seg in (1, 2, 3):
            np.testing.assert_allclose(jax.grad(loss)(x, w, seg), g0, rtol=1e-6)

    def test_indivisible_segment_falls_back(self):
        """Odd layer counts degrade to the largest divisor, not an error."""
        w = jnp.stack([jnp.eye(2) * 0.9 for _ in range(5)])
        x = jnp.ones((2,))
        with pytest.warns(UserWarning, match="does not divide"):
            out, _ = remat_scan(lambda c, wi: (jnp.tanh(c @ wi), None), x, w,
                                config=CheckpointConfig(segment_size=2))
        ref, _ = jax.lax.scan(lambda c, wi: (jnp.tanh(c @ wi), None), x, w)
        np.testing.assert_allclose(out, ref, rtol=1e-6)

    def test_indivisible_uses_largest_divisor_not_gcd(self):
        """Regression: 48 layers @ segment 5 must degrade to 4 (largest
        divisor <= 5), NOT gcd(48, 5) == 1 == per-layer remat."""
        n = 48
        w = jnp.stack([jnp.eye(2) * (0.9 + 0.001 * i) for i in range(n)])
        x = jnp.ones((2,))
        body = lambda c, wi: (jnp.tanh(c @ wi), None)  # noqa: E731
        with pytest.warns(UserWarning, match=r"using largest divisor 4"):
            out, _ = remat_scan(body, x, w,
                                config=CheckpointConfig(segment_size=5))
        ref, _ = jax.lax.scan(body, x, w)
        np.testing.assert_allclose(out, ref, rtol=1e-6)
        # a dividing segment_size stays silent
        with warnings.catch_warnings():
            warnings.simplefilter("error")
            remat_scan(body, x, w, config=CheckpointConfig(segment_size=6))

    @pytest.mark.parametrize("boundaries", [(), (3,), (2, 5), (1, 2, 3, 6)])
    def test_plan_scan_matches_plain(self, boundaries):
        """Non-uniform planned segments: values, ys stacking and grads all
        match the plain scan."""
        n = 7
        w = jnp.stack([jnp.eye(3) * (0.9 + 0.01 * i) for i in range(n)])
        x = jnp.ones((3,))

        def body(c, wi):
            return jnp.tanh(c @ wi), c.sum()

        cfg = CheckpointConfig(plan=RematPlan(n, boundaries))
        ref, ys_ref = jax.lax.scan(body, x, w)
        out, ys = remat_scan(body, x, w, config=cfg)
        np.testing.assert_allclose(ref, out, rtol=1e-6)
        np.testing.assert_allclose(np.asarray(ys_ref), np.asarray(ys),
                                   rtol=1e-6)
        g0 = jax.grad(lambda x: jax.lax.scan(body, x, w)[0].sum())(x)
        g1 = jax.grad(
            lambda x: remat_scan(body, x, w, config=cfg)[0].sum())(x)
        np.testing.assert_allclose(g0, g1, rtol=1e-6)

    def test_plan_depth_mismatch_rejected(self):
        w = jnp.stack([jnp.eye(2)] * 4)
        with pytest.raises(ValueError, match="solved for 6 layers"):
            remat_scan(lambda c, wi: (c @ wi, None), jnp.ones((2,)), w,
                       config=CheckpointConfig(plan=RematPlan(6, (2,))))


class TestOptimalSegments:
    def test_prefers_narrow_layers(self):
        # UNet-like profile (paper Fig. 11): bottleneck in the middle
        sizes = [100, 50, 4, 50, 100]
        b = optimal_segments(sizes, 1)
        assert b == [3]  # checkpoint right after the narrow layer

    @given(st.lists(st.integers(1, 100), min_size=3, max_size=12),
           st.integers(1, 4))
    @settings(deadline=None, max_examples=30)
    def test_boundaries_valid_and_beats_worst(self, sizes, k):
        b = optimal_segments(sizes, k)
        n = len(sizes)
        assert all(0 < x < n for x in b)
        assert len(b) == len(set(b)) <= k
        # objective never exceeds the no-checkpoint peak (sum of all)
        prefix = np.concatenate([[0], np.cumsum(sizes)])
        bounds = [0, *sorted(b), n]
        stored = sum(sizes[x - 1] for x in b)
        max_seg = max(prefix[hi] - prefix[lo]
                      for lo, hi in zip(bounds[:-1], bounds[1:]))
        assert stored + max_seg <= sum(sizes) + max(sizes)
