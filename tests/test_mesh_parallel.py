"""Mesh-parallel hot paths: sharding rules, per-device budgets, and
multi-device parity.

Rule tests run on abstract meshes (any device count).  Parity tests need 8
real devices — CI provides them via
``XLA_FLAGS=--xla_force_host_platform_device_count=8``; on a bare 1-device
checkout they skip.
"""
import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from jax.sharding import PartitionSpec as P

from repro import configs
from repro.distributed import sharding as shd
from repro.launch.mesh import abstract_mesh, make_mesh
from repro.models import transformer

multidevice = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs 8 devices (XLA_FLAGS=--xla_force_host_platform_"
           "device_count=8)")

MESH_SHAPES = [(1, 1), (2, 1), (1, 2), (2, 4), (1, 8)]


def _mesh8(shape=(1, 8)):
    return make_mesh(shape, ("data", "model"))


# -- sharding rules (abstract meshes, run everywhere) ----------------------
class TestParamSpecsOnMesh:
    @pytest.mark.parametrize("arch", configs.list_archs())
    @pytest.mark.parametrize("mesh_shape", MESH_SHAPES)
    def test_every_config_resolves_to_valid_specs(self, arch, mesh_shape):
        """The divisibility fallback makes the production rule table legal
        on ANY mesh: every 'model'-sharded dim divides the model axis."""
        cfg = configs.get_config(arch)
        mesh = abstract_mesh(mesh_shape, ("data", "model"))
        sds = jax.eval_shape(
            lambda: transformer.init_params(cfg, jax.random.PRNGKey(0)))
        specs = shd.param_specs(cfg, sds, mesh=mesh)
        flat_s = jax.tree_util.tree_leaves(
            specs, is_leaf=lambda x: isinstance(x, P))
        flat_p = jax.tree_util.tree_leaves(sds)
        assert len(flat_s) == len(flat_p)
        n_model = mesh.shape["model"]
        for spec, leaf in zip(flat_s, flat_p):
            for ax, name in enumerate(spec):
                if name == "model":
                    assert leaf.shape[ax] % n_model == 0, (spec, leaf.shape)

    def test_fallback_replicates_non_dividing_dims(self):
        """llama3 kv projection: n_kv * head_dim = 1024 divides 2 but the
        smoke config's 64 does not divide e.g. 48 — pick a width that
        forces the fallback and check the raw rule still shards."""
        cfg = configs.get_config("llama3-8b")
        sds = jax.eval_shape(
            lambda: transformer.init_params(cfg, jax.random.PRNGKey(0)))
        raw = shd.param_specs(cfg, sds)
        # production rules shard wk's last dim
        assert raw["blocks"]["attn"]["wk"][-1] == "model"
        odd = abstract_mesh((1, 3), ("data", "model"))
        fitted = shd.param_specs(cfg, sds, mesh=odd)
        wk_dim = sds["blocks"]["attn"]["wk"].shape[-1]
        expect = "model" if wk_dim % 3 == 0 else None
        assert fitted["blocks"]["attn"]["wk"][-1] == expect

    @pytest.mark.parametrize("mesh_shape", MESH_SHAPES)
    def test_ssm_replicated_on_every_mesh(self, mesh_shape):
        cfg = configs.get_config("mamba2-130m")
        mesh = abstract_mesh(mesh_shape, ("data", "model"))
        sds = jax.eval_shape(
            lambda: transformer.init_params(cfg, jax.random.PRNGKey(0)))
        specs = shd.param_specs(cfg, sds, mesh=mesh)
        for s in jax.tree_util.tree_leaves(
                specs["blocks"]["ssm"], is_leaf=lambda x: isinstance(x, P)):
            assert s == P()


class TestFlashShardSpecs:
    def test_trivial_mesh_opts_out(self):
        assert shd.flash_shard_specs(None, 8, 8, 8) is None
        mesh = abstract_mesh((1, 1), ("data", "model"))
        assert shd.flash_shard_specs(mesh, 8, 8, 8) is None

    def test_heads_and_batch_shard_when_divisible(self):
        mesh = abstract_mesh((2, 4), ("data", "model"))
        spec = shd.flash_shard_specs(mesh, batch=8, heads=8, kv_heads=4)
        assert spec == P("data", "model", None, None)

    def test_gqa_misaligned_heads_fall_back_to_batch(self):
        # kv_heads=2 doesn't divide model=4: head sharding would split a
        # GQA group across shards, so only the batch axis shards
        mesh = abstract_mesh((2, 4), ("data", "model"))
        spec = shd.flash_shard_specs(mesh, batch=8, heads=8, kv_heads=2)
        assert spec == P("data", None, None, None)

    def test_nothing_divides_means_none(self):
        mesh = abstract_mesh((2, 4), ("data", "model"))
        assert shd.flash_shard_specs(mesh, batch=3, heads=6, kv_heads=3) \
            is None


class TestServeKvShard:
    def test_mode_table(self):
        mesh = abstract_mesh((1, 8), ("data", "model"))
        assert shd.serve_kv_shard(None, 8, 64) == "none"
        assert shd.serve_kv_shard(
            abstract_mesh((8, 1), ("data", "model")), 8, 64) == "none"
        assert shd.serve_kv_shard(mesh, 8, 64) == "heads"
        assert shd.serve_kv_shard(mesh, 2, 64) == "seq"   # hkv fallback
        assert shd.serve_kv_shard(mesh, 2, 63) == "none"  # nothing divides

    @pytest.mark.parametrize("arch", configs.list_archs())
    @pytest.mark.parametrize("mesh_shape", MESH_SHAPES)
    def test_cache_specs_follow_the_rule(self, arch, mesh_shape):
        """serve_cache_specs must agree with serve_kv_shard for every
        config, and the slot axis must never shard."""
        cfg = configs.get_config(arch)
        mesh = abstract_mesh(mesh_shape, ("data", "model"))
        cache = jax.eval_shape(
            lambda: transformer.init_cache(cfg, 4, 128, quantized=True))
        specs = shd.serve_cache_specs(cfg, cache, mesh)
        for name, spec in specs.items():
            leaf = cache[name]
            # slot (batch) axis is never sharded
            if len(leaf.shape) >= 2:
                assert len(spec) < 2 or spec[1] is None, (name, spec)
            if name in ("k", "v") and len(leaf.shape) == 5:
                mode = shd.serve_kv_shard(mesh, leaf.shape[2], leaf.shape[3])
                want = {"heads": P(None, None, "model", None, None),
                        "seq": P(None, None, None, "model", None),
                        "none": P()}[mode]
                assert spec == want, (name, mode, spec)
            elif name not in ("k_scale", "v_scale"):
                assert spec == P(), (name, spec)

    def test_spec_shards_counts_devices(self):
        mesh = abstract_mesh((2, 4), ("data", "model"))
        assert shd.spec_shards(mesh, P()) == 1
        assert shd.spec_shards(mesh, P(None, "model")) == 4
        assert shd.spec_shards(mesh, P("data", "model")) == 8
        assert shd.spec_shards(mesh, P(("data", "model"))) == 8


# -- per-device planner budgets (abstract, run everywhere) -----------------
class TestPerDeviceBudgets:
    def test_attn_residuals_divide_by_model_shards(self):
        from repro.plan import profile_transformer
        cfg = configs.smoke_config("llama3-8b")
        sds = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
        p1 = profile_transformer(cfg, sds, dtype_bytes=4)
        p2 = profile_transformer(cfg, sds, dtype_bytes=4, model_shards=2)
        # smoke llama3: heads=4, kv=2 — both divide 2, residuals halve
        assert all(b2 * 2 == b1 for b1, b2 in
                   zip(p1.resid_bytes, p2.resid_bytes))
        # the (B, S, D) carry is replicated over model: NOT divided
        assert p1.act_bytes == p2.act_bytes

    def test_non_dividing_heads_keep_whole_residuals(self):
        from repro.plan import profile_transformer
        cfg = configs.smoke_config("llama3-8b")   # kv=2 doesn't divide 8
        sds = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
        p1 = profile_transformer(cfg, sds, dtype_bytes=4)
        p8 = profile_transformer(cfg, sds, dtype_bytes=4, model_shards=8)
        assert p1.resid_bytes == p8.resid_bytes

    def test_serve_capacity_scales_with_devices(self):
        """Acceptance: per-device slot capacity x devices >= single-device
        capacity — sharding the cache can only admit MORE total slots."""
        from repro.plan import serve_capacity_report
        cfg = configs.get_config("llama3-8b")
        budget = 8 * 2 ** 30
        r1 = serve_capacity_report(cfg, 4096, budget)
        mesh = abstract_mesh((1, 8), ("data", "model"))
        r8 = serve_capacity_report(cfg, 4096, budget, mesh=mesh)
        assert r8["kv_shard"] == "heads" and r8["model_shards"] == 8
        assert r8["bytes_per_slot_per_device"] * 8 >= r8["bytes_per_slot"]
        assert r8["max_slots"] >= r1["max_slots"]
        # same per-chip budget, 1/8th the bytes pinned per chip per slot
        assert r8["bytes_per_slot_per_device"] <= r1["bytes_per_slot"] // 4

    def test_plan_profile_threads_model_shards(self):
        from repro.train.train_step import TrainConfig, plan_profile
        cfg = configs.smoke_config("llama3-8b")
        sds = {"tokens": jax.ShapeDtypeStruct((8, 64), jnp.int32)}
        tc = TrainConfig(policy="full")
        mesh = abstract_mesh((4, 2), ("data", "model"))
        p1 = plan_profile(cfg, tc, sds)
        p2 = plan_profile(cfg, tc, sds, mesh=mesh)
        # microbatch 8/4 dp + residuals /2 model: strictly smaller profile
        assert p2.total_resid_bytes() < p1.total_resid_bytes()


# -- multi-device parity (8 emulated devices) ------------------------------
@multidevice
class TestTrainParity:
    def test_flash_train_grads_match_single_device(self):
        """Loss and grads on a (4, 2) mesh match the (1, 1) mesh — the
        shard_map'd flash path under remat + scan + grad is exact."""
        from repro.core.mixed_precision import get_policy
        from repro.train import train_step as ts
        cfg = dataclasses.replace(configs.smoke_config("llama3-8b"),
                                  attn_backend="interpret")
        b, s = 8, 64
        rng = np.random.default_rng(0)
        batch = {"tokens": jnp.asarray(
                     rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
                 "labels": jnp.asarray(
                     rng.integers(0, cfg.vocab, (b, s)), jnp.int32)}
        params = transformer.init_params(cfg, jax.random.PRNGKey(0))
        tc = ts.TrainConfig(policy="full")
        pol = get_policy("full")

        def grads_for(mesh):
            def loss(p, mb):
                return transformer.loss_fn(p, cfg, mb, policy=pol,
                                           remat=tc.remat, mesh=mesh)[0]
            p_shard = shd.to_shardings(
                mesh, shd.param_specs(cfg, params, mesh=mesh))
            b_shard = shd.to_shardings(mesh, shd.batch_specs(cfg, batch,
                                                             mesh))
            pp = jax.device_put(params, p_shard)
            bb = jax.device_put(batch, b_shard)
            return jax.jit(jax.value_and_grad(loss),
                           in_shardings=(p_shard, b_shard))(pp, bb)

        l1, g1 = grads_for(make_mesh((1, 1), ("data", "model")))
        l8, g8 = grads_for(make_mesh((4, 2), ("data", "model")))
        assert abs(float(l1) - float(l8)) < 1e-4
        g1, g8 = jax.device_get(g1), jax.device_get(g8)
        diffs = jax.tree_util.tree_map(
            lambda a, b_: float(np.abs(a - b_).max()), g1, g8)
        assert max(jax.tree_util.tree_leaves(diffs)) < 1e-3


@multidevice
class TestServeParity:
    def _trace(self):
        from repro.serve.trace import TraceRequest
        rng = np.random.default_rng(0)
        lens = [(5, 0), (9, 0), (13, 2), (3, 4), (7, 5)]
        return [TraceRequest(prompt=list(rng.integers(1, 200, (pl,))),
                             max_new_tokens=6, arrival_step=st)
                for pl, st in lens]

    def _run(self, cfg, mesh):
        from repro.serve.engine import ServeEngine
        params = transformer.init_params(cfg, jax.random.PRNGKey(0))
        eng = ServeEngine(params, cfg, max_slots=4, max_len=64,
                          prompt_buckets=(8, 16), policy_name="full",
                          mesh=mesh)
        compiles = eng.warmup()
        eng.run(self._trace())
        assert eng.compile_counts() == compiles, "recompile during serving"
        return eng, {r.rid: list(r.tokens) for r in eng._requests_done}

    def _assert_no_cache_gather(self, eng):
        import re
        hlo = eng.decode_hlo()
        k = eng.pool.cache["k"]
        # the smallest gather that could materialize a whole per-layer
        # K slice
        thresh = k.shape[1] * k.shape[2] * k.shape[3] * k.shape[4]
        sizes = {"f32": 4, "s32": 4, "u32": 4, "bf16": 2, "f16": 2,
                 "s8": 1, "u8": 1, "pred": 1}
        bad = []
        for m in re.finditer(r"(\w+)\[([\d,]*)\][^=]*= \S*all-gather", hlo):
            dims = m.group(2)
            n = int(np.prod([int(x) for x in dims.split(",") if x])) \
                if dims else 1
            if sizes.get(m.group(1), 4) * n >= thresh:
                bad.append(m.group(0)[:120])
        assert not bad, bad

    def test_heads_sharded_engine_token_exact(self):
        cfg = dataclasses.replace(configs.smoke_config("llama3-8b"),
                                  n_heads=8, n_kv=8, window=0)
        mesh = _mesh8()
        assert shd.serve_kv_shard(mesh, cfg.n_kv, 64) == "heads"
        _, t1 = self._run(cfg, None)
        eng, t8 = self._run(cfg, mesh)
        assert t1 == t8
        self._assert_no_cache_gather(eng)

    def test_seq_sharded_engine_token_exact(self):
        cfg = dataclasses.replace(configs.smoke_config("llama3-8b"),
                                  window=0)
        mesh = _mesh8()
        assert shd.serve_kv_shard(mesh, cfg.n_kv, 64) == "seq"
        _, t1 = self._run(cfg, None)
        eng, t8 = self._run(cfg, mesh)
        assert t1 == t8
        self._assert_no_cache_gather(eng)


@multidevice
class TestSeqShardedDecodeCollective:
    def _setup(self):
        from repro.kernels.kvq import ref as kvq_ref
        b, h, hkv, s, d = 3, 4, 2, 64, 16
        rng = np.random.default_rng(0)
        q = jnp.asarray(rng.normal(size=(b, h, d)), jnp.float32)
        k = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
        v = jnp.asarray(rng.normal(size=(b, hkv, s, d)), jnp.float32)
        kq, ks = kvq_ref.quantize_kv(k)
        vq, vs = kvq_ref.quantize_kv(v)
        kn = jnp.asarray(rng.normal(size=(b, hkv, d)), jnp.float32)
        vn = jnp.asarray(rng.normal(size=(b, hkv, d)), jnp.float32)
        kqn, ksn = kvq_ref.quantize_kv(kn)
        vqn, vsn = kvq_ref.quantize_kv(vn)
        write_at = jnp.asarray([5, 17, 40], jnp.int32)

        def wr(c, n, at):
            return jax.vmap(lambda cc, nn, a: jax.lax.dynamic_update_slice(
                cc, nn[:, None], (0, a, 0)[:cc.ndim]))(c, n, at)

        ck, csk = wr(kq, kqn, write_at), wr(ks, ksn, write_at)
        cv, csv = wr(vq, vqn, write_at), wr(vs, vsn, write_at)
        ref = kvq_ref.decode_attention_ref(
            q.reshape(b, hkv, h // hkv, d), ck, csk, cv, csv, None,
            d ** -0.5, lengths=write_at + 1).reshape(b, h, d)
        return (q, kq, ks, vq, vs, (kqn, ksn, vqn, vsn), write_at, d,
                (ck, csk, cv, csv), ref)

    def test_lengths_path_matches_oracle(self):
        from repro.distributed import collectives
        (q, kq, ks, vq, vs, new, at, d, written, ref) = self._setup()
        out, ck, csk, cv, csv = collectives.sp_decode_attention_int8(
            q, kq, ks, vq, vs, new, at, _mesh8(), sm_scale=d ** -0.5,
            lengths=at + 1)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5)
        # the sharded in-place write produced the same cache
        np.testing.assert_array_equal(np.asarray(ck), np.asarray(written[0]))
        np.testing.assert_array_equal(np.asarray(csv),
                                      np.asarray(written[3]))

    def test_bias_path_matches_oracle(self):
        from repro.distributed import collectives
        (q, kq, ks, vq, vs, new, at, d, _w, ref) = self._setup()
        s = kq.shape[2]
        bias = jnp.where(jnp.arange(s)[None, :] < (at + 1)[:, None],
                         0.0, -1e30).astype(jnp.float32)
        out, *_ = collectives.sp_decode_attention_int8(
            q, kq, ks, vq, vs, new, at, _mesh8(), sm_scale=d ** -0.5,
            bias=bias)
        np.testing.assert_allclose(np.asarray(out), np.asarray(ref),
                                   atol=1e-5)


@multidevice
class TestCompressedPsumGrads:
    def _grads(self):
        rng = np.random.default_rng(3)
        # realistic post-backward magnitudes: int8 quantization noise on
        # N(0,1)-scale grads would swamp the 1e-2 parity bound
        return {"w": jnp.asarray(rng.normal(size=(8, 32, 16)) * 0.4,
                                 jnp.float32),
                "b": jnp.asarray(rng.normal(size=(8, 16)) * 0.4,
                                 jnp.float32)}

    def test_matches_plain_psum_mean(self):
        from repro.distributed import collectives
        g = self._grads()
        mesh = make_mesh((8, 1), ("data", "model"))
        out = jax.device_get(collectives.compressed_psum_grads(
            g, mesh, "data", jax.random.PRNGKey(0)))
        plain = jax.device_get(
            jax.tree_util.tree_map(lambda x: x.mean(0), g))
        diffs = jax.tree_util.tree_map(
            lambda a, b: float(np.abs(a - b).max()), out, plain)
        assert max(jax.tree_util.tree_leaves(diffs)) < 1e-2

    def test_unbiased_over_seeds(self):
        from repro.distributed import collectives
        g = self._grads()
        mesh = make_mesh((8, 1), ("data", "model"))
        plain = jax.device_get(
            jax.tree_util.tree_map(lambda x: x.mean(0), g))
        acc = None
        n = 30
        for i in range(n):
            o = jax.device_get(collectives.compressed_psum_grads(
                g, mesh, "data", jax.random.PRNGKey(i)))
            acc = o if acc is None else \
                jax.tree_util.tree_map(np.add, acc, o)
        errs = jax.tree_util.tree_map(
            lambda a, p: float(np.abs(a / n - p).max()), acc, plain)
        assert max(jax.tree_util.tree_leaves(errs)) < 2e-3
