"""Split-K flash decode over the int8 KV cache (ISSUE 4): parity vs the
ref oracle across ragged lengths / GQA / MQA / windowed-tier caches and
split counts, the split-K merge oracle, measured-vs-analytic tile-step
counters, the >=70% ragged skip-ratio acceptance, the no-bias jaxpr
contract on every backend, decode_step / two-tier integration, the
planner's decode report vs measured counts, and the serve CLI flags."""
import os
import subprocess
import sys

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs, plan
from repro.kernels import tiling
from repro.kernels.kvq import kernel as DK, ops as DO, ref as DR
from repro.models import transformer

RNG = np.random.default_rng(11)


def _cache(b, hkv, s, d):
    k = jnp.asarray(RNG.normal(size=(b, hkv, s, d)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(b, hkv, s, d)).astype(np.float32))
    kq, ks = DR.quantize_kv(k)
    vq, vs = DR.quantize_kv(v)
    return kq, ks, vq, vs


def _q(b, h, d):
    return jnp.asarray(RNG.normal(size=(b, h, d)).astype(np.float32))


# (b, h, hkv, s, d, splits, block_s) — MHA/GQA/MQA, ragged tile counts,
# split counts that don't divide the tile count, splits > tiles (clamped),
# and a window-tier-sized cache (s == W, the two-tier rolling geometry)
CASES = [
    (1, 4, 4, 512, 64, 1, 512),       # MHA, sequential baseline
    (2, 8, 2, 1024, 64, 4, 256),      # GQA 4:1, even split
    (2, 8, 1, 512, 128, 2, 128),      # MQA
    (3, 6, 2, 768, 32, 3, 256),       # odd batch, ns == splits
    (2, 8, 2, 768, 64, 2, 256),       # splits don't divide ns (3 tiles)
    (2, 4, 2, 256, 16, 8, 64),        # splits > ns -> clamped
    (2, 4, 2, 256, 64, 2, 128),       # windowed tier: W-slot rolling cache
    (1, 4, 2, 2048, 64, 3, 512),      # ns=4, splits=3 -> empty last shard
]


class TestSplitKParity:
    @pytest.mark.parametrize("b,h,hkv,s,d,splits,bs", CASES)
    def test_ragged_lengths_match_ref(self, b, h, hkv, s, d, splits, bs):
        q = _q(b, h, d)
        kq, ks, vq, vs = _cache(b, hkv, s, d)
        lengths = jnp.asarray(RNG.integers(1, s + 1, (b,)), jnp.int32)
        o_ref = DO.decode_attention(q, kq, ks, vq, vs, lengths=lengths,
                                    backend="ref")
        o_int = DO.decode_attention(q, kq, ks, vq, vs, lengths=lengths,
                                    backend="interpret", splits=splits,
                                    block_s=bs)
        np.testing.assert_allclose(np.asarray(o_int), np.asarray(o_ref),
                                   atol=1e-3)

    @pytest.mark.parametrize("b,h,hkv,s,d,splits,bs", CASES)
    def test_splitk_oracle_matches_ref(self, b, h, hkv, s, d, splits, bs):
        """The pure-jnp split/merge oracle must agree with the one-shot
        softmax — the merge arithmetic has its own ground truth."""
        q = _q(b, h, d)
        kq, ks, vq, vs = _cache(b, hkv, s, d)
        lengths = jnp.asarray(RNG.integers(1, s + 1, (b,)), jnp.int32)
        g = h // hkv
        qg = q.astype(jnp.float32).reshape(b, hkv, g, d)
        o_ref = DR.decode_attention_ref(qg, kq, ks, vq, vs, None, d ** -0.5,
                                        lengths=lengths)
        o_sk = DR.decode_attention_splitk_ref(qg, kq, ks, vq, vs, d ** -0.5,
                                              lengths=lengths, block_s=bs,
                                              splits=splits)
        np.testing.assert_allclose(np.asarray(o_sk), np.asarray(o_ref),
                                   atol=1e-4)

    def test_no_mask_and_bias_paths_with_splits(self):
        b, h, hkv, s, d = 2, 4, 2, 512, 64
        q = _q(b, h, d)
        kq, ks, vq, vs = _cache(b, hkv, s, d)
        o_ref = DO.decode_attention(q, kq, ks, vq, vs, backend="ref")
        o_int = DO.decode_attention(q, kq, ks, vq, vs, backend="interpret",
                                    splits=4, block_s=128)
        np.testing.assert_allclose(np.asarray(o_int), np.asarray(o_ref),
                                   atol=1e-3)
        # dense-bias fallback (masks lengths can't express) on the split grid
        bias = jnp.where(jnp.arange(s)[None, :] % 3 != 0, 0.0, -1e30
                         ).astype(jnp.float32)
        bias = jnp.broadcast_to(bias, (b, s))
        o_ref = DO.decode_attention(q, kq, ks, vq, vs, bias=bias,
                                    backend="ref")
        o_int = DO.decode_attention(q, kq, ks, vq, vs, bias=bias,
                                    backend="interpret", splits=2,
                                    block_s=256)
        np.testing.assert_allclose(np.asarray(o_int), np.asarray(o_ref),
                                   atol=1e-3)

    def test_lengths_and_bias_are_exclusive(self):
        b, h, hkv, s, d = 1, 2, 2, 256, 16
        q = _q(b, h, d)
        kq, ks, vq, vs = _cache(b, hkv, s, d)
        with pytest.raises(ValueError, match="exclusive"):
            DO.decode_attention(q, kq, ks, vq, vs,
                                lengths=jnp.ones((b,), jnp.int32),
                                bias=jnp.zeros((b, s)))
        with pytest.raises(ValueError, match="debug_counts"):
            DO.decode_attention(q, kq, ks, vq, vs, backend="ref",
                                debug_counts=True)


class TestDecodeCounters:
    """Measured ``debug_counts`` == ``tiling.decode_tile_step_counts``,
    tile-for-tile per (batch row, split), identical across KV heads."""

    def _measure(self, s, lengths, *, splits, bs, b=None, hkv=2, d=32):
        b = len(lengths) if b is None else b
        q = _q(b, 2 * hkv, d)
        kq, ks, vq, vs = _cache(b, hkv, s, d)
        _, cnt = DO.decode_attention(
            q, kq, ks, vq, vs,
            lengths=None if lengths is None else jnp.asarray(lengths),
            backend="interpret", splits=splits, block_s=bs,
            debug_counts=True)
        return np.asarray(cnt)                         # (B, Hkv, splits)

    @pytest.mark.parametrize("s,lengths,splits,bs", [
        (512, [1, 512], 1, 512),
        (512, [100, 300, 512], 2, 128),
        (1024, [1000, 17], 4, 256),
        (768, [768, 700, 5], 3, 256),
        (512, None, 4, 128),                 # no lengths: every tile visited
        (256, [64, 256], 8, 64),             # splits clamped to ns=4
    ])
    def test_counters_match_analytic(self, s, lengths, splits, bs):
        b = 2 if lengths is None else len(lengths)
        cnt = self._measure(s, lengths, splits=splits, bs=bs, b=b)
        c = tiling.decode_tile_step_counts(s, lengths, block_s=bs,
                                           splits=splits)
        ana = np.asarray(c["counts"]) if lengths is not None else \
            np.broadcast_to(np.asarray(c["counts"]), (b, c["splits"]))
        assert cnt.shape == (b, cnt.shape[1], c["splits"])
        for i in range(b):
            for j in range(cnt.shape[1]):              # every KV head alike
                np.testing.assert_array_equal(cnt[i, j], ana[i])
        if lengths is None:
            assert int(cnt[0, 0].sum()) == c["ns"]     # dense sweep

    def test_ragged_mean_quarter_skips_70pct(self):
        """Acceptance: a ragged batch with mean length S/4 at S=2048 must
        execute <= 30% of the dense tile-steps."""
        s, bs = 2048, 256
        lengths = [256, 512, 512, 768]                 # mean 512 == S/4
        assert sum(lengths) * 4 == s * len(lengths)
        cnt = self._measure(s, lengths, splits=4, bs=bs)
        executed = int(cnt[:, 0].sum())                # per kv head
        dense = len(lengths) * (s // bs)
        assert executed / dense <= 0.30, (executed, dense)
        c = tiling.decode_tile_step_counts(s, lengths, block_s=bs, splits=4)
        assert executed == c["visited"]


class TestNoBiasMaterialization:
    """With ``lengths`` the decode path must never build a (B, S) f32
    tensor — on the ref backend, the kernel backends, and the
    non-quantized inline path alike (satellite: ALL backends)."""

    def test_ref_backend_jaxpr(self):
        b, h, hkv, s, d = 2, 4, 2, 256, 64
        q = jax.ShapeDtypeStruct((b, h, d), jnp.float32)
        kq = jax.ShapeDtypeStruct((b, hkv, s, d), jnp.int8)
        sc = jax.ShapeDtypeStruct((b, hkv, s), jnp.float32)
        ln = jax.ShapeDtypeStruct((b,), jnp.int32)
        jaxpr = str(jax.make_jaxpr(
            lambda q, kq, ks, vq, vs, ln: DO.decode_attention(
                q, kq, ks, vq, vs, lengths=ln, backend="ref"))(
            q, kq, sc, kq, sc, ln))
        assert f"f32[{b},{s}]" not in jaxpr

    def test_interpret_backend_jaxpr(self):
        # b chosen != the kernel's per-tile group dim so the (B, S) pattern
        # can only match a genuinely materialized dense bias
        b, h, hkv, s, d = 3, 4, 2, 256, 64
        q = jax.ShapeDtypeStruct((b, h, d), jnp.float32)
        kq = jax.ShapeDtypeStruct((b, hkv, s, d), jnp.int8)
        sc = jax.ShapeDtypeStruct((b, hkv, s), jnp.float32)
        ln = jax.ShapeDtypeStruct((b,), jnp.int32)
        jaxpr = str(jax.make_jaxpr(
            lambda q, kq, ks, vq, vs, ln: DO.decode_attention(
                q, kq, ks, vq, vs, lengths=ln, backend="interpret",
                splits=2))(q, kq, sc, kq, sc, ln))
        assert f"f32[{b},{s}]" not in jaxpr

    def test_attn_decode_unquantized_jaxpr(self):
        from repro.models import attention as attn
        cfg = configs.smoke_config("llama3-8b")
        b, s = 2, 96                 # s != d_model: no benign collisions
        d_model = cfg.d_model
        hkv, hd = cfg.n_kv, cfg.head_dim
        p = {k: jnp.zeros(sh) for k, sh in (
            ("wq", (d_model, cfg.n_heads * hd)),
            ("wk", (d_model, hkv * hd)), ("wv", (d_model, hkv * hd)),
            ("wo", (cfg.n_heads * hd, d_model)))}
        x = jax.ShapeDtypeStruct((b, d_model), jnp.float32)
        ck = jax.ShapeDtypeStruct((b, hkv, s, hd), jnp.bfloat16)
        cs = jax.ShapeDtypeStruct((b, hkv, s), jnp.float32)
        jaxpr = str(jax.make_jaxpr(
            lambda x, ck, cs, cv, csv: attn.attn_decode(
                p, x, cfg, ck, cs, cv, csv, jnp.int32(5), window=0,
                quantized=False))(x, ck, cs, ck, cs))
        assert f"f32[{b},{s}]" not in jaxpr


class TestDecodeStepIntegration:
    """The serve path end-to-end: decode_step (uniform schedule -> static
    window -> lengths path) and decode_step_two_tier on interpret split-K
    match the ref backend."""

    def _run(self, cfg, step_fn, cache, steps=3, **kw):
        toks = jnp.asarray(
            np.random.default_rng(0).integers(0, cfg.vocab, (2,)), jnp.int32)
        outs = []
        for _ in range(steps):
            logits, cache = step_fn(cache, toks, **kw)
            toks = jnp.asarray(logits.argmax(-1), jnp.int32)
            outs.append(np.asarray(logits))
        return outs

    def test_decode_step_splitk_matches_ref(self):
        cfg = configs.smoke_config("llama3-8b")
        params = transformer.init_params(cfg, jax.random.PRNGKey(0))
        outs = {}
        for backend, splits in (("ref", 1), ("interpret", 2)):
            cache = transformer.init_cache(cfg, 2, 64, quantized=True)
            step = lambda c, t, _b=backend, _s=splits: transformer.decode_step(
                params, cfg, c, t, quantized=True, kvq_backend=_b,
                kvq_splits=_s)
            outs[backend] = self._run(cfg, step, cache)
        for a, b_ in zip(outs["ref"], outs["interpret"]):
            np.testing.assert_allclose(a, b_, atol=1e-3)

    def test_two_tier_splitk_matches_ref(self):
        cfg = configs.smoke_config("hymba-1.5b")
        params = transformer.init_params(cfg, jax.random.PRNGKey(1))
        outs = {}
        for backend in ("ref", "interpret"):
            cache = transformer.init_cache_two_tier(cfg, 2, 32,
                                                    quantized=True)
            step = lambda c, t, _b=backend: transformer.decode_step_two_tier(
                params, cfg, c, t, quantized=True, kvq_backend=_b,
                kvq_splits=2)
            outs[backend] = self._run(cfg, step, cache)
        for a, b_ in zip(outs["ref"], outs["interpret"]):
            np.testing.assert_allclose(a, b_, atol=1e-3)


class TestPlannerDecodeHonesty:
    """plan.decode_tile_report's visited counts == the kernel's measured
    counters, within one tile per layer (ISSUE 4 acceptance); cache-byte
    report sanity."""

    def _measured_layer_tiles(self, s_l, lens_l, *, splits, hkv=2, d=32):
        b = len(lens_l)
        q = _q(b, 2 * hkv, d)
        kq, ks, vq, vs = _cache(b, hkv, s_l, d)
        _, cnt = DO.decode_attention(
            q, kq, ks, vq, vs, lengths=jnp.asarray(lens_l),
            backend="interpret", splits=splits, debug_counts=True)
        return int(np.asarray(cnt).sum()) // hkv

    def test_report_within_one_tile_of_measured(self):
        cfg = configs.smoke_config("llama3-8b")       # uniform window 0
        b, s, splits = 3, 1024, 4
        lengths = [100, 700, 1024]
        rep = plan.decode_tile_report(cfg, b, s, lengths=lengths,
                                      splits=splits)
        assert rep["eligible"] and len(rep["per_layer"]) == cfg.n_layers
        for layer in rep["per_layer"]:
            s_l = layer["cache_len"]
            meas = self._measured_layer_tiles(
                s_l, [min(ln, s_l) for ln in lengths], splits=splits)
            assert abs(layer["visited"] - meas) <= 1, (layer, meas)

    def test_windowed_layers_shrink_statically(self):
        cfg = configs.get_config("hymba-1.5b")
        rep = plan.decode_tile_report(cfg, 2, 32768)
        win_layers = [l for l in rep["per_layer"] if l["window"] > 0]
        assert win_layers and all(
            l["cache_len"] == min(l["window"], 32768) for l in win_layers)
        # the two-tier claw-back: most layers pay ~W/S of the dense sweep
        assert rep["skip_frac"] > 0.8
        assert rep["visited_flops"] < rep["dense_flops"]

    def test_lengths_batch_mismatch_raises(self):
        cfg = configs.smoke_config("llama3-8b")
        with pytest.raises(ValueError, match="lengths"):
            plan.decode_tile_report(cfg, 8, 1024, lengths=[512] * 4)

    def test_ineligible_archs_report_zeros(self):
        for arch in ("mamba2-130m", "minicpm3-4b"):   # SSM / MLA caches
            rep = plan.decode_tile_report(configs.get_config(arch), 2, 1024)
            assert not rep["eligible"] and rep["visited_tile_steps"] == 0

    def test_kv_cache_report_int8_vs_f32(self):
        cfg = configs.get_config("llama3-8b")
        rep = plan.kv_cache_report(cfg, 4, 32768)
        assert rep["eligible"] and rep["int8_bytes"] < rep["f32_bytes"]
        assert rep["ratio"] > 3.0                     # ~3.76x at head_dim 128
        # two-tier shrinks the windowed share on top of quantization
        hy = plan.kv_cache_report(configs.get_config("hymba-1.5b"), 4, 32768)
        full = 4 * configs.get_config("hymba-1.5b").n_layers
        assert hy["int8_bytes"] < hy["f32_bytes"]


class TestServeCLI:
    def test_kv_backend_and_splits_flags(self, tmp_path):
        """--kv-backend/--kv-splits plumb through to decode_attention and
        the banner names the resolved backend + clamped split count."""
        env = {**os.environ, "PYTHONPATH": "src", "PYTHONUNBUFFERED": "1",
               "XLA_FLAGS": "", "JAX_PLATFORMS": "cpu"}
        out = subprocess.run(
            [sys.executable, "-m", "repro.launch.serve", "--arch",
             "llama3-8b", "--smoke", "--batch", "2", "--prompt-len", "16",
             "--gen", "4", "--kv-backend", "interpret", "--kv-splits", "2"],
            env=env, capture_output=True, text=True, timeout=480)
        assert out.returncode == 0, out.stdout + out.stderr
        assert "kv decode: backend=interpret splits=" in out.stdout
        assert "prefill" in out.stdout and "decode" in out.stdout
