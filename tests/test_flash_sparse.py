"""Sparsity-aware flash grids (ISSUE 3): tile-bound math vs the mask's
support, measured interpret-mode visit counters vs the analytic counts,
the skip-ratio acceptance bars, grad parity on the sparse grids (incl.
the ragged last tile), planner-honest FLOP budgets, the bf16 residual
policy, and the kvq no-bias passthrough."""
import dataclasses as dc

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.kernels.flash import kernel as K, ops as O, ref as R
from repro.models import transformer

RNG = np.random.default_rng(7)


def _qkv(b, h, hkv, s, d, dtype=np.float32):
    q = jnp.asarray(RNG.normal(size=(b, h, s, d)).astype(dtype))
    k = jnp.asarray(RNG.normal(size=(b, hkv, s, d)).astype(dtype))
    v = jnp.asarray(RNG.normal(size=(b, hkv, s, d)).astype(dtype))
    return q, k, v


def _flat(h, hkv, s, d):
    q = jnp.asarray(RNG.normal(size=(h, s, d)).astype(np.float32))
    k = jnp.asarray(RNG.normal(size=(hkv, s, d)).astype(np.float32))
    v = jnp.asarray(RNG.normal(size=(hkv, s, d)).astype(np.float32))
    return q, k, v


def _dense_mask(s_len, *, causal, window, kv_len):
    """Position-level ground truth of ``_position_mask``'s geometry."""
    q = np.arange(s_len)[:, None]
    k = np.arange(s_len)[None, :]
    ok = np.broadcast_to(k < kv_len, (s_len, s_len)).copy()
    if causal:
        ok &= q >= k
        if window > 0:
            ok &= (q - k) < window
    return ok


# schedule sweep: (bq, bk, s_len, kv_len, window, causal) — mixed tile
# sizes, ragged kv tails, windows that don't divide tiles
SWEEP = [
    (128, 128, 512, 512, 0, True),
    (128, 128, 512, 512, 128, True),
    (128, 128, 512, 512, 100, True),      # window not tile-aligned
    (128, 128, 512, 300, 64, True),       # ragged kv + window
    (128, 128, 512, 300, 0, True),        # ragged kv, full causal
    (128, 128, 512, 200, 0, False),       # non-causal, dead last tile
    (64, 128, 512, 512, 96, True),        # bq != bk
    (128, 64, 512, 400, 96, True),        # bq != bk, ragged
    (64, 64, 256, 256, 1, True),          # degenerate window = 1
    (128, 128, 2048, 2048, 256, True),
    (8, 8, 40, 40, 0, True),              # sub-block path (ops pads to 8)
]


class TestTileBounds:
    """The wedge bounds must EXACTLY cover ``_position_mask``'s support:
    every tile holding a live position is inside [lo, hi], and (when any
    live tile exists) lo/hi are the min/max live tiles — no overshoot."""

    @pytest.mark.parametrize("bq,bk,s,kv_len,window,causal", SWEEP)
    def test_kv_bounds_cover_support_exactly(self, bq, bk, s, kv_len,
                                             window, causal):
        ok = _dense_mask(s, causal=causal, window=window, kv_len=kv_len)
        n_q, n_k = s // bq, s // bk
        for i in range(n_q):
            lo, hi = K.kv_tile_bounds(i, bq=bq, bk=bk, causal=causal,
                                      window=window, kv_len=kv_len)
            live = [t for t in range(n_k)
                    if ok[i * bq:(i + 1) * bq, t * bk:(t + 1) * bk].any()]
            if live:
                assert (lo, hi) == (min(live), max(live)), \
                    (i, lo, hi, live)
            else:  # fully-masked q tile: any one-step range is legal
                assert 0 <= lo <= hi < n_k

    @pytest.mark.parametrize("bq,bk,s,kv_len,window,causal", SWEEP)
    def test_q_bounds_cover_support_exactly(self, bq, bk, s, kv_len,
                                            window, causal):
        ok = _dense_mask(s, causal=causal, window=window, kv_len=kv_len)
        n_q, n_k = s // bq, s // bk
        for t in range(n_k):
            lo, hi = K.q_tile_bounds(t, bq=bq, bk=bk, causal=causal,
                                     window=window, n_q=n_q, kv_len=kv_len)
            live = [i for i in range(n_q)
                    if ok[i * bq:(i + 1) * bq, t * bk:(t + 1) * bk].any()]
            if live:
                assert (lo, hi) == (min(live), max(live)), \
                    (t, lo, hi, live)
            else:  # dead KV tile: visited via a one-step range, early-out
                assert 0 <= lo <= hi < n_q

    @pytest.mark.parametrize("bq,bk,s,kv_len,window,causal", SWEEP[:6])
    def test_traced_bounds_agree_with_static(self, bq, bk, s, kv_len,
                                             window, causal):
        """The same formulas run on traced grid indices inside index maps
        and kernel bodies — the jnp arithmetic must agree with the Python
        ints used for grid sizing."""
        for i in range(s // bq):
            lo_s, hi_s = K.kv_tile_bounds(i, bq=bq, bk=bk, causal=causal,
                                          window=window, kv_len=kv_len)
            lo_t, hi_t = K.kv_tile_bounds(jnp.int32(i), bq=bq, bk=bk,
                                          causal=causal, window=window,
                                          kv_len=kv_len)
            assert (int(lo_t), int(hi_t)) == (lo_s, hi_s)
        for t in range(s // bk):
            lo_s, hi_s = K.q_tile_bounds(t, bq=bq, bk=bk, causal=causal,
                                         window=window, n_q=s // bq,
                                         kv_len=kv_len)
            lo_t, hi_t = K.q_tile_bounds(jnp.int32(t), bq=bq, bk=bk,
                                         causal=causal, window=window,
                                         n_q=s // bq, kv_len=kv_len)
            assert (int(lo_t), int(hi_t)) == (lo_s, hi_s)

    def test_analytic_counts_match_mask_support(self):
        """tile_step_counts == the number of tiles with any live position
        (plus the clamped one-step rows for fully-masked q tiles)."""
        for bq, bk, s, kv_len, window, causal in SWEEP:
            ok = _dense_mask(s, causal=causal, window=window, kv_len=kv_len)
            c = K.tile_step_counts(s, bq=bq, bk=bk, causal=causal,
                                   window=window, kv_len=kv_len)
            n_q, n_k = s // bq, s // bk
            live_pairs = sum(
                ok[i * bq:(i + 1) * bq, t * bk:(t + 1) * bk].any()
                for i in range(n_q) for t in range(n_k))
            # fwd visits every live pair, plus 1 step per fully-dead q row
            dead_q = sum(not ok[i * bq:(i + 1) * bq].any()
                         for i in range(n_q))
            assert c["fwd"] == live_pairs + dead_q
            # dkv visits every live pair; dead KV tiles are early-outed
            assert c["dkv"] == live_pairs
            assert c["dense"] == n_q * n_k


class TestMeasuredCounters:
    """interpret-mode debug counters vs the analytic counts, and the
    ISSUE 3 acceptance ratios."""

    def _measure(self, s, *, window, causal, kv_len=None, h=2, hkv=1, d=64):
        kvl = s if kv_len is None else kv_len
        q, k, v = _flat(h, hkv, s, d)
        o, m, l, cnt = K.flash_attention_fwd_pallas(
            q, k, v, causal=causal, window=window, kv_len=kvl,
            interpret=True, debug_counts=True)
        do = jnp.ones_like(o)
        _, _, _, dqc, dkvc = K.flash_attention_bwd_pallas(
            q, k, v, o, m, l, do, causal=causal, window=window, kv_len=kvl,
            interpret=True, debug_counts=True)
        group = h // hkv
        return {"fwd": int(cnt[0].sum()), "dq": int(dqc[0].sum()),
                "dkv": int(dkvc[0].sum()) // group}

    @pytest.mark.parametrize("s,window,causal,kv_len", [
        (512, 0, True, None),
        (512, 128, True, None),
        (512, 100, True, 400),
        (256, 0, False, 200),
        (256, 64, True, None),
    ])
    def test_counters_match_analytic(self, s, window, causal, kv_len):
        kvl = s if kv_len is None else kv_len
        meas = self._measure(s, window=window, causal=causal, kv_len=kv_len)
        c = K.tile_step_counts(s, causal=causal, window=window, kv_len=kvl)
        assert meas == {k_: c[k_] for k_ in ("fwd", "dq", "dkv")}

    def test_causal_s2048_skips_at_least_45pct(self):
        """Acceptance: causal S=2048 must skip >= 45% of KV tile-steps on
        all three grids (the dense rectangle is 16x16=256; the wedge
        visits the 136-step lower triangle)."""
        meas = self._measure(2048, window=0, causal=True)
        dense = K.tile_step_counts(2048, causal=True, window=0)["dense"]
        for grid in ("fwd", "dq", "dkv"):
            skipped = 1 - meas[grid] / dense
            assert skipped >= 0.45, (grid, skipped)

    def test_window256_s2048_skips_band_complement(self):
        """Acceptance: W=256 at S=2048 must skip >= 1 - W/S - eps where
        eps = (BQ + BK)/S covers tile-granularity overhang (a band of
        width W can straddle at most W/BK + 1 tiles per q tile)."""
        s, w = 2048, 256
        meas = self._measure(s, window=w, causal=True)
        c = K.tile_step_counts(s, causal=True, window=w)
        eps = (c["bq"] + c["bk"]) / s
        for grid in ("fwd", "dq", "dkv"):
            skipped = 1 - meas[grid] / c["dense"]
            assert skipped >= 1 - w / s - eps, (grid, skipped)

    def test_counts_via_public_op_shapes(self):
        """The wedge grid + counters also run where ops.py pads (ragged
        last tile): S=300 pads to 384, kv_len=300 masks the tail."""
        s_pad = O.padded_seq_len(300)
        assert s_pad == 384
        meas = self._measure(s_pad, window=0, causal=True, kv_len=300)
        c = K.tile_step_counts(s_pad, causal=True, window=0, kv_len=300)
        assert meas == {k_: c[k_] for k_ in ("fwd", "dq", "dkv")}


class TestSparseGridGradParity:
    """Grad parity (<= 1e-3 vs the jnp oracle) re-run on the SPARSE grids,
    including the ragged last tile, window + ragged, GQA and non-causal
    padded-KV cases."""

    @pytest.mark.parametrize("b,h,hkv,s,d,window,causal", [
        (1, 4, 4, 256, 64, 0, True),      # causal wedge
        (2, 8, 2, 256, 64, 0, True),      # GQA 4:1 on the wedge dKV grid
        (1, 4, 2, 200, 64, 0, True),      # ragged last tile (pads to 256)
        (1, 4, 4, 200, 64, 100, True),    # window + ragged
        (1, 4, 4, 512, 64, 128, True),    # statically shrunk window grid
        (1, 2, 2, 200, 64, 0, False),     # non-causal padded KV
        (1, 2, 1, 384, 64, 96, True),     # MQA, window not tile-aligned
    ])
    def test_grads_match_ref(self, b, h, hkv, s, d, window, causal):
        q, k, v = _qkv(b, h, hkv, s, d)
        t = jnp.asarray(RNG.normal(size=(b, h, s, d)).astype(np.float32))

        def loss(fn):
            return lambda q, k, v: jnp.sum(fn(q, k, v) * t)

        g_int = jax.grad(loss(lambda q, k, v: O.flash_attention(
            q, k, v, causal=causal, window=window, backend="interpret")),
            argnums=(0, 1, 2))(q, k, v)
        g_ref = jax.grad(loss(lambda q, k, v: R.flash_ref(
            q, k, v, causal=causal, window=window)),
            argnums=(0, 1, 2))(q, k, v)
        for name, a, b_ in zip("qkv", g_int, g_ref):
            np.testing.assert_allclose(
                np.asarray(a), np.asarray(b_), atol=1e-3,
                err_msg=f"d{name} mismatch")


class TestPlannerHonesty:
    """profile/flash_bwd_recompute_flops budgets == the measured visited
    tiles, within one tile per layer (ISSUE 3 acceptance)."""

    def _cfg(self, **kw):
        return dc.replace(configs.smoke_config("llama3-8b"),
                          attn_backend="interpret", **kw)

    def test_profile_budget_matches_measured_tiles(self):
        b, s, d = 1, 256, 64
        cfg = self._cfg(head_dim=d)
        h, hkv = cfg.n_heads, cfg.n_kv
        prof_flops = {}
        from repro.plan import profile_transformer
        batch = {"tokens": jax.ShapeDtypeStruct((b, s), jnp.int32)}
        prof = profile_transformer(cfg, batch)

        # measured: one layer's forward on the padded flash grid
        q, k, v = _flat(b * h, hkv, O.padded_seq_len(s), d)
        w = int(cfg.window)
        *_, cnt = K.flash_attention_fwd_pallas(
            q, k, v, causal=True, window=w, kv_len=s, interpret=True,
            debug_counts=True)
        measured_tiles = int(cnt.sum()) // (b * h)

        # budgeted: back out the per-head tile count from the profile's
        # attention term (total layer flops - matmul term)
        params_sds = jax.eval_shape(
            lambda: transformer.init_params(cfg, jax.random.PRNGKey(0)))
        block_elems = sum(x.size for x in jax.tree_util.tree_leaves(
            params_sds["blocks"]))
        matmul = 2.0 * b * s * (block_elems / cfg.n_layers)
        c = K.tile_step_counts(O.padded_seq_len(s), causal=True, window=w,
                               kv_len=s)
        per_tile = 4.0 * b * h * d * c["bq"] * c["bk"]
        budget_tiles = (prof.flops[0] - matmul) / per_tile * (b * h) \
            / (b * h)
        assert abs(budget_tiles - measured_tiles) <= 1, \
            (budget_tiles, measured_tiles)

    def test_bwd_budget_matches_measured_tiles(self):
        b, s, d = 1, 256, 64
        cfg = self._cfg(head_dim=d)
        h, hkv = cfg.n_heads, cfg.n_kv
        from repro.plan import flash_bwd_recompute_flops
        per_layer = flash_bwd_recompute_flops(cfg, b, s)

        s_pad = O.padded_seq_len(s)
        q, k, v = _flat(b * h, hkv, s_pad, d)
        w = int(cfg.window)
        o, m, l, _ = K.flash_attention_fwd_pallas(
            q, k, v, causal=True, window=w, kv_len=s, interpret=True,
            debug_counts=True)
        *_, dqc, dkvc = K.flash_attention_bwd_pallas(
            q, k, v, o, m, l, jnp.ones_like(o), causal=True, window=w,
            kv_len=s, interpret=True, debug_counts=True)
        group = h // hkv
        measured = int(dqc.sum()) // (b * h) + int(dkvc.sum()) // (b * group
                                                                   * hkv)
        c = K.tile_step_counts(s_pad, causal=True, window=w, kv_len=s)
        per_tile = 2.0 * b * h * d * c["bq"] * c["bk"]
        assert abs(per_layer[0] / per_tile - measured) <= 1

    def test_flop_report_claws_back_causal(self):
        from repro.plan import flash_attn_flop_report
        cfg = self._cfg(head_dim=64)
        rep = flash_attn_flop_report(cfg, 1, 2048)
        assert rep["eligible"]
        assert rep["visited_flops"] < 0.6 * rep["dense_flops"]
        assert 0.45 <= rep["skip_frac"] < 1.0
        # ineligible config reports zeros, not a phantom claw-back
        rep_jnp = flash_attn_flop_report(dc.replace(cfg, attn_backend="jnp"),
                                         1, 2048)
        assert not rep_jnp["eligible"] and rep_jnp["dense_flops"] == 0.0

    def test_sparse_budget_shifts_checkpoint_boundaries(self):
        """The point of honesty: a hybrid window/global schedule prices
        windowed flash layers FAR cheaper to recompute than global ones,
        so the budget DP's recompute objective must see heterogeneous
        flops (the dense model priced every layer's scores ~equally)."""
        from repro.plan import profile_transformer
        cfg = dc.replace(
            configs.smoke_config("llama3-8b"), attn_backend="interpret",
            head_dim=64, n_layers=8, window=128, global_layers=())
        batch = {"tokens": jax.ShapeDtypeStruct((1, 2048), jnp.int32)}
        prof_w = profile_transformer(cfg, batch)
        prof_g = profile_transformer(dc.replace(cfg, window=0), batch)
        # windowed flash layers must be budgeted well under causal-full
        assert sum(prof_w.flops) < 0.6 * sum(prof_g.flops)


class TestFlashResidPolicy:
    """bf16 policy on the saved (q, k, v, o) residual tuple; (m, l) stats
    stay f32; planner resid_bytes follow the policy dtype."""

    def _resid_structure(self, resid_dtype):
        b, h, s, d = 1, 2, 256, 64
        sds = [jax.ShapeDtypeStruct((b, h, s, d), jnp.float32)] * 3
        out = jax.eval_shape(
            lambda q, k, v: jax.vjp(lambda *a: O.flash_attention(
                *a, backend="interpret", resid_dtype=resid_dtype), q, k, v),
            *sds)
        return jax.tree_util.tree_leaves(out)

    def test_qkvo_cast_stats_stay_f32(self):
        leaves = self._resid_structure("bfloat16")
        dtypes = sorted(str(x.dtype) for x in leaves)
        # output stays f32; saved q,k,v,o are bf16; m,l stay f32
        assert dtypes.count("bfloat16") == 4
        assert dtypes.count("float32") == 3
        f32 = sum(x.size * x.dtype.itemsize for x in leaves)
        plain = sum(x.size * x.dtype.itemsize
                    for x in self._resid_structure(None))
        assert f32 < plain

    def test_grads_f32_and_close(self):
        q, k, v = _qkv(1, 2, 2, 256, 64)
        g16 = jax.grad(lambda q, k, v: jnp.sum(O.flash_attention(
            q, k, v, backend="interpret", resid_dtype="bfloat16") ** 2),
            argnums=(0, 1, 2))(q, k, v)
        gr = jax.grad(lambda q, k, v: jnp.sum(R.flash_ref(q, k, v) ** 2),
                      argnums=(0, 1, 2))(q, k, v)
        for a, b_ in zip(g16, gr):
            assert a.dtype == jnp.float32        # cotangents match primals
            scale = float(jnp.abs(b_).max()) + 1e-9
            assert float(jnp.abs(a - b_).max()) / scale < 2e-2  # bf16 trade

    def test_policy_threads_through_transformer(self):
        from repro.core.mixed_precision import get_policy
        pol = get_policy("resid_bf16")
        assert pol.flash_resid_dtype == jnp.bfloat16
        assert pol.compute_dtype == jnp.float32
        cfg = dc.replace(configs.smoke_config("llama3-8b"),
                         attn_backend="interpret")
        params = transformer.init_params(cfg, jax.random.PRNGKey(0))
        batch = {"tokens": jnp.asarray(RNG.integers(0, cfg.vocab, (2, 32)),
                                       jnp.int32),
                 "labels": jnp.asarray(RNG.integers(0, cfg.vocab, (2, 32)),
                                       jnp.int32)}
        g = jax.grad(lambda p: transformer.loss_fn(
            p, cfg, batch, policy=pol)[0])(params)
        assert all(bool(jnp.all(jnp.isfinite(x)))
                   for x in jax.tree_util.tree_leaves(g))

    def test_planner_resid_bytes_follow_policy(self):
        from repro.plan import profile_transformer
        cfg = dc.replace(configs.smoke_config("llama3-8b"),
                         attn_backend="interpret")
        batch = {"tokens": jax.ShapeDtypeStruct((2, 512), jnp.int32)}
        p4 = profile_transformer(cfg, batch, dtype_bytes=4)
        p2 = profile_transformer(cfg, batch, dtype_bytes=4,
                                 flash_resid_bytes=2)
        # the O(S*D) qkvo term halves; the f32 (m, l) rows do not move
        stats = 2 * 4 * 2 * cfg.n_heads * 512
        qo_kv4 = (2 * cfg.n_heads + 2 * cfg.n_kv) * 2 * 512 \
            * cfg.head_dim * 4
        assert p4.resid_bytes[0] == qo_kv4 + stats
        assert p2.resid_bytes[0] == qo_kv4 // 2 + stats


class TestKvqNoBiasPassthrough:
    def test_no_mask_matches_zero_bias(self):
        from repro.kernels.kvq import ops as KO
        b, h, hkv, s, d = 2, 8, 4, 512, 64
        q = jnp.asarray(RNG.normal(size=(b, h, d)).astype(np.float32))
        k = jnp.asarray(RNG.normal(size=(b, hkv, s, d)).astype(np.float32))
        v = jnp.asarray(RNG.normal(size=(b, hkv, s, d)).astype(np.float32))
        kq, ks = KO.quantize_kv(k)
        vq, vs = KO.quantize_kv(v)
        zeros = jnp.zeros((b, s), jnp.float32)
        for backend in ("ref", "interpret"):
            o_none = KO.decode_attention(q, kq, ks, vq, vs, backend=backend)
            o_zero = KO.decode_attention(q, kq, ks, vq, vs, bias=zeros,
                                         backend=backend)
            np.testing.assert_allclose(np.asarray(o_none),
                                       np.asarray(o_zero), atol=1e-6)

    def test_no_bias_tensor_materialized(self):
        """The no-mask jaxpr must contain NO (B, S) f32 tensor at all —
        previously a dense zero bias was built and broadcast-added."""
        from repro.kernels.kvq import ops as KO
        b, h, hkv, s, d = 2, 4, 2, 256, 64
        q = jax.ShapeDtypeStruct((b, h, d), jnp.float32)
        kq = jax.ShapeDtypeStruct((b, hkv, s, d), jnp.int8)
        sc = jax.ShapeDtypeStruct((b, hkv, s), jnp.float32)
        jaxpr = str(jax.make_jaxpr(
            lambda q, kq, ks, vq, vs: KO.decode_attention(
                q, kq, ks, vq, vs, backend="ref"))(q, kq, sc, kq, sc))
        assert f"f32[{b},{s}]" not in jaxpr
