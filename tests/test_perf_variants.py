"""Beyond-paper perf variants must preserve semantics: chunked CE, EP vs TP
experts, capacity vs dropless dispatch, bf16-cotangent RMSNorm."""
import dataclasses as dc

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.models import transformer
from repro.core.mixed_precision import get_policy

KEY = jax.random.PRNGKey(0)


def _batch(cfg, b=2, s=32, seed=0):
    rng = np.random.default_rng(seed)
    return {"tokens": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32),
            "labels": jnp.asarray(rng.integers(0, cfg.vocab, (b, s)), jnp.int32)}


def test_chunked_ce_exact():
    cfg = configs.smoke_config("llama3-8b")
    params = transformer.init_params(cfg, KEY)
    batch = _batch(cfg)
    l1, _ = transformer.loss_fn(params, cfg, batch)
    l2, _ = transformer.loss_fn(params, cfg, batch, ce_chunk=8)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)
    g1 = jax.grad(lambda p: transformer.loss_fn(p, cfg, batch)[0])(params)
    g2 = jax.grad(lambda p: transformer.loss_fn(p, cfg, batch,
                                                ce_chunk=8)[0])(params)
    for a, b in zip(jax.tree_util.tree_leaves(g1),
                    jax.tree_util.tree_leaves(g2)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                   atol=1e-5, rtol=1e-4)


def test_chunked_ce_tied_embeddings():
    cfg = configs.smoke_config("qwen2-vl-2b")
    params = transformer.init_params(cfg, KEY)
    batch = _batch(cfg)
    batch["positions"] = jnp.broadcast_to(
        jnp.arange(32)[None, None], (3, 2, 32)).astype(jnp.int32)
    l1, _ = transformer.loss_fn(params, cfg, batch)
    l2, _ = transformer.loss_fn(params, cfg, batch, ce_chunk=16)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-6)


def test_capacity_equals_dropless_when_uncapped():
    cfg = configs.smoke_config("deepseek-moe-16b")
    cfg_cap = dc.replace(cfg, moe=dc.replace(cfg.moe, capacity_factor=8.0))
    cfg_drop = dc.replace(cfg, moe=dc.replace(cfg.moe, capacity_factor=0.0))
    params = transformer.init_params(cfg, KEY)
    batch = _batch(cfg, b=4)
    l1, _ = transformer.loss_fn(params, cfg_cap, batch)
    l2, _ = transformer.loss_fn(params, cfg_drop, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=1e-5)


def test_capacity_drops_bounded():
    """With cf=1.0 some tokens drop but loss stays in the same ballpark."""
    cfg = configs.smoke_config("granite-moe-3b-a800m")
    cfg_tight = dc.replace(cfg, moe=dc.replace(cfg.moe, capacity_factor=1.0))
    cfg_loose = dc.replace(cfg, moe=dc.replace(cfg.moe, capacity_factor=8.0))
    params = transformer.init_params(cfg, KEY)
    batch = _batch(cfg, b=4)
    lt, _ = transformer.loss_fn(params, cfg_tight, batch)
    ll, _ = transformer.loss_fn(params, cfg_loose, batch)
    assert abs(float(lt) - float(ll)) < 0.5


def test_norm_bf16_grad_forward_identical():
    cfg = configs.smoke_config("glm4-9b")
    cfg2 = dc.replace(cfg, norm_bf16_grad=True)
    params = transformer.init_params(cfg, KEY)
    batch = _batch(cfg)
    pol = get_policy("bf16")
    l1, _ = transformer.loss_fn(params, cfg, batch, policy=pol)
    l2, _ = transformer.loss_fn(params, cfg2, batch, policy=pol)
    assert float(l1) == float(l2)


def test_norm_bf16_grad_close_grads():
    cfg = configs.smoke_config("llama3-8b")
    cfg2 = dc.replace(cfg, norm_bf16_grad=True)
    params = transformer.init_params(cfg, KEY)
    batch = _batch(cfg, b=4)
    pol = get_policy("bf16")
    g1 = jax.grad(lambda p: transformer.loss_fn(p, cfg, batch,
                                                policy=pol)[0])(params)
    g2 = jax.grad(lambda p: transformer.loss_fn(p, cfg2, batch,
                                                policy=pol)[0])(params)
    for a, b in zip(jax.tree_util.tree_leaves(g1),
                    jax.tree_util.tree_leaves(g2)):
        scale = float(jnp.abs(a).max()) + 1e-9
        assert float(jnp.abs(a - b).max()) / scale < 0.06


def test_flash_attn_backend_matches_jnp():
    """The Pallas flash-attention path must match the jnp attention path."""
    cfg = configs.smoke_config("llama3-8b")
    cfg_flash = dc.replace(cfg, attn_backend="interpret")
    params = transformer.init_params(cfg, KEY)
    batch = _batch(cfg)
    l1, _ = transformer.loss_fn(params, cfg, batch)
    l2, _ = transformer.loss_fn(params, cfg_flash, batch)
    np.testing.assert_allclose(float(l1), float(l2), rtol=2e-5)
