"""Parallel E-D loader: double buffering, determinism, resume, SBS hooks."""
import numpy as np
import pytest

from repro.core import encoding
from repro.data.pipeline import LoaderState, ParallelEncodedLoader
from repro.data.synthetic import make_cifar_like


@pytest.fixture(scope="module")
def data():
    return make_cifar_like(n=256, seed=0)


def test_u32_batches_decode(data):
    imgs, labels = data
    with ParallelEncodedLoader(imgs, labels, 16, codec="u32") as dl:
        enc, labs = next(dl)
        assert enc.shape == (4, 32, 32, 3) and enc.dtype == np.uint32
        dec = encoding.unpack_u32_to_u8(enc)
        # decoded images are a permutation subset of the dataset
        assert dec.shape == (16, 32, 32, 3)
        assert labs.shape == (16,)


def test_deterministic_given_state(data):
    imgs, labels = data
    with ParallelEncodedLoader(imgs, labels, 16, codec="none",
                               state=LoaderState(seed=7)) as d1, \
         ParallelEncodedLoader(imgs, labels, 16, codec="none",
                               state=LoaderState(seed=7)) as d2:
        for _ in range(3):
            b1, l1 = next(d1)
            b2, l2 = next(d2)
            np.testing.assert_array_equal(b1, b2)
            np.testing.assert_array_equal(l1, l2)


def test_resume_mid_epoch(data):
    imgs, labels = data
    with ParallelEncodedLoader(imgs, labels, 16, codec="none",
                               state=LoaderState(seed=3)) as d1:
        seen = [next(d1) for _ in range(5)]
        state = d1.state
    with ParallelEncodedLoader(imgs, labels, 16, codec="none",
                               state=state) as d2:
        nxt_resumed = next(d2)
    with ParallelEncodedLoader(imgs, labels, 16, codec="none",
                               state=LoaderState(seed=3)) as d3:
        for _ in range(5):
            next(d3)
        nxt_straight = next(d3)
    np.testing.assert_array_equal(nxt_resumed[0], nxt_straight[0])


def test_sbs_weights_respected(data):
    imgs, labels = data
    weights = {c: (2.0 if c == 0 else 1.0) for c in range(10)}
    with ParallelEncodedLoader(imgs, labels, 22, codec="none",
                               class_weights=weights) as dl:
        counts = np.zeros(10)
        for _ in range(10):
            _, labs = next(dl)
            counts += np.bincount(labs, minlength=10)
    assert counts[0] > counts[1:].mean() * 1.5


def test_per_class_preprocess_hook(data):
    imgs, labels = data
    hook = {3: lambda x: np.zeros_like(x)}
    with ParallelEncodedLoader(imgs, labels, 32, codec="none",
                               preprocess=hook) as dl:
        for _ in range(6):
            batch, labs = next(dl)
            m = labs == 3
            if m.any():
                assert np.all(batch[m] == 0.0)
                return
    pytest.skip("class 3 never sampled in 6 batches")
