"""Fault-tolerance: atomic saves, GC, restore, resharding, corruption safety."""
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpointing.ckpt import CheckpointManager


@pytest.fixture
def tmp_ckpt(tmp_path):
    return CheckpointManager(str(tmp_path / "ck"), keep_last=2)


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (8, 8)),
                       "b": jnp.zeros((8,))},
            "nested": [jnp.arange(4.0), jnp.int32(7)]}


def test_save_restore_roundtrip(tmp_ckpt):
    s = _state()
    tmp_ckpt.save(10, s, extra={"step": 10})
    out, extra = tmp_ckpt.restore(10, jax.tree_util.tree_map(jnp.zeros_like, s))
    assert extra["step"] == 10
    for a, b in zip(jax.tree_util.tree_leaves(s),
                    jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_last_gc(tmp_ckpt):
    for step in (1, 2, 3, 4):
        tmp_ckpt.save(step, _state())
    assert tmp_ckpt.all_steps() == [3, 4]


def test_latest_and_resave_noop(tmp_ckpt):
    tmp_ckpt.save(5, _state(1))
    tmp_ckpt.save(5, _state(2))        # re-save same step: no crash, no-op
    assert tmp_ckpt.latest_step() == 5


def test_crash_mid_write_leaves_previous_intact(tmp_ckpt):
    tmp_ckpt.save(1, _state())
    # simulate a crashed writer: stale tmp dir
    os.makedirs(os.path.join(tmp_ckpt.directory, "step_00000002.tmp"))
    assert tmp_ckpt.latest_step() == 1
    tmp_ckpt.save(3, _state())         # next save cleans stale tmp
    assert not any(n.endswith(".tmp")
                   for n in os.listdir(tmp_ckpt.directory))


def test_shape_mismatch_rejected(tmp_ckpt):
    tmp_ckpt.save(1, {"w": jnp.zeros((4,))})
    with pytest.raises(ValueError):
        tmp_ckpt.restore(1, {"w": jnp.zeros((5,))})


def test_missing_leaf_rejected(tmp_ckpt):
    tmp_ckpt.save(1, {"w": jnp.zeros((4,))})
    with pytest.raises(KeyError):
        tmp_ckpt.restore(1, {"w": jnp.zeros((4,)), "extra": jnp.zeros((1,))})


def test_resharding_restore(tmp_ckpt):
    """Elastic scaling: save unsharded, restore onto a 1x1 mesh sharding."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    s = {"w": jnp.arange(64.0).reshape(8, 8)}
    tmp_ckpt.save(1, s)
    sh = {"w": NamedSharding(mesh, P(None, "model"))}
    out, _ = tmp_ckpt.restore(1, jax.tree_util.tree_map(jnp.zeros_like, s),
                              shardings=sh)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(s["w"]))
    assert out["w"].sharding == sh["w"]
