"""Fault-tolerance: atomic saves, GC, restore, resharding, corruption safety."""
import json
import os
import shutil

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpointing.ckpt import CheckpointManager


@pytest.fixture
def tmp_ckpt(tmp_path):
    return CheckpointManager(str(tmp_path / "ck"), keep_last=2)


def _state(seed=0):
    k = jax.random.PRNGKey(seed)
    return {"params": {"w": jax.random.normal(k, (8, 8)),
                       "b": jnp.zeros((8,))},
            "nested": [jnp.arange(4.0), jnp.int32(7)]}


def test_save_restore_roundtrip(tmp_ckpt):
    s = _state()
    tmp_ckpt.save(10, s, extra={"step": 10})
    out, extra = tmp_ckpt.restore(10, jax.tree_util.tree_map(jnp.zeros_like, s))
    assert extra["step"] == 10
    for a, b in zip(jax.tree_util.tree_leaves(s),
                    jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_keep_last_gc(tmp_ckpt):
    for step in (1, 2, 3, 4):
        tmp_ckpt.save(step, _state())
    assert tmp_ckpt.all_steps() == [3, 4]


def test_latest_and_resave_noop(tmp_ckpt):
    tmp_ckpt.save(5, _state(1))
    tmp_ckpt.save(5, _state(2))        # re-save same step: no crash, no-op
    assert tmp_ckpt.latest_step() == 5


def test_crash_mid_write_leaves_previous_intact(tmp_ckpt):
    tmp_ckpt.save(1, _state())
    # simulate a crashed writer: stale tmp dir
    os.makedirs(os.path.join(tmp_ckpt.directory, "step_00000002.tmp"))
    assert tmp_ckpt.latest_step() == 1
    tmp_ckpt.save(3, _state())         # next save cleans stale tmp
    assert not any(n.endswith(".tmp")
                   for n in os.listdir(tmp_ckpt.directory))


def test_shape_mismatch_rejected(tmp_ckpt):
    tmp_ckpt.save(1, {"w": jnp.zeros((4,))})
    with pytest.raises(ValueError):
        tmp_ckpt.restore(1, {"w": jnp.zeros((5,))})


def test_missing_leaf_rejected(tmp_ckpt):
    tmp_ckpt.save(1, {"w": jnp.zeros((4,))})
    with pytest.raises(KeyError):
        tmp_ckpt.restore(1, {"w": jnp.zeros((4,)), "extra": jnp.zeros((1,))})


def test_resharding_restore(tmp_ckpt):
    """Elastic scaling: save unsharded, restore onto a 1x1 mesh sharding."""
    from jax.sharding import NamedSharding, PartitionSpec as P
    from repro.launch.mesh import make_mesh
    mesh = make_mesh((1, 1), ("data", "model"))
    s = {"w": jnp.arange(64.0).reshape(8, 8)}
    tmp_ckpt.save(1, s)
    sh = {"w": NamedSharding(mesh, P(None, "model"))}
    out, _ = tmp_ckpt.restore(1, jax.tree_util.tree_map(jnp.zeros_like, s),
                              shardings=sh)
    np.testing.assert_array_equal(np.asarray(out["w"]), np.asarray(s["w"]))
    assert out["w"].sharding == sh["w"]


# -- manifest fingerprint + conflicting re-save (ISSUE 7) -------------------
def test_fingerprint_mismatch_rejected(tmp_ckpt):
    """Restoring one model's checkpoint into another's tree fails loudly
    with the differing leaves named — not silently, not deep in jax."""
    from repro.checkpointing.ckpt import CheckpointMismatchError
    tmp_ckpt.save(1, {"w": jnp.zeros((4, 4)), "b": jnp.zeros((4,))})
    with pytest.raises(CheckpointMismatchError, match="does not fit"):
        tmp_ckpt.restore(1, {"w": jnp.zeros((4, 4)),
                             "b": jnp.zeros((4,), jnp.int32)})  # dtype flip


def test_config_identity_checked(tmp_ckpt):
    from repro.checkpointing.ckpt import CheckpointMismatchError
    s = {"w": jnp.zeros((4,))}
    tmp_ckpt.save(1, s, config="llama3-8b")
    out, _ = tmp_ckpt.restore(1, s, config="llama3-8b")   # match: fine
    with pytest.raises(CheckpointMismatchError, match="whisper"):
        tmp_ckpt.restore(1, s, config="whisper-large")
    # caller not passing a config keeps the old lenient behavior
    tmp_ckpt.restore(1, s)


def test_conflicting_resave_rejected(tmp_ckpt):
    """Same step, DIFFERENT state shape: no more silent no-op."""
    from repro.checkpointing.ckpt import CheckpointMismatchError
    tmp_ckpt.save(5, {"w": jnp.zeros((4,))}, config="arch-a")
    with pytest.raises(CheckpointMismatchError, match="refusing"):
        tmp_ckpt.save(5, {"w": jnp.zeros((8,))}, config="arch-a")
    with pytest.raises(CheckpointMismatchError, match="config"):
        tmp_ckpt.save(5, {"w": jnp.zeros((4,))}, config="other-arch")
    # identical manifest stays an idempotent no-op (crash-resume re-save)
    tmp_ckpt.save(5, {"w": jnp.ones((4,))}, config="arch-a")
    assert tmp_ckpt.latest_step() == 5


def test_tree_fingerprint_ignores_values():
    from repro.checkpointing.ckpt import tree_fingerprint
    a = tree_fingerprint({"w": jnp.zeros((4, 2)), "b": jnp.ones((3,))})
    b = tree_fingerprint({"w": jnp.full((4, 2), 9.0), "b": jnp.ones((3,))})
    c = tree_fingerprint({"w": jnp.zeros((4, 3)), "b": jnp.ones((3,))})
    assert a == b and a != c


# -- cross-mesh resharding round trip (ISSUE 7 satellite) -------------------
multidevice = pytest.mark.skipif(
    len(jax.devices()) < 8,
    reason="needs 8 devices (XLA_FLAGS=--xla_force_host_platform_"
           "device_count=8)")


@multidevice
def test_cross_mesh_resharding_roundtrip(tmp_path):
    """Elastic restore, mesh to mesh: save real transformer params under
    the 8-device (4,2) mesh, restore onto 1- and 2-device meshes and
    back onto (4,2) — bit-exact at every hop."""
    from repro import configs
    from repro.distributed import sharding as shd
    from repro.launch.mesh import make_mesh
    from repro.models import transformer

    cfg = configs.smoke_config("llama3-8b")
    params = transformer.init_params(cfg, jax.random.PRNGKey(0))
    ref = jax.tree_util.tree_map(lambda x: np.asarray(x), params)

    def shardings_for(mesh):
        return shd.to_shardings(mesh, shd.param_specs(cfg, params, mesh=mesh))

    mesh8 = make_mesh((4, 2), ("data", "model"))
    placed = jax.device_put(params, shardings_for(mesh8))
    mgr = CheckpointManager(str(tmp_path / "xmesh"))
    mgr.save(1, placed, config=cfg.arch_id)

    state = placed
    for shape in [(1, 1), (2, 1), (4, 2)]:
        mesh = make_mesh(shape, ("data", "model"))
        sh = shardings_for(mesh)
        state, _ = mgr.restore(1, state, shardings=sh, config=cfg.arch_id)
        for (pa, a), (pb, b) in zip(
                jax.tree_util.tree_leaves_with_path(ref),
                jax.tree_util.tree_leaves_with_path(state)):
            np.testing.assert_array_equal(
                a, np.asarray(b), err_msg=f"{shape}: {jax.tree_util.keystr(pb)}")
        # round-trip through the smaller mesh must also SAVE identically
        mgr2 = CheckpointManager(str(tmp_path / f"xmesh_{shape[0]}x{shape[1]}"))
        mgr2.save(1, state, config=cfg.arch_id)
        back, _ = mgr2.restore(1, state, shardings=shardings_for(mesh8),
                               config=cfg.arch_id)
        for a, b in zip(jax.tree_util.tree_leaves(ref),
                        jax.tree_util.tree_leaves(back)):
            np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


# -- shard checksums + intact-fallback (ISSUE 8) ----------------------------
def _corrupt_shard(mgr, step):
    d = mgr._step_dir(step)
    [shard] = [n for n in os.listdir(d) if n.endswith(".npz")]
    path = os.path.join(d, shard)
    with open(path, "r+b") as f:
        f.seek(20)
        f.write(b"\xff\xff\xff\xff")
    return path


def test_checksums_in_manifest_and_verify(tmp_ckpt):
    tmp_ckpt.save(1, _state())
    d = tmp_ckpt._step_dir(1)
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    assert manifest["checksums"], "manifest carries shard checksums"
    assert tmp_ckpt.verify(1)
    _corrupt_shard(tmp_ckpt, 1)
    assert not tmp_ckpt.verify(1)


def test_restore_rejects_corrupt_shard(tmp_ckpt):
    from repro.checkpointing.ckpt import CheckpointMismatchError
    s = _state()
    tmp_ckpt.save(1, s)
    _corrupt_shard(tmp_ckpt, 1)
    with pytest.raises(CheckpointMismatchError, match="checksum mismatch"):
        tmp_ckpt.restore(1, jax.tree_util.tree_map(jnp.zeros_like, s))


def test_restore_rejects_missing_shard(tmp_ckpt):
    from repro.checkpointing.ckpt import CheckpointMismatchError
    s = _state()
    tmp_ckpt.save(1, s)
    d = tmp_ckpt._step_dir(1)
    [shard] = [n for n in os.listdir(d) if n.endswith(".npz")]
    os.remove(os.path.join(d, shard))
    with pytest.raises(CheckpointMismatchError, match="missing"):
        tmp_ckpt.restore(1, jax.tree_util.tree_map(jnp.zeros_like, s))


def test_restore_latest_falls_back_past_corruption(tmp_ckpt):
    s = _state()
    tmp_ckpt.save(1, s)
    tmp_ckpt.save(2, _state(seed=2))
    _corrupt_shard(tmp_ckpt, 2)           # newest checkpoint is damaged
    with pytest.warns(UserWarning, match="failed verification"):
        assert tmp_ckpt.latest_intact_step() == 1
    with pytest.warns(UserWarning, match="falling back"):
        step, out, _ = tmp_ckpt.restore_latest(
            jax.tree_util.tree_map(jnp.zeros_like, s))
    assert step == 1                      # one interval lost, not the run
    for a, b in zip(jax.tree_util.tree_leaves(s),
                    jax.tree_util.tree_leaves(out)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))
    # latest_step (resume-point listing) still sees the damaged one
    assert tmp_ckpt.latest_step() == 2


def test_restore_latest_with_no_intact_checkpoint(tmp_ckpt):
    tmp_ckpt.save(1, _state())
    _corrupt_shard(tmp_ckpt, 1)
    with pytest.warns(UserWarning, match="failed verification"):
        with pytest.raises(FileNotFoundError, match="no intact"):
            tmp_ckpt.restore_latest(
                jax.tree_util.tree_map(jnp.zeros_like, _state()))


def test_pre_checksum_checkpoints_still_verify(tmp_ckpt):
    tmp_ckpt.save(1, _state())
    d = tmp_ckpt._step_dir(1)
    with open(os.path.join(d, "manifest.json")) as f:
        manifest = json.load(f)
    del manifest["checksums"]             # an older-format checkpoint
    with open(os.path.join(d, "manifest.json"), "w") as f:
        json.dump(manifest, f)
    assert tmp_ckpt.verify(1)             # trusted, not rejected
    assert tmp_ckpt.latest_intact_step() == 1
