"""Tiny deterministic stand-in for ``hypothesis`` so the property tests run
(with fixed seeded examples) on machines without the real package.

Only the surface this repo's tests use is implemented: ``given``,
``settings`` (incl. ``register_profile``/``load_profile`` no-ops) and the
``integers`` / ``lists`` / ``tuples`` strategies with ``.map``/``.filter``.
With the real hypothesis installed (see requirements-dev.txt) the test
modules import it instead and get full shrinking/coverage.
"""
from __future__ import annotations

import random

MAX_EXAMPLES = 25
_FILTER_TRIES = 200


class _Strategy:
    def __init__(self, gen):
        self._gen = gen

    def example(self, rng: random.Random):
        return self._gen(rng)

    def map(self, fn):
        return _Strategy(lambda rng: fn(self._gen(rng)))

    def filter(self, pred):
        def gen(rng):
            for _ in range(_FILTER_TRIES):
                v = self._gen(rng)
                if pred(v):
                    return v
            raise ValueError("hypothesis_fallback: filter predicate never "
                             "satisfied in %d tries" % _FILTER_TRIES)
        return _Strategy(gen)


class strategies:  # noqa: N801 - mirrors `from hypothesis import strategies`
    @staticmethod
    def integers(min_value, max_value):
        return _Strategy(lambda rng: rng.randint(min_value, max_value))

    @staticmethod
    def lists(elem: _Strategy, min_size=0, max_size=10):
        return _Strategy(lambda rng: [
            elem.example(rng)
            for _ in range(rng.randint(min_size, max_size))])

    @staticmethod
    def tuples(*elems: _Strategy):
        return _Strategy(lambda rng: tuple(e.example(rng) for e in elems))


def given(*strats: _Strategy, **kw_strats: _Strategy):
    def deco(fn):
        def wrapper(*args, **kwargs):
            rng = random.Random(0)  # deterministic across runs
            for _ in range(MAX_EXAMPLES):
                ex = [s.example(rng) for s in strats]
                kex = {k: s.example(rng) for k, s in kw_strats.items()}
                fn(*args, *ex, **kwargs, **kex)
        # deliberately NOT functools.wraps: copying __wrapped__ would make
        # pytest read the original signature and treat the injected
        # example arguments as fixtures
        wrapper.__name__ = fn.__name__
        wrapper.__doc__ = fn.__doc__
        return wrapper
    return deco


class settings:  # noqa: N801
    def __init__(self, *a, **k):
        pass

    def __call__(self, fn):
        return fn

    @staticmethod
    def register_profile(*a, **k):
        pass

    @staticmethod
    def load_profile(*a, **k):
        pass
