"""Append-only JSONL event sink (ISSUE 8 satellite): ordering, crash
tolerance, and the three producers — train guards, serve metrics, and
the fleet router (router emission is covered in test_router.py)."""
from __future__ import annotations

import json

import pytest

from repro.events import EventSink, read_events
from repro.serve.metrics import ServeMetrics, fleet_summary
from repro.train.guards import GuardConfig, TrainGuard


def test_emit_seq_and_filter(tmp_path):
    path = str(tmp_path / "ev.jsonl")
    with EventSink(path, clock=lambda: 12.5) as sink:
        sink.emit("a", x=1)
        sink.emit("b")
        sink.emit("a", x=2)
        assert sink.emitted == 3
    evs = read_events(path)
    assert [e["seq"] for e in evs] == [0, 1, 2]
    assert all(e["t"] == 12.5 for e in evs)
    assert [e["x"] for e in read_events(path, "a")] == [1, 2]


def test_append_only_across_sinks(tmp_path):
    """Two sink sessions on one path append — a restart keeps history."""
    path = str(tmp_path / "ev.jsonl")
    with EventSink(path) as s:
        s.emit("run", n=1)
    with EventSink(path) as s:
        s.emit("run", n=2)
    assert [e["n"] for e in read_events(path, "run")] == [1, 2]


def test_truncated_final_line_skipped_with_warning(tmp_path):
    path = str(tmp_path / "ev.jsonl")
    with EventSink(path) as s:
        s.emit("ok")
    with open(path, "a") as f:
        f.write('{"seq": 1, "kind": "torn')          # crash mid-write
    with pytest.warns(UserWarning, match="truncated"):
        evs = read_events(path)
    assert len(evs) == 1 and evs[0]["kind"] == "ok"


def test_flush_every_batches(tmp_path):
    path = str(tmp_path / "ev.jsonl")
    sink = EventSink(path, flush_every=3)
    sink.emit("a"), sink.emit("a")
    sink.close()                          # close flushes the tail
    assert len(read_events(path)) == 2


def test_closed_sink_raises(tmp_path):
    sink = EventSink(str(tmp_path / "ev.jsonl"))
    sink.close()
    with pytest.raises(RuntimeError, match="closed"):
        sink.emit("late")


def test_guard_streams_verdicts(tmp_path):
    path = str(tmp_path / "guard.jsonl")
    with EventSink(path) as sink:
        g = TrainGuard(GuardConfig(min_history=2, rollback_after=2),
                       sink=sink)
        for loss in (1.0, 1.1, 1.05):
            assert g.observe(loss) == g.OK
        assert g.observe(float("nan")) == g.SKIP
        assert g.observe(99.0) == g.ROLLBACK       # second bad in streak
    skips = read_events(path, "guard_skip")
    assert len(skips) == 1 and skips[0]["reason"] == "nonfinite"
    rb = read_events(path, "guard_rollback")
    assert len(rb) == 1 and rb[0]["reason"] == "spike"
    assert all("guard_step" in e for e in skips + rb)


def test_serve_metrics_stream_failure_counters(tmp_path):
    path = str(tmp_path / "serve.jsonl")
    with EventSink(path) as sink:
        m = ServeMetrics(sink=sink, replica=1)
        m.on_submit(0, 0)
        m.on_fault(0)
        m.on_retry(0)
        m.on_reject()
        m.on_terminal(0, "FAILED")
    kinds = [e["kind"] for e in read_events(path)]
    assert kinds == ["fault", "retry", "reject", "terminal"]
    # every event is replica-tagged for fleet-level attribution
    assert all(e["replica"] == 1 for e in read_events(path))
    term = read_events(path, "terminal")[0]
    assert term["rid"] == 0 and term["state"] == "FAILED"


def test_shared_sink_interleaves_producers(tmp_path):
    """A router and its replicas' metrics share ONE sink; seq orders
    the interleaved stream deterministically."""
    path = str(tmp_path / "shared.jsonl")
    with EventSink(path) as sink:
        g = TrainGuard(GuardConfig(rollback_after=2), sink=sink)
        m = ServeMetrics(sink=sink, replica=0)
        g.observe(float("inf"))
        m.on_reject()
        g.observe(float("inf"))
    evs = read_events(path)
    assert [e["kind"] for e in evs] == ["guard_skip", "reject",
                                       "guard_rollback"]
    assert [e["seq"] for e in evs] == [0, 1, 2]
    raw = [json.loads(line) for line in open(path)]
    assert len(raw) == 3


def test_read_events_kind_and_offset_combined(tmp_path):
    """kind= filtering composes with offset= resume: the filter applies
    only to records AFTER the offset, and next_offset is filter-blind
    (it advances past every complete line, matched or not)."""
    path = str(tmp_path / "inc.jsonl")
    with EventSink(path) as sink:
        sink.emit("a", n=0)
        sink.emit("b", n=1)
    first, off = read_events(path, "a", with_offset=True)
    assert [e["n"] for e in first] == [0]
    with EventSink(path) as sink:
        sink.emit("a", n=2)
        sink.emit("b", n=3)
        sink.emit("a", n=4)
    tail = read_events(path, "a", offset=off)
    assert [e["n"] for e in tail] == [2, 4]
    # unfiltered resume from the same offset sees every new record
    assert [e["n"] for e in read_events(path, offset=off)] == [2, 3, 4]
    # resuming at EOF yields nothing and a stable offset
    rest, end = read_events(path, offset=off, with_offset=True)
    again, end2 = read_events(path, offset=end, with_offset=True)
    assert again == [] and end2 == end


def test_fleet_summary_empty_fleet(tmp_path):
    """An empty replica list must aggregate to an all-zero fleet view,
    not divide by zero or KeyError."""
    out = fleet_summary([])
    assert out["n_requests"] == out["n_done"] == out["total_tokens"] == 0
    assert out["wall_s"] == 0.0
    assert out["tokens_per_s"] == out["goodput_tokens_per_s"] == 0.0
    assert out["per_replica"] == []


def test_fleet_summary_all_rejected():
    """Replicas that rejected everything: zero wall clock, zero tokens —
    rates stay 0.0 instead of dividing by zero."""
    m0, m1 = ServeMetrics(), ServeMetrics()
    for m in (m0, m1):
        m.on_reject()
        m.on_reject()
    out = fleet_summary([m0.summary(), m1.summary()])
    assert out["n_rejected"] == 4
    assert out["n_requests"] == out["n_done"] == 0
    assert out["tokens_per_s"] == 0.0
    assert out["goodput_tokens_per_s"] == 0.0


def test_fleet_summary_missing_keys_tolerated():
    """A dead worker's synthesized mirror summary may lack keys newer
    summaries carry; aggregation treats them as 0."""
    full = ServeMetrics().summary()
    out = fleet_summary([full, {"n_done": 2, "total_tokens": 9}])
    assert out["n_done"] == 2 and out["total_tokens"] == 9
    assert len(out["per_replica"]) == 2
