"""M-P policies, loss scaling dynamics, master-weight grad semantics."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.mixed_precision import (LossScale, Policy, all_finite,
                                        get_policy, scaled_value_and_grad)


def test_policy_casting():
    pol = Policy.bf16()
    tree = {"w": jnp.ones((2,), jnp.float32), "i": jnp.ones((2,), jnp.int32)}
    out = pol.cast_to_compute(tree)
    assert out["w"].dtype == jnp.bfloat16
    assert out["i"].dtype == jnp.int32          # non-float untouched


def test_grads_come_back_fp32():
    pol = Policy.fp16()
    params = {"w": jnp.ones((3, 3), jnp.float32)}
    def loss(p, x):
        return (p["w"] @ x).sum(), {}
    vg = scaled_value_and_grad(loss, pol, LossScale.init(2.0 ** 8))
    (l, _), g, fin = vg(params, jnp.ones((3,)))
    assert g["w"].dtype == jnp.float32
    assert bool(fin)
    np.testing.assert_allclose(float(l), 9.0, rtol=1e-2)
    np.testing.assert_allclose(np.asarray(g["w"]), 1.0, rtol=1e-2)


def test_loss_scale_dynamics():
    ls = LossScale.init(1024.0, growth_interval=2)
    ls = ls.update(jnp.bool_(False))            # overflow -> halve
    assert float(ls.scale) == 512.0
    ls = ls.update(jnp.bool_(True))
    ls = ls.update(jnp.bool_(True))             # 2 finite steps -> double
    assert float(ls.scale) == 1024.0
    assert int(ls.growth_counter) == 0


def test_nonfinite_detection():
    assert not bool(all_finite({"a": jnp.array([1.0, jnp.inf])}))
    assert bool(all_finite({"a": jnp.array([1.0]), "i": jnp.array([1])}))


def test_fp16_overflow_flags_step():
    pol = Policy.fp16()
    params = {"w": jnp.full((4, 4), 200.0, jnp.float32)}  # fp16 max ~65k
    def loss(p, x):
        h = p["w"] @ x
        return (h @ h).sum(), {}                # ~ (200*4)^2 * 4 -> inf fp16
    vg = scaled_value_and_grad(loss, pol, LossScale.init(2.0 ** 15))
    (_, _), g, fin = vg(params, jnp.ones((4, 4), jnp.float32))
    assert not bool(fin)
