"""Launcher-layer units: collective parser, mesh construction, memory floor,
and an end-to-end preemption (SIGTERM) resume through the real driver."""
import json
import os
import signal
import subprocess
import sys
import time

import pytest


def test_collective_parser_counts_and_widening():
    from repro.launch.dryrun import collective_bytes
    hlo = "\n".join([
        "  %all-reduce.1 = f32[16,128]{1,0} all-reduce(%x), replica_groups={}",
        "  %ar2 = (f32[4,4]{1,0}, f32[2]{0}) all-reduce(%a, %b)",
        "  %ag = bf16[8,256]{1,0} all-gather(%c), dimensions={0}",
        "  %w = f32[4,128]{1,0} all-reduce(%convert_fusion.3)",  # widened bf16
        "  %rs-start = f32[64]{0} reduce-scatter-start(%d)",
        "  %done = f32[64]{0} all-reduce-done(%rs)",             # skip -done
        "  %notacoll = f32[2]{0} add(%e, %f)",
    ])
    out = collective_bytes(hlo)
    expected_ar = 16 * 128 * 4 + (16 + 2) * 4 + 4 * 128 * 4 // 2
    assert out["all-reduce"] == expected_ar
    assert out["all-gather"] == 8 * 256 * 2
    assert out["all-reduce_widened"] == 4 * 128 * 2
    assert out["total"] == sum(v for k, v in out.items()
                               if k not in ("total",) and
                               not k.endswith("_widened"))


def test_memory_floor_positive_all_cells():
    from repro import configs
    from repro.launch.dryrun import _memory_floor_bytes
    from repro.launch.mesh import abstract_mesh
    mesh = abstract_mesh((16, 16), ("data", "model"))
    for arch in configs.list_archs():
        cfg = configs.get_config(arch)
        for shape in configs.applicable_shapes(cfg):
            fb = _memory_floor_bytes(cfg, shape, mesh, accum=4)
            assert fb > 0, (arch, shape)


def test_make_mesh_for_elastic_shapes():
    from repro.launch.mesh import make_mesh_for
    m = make_mesh_for(1)
    assert m.devices.size == 1


def test_layer_runs_partition():
    import dataclasses as dc
    from repro import configs
    from repro.models.transformer import layer_runs
    cfg = configs.get_config("hymba-1.5b")
    runs = layer_runs(cfg)
    assert runs[0] == (0, 1, True)
    assert sum(hi - lo for lo, hi, _ in runs) == cfg.n_layers
    # order-preserving and alternating
    for (a, b, g1), (c, d, g2) in zip(runs, runs[1:]):
        assert b == c and g1 != g2


def test_train_attn_backend_flag(tmp_path):
    """--attn-backend plumbs through to the config and trains (ISSUE 2:
    the flash custom_vjp backward is exercised by real optimizer steps)."""
    env = {**os.environ, "PYTHONPATH": "src", "PYTHONUNBUFFERED": "1",
           "XLA_FLAGS": ""}
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.train", "--arch", "llama3-8b",
         "--smoke", "--steps", "2", "--batch", "2", "--seq", "32",
         "--attn-backend", "interpret", "--ckpt-dir", str(tmp_path),
         "--fresh", "--log-every", "1"],
        env=env, capture_output=True, text=True, timeout=480)
    assert out.returncode == 0, out.stdout + out.stderr
    assert "attn backend: interpret" in out.stdout
    assert "step     1" in out.stdout


def test_preemption_sigterm_saves_and_resumes(tmp_path):
    env = {**os.environ, "PYTHONPATH": "src", "PYTHONUNBUFFERED": "1",
           "XLA_FLAGS": ""}  # don't inherit dryrun's 512 fake devices
    args = [sys.executable, "-m", "repro.launch.train", "--arch", "llama3-8b",
            "--smoke", "--steps", "500", "--batch", "2", "--seq", "32",
            "--ckpt-dir", str(tmp_path), "--ckpt-every", "1000",
            "--log-every", "1", "--fresh"]
    proc = subprocess.Popen(args, env=env, stdout=subprocess.PIPE,
                            stderr=subprocess.STDOUT, text=True)
    # wait until a couple of steps have logged, then preempt
    t0 = time.time()
    saw_step = False
    lines = []
    while time.time() - t0 < 480:
        line = proc.stdout.readline()
        if not line:                      # EOF: child died early
            break
        lines.append(line)
        if line.startswith("step     2"):
            saw_step = True
            break
    if not saw_step:
        proc.kill()
    assert saw_step, "trainer never reached step 2:\n" + "".join(lines[-20:])
    proc.send_signal(signal.SIGTERM)
    proc.wait(timeout=120)
    from repro.checkpointing.ckpt import CheckpointManager
    mgr = CheckpointManager(str(tmp_path))
    assert mgr.latest_step() is not None, "no checkpoint after SIGTERM"
    # resume run picks it up and finishes quickly
    resume_args = [a if a != "500" else str(mgr.latest_step() + 2)
                   for a in args[:-1]]
    out = subprocess.run(resume_args, env=env, capture_output=True, text=True,
                         timeout=240)
    assert "resumed from step" in out.stdout


def test_watchdog_alert_emits_event_and_keeps_duration_sample(tmp_path):
    """ISSUE 9 satellite: the watchdog's monitor thread must not race
    ``step_end`` — an alerted step still records its duration (the old
    implementation cleared the shared latch mid-read and dropped the
    sample) — and each alert lands in the event stream, once per step."""
    from repro.events import EventSink, read_events
    from repro.launch.train import Watchdog

    ev = str(tmp_path / "events.jsonl")
    sink = EventSink(ev)
    wd = Watchdog(factor=5.0, min_history=3, sink=sink)
    try:
        wd.times = [0.01] * 5             # fast history
        wd.step_start()
        with wd._lock:                    # the step has "run" 30 s
            wd._started = time.time() - 30.0
        deadline = time.time() + 5.0
        while wd.alerts == 0 and time.time() < deadline:
            time.sleep(0.05)
        assert wd.alerts == 1, "watchdog never alerted"
        time.sleep(1.2)                   # > 2 monitor periods
        assert wd.alerts == 1             # one alert per step generation
        n = len(wd.times)
        wd.step_end()
        assert len(wd.times) == n + 1     # alerted step still sampled
        assert wd.times[-1] > 25.0
        wd.step_start()                   # new generation re-arms
        with wd._lock:
            wd._started = time.time() - 30.0
        deadline = time.time() + 5.0
        while wd.alerts < 2 and time.time() < deadline:
            time.sleep(0.05)
        assert wd.alerts == 2
        wd.step_end()
    finally:
        wd.close()
        sink.close()
    alerts = read_events(ev, kind="watchdog_alert")
    assert len(alerts) == 2
    assert alerts[0]["factor"] == 5.0 and alerts[0]["running_s"] > 25.0


def test_watchdog_step_boundary_race(tmp_path):
    """Hammer step boundaries from the main thread while the monitor
    polls: no sample may be lost and no crash may surface regardless of
    interleaving (lock + generation counter)."""
    from repro.launch.train import Watchdog

    wd = Watchdog(factor=1000.0, min_history=2)
    try:
        for _ in range(300):
            wd.step_start()
            wd.step_end()
        assert len(wd.times) == 100       # rolling window, none dropped
        assert wd.alerts == 0
    finally:
        wd.close()
