"""Write-ahead request journal (ISSUE 9): fsync durability knob,
incremental/tail reads, the JournalState reducer, snapshot+compaction,
torn-tail tolerance, and the crash-at-every-point arming helpers.

Everything here is journal/events-level — no engines, no jit — so the
whole module runs in milliseconds and can afford to sweep crash points
exhaustively.
"""
from __future__ import annotations

import json
import os
import warnings

import pytest

from repro.events import EventSink, read_events
from repro.serve.faults import SimulatedCrash, crash_after_appends, tear_tail
from repro.serve.journal import (JournalState, RequestJournal, WAL_KINDS,
                                 load_state)


# ---------------------------------------------------------------------------
class TestEventSinkDurability:
    def test_fsync_knob(self, tmp_path):
        p = str(tmp_path / "ev.jsonl")
        with EventSink(p, fsync=True) as sink:
            sink.emit("a", x=1)
            sink.emit("b", x=2)
            assert sink.fsyncs == 2       # one os.fsync per append
        with EventSink(p) as sink:
            sink.emit("c", x=3)
            assert sink.fsyncs == 0       # default: buffer flush only

    def test_fsync_respects_flush_batching(self, tmp_path):
        p = str(tmp_path / "ev.jsonl")
        with EventSink(p, fsync=True, flush_every=3) as sink:
            sink.emit("a")
            sink.emit("b")
            assert sink.fsyncs == 0
            sink.emit("c")
            assert sink.fsyncs == 1       # fsync rides the batched flush

    def test_tell_is_end_of_written_records(self, tmp_path):
        p = str(tmp_path / "ev.jsonl")
        with EventSink(p, flush_every=10) as sink:
            sink.emit("a", x=1)
            off = sink.tell()             # flushes first
            assert off == os.path.getsize(p) > 0
            sink.emit("b", x=2)
            assert sink.tell() > off


class TestIncrementalReads:
    def test_offset_resumes_where_previous_read_ended(self, tmp_path):
        p = str(tmp_path / "ev.jsonl")
        with EventSink(p) as sink:
            sink.emit("a", i=0)
            first, off = read_events(p, with_offset=True)
            assert [r["kind"] for r in first] == ["a"]
            sink.emit("b", i=1)
            sink.emit("c", i=2)
            tail, end = read_events(p, offset=off, with_offset=True)
        assert [r["kind"] for r in tail] == ["b", "c"]
        assert end == os.path.getsize(p)
        # fully-consumed tail: next incremental read is empty
        again, end2 = read_events(p, offset=end, with_offset=True)
        assert again == [] and end2 == end

    def test_torn_tail_under_fsync_batching(self, tmp_path):
        """The regression the satellite names: with fsync batching a
        partial final line is the steady state, not a crash — tail mode
        must stop BEFORE it (silently, retryable), while the default
        mode warns and skips."""
        p = str(tmp_path / "ev.jsonl")
        with EventSink(p, fsync=True) as sink:
            sink.emit("a", i=0)
            sink.emit("b", i=1)
        size = os.path.getsize(p)
        with open(p, "a") as f:           # in-flight write: no newline yet
            f.write('{"seq": 2, "kind": "c", "half')
        with warnings.catch_warnings():
            warnings.simplefilter("error")     # tail mode must NOT warn
            recs, end = read_events(p, with_offset=True)
        assert [r["kind"] for r in recs] == ["a", "b"]
        assert end == size                # offset stops before the tear
        with pytest.warns(UserWarning, match="truncated"):
            assert len(read_events(p)) == 2    # default mode warns + skips
        # the write completes -> the SAME offset now yields the record
        with open(p, "a") as f:
            f.write('": 1}\n')
        more, _ = read_events(p, offset=end, with_offset=True)
        assert [r["kind"] for r in more] == ["c"]

    def test_kind_filter_composes_with_offset(self, tmp_path):
        p = str(tmp_path / "ev.jsonl")
        with EventSink(p) as sink:
            for i in range(6):
                sink.emit("a" if i % 2 else "b", i=i)
        _, mid = read_events(p, with_offset=True)
        with EventSink(p) as sink:
            sink.emit("a", i=6)
            sink.emit("b", i=7)
        assert [r["i"] for r in read_events(p, kind="a", offset=mid)] == [6]


# ---------------------------------------------------------------------------
def _submit(j, gid, prompt=(1, 2, 3), max_new=4, eos=None, deadline=None):
    j.submit(gid, list(prompt), max_new, eos, deadline)


class TestJournalState:
    def test_reducer_lifecycle(self):
        st = JournalState()
        st.apply("wal_submit", dict(gid=0, prompt=[1, 2], max_new_tokens=3,
                                    eos_id=None, deadline_steps=None))
        assert st.n_live == 1 and st.next_gid == 1
        st.apply("wal_place", dict(gid=0, replica=1, rid=0, front=False,
                                   emitted=0))
        st.apply("wal_tokens", dict(gid=0, start=0, toks=[5, 6]))
        st.apply("wal_migrate", dict(gid=0, reason="x"))
        rec = st.live[0]
        assert rec["tokens"] == [5, 6]
        assert rec["placements"] == 1 and rec["migrations"] == 1
        st.apply("wal_terminal", dict(gid=0, state="DONE", n_tokens=2))
        assert st.n_live == 0 and st.n_terminals == 1
        assert st.goodput_tokens == 2
        assert st.terminal_counts == {"DONE": 1}

    def test_token_splice_is_idempotent(self):
        """The start index makes a post-recovery re-emission overwrite
        the regenerated overlap instead of double-appending."""
        st = JournalState()
        st.apply("wal_submit", dict(gid=0, prompt=[1], max_new_tokens=8,
                                    eos_id=None, deadline_steps=None))
        st.apply("wal_tokens", dict(gid=0, start=0, toks=[10, 11, 12]))
        # recovery replayed from the 2-token durable prefix, then the
        # recovered run re-emitted from start=2
        st.apply("wal_tokens", dict(gid=0, start=2, toks=[12, 13]))
        assert st.live[0]["tokens"] == [10, 11, 12, 13]

    def test_duplicate_terminal_is_counted_not_applied(self):
        st = JournalState()
        st.apply("wal_submit", dict(gid=0, prompt=[1], max_new_tokens=2,
                                    eos_id=None, deadline_steps=None))
        st.apply("wal_terminal", dict(gid=0, state="DONE", n_tokens=2))
        st.apply("wal_terminal", dict(gid=0, state="DONE", n_tokens=2))
        assert st.duplicate_terminals == 1
        assert st.n_terminals == 1        # the second never lands

    def test_json_roundtrip(self):
        st = JournalState()
        st.apply("wal_submit", dict(gid=3, prompt=[7], max_new_tokens=2,
                                    eos_id=1, deadline_steps=9))
        back = JournalState.from_json(
            json.loads(json.dumps(st.to_json())))
        assert back.to_json() == st.to_json()
        assert 3 in back.live             # gid keys back to int


class TestRequestJournal:
    def test_append_reduces_incrementally_and_reopen_replays(self, tmp_path):
        p = str(tmp_path / "wal.jsonl")
        j = RequestJournal(p)
        _submit(j, 0)
        j.place(0, 0, 0, front=False, emitted=0)
        j.tokens(0, 0, [9, 8])
        _submit(j, 1)
        j.terminal(0, "DONE", n_tokens=2)
        live_json = j.state.to_json()
        j.close()
        j2 = RequestJournal(p)            # reopen = replay
        assert j2.state.to_json() == live_json
        j2.close()
        # the incremental state matches a cold full-history reduction
        st, off = load_state(p)
        assert st.to_json() == live_json
        assert off == os.path.getsize(p)

    def test_snapshot_plus_tail_equals_full_history(self, tmp_path):
        p = str(tmp_path / "wal.jsonl")
        j = RequestJournal(p)
        for g in range(4):
            _submit(j, g)
            j.tokens(g, 0, [g])
        j.terminal(0, "DONE", n_tokens=1)
        j.snapshot()
        snap_off = json.load(open(p + ".snap"))["offset"]
        j.tokens(1, 1, [42])              # tail records after the snapshot
        j.terminal(2, "CANCELLED")
        j.close()
        with_snap, off1 = load_state(p)
        os.remove(p + ".snap")
        full, off2 = load_state(p)        # O(history) fallback
        assert with_snap.to_json() == full.to_json()
        assert off1 == off2 == os.path.getsize(p) > snap_off

    def test_auto_snapshot_cadence(self, tmp_path):
        p = str(tmp_path / "wal.jsonl")
        j = RequestJournal(p, snapshot_every=3)
        for g in range(4):
            _submit(j, g)
        assert j.snapshots == 1 and os.path.exists(p + ".snap")
        j.close()

    def test_half_written_snapshot_falls_back_to_full_scan(self, tmp_path):
        p = str(tmp_path / "wal.jsonl")
        j = RequestJournal(p)
        _submit(j, 0)
        j.snapshot()
        _submit(j, 1)
        j.close()
        want = load_state(p)[0].to_json()
        with open(p + ".snap", "w") as f:
            f.write('{"offset": 12, "sta')   # torn snapshot
        assert load_state(p)[0].to_json() == want

    def test_fsync_default_on(self, tmp_path):
        p = str(tmp_path / "wal.jsonl")
        j = RequestJournal(p)
        _submit(j, 0)
        assert j._sink.fsyncs == 1        # WAL default: durable appends
        j.close()


# ---------------------------------------------------------------------------
class TestCrashHarness:
    def test_crash_after_appends_fires_after_durable_write(self, tmp_path):
        p = str(tmp_path / "wal.jsonl")
        j = RequestJournal(p)
        state = crash_after_appends(j, 2)
        _submit(j, 0)
        with pytest.raises(SimulatedCrash):
            j.place(0, 0, 0, front=False, emitted=0)
        assert state == {"appends": 2, "fired": True}
        assert "post_append" not in j.hooks    # self-uninstalls
        # the record that "killed" us is ON DISK and recoverable
        st, _ = load_state(p)
        assert st.live[0]["placements"] == 1
        j.close()

    def test_crash_at_every_point_never_loses_a_submit(self, tmp_path):
        """Exhaustive sweep: crash after EVERY append index of a small
        scripted run — recovery always sees submits >= terminals + live
        with no duplicates (the reconcile invariant), because appends
        hit disk before anything acts on them."""
        def script(j):
            _submit(j, 0)
            j.place(0, 0, 0, front=False, emitted=0)
            j.tokens(0, 0, [1, 2])
            _submit(j, 1)
            j.terminal(0, "DONE", n_tokens=2)
            j.terminal(1, "CANCELLED")
        total = 6
        for n in range(1, total + 1):
            p = str(tmp_path / f"wal{n}.jsonl")
            j = RequestJournal(p)
            crash_after_appends(j, n)
            with pytest.raises(SimulatedCrash):
                script(j)
            st, _ = load_state(p)
            assert st.duplicate_terminals == 0
            assert st.n_submits == st.n_terminals + st.n_live
            assert st.n_submits == (1 if n < 4 else 2)

    def test_tear_tail_loses_only_the_final_record(self, tmp_path):
        p = str(tmp_path / "wal.jsonl")
        j = RequestJournal(p)
        _submit(j, 0)
        j.tokens(0, 0, [1, 2, 3])
        j.tokens(0, 3, [4])               # this record will be torn
        j.close()
        full_size = os.path.getsize(p)
        new_size = tear_tail(p)
        assert new_size == os.path.getsize(p) < full_size
        st, off = load_state(p)           # tail mode: no warning path
        assert st.live[0]["tokens"] == [1, 2, 3]   # torn delta is gone
        assert off <= new_size
        # a journal REOPENED on the torn file keeps appending after the
        # recovered offset's state (the torn bytes are inert garbage the
        # tail scan never yields)
        j2 = RequestJournal(p)
        assert j2.state.live[0]["tokens"] == [1, 2, 3]
        j2.close()


class TestWalKinds:
    def test_kind_constants_cover_the_schema(self):
        assert WAL_KINDS == ("wal_submit", "wal_place", "wal_tokens",
                             "wal_migrate", "wal_terminal")
