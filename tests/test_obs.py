"""Observability plane (ISSUE 10): metrics registry, request tracing,
schema closure, memory sampling, and the tracelens timeline exporter.

The acceptance scenario lives in :class:`TestFleetTrace`: a traced,
journaled fleet run takes a replica SIGKILL (request migration) and a
whole-router crash + journal recovery, and ``tools/tracelens.py`` must
reconstruct a complete per-request timeline — segments summing exactly
to the end-to-end span — plus a valid Perfetto export, with compile
counts frozen throughout (all instrumentation is host-side).
"""
from __future__ import annotations

import importlib.util
import json
import math
import os

import jax
import numpy as np
import pytest

from repro import configs
from repro.events import EventSink, read_events
from repro.models import transformer
from repro.obs import (EVENT_KINDS, SPAN_NAMES, Histogram, MemStat,
                       MetricsRegistry, Tracer, hist_quantile, maybe_span)
from repro.obs.schema import undeclared_kinds_in_source, validate_events
from repro.serve import (DONE, TERMINAL, RequestJournal, Router,
                         ServeEngine)

_TOOLS = os.path.join(os.path.dirname(__file__), "..", "tools")
_SRC = os.path.join(os.path.dirname(__file__), "..", "src")


def _load_tracelens():
    spec = importlib.util.spec_from_file_location(
        "tracelens", os.path.join(_TOOLS, "tracelens.py"))
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    return mod


tracelens = _load_tracelens()


# ---------------------------------------------------------------------------
class TestRegistry:
    def test_counters_and_gauges(self):
        r = MetricsRegistry()
        r.inc("a")
        r.inc("a", 4)
        r.set("g", 2.5)
        assert r.count("a") == 5
        assert r.count("missing") == 0
        snap = r.snapshot()
        assert snap["counters"]["a"] == 5
        assert snap["gauges"]["g"] == {"value": 2.5, "updates": 1}

    def test_histogram_exact_moments_bounded_buckets(self):
        h = Histogram()
        vals = [0.001 * (i % 97 + 1) for i in range(10_000)]
        for v in vals:
            h.observe(v)
        assert h.n == 10_000
        assert h.mean == pytest.approx(sum(vals) / len(vals))
        assert h.min == pytest.approx(min(vals))
        assert h.max == pytest.approx(max(vals))
        # bounded memory: log2 buckets, never per-sample storage
        assert len(h.counts) < 20
        # quantiles: monotone, clamped to [min, max], 2x relative error
        q50, q95 = h.quantile(0.5), h.quantile(0.95)
        assert h.min <= q50 <= q95 <= h.max
        exact = sorted(vals)[5000]
        assert q50 / exact < 2.0 and exact / q50 < 2.0

    def test_histogram_adversarial_values(self):
        h = Histogram()
        for v in (0.0, -1.0, math.inf, 1e-300, 1e300):
            h.observe(v)
        assert h.n == 5
        assert h.quantile(0.5) >= h.min
        snap = h.to_dict()
        assert json.loads(json.dumps(snap)) == snap   # JSON-safe keys

    def test_merge_commutative_associative(self):
        regs = []
        for seed in range(3):
            r = MetricsRegistry()
            rng = np.random.RandomState(seed)
            for _ in range(50):
                r.inc("n", int(rng.randint(1, 5)))
                r.observe("lat", float(rng.exponential(0.01)))
            r.set("last", float(seed))
            regs.append(r.snapshot())
        a, b, c = regs
        m = MetricsRegistry.merge
        assert m(a, b) == m(b, a)
        assert m(m(a, b), c) == m(a, m(b, c))
        fused = m(m(a, b), c)
        assert fused["counters"]["n"] == sum(
            r["counters"]["n"] for r in regs)
        assert fused["hists"]["lat"]["n"] == 150
        # gauge winner: most updates, deterministic either order
        assert fused["gauges"]["last"]["updates"] == 1

    def test_merge_empty_histogram_placeholders(self):
        r1, r2 = MetricsRegistry(), MetricsRegistry()
        r1.histogram("h")                      # empty: min/max placeholders
        r2.observe("h", 5.0)
        for first, second in ((r1, r2), (r2, r1)):
            out = MetricsRegistry.merge(first.snapshot(), second.snapshot())
            assert out["hists"]["h"]["min"] == 5.0
            assert out["hists"]["h"]["max"] == 5.0
            assert out["hists"]["h"]["n"] == 1

    def test_hist_quantile_on_snapshot(self):
        r = MetricsRegistry()
        for v in (1.0, 2.0, 3.0, 4.0):
            r.observe("x", v)
        h = r.snapshot()["hists"]["x"]
        assert hist_quantile(h, 0.0) >= 1.0
        assert hist_quantile(h, 1.0) == 4.0
        assert hist_quantile({"n": 0, "counts": {}}, 0.5) == 0.0

    def test_emit_snapshot_event(self, tmp_path):
        p = str(tmp_path / "m.jsonl")
        sink = EventSink(p)
        r = MetricsRegistry()
        r.inc("c", 3)
        r.emit(sink, step=7)
        sink.close()
        (rec,) = read_events(p)
        assert rec["kind"] == "metrics_snapshot"
        assert rec["step"] == 7
        assert rec["snapshot"]["counters"]["c"] == 3


# ---------------------------------------------------------------------------
class TestTracer:
    def test_pairing_and_attrs(self, tmp_path):
        p = str(tmp_path / "t.jsonl")
        sink = EventSink(p)
        tr = Tracer(sink, pid="w")
        sid = tr.begin("req", trace=4, rid=4)
        with tr.span("queue", trace=4, parent=sid, reason="submit"):
            pass
        tr.end(sid, state="DONE")
        tr.end(None)                       # late-attach no-op
        sink.close()
        closed, open_ = tracelens.load_spans(p)
        assert open_ == []
        assert [s["name"] for s in closed] == ["queue", "req"]
        req = closed[1]
        assert req["pid"] == "w" and req["trace"] == 4
        assert req["attrs"]["state"] == "DONE"
        assert closed[0]["parent"] == req["sid"]
        assert req["dur"] >= closed[0]["dur"] >= 0.0

    def test_undeclared_span_name_rejected(self, tmp_path):
        sink = EventSink(str(tmp_path / "t.jsonl"))
        with pytest.raises(ValueError, match="undeclared span name"):
            Tracer(sink).begin("not_a_span")
        sink.close()

    def test_maybe_span_none_tracer(self):
        with maybe_span(None, "req"):
            pass                            # nullcontext, no error


# ---------------------------------------------------------------------------
class TestSchema:
    def test_source_tree_emits_only_declared_kinds(self):
        bad = undeclared_kinds_in_source(_SRC)
        assert bad == {}, f"undeclared event kinds: {bad}"

    def test_span_names_closed_world(self):
        assert set(SPAN_NAMES) >= {"req", "queue", "prefill", "decode",
                                   "fleet_req", "migrate", "recover",
                                   "rpc", "journal_append", "train_step"}
        assert {"span_begin", "span_end", "metrics_snapshot",
                "mem_sample"} <= set(EVENT_KINDS)


# ---------------------------------------------------------------------------
class TestMemStat:
    def test_sample_and_banner(self, tmp_path):
        p = str(tmp_path / "m.jsonl")
        sink = EventSink(p)
        reg = MetricsRegistry()
        ms = MemStat(sink=sink, registry=reg, plan_bytes=2**20)
        _keep = jax.numpy.zeros((128, 128))   # something must be live
        rec = ms.sample(3)
        sink.close()
        assert rec["step"] == 3
        assert rec["live_bytes"] > 0 and rec["n_arrays"] > 0
        assert rec["plan_bytes"] == 2**20
        assert rec["frac_of_plan"] == pytest.approx(
            rec["live_bytes"] / 2**20, abs=1e-3)
        (ev,) = read_events(p)
        assert ev["kind"] == "mem_sample"
        assert reg.snapshot()["gauges"]["mem.live_bytes"]["value"] > 0
        assert "plan" in ms.banner()
        del _keep


# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def llama():
    cfg = configs.smoke_config("llama3-8b")
    return cfg, transformer.init_params(cfg, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def engines_mod(llama):
    cfg, params = llama
    out = []
    for _ in range(2):
        e = ServeEngine(params, cfg, max_slots=2, max_len=32,
                        prompt_buckets=(16,), sampler_keys="request")
        e.warmup()
        out.append(e)
    return out


def _reset(engines):
    for e in engines:
        e.reset()
        e.hooks.clear()
        e.tracer = None
    return engines


def _prompts(n=6, seed=0):
    rng = np.random.RandomState(seed)
    vocab = configs.smoke_config("llama3-8b").vocab
    return [rng.randint(1, vocab, size=rng.randint(4, 9)).astype(np.int32)
            for _ in range(n)]


def _force_drain(engines):
    for e in engines:
        for rid, st in list(e.request_states().items()):
            if st["state"] not in TERMINAL:
                e.evict_request(rid)
        e.reset()


MAX_NEW = 8


class TestEngineTrace:
    def test_traced_run_complete_chains_zero_recompiles(
            self, engines_mod, tmp_path):
        eng = _reset(engines_mod)[0]
        compiles = eng.compile_counts()
        p = str(tmp_path / "eng.jsonl")
        sink = EventSink(p)
        eng.tracer = Tracer(sink, pid="r0")
        rids = [eng.submit(pr, MAX_NEW) for pr in _prompts(4)]
        guard = 200
        while eng.scheduler.has_work() and guard:
            eng.step()
            guard -= 1
        assert guard
        eng.tracer = None
        sink.close()
        assert eng.compile_counts() == compiles   # host-side only
        assert validate_events(p) == set()
        closed, open_ = tracelens.load_spans(p)
        assert open_ == []
        groups = tracelens.by_trace(closed)
        for rid in rids:
            names = [s["name"] for s in groups[rid]]
            assert names.count("req") == 1
            assert names.count("queue") >= 1
            assert names.count("prefill") == 1
            assert names.count("decode") >= 1
            root = tracelens._root(groups[rid])
            assert root["attrs"]["state"] == "DONE"
            segs = tracelens.segments(groups[rid], root)
            assert sum(s["dur"] for s in segs) == \
                pytest.approx(root["dur"], rel=1e-9)

    def test_metrics_state_is_o_live(self, engines_mod):
        eng = _reset(engines_mod)[0]
        for pr in _prompts(4, seed=3):
            eng.submit(pr, MAX_NEW)
        guard = 200
        while eng.scheduler.has_work() and guard:
            eng.step()
            guard -= 1
        assert guard
        assert eng.metrics._live == {}            # everything retired
        s = eng.metrics.summary()
        assert s["n_done"] == 4
        assert s["ttft_p95_s"] >= s["ttft_p50_s"] > 0


# ---------------------------------------------------------------------------
class TestFleetTrace:
    """The acceptance scenario: migration + journal recovery, traced."""

    @pytest.fixture(scope="class")
    def traced_run(self, engines_mod, tmp_path_factory):
        tmp = tmp_path_factory.mktemp("obs_fleet")
        ep, jp = str(tmp / "events.jsonl"), str(tmp / "wal.jsonl")
        sink = EventSink(ep)

        def wire(router, journal, engines):
            for i, e in enumerate(engines):
                e.tracer = Tracer(sink, pid=f"r{i}")
            router.tracer = Tracer(sink, pid="router")
            journal.tracer = Tracer(sink, pid="journal")

        compiles = [e.compile_counts() for e in engines_mod]
        # -- epoch 1: journaled run; replica 0 dies; router crashes ----
        j1 = RequestJournal(jp)
        r1 = Router(_reset(engines_mod), journal=j1)
        wire(r1, j1, engines_mod)
        gids = [r1.submit(pr, MAX_NEW) for pr in _prompts()]
        for _ in range(3):
            r1.step()
        assert r1.kill(0)                  # replica crash -> migrations
        migrated = [g for g in gids if r1.request(g).migrations > 0]
        assert migrated, "kill must migrate at least one live request"
        for _ in range(2):
            r1.step()
        assert r1.live_requests() > 0, "must crash mid-flight"
        snap1 = r1.registry_snapshot()
        del r1                             # kill -9: no goodbye
        _force_drain(engines_mod)
        j1.close()

        # -- epoch 2: fresh router recovers from the journal -----------
        j2 = RequestJournal(jp)
        r2 = Router(_reset(engines_mod), journal=j2)
        wire(r2, j2, engines_mod)
        info = r2.recover()
        assert info["n_recovered"] > 0
        guard = 600
        while r2.live_requests() > 0 and guard:
            r2.step()
            guard -= 1
        assert guard
        states = {g: r2.request(g).state for g in gids}
        snap = r2.registry_snapshot()
        rec = r2.reconcile()
        for e in engines_mod:
            e.tracer = None
        j2.close()
        sink.close()
        assert rec["ok"], rec
        assert [e.compile_counts() for e in engines_mod] == compiles
        return {"events": ep, "gids": gids, "migrated": migrated,
                "recovered": info["n_recovered"], "states": states,
                "registry": snap, "registry_precrash": snap1}

    def test_schema_clean(self, traced_run):
        assert validate_events(traced_run["events"]) == set()

    def test_every_done_request_has_one_complete_chain(self, traced_run):
        closed, _open = tracelens.load_spans(traced_run["events"])
        groups = tracelens.by_trace(closed)
        for g in traced_run["gids"]:
            if traced_run["states"][g] != DONE:
                continue
            roots = [s for s in groups[g] if s["name"] == "fleet_req"
                     and s["attrs"].get("state") == DONE]
            assert len(roots) == 1, \
                f"gid {g}: want exactly one closed DONE root"
            assert roots[0]["attrs"]["tokens"] == MAX_NEW

    def test_crash_leaves_open_spans_visible(self, traced_run):
        _closed, open_ = tracelens.load_spans(traced_run["events"])
        # the crashed router's fleet_req spans died open — the timeline
        # SHOWS the crash instead of losing it
        assert any(s["name"] == "fleet_req" for s in open_)

    def test_migrated_timeline_has_migrate_segment(self, traced_run):
        closed, _ = tracelens.load_spans(traced_run["events"])
        groups = tracelens.by_trace(closed)
        names = {n for g in traced_run["migrated"]
                 for n in (s["name"] for s in groups.get(g, []))}
        assert "migrate" in names

    def test_recovered_timeline_segments_sum_exact(self, traced_run):
        closed, _ = tracelens.load_spans(traced_run["events"])
        groups = tracelens.by_trace(closed)
        checked = 0
        for g, spans in groups.items():
            roots = [s for s in spans if s["name"] == "fleet_req"
                     and s["attrs"].get("replay")]
            for root in roots:
                segs = tracelens.segments(spans, root)
                assert sum(s["dur"] for s in segs) == \
                    pytest.approx(root["dur"], rel=1e-9)
                checked += 1
        assert checked > 0, "no recovered root spans found"

    def test_journal_and_rpc_lanes_present(self, traced_run):
        closed, _ = tracelens.load_spans(traced_run["events"])
        names = {s["name"] for s in closed}
        assert "journal_append" in names
        assert "queue" in names and "prefill" in names

    def test_perfetto_export_valid(self, traced_run, tmp_path):
        closed, open_ = tracelens.load_spans(traced_run["events"])
        doc = tracelens.perfetto(closed, open_)
        ev = doc["traceEvents"]
        assert len(ev) == len(closed) + len(open_) + \
            len({s["pid"] for s in closed + open_})
        for e in ev:
            assert e["ph"] in ("M", "B", "X")
            if e["ph"] == "X":
                assert e["dur"] >= 0 and e["ts"] >= 0
        json.dumps(doc)                    # serializable end to end
        lanes = {e["args"]["name"] for e in ev if e["ph"] == "M"}
        assert {"router", "journal", "r0", "r1"} <= lanes

    def test_fleet_registry_merges_replicas(self, traced_run):
        # the crashed router's registry held the kill's failover counts
        pre = traced_run["registry_precrash"]
        assert pre["counters"]["fleet.failovers"] >= 1
        assert pre["counters"]["fleet.migrations"] >= 1
        # recovery router: per-replica serve counters + streaming hists
        # folded in through the same order-independent merge
        snap = traced_run["registry"]
        assert snap["counters"]["serve.submitted"] > 0
        assert snap["hists"]["serve.ttft_s"]["n"] > 0
        # both sides merge cleanly into one whole-history view
        whole = MetricsRegistry.merge(pre, snap)
        assert whole["counters"]["fleet.failovers"] == \
            pre["counters"]["fleet.failovers"]

    def test_latency_table_and_gantt_render(self, traced_run):
        closed, open_ = tracelens.load_spans(traced_run["events"])
        table = tracelens.latency_table(closed)
        assert "p95 ms" in table and "fleet_req" in table
        g = tracelens.gantt(closed + open_)
        assert "requests" in g
