"""Memory planner: profiling, placement DP, budget solver, serialization."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.checkpoint import CheckpointConfig
from repro.plan import (ChainProfile, RematPlan, budget_boundaries,
                        min_peak_boundaries, plan_for_budget, plan_metrics,
                        plan_min_peak, plan_report, profile_resnet,
                        profile_sequential, profile_transformer)

UNET = [100, 60, 8, 4, 8, 60, 100]  # bytes: bottleneck in the middle


class TestSolver:
    def test_picks_unet_bottleneck(self):
        """Fig. 11: checkpoints land on the narrow middle activations."""
        b = min_peak_boundaries(UNET, 2)
        assert set(b) <= {3, 4, 5}, b  # sites storing the 4/8-byte acts
        assert plan_metrics(UNET, [1.0] * 7, b)["stored_bytes"] <= 12

    def test_peak_bounded_by_no_remat(self):
        # (peak is NOT monotone in k — storing an extra forced checkpoint
        # can cost more than it saves — but it never exceeds no-remat)
        no_remat = sum(UNET)
        peaks = []
        for k in range(1, 6):
            b = min_peak_boundaries(UNET, k)
            peaks.append(plan_metrics(UNET, [1.0] * 7, b)["peak_bytes"])
            assert peaks[-1] <= no_remat
        assert min(peaks) < no_remat  # checkpointing actually helps

    def test_budget_monotonicity(self):
        """Looser budget -> less (or equal) recompute FLOPs."""
        flops = [10.0, 20.0, 5.0, 5.0, 5.0, 20.0, 10.0]
        prev = float("inf")
        for budget in (50, 120, 180, 250, 340, 1000):
            b, _ = budget_boundaries(UNET, flops, budget)
            rec = plan_metrics(UNET, flops, b)["recompute_flops"]
            assert rec <= prev, (budget, b)
            prev = rec

    def test_budget_respected_when_feasible(self):
        b, feasible = budget_boundaries(UNET, [1.0] * 7, 250)
        assert feasible
        assert plan_metrics(UNET, [1.0] * 7, b)["peak_bytes"] <= 250

    def test_loose_budget_means_no_remat(self):
        b, feasible = budget_boundaries(UNET, [1.0] * 7, 10_000)
        assert feasible and b == []

    def test_infeasible_budget_falls_back_to_min_peak(self):
        b, feasible = budget_boundaries(UNET, [1.0] * 7, 1)
        assert not feasible and len(b) >= 1

    def test_recompute_is_prefix_of_last_boundary(self):
        flops = [float(10 ** i) for i in range(1, 8)]
        m = plan_metrics(UNET, flops, [2, 5])
        assert m["recompute_flops"] == sum(flops[:5])


class TestRematPlan:
    def test_json_round_trip(self):
        p = RematPlan(12, (3, 7, 9), policy=("full", "dots", "none", "full"),
                      source="budget:1234")
        assert RematPlan.from_json(p.to_json()) == p
        q = RematPlan(5, (2,))
        assert RematPlan.from_json(q.to_json()) == q

    def test_file_round_trip(self, tmp_path):
        p = plan_for_budget(ChainProfile(tuple(UNET), (1.0,) * 7), 250)
        f = str(tmp_path / "plan.json")
        p.save(f)
        assert RematPlan.load(f) == p

    def test_uniform_matches_even_split(self):
        p = RematPlan.uniform(12, 4)
        assert p.segment_sizes() == [3, 3, 3, 3]
        assert RematPlan.uniform(7, 3).n_segments == 3

    def test_validation(self):
        with pytest.raises(ValueError):
            RematPlan(4, (0,))            # boundary at chain start
        with pytest.raises(ValueError):
            RematPlan(4, (4,))            # boundary at chain end
        with pytest.raises(ValueError):
            RematPlan(8, (2, 4), policy=("full",))  # wrong policy count


class TestProfiles:
    def test_sequential_profile_tracks_shapes(self):
        fns = [lambda x: jnp.tanh(x @ jnp.ones((8, 2))),   # narrow
               lambda x: jnp.tanh(x @ jnp.ones((2, 8))),   # wide again
               lambda x: x.sum(-1)]
        prof = profile_sequential(fns, jax.ShapeDtypeStruct((4, 8),
                                                            jnp.float32))
        assert prof.n_layers == 3
        assert prof.act_bytes[0] == 4 * 2 * 4      # (4, 2) f32
        assert prof.act_bytes[1] == 4 * 8 * 4
        assert all(f > 0 for f in prof.flops)
        assert ChainProfile.from_json(prof.to_json()) == prof

    def test_resnet_profile_is_heterogeneous(self):
        from repro.models import cnn
        cfg = cnn.resnet18(stem_stride=2)
        params = cnn.init_params(cfg, jax.random.PRNGKey(0))
        prof = profile_resnet(params, cfg,
                              jax.ShapeDtypeStruct((2, 64, 64, 3),
                                                   jnp.float32))
        assert prof.n_layers == cnn.num_layer_fns(cfg)
        # strided stages shrink activations: profile must not be flat
        assert max(prof.act_bytes) > 2 * min(prof.act_bytes[:-1])
        # the planner prefers the narrow late sites over an even split:
        # strictly fewer stored checkpoint bytes at the same count, and
        # never a worse peak
        for k in (3, 4, 5):
            planned = min_peak_boundaries(prof.act_bytes, k)
            uniform = RematPlan.uniform(prof.n_layers, k + 1).boundaries
            assert len(planned) == len(uniform)
            mp = plan_metrics(prof.act_bytes, prof.flops, planned)
            mu = plan_metrics(prof.act_bytes, prof.flops, uniform)
            assert mp["stored_bytes"] < mu["stored_bytes"]
            assert mp["peak_bytes"] <= mu["peak_bytes"]

    def test_transformer_profile_window_aware(self):
        from repro import configs
        import dataclasses
        cfg = dataclasses.replace(configs.smoke_config("hymba-1.5b"),
                                  n_layers=4, global_layers=(0,), window=16)
        prof = profile_transformer(
            cfg, {"tokens": jax.ShapeDtypeStruct((2, 64), jnp.int32)})
        assert prof.n_layers == 4
        # global layer 0 attends full context -> more recompute FLOPs
        assert prof.flops[0] > prof.flops[1]
        assert len(set(prof.act_bytes)) == 1  # carry bytes are uniform


class TestBackendAwareResiduals:
    """ISSUE 2: flash layers carry O(S*D) residuals, not S^2 scores."""

    def _profiles(self, s=512):
        import dataclasses
        from repro import configs
        # head_dim pinned to a Mosaic-legal 64: the smoke config's 16
        # would make the pallas backend INELIGIBLE (silent ref fallback)
        # and the profiler must then budget S^2 — tested separately below
        cfg = dataclasses.replace(configs.smoke_config("llama3-8b"),
                                  head_dim=64)
        batch = {"tokens": jax.ShapeDtypeStruct((2, s), jnp.int32)}
        p_jnp = profile_transformer(cfg, batch)
        p_fla = profile_transformer(
            dataclasses.replace(cfg, attn_backend="pallas"), batch)
        return cfg, p_jnp, p_fla

    def test_flash_resid_subquadratic(self):
        cfg, p_jnp, p_fla = self._profiles()
        assert p_jnp.resid_bytes and p_fla.resid_bytes
        # jnp budgets the f32 (S x S) probability matrix; flash only the
        # O(S*D) stats -> the S^2 phantom is gone from every layer
        s2 = 4 * 2 * cfg.n_heads * 512 * 512
        for rj, rf in zip(p_jnp.resid_bytes, p_fla.resid_bytes):
            assert rj - rf == s2 - 2 * 4 * 2 * cfg.n_heads * 512
            assert rf < rj / 2

    def test_resid_widens_planned_peak(self):
        _, p_jnp, p_fla = self._profiles()
        plan = plan_min_peak(p_jnp, 3)
        rep_jnp = plan_report(p_jnp, plan)
        rep_fla = plan_report(p_fla, plan)
        assert rep_jnp["peak_bytes"] > rep_fla["peak_bytes"]
        assert rep_jnp["resid_bytes_total"] > rep_fla["resid_bytes_total"]
        # carries are identical; only the live-set term moved
        assert rep_jnp["stored_bytes"] == rep_fla["stored_bytes"]

    def test_solver_resid_shifts_boundaries(self):
        # two fat-residual layers at the end: the resid-aware DP must cut
        # them apart while the resid-blind one sees a flat chain
        act = [10] * 6
        resid = [0, 0, 0, 0, 100, 100]
        blind = min_peak_boundaries(act, 1)
        aware = min_peak_boundaries(act, 1, resid_bytes=resid)
        m_blind = plan_metrics(act, [1.0] * 6, blind, resid_bytes=resid)
        m_aware = plan_metrics(act, [1.0] * 6, aware, resid_bytes=resid)
        assert aware == [5]                       # splits the two fat layers
        assert m_aware["peak_bytes"] < m_blind["peak_bytes"]

    def test_budget_solver_accounts_resid(self):
        act = [10] * 6
        resid = [0, 0, 0, 0, 100, 100]
        # feasible without resid, infeasible live-set once resid counts
        b_blind, ok_blind = budget_boundaries(act, [1.0] * 6, 80)
        assert ok_blind and b_blind == []
        b_aware, ok_aware = budget_boundaries(act, [1.0] * 6, 80,
                                              resid_bytes=resid)
        assert not ok_aware or b_aware != []

    def test_flash_bwd_recompute_flops(self):
        import dataclasses
        from repro import configs
        from repro.kernels.flash.kernel import tile_step_counts
        from repro.plan import flash_bwd_recompute_flops
        cfg = dataclasses.replace(configs.smoke_config("llama3-8b"),
                                  attn_backend="pallas", head_dim=64)
        per_layer = flash_bwd_recompute_flops(cfg, 2, 512)
        assert len(per_layer) == cfg.n_layers
        # dQ and dKV each recompute scores, but only on the tiles their
        # sparse grids visit — NOT the dense (S x S) rectangle
        c = tile_step_counts(512, causal=True, window=0)
        expect = 2.0 * 2 * cfg.n_heads * cfg.head_dim * c["bq"] * c["bk"] \
            * (c["dq"] + c["dkv"])
        assert per_layer[0] == expect
        dense = 4.0 * 2 * 512 * 512 * cfg.n_heads * cfg.head_dim
        assert per_layer[0] < 0.7 * dense     # causal claws back ~2x
        cfg_jnp = dataclasses.replace(cfg, attn_backend="jnp")
        assert sum(flash_bwd_recompute_flops(cfg_jnp, 2, 512)) == 0.0

    def test_resid_follows_effective_dispatch_not_config_flag(self):
        """Asking for a flash backend is not enough: shapes/archs where
        the model silently falls back to the jnp/ref path must still be
        budgeted at O(S^2), or budget plans OOM."""
        import dataclasses
        from repro import configs
        from repro.plan import flash_training_eligible
        batch = {"tokens": jax.ShapeDtypeStruct((2, 512), jnp.int32)}
        # smoke head_dim=16: pallas falls back to ref -> S^2 budget
        tiny = dataclasses.replace(configs.smoke_config("llama3-8b"),
                                   attn_backend="pallas")
        assert not flash_training_eligible(tiny, 512)
        assert profile_transformer(tiny, batch).resid_bytes == \
            profile_transformer(dataclasses.replace(
                tiny, attn_backend="jnp"), batch).resid_bytes
        # ...but the interpreter executes any head_dim -> O(S*D) budget
        interp = dataclasses.replace(tiny, attn_backend="interpret")
        assert flash_training_eligible(interp, 512)
        assert profile_transformer(interp, batch).resid_bytes < \
            profile_transformer(tiny, batch).resid_bytes
        # global_layers force traced windows -> jnp path on any backend
        hyb = dataclasses.replace(configs.smoke_config("hymba-1.5b"),
                                  attn_backend="interpret")
        assert hyb.global_layers and not flash_training_eligible(hyb, 512)


class TestPlannedExecution:
    def test_planned_resnet_grads_match(self):
        """A solved plan through cnn.forward reproduces plain grads."""
        from repro.models import cnn
        cfg = cnn.resnet18()
        params = cnn.init_params(cfg, jax.random.PRNGKey(0))
        imgs = jnp.asarray(np.random.default_rng(0).normal(
            size=(2, 16, 16, 3)).astype(np.float32))
        labels = jnp.asarray([1, 3])
        prof = profile_resnet(params, cfg, imgs)
        plan = plan_min_peak(prof, 4)
        assert plan.boundaries  # the DP actually placed checkpoints

        def loss(p, remat):
            return cnn.loss_fn(p, cfg, imgs, labels, remat=remat)[0]

        g_plain = jax.grad(loss)(params, None)
        g_plan = jax.grad(loss)(params, CheckpointConfig(plan=plan))
        for a, b in zip(jax.tree_util.tree_leaves(g_plain),
                        jax.tree_util.tree_leaves(g_plan)):
            np.testing.assert_allclose(np.asarray(a), np.asarray(b),
                                       rtol=2e-4, atol=2e-5)

    def test_planned_transformer_loss_matches(self):
        from repro import configs
        from repro.models import transformer
        import dataclasses
        cfg = dataclasses.replace(configs.smoke_config("llama3-8b"),
                                  n_layers=6)
        params = transformer.init_params(cfg, jax.random.PRNGKey(0))
        batch = {"tokens": jnp.ones((2, 16), jnp.int32),
                 "labels": jnp.ones((2, 16), jnp.int32)}
        prof = profile_transformer(cfg, batch)
        with pytest.warns(UserWarning, match="infeasible"):
            # budget below any achievable peak: warned, best-effort plan
            plan = plan_for_budget(prof, 2 * prof.act_bytes[0] + 1)
        assert plan.boundaries  # tight budget forces checkpoints

        l_plain = transformer.loss_fn(
            params, cfg, batch, remat=CheckpointConfig(enabled=False))[0]
        l_plan = transformer.loss_fn(
            params, cfg, batch, remat=CheckpointConfig(plan=plan))[0]
        np.testing.assert_allclose(np.asarray(l_plain), np.asarray(l_plan),
                                   rtol=1e-5)

    def test_plan_policy_wins_in_both_paths(self):
        """A plan carries its policy: identical precedence for the scan
        path (CheckpointConfig.segment_policy) and the sequential path."""
        from repro.core.checkpoint import POLICIES
        cfgr = CheckpointConfig(policy="dots",
                                plan=RematPlan(4, (2,), policy="none"))
        assert cfgr.segment_policy(0) is POLICIES["none"]  # plan, not "dots"
        assert CheckpointConfig(policy="dots").segment_policy(0) \
            is POLICIES["dots"]

    def test_microbatch_specs_shard_and_dtype(self):
        """The planner budgets the PER-DEVICE microbatch in the policy's
        compute dtype (regression: global batch + hardcoded bf16)."""
        from repro.launch.mesh import abstract_mesh
        from repro.train.train_step import microbatch_specs
        sds = {"tokens": jax.ShapeDtypeStruct((64, 32), jnp.int32)}
        mesh = abstract_mesh((16, 1), ("data", "model"))
        assert microbatch_specs(sds, accum=2,
                                mesh=mesh)["tokens"].shape == (2, 32)
        assert microbatch_specs(sds, accum=2)["tokens"].shape == (32, 32)
        from repro import configs
        cfg = configs.smoke_config("llama3-8b")
        mb = {"tokens": jax.ShapeDtypeStruct((2, 16), jnp.int32)}
        p16 = profile_transformer(cfg, mb, dtype_bytes=2)
        p32 = profile_transformer(cfg, mb, dtype_bytes=4)
        assert p32.act_bytes[0] == 2 * p16.act_bytes[0]

    def test_report_fields(self):
        prof = ChainProfile(tuple(UNET), tuple(float(i + 1) for i in range(7)))
        rep = plan_report(prof, plan_min_peak(prof, 2))
        for key in ("peak_bytes", "stored_bytes", "recompute_flops",
                    "segment_sizes", "recompute_frac", "no_remat_bytes"):
            assert key in rep
        assert 0 <= rep["recompute_frac"] <= 1
