"""Replica fleet (ISSUE 8): router admission, the error-budget circuit
breaker, cross-replica migration, crash failover, elastic drain/rejoin,
fleet metrics reconciliation, and the event sink.

The chaos acceptance scenario: a seeded trace over 2 replicas with one
replica killed mid-flight — every non-cancelled request still completes,
migrated requests' greedy tokens exactly match a fault-free
single-engine run, both slot pools audit to zero leaks, the fleet
summary reconciles against the trace + fault plan, and the surviving
replica's jit program cache stays frozen.
"""
from __future__ import annotations

import jax
import numpy as np
import pytest

from repro import configs
from repro.events import EventSink, read_events
from repro.models import transformer
from repro.serve import (DEAD, DEGRADED, DONE, DRAINED, DRAINING, FAILED,
                         HEALTHY, QUARANTINED, AdmissionRejected,
                         BreakerConfig, FaultPlan, FleetFaultInjector,
                         Router, ServeEngine, TraceRequest, chaos_plan)


def _smoke_cfg():
    return configs.smoke_config("llama3-8b")


@pytest.fixture(scope="module")
def llama():
    cfg = _smoke_cfg()
    return cfg, transformer.init_params(cfg, jax.random.PRNGKey(0))


@pytest.fixture(scope="module")
def engines_mod(llama):
    """Two warmed greedy replicas in per-request key mode (identical
    construction — same base seed, as a fleet deployment would)."""
    cfg, params = llama
    out = []
    for _ in range(2):
        e = ServeEngine(params, cfg, max_slots=3, max_len=32,
                        max_retries=2, sampler_keys="request")
        e.warmup()
        out.append(e)
    return out


def _reset(engines):
    for e in engines:
        e.reset()
        e.hooks.clear()
        e.deadline_steps = None
        e.max_retries = 2
        e.retry_backoff_steps = 1
        e.scheduler.max_queue = None
    return engines


@pytest.fixture
def fleet(engines_mod):
    """Fresh Router over the shared warmed replicas."""
    return Router(_reset(engines_mod))


def _prompts(n, seed=0, lo=4, hi=10):
    rng = np.random.default_rng(seed)
    vocab = _smoke_cfg().vocab
    return [rng.integers(1, vocab, size=int(rng.integers(lo, hi)))
            .astype(np.int32) for _ in range(n)]


def _trace(n=8, seed=7, spread=6, max_new=(4, 8)):
    rng = np.random.default_rng(seed)
    return [TraceRequest(arrival_step=int(rng.integers(0, spread + 1)),
                         prompt=p,
                         max_new_tokens=int(rng.integers(*max_new)))
            for p in _prompts(n, seed=seed)]


def _drive(router, guard=600):
    while router.live_requests() > 0 and guard:
        router.step()
        guard -= 1
    assert guard, "fleet failed to drain"


# ---------------------------------------------------------------------------
class TestBreakerConfig:
    def test_validation(self):
        with pytest.raises(ValueError, match="window"):
            BreakerConfig(window_steps=0)
        with pytest.raises(ValueError, match="degrade_faults"):
            BreakerConfig(degrade_faults=5, quarantine_faults=3)

    def test_router_validation(self, engines_mod):
        engines = _reset(engines_mod)
        with pytest.raises(ValueError, match="policy"):
            Router(engines, policy="random")
        with pytest.raises(ValueError, match="at least one"):
            Router([])


class TestRouting:
    def test_least_loaded_spreads_with_index_tiebreak(self, fleet):
        gids = [fleet.submit(p, 2) for p in _prompts(4)]
        placements = [fleet.request(g).placements[0][0] for g in gids]
        # empty fleet: tie broken by index -> 0 first, then the less
        # loaded 1, alternating as queues balance
        assert placements == [0, 1, 0, 1]
        _drive(fleet)
        assert all(fleet.request(g).state == DONE for g in gids)

    def test_round_robin_rotates(self, engines_mod):
        router = Router(_reset(engines_mod), policy="round_robin")
        gids = [router.submit(p, 2) for p in _prompts(4)]
        assert [router.request(g).placements[0][0] for g in gids] \
            == [0, 1, 0, 1]
        _drive(router)

    def test_fleet_backpressure_when_all_reject(self, fleet):
        for e in fleet.engines:
            e.scheduler.max_queue = 1
        for p in _prompts(2):
            fleet.submit(p, 2)            # one queued per replica
        with pytest.raises(AdmissionRejected, match="fleet backpressure"):
            fleet.submit(_prompts(1)[0], 2)
        assert fleet.rejected == 1
        assert fleet.summary()["fleet"]["n_rejected"] == 1
        _drive(fleet)

    def test_fleet_cancel_is_idempotent(self, fleet):
        [p] = _prompts(1)
        gid = fleet.submit(p, 6)
        assert fleet.cancel(gid) and not fleet.cancel(gid)
        _drive(fleet)
        assert fleet.summary()["fleet"]["n_cancelled"] == 1


class TestBreaker:
    def test_sick_replica_degrades_quarantines_and_rejoins(self, engines_mod):
        b = BreakerConfig(window_steps=6, degrade_faults=1,
                          quarantine_faults=2, cooldown_steps=3,
                          stall_steps=50)
        router = Router(_reset(engines_mod), breaker=b)
        # long request pinned to replica 0, repeatedly poisoned there
        [p] = _prompts(1)
        gid = fleet_gid = router.submit(p, 8)
        assert router.request(gid).placements[0][0] == 0
        # poison replica 0 whenever the victim is resident (events that
        # catch it queued in retry backoff land nowhere) — at least two
        # land inside the 6-step window, tripping the quarantine budget
        plan = FaultPlan()
        for s in (2, 4, 5, 6, 7):
            plan.replica_sick(s, 0)
        inj = FleetFaultInjector(router, plan)
        seen = set()
        for _ in range(40):
            router.step()
            seen.add(router.health[0])
            if router.live_requests() == 0 and QUARANTINED in seen:
                break
        _drive(router)
        assert {DEGRADED, QUARANTINED} <= seen
        for _ in range(b.cooldown_steps + b.window_steps + 1):
            router.step()                 # idle steps age the breaker
        # cooldown rejoined it (probation first, HEALTHY once clean)
        assert router.health[0] in (DEGRADED, HEALTHY)
        assert router.time_in_quarantine[0] >= b.cooldown_steps
        # the victim migrated to replica 1 and still finished
        fr = router.request(fleet_gid)
        assert fr.state == DONE and fr.migrations >= 1
        assert inj.injected["replica_sick"] >= 1
        assert router.summary()["reconcile"]["ok"]

    def test_stalled_replica_quarantined(self, engines_mod):
        b = BreakerConfig(window_steps=8, quarantine_faults=3,
                          cooldown_steps=4, stall_steps=3)
        router = Router(_reset(engines_mod), breaker=b)
        gid = router.submit(_prompts(1)[0], 6)
        router.step()                     # request resident on replica 0
        assert router.pause(0, 10)
        for _ in range(b.stall_steps + 1):
            router.step()
        assert router.health[0] == QUARANTINED
        _drive(router)
        fr = router.request(gid)
        assert fr.state == DONE and fr.migrations == 1
        assert fr.placements[-1][0] == 1  # finished on the survivor


class TestDrainRejoin:
    def test_drain_migrates_queued_lets_residents_finish(self, fleet):
        counts0 = fleet.engines[0].compile_counts()
        gids = [fleet.submit(p, 5) for p in _prompts(6, seed=3)]
        fleet.step()                      # some resident, some queued
        fleet.drain_replica(0)
        assert fleet.health[0] == DRAINING
        _drive(fleet)
        assert fleet.health[0] == DRAINED
        assert all(fleet.request(g).state == DONE for g in gids)
        # elastic rejoin: back in rotation, ZERO recompiles
        fleet.rejoin(0)
        assert fleet.health[0] == HEALTHY
        g2 = fleet.submit(_prompts(1, seed=9)[0], 3)
        _drive(fleet)
        assert fleet.request(g2).state == DONE
        assert fleet.engines[0].compile_counts() == counts0

    def test_rejoin_rejects_wrong_state(self, fleet):
        with pytest.raises(ValueError, match="DRAINED"):
            fleet.rejoin(0)               # HEALTHY, nothing to rejoin

    def test_drain_twice_is_idempotent(self, fleet):
        gid = fleet.submit(_prompts(1)[0], 3)
        fleet.drain_replica(0)
        fleet.drain_replica(0)            # no-op, no double-migrate
        _drive(fleet)
        assert fleet.request(gid).state == DONE
        assert fleet.summary()["reconcile"]["ok"]


class TestMigrationBudget:
    def test_exhausted_budget_fails_at_fleet_level(self, engines_mod):
        router = Router(_reset(engines_mod), max_migrations=0)
        gid = router.submit(_prompts(1)[0], 8)
        router.step()
        assert router.kill(router.request(gid).placements[0][0])
        assert router.request(gid).state == FAILED
        assert router.summary()["fleet"]["n_failed"] == 1

    def test_kill_is_idempotent(self, fleet):
        assert fleet.kill(0)
        assert not fleet.kill(0)
        assert fleet.health[0] == DEAD


class TestChaosAcceptance:
    """The ISSUE 8 acceptance scenario (see module docstring)."""

    def test_replica_kill_mid_trace(self, engines_mod):
        engines = _reset(engines_mod)
        trace = _trace(n=8, seed=7)
        # fault-free reference: the same trace on ONE engine (greedy
        # decode is placement-independent, so this is the ground truth
        # token stream for every request)
        ref_sum = engines[0].run(trace)
        assert ref_sum["n_done"] == len(trace)
        ref = {r.rid: list(r.tokens) for r in engines[0]._requests_done}
        _reset(engines)

        router = Router(engines, breaker=BreakerConfig(window_steps=8))
        plan = FaultPlan().replica_crash(4, 1)
        inj = FleetFaultInjector(router, plan)
        counts0 = engines[0].compile_counts()
        summ = router.run(trace)

        assert inj.injected["replica_crash"] == 1
        assert not summ["stalled"]
        # every non-cancelled request completed, token-exact vs the
        # fault-free run (trace submit order == gid order == ref rid)
        assert summ["fleet"]["n_done"] == len(trace)
        order = sorted(range(len(trace)),
                       key=lambda i: trace[i].arrival_step)
        for gid in range(len(trace)):
            fr = router.request(gid)
            assert fr.state == DONE
            assert fr.tokens == ref[gid], \
                f"gid {gid} diverged after failover"
        # the kill actually moved work (replica 1 had live requests)
        assert summ["fleet"]["failovers"] >= 1
        assert summ["fleet"]["n_migrated_requests"] >= 1
        assert summ["fleet"]["replay_success_rate"] == 1.0
        # zero slot leaks on BOTH replicas — including the dead one,
        # whose ledger was closed out by the crash harvest
        for e in engines:
            assert e.pool.allocs == e.pool.frees
            assert e.pool.occupancy == 0
            e.pool.audit()
        # ledger reconciliation: fleet table vs every replica ledger
        rec = summ["reconcile"]
        assert rec["ok"], rec
        assert rec["placements"] == len(trace) + summ["fleet"]["n_migrations"]
        # goodput accounting: every request's full stream counted once
        assert summ["fleet"]["goodput_tokens"] == \
            sum(len(ref[g]) for g in range(len(trace)))
        # frozen program cache on the survivor: failover replays ride
        # the same compiled prefill/decode programs
        assert engines[0].compile_counts() == counts0
        assert router.health[1] == DEAD and len(order) == len(trace)

    def test_seeded_chaos_plan_is_replayable(self):
        p1 = chaos_plan(11, steps=20, replicas=2, n_events=5)
        p2 = chaos_plan(11, steps=20, replicas=2, n_events=5)
        assert [vars(a) for a in p1.events] == [vars(b) for b in p2.events]
        p3 = chaos_plan(12, steps=20, replicas=2, n_events=5)
        assert [vars(a) for a in p1.events] != [vars(b) for b in p3.events]


class TestPlacementIndependentSampling:
    """sampler_keys="request": a request's sampled trajectory is a pure
    function of (base seed, key_id, draw index) — independent of the
    slot, step, co-tenants, or replica that serve it."""

    @pytest.fixture(scope="class")
    def sampled_engines(self, llama):
        cfg, params = llama
        out = []
        for _ in range(2):
            e = ServeEngine(params, cfg, max_slots=3, max_len=32,
                            temperature=0.7, top_k=8, seed=13,
                            max_retries=2, sampler_keys="request")
            e.warmup()
            out.append(e)
        return out

    def test_trajectory_ignores_slot_step_and_cotenants(self,
                                                        sampled_engines):
        eng = _reset(sampled_engines)[0]
        [p] = _prompts(1, seed=5)
        eng.submit(p, 6, key_id=100)      # alone, slot 0, step 0
        while eng.scheduler.has_work():
            eng.step()
        ref = list(eng._requests_done[0].tokens)
        eng.reset()
        for q in _prompts(3, seed=6):     # crowd the pool first
            eng.submit(q, 5)
        for _ in range(4):
            eng.step()
        eng.submit(p, 6, key_id=100)      # later step, different slot
        while eng.scheduler.has_work():
            eng.step()
        got = next(list(r.tokens) for r in eng._requests_done
                   if r.key_id == 100)
        assert got == ref

    def test_migration_preserves_sampled_trajectory(self, sampled_engines):
        engines = _reset(sampled_engines)
        trace = _trace(n=6, seed=21, max_new=(5, 9))
        # fault-free single-engine reference: local rids == fleet gids
        # (same submit order), and key_id defaults to the rid — so the
        # per-request key streams match the fleet run exactly
        engines[0].run(trace)
        ref = {r.rid: list(r.tokens) for r in engines[0]._requests_done}
        assert len(ref) == len(trace)
        _reset(engines)

        router = Router(engines)
        inj = FleetFaultInjector(router, FaultPlan().replica_crash(3, 0))
        summ = router.run(trace)
        assert inj.injected["replica_crash"] == 1
        assert summ["fleet"]["n_done"] == len(trace)
        assert summ["fleet"]["n_migrated_requests"] >= 1
        for gid in range(len(trace)):
            fr = router.request(gid)
            assert fr.state == DONE
            assert fr.tokens == ref[gid], \
                f"sampled gid {gid} diverged after failover"
        assert summ["reconcile"]["ok"]


class TestEvents:
    def test_router_streams_health_and_failover_events(self, engines_mod,
                                                       tmp_path):
        path = str(tmp_path / "fleet.jsonl")
        with EventSink(path) as sink:
            router = Router(_reset(engines_mod), sink=sink)
            gid = router.submit(_prompts(1)[0], 6)
            router.step()
            router.kill(router.request(gid).placements[0][0])
            _drive(router)
        health = read_events(path, "health")
        assert any(e["to"] == DEAD for e in health)
        fail = read_events(path, "failover")
        assert fail and fail[0]["gid"] == gid
        places = read_events(path, "place")
        assert len(places) == 2           # initial + failover placement
        assert places[1]["front"] and places[1]["emitted"] >= 1
        done = read_events(path, "fleet_terminal")
        assert any(e["state"] == DONE and e["gid"] == gid for e in done)
